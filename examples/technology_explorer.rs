//! Technology exploration (paper Section IV-B / Table I): evaluate the
//! MWC computing element with polysilicon (baseline), MOR, WOx HDLR, and
//! RRAM resistive technologies, and the 128x128-array extension the paper
//! projects for HDLR post-processing.
//!
//! Run: cargo run --release --example technology_explorer

use acore_cim::analog::power::{self, technologies};
use acore_cim::util::table::{eng, f, Table};

fn main() {
    let techs = technologies();
    let base = techs[0].clone();

    let mut t = Table::new("Table I — MWC with various resistive technologies").header(&[
        "technology",
        "R_U",
        "MWC area 1b-6b [um^2]",
        "unit current",
        "area improv.",
        "power improv.",
    ]);
    for tech in &techs {
        let (ai, pi) = (
            tech.area_improvement(&base),
            tech.power_improvement(&base),
        );
        t.row(&[
            tech.name.to_string(),
            eng(tech.r_u, "Ohm"),
            format!("{} - {}", tech.area_1b_um2, tech.area_6b_um2),
            eng(tech.unit_current(), "A"),
            if tech.name == base.name { "baseline".into() } else { format!("{:.0}x", ai) },
            if tech.name == base.name { "baseline".into() } else { format!("{:.2}x", pi) },
        ]);
    }
    t.print();
    println!("paper Table I: MOR 14x/17x, WOx 14x/70x, RRAM 225x/0.08x\n");

    // HDLR extension: 128x128 MWC array in the same 0.14 mm^2 footprint
    let mor = &techs[1];
    let cells = 128.0 * 128.0;
    let area_mm2 = cells * mor.area_6b_um2 / 1e6 * 1.1; // 10% routing overhead
    let power_w = cells * mor.unit_current() * 0.5 * 0.8; // half-scale codes, 0.8 V
    println!(
        "HDLR extension (Section IV-B): 128x128 MOR array = {:.3} mm^2 (paper: ~0.14 mm^2), \
         array power {:.2} mW, {:.0}x more MACs/cycle than the 36x32 prototype",
        area_mm2,
        power_w * 1e3,
        cells / (36.0 * 32.0)
    );

    // Fig. 2(c): power distribution of the prototype SoC
    let breakdown = power::PowerBreakdown::prototype();
    let total = breakdown.total();
    let mut t = Table::new("Fig. 2(c) — SoC power distribution").header(&[
        "component",
        "power [mW]",
        "share",
    ]);
    for (name, p) in &breakdown.components {
        t.row(&[
            name.to_string(),
            f(p * 1e3, 2),
            format!("{:.1}%", p / total * 100.0),
        ]);
    }
    t.row_strs(&["TOTAL", &format!("{:.2}", total * 1e3), "100%"]);
    t.print();
    println!(
        "macro power {:.1} mW -> {:.1} nJ per 1-us inference cycle (paper: 16.9 nJ)",
        breakdown.macro_power() * 1e3,
        breakdown.macro_power() * acore_cim::analog::consts::T_SH * 1e9
    );
}
