//! End-to-end DNN driver (paper §VII-C) — the full-system validation run:
//! train a 784-72-10 MLP (on MNIST if `data/mnist/` exists, else the
//! synthetic digit set), quantize to 6+1-bit codes, map onto the 36x32
//! array (22x3 + 2x1 tiles), and measure the accuracy ladder
//! simulation -> uncalibrated silicon -> BISC-calibrated silicon,
//! with the hot MAC path OPTIONALLY routed through the AOT-compiled
//! JAX/Pallas artifact on PJRT (--pjrt) instead of the rust golden model.
//!
//! Run: cargo run --release --example mnist_e2e [-- --pjrt]
//! The results are recorded in EXPERIMENTS.md §VII-C.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::dnn::CimMlp;
use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
use acore_cim::util::table::Table;

fn main() {
    let use_pjrt = std::env::args().any(|a| a == "--pjrt");
    let cfg = SimConfig::default();
    let (train_ds, test_ds, source) = acore_cim::data::load_or_synth(4000, 800, cfg.seed);
    println!("dataset: {source} ({} train / {} test)", train_ds.len(), test_ds.len());

    // train the float MLP (paper baseline ~94%)
    let mut mlp = Mlp::new(7);
    let t0 = std::time::Instant::now();
    train(&mut mlp, &train_ds, &TrainConfig { epochs: 14, ..Default::default() });
    let acc_float = mlp.accuracy(&test_ds);
    println!("float MLP trained in {:.1} s, test acc {:.4}", t0.elapsed().as_secs_f64(), acc_float);

    let q = QuantMlp::from_float(&mlp, &train_ds, 300);
    let mut cim_mlp = CimMlp::new(q, &train_ds, 150);
    let acc_sim = cim_mlp.quant.accuracy_digital(&test_ds);

    // the silicon
    let sample = VariationSample::draw(&cfg);
    let mut die = CimAnalogModel::from_sample(&cfg, &sample);
    let limit = 400;
    let (acc_raw, _) = cim_mlp.accuracy(&mut die, &test_ds, limit);
    cim_mlp.measure_zero_point(&mut die);
    let (acc_zp, _) = cim_mlp.accuracy(&mut die, &test_ds, limit);

    // BISC + digital residual trim
    let half = c::V_BIAS - cim_mlp.refs1.0;
    BiscEngine::calibrate_for_workload(&cfg, AdcCharacterization::ideal(), &mut die, half);
    cim_mlp.clear_corrections();
    cim_mlp.measure_digital_trim(&mut die, &cfg);
    let t1 = std::time::Instant::now();
    let (acc_cal, stats) = cim_mlp.accuracy(&mut die, &test_ds, limit);
    let dt = t1.elapsed().as_secs_f64();

    let mut t = Table::new("accuracy ladder (paper §VII-C)")
        .header(&["configuration", "this repro", "paper"]);
    t.row_strs(&["float MLP", &format!("{:.2}%", acc_float * 100.0), "-"]);
    t.row_strs(&["simulation (quantized)", &format!("{:.2}%", acc_sim * 100.0), "94.23%"]);
    t.row_strs(&["raw uncalibrated", &format!("{:.2}%", acc_raw * 100.0), "-"]);
    t.row_strs(&["zero-point only ('uncal')", &format!("{:.2}%", acc_zp * 100.0), "88.70%"]);
    t.row_strs(&["BISC calibrated", &format!("{:.2}%", acc_cal * 100.0), "92.33%"]);
    t.print();
    println!(
        "throughput: {limit} inferences in {dt:.2} s ({:.1} inf/s host wall-clock); \
         {} MAC pulses ({} per inference)",
        limit as f64 / dt,
        stats.mac_ops,
        stats.mac_ops / limit as u64
    );
    println!(
        "modelled chip time: {} MAC pulses x 1 us = {:.1} ms of S&H time",
        stats.mac_ops,
        stats.mac_ops as f64 * c::T_SH * 1e3
    );

    // optional: run a batch through the PJRT artifact to prove the same
    // numbers come out of the compiled JAX/Pallas path (requires building
    // with `--features pjrt`; the default build only has the fallback)
    if use_pjrt {
        pjrt_crosscheck(&sample, &mut die, &cim_mlp);
    }
}

/// Cross-check one calibrated weight tile on the compiled artifact.
#[cfg(feature = "pjrt")]
fn pjrt_crosscheck(sample: &VariationSample, die: &mut CimAnalogModel, cim_mlp: &CimMlp) {
    use acore_cim::runtime::{CimRuntime, Executor};
    println!("\n--pjrt: cross-checking a weight tile on the PJRT artifact ...");
    let exec = Executor::discover().expect("run `make artifacts`");
    println!("PJRT platform: {}", exec.platform());
    let mut rt = CimRuntime::new(exec, sample.clone());
    // mirror the die's calibrated trim state into the runtime
    for col in 0..c::M_COLS {
        let amp = &die.amps[col];
        rt.trims.pot_p[col] = amp.pot_p;
        rt.trims.pot_n[col] = amp.pot_n;
        rt.trims.cal[col] = amp.cal;
    }
    let tile = &cim_mlp.layer1.tiles[0][0];
    rt.program(tile);
    die.program(tile);
    die.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
    let x: Vec<i32> = (0..8 * c::N_ROWS).map(|i| (i % 64) as i32 - 32).collect();
    let q_rt = rt.forward_batch(&x, 8).unwrap();
    let q_gold = die.forward_batch(&x, 8);
    let diffs = q_rt.iter().zip(&q_gold).filter(|(a, b)| a != b).count();
    println!(
        "PJRT vs golden model: {}/{} codes differ (<= rounding ties)",
        diffs,
        q_rt.len()
    );
    assert!(diffs < q_rt.len() / 20);
}

/// Default-build stand-in: explain how to enable the PJRT cross-check.
#[cfg(not(feature = "pjrt"))]
fn pjrt_crosscheck(_sample: &VariationSample, _die: &mut CimAnalogModel, _cim_mlp: &CimMlp) {
    println!("\n--pjrt ignored: rebuild with --features pjrt (needs xla_extension)");
}
