//! Quickstart: the public API in ~60 lines.
//!
//!   1. draw a Monte-Carlo die (a simulated fabricated chip),
//!   2. program weights and run mixed-signal MACs,
//!   3. run the RISC-V-controlled BISC calibration,
//!   4. watch the compute SNR improve (the paper's headline claim).
//!
//! Run: cargo run --release --example quickstart

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::snr::{measure_snr, SnrWorkload};

fn main() {
    // 1. one die: all DAC/MWC/2SA/ADC non-idealities sampled from the
    //    configured sigmas — fully reproducible from the seed
    let cfg = SimConfig::default();
    let die_params = VariationSample::draw(&cfg);
    let mut chip = CimAnalogModel::from_sample(&cfg, &die_params);
    println!("die seed {:#x}", cfg.seed);

    // 2. program a 36x32 weight matrix (signed 6+1-bit codes) and run MACs
    let weights: Vec<i32> = (0..c::N_ROWS * c::M_COLS)
        .map(|i| ((i as i32 * 7) % 127) - 63)
        .collect();
    chip.program(&weights);
    let inputs = vec![25i32; c::N_ROWS];
    let q = chip.forward_golden(&inputs);
    let q_nom = CimAnalogModel::q_nominal(&inputs, &weights, 1);
    println!("column 0: ADC code {} (nominal {:.1})", q[0], q_nom[0]);

    // 3. compute SNR before calibration (Eq. 15)
    let before = measure_snr(&mut chip, SnrWorkload::Ramp, 64, 1);

    // 4. BISC: online characterization (Z-point sweep per column, per
    //    line) + online correction (R_SA / V_CAL trims), Algorithm 1
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let report = engine.calibrate(&mut chip);
    println!(
        "BISC: calibrated {} columns with {} characterization reads",
        report.columns.len(),
        report.reads
    );

    let after = measure_snr(&mut chip, SnrWorkload::Ramp, 64, 1);
    println!(
        "compute SNR: {:.1} dB -> {:.1} dB (boost {:.1} dB; paper: +6-8 dB into 18-24 dB)",
        before.mean_snr_db(),
        after.mean_snr_db(),
        after.mean_snr_db() - before.mean_snr_db()
    );
    assert!(after.mean_snr_db() > before.mean_snr_db());
}
