//! SoC-level demo: the BISC routine running as RV32IM *firmware* on the
//! instruction-set simulator, driving the CIM core through memory-mapped
//! AXI4-Lite registers — the paper's "automated RISC-V controlled
//! self-calibration" made literal (Section VI / Algorithm 1).
//!
//! Run: cargo run --release --example soc_firmware

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::AdcCharacterization;
use acore_cim::soc::firmware;
use acore_cim::soc::memmap::{map, Soc};
use acore_cim::soc::riscv::cpu::Halt;
use acore_cim::util::table::{f, Table};

fn mean_abs_error(soc: &mut Soc) -> f64 {
    let dev = soc.cim_mut();
    dev.program_weights(&vec![c::CODE_MAX; c::N_ROWS * c::M_COLS]);
    let k = c::code_gain_nominal();
    let mid = c::q_mid_nominal();
    let mut err = 0.0;
    for x in [-40i32, -20, 0, 20, 40] {
        let q = dev.model.forward_batch(&vec![x; c::N_ROWS], 1);
        let nom = mid + k * (x as f64 * 63.0 * c::N_ROWS as f64);
        for col in 0..c::M_COLS {
            err += (q[col] as f64 - nom).abs();
        }
    }
    err / (5.0 * c::M_COLS as f64)
}

fn main() {
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0; // keep the demo deterministic
    let sample = VariationSample::draw(&cfg);
    let mut soc = Soc::new(CimAnalogModel::from_sample(&cfg, &sample));

    let img = firmware::bisc_program();
    println!(
        "BISC firmware: {} RV32IM instructions ({} bytes)",
        img.len() / 4,
        img.len()
    );
    let before = mean_abs_error(&mut soc);

    soc.load_program(&img);
    soc.write_words(
        map::PARAM_BLOCK,
        &firmware::bisc_param_block(&cfg, AdcCharacterization::ideal()),
    );
    let halt = soc.run(1_000_000_000);
    assert_eq!(halt, Halt::Exit(0), "firmware crashed: {halt:?}");
    let after = mean_abs_error(&mut soc);

    let (instret, cycles) = (soc.cpu.instret, soc.cpu.cycles);
    let (rd, wr) = (soc.bus.reads, soc.bus.writes);
    let sh = soc.cim_mut().busy_sh_periods();

    let mut t = Table::new("RISC-V controlled BISC (Alg. 1 on the ISS)")
        .header(&["metric", "value"]);
    t.row_strs(&["instructions retired", &instret.to_string()]);
    t.row_strs(&["CPU cycles", &cycles.to_string()]);
    t.row_strs(&["AXI4-Lite reads / writes", &format!("{rd} / {wr}")]);
    t.row_strs(&["analog S&H periods", &sh.to_string()]);
    t.row_strs(&[
        "SoC latency @50 MHz",
        &format!("{:.2} ms", (cycles as f64 / 50e6 + sh as f64 * c::T_SH) * 1e3),
    ]);
    t.row_strs(&["mean |MAC error| before", &format!("{} codes", f(before, 2))]);
    t.row_strs(&["mean |MAC error| after", &format!("{} codes", f(after, 2))]);
    t.print();
    assert!(after < before * 0.5);

    // show a couple of per-column trims the firmware chose
    println!("per-column trims chosen by the firmware (first 6 columns):");
    for col in 0..6 {
        let amp = &soc.cim_mut().model.amps[col];
        let (p, n, cal, rsa, vcal) =
            (amp.pot_p, amp.pot_n, amp.cal, amp.rsa_p(), amp.vcal());
        println!(
            "  col {col}: POT_P={p} POT_N={n} CAL={cal} -> R_SA={:.0} Ohm, V_CAL={:.4} V",
            rsa, vcal
        );
    }
}
