//! Regenerates Fig. 8 (a-e) and Fig. 9:
//!   (a) uncalibrated MAC outputs across columns,
//!   (b) extracted per-column gain (g) and offset (eps) errors,
//!   (c) BISC-calibrated R_SA and V_CAL trim values,
//!   (d) calibrated MAC outputs,
//!   (e) residual gain/offset errors after calibration,
//!   Fig. 9: mean CIM output vs ideal MAC value, uncal vs BISC.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::util::stats;
use acore_cim::util::table::{f, Table};

fn mac_outputs(model: &mut CimAnalogModel, x: i32) -> Vec<f64> {
    model.program(&vec![c::CODE_MAX; c::N_ROWS * c::M_COLS]);
    model
        .forward_batch(&vec![x; c::N_ROWS], 1)
        .iter()
        .map(|&q| q as f64)
        .collect()
}

fn main() {
    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());

    // (a) uncalibrated MAC outputs at a fixed test MAC value
    let x_test = 25;
    let k = c::code_gain_nominal();
    let mid = c::q_mid_nominal();
    let nom = mid + k * (x_test as f64 * 63.0 * c::N_ROWS as f64);
    let uncal_out = mac_outputs(&mut model, x_test);

    // (b) extracted per-column errors (characterization)
    let before = engine.characterize_only(&mut model);

    // (c) calibration
    let report = engine.calibrate(&mut model);

    // (d) calibrated outputs, (e) residual errors
    let cal_out = mac_outputs(&mut model, x_test);
    let after = engine.characterize_only(&mut model);

    let mut t = Table::new("Fig. 8 — per-column calibration summary").header(&[
        "col",
        "(a) uncal Q",
        "(b) g",
        "(b) eps",
        "(c) R_SA' [kOhm]",
        "(c) V_CAL' [V]",
        "(d) cal Q",
        "(e) g resid",
        "(e) eps resid",
    ]);
    for col in 0..c::M_COLS {
        let g_b = 0.5 * (before[col].0.g_tot + before[col].1.g_tot);
        let e_b = 0.5 * (before[col].0.eps_tot + before[col].1.eps_tot);
        let g_a = 0.5 * (after[col].0.g_tot + after[col].1.g_tot);
        let e_a = 0.5 * (after[col].0.eps_tot + after[col].1.eps_tot);
        t.row(&[
            col.to_string(),
            f(uncal_out[col], 0),
            f(g_b, 3),
            f(e_b, 2),
            f(report.columns[col].rsa_p / 1e3, 2),
            f(report.columns[col].vcal, 4),
            f(cal_out[col], 0),
            f(g_a, 3),
            f(e_a, 2),
        ]);
    }
    t.print();
    println!("nominal Q at the test vector: {nom:.1}");

    // summary stats (the figure's visual claim in numbers)
    let spread = |o: &[f64]| stats::max(o) - stats::min(o);
    let g_before: Vec<f64> = before.iter().map(|(p, n)| 0.5 * (p.g_tot + n.g_tot)).collect();
    let g_after: Vec<f64> = after.iter().map(|(p, n)| 0.5 * (p.g_tot + n.g_tot)).collect();
    let e_before: Vec<f64> = before.iter().map(|(p, n)| 0.5 * (p.eps_tot + n.eps_tot)).collect();
    let e_after: Vec<f64> = after.iter().map(|(p, n)| 0.5 * (p.eps_tot + n.eps_tot)).collect();
    println!(
        "column spread at test vector: {:.1} codes uncal -> {:.1} codes cal",
        spread(&uncal_out),
        spread(&cal_out)
    );
    println!(
        "gain errors: {:.3} +/- {:.3} -> {:.3} +/- {:.3}",
        stats::mean(&g_before),
        stats::std_dev(&g_before),
        stats::mean(&g_after),
        stats::std_dev(&g_after)
    );
    println!(
        "offset errors [LSB]: {:.2} +/- {:.2} -> {:.2} +/- {:.2}",
        stats::mean(&e_before),
        stats::std_dev(&e_before),
        stats::mean(&e_after),
        stats::std_dev(&e_after)
    );
    assert!(spread(&cal_out) < spread(&uncal_out));
    assert!(stats::std_dev(&g_after) < stats::std_dev(&g_before) * 0.5);

    // ---- Fig. 9: spatial variation across the MAC range -----------------
    let mut uncal_model = CimAnalogModel::from_sample(&cfg, &sample);
    let mut t = Table::new("Fig. 9 — mean CIM output vs ideal MAC value").header(&[
        "x code",
        "ideal Q",
        "uncal mean (min..max)",
        "BISC mean (min..max)",
    ]);
    for x in (-48..=48).step_by(16) {
        let nom = mid + k * (x as f64 * 63.0 * c::N_ROWS as f64);
        let u = mac_outputs(&mut uncal_model, x);
        let cal = mac_outputs(&mut model, x);
        t.row(&[
            x.to_string(),
            f(nom, 1),
            format!("{:.1} ({:.0}..{:.0})", stats::mean(&u), stats::min(&u), stats::max(&u)),
            format!("{:.1} ({:.0}..{:.0})", stats::mean(&cal), stats::min(&cal), stats::max(&cal)),
        ]);
    }
    t.print();
    println!("shape: BISC curve hugs the ideal column; uncal shows offset + spread");

    // CI bench artifact: the calibration-quality trajectory in numbers
    // (no-op unless ACORE_BENCH_JSON_DIR is set)
    let body = format!(
        "{{\n  \"bench\": \"fig8_calibration\",\n  \"seed\": {},\n  \
         \"reads\": {},\n  \"g_mean_uncal\": {:.6},\n  \"g_std_uncal\": {:.6},\n  \
         \"g_mean_cal\": {:.6},\n  \"g_std_cal\": {:.6},\n  \
         \"eps_mean_uncal_lsb\": {:.4},\n  \"eps_mean_cal_lsb\": {:.4},\n  \
         \"spread_uncal_codes\": {:.2},\n  \"spread_cal_codes\": {:.2}\n}}\n",
        cfg.seed,
        report.reads,
        stats::mean(&g_before),
        stats::std_dev(&g_before),
        stats::mean(&g_after),
        stats::std_dev(&g_after),
        stats::mean(&e_before),
        stats::mean(&e_after),
        spread(&uncal_out),
        spread(&cal_out)
    );
    acore_cim::util::bench::write_bench_json("fig8_calibration", &body);
}
