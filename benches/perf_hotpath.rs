//! Performance benchmarks for the hot paths (EXPERIMENTS.md §Perf):
//!   L3 golden per-cell path vs folded fast path (analog model),
//!   runtime backend throughput (PJRT artifact with `--features pjrt`,
//!     golden-model fallback otherwise),
//!   RV32IM ISS instruction rate,
//!   BISC calibration wall time (single die + parallel cluster),
//!   batcher request throughput (unified submit path),
//!   multi-core cluster serving throughput at K = 1, 2, 4, 8, per-request
//!     Mac + round-robin vs native MacBatch + least-loaded placement,
//!   wire front-end overhead: the same pipelined workloads through a
//!     loopback-TCP WireServer/RemoteClient pair vs in-process submits,
//!     for Mac and MacBatch(64) at K = 1 and 4 (EXPERIMENTS.md §Perf).

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::cluster::CimCluster;
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::soc::memmap::{map, Soc};
use acore_cim::soc::riscv::asm::Asm;
use acore_cim::util::bench::Bencher;
use acore_cim::util::rng::Rng;

/// Drive `n_requests` through a K-core cluster with `k` pipelined
/// producer threads; returns requests/second. `batch == 1` submits
/// per-request `Job::Mac`s; `batch > 1` submits native `Job::MacBatch`
/// jobs of that size. `least_loaded` switches the placement policy from
/// the shared round-robin cursor to the in-flight depth gauges.
fn cluster_throughput(
    cfg: &SimConfig,
    k: usize,
    n_requests: usize,
    batch: usize,
    least_loaded: bool,
) -> f64 {
    use acore_cim::coordinator::batcher::Batcher;
    use acore_cim::coordinator::service::{CimService, SubmitOpts};
    let mut cluster = CimCluster::new(cfg, k);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let t0 = std::time::Instant::now();
    let producers = k;
    let per_producer = n_requests / producers;
    let mut joins = Vec::new();
    for p in 0..producers {
        let client = server.client();
        joins.push(std::thread::spawn(move || {
            let opts =
                if least_loaded { SubmitOpts::least_loaded() } else { SubmitOpts::default() };
            let make = |i: usize| vec![((p + i) % 63) as i32 - 31; c::N_ROWS];
            if batch > 1 {
                client
                    .mac_batch_pipelined(
                        per_producer / batch,
                        batch,
                        (512 / batch).max(1),
                        opts,
                        make,
                    )
                    .expect("serving failed");
            } else {
                client.mac_pipelined_with(per_producer, 512, opts, make).expect("serving failed");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    // clock stops when every reply is gathered — teardown excluded, the
    // same measurement point as `wire_throughput`, so the printed
    // in-process-vs-TCP ratio compares equal spans
    let dt = t0.elapsed().as_secs_f64();
    let (_cluster, stats) = server.join();
    let total: u64 = stats.iter().map(|s| s.requests).sum();
    let expect = if batch > 1 {
        (per_producer / batch) * batch * producers
    } else {
        per_producer * producers
    };
    assert_eq!(total as usize, expect, "lost requests");
    total as f64 / dt
}

/// The same pipelined workload as [`cluster_throughput`], but driven over
/// a loopback-TCP `WireServer`/`RemoteClient` pair — one connection per
/// producer, each pure `CimService` calls — so the printed pair isolates
/// the wire protocol's overhead (framing, syscalls, reply routing).
fn wire_throughput(
    cfg: &SimConfig,
    k: usize,
    n_requests: usize,
    batch: usize,
    least_loaded: bool,
) -> f64 {
    use acore_cim::coordinator::batcher::Batcher;
    use acore_cim::coordinator::service::{CimService, SubmitOpts};
    use acore_cim::coordinator::wire::{RemoteClient, WireServer};
    use std::sync::Arc;
    let mut cluster = CimCluster::new(cfg, k);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port"),
    );
    let addr = wire.local_addr().expect("bound listener has an address");
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };
    let t0 = std::time::Instant::now();
    let producers = k;
    let per_producer = n_requests / producers;
    let mut joins = Vec::new();
    for p in 0..producers {
        let client = RemoteClient::connect(addr).expect("connect loopback");
        joins.push(std::thread::spawn(move || {
            let opts =
                if least_loaded { SubmitOpts::least_loaded() } else { SubmitOpts::default() };
            let make = |i: usize| vec![((p + i) % 63) as i32 - 31; c::N_ROWS];
            if batch > 1 {
                client
                    .mac_batch_pipelined(
                        per_producer / batch,
                        batch,
                        (512 / batch).max(1),
                        opts,
                        make,
                    )
                    .expect("wire serving failed");
            } else {
                client
                    .mac_pipelined_with(per_producer, 512, opts, make)
                    .expect("wire serving failed");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    let (_cluster, stats) = server.join();
    let total: u64 = stats.iter().map(|s| s.requests).sum();
    let expect = if batch > 1 {
        (per_producer / batch) * batch * producers
    } else {
        per_producer * producers
    };
    assert_eq!(total as usize, expect, "lost requests over the wire");
    total as f64 / dt
}

/// Aggregate throughput with `conns` CONCURRENT connections, each its
/// own socket + pipelined Mac stream — the scaling axis the event-driven
/// front-end exists for (one poller thread owns every socket; the old
/// design spent two OS threads per connection). Connects happen inside
/// the producer threads, so the accept storm is part of the measured
/// span.
fn wire_concurrency_throughput(cfg: &SimConfig, k: usize, conns: usize, per_conn: usize) -> f64 {
    use acore_cim::coordinator::batcher::Batcher;
    use acore_cim::coordinator::service::{CimService, SubmitOpts};
    use acore_cim::coordinator::wire::{RemoteClient, WireServer};
    use std::sync::Arc;
    let mut cluster = CimCluster::new(cfg, k);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port"),
    );
    let addr = wire.local_addr().expect("bound listener has an address");
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for p in 0..conns {
        joins.push(std::thread::spawn(move || {
            let client = RemoteClient::connect(addr).expect("connect loopback");
            let make = |i: usize| vec![((p + i) % 63) as i32 - 31; c::N_ROWS];
            client
                .mac_pipelined_with(per_conn, 64, SubmitOpts::default(), make)
                .expect("wire serving failed");
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    let (_cluster, stats) = server.join();
    let total: u64 = stats.iter().map(|s| s.requests).sum();
    assert_eq!(total as usize, conns * per_conn, "lost requests across connections");
    total as f64 / dt
}

/// PJRT artifact throughput (only with `--features pjrt` + artifacts).
#[cfg(feature = "pjrt")]
fn pjrt_bench(
    b: &mut Bencher,
    sample: &VariationSample,
    weights: &[i32],
    x1: &[i32],
    x256: &[i32],
) {
    match acore_cim::runtime::Executor::discover() {
        Ok(exec) => {
            let mut rt = acore_cim::runtime::CimRuntime::new(exec, sample.clone());
            rt.program(weights);
            // warm the compile caches outside the timed region
            let _ = rt.forward_batch(x1, 1).unwrap();
            let _ = rt.forward_batch(x256, 256).unwrap();
            let rb1 =
                b.bench("pjrt cim_mac (batch 1)", || rt.forward_batch(x1, 1).unwrap()).clone();
            let rb256 = b
                .bench("pjrt cim_mac (batch 256)", || rt.forward_batch(x256, 256).unwrap())
                .clone();
            println!(
                "   => per-eval: {:.1} us (b1) vs {:.2} us (b256) — batching {:.0}x",
                rb1.median_ns / 1e3,
                rb256.median_ns / 1e3 / 256.0,
                rb1.median_ns / (rb256.median_ns / 256.0)
            );
        }
        Err(e) => println!("skipping PJRT benches: {e}"),
    }
}

/// Default build: the fallback-runtime bench above already covers it.
#[cfg(not(feature = "pjrt"))]
fn pjrt_bench(_b: &mut Bencher, _s: &VariationSample, _w: &[i32], _x1: &[i32], _x256: &[i32]) {
    println!("   (pjrt benches skipped: build with --features pjrt + artifacts)");
}

fn main() {
    let fast = std::env::var("ACORE_BENCH_FAST").is_ok();
    let mut b = Bencher::new();
    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let mut rng = Rng::new(42);
    let weights: Vec<i32> = (0..c::N_ROWS * c::M_COLS)
        .map(|_| rng.int_in(-63, 63) as i32)
        .collect();

    println!("== L3 analog model ==");
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);
    model.program(&weights);
    let x1: Vec<i32> = (0..c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
    b.bench("golden per-cell forward (1 vec)", || model.forward_golden(&x1));
    let x256: Vec<i32> = (0..256 * c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
    let r256 = b.bench("folded fast path (batch 256)", || model.forward_batch(&x256, 256)).clone();
    let evals_per_sec = r256.per_sec() * 256.0;
    println!("   => {:.2} M array-evals/s on the folded path", evals_per_sec / 1e6);
    let r1 = b.bench("folded fast path (batch 1)", || model.forward_batch(&x1, 1)).clone();
    println!(
        "   => batching gain: {:.1}x per-eval",
        r1.median_ns / (r256.median_ns / 256.0)
    );
    // the zero-allocation serving form: caller-owned output buffer,
    // fold-time DAC coefficients, internal scratch reuse (DESIGN.md §11)
    let mut out_buf: Vec<u32> = Vec::new();
    let ri = b
        .bench("folded fast path (batch 256, into)", || {
            model.forward_batch_into(&x256, 256, &mut out_buf);
            out_buf.len()
        })
        .clone();
    println!(
        "   => _into steady state: {:.2}x vs the allocating wrapper",
        r256.median_ns / ri.median_ns
    );

    println!("\n== runtime backend (CimRuntime) ==");
    {
        // golden-model fallback: always available, measures the register-
        // sync + refold overhead the fallback pays per call
        let mut rt = acore_cim::runtime::CimRuntime::golden(sample.clone());
        rt.program(&weights);
        let rb1 = b
            .bench("fallback runtime (batch 1)", || rt.forward_batch(&x1, 1).unwrap())
            .clone();
        let rb256 = b
            .bench("fallback runtime (batch 256)", || rt.forward_batch(&x256, 256).unwrap())
            .clone();
        println!(
            "   => per-eval: {:.2} us (b1) vs {:.3} us (b256); backend: golden fallback",
            rb1.median_ns / 1e3,
            rb256.median_ns / 1e3 / 256.0
        );
    }
    pjrt_bench(&mut b, &sample, &weights, &x1, &x256);

    println!("\n== DNN inference (tile scheduler) ==");
    {
        use acore_cim::coordinator::dnn::CimMlp;
        use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
        let (train_ds, test_ds) = acore_cim::data::synth::generate(400, 50, 3);
        let mut mlp = Mlp::new(1);
        train(&mut mlp, &train_ds, &TrainConfig { epochs: 3, ..Default::default() });
        let q = QuantMlp::from_float(&mlp, &train_ds, 50);
        let cim_mlp = CimMlp::new(q, &train_ds, 30);
        let mut cfg2 = cfg.clone();
        cfg2.sigma_noise = 0.0;
        let mut die = CimAnalogModel::from_sample(&cfg2, &sample);
        let img = test_ds.image(0).to_vec();
        let rd = b
            .bench("infer, direct (program+fold per tile)", || {
                let mut st = Default::default();
                cim_mlp.infer(&mut die, &img, &mut st)
            })
            .clone();
        let prepared = cim_mlp.prepare(&mut die);
        let rp = b
            .bench("infer, prepared (cached folded tiles)", || {
                let mut st = Default::default();
                cim_mlp.infer_prepared(&die, &prepared, &img, &mut st)
            })
            .clone();
        println!(
            "   => prepared schedule speedup: {:.1}x ({:.0} -> {:.0} inf/s)",
            rd.median_ns / rp.median_ns,
            rd.per_sec(),
            rp.per_sec()
        );
    }

    println!("\n== RV32IM ISS ==");
    // tight arithmetic loop: ~4 instr/iteration
    let mut soc = Soc::new(CimAnalogModel::ideal());
    let mut a = Asm::new(map::ENTRY);
    a.li(5, 2_000_000);
    a.label("spin");
    a.addi(6, 6, 1);
    a.addi(5, 5, -1);
    a.bne(5, 0, "spin");
    a.li(10, 0);
    a.exit();
    soc.load_program(&a.assemble());
    let r = b.bench_n("ISS: 6M-instruction loop", 5, || {
        soc.cpu.pc = map::ENTRY;
        soc.cpu.regs = [0; 32];
        soc.cpu.regs[2] = map::STACK_TOP;
        soc.run(10_000_000)
    });
    let mips = 6.0e6 / (r.median_ns / 1e9) / 1e6;
    println!("   => {mips:.0} MIPS");

    println!("\n== BISC calibration wall time ==");
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let r = b
        .bench_n("BISC full-array calibrate (1 die)", 5, || {
            let mut m = CimAnalogModel::from_sample(&cfg, &sample);
            engine.calibrate(&mut m)
        })
        .clone();
    println!("   => {:.1} ms per full calibration", r.median_ns / 1e6);
    let rc = b.bench_n("parallel BISC (4-core cluster)", 3, || {
        let mut cluster = CimCluster::new(&cfg, 4);
        cluster.calibrate_parallel(&engine);
        cluster.total_calibration_reads()
    });
    println!(
        "   => {:.1} ms wall for 4 dies ({:.1}x the single-die time, ideal 1.0x)",
        rc.median_ns / 1e6,
        rc.median_ns / r.median_ns
    );

    println!("\n== batcher (single worker) ==");
    use acore_cim::coordinator::batcher::Batcher;
    use acore_cim::coordinator::service::{CimService, Job, SubmitOpts, Ticket};
    let r = b.bench_n("batched serving: 2000 requests", 5, || {
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        m.program(&vec![40; c::N_ROWS * c::M_COLS]);
        let (client, worker) = Batcher::default().spawn_solo(m);
        let tickets: Vec<Ticket<Vec<u32>>> = (0..2000)
            .map(|i| {
                client
                    .submit(
                        Job::Mac(vec![(i % 63) as i32 - 31; c::N_ROWS]),
                        SubmitOpts::default(),
                    )
                    .unwrap()
                    .typed()
            })
            .collect();
        for t in tickets {
            t.wait().expect("request failed");
        }
        drop(client);
        worker.join().unwrap().1
    });
    println!(
        "   => {:.0}k requests/s through the batcher",
        2000.0 / (r.median_ns / 1e9) / 1e3
    );

    println!("\n== multi-core cluster serving (unified submit path) ==");
    let n_requests = if fast { 20_000 } else { 80_000 };
    let mut rr1 = 0.0;
    let mut ll1 = 0.0;
    for k in [1usize, 2, 4, 8] {
        // one warmup + median of 3 runs per mode
        let _ = cluster_throughput(&cfg, k, n_requests / 4, 1, false);
        let median = |batch: usize, least_loaded: bool| {
            let mut runs: Vec<f64> = (0..3)
                .map(|_| cluster_throughput(&cfg, k, n_requests, batch, least_loaded))
                .collect();
            runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            runs[1]
        };
        // the pre-redesign configuration: per-request Mac jobs, round-robin
        let rps_rr = median(1, false);
        // the redesigned hot path: native 64-wide MacBatch jobs, least-loaded
        let rps_ll = median(64, true);
        if k == 1 {
            rr1 = rps_rr;
            ll1 = rps_ll;
        }
        println!(
            "K = {k}: {:>10.0} req/s Mac+round-robin ({:.2}x vs K=1) | \
             {:>10.0} req/s MacBatch(64)+least-loaded ({:.2}x vs K=1)",
            rps_rr,
            rps_rr / rr1,
            rps_ll,
            rps_ll / ll1
        );
        b.note_rate(&format!("cluster K={k} Mac+round-robin req/s"), rps_rr);
        b.note_rate(&format!("cluster K={k} MacBatch(64)+least-loaded req/s"), rps_ll);
    }
    println!(
        "   (host has {} CPUs; scaling saturates at the physical core count)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );

    println!("\n== wire front-end: in-process vs loopback TCP ==");
    // the same two serving modes as above, re-measured through a real
    // socket: the gap is the wire protocol's whole cost (framing,
    // syscalls, reply routing) — MacBatch amortizes it ~64x per frame
    let n_wire = if fast { 8_000 } else { 24_000 };
    for k in [1usize, 4] {
        for (label, batch, ll) in
            [("Mac + round-robin    ", 1usize, false), ("MacBatch(64) + least-loaded", 64, true)]
        {
            let inproc = cluster_throughput(&cfg, k, n_wire, batch, ll);
            let tcp = wire_throughput(&cfg, k, n_wire, batch, ll);
            println!(
                "K = {k} {label}: {inproc:>10.0} req/s in-process | {tcp:>10.0} req/s \
                 loopback TCP ({:.0}% of in-process)",
                100.0 * tcp / inproc
            );
            b.note_rate(&format!("wire K={k} {} in-process req/s", label.trim()), inproc);
            b.note_rate(&format!("wire K={k} {} loopback-tcp req/s", label.trim()), tcp);
        }
    }

    println!("\n== wire front-end: concurrent-connection scaling ==");
    // the event-loop axis: many sockets, few requests each — the cost
    // here is readiness dispatch + per-connection buffers, not framing
    // (EXPERIMENTS.md §Perf documents the methodology)
    let conns = 256;
    let per_conn = if fast { 40 } else { 160 };
    let rps = wire_concurrency_throughput(&cfg, 4, conns, per_conn);
    println!(
        "C = {conns} concurrent connections on K = 4: {rps:>10.0} req/s aggregate \
         ({} requests per connection, accept storm included)",
        per_conn
    );
    b.note_rate(&format!("wire C={conns} concurrent connections aggregate req/s"), rps);

    // CI bench artifact (no-op unless ACORE_BENCH_JSON_DIR is set)
    b.export_json("perf_hotpath");
}
