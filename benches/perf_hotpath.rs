//! Performance benchmarks for the hot paths (EXPERIMENTS.md §Perf):
//!   L3 golden per-cell path vs folded fast path (analog model),
//!   PJRT artifact throughput vs batch size (per-sample amortization),
//!   RV32IM ISS instruction rate,
//!   BISC calibration wall time,
//!   batcher request throughput.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::soc::memmap::{map, Soc};
use acore_cim::soc::riscv::asm::Asm;
use acore_cim::util::bench::Bencher;
use acore_cim::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let mut rng = Rng::new(42);
    let weights: Vec<i32> = (0..c::N_ROWS * c::M_COLS)
        .map(|_| rng.int_in(-63, 63) as i32)
        .collect();

    println!("== L3 analog model ==");
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);
    model.program(&weights);
    let x1: Vec<i32> = (0..c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
    b.bench("golden per-cell forward (1 vec)", || model.forward_golden(&x1));
    let x256: Vec<i32> = (0..256 * c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
    let r256 = b.bench("folded fast path (batch 256)", || model.forward_batch(&x256, 256)).clone();
    let evals_per_sec = r256.per_sec() * 256.0;
    println!("   => {:.2} M array-evals/s on the folded path", evals_per_sec / 1e6);
    let r1 = b.bench("folded fast path (batch 1)", || model.forward_batch(&x1, 1)).clone();
    println!(
        "   => batching gain: {:.1}x per-eval",
        r1.median_ns / (r256.median_ns / 256.0)
    );

    println!("\n== L1/L2 PJRT artifact (compiled JAX/Pallas) ==");
    match acore_cim::runtime::Executor::discover() {
        Ok(exec) => {
            let mut rt = acore_cim::runtime::CimRuntime::new(exec, sample.clone());
            rt.program(&weights);
            // warm the compile caches outside the timed region
            let _ = rt.forward_batch(&x1, 1).unwrap();
            let _ = rt.forward_batch(&x256, 256).unwrap();
            let rb1 =
                b.bench("pjrt cim_mac (batch 1)", || rt.forward_batch(&x1, 1).unwrap()).clone();
            let rb256 = b
                .bench("pjrt cim_mac (batch 256)", || rt.forward_batch(&x256, 256).unwrap())
                .clone();
            println!(
                "   => per-eval: {:.1} us (b1) vs {:.2} us (b256) — batching {:.0}x",
                rb1.median_ns / 1e3,
                rb256.median_ns / 1e3 / 256.0,
                rb1.median_ns / (rb256.median_ns / 256.0)
            );
        }
        Err(e) => println!("skipping PJRT benches: {e}"),
    }

    println!("\n== DNN inference (tile scheduler) ==");
    {
        use acore_cim::coordinator::dnn::CimMlp;
        use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
        let (train_ds, test_ds) = acore_cim::data::synth::generate(400, 50, 3);
        let mut mlp = Mlp::new(1);
        train(&mut mlp, &train_ds, &TrainConfig { epochs: 3, ..Default::default() });
        let q = QuantMlp::from_float(&mlp, &train_ds, 50);
        let cim_mlp = CimMlp::new(q, &train_ds, 30);
        let mut cfg2 = cfg.clone();
        cfg2.sigma_noise = 0.0;
        let mut die = CimAnalogModel::from_sample(&cfg2, &sample);
        let img = test_ds.image(0).to_vec();
        let rd = b
            .bench("infer, direct (program+fold per tile)", || {
                let mut st = Default::default();
                cim_mlp.infer(&mut die, &img, &mut st)
            })
            .clone();
        let prepared = cim_mlp.prepare(&mut die);
        let rp = b
            .bench("infer, prepared (cached folded tiles)", || {
                let mut st = Default::default();
                cim_mlp.infer_prepared(&die, &prepared, &img, &mut st)
            })
            .clone();
        println!(
            "   => prepared schedule speedup: {:.1}x ({:.0} -> {:.0} inf/s)",
            rd.median_ns / rp.median_ns,
            rd.per_sec(),
            rp.per_sec()
        );
    }

    println!("\n== RV32IM ISS ==");
    // tight arithmetic loop: ~4 instr/iteration
    let mut soc = Soc::new(CimAnalogModel::ideal());
    let mut a = Asm::new(map::ENTRY);
    a.li(5, 2_000_000);
    a.label("spin");
    a.addi(6, 6, 1);
    a.addi(5, 5, -1);
    a.bne(5, 0, "spin");
    a.li(10, 0);
    a.exit();
    soc.load_program(&a.assemble());
    let r = b.bench_n("ISS: 6M-instruction loop", 5, || {
        soc.cpu.pc = map::ENTRY;
        soc.cpu.regs = [0; 32];
        soc.cpu.regs[2] = map::STACK_TOP;
        soc.run(10_000_000)
    });
    let mips = 6.0e6 / (r.median_ns / 1e9) / 1e6;
    println!("   => {mips:.0} MIPS");

    println!("\n== BISC calibration wall time (host engine) ==");
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let r = b.bench_n("BISC full-array calibrate", 5, || {
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        engine.calibrate(&mut m)
    });
    println!("   => {:.1} ms per full calibration", r.median_ns / 1e6);

    println!("\n== batcher ==");
    use acore_cim::coordinator::batcher::{Batcher, MacRequest};
    use std::sync::mpsc::channel;
    let r = b.bench_n("batched serving: 2000 requests", 5, || {
        let (tx, rx) = channel::<MacRequest>();
        let cfg2 = cfg.clone();
        let s2 = sample.clone();
        let worker = std::thread::spawn(move || {
            let mut m = CimAnalogModel::from_sample(&cfg2, &s2);
            m.program(&vec![40; c::N_ROWS * c::M_COLS]);
            Batcher::default().run(rx, &mut m)
        });
        let mut replies = Vec::new();
        for i in 0..2000 {
            let (rtx, rrx) = channel();
            tx.send(MacRequest { x: vec![(i % 63) as i32 - 31; c::N_ROWS], reply: rtx })
                .unwrap();
            replies.push(rrx);
        }
        for rr in replies {
            rr.recv().unwrap();
        }
        drop(tx);
        worker.join().unwrap()
    });
    println!(
        "   => {:.0}k requests/s through the batcher",
        2000.0 / (r.median_ns / 1e9) / 1e3
    );
}
