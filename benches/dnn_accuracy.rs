//! Regenerates the §VII-C DNN demonstration (MNIST-or-synthetic MLP
//! 784-72-10): the simulation / uncalibrated / BISC accuracy ladder, plus
//! ablations the design section motivates:
//!   * ADC window mapping (calibrated per-layer windows vs default refs)
//!   * digital residual trim on/off
//!   * variation-magnitude sweep (where does the paper's 88.7% live?)

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::dnn::CimMlp;
use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
use acore_cim::util::table::Table;

fn main() {
    let fast = std::env::var("ACORE_BENCH_FAST").is_ok();
    let (n_train, n_test, epochs, limit) =
        if fast { (800, 200, 6, 100) } else { (3000, 600, 12, 300) };
    let base_cfg = SimConfig::default();
    let (train_ds, test_ds, source) = acore_cim::data::load_or_synth(n_train, n_test, base_cfg.seed);
    println!("dataset: {source} ({} train / {} test)", train_ds.len(), test_ds.len());

    let mut mlp = Mlp::new(7);
    train(&mut mlp, &train_ds, &TrainConfig { epochs, ..Default::default() });
    let acc_float = mlp.accuracy(&test_ds);
    let q = QuantMlp::from_float(&mlp, &train_ds, 200);

    // ---- main ladder -----------------------------------------------------
    let mut cim_mlp = CimMlp::new(q.clone(), &train_ds, 100);
    let acc_sim = cim_mlp.quant.accuracy_digital(&test_ds);
    let sample = VariationSample::draw(&base_cfg);
    let mut die = CimAnalogModel::from_sample(&base_cfg, &sample);
    let (acc_raw, _) = cim_mlp.accuracy(&mut die, &test_ds, limit);
    cim_mlp.measure_zero_point(&mut die);
    let (acc_zp, _) = cim_mlp.accuracy(&mut die, &test_ds, limit);
    let half = c::V_BIAS - cim_mlp.refs1.0;
    BiscEngine::calibrate_for_workload(&base_cfg, AdcCharacterization::ideal(), &mut die, half);
    cim_mlp.clear_corrections();
    let (acc_bisc_only, _) = cim_mlp.accuracy(&mut die, &test_ds, limit);
    cim_mlp.measure_digital_trim(&mut die, &base_cfg);
    let (acc_full, _) = cim_mlp.accuracy(&mut die, &test_ds, limit);

    let mut t = Table::new("§VII-C — DNN accuracy ladder").header(&["configuration", "this repro", "paper"]);
    t.row_strs(&["float MLP", &pc(acc_float), "-"]);
    t.row_strs(&["simulation (quantized)", &pc(acc_sim), "94.23%"]);
    t.row_strs(&["raw uncalibrated", &pc(acc_raw), "-"]);
    t.row_strs(&["zero-point only ('uncal')", &pc(acc_zp), "88.70%"]);
    t.row_strs(&["BISC (analog trims only)", &pc(acc_bisc_only), "-"]);
    t.row_strs(&["BISC + digital residual trim", &pc(acc_full), "92.33%"]);
    t.print();
    assert!(acc_full > acc_zp, "calibration must beat the bring-up baseline");
    assert!(acc_full > acc_sim - 0.08, "calibration recovers to near-sim");

    // ---- ablation: ADC window mapping -----------------------------------
    let mut naive = CimMlp::new_default_refs(q.clone());
    let mut die2 = CimAnalogModel::from_sample(&base_cfg, &sample);
    let (acc_naive_ideal, _) = naive.accuracy(&mut CimAnalogModel::ideal(), &test_ds, limit);
    naive.measure_zero_point(&mut die2);
    let (acc_naive, _) = naive.accuracy(&mut die2, &test_ds, limit);
    let mut t = Table::new("ablation — per-layer ADC windows (dynamic-range management)")
        .header(&["mapping", "ideal die", "noisy die (zero-point)"]);
    t.row_strs(&["default full-range refs", &pc(acc_naive_ideal), &pc(acc_naive)]);
    t.row_strs(&["calibrated windows", &pc(acc_sim), &pc(acc_zp)]);
    t.print();
    println!("(full-range refs bury the per-tile MAC in quantization: DESIGN.md §6)\n");

    // ---- ablation: variation magnitude sweep -----------------------------
    let mut t = Table::new("ablation — accuracy vs variation magnitude").header(&[
        "sigma scale",
        "zero-point ('uncal')",
        "BISC + trim",
    ]);
    for scale in [0.25, 0.5, 1.0, 1.5] {
        let cfg = base_cfg.scaled(scale);
        let s = VariationSample::draw(&cfg);
        let mut d = CimAnalogModel::from_sample(&cfg, &s);
        let mut m = CimMlp::new(q.clone(), &train_ds, 100);
        m.measure_zero_point(&mut d);
        let (a_zp, _) = m.accuracy(&mut d, &test_ds, limit);
        let half = c::V_BIAS - m.refs1.0;
        BiscEngine::calibrate_for_workload(&cfg, AdcCharacterization::ideal(), &mut d, half);
        m.clear_corrections();
        m.measure_digital_trim(&mut d, &cfg);
        let (a_cal, _) = m.accuracy(&mut d, &test_ds, limit);
        t.row_strs(&[&format!("{scale:.2}x"), &pc(a_zp), &pc(a_cal)]);
    }
    t.print();
    println!("shape: BISC holds accuracy near simulation across the whole sweep,");
    println!("while the uncalibrated baseline degrades with variation magnitude.");
}

fn pc(a: f64) -> String {
    format!("{:.2}%", a * 100.0)
}
