//! Regenerates Table II ("This SoC" column) and the Alg. 1 overhead row:
//! normalized throughput / energy efficiency / area efficiency at macro and
//! system level — with the system slowdown MEASURED on the RISC-V ISS
//! (input writes + MAC + output reads over AXI4-Lite), not assumed.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, power, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::AdcCharacterization;
use acore_cim::coordinator::cim_core::regs;
use acore_cim::soc::firmware;
use acore_cim::soc::memmap::{map, Soc};
use acore_cim::soc::riscv::asm::Asm;
use acore_cim::soc::riscv::cpu::Halt;
use acore_cim::util::table::{f, Table};

/// Measure CPU cycles per complete MAC transaction on the ISS.
fn measure_system_slowdown() -> f64 {
    let mut soc = Soc::new(CimAnalogModel::ideal());
    soc.cim_mut().program_weights(&vec![20; c::N_ROWS * c::M_COLS]);
    let k_macs = 200;
    let mut a = Asm::new(map::ENTRY);
    a.li(5, map::CIM_BASE as i32);
    a.li(9, k_macs);
    a.label("mac_loop");
    a.li(6, 17);
    a.li(7, 0);
    a.li(28, (map::CIM_BASE + regs::INPUT) as i32);
    a.label("in_loop");
    a.sw(28, 6, 0);
    a.addi(28, 28, 4);
    a.addi(7, 7, 1);
    a.li(31, c::N_ROWS as i32);
    a.blt(7, 31, "in_loop");
    a.li(6, 1);
    a.sw(5, 6, regs::CTRL as i32);
    a.li(7, 0);
    a.li(28, (map::CIM_BASE + regs::OUT) as i32);
    a.label("out_loop");
    a.lw(6, 28, 0);
    a.add(29, 29, 6);
    a.addi(28, 28, 4);
    a.addi(7, 7, 1);
    a.li(31, c::M_COLS as i32);
    a.blt(7, 31, "out_loop");
    a.addi(9, 9, -1);
    a.bne(9, 0, "mac_loop");
    a.li(10, 0);
    a.exit();
    soc.load_program(&a.assemble());
    assert_eq!(soc.run(100_000_000), Halt::Exit(0));
    // CPU runs at 50 MHz while the array's MAC takes one 1-us S&H period
    // (50 CPU cycles); slowdown = total cycles per MAC / cycles per bare MAC
    let cycles_per_mac = soc.cpu.cycles as f64 / k_macs as f64;
    let sh_in_cpu_cycles = 50.0; // 1 us at 50 MHz
    (cycles_per_mac + sh_in_cpu_cycles) / sh_in_cpu_cycles
}

fn main() {
    let slowdown = measure_system_slowdown();
    println!("measured system slowdown on the ISS: {slowdown:.1}x (paper implies ~37x)\n");

    let macro_m = power::macro_metrics();
    let sys_m = power::system_metrics(slowdown);

    let mut t = Table::new("Table II — This SoC").header(&["metric", "macro (model/paper)", "system (model/paper)"]);
    t.row_strs(&[
        "norm. throughput [1b-GOPS]",
        &format!("{} / 113", f(macro_m.norm_throughput_gops, 1)),
        &format!("{} / 3.05", f(sys_m.norm_throughput_gops, 2)),
    ]);
    t.row_strs(&[
        "norm. energy eff. [1b-TOPS/W]",
        &format!("{} / 6.65", f(macro_m.norm_energy_eff, 2)),
        &format!("{} / 0.122", f(sys_m.norm_energy_eff, 3)),
    ]);
    t.row_strs(&[
        "norm. area eff. [1b-TOPS/mm^2]",
        &format!("{} / 0.155", f(macro_m.norm_area_eff, 3)),
        &format!("{} / -", f(sys_m.norm_area_eff, 4)),
    ]);
    t.row_strs(&[
        "energy / inference cycle",
        &format!("{:.1} nJ / 16.9 nJ", macro_m.energy_per_inference * 1e9),
        "-",
    ]);
    t.row_strs(&["precision (I:W:O)", "7:7:6 / 7:7:6", "-"]);
    t.row_strs(&["inference frequency", "1 MHz / 1 MHz", "-"]);
    t.print();

    // ---- Alg. 1 overhead (calibration features row of Table II) ---------
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let sample = VariationSample::draw(&cfg);
    let mut soc = Soc::new(CimAnalogModel::from_sample(&cfg, &sample));
    soc.load_program(&firmware::bisc_program());
    soc.write_words(
        map::PARAM_BLOCK,
        &firmware::bisc_param_block(&cfg, AdcCharacterization::ideal()),
    );
    assert_eq!(soc.run(1_000_000_000), Halt::Exit(0));
    let cycles = soc.cpu.cycles;
    let sh = soc.cim_mut().busy_sh_periods();
    let wall_ms = (cycles as f64 / 50e6 + sh as f64 * c::T_SH) * 1e3;
    let mut t = Table::new("BISC overhead (Alg. 1, on-chip)").header(&["metric", "value"]);
    t.row_strs(&["RISC-V instructions", &soc.cpu.instret.to_string()]);
    t.row_strs(&["characterization MAC reads", &sh.to_string()]);
    t.row_strs(&["latency @ 50 MHz", &format!("{wall_ms:.2} ms")]);
    t.row_strs(&[
        "area overhead",
        "trim DACs + digi-pots only (reuses compute path)",
    ]);
    t.print();
    assert!(wall_ms < 1000.0);
}
