//! Regenerates the Fig. 1 non-ideality plots (and the Fig. 3(b) input-DAC
//! transfer): DAC output error vs load, input-voltage attenuation across
//! columns, summation-node droop across rows, and the accumulated MAC
//! error with extracted gain/offset — the same four series the paper uses
//! to motivate BISC.

use acore_cim::analog::rdac::{InputCode, InputDac};
use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::util::stats;
use acore_cim::util::table::{f, Table};

fn main() {
    // --- Fig. 3(b): signed input-DAC transfer ---------------------------
    let dac = InputDac::default();
    let mut t = Table::new("Fig. 3(b) — input DAC transfer (signed)").header(&[
        "code",
        "V_DAC [V]",
    ]);
    for code in [-63, -48, -32, -16, 0, 16, 32, 48, 63] {
        t.row(&[code.to_string(), f(dac.output(InputCode(code)), 4)]);
    }
    t.print();

    // --- Fig. 1 plot 1: DAC non-idealities vs load (effects 1+2+3+6) ----
    let loaded = InputDac { r_out: 300.0, gain: 1.0, offset: 0.0 };
    let mut t = Table::new("Fig. 1 — DAC output error vs digital input (LSB)").header(&[
        "code",
        "R_L = 5 kOhm",
        "R_L = 11 kOhm",
    ]);
    for code in [0, 8, 16, 24, 32, 40, 48, 56, 63] {
        t.row(&[
            code.to_string(),
            f(loaded.error_lsb(InputCode(code), 5_000.0), 3),
            f(loaded.error_lsb(InputCode(code), 11_000.0), 3),
        ]);
    }
    t.print();
    println!("shape check: error grows with code, heavier load (smaller R_L) worse\n");

    // --- Fig. 1 plot 2: input-voltage drop across columns (1+3+4) -------
    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let model = CimAnalogModel::from_sample(&cfg, &sample);
    let mut t = Table::new("Fig. 1 — input differential attenuation across columns").header(&[
        "column",
        "attenuation factor",
    ]);
    for col in [0usize, 8, 16, 24, 31] {
        t.row(&[col.to_string(), f(model.array.col_factor(col), 4)]);
    }
    t.print();

    // --- Fig. 1 plot 3: V_REG droop across rows (3+5+7) ------------------
    let prof = model.array.vreg_profile(c::V_BIAS);
    let mut t = Table::new("Fig. 1 — summation-node regulation voltage across rows").header(&[
        "row",
        "V_REG [V]",
    ]);
    for row in [0usize, 9, 18, 27, 35] {
        t.row(&[row.to_string(), f(prof[row], 4)]);
    }
    t.print();

    // --- Fig. 1 plot 4: accumulated error, extracted (g, eps) -----------
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);
    model.program(&vec![c::CODE_MAX; c::N_ROWS * c::M_COLS]);
    let k = c::code_gain_nominal();
    let mid = c::q_mid_nominal();
    let col = 5;
    let mut nominal = Vec::new();
    let mut actual = Vec::new();
    let mut t = Table::new("Fig. 1 — accumulated MAC error (column 5)").header(&[
        "MAC value (x code)",
        "ideal Q",
        "actual Q",
        "error",
    ]);
    for x in (-48..=48).step_by(12) {
        let nom = mid + k * (x as f64 * 63.0 * c::N_ROWS as f64);
        let q = model.forward_batch(&vec![x; c::N_ROWS], 1)[col] as f64;
        nominal.push(nom);
        actual.push(q);
        t.row(&[x.to_string(), f(nom, 2), f(q, 1), f(q - nom, 2)]);
    }
    t.print();
    let (g, eps) = stats::linfit(&nominal, &actual);
    println!(
        "extracted per-column errors: g = {g:.3}, eps = {eps:.2} LSB \
         (the paper's Fig. 1 inset: systematic gain + offset deviations)\n"
    );
    assert!((g - 1.0).abs() > 0.005 || eps.abs() > 0.1, "die should show errors");
}
