//! Regenerates Table I (MWC with polysilicon / MOR / WOx / RRAM) and the
//! Fig. 2(c) SoC power distribution, with paper-vs-model columns.

use acore_cim::analog::power::{self, technologies, PowerBreakdown};
use acore_cim::util::table::{eng, f, Table};

fn main() {
    let techs = technologies();
    let base = techs[0].clone();

    let mut t = Table::new("Table I — performance with various resistive technologies").header(&[
        "technology",
        "R_U",
        "unit current (model)",
        "unit current (paper)",
        "area improv. (model/paper)",
        "power improv. (model/paper)",
    ]);
    let paper_current = ["2.6 uA", "0.15 uA", "0.036 uA", "33 uA"];
    let paper_area = ["baseline", "14x", "14x", "225x"];
    let paper_power = ["baseline", "17x", "70x", "0.08x"];
    for (i, tech) in techs.iter().enumerate() {
        let ai = tech.area_improvement(&base);
        let pi = tech.power_improvement(&base);
        t.row(&[
            tech.name.to_string(),
            eng(tech.r_u, "Ohm"),
            eng(tech.unit_current(), "A"),
            paper_current[i].to_string(),
            if i == 0 { "baseline".into() } else { format!("{:.0}x / {}", ai, paper_area[i]) },
            if i == 0 { "baseline".into() } else { format!("{:.2}x / {}", pi, paper_power[i]) },
        ]);
    }
    t.print();

    let b = PowerBreakdown::prototype();
    let total = b.total();
    let mut t = Table::new("Fig. 2(c) — power distribution of the SoC prototype").header(&[
        "component",
        "power [mW]",
        "share [%]",
    ]);
    for (name, p) in &b.components {
        t.row(&[name.to_string(), f(p * 1e3, 2), f(p / total * 100.0, 1)]);
    }
    t.print();
    println!(
        "macro {:.1} mW / system {:.1} mW; energy per inference {:.1} nJ (paper: 16.9 nJ)",
        b.macro_power() * 1e3,
        total * 1e3,
        power::macro_metrics().energy_per_inference * 1e9
    );
}
