//! Regenerates Fig. 10: per-column compute SNR (Eq. 15), uncalibrated vs
//! BISC-calibrated, plus the ENOB summary — the paper's headline claim:
//! +6-8 dB (25-45%) into the 18-24 dB band, every column improving,
//! average ENOB 2.3 -> 3.3 bits.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::snr::{measure_snr, SnrWorkload};
use acore_cim::util::stats;
use acore_cim::util::table::{f, Table};

fn main() {
    let mut cfg = SimConfig::default();
    cfg.seed = std::env::var("ACORE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cfg.seed);

    let sample = VariationSample::draw(&cfg);
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);
    let before = measure_snr(&mut model, SnrWorkload::Ramp, 128, cfg.seed);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    engine.calibrate(&mut model);
    let after = measure_snr(&mut model, SnrWorkload::Ramp, 128, cfg.seed);

    let mut t = Table::new("Fig. 10 — compute SNR per column").header(&[
        "col",
        "uncal [dB]",
        "BISC [dB]",
        "boost [dB]",
        "boost [%]",
    ]);
    let mut improved = 0;
    for col in 0..c::M_COLS {
        let b = after.snr_db[col] - before.snr_db[col];
        if b > 0.0 {
            improved += 1;
        }
        t.row(&[
            col.to_string(),
            f(before.snr_db[col], 1),
            f(after.snr_db[col], 1),
            f(b, 1),
            f((after.snr_db[col] / before.snr_db[col] - 1.0) * 100.0, 0),
        ]);
    }
    t.print();

    let boost = after.mean_snr_db() - before.mean_snr_db();
    let pct = (after.mean_snr_db() / before.mean_snr_db() - 1.0) * 100.0;
    let mut t = Table::new("summary vs paper").header(&["metric", "this repro", "paper"]);
    t.row_strs(&[
        "mean SNR uncal",
        &format!("{:.1} dB", before.mean_snr_db()),
        "~12-18 dB",
    ]);
    t.row_strs(&[
        "mean SNR BISC",
        &format!("{:.1} dB", after.mean_snr_db()),
        "18-24 dB",
    ]);
    t.row_strs(&["mean boost", &format!("{boost:.1} dB ({pct:.0}%)"), "6 dB avg, up to 8 dB (25-45%)"]);
    t.row_strs(&[
        "columns improved",
        &format!("{improved}/{}", c::M_COLS),
        "all",
    ]);
    t.row_strs(&[
        "ENOB avg",
        &format!("{:.2} -> {:.2} bits", before.mean_enob(), after.mean_enob()),
        "2.3 -> 3.3 bits",
    ]);
    t.row_strs(&[
        "SNR range after",
        &format!("{:.1} - {:.1} dB", after.min_snr_db(), after.max_snr_db()),
        "18-24 dB",
    ]);
    t.print();

    // shape assertions
    assert!(boost > 4.0, "boost too small: {boost}");
    assert!(
        improved as f64 >= c::M_COLS as f64 * 0.85,
        "most columns improve strictly ({improved}/{})",
        c::M_COLS
    );
    // a column may stay flat only if it is already comfortably good
    for col in 0..c::M_COLS {
        let regress = before.snr_db[col] - after.snr_db[col];
        assert!(
            regress < 2.0 && (regress < 0.5 || after.snr_db[col] > 18.0),
            "col {col} regressed {regress:.1} dB to {:.1} dB",
            after.snr_db[col]
        );
    }
    assert!(after.mean_snr_db() > 18.0 && after.mean_snr_db() < 27.0);

    // random-workload variant (robustness of the claim)
    let mut m2 = CimAnalogModel::from_sample(&cfg, &sample);
    let b2 = measure_snr(&mut m2, SnrWorkload::Random, 256, cfg.seed);
    engine.calibrate(&mut m2);
    let a2 = measure_snr(&mut m2, SnrWorkload::Random, 256, cfg.seed);
    println!(
        "random workload: {:.1} -> {:.1} dB (boost {:.1} dB)",
        b2.mean_snr_db(),
        a2.mean_snr_db(),
        a2.mean_snr_db() - b2.mean_snr_db()
    );

    // Monte-Carlo over dies: the claim holds across fabrication
    let mut boosts = Vec::new();
    for die in 0..5u64 {
        let mut cfg_i = cfg.clone();
        cfg_i.seed = cfg.seed ^ (0x1000 + die);
        let s = VariationSample::draw(&cfg_i);
        let mut m = CimAnalogModel::from_sample(&cfg_i, &s);
        let b = measure_snr(&mut m, SnrWorkload::Ramp, 64, die);
        engine.calibrate(&mut m);
        let a = measure_snr(&mut m, SnrWorkload::Ramp, 64, die);
        boosts.push(a.mean_snr_db() - b.mean_snr_db());
    }
    println!(
        "boost across 5 Monte-Carlo dies: mean {:.1} dB, min {:.1}, max {:.1}",
        stats::mean(&boosts),
        stats::min(&boosts),
        stats::max(&boosts)
    );
}
