//! Regenerates Fig. 7: error distributions for a selected CIM column
//! during the characterization phase (positive line / negative line,
//! uncalibrated) and in normal operation after BISC — showing distinct
//! per-line profiles and the post-calibration error collapse.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::util::stats;
use acore_cim::util::table::{f, Table};

const COL: usize = 7; // "a selected CIM column"

/// Error samples (actual - nominal, in LSB) for one line of one column.
fn line_errors(model: &mut CimAnalogModel, positive: bool, reads: usize) -> Vec<f64> {
    let wmax = if positive { c::CODE_MAX } else { -c::CODE_MAX };
    model.program_column(COL, &vec![wmax; c::N_ROWS]);
    let k = c::code_gain_nominal();
    let mid = c::q_mid_nominal();
    let sign = if positive { 1.0 } else { -1.0 };
    let mut errors = Vec::new();
    for x in -40..=40 {
        let nom = mid + k * (x as f64 * 63.0 * c::N_ROWS as f64) * sign;
        for _ in 0..reads {
            let q = model.forward_golden(&vec![x; c::N_ROWS])[COL] as f64;
            errors.push(q - nom);
        }
    }
    errors
}

fn histo_row(name: &str, errors: &[f64], t: &mut Table) {
    t.row(&[
        name.to_string(),
        f(stats::mean(errors), 2),
        f(stats::std_dev(errors), 2),
        f(stats::min(errors), 1),
        f(stats::max(errors), 1),
    ]);
}

fn render_hist(name: &str, errors: &[f64]) {
    let h = stats::histogram(errors, -8.0, 8.0, 16);
    let peak = *h.iter().max().unwrap() as f64;
    println!("{name:>24}:");
    for (i, &count) in h.iter().enumerate() {
        let lo = -8.0 + i as f64;
        let bar = "#".repeat((count as f64 / peak * 40.0) as usize);
        if count > 0 {
            println!("  [{lo:+5.1},{:+5.1}) {bar} {count}", lo + 1.0);
        }
    }
}

fn main() {
    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);

    // characterization phase (uncalibrated, per line)
    let pos_before = line_errors(&mut model, true, 2);
    let neg_before = line_errors(&mut model, false, 2);

    // BISC, then normal operation (random signed weights on the column)
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    engine.calibrate(&mut model);
    let pos_after = line_errors(&mut model, true, 2);
    let neg_after = line_errors(&mut model, false, 2);
    let mut normal: Vec<f64> = Vec::new();
    normal.extend_from_slice(&pos_after);
    normal.extend_from_slice(&neg_after);

    let mut t = Table::new(format!("Fig. 7 — error distributions, column {COL} (LSB)").as_str())
        .header(&["distribution", "mean", "std", "min", "max"]);
    histo_row("positive line (uncal)", &pos_before, &mut t);
    histo_row("negative line (uncal)", &neg_before, &mut t);
    histo_row("normal operation (BISC)", &normal, &mut t);
    t.print();

    render_hist("positive line (uncal)", &pos_before);
    render_hist("negative line (uncal)", &neg_before);
    render_hist("normal op (BISC)", &normal);

    // shape assertions matching the paper's narrative
    let spread_before = stats::std_dev(&pos_before).max(stats::std_dev(&neg_before))
        + stats::mean(&pos_before).abs().max(stats::mean(&neg_before).abs());
    let spread_after = stats::std_dev(&normal) + stats::mean(&normal).abs();
    println!(
        "\nerror magnitude (|mean|+std): {:.2} LSB uncal -> {:.2} LSB after BISC",
        spread_before, spread_after
    );
    assert!(spread_after < spread_before, "BISC must reduce errors");
    // the two lines show distinct profiles before calibration
    let distinct = (stats::mean(&pos_before) - stats::mean(&neg_before)).abs();
    println!("pos/neg line profile separation before BISC: {distinct:.2} LSB");
}
