//! Positive fixture for `unsafe_block_safety`: an unsafe block with no
//! `// SAFETY:` comment anywhere near it.

pub fn read_register(p: *const u32) -> u32 {
    unsafe { p.read_volatile() } // violation: no SAFETY comment
}
