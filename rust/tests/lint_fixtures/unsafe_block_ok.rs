//! Negative fixture for `unsafe_block_safety`: the safety contract is
//! stated immediately above the block.

pub fn read_register(p: *const u32) -> u32 {
    // SAFETY: fixture — the caller guarantees `p` is non-null, aligned,
    // and points into a live MMIO mapping for the duration of the call.
    unsafe { p.read_volatile() }
}
