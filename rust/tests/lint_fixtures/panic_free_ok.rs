//! Negative fixture for `panic_free`: the same shapes written the way
//! serving code must write them — graceful fallbacks, constant-only
//! indexing, checked invariants, test-module exemption, and exactly one
//! justified suppression (the driving test asserts `allows_used == 1`).

pub fn answer(queue: &mut Vec<u32>, i: usize) -> u32 {
    let head = queue.pop().unwrap_or(0);
    let first = queue.get(i).copied().unwrap_or_default();
    let fixed = [1u32, 2, 3];
    let second = fixed[0] + fixed[2];
    assert!(second > 0, "assert! states an invariant; it is not flagged");
    // lint: allow(panic_free) — fixture: a deliberately suppressed index with a justification
    let third = queue[i % 2];
    head + first + second + third
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap_freely() {
        let v = vec![1u32];
        assert_eq!(*v.first().unwrap(), 1);
        v.get(0).expect("test code is exempt from panic_free");
    }
}
