//! Positive fixture for `lock_across_io`: a mutex guard held across
//! socket writes, and a lock taken in the same statement as a send.

pub fn guard_across_write(m: &Mutex<Stats>, w: &mut TcpStream) {
    let guard = m.lock();
    let _ = w.write_all(b"stats"); // violation: write while `guard` is live
    let _ = w.flush(); // violation: `guard` is still live here
    drop(guard);
}

pub fn lock_in_send_statement(m: &Mutex<u64>, tx: &Sender<u64>) {
    let _ = tx.send(*lock_unpoisoned(m)); // violation: lock and send in one statement
}
