//! Negative fixture for `lock_across_io`: the disciplined shapes —
//! explicit drop before I/O, a scoped guard, and one deliberately
//! justified write-mutex site (the driving test asserts
//! `allows_used == 1`).

pub fn drop_before_write(m: &Mutex<Stats>, w: &mut TcpStream) {
    let guard = m.lock();
    let snapshot = clone_of(&guard);
    drop(guard);
    let _ = w.write_all(&snapshot);
    let _ = w.flush();
}

pub fn scope_before_write(m: &Mutex<Stats>, w: &mut TcpStream) {
    let mut snapshot = Stats::default();
    {
        let guard = m.lock();
        snapshot = clone_of(&guard);
    }
    let _ = w.write_all(&snapshot);
}

pub fn deliberate_write_mutex(w: &Mutex<TcpStream>, buf: &[u8]) {
    let mut stream = lock_unpoisoned(w);
    // lint: allow(lock_across_io) — fixture: a write mutex exists to serialize whole-frame writes
    let _ = stream.write_all(buf);
    drop(stream);
}
