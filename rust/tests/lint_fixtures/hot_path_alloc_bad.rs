//! Positive fixture for `hot_path_alloc`: a `_into` kernel body that
//! allocates per call in every way the rule knows about.

pub fn forward_batch_into(x: &[i32], out: &mut Vec<u32>) {
    let mut staging = Vec::new(); // violation: Vec::new in a _into body
    staging.extend(x.iter().map(|&v| v as u32));
    let copied = staging.to_vec(); // violation: .to_vec()
    let doubled: Vec<u32> = copied.iter().map(|v| v * 2).collect(); // violation: .collect()
    let label = format!("{} lanes", doubled.len()); // violation: format!
    let boxed = Box::new(label); // violation: Box::new
    drop(boxed);
    out.extend_from_slice(&doubled);
}
