//! Positive fixture for `lint_allow_justification`: a suppression must
//! name a real rule AND carry a justification. A bare allow is itself a
//! violation — and it suppresses nothing, so the site it hovers over
//! still reports too.

pub fn sloppy(v: &[u32], i: usize) -> u32 {
    // lint: allow(panic_free)
    let a = v[i]; // still reported: the allow above has no justification
    // lint: allow(no_such_rule) — a justification cannot save an unknown rule
    a + (i as u32)
}
