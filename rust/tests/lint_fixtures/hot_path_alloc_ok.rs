//! Negative fixture for `hot_path_alloc`: a steady-state `_into` kernel
//! that only reuses caller-owned capacity, next to a non-kernel helper
//! that may allocate freely (the rule scopes to `_into` bodies only).

pub fn forward_batch_into(x: &[i32], scratch: &mut Vec<u32>, out: &mut Vec<u32>) {
    scratch.clear();
    scratch.reserve(x.len());
    for &v in x {
        scratch.push(v as u32);
    }
    out.clear();
    out.extend_from_slice(scratch);
}

/// Not a `_into` kernel: allocation here is outside the rule's scope.
pub fn forward_batch(x: &[i32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut scratch = Vec::new();
    forward_batch_into(x, &mut scratch, &mut out);
    out
}
