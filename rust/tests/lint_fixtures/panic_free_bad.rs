//! Positive fixture for `panic_free`: every marked line below must be
//! reported when this file is linted under a serving-scope path.
//! Never compiled — `tests/lint.rs` feeds it to the linter as text.

pub fn answer(queue: &mut Vec<u32>, i: usize) -> u32 {
    let head = queue.pop().unwrap(); // violation: .unwrap()
    let tail = queue.pop().expect("non-empty"); // violation: .expect()
    if head == tail {
        panic!("head met tail"); // violation: panic!
    }
    match head {
        0 => unreachable!("zero is filtered upstream"), // violation: unreachable!
        _ => {}
    }
    head + queue[i] // violation: non-constant slice indexing
}
