//! Wire protocol tests: randomized codec round-trip properties,
//! adversarial decodes (truncated / oversized / unknown-tag / wrong-
//! version frames must surface as `WireError`, never a panic), and a
//! loopback `WireServer`/`RemoteClient` integration run driven through
//! the `CimService` trait — including a `Drain` that recalibrates and a
//! post-drain `Health` back in band.

use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::{Batcher, BatcherStats, ModelStats, ServeError};
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::calibrator::CoreCalStats;
use acore_cim::coordinator::cluster::{core_seed, CimCluster, ServiceConfig};
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::coordinator::service::{
    gather, CimService, CoreHealth, Job, JobReply, Placement, SubmitOpts, Ticket, TileRef,
};
use acore_cim::coordinator::wire::{
    encode_frame, read_frame, Frame, RemoteClient, WireError, WireServer, HEADER_LEN, MAX_BODY,
    WIRE_VERSION,
};
use acore_cim::util::proptest::forall;
use acore_cim::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

// ---- randomized round-trip properties -----------------------------------

fn rand_vec_i32(rng: &mut Rng, max_len: i64) -> Vec<i32> {
    (0..rng.int_in(0, max_len)).map(|_| rng.int_in(-64, 63) as i32).collect()
}

fn rand_vec_u32(rng: &mut Rng, max_len: i64) -> Vec<u32> {
    (0..rng.int_in(0, max_len)).map(|_| rng.next_u64() as u32).collect()
}

fn rand_model(rng: &mut Rng) -> Option<u32> {
    if rng.int_in(0, 1) == 1 {
        Some(rng.int_in(0, 9000) as u32)
    } else {
        None
    }
}

fn rand_job(rng: &mut Rng) -> Job {
    match rng.int_in(0, 5) {
        0 => Job::Mac(rand_vec_i32(rng, 40)),
        1 => {
            let n = rng.int_in(0, 6);
            let xs = (0..n).map(|_| rand_vec_i32(rng, 12)).collect();
            let tile = if rng.int_in(0, 1) == 1 {
                Some(TileRef {
                    layer: rng.int_in(0, 3) as usize,
                    tr: rng.int_in(0, 7) as usize,
                    tc: rng.int_in(0, 7) as usize,
                })
            } else {
                None
            };
            Job::MacBatch { xs, tile, model: rand_model(rng) }
        }
        2 => Job::Drain,
        3 => Job::Rollout {
            model: rng.int_in(0, 9000) as u32,
            weights: rand_vec_i32(rng, 24),
        },
        4 => Job::Faults(match rng.int_in(0, 2) {
            // the codec carries the plan as an opaque string — exercise
            // empty, well-formed, and junk specs alike
            0 => String::new(),
            1 => format!(
                "core={},col={};core={},at={},sa={}:0.0",
                rng.int_in(0, 7),
                rng.int_in(0, 31),
                rng.int_in(0, 7),
                rng.next_u64() % 100_000,
                rng.int_in(0, 31),
            ),
            _ => format!("not a plan at all #{} — ünïcode", rng.int_in(0, 999)),
        }),
        _ => Job::Health,
    }
}

fn rand_opts(rng: &mut Rng) -> SubmitOpts {
    let placement = match rng.int_in(0, 3) {
        0 => Placement::RoundRobin,
        1 => Placement::LeastLoaded,
        2 => Placement::Pinned(rng.int_in(0, 15) as usize),
        _ => Placement::Model {
            model: rng.int_in(0, 9000) as u32,
            tile: if rng.int_in(0, 1) == 1 {
                Some(TileRef {
                    layer: rng.int_in(0, 3) as usize,
                    tr: rng.int_in(0, 7) as usize,
                    tc: rng.int_in(0, 7) as usize,
                })
            } else {
                None
            },
        },
    };
    SubmitOpts {
        priority: rng.int_in(0, 255) as u8,
        deadline: if rng.int_in(0, 1) == 1 {
            Some(Duration::from_nanos(rng.next_u64()))
        } else {
            None
        },
        placement,
    }
}

fn rand_serve_error(rng: &mut Rng) -> ServeError {
    match rng.int_in(0, 7) {
        0 => ServeError::BadRequest {
            expected: rng.int_in(0, 1024) as usize,
            got: rng.int_in(0, 1024) as usize,
        },
        1 => ServeError::Backend(format!("backend error #{} — ünïcode", rng.int_in(0, 999))),
        2 => ServeError::Disconnected,
        3 => ServeError::DeadlineExceeded,
        4 => ServeError::ModelNotResident { model: rng.int_in(0, 9000) as u32 },
        5 => ServeError::WrongModel {
            requested: rng.int_in(0, 9000) as u32,
            resident: rand_model(rng),
        },
        6 => ServeError::Overloaded {
            in_flight: rng.int_in(0, 1 << 20) as usize,
            limit: rng.int_in(0, 1 << 20) as usize,
        },
        _ => ServeError::NoHealthyCore,
    }
}

fn rand_reply(rng: &mut Rng) -> JobReply {
    match rng.int_in(0, 2) {
        0 => JobReply::Mac(rand_vec_u32(rng, 40)),
        1 => {
            let n = rng.int_in(0, 6);
            JobReply::MacBatch((0..n).map(|_| rand_vec_u32(rng, 12)).collect())
        }
        _ => JobReply::Health(CoreHealth {
            core: rng.int_in(0, 15) as usize,
            residual: if rng.int_in(0, 1) == 1 { Some(rng.uniform()) } else { None },
            fenced: rng.int_in(0, 1) == 1,
            recalibrated: rng.int_in(0, 1) == 1,
            recal_epoch: rng.next_u64(),
            model: rand_model(rng),
            retired: rng.int_in(0, 1) == 1,
            fault_mask: rng.next_u64() as u32,
        }),
    }
}

fn rand_stats(rng: &mut Rng) -> BatcherStats {
    BatcherStats {
        requests: rng.next_u64(),
        batches: rng.next_u64(),
        max_batch_seen: rng.int_in(0, 4096) as usize,
        rejected: rng.next_u64(),
        expired: rng.next_u64(),
    }
}

fn rand_calstats(rng: &mut Rng) -> CoreCalStats {
    CoreCalStats {
        samples: rng.next_u64(),
        trend: if rng.int_in(0, 1) == 1 { Some(rng.uniform()) } else { None },
        last_recal_epoch: rng.next_u64(),
        trend_triggers: rng.next_u64(),
        staleness_triggers: rng.next_u64(),
        drains: rng.next_u64(),
        drain_failures: rng.next_u64(),
        fenced: rng.int_in(0, 1) == 1,
        model: rand_model(rng),
        retired: rng.int_in(0, 1) == 1,
    }
}

fn rand_modelstats(rng: &mut Rng) -> ModelStats {
    ModelStats {
        model: rng.int_in(0, 9000) as u32,
        requests: rng.next_u64(),
        rejected: rng.next_u64(),
        expired: rng.next_u64(),
        recals: rng.next_u64(),
    }
}

fn rand_hello(rng: &mut Rng) -> Frame {
    let cores = rng.int_in(1, 8) as u32;
    let models = (0..rng.int_in(0, 4))
        .map(|i| format!("model-{i}"))
        .collect();
    let residency = (0..cores as usize)
        .map(|_| {
            if rng.int_in(0, 1) == 1 {
                let tiles = (0..rng.int_in(0, 4))
                    .map(|_| TileRef {
                        layer: rng.int_in(0, 3) as usize,
                        tr: rng.int_in(0, 7) as usize,
                        tc: rng.int_in(0, 7) as usize,
                    })
                    .collect();
                Some((rng.int_in(0, 9000) as u32, tiles))
            } else {
                None
            }
        })
        .collect();
    Frame::Hello { cores, window: rng.int_in(1, 1 << 16) as u32, models, residency }
}

fn rand_residency(rng: &mut Rng) -> Option<(u32, Vec<TileRef>)> {
    if rng.int_in(0, 1) == 1 {
        let tiles = (0..rng.int_in(0, 4))
            .map(|_| TileRef {
                layer: rng.int_in(0, 3) as usize,
                tr: rng.int_in(0, 7) as usize,
                tc: rng.int_in(0, 7) as usize,
            })
            .collect();
        Some((rng.int_in(0, 9000) as u32, tiles))
    } else {
        None
    }
}

fn rand_frame(rng: &mut Rng) -> Frame {
    match rng.int_in(0, 15) {
        0 => rand_hello(rng),
        1 => Frame::Submit { id: rng.next_u64(), job: rand_job(rng), opts: rand_opts(rng) },
        2 => {
            let result = if rng.int_in(0, 1) == 1 {
                Ok(rand_reply(rng))
            } else {
                Err(rand_serve_error(rng))
            };
            Frame::Reply { id: rng.next_u64(), core: rng.int_in(0, 64) as u32, result }
        }
        3 => Frame::StatsReq { id: rng.next_u64() },
        4 => {
            let n = rng.int_in(0, 8);
            Frame::StatsReply {
                id: rng.next_u64(),
                stats: (0..n).map(|_| rand_stats(rng)).collect(),
            }
        }
        5 => Frame::CalStatsReq { id: rng.next_u64() },
        6 => {
            let n = rng.int_in(0, 8);
            Frame::CalStatsReply {
                id: rng.next_u64(),
                stats: (0..n).map(|_| rand_calstats(rng)).collect(),
            }
        }
        7 => Frame::ModelStatsReq { id: rng.next_u64() },
        8 => Frame::ModelStatsReply {
            id: rng.next_u64(),
            stats: (0..rng.int_in(0, 8)).map(|_| rand_modelstats(rng)).collect(),
        },
        // wire v4: flow control + the server-pushed control plane
        9 => Frame::Subscribe { id: rng.next_u64() },
        10 => Frame::Credit { grant: rng.next_u64() as u32 },
        11 => Frame::FencePush {
            core: rng.int_in(0, 64) as u32,
            fenced: rng.int_in(0, 1) == 1,
        },
        12 => Frame::RecalEpochPush { core: rng.int_in(0, 64) as u32, epoch: rng.next_u64() },
        13 => Frame::ResidencyPush {
            core: rng.int_in(0, 64) as u32,
            residency: rand_residency(rng),
        },
        // wire v5: permanent retirement
        14 => Frame::RetirePush {
            core: rng.int_in(0, 64) as u32,
            mask: rng.next_u64() as u32,
        },
        _ => Frame::CalStatsPush {
            stats: (0..rng.int_in(0, 8)).map(|_| rand_calstats(rng)).collect(),
        },
    }
}

#[test]
fn codec_roundtrips_randomized_frames() {
    forall("wire frame round-trip", 512, |rng| {
        let frame = rand_frame(rng);
        let bytes = encode_frame(&frame);
        let mut slice: &[u8] = &bytes;
        let decoded = match read_frame(&mut slice) {
            Ok(f) => f,
            Err(e) => return Err(format!("decode failed on {frame:?}: {e}")),
        };
        if decoded != frame {
            return Err(format!("round-trip mismatch:\n  sent {frame:?}\n  got  {decoded:?}"));
        }
        if !slice.is_empty() {
            return Err(format!("{} bytes left unconsumed", slice.len()));
        }
        Ok(())
    });
}

#[test]
fn back_to_back_frames_decode_in_order() {
    // a stream is frames laid end to end; each decode must consume
    // exactly one frame
    let frames = vec![
        Frame::Hello {
            cores: 3,
            window: 1024,
            models: vec!["demo".to_string()],
            residency: vec![None; 3],
        },
        Frame::Submit { id: 1, job: Job::Mac(vec![1, 2, 3]), opts: SubmitOpts::default() },
        Frame::Reply { id: 1, core: 0, result: Ok(JobReply::Mac(vec![9, 8])) },
        Frame::StatsReq { id: 2 },
    ];
    let mut bytes = Vec::new();
    for f in &frames {
        bytes.extend_from_slice(&encode_frame(f));
    }
    let mut slice: &[u8] = &bytes;
    for f in &frames {
        assert_eq!(&read_frame(&mut slice).unwrap(), f);
    }
    assert!(matches!(read_frame(&mut slice), Err(WireError::Closed)));
}

// ---- adversarial decodes -------------------------------------------------

#[test]
fn truncated_frames_error_at_every_cut_point() {
    let frame = encode_frame(&Frame::Submit {
        id: 42,
        job: Job::MacBatch { xs: vec![vec![1, 2], vec![3, 4]], tile: None, model: None },
        opts: SubmitOpts::default().with_deadline(Duration::from_millis(5)),
    });
    for cut in 1..frame.len() {
        let mut slice = &frame[..cut];
        match read_frame(&mut slice) {
            Err(WireError::Truncated) => {}
            other => {
                panic!("cut at {cut}/{} bytes: expected Truncated, got {other:?}", frame.len())
            }
        }
    }
    // a clean EOF exactly at a frame boundary is Closed, not Truncated
    let mut empty: &[u8] = &[];
    assert!(matches!(read_frame(&mut empty), Err(WireError::Closed)));
}

#[test]
fn bad_magic_version_tag_and_oversized_length_are_typed_errors() {
    let frame = encode_frame(&Frame::StatsReq { id: 7 });
    assert_eq!(frame.len(), HEADER_LEN);

    let mut bad = frame.clone();
    bad[0] ^= 0xFF;
    let mut slice: &[u8] = &bad;
    assert!(matches!(read_frame(&mut slice), Err(WireError::BadMagic(_))));

    let mut bad = frame.clone();
    bad[2] = WIRE_VERSION + 1;
    let mut slice: &[u8] = &bad;
    assert_eq!(read_frame(&mut slice), Err(WireError::BadVersion(WIRE_VERSION + 1)));

    let mut bad = frame.clone();
    bad[3] = 0xEE;
    let mut slice: &[u8] = &bad;
    assert_eq!(read_frame(&mut slice), Err(WireError::UnknownTag(0xEE)));

    // an oversized body length prefix is rejected before any allocation
    let mut bad = frame.clone();
    bad[12..16].copy_from_slice(&(MAX_BODY + 1).to_le_bytes());
    let mut slice: &[u8] = &bad;
    assert_eq!(
        read_frame(&mut slice),
        Err(WireError::Oversized { len: MAX_BODY + 1, max: MAX_BODY })
    );
}

#[test]
fn hostile_interior_length_prefix_is_truncated_not_oom() {
    // a well-framed Submit whose nested vector claims u32::MAX elements:
    // the decoder must reject it from the remaining byte count instead of
    // allocating 16 GiB
    let mut bad = encode_frame(&Frame::Submit {
        id: 9,
        job: Job::Mac(Vec::new()),
        opts: SubmitOpts::default(),
    });
    let n = bad.len();
    // the trailing 4 bytes are Job::Mac's element-count prefix
    bad[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
    let mut slice: &[u8] = &bad;
    assert_eq!(read_frame(&mut slice), Err(WireError::Truncated));
}

#[test]
fn trailing_bytes_after_the_body_are_rejected() {
    let mut bad = encode_frame(&Frame::StatsReq { id: 1 });
    bad.push(0);
    bad[12..16].copy_from_slice(&1u32.to_le_bytes());
    let mut slice: &[u8] = &bad;
    assert!(matches!(read_frame(&mut slice), Err(WireError::BadPayload(_))));
}

#[test]
fn hostile_interior_fault_and_retirement_bytes_are_typed_errors() {
    // wire v5 adversarial decodes: every new byte position must fail as a
    // typed WireError, never a panic or a silent mis-decode.

    // a Faults plan whose string bytes are not UTF-8
    let mut bad = encode_frame(&Frame::Submit {
        id: 5,
        job: Job::Faults("core=0,col=1".to_string()),
        opts: SubmitOpts::default(),
    });
    let n = bad.len();
    bad[n - 1] = 0xFF; // 0xFF is invalid in any UTF-8 position
    let mut slice: &[u8] = &bad;
    assert!(matches!(read_frame(&mut slice), Err(WireError::BadPayload(_))));

    // a Health reply whose retired flag is neither 0 nor 1
    let mut bad = encode_frame(&Frame::Reply {
        id: 6,
        core: 0,
        result: Ok(JobReply::Health(CoreHealth {
            core: 0,
            residual: None,
            fenced: true,
            recalibrated: false,
            recal_epoch: 3,
            model: None,
            retired: true,
            fault_mask: 0x0000_0088,
        })),
    });
    let n = bad.len();
    // the retired bool sits immediately before the trailing 4-byte fault mask
    bad[n - 5] = 7;
    let mut slice: &[u8] = &bad;
    assert!(matches!(read_frame(&mut slice), Err(WireError::BadPayload(_))));

    // a RetirePush cut short inside its fault mask
    let full = encode_frame(&Frame::RetirePush { core: 1, mask: 0xDEAD_BEEF });
    let body_len = (full.len() - HEADER_LEN - 2) as u32;
    let mut bad = full[..full.len() - 2].to_vec();
    bad[12..16].copy_from_slice(&body_len.to_le_bytes());
    let mut slice: &[u8] = &bad;
    assert_eq!(read_frame(&mut slice), Err(WireError::Truncated));
}

// ---- loopback integration ------------------------------------------------

fn ideal_cfg() -> SimConfig {
    let mut cfg = SimConfig::default().scaled(0.0);
    cfg.sigma_noise = 0.0;
    cfg
}

/// Bind a `WireServer` on an ephemeral loopback port and run its accept
/// loop on a background thread.
fn spawn_wire(
    server: &acore_cim::coordinator::cluster::ClusterServer,
) -> (Arc<WireServer>, std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port")
            .with_models(vec!["demo".to_string()])
            .with_model_stats(server.model_stats_handles()),
    );
    let addr = wire.local_addr().expect("bound listener has an address");
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };
    (wire, addr, acceptor)
}

#[test]
fn loopback_round_trip_through_the_cim_service_trait() {
    let cfg = ideal_cfg();
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let (wire, addr, acceptor) = spawn_wire(&server);
    let client = RemoteClient::connect(addr).expect("connect loopback");
    assert_eq!(client.cores(), 2, "handshake must carry the core count");

    // correctness against a direct model evaluation (ideal dies => every
    // core computes the same answer)
    let mut reference = CimAnalogModel::ideal();
    reference.program(&vec![40; c::N_ROWS * c::M_COLS]);
    let x = vec![30; c::N_ROWS];
    let expect = reference.forward_batch(&x, 1);
    assert_eq!(client.mac(x.clone()).unwrap(), expect);

    // many concurrent in-flight jobs on ONE connection, correlated by
    // request id: interleave Macs and native MacBatches, then gather
    let macs: Vec<Ticket<Vec<u32>>> = (0..32)
        .map(|_| client.submit(Job::Mac(x.clone()), SubmitOpts::default()).unwrap().typed())
        .collect();
    let batches: Vec<Ticket<Vec<Vec<u32>>>> = (0..4)
        .map(|_| {
            let xs: Vec<Vec<i32>> = (0..8).map(|_| x.clone()).collect();
            client
                .submit(Job::MacBatch { xs, tile: None, model: None }, SubmitOpts::least_loaded())
                .unwrap()
                .typed()
        })
        .collect();
    for (_, qs) in gather(batches).unwrap() {
        assert_eq!(qs.len(), 8);
        for q in qs {
            assert_eq!(q, expect);
        }
    }
    for (_, q) in gather(macs).unwrap() {
        assert_eq!(q, expect);
    }
    // every mirror depth reservation settles once replies are gathered
    assert_eq!(client.board().in_flight(0), 0);
    assert_eq!(client.board().in_flight(1), 0);

    // serving errors surface typed over the wire, and the connection
    // keeps serving afterwards
    let err = client.mac(vec![1; 3]).unwrap_err();
    assert_eq!(err, ServeError::BadRequest { expected: c::N_ROWS, got: 3 });
    assert_eq!(client.mac(x.clone()).unwrap(), expect);

    // no calibrator daemon attached: calstats answers empty, not an error
    assert!(client.calibrator_stats().unwrap().is_empty());

    // clones share the connection across producer threads
    let mut joins = Vec::new();
    for _ in 0..4 {
        let cl = client.clone();
        let x = x.clone();
        let expect = expect.clone();
        joins.push(std::thread::spawn(move || {
            for _ in 0..10 {
                assert_eq!(cl.mac(x.clone()).unwrap(), expect);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // the remote live-stats snapshot converges on the served total
    // (workers republish each dispatch round, so poll briefly)
    let want = 32 + 4 * 8 + 2 + 40;
    let mut total = 0;
    for _ in 0..100 {
        let stats = client.remote_stats().expect("stats over the wire");
        assert_eq!(stats.len(), 2);
        total = stats.iter().map(|s| s.requests).sum::<u64>();
        if total >= want {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(total >= want, "live stats stuck at {total}, want >= {want}");

    drop(client);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    let (_cluster, stats) = server.join();
    let served: u64 = stats.iter().map(|s| s.requests).sum();
    assert!(served >= want, "workers served {served}, want >= {want}");
}

#[test]
fn remote_drain_recalibrates_and_post_drain_health_is_in_band() {
    // noise-free default-sigma dies: deterministic residuals, twin trick
    // for a band that provably separates uncalibrated from calibrated
    // (same construction as tests/service.rs, here over a real socket)
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let mut cfg1 = cfg.clone();
    cfg1.seed = core_seed(cfg.seed, 1);
    let mut twin = CimAnalogModel::from_sample(&cfg1, &cluster.cores[1].sample);
    let r_uncal = engine.residual_gain_error(&mut twin);
    engine.calibrate(&mut twin);
    let r_cal = engine.residual_gain_error(&mut twin);
    assert!(r_cal < r_uncal, "BISC did not improve the twin: {r_cal} vs {r_uncal}");
    let band = 0.5 * (r_cal + r_uncal);

    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        health_band: band,
    });
    let (wire, addr, acceptor) = spawn_wire(&server);
    let client = RemoteClient::connect(addr).expect("connect loopback");

    // the remote health probe finds core 1 out of band; the reply syncs
    // the client's fence mirror
    let h = client.health(1).unwrap();
    assert_eq!(h.core, 1);
    assert!(h.residual.expect("engine is configured") > band);
    assert!(h.fenced);
    assert!(client.is_fenced(1), "fence state must mirror over the wire");

    // edge-resolved placement now avoids the fenced core
    for _ in 0..8 {
        let t = client.submit(Job::Mac(vec![30; c::N_ROWS]), SubmitOpts::default()).unwrap();
        assert_ne!(t.core(), 1, "job placed on a fenced core through the wire");
        t.typed::<Vec<u32>>().wait().unwrap();
    }

    // drain over the wire: fence -> barrier -> recalibrate -> rejoin
    let h = client.drain(1).unwrap();
    assert!(h.recalibrated, "drain with an engine must recalibrate");
    assert!(h.residual.expect("engine is configured") <= band);
    assert!(!h.fenced);
    assert!(!client.is_fenced(1), "rejoin must mirror over the wire");

    // a post-drain health probe is back in band and leaves the core in
    assert!(client.board().recal_epoch(1) > 0, "mirror must track the recalibration");
    let h = client.health(1).unwrap();
    assert!(h.residual.expect("engine is configured") <= band);
    assert!(!h.fenced);

    // the rejoined core serves remote traffic again
    let mut served_core1 = false;
    let tickets: Vec<Ticket<Vec<u32>>> = (0..8)
        .map(|_| {
            let t =
                client.submit(Job::Mac(vec![30; c::N_ROWS]), SubmitOpts::default()).unwrap();
            served_core1 |= t.core() == 1;
            t.typed()
        })
        .collect();
    gather(tickets).unwrap();
    assert!(served_core1, "rejoined core never placed");

    drop(client);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    let (cluster, stats) = server.join();
    assert!(cluster.cores[1].report.is_some(), "in-service recalibration left no report");
    assert!(stats[1].requests <= 8, "fenced core served placed jobs: {:?}", stats[1]);
}

#[test]
fn remote_mirror_syncs_epochs_from_drains_it_never_requested() {
    // the stale-mirror fix: client B never drains anything, but client
    // A's (or the calibrator daemon's) recalibration must reach B's
    // board mirror through the server-observed epoch in Health replies
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        ..ServiceConfig::default()
    });
    let (wire, addr, acceptor) = spawn_wire(&server);
    let a = RemoteClient::connect(addr).expect("connect client A");
    let b = RemoteClient::connect(addr).expect("connect client B");
    assert_eq!(b.board().recal_epoch(1), 0);

    // A recalibrates core 1; B has observed nothing yet
    let h = a.drain(1).unwrap();
    assert!(h.recalibrated);
    assert!(h.recal_epoch > 0, "drain reply must carry the server epoch");
    assert_eq!(
        b.board().recal_epoch(1),
        0,
        "replies are not pushed to other connections"
    );

    // B's next lifecycle probe observes the server epoch and catches up
    let hb = b.health(1).unwrap();
    assert_eq!(hb.recal_epoch, h.recal_epoch);
    assert_eq!(
        b.board().recal_epoch(1),
        h.recal_epoch,
        "mirror must sync from a drain it never requested"
    );

    drop(a);
    drop(b);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    server.join();
}

#[test]
fn calstats_over_the_wire_report_the_daemon() {
    use acore_cim::coordinator::calibrator::{Calibrator, CalibratorConfig};

    let cfg = ideal_cfg();
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        ..ServiceConfig::default()
    });
    // fast-sampling daemon with an unreachable threshold: it observes
    // residuals but must never drain
    let daemon = Calibrator::spawn(
        server.client(),
        CalibratorConfig {
            period: Duration::from_millis(5),
            threshold: f64::INFINITY,
            max_staleness: Duration::from_secs(3600),
            cooldown: Duration::from_millis(10),
            ewma_alpha: 0.5,
        },
    );
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port")
            .with_calibrator(daemon.shared()),
    );
    let addr = wire.local_addr().expect("bound listener has an address");
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };
    let client = RemoteClient::connect(addr).expect("connect loopback");
    let mut sampled = false;
    for _ in 0..500 {
        let stats = client.calibrator_stats().expect("calstats over the wire");
        assert_eq!(stats.len(), 2, "one entry per core");
        if stats.iter().all(|s| s.samples > 0 && s.trend.is_some()) {
            sampled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(sampled, "daemon never published residual samples");
    drop(client);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    let stats = daemon.stop();
    assert!(
        stats.iter().all(|s| s.drains == 0 && s.trend_triggers == 0),
        "an infinite threshold must never trigger: {stats:?}"
    );
    server.join();
}

#[test]
fn a_stalled_reader_cannot_stall_other_connections() {
    // the event-loop isolation property: a peer that submits a burst and
    // then never reads a byte parks its replies in ITS outbound buffer
    // only — a second connection keeps round-tripping. Under the old
    // thread-per-connection design the stalled socket blocked its writer
    // thread for a 10s timeout per reply; here the healthy client's
    // round-trips below complete (or the whole test times out).
    use std::io::Write as _;
    use std::net::TcpStream;

    let cfg = ideal_cfg();
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let (wire, addr, acceptor) = spawn_wire(&server);

    let mut stalled = TcpStream::connect(addr).unwrap();
    let hello = read_frame(&mut stalled).unwrap();
    assert!(matches!(hello, Frame::Hello { .. }));
    let x = vec![30; c::N_ROWS];
    let mut burst = Vec::new();
    for id in 1..=64u64 {
        burst.extend_from_slice(&encode_frame(&Frame::Submit {
            id,
            job: Job::Mac(x.clone()),
            opts: SubmitOpts::default(),
        }));
    }
    stalled.write_all(&burst).unwrap();
    // ... and from here the stalled peer reads nothing

    let client = RemoteClient::connect(addr).expect("connect healthy client");
    for _ in 0..32 {
        assert_eq!(client.mac(x.clone()).unwrap().len(), c::M_COLS);
    }

    // no reply was dropped: once the stalled peer resumes reading, all
    // 64 are sitting there in completion order, plus its credit grants
    let mut seen = 0;
    while seen < 64 {
        match read_frame(&mut stalled).unwrap() {
            Frame::Reply { result, .. } => {
                result.unwrap();
                seen += 1;
            }
            Frame::Credit { .. } => {}
            other => panic!("unexpected frame {other:?}"),
        }
    }

    drop(stalled);
    drop(client);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    server.join();
}

#[test]
fn an_idle_subscriber_observes_pushed_recal_epochs() {
    // the control-plane push: client B subscribes and then NEVER submits
    // or probes — client A's drain must still reach B's board mirror,
    // carried entirely by server-initiated push frames
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        ..ServiceConfig::default()
    });
    let (wire, addr, acceptor) = spawn_wire(&server);
    let a = RemoteClient::connect(addr).expect("connect client A");
    let b = RemoteClient::connect(addr).expect("connect client B");
    b.subscribe().expect("subscribe B");
    assert_eq!(b.board().recal_epoch(1), 0);

    let h = a.drain(1).unwrap();
    assert!(h.recal_epoch > 0, "drain reply must carry the server epoch");

    // B stays idle; poll only ITS OWN mirror for the pushed delta
    let mut synced = false;
    for _ in 0..400 {
        if b.board().recal_epoch(1) == h.recal_epoch && !b.is_fenced(1) {
            synced = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(synced, "pushed recal epoch never reached the idle subscriber's mirror");

    drop(a);
    drop(b);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    server.join();
}

#[test]
fn a_window_overrun_is_answered_with_a_typed_overload() {
    // admission control: with a 1-deep window, a burst of submits behind
    // a slow barrier job must be shed with the typed, retryable
    // `Overloaded` — and the connection must survive the rejection
    use acore_cim::coordinator::wire::write_frame;
    use std::io::Write as _;
    use std::net::TcpStream;

    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 1);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        ..ServiceConfig::default()
    });
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port")
            .with_admission(1, None),
    );
    let addr = wire.local_addr().expect("bound listener has an address");
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };

    let mut raw = TcpStream::connect(addr).unwrap();
    match read_frame(&mut raw).unwrap() {
        Frame::Hello { window, .. } => assert_eq!(window, 1, "Hello must advertise the window"),
        other => panic!("expected Hello, got {other:?}"),
    }
    // one write: a Drain (slow — it recalibrates) followed by Macs that
    // arrive while it is still in flight
    let x = vec![30; c::N_ROWS];
    let mut burst = encode_frame(&Frame::Submit {
        id: 1,
        job: Job::Drain,
        opts: SubmitOpts::default(),
    });
    for id in 2..=4u64 {
        burst.extend_from_slice(&encode_frame(&Frame::Submit {
            id,
            job: Job::Mac(x.clone()),
            opts: SubmitOpts::default(),
        }));
    }
    raw.write_all(&burst).unwrap();

    let mut drained = false;
    let mut overloaded = 0usize;
    let mut seen = 0usize;
    while seen < 4 {
        match read_frame(&mut raw).unwrap() {
            Frame::Reply { id, result, .. } => {
                seen += 1;
                if id == 1 {
                    assert!(matches!(result, Ok(JobReply::Health(_))), "got {result:?}");
                    drained = true;
                } else {
                    match result {
                        Err(ServeError::Overloaded { in_flight, limit }) => {
                            assert_eq!(limit, 1);
                            assert!(in_flight >= limit);
                            overloaded += 1;
                        }
                        other => panic!("expected Overloaded, got {other:?}"),
                    }
                }
            }
            Frame::Credit { .. } => {}
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert!(drained, "the admitted barrier job must still serve");
    assert_eq!(overloaded, 3, "every submit past the window must shed");

    // a well-paced submit after the rejection round-trips fine
    write_frame(
        &mut raw,
        &Frame::Submit { id: 99, job: Job::Mac(x), opts: SubmitOpts::default() },
    )
    .unwrap();
    loop {
        match read_frame(&mut raw).unwrap() {
            Frame::Reply { id, result, .. } => {
                assert_eq!(id, 99);
                result.unwrap();
                break;
            }
            Frame::Credit { .. } => {}
            other => panic!("unexpected frame {other:?}"),
        }
    }

    drop(raw);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    server.join();
}

#[test]
fn pinned_core_out_of_range_is_a_wire_error_not_a_crash() {
    let cfg = ideal_cfg();
    let mut cluster = CimCluster::new(&cfg, 1);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let (wire, addr, acceptor) = spawn_wire(&server);
    let client = RemoteClient::connect(addr).expect("connect loopback");
    // the client's own mirror panics on an out-of-range pin (programmer
    // error, same as in-process), so craft the frame below the trait:
    // a hostile peer pinning core 7 on a 1-core cluster must get a typed
    // error back, and the connection must survive
    use acore_cim::coordinator::wire::write_frame;
    use std::net::TcpStream;
    let mut raw = TcpStream::connect(addr).unwrap();
    let hello = read_frame(&mut raw).unwrap();
    match hello {
        Frame::Hello { cores, window, ref models, ref residency } => {
            assert_eq!(cores, 1);
            assert!(window >= 1, "the handshake must grant a usable submit window");
            assert_eq!(models.as_slice(), ["demo".to_string()]);
            assert_eq!(residency.len(), 1);
        }
        ref other => panic!("expected a Hello frame, got {other:?}"),
    }
    write_frame(
        &mut raw,
        &Frame::Submit {
            id: 77,
            job: Job::Mac(vec![0; c::N_ROWS]),
            opts: SubmitOpts::pinned(7),
        },
    )
    .unwrap();
    match read_frame(&mut raw).unwrap() {
        Frame::Reply { id, result, .. } => {
            assert_eq!(id, 77);
            assert!(matches!(result, Err(ServeError::Backend(_))), "got {result:?}");
        }
        other => panic!("expected a Reply frame, got {other:?}"),
    }
    drop(raw);
    // the well-behaved client on the same server still serves
    let q = client.mac(vec![30; c::N_ROWS]).unwrap();
    assert_eq!(q.len(), c::M_COLS);

    drop(client);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    server.join();
}
