//! Firmware-native calibration control, end to end: the RV32IM
//! supervisor firmware must make the SAME decisions as the host
//! `CalibratorPolicy` on identical residual traces (property test over
//! randomized schedules, in the spirit of the `soc_bisc.rs` 1-LSB
//! trim-agreement gate), and a live cluster under injected drift must
//! complete an autonomous drain → recalibrate → rejoin cycle with the
//! decision made by the firmware, not the host daemon.

use acore_cim::analog::consts as c;
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::Batcher;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::calibrator::{CalibratorConfig, CalibratorPolicy, DrainReason};
use acore_cim::coordinator::cluster::{CimCluster, ServiceConfig};
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::coordinator::service::CimService;
use acore_cim::soc::ctl::{FirmwareCalibrator, SupervisorCore};
use acore_cim::util::proptest::forall;
use acore_cim::util::rng::Rng;
use acore_cim::{prop_assert, prop_assert_eq};
use std::time::{Duration, Instant};

/// A residual on the exact Q16 grid in [0, cap], so the only
/// quantization the firmware sees is its own EWMA arithmetic.
fn grid_residual(rng: &mut Rng, cap_q16: i64) -> f64 {
    rng.int_in(0, cap_q16) as f64 / 65536.0
}

/// Randomized-schedule agreement: for every trace of samples, fences,
/// healthy-core counts, drain outcomes, and clock jumps, the firmware's
/// published trend stays within fixed-point tolerance of the f64 EWMA,
/// and its drain decisions match `CalibratorPolicy::decide` everywhere
/// outside a narrow quantization band around the trend threshold (time
/// triggers — staleness and cool-down — use exact integer milliseconds
/// on both sides, so they must agree exactly).
#[test]
fn firmware_policy_matches_host_policy_on_random_traces() {
    forall("firmware/host policy agreement", 48, |rng| {
        // alpha and threshold drawn ON the Q16 grid: the param block
        // round-trips them exactly, so reference and firmware run the
        // same constants
        let alpha_q = rng.int_in(3277, 65536); // 0.05 ..= 1.0
        let alpha = alpha_q as f64 / 65536.0;
        let thr_q = rng.int_in(655, 6554); // ~0.01 ..= ~0.1
        let threshold = thr_q as f64 / 65536.0;
        let cfg = CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: alpha,
            threshold,
            cooldown: Duration::from_millis(rng.int_in(0, 3000) as u64),
            max_staleness: Duration::from_millis(rng.int_in(500, 60_000) as u64),
        };
        let cores = rng.int_in(1, 3) as usize;
        let base = Instant::now();
        let mut policy = CalibratorPolicy::new(cfg.clone(), cores, base);
        let mut fw = SupervisorCore::new(cores, &cfg);

        // EWMA truncation settles within ~1/alpha LSB of the f64 value;
        // the decision margin is doubled so a trend that close to the
        // threshold may legitimately differ between the two
        let tol = (2.0 / alpha + 8.0) / 65536.0;
        let margin = 2.0 * tol;

        let mut now_ms: u64 = 0;
        let mut epoch: u64 = 0;
        for _ in 0..30 {
            now_ms += rng.int_in(20, 900) as u64;
            let healthy = rng.int_in(0, cores as i64) as usize;
            for core in 0..cores {
                let fenced = rng.int_in(0, 9) == 0;
                let residual =
                    (rng.int_in(0, 9) != 0).then(|| grid_residual(rng, 13_107)); // <= 0.2
                let t_fw = fw.observe(core, residual, fenced, epoch, healthy, now_ms as u32);
                let t_ref = match residual {
                    Some(r) => Some(policy.observe(core, r)),
                    None => policy.trend(core),
                };
                prop_assert_eq!(t_fw.is_some(), t_ref.is_some());
                if let (Some(f), Some(h)) = (t_fw, t_ref) {
                    prop_assert!(
                        (f - h).abs() <= tol,
                        "trend diverged: fw {f:.6} vs host {h:.6} (alpha {alpha:.4})"
                    );
                }

                let ref_now = base + Duration::from_millis(now_ms);
                let ref_dec = policy.decide(core, healthy, fenced, ref_now);
                let fw_dec = fw.take_decision(core);
                let near_threshold =
                    t_ref.is_some_and(|t| (t - threshold).abs() <= margin);
                if !near_threshold {
                    prop_assert!(
                        fw_dec == ref_dec,
                        "decision diverged at {now_ms} ms core {core}: \
                         fw {fw_dec:?} vs host {ref_dec:?} (trend {t_ref:?}, \
                         threshold {threshold:.6}, healthy {healthy}, fenced {fenced})"
                    );
                }
                // execute the drain the REFERENCE wants, on both sides,
                // so the two state machines stay on one schedule (a
                // firmware-only fire inside the margin band leaves its
                // state untouched — decisions are pure until a result
                // is posted)
                if ref_dec.is_some() {
                    let recalibrated = rng.int_in(0, 3) != 0;
                    let post = recalibrated.then(|| grid_residual(rng, 3_277)); // <= 0.05
                    if recalibrated {
                        epoch += 1;
                    }
                    policy.record_drain(core, ref_now, recalibrated, post);
                    fw.record_drain(core, recalibrated, post, now_ms as u32);
                }
            }
        }
        prop_assert!(
            fw.faults() == 0,
            "firmware faulted during the trace: {:?}",
            fw.last_fault()
        );
        Ok(())
    });
}

/// Staleness and cool-down are pure integer-time triggers: replayed on
/// a fixed schedule, firmware and host must agree exactly (no margin).
#[test]
fn firmware_time_triggers_agree_exactly() {
    let cfg = CalibratorConfig {
        period: Duration::from_millis(10),
        ewma_alpha: 0.5,
        threshold: 0.05,
        cooldown: Duration::from_millis(700),
        max_staleness: Duration::from_millis(2_000),
    };
    let base = Instant::now();
    let mut policy = CalibratorPolicy::new(cfg.clone(), 1, base);
    let mut fw = SupervisorCore::new(1, &cfg);
    // quiet in-band residual, clock marching in uneven steps across the
    // staleness deadline and through a cool-down window
    let mut drains = 0;
    for now_ms in [0u64, 450, 900, 1_350, 1_800, 2_250, 2_700, 3_150, 3_600, 4_050] {
        let t_fw = fw.observe(0, Some(0.01), false, 0, 2, now_ms as u32);
        policy.observe(0, 0.01);
        assert!(t_fw.is_some());
        let ref_now = base + Duration::from_millis(now_ms);
        let ref_dec = policy.decide(0, 2, false, ref_now);
        let fw_dec = fw.take_decision(0);
        assert_eq!(fw_dec, ref_dec, "at {now_ms} ms");
        if let Some(reason) = ref_dec {
            assert_eq!(reason, DrainReason::Staleness);
            drains += 1;
            policy.record_drain(0, ref_now, true, Some(0.01));
            fw.record_drain(0, true, Some(0.01), now_ms as u32);
        }
    }
    assert!(drains >= 1, "the staleness deadline never fired on either side");
}

/// The tentpole acceptance path: a live two-core cluster under injected
/// drift, served with the FIRMWARE calibrator — the drain →
/// recalibrate → rejoin cycle completes with the decision made by the
/// RV32 core, traffic never drops, and the stats surface is identical
/// to the host daemon's.
#[test]
fn firmware_calibrator_autonomously_recalibrates_drifting_cores() {
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    cfg.sigma_drift = 2e-4;
    let mut cluster = CimCluster::new(&cfg, 2);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    cluster.calibrate_parallel(&engine);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    // wide health band so any drain is the firmware's own decision, not
    // the passive fence beating it to the punch
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        health_band: 0.5,
    });
    let threshold = 0.05;
    let daemon = FirmwareCalibrator::spawn(
        server.client(),
        CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: 0.5,
            threshold,
            max_staleness: Duration::from_secs(3600),
            cooldown: Duration::from_millis(50),
        },
    );
    let shared = daemon.shared();
    let client = server.client();

    // age the dies under real traffic until the firmware fires
    let mut sent = 0u64;
    let deadline = Instant::now() + Duration::from_secs(60);
    while shared.total_drains() == 0 {
        assert!(
            Instant::now() < deadline,
            "firmware never drained after {sent} MACs: {:?}",
            shared.snapshot()
        );
        for _ in 0..4 {
            let qs = client
                .mac_batch(vec![vec![30; c::N_ROWS]; 16])
                .expect("traffic must keep serving through firmware-driven drains");
            assert_eq!(qs.len(), 16);
            sent += 16;
        }
        std::thread::sleep(Duration::from_millis(3));
    }

    // traffic stops, dies stop aging: every trend must settle back
    // below the trigger threshold through firmware-driven recalibration
    let settle = Instant::now() + Duration::from_secs(60);
    loop {
        let stats = shared.snapshot();
        if stats.iter().all(|s| !s.trend.is_some_and(|t| t >= threshold)) {
            break;
        }
        assert!(Instant::now() < settle, "trends never settled: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(shared.sweeps() > 0, "the daemon never completed a sweep");
    let stats = daemon.stop();
    let drains: u64 = stats.iter().map(|s| s.drains).sum();
    let triggers: u64 = stats.iter().map(|s| s.trend_triggers + s.staleness_triggers).sum();
    assert!(drains >= 1, "no firmware-decided drain recorded: {stats:?}");
    assert!(triggers >= drains, "every drain needs a recorded trigger: {stats:?}");
    assert_eq!(
        stats.iter().map(|s| s.drain_failures).sum::<u64>(),
        0,
        "drains must succeed: {stats:?}"
    );
    for s in &stats {
        if s.drains > 0 {
            assert!(s.last_recal_epoch > 0, "recal epoch never advanced: {s:?}");
            assert!(s.samples > 0, "drained without folded samples: {s:?}");
        }
    }

    // zero dropped in-flight jobs across firmware-driven drains
    drop(client);
    let (cluster, wstats) = server.join();
    let served: u64 = wstats.iter().map(|s| s.requests).sum();
    assert!(served >= sent, "workers served {served} of {sent}");
    assert_eq!(
        wstats.iter().map(|s| s.rejected + s.expired).sum::<u64>(),
        0,
        "jobs were dropped during firmware-driven recalibration: {wstats:?}"
    );
    assert!(
        cluster.cores.iter().any(|core| core.recal_count > 0),
        "no core records an in-service recalibration"
    );
}
