//! Integration tests for the in-repo static analysis (`acore-cim lint`,
//! DESIGN.md §12): every rule gets at least one positive fixture (the
//! violations ARE reported) and one negative fixture (the disciplined
//! shape is clean), plus suppression-hygiene coverage. Fixtures live in
//! `tests/lint_fixtures/` as text — they are never compiled — and are
//! linted under virtual paths so scope-sensitive rules see them where
//! they claim to be.

use acore_cim::analysis::{lint_sources, LintReport, RULE_NAMES};

/// A serving-scope virtual path (rule `panic_free` applies).
const SERVING: &str = "src/coordinator/wire/fixture.rs";
/// A non-serving virtual path (only the everywhere-rules apply).
const ELSEWHERE: &str = "src/analog/fixture.rs";

fn lint_one(path: &str, source: &str) -> LintReport {
    lint_sources(&[(path, source)])
}

fn rule_counts(report: &LintReport) -> Vec<(&'static str, usize)> {
    RULE_NAMES
        .iter()
        .map(|&r| (r, report.violations.iter().filter(|v| v.rule == r).count()))
        .filter(|&(_, n)| n > 0)
        .collect()
}

#[test]
fn panic_free_positive_reports_every_site() {
    let report = lint_one(SERVING, include_str!("lint_fixtures/panic_free_bad.rs"));
    assert_eq!(rule_counts(&report), vec![("panic_free", 5)], "{report:?}");
}

#[test]
fn panic_free_negative_is_clean_with_one_justified_allow() {
    let report = lint_one(SERVING, include_str!("lint_fixtures/panic_free_ok.rs"));
    assert!(report.clean(), "unexpected violations: {:?}", report.violations);
    assert_eq!(report.allows_used, 1, "the one justified allow must be consumed");
}

#[test]
fn panic_free_is_scoped_to_serving_files() {
    // the same panic-prone source outside the serving scope only trips
    // the everywhere-rules (none of which it violates)
    let report = lint_one(ELSEWHERE, include_str!("lint_fixtures/panic_free_bad.rs"));
    assert!(report.clean(), "panic_free leaked outside its scope: {:?}", report.violations);
}

#[test]
fn hot_path_alloc_positive_reports_every_allocation() {
    let report = lint_one(ELSEWHERE, include_str!("lint_fixtures/hot_path_alloc_bad.rs"));
    assert_eq!(rule_counts(&report), vec![("hot_path_alloc", 5)], "{report:?}");
}

#[test]
fn hot_path_alloc_negative_is_clean() {
    let report = lint_one(ELSEWHERE, include_str!("lint_fixtures/hot_path_alloc_ok.rs"));
    assert!(report.clean(), "unexpected violations: {:?}", report.violations);
}

#[test]
fn lock_across_io_positive_reports_live_guards_and_same_statement() {
    let report = lint_one(ELSEWHERE, include_str!("lint_fixtures/lock_across_io_bad.rs"));
    assert_eq!(rule_counts(&report), vec![("lock_across_io", 3)], "{report:?}");
}

#[test]
fn lock_across_io_negative_is_clean_with_one_justified_allow() {
    let report = lint_one(ELSEWHERE, include_str!("lint_fixtures/lock_across_io_ok.rs"));
    assert!(report.clean(), "unexpected violations: {:?}", report.violations);
    assert_eq!(report.allows_used, 1, "the write-mutex allow must be consumed");
}

#[test]
fn unsafe_block_positive_and_negative() {
    let bad = lint_one(ELSEWHERE, include_str!("lint_fixtures/unsafe_block_bad.rs"));
    assert_eq!(rule_counts(&bad), vec![("unsafe_block_safety", 1)], "{bad:?}");
    let ok = lint_one(ELSEWHERE, include_str!("lint_fixtures/unsafe_block_ok.rs"));
    assert!(ok.clean(), "unexpected violations: {:?}", ok.violations);
}

#[test]
fn unjustified_or_unknown_allows_are_violations_and_suppress_nothing() {
    let report = lint_one(SERVING, include_str!("lint_fixtures/allow_hygiene_bad.rs"));
    assert_eq!(
        rule_counts(&report),
        vec![("panic_free", 1), ("lint_allow_justification", 2)],
        "{report:?}"
    );
    assert_eq!(report.allows_used, 0, "a bare allow must never be consumed");
}

#[test]
fn multi_file_report_is_sorted_and_counts_files() {
    let report = lint_sources(&[
        (SERVING, include_str!("lint_fixtures/panic_free_bad.rs")),
        (ELSEWHERE, include_str!("lint_fixtures/unsafe_block_bad.rs")),
    ]);
    assert_eq!(report.files_scanned, 2);
    assert_eq!(report.violations.len(), 6);
    let order: Vec<(&str, usize)> =
        report.violations.iter().map(|v| (v.file.as_str(), v.line)).collect();
    let mut sorted = order.clone();
    sorted.sort();
    assert_eq!(order, sorted, "violations must come out sorted by (file, line)");
}

#[test]
fn json_report_carries_every_violation() {
    let report = lint_one(SERVING, include_str!("lint_fixtures/panic_free_bad.rs"));
    let json = report.to_json();
    assert!(json.contains("\"violation_count\": 5"), "{json}");
    assert!(json.contains("\"rule\": \"panic_free\""), "{json}");
}
