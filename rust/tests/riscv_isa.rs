//! ISA-level regression suite for the RV32IM core, beyond the
//! `selftest.rs` smoke: per-instruction semantics (arithmetic wrap,
//! set-less-than boundaries, shift-amount masking, load extension,
//! link-register and branch behavior), M-extension edge cases
//! (divide-by-zero, signed-overflow division, high-half multiplies
//! cross-checked against 64/128-bit reference arithmetic), and the trap
//! surface (misaligned access, illegal instruction, fetch/load faults,
//! ecall/ebreak). The supervisor firmware of `soc/ctl` rides on exactly
//! these semantics — especially `mul`/`mulh` composition and unsigned
//! branch comparisons — so they are pinned here at the instruction level.

use acore_cim::soc::bus::{Axi4LiteBus, Ram};
use acore_cim::soc::riscv::asm::Asm;
use acore_cim::soc::riscv::cpu::{Cpu, Halt};
use acore_cim::util::proptest::forall;
use acore_cim::{prop_assert, prop_assert_eq};

const RAM_SIZE: u32 = 0x1_0000;

/// Run a raw little-endian image at address 0 with optional CPU setup.
fn run_image(image: &[u8], setup: impl FnOnce(&mut Cpu)) -> (Cpu, Halt) {
    let mut bus = Axi4LiteBus::new();
    let mut ram = Ram::new(RAM_SIZE, "ram");
    ram.load(0, image);
    bus.map(0, Box::new(ram));
    let mut cpu = Cpu::new(0);
    setup(&mut cpu);
    let halt = cpu.run(&mut bus, 100_000);
    (cpu, halt)
}

/// Assemble and run a program built with the `Asm` builder.
fn run_asm(build: impl FnOnce(&mut Asm)) -> (Cpu, Halt) {
    let mut a = Asm::new(0);
    build(&mut a);
    run_image(&a.assemble(), |_| {})
}

/// Run and expect a clean exit; returns the exit code (a0).
fn exec(build: impl FnOnce(&mut Asm)) -> u32 {
    match run_asm(build) {
        (_, Halt::Exit(code)) => code,
        (_, halt) => panic!("expected Exit, got {halt:?}"),
    }
}

/// Hand-encoded R-type word (the assembler has no `mulhsu` helper).
fn r_type(funct7: u32, rs2: u8, rs1: u8, funct3: u32, rd: u8) -> u32 {
    (funct7 << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (funct3 << 12)
        | ((rd as u32) << 7)
        | 0b011_0011
}

const ECALL: u32 = 0x0000_0073;

/// Run raw instruction words with pre-seeded registers; the word list
/// should end in ECALL with x17 preset to 93 by `setup`.
fn exec_raw(words: &[u32], setup: impl FnOnce(&mut Cpu)) -> (Cpu, Halt) {
    let mut image = Vec::new();
    for w in words {
        image.extend_from_slice(&w.to_le_bytes());
    }
    run_image(&image, setup)
}

// ---- arithmetic and logic ------------------------------------------------

#[test]
fn add_sub_wrap_around() {
    let code = exec(|a| {
        a.li(5, i32::MAX);
        a.addi(10, 5, 1); // MAX + 1 wraps to MIN
        a.exit();
    });
    assert_eq!(code, i32::MIN as u32);
    let code = exec(|a| {
        a.li(5, i32::MIN);
        a.li(6, 1);
        a.sub(10, 5, 6); // MIN - 1 wraps to MAX
        a.exit();
    });
    assert_eq!(code, i32::MAX as u32);
}

#[test]
fn set_less_than_signedness_boundaries() {
    // slt: -1 < 1 signed
    assert_eq!(
        exec(|a| {
            a.li(5, -1);
            a.li(6, 1);
            a.slt(10, 5, 6);
            a.exit();
        }),
        1
    );
    // sltu: 0xFFFF_FFFF is the LARGEST unsigned value
    assert_eq!(
        exec(|a| {
            a.li(5, -1);
            a.li(6, 1);
            a.sltu(10, 5, 6);
            a.exit();
        }),
        0
    );
    // slti sign-extends its immediate
    assert_eq!(
        exec(|a| {
            a.li(5, -2);
            a.slti(10, 5, -1);
            a.exit();
        }),
        1
    );
    // sltiu compares against the sign-EXTENDED immediate as unsigned:
    // imm -1 becomes 0xFFFF_FFFF, so anything but all-ones is below it
    assert_eq!(
        exec(|a| {
            a.li(5, 7);
            a.sltiu(10, 5, -1);
            a.exit();
        }),
        1
    );
}

#[test]
fn logic_register_and_immediate_forms() {
    let code = exec(|a| {
        a.li(5, 0b1100);
        a.li(6, 0b1010);
        a.and(28, 5, 6); // 0b1000
        a.or(29, 5, 6); //  0b1110
        a.xor(30, 5, 6); // 0b0110
        a.slli(28, 28, 8);
        a.slli(29, 29, 4);
        a.add(10, 28, 29);
        a.add(10, 10, 30);
        a.exit();
    });
    assert_eq!(code, (0b1000 << 8) + (0b1110 << 4) + 0b0110);
    let code = exec(|a| {
        a.li(5, 0xF0);
        a.andi(28, 5, 0x3C); // 0x30
        a.ori(29, 5, 0x0F); //  0xFF
        a.xori(30, 5, -1); //   !0xF0
        a.sub(10, 30, 29); //   !0xF0 - 0xFF
        a.add(10, 10, 28);
        a.exit();
    });
    assert_eq!(code, (!0xF0u32).wrapping_sub(0xFF).wrapping_add(0x30));
}

#[test]
fn shift_amounts_mask_to_five_bits() {
    // register-form shift by 33 must behave as shift by 1
    let code = exec(|a| {
        a.li(5, 0x40);
        a.li(6, 33);
        a.sll(10, 5, 6);
        a.exit();
    });
    assert_eq!(code, 0x80);
    let code = exec(|a| {
        a.li(5, -8); // 0xFFFF_FFF8
        a.li(6, 34);
        a.sra(10, 5, 6); // arithmetic >> 2
        a.exit();
    });
    assert_eq!(code, (-2i32) as u32);
    let code = exec(|a| {
        a.li(5, -8);
        a.li(6, 34);
        a.srl(10, 5, 6); // logical >> 2
        a.exit();
    });
    assert_eq!(code, 0xFFFF_FFF8u32 >> 2);
    // immediate forms at the 31 boundary
    let code = exec(|a| {
        a.li(5, i32::MIN);
        a.srai(10, 5, 31);
        a.exit();
    });
    assert_eq!(code, u32::MAX, "srai 31 of MIN is all-ones");
    let code = exec(|a| {
        a.li(5, i32::MIN);
        a.srli(10, 5, 31);
        a.exit();
    });
    assert_eq!(code, 1);
}

#[test]
fn lui_and_auipc() {
    let code = exec(|a| {
        a.lui(10, 0x12345 << 12);
        a.exit();
    });
    assert_eq!(code, 0x1234_5000);
    // auipc adds to the pc OF THE INSTRUCTION; two nops put it at 8
    let code = exec(|a| {
        a.nop();
        a.nop();
        a.auipc(10, 0x1000);
        a.exit();
    });
    assert_eq!(code, 0x1008);
}

#[test]
fn x0_is_hardwired_to_zero() {
    let code = exec(|a| {
        a.li(5, 123);
        a.addi(0, 5, 1); // write to x0 must be discarded
        a.sll(0, 5, 5);
        a.mv(10, 0);
        a.exit();
    });
    assert_eq!(code, 0);
}

// ---- control flow --------------------------------------------------------

#[test]
fn jal_links_and_jalr_clears_the_low_bit() {
    // jal: x1 = return address (pc + 4)
    let (cpu, halt) = run_asm(|a| {
        a.jal_label(1, "over"); // at pc 0, link = 4
        a.nop();
        a.label("over");
        a.mv(10, 1);
        a.exit();
    });
    assert_eq!(halt, Halt::Exit(4));
    assert_eq!(cpu.regs[1], 4);
    // jalr: the ODD target address must land on target & !1
    let code = exec(|a| {
        a.li(6, 21); //  20 | 1: "target" is the mv at byte 20
        a.jalr(5, 6, 0); // at byte 4: link in x5 = 8
        a.li(10, 99); // skipped on a correct (even) landing
        a.exit();
        a.mv(10, 5); // byte 20 (every li above is a single addi)
        a.exit();
    });
    assert_eq!(code, 8, "jalr must clear bit 0 of the target and link pc+4");
}

#[test]
fn all_branches_taken_and_not_taken() {
    // each taken branch sets one bit; a wrong fall-through poisons 0x80
    let code = exec(|a| {
        a.li(5, -1);
        a.li(6, 1);
        a.li(10, 0);

        a.beq(5, 5, "beq_t");
        a.ori(10, 10, 0x80);
        a.label("beq_t");
        a.beq(5, 6, "poison");
        a.ori(10, 10, 0x01);

        a.bne(5, 6, "bne_t");
        a.ori(10, 10, 0x80);
        a.label("bne_t");
        a.bne(5, 5, "poison");
        a.ori(10, 10, 0x02);

        a.blt(5, 6, "blt_t"); // -1 < 1 signed
        a.ori(10, 10, 0x80);
        a.label("blt_t");
        a.blt(6, 5, "poison");
        a.ori(10, 10, 0x04);

        a.bge(6, 5, "bge_t"); // 1 >= -1 signed
        a.ori(10, 10, 0x80);
        a.label("bge_t");
        a.bge(5, 6, "poison");
        a.ori(10, 10, 0x08);

        a.bltu(6, 5, "bltu_t"); // 1 < 0xFFFF_FFFF unsigned
        a.ori(10, 10, 0x80);
        a.label("bltu_t");
        a.bltu(5, 6, "poison");
        a.ori(10, 10, 0x10);

        a.bgeu(5, 6, "bgeu_t"); // 0xFFFF_FFFF >= 1 unsigned
        a.ori(10, 10, 0x80);
        a.label("bgeu_t");
        a.bgeu(6, 5, "poison");
        a.ori(10, 10, 0x20);

        a.exit();
        a.label("poison");
        a.li(10, 0x80);
        a.exit();
    });
    assert_eq!(code, 0x3F, "taken/not-taken matrix: got {code:#x}");
}

// ---- loads and stores ----------------------------------------------------

#[test]
fn load_sign_and_zero_extension_at_every_byte_offset() {
    // memory word at 0x100: bytes 01 7F FF 80 (LE)
    let (cpu, halt) = run_asm(|a| {
        a.li(5, 0x100);
        a.li(6, 0x80FF_7F01u32 as i32);
        a.sw(5, 6, 0);
        a.lb(28, 5, 1); //  0x7F ->  127
        a.lb(29, 5, 2); //  0xFF ->   -1
        a.lbu(30, 5, 2); // 0xFF ->  255
        a.lbu(31, 5, 3); // 0x80 ->  128
        a.lh(7, 5, 2); //   0x80FF -> sign-extended
        a.lhu(9, 5, 2); //  0x80FF -> zero-extended
        a.lh(18, 5, 0); //  0x7F01 -> positive as-is
        a.li(10, 0);
        a.exit();
    });
    assert_eq!(halt, Halt::Exit(0));
    assert_eq!(cpu.regs[28], 127);
    assert_eq!(cpu.regs[29], -1i32 as u32);
    assert_eq!(cpu.regs[30], 255);
    assert_eq!(cpu.regs[31], 128);
    assert_eq!(cpu.regs[7], 0xFFFF_80FF);
    assert_eq!(cpu.regs[9], 0x0000_80FF);
    assert_eq!(cpu.regs[18], 0x7F01);
}

#[test]
fn byte_and_half_stores_merge_into_words() {
    let code = exec(|a| {
        a.li(5, 0x200);
        a.li(6, 0x1111_1111);
        a.sw(5, 6, 0);
        a.li(6, 0xAB);
        a.sb(5, 6, 2); // byte lane 2
        a.li(6, 0xCDEF_u32 as i32);
        a.sh(5, 6, 0); // low half
        a.lw(10, 5, 0);
        a.exit();
    });
    assert_eq!(code, 0x11AB_CDEF);
}

#[test]
fn misaligned_accesses_fault_with_the_offender() {
    for (name, build) in [
        ("LW", Box::new(|a: &mut Asm| {
            a.li(5, 0x102);
            a.lw(6, 5, 0);
        }) as Box<dyn Fn(&mut Asm)>),
        ("LH", Box::new(|a: &mut Asm| {
            a.li(5, 0x101);
            a.lh(6, 5, 0);
        })),
        ("SW", Box::new(|a: &mut Asm| {
            a.li(5, 0x102);
            a.sw(5, 6, 0);
        })),
        ("SH", Box::new(|a: &mut Asm| {
            a.li(5, 0x103);
            a.sh(5, 6, 0);
        })),
    ] {
        let (_, halt) = run_asm(|a| {
            build(a);
            a.exit();
        });
        match halt {
            Halt::Fault(msg) => assert!(
                msg.contains("misaligned") && msg.contains(name),
                "{name}: fault message `{msg}` must name the misaligned op"
            ),
            other => panic!("{name}: expected a misalignment fault, got {other:?}"),
        }
    }
}

#[test]
fn unmapped_fetch_and_load_fault() {
    let (_, halt) = run_asm(|a| {
        a.li(5, 0x00FF_0000); // far beyond the 64 KiB RAM
        a.lw(6, 5, 0);
        a.exit();
    });
    match halt {
        Halt::Fault(msg) => assert!(msg.contains("load fault"), "got `{msg}`"),
        other => panic!("expected a load fault, got {other:?}"),
    }
    let (_, halt) = run_asm(|a| {
        a.li(5, 0x00FF_0000);
        a.jalr(0, 5, 0); // jump into the void
    });
    match halt {
        Halt::Fault(msg) => assert!(msg.contains("fetch fault"), "got `{msg}`"),
        other => panic!("expected a fetch fault, got {other:?}"),
    }
}

// ---- M extension ---------------------------------------------------------

#[test]
fn division_by_zero_follows_the_spec() {
    // div x/0 = -1, divu x/0 = 2^32-1, rem/remu x/0 = x (no trap)
    let cases: [(fn(&mut Asm, u8, u8, u8), i32, u32); 4] = [
        (Asm::div, 42, u32::MAX),
        (Asm::divu, 42, u32::MAX),
        (Asm::rem, 42, 42),
        (Asm::remu, -7, (-7i32) as u32),
    ];
    for (op, dividend, want) in cases {
        let code = exec(|a| {
            a.li(5, dividend);
            a.li(6, 0);
            op(a, 10, 5, 6);
            a.exit();
        });
        assert_eq!(code, want, "dividend {dividend} / 0");
    }
}

#[test]
fn signed_division_overflow_is_defined() {
    // i32::MIN / -1 overflows: div = i32::MIN, rem = 0 (no trap)
    let code = exec(|a| {
        a.li(5, i32::MIN);
        a.li(6, -1);
        a.div(10, 5, 6);
        a.exit();
    });
    assert_eq!(code, i32::MIN as u32);
    let code = exec(|a| {
        a.li(5, i32::MIN);
        a.li(6, -1);
        a.rem(10, 5, 6);
        a.exit();
    });
    assert_eq!(code, 0);
}

#[test]
fn multiply_family_matches_wide_reference() {
    forall("rv32m multiply reference", 64, |rng| {
        // bias toward boundary magnitudes where the high half matters
        let pick = |rng: &mut acore_cim::util::rng::Rng| -> i32 {
            match rng.int_in(0, 3) {
                0 => rng.int_in(i32::MIN as i64, i32::MAX as i64) as i32,
                1 => rng.int_in(-3, 3) as i32,
                2 => i32::MIN.wrapping_add(rng.int_in(0, 2) as i32),
                _ => i32::MAX.wrapping_sub(rng.int_in(0, 2) as i32),
            }
        };
        let x = pick(rng);
        let y = pick(rng);
        let wide = x as i64 * y as i64;
        let wide_u = (x as u32 as u64) * (y as u32 as u64);

        let got = exec(|a| {
            a.li(5, x);
            a.li(6, y);
            a.mul(10, 5, 6);
            a.exit();
        });
        prop_assert_eq!(got, wide as u32);

        let got = exec(|a| {
            a.li(5, x);
            a.li(6, y);
            a.mulh(10, 5, 6);
            a.exit();
        });
        prop_assert_eq!(got, (wide >> 32) as u32);

        let got = exec(|a| {
            a.li(5, x);
            a.li(6, y);
            a.mulhu(10, 5, 6);
            a.exit();
        });
        prop_assert_eq!(got, (wide_u >> 32) as u32);
        Ok(())
    });
}

#[test]
fn mulhsu_signed_times_unsigned() {
    // no assembler helper: hand-encode MULHSU (funct7 1, funct3 010)
    for (x, y) in [
        (-1i32, u32::MAX),
        (i32::MIN, u32::MAX),
        (7, 0x8000_0000),
        (-7, 0x8000_0000),
        (0, 12345),
    ] {
        let want = (((x as i64 as i128) * (y as i128)) >> 32) as u32;
        let (_, halt) = exec_raw(&[r_type(1, 6, 5, 0b010, 10), ECALL], |cpu| {
            cpu.regs[5] = x as u32;
            cpu.regs[6] = y;
            cpu.regs[17] = 93;
        });
        assert_eq!(halt, Halt::Exit(want), "mulhsu {x} x {y}");
    }
}

#[test]
fn mul_div_roundtrip_property() {
    forall("q / d * d + r == q", 64, |rng| {
        let q = rng.int_in(i32::MIN as i64 + 1, i32::MAX as i64) as i32;
        let d = match rng.int_in(1, 1000) as i32 {
            d if rng.int_in(0, 1) == 0 => d,
            d => -d,
        };
        let code = exec(|a| {
            a.li(5, q);
            a.li(6, d);
            a.div(28, 5, 6);
            a.rem(29, 5, 6);
            a.mul(30, 28, 6);
            a.add(10, 30, 29); // q/d*d + q%d must reconstruct q
            a.exit();
        });
        prop_assert_eq!(code, q as u32);
        Ok(())
    });
}

// ---- traps and environment -----------------------------------------------

#[test]
fn non_exit_ecalls_are_logged_and_execution_continues() {
    let (cpu, halt) = run_asm(|a| {
        a.li(17, 5); // a7 = 5: not the exit syscall
        a.li(10, 42);
        a.ecall();
        a.li(10, 7); // must still run
        a.exit();
    });
    assert_eq!(halt, Halt::Exit(7));
    assert_eq!(cpu.ecalls, vec![(5, 42)]);
}

#[test]
fn ebreak_halts_without_advancing() {
    let (cpu, halt) = run_asm(|a| {
        a.nop();
        a.ebreak();
        a.nop();
    });
    assert_eq!(halt, Halt::Break);
    assert_eq!(cpu.pc, 4, "ebreak must not advance past itself");
}

#[test]
fn illegal_instruction_faults() {
    let (_, halt) = exec_raw(&[0xFFFF_FFFF], |_| {});
    match halt {
        Halt::Fault(msg) => assert!(msg.contains("illegal"), "got `{msg}`"),
        other => panic!("expected an illegal-instruction fault, got {other:?}"),
    }
}

#[test]
fn runaway_programs_hit_the_step_limit() {
    let mut a = Asm::new(0);
    a.label("spin");
    a.j("spin");
    let (_, halt) = run_image(&a.assemble(), |_| {});
    assert_eq!(halt, Halt::StepLimit);
}
