//! Degraded-mode chaos drills (DESIGN.md §16, EXPERIMENTS.md):
//!
//! * a seeded dead-column plan injected MID-TRAFFIC over the wire — the
//!   wounded core keeps serving until its next drain, whose fault
//!   classifier finds damage that survives recalibration and retires
//!   the core for good: placement routes around it, the retirement
//!   pushes to subscribers, and not one admitted job is dropped;
//! * the variance-aware column placement measurably recovering MLP
//!   accuracy on a wounded die where the naive placement measurably
//!   does not.

use acore_cim::analog::consts as c;
use acore_cim::analog::faults::FaultPlan;
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::Batcher;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::cluster::{CimCluster, ServiceConfig};
use acore_cim::coordinator::dnn::{CimMlp, TilePlacement};
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::coordinator::service::{gather, CimService, Job, SubmitOpts, Ticket};
use acore_cim::coordinator::wire::{RemoteClient, WireServer};
use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
use acore_cim::data::synth;
use std::sync::Arc;
use std::time::Duration;

/// Bind a `WireServer` on an ephemeral loopback port and run its accept
/// loop on a background thread (same shape as tests/wire.rs).
fn spawn_wire(
    server: &acore_cim::coordinator::cluster::ClusterServer,
) -> (Arc<WireServer>, std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port")
            .with_models(vec!["demo".to_string()])
            .with_model_stats(server.model_stats_handles()),
    );
    let addr = wire.local_addr().expect("bound listener has an address");
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };
    (wire, addr, acceptor)
}

#[test]
fn a_dead_column_mid_traffic_retires_the_core_with_zero_dropped_jobs() {
    // deterministic variation dies (no per-MAC noise): the chaos drill
    // must replay identically from the seed
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 3);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        ..ServiceConfig::default()
    });
    let (wire, addr, acceptor) = spawn_wire(&server);
    let client = RemoteClient::connect(addr).expect("connect loopback");
    let watcher = RemoteClient::connect(addr).expect("connect watcher");
    watcher.subscribe().expect("subscribe watcher");

    let x = vec![30; c::N_ROWS];
    let mut admitted = 0u32;
    let mut answered = 0u32;

    // traffic in flight when the wound lands: the fault job is a drain-
    // style barrier, so every job admitted to core 1 before it completes
    // on healthy silicon
    let pre: Vec<Ticket<Vec<u32>>> = (0..16)
        .map(|_| client.submit(Job::Mac(x.clone()), SubmitOpts::default()).unwrap().typed())
        .collect();
    admitted += 16;

    // strike: weld physical column 3 of core 1 dead, mid-traffic
    let h = client.inject_faults(1, "core=1,col=3").expect("inject over the wire");
    assert!(!h.fenced, "injection must NOT fence — the wound stays live");
    assert!(!h.retired, "classification happens at the drain barrier, not at injection");
    for (_, q) in gather(pre).unwrap() {
        assert_eq!(q.len(), c::M_COLS);
        answered += 1;
    }

    // the wounded core keeps serving (degraded) until the health loop acts
    assert!(!client.is_fenced(1));
    let degraded = client.mac_on(1, x.clone()).expect("wounded core must still answer");
    assert_eq!(degraded.len(), c::M_COLS);

    // drain → recalibrate → classify: the dead column survives
    // recalibration, so the core retires instead of rejoining
    let h = client.drain(1).expect("drain the wounded core");
    assert!(h.recalibrated, "drain with an engine must recalibrate");
    assert!(h.retired, "a dead column must classify as permanent");
    assert_ne!(h.fault_mask & (1 << 3), 0, "the mask must name column 3: {:#010x}", h.fault_mask);
    assert!(h.fenced, "retirement is a permanent fence");
    assert!(client.board().is_retired(1), "retirement must mirror over the wire");

    // never rejoins: the board refuses to unfence a retired core
    client.unfence(1);
    assert!(client.is_fenced(1), "a retired core must never rejoin placement");

    // the retirement pushes to the idle subscriber's mirror
    let mut pushed = false;
    for _ in 0..200 {
        if watcher.board().is_retired(1) {
            pushed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(pushed, "RetirePush never reached the subscriber");
    assert_eq!(watcher.board().fault_mask(1), h.fault_mask);

    // placement resolves around the retired core and every admitted job
    // is answered — the cluster keeps serving on the survivors
    let post: Vec<Ticket<Vec<u32>>> = (0..24)
        .map(|_| {
            let t = client.submit(Job::Mac(x.clone()), SubmitOpts::default()).unwrap();
            assert_ne!(t.core(), 1, "job placed on a retired core");
            t.typed()
        })
        .collect();
    admitted += 24;
    for (_, q) in gather(post).unwrap() {
        assert_eq!(q.len(), c::M_COLS);
        answered += 1;
    }
    assert_eq!(answered, admitted, "admitted jobs were dropped");

    // a later probe still reports the terminal state
    let h = client.health(1).unwrap();
    assert!(h.retired && h.fenced);

    drop(client);
    drop(watcher);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    let (_cluster, stats) = server.join();
    // the retired core served only the jobs admitted before retirement
    assert!(stats[1].requests < admitted as u64, "retired core kept taking placed work");
}

#[test]
fn variance_aware_placement_recovers_accuracy_on_a_wounded_die() {
    // one trained pipeline, three single-core clusters: healthy naive
    // (the pre-fault baseline), wounded naive, wounded variance-aware
    let (train_ds, test_ds) = synth::generate(600, 120, 17);
    let mut mlp = Mlp::new(4);
    train(&mut mlp, &train_ds, &TrainConfig { epochs: 6, ..Default::default() });
    let q = QuantMlp::from_float(&mlp, &train_ds, 100);
    let cim_mlp = CimMlp::new(q, &train_ds, 50);
    let mut cfg = SimConfig::default().scaled(0.0);
    cfg.sigma_noise = 0.0;
    let n = 120;
    let plan = FaultPlan::parse("core=0,col=1").expect("valid plan");

    let run = |placement: TilePlacement, wound: bool| {
        let mut cluster = CimCluster::new(&cfg, 1);
        if wound {
            cluster.schedule_faults(&plan);
        }
        let sched = cim_mlp.prepare_cluster_with(&mut cluster, None, placement);
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        let (acc, _) = cim_mlp
            .accuracy_service(&client, &sched, &test_ds, n)
            .expect("serving failed");
        drop(client);
        server.join();
        acc
    };

    let acc0 = run(TilePlacement::Naive, false);
    let acc_naive = run(TilePlacement::Naive, true);
    let acc_var = run(TilePlacement::VarianceAware, true);

    // naive placement leaves the class-1 logit (and three hidden units)
    // on the dead physical column: a measurable accuracy collapse
    assert!(
        acc_naive < acc0 - 0.02,
        "naive placement should measurably degrade: healthy {acc0} wounded {acc_naive}"
    );
    // variance-aware placement routes the weight mass onto healthy
    // columns and parks the least-important logical column on the dead
    // one: within 2% of the pre-fault baseline (the ISSUE acceptance bar)
    assert!(
        acc_var >= acc0 - 0.02,
        "variance-aware placement should hold the line: healthy {acc0} wounded {acc_var}"
    );
    assert!(
        acc_var > acc_naive,
        "variance-aware must beat naive on the same wound: {acc_var} vs {acc_naive}"
    );
}
