//! End-to-end DNN integration (paper §VII-C): train -> quantize -> map to
//! CIM tiles -> run on an errorful die -> calibrate -> accuracy ladder.
//! Small sizes keep this under test-time budgets; the full-size run lives
//! in `examples/mnist_e2e.rs` and benches/dnn_accuracy.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::dnn::CimMlp;
use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
use acore_cim::data::synth;

fn trained_pipeline() -> (CimMlp, synth::Dataset) {
    let (train_ds, test_ds) = synth::generate(800, 200, 23);
    let mut mlp = Mlp::new(2);
    train(&mut mlp, &train_ds, &TrainConfig { epochs: 8, ..Default::default() });
    let q = QuantMlp::from_float(&mlp, &train_ds, 100);
    (CimMlp::new(q, &train_ds, 60), test_ds)
}

#[test]
fn accuracy_ladder_reproduces_paper_shape() {
    let (mut cim_mlp, test_ds) = trained_pipeline();
    let n = 100;

    // "simulation" row: the digital quantized reference
    let acc_sim = cim_mlp.quant.accuracy_digital(&test_ds);

    // uncalibrated silicon
    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let mut die = CimAnalogModel::from_sample(&cfg, &sample);
    let (acc_uncal, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

    // BISC (cascaded full-range + operating point) + digital residual trim
    let half = c::V_BIAS - cim_mlp.refs1.0;
    BiscEngine::calibrate_for_workload(
        &cfg,
        AdcCharacterization::ideal(),
        &mut die,
        half,
    );
    let (acc_bisc, _) = cim_mlp.accuracy(&mut die, &test_ds, n);
    cim_mlp.measure_digital_trim(&mut die, &cfg);
    let (acc_full, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

    println!(
        "accuracy ladder: sim {acc_sim:.3} | uncal {acc_uncal:.3} | \
         BISC {acc_bisc:.3} | BISC+trim {acc_full:.3}"
    );
    // paper shape: sim >= cal > uncal, calibration recovers most of the gap
    assert!(acc_sim > 0.8, "sim {acc_sim}");
    assert!(acc_uncal < acc_sim - 0.05, "errors should degrade: {acc_uncal}");
    assert!(acc_bisc >= acc_uncal, "BISC must not hurt");
    assert!(
        acc_full > acc_sim - 0.07,
        "calibration should recover to near-sim: {acc_full} vs {acc_sim}"
    );
    assert!(acc_full > acc_uncal + 0.1, "recovery too small");
}

#[test]
fn zero_point_baseline_then_bisc_matches_paper_shape() {
    // The paper's "uncalibrated" chip still runs at 88.7% — our equivalent
    // bring-up baseline is zero-point subtraction (offsets removed
    // digitally, gains untouched). BISC then also fixes the gains — in the
    // *analog* domain — closing most of the remaining gap (92.33%).
    let (mut cim_mlp, test_ds) = trained_pipeline();
    let n = 100;
    let acc_sim = cim_mlp.quant.accuracy_digital(&test_ds);

    let cfg = SimConfig::default();
    let sample = VariationSample::draw(&cfg);
    let mut die = CimAnalogModel::from_sample(&cfg, &sample);
    let (acc_raw, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

    cim_mlp.measure_zero_point(&mut die);
    let (acc_zp, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

    let half = c::V_BIAS - cim_mlp.refs1.0;
    BiscEngine::calibrate_for_workload(&cfg, AdcCharacterization::ideal(), &mut die, half);
    cim_mlp.clear_corrections();
    cim_mlp.measure_digital_trim(&mut die, &cfg);
    let (acc_cal, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

    println!(
        "ladder: sim {acc_sim:.3} | raw {acc_raw:.3} | zero-point {acc_zp:.3} | BISC {acc_cal:.3}"
    );
    assert!(acc_zp > acc_raw, "zero-point should rescue the collapse");
    assert!(acc_zp > 0.3, "zero-point baseline functional: {acc_zp}");
    assert!(acc_cal > acc_zp - 0.02, "BISC at least as good as zero-point");
    assert!(acc_cal > acc_sim - 0.08, "BISC recovers to near-sim");
}

#[test]
fn stats_track_tile_schedule() {
    let (cim_mlp, test_ds) = trained_pipeline();
    let mut die = CimAnalogModel::ideal();
    let (_, stats) = cim_mlp.accuracy(&mut die, &test_ds, 5);
    // 22*3 layer-1 tiles + 2*1 layer-2 tiles per image
    assert_eq!(stats.mac_ops, 5 * (22 * 3 + 2));
    assert_eq!(stats.reprograms, stats.mac_ops);
}
