//! Multi-model serving: `Placement::Model` resolution properties over
//! randomized residency/fencing boards, the in-process hot-rollout
//! lifecycle (drain barrier → reprogram → recalibrate → rejoin with
//! zero lost requests), and the loopback wire e2e — two models served
//! concurrently over TCP, a live rollout under traffic, and per-model
//! stats split by model id.

use acore_cim::analog::consts as c;
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::{Batcher, ModelStats, ServeError};
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::cluster::{CimCluster, ClusterServer, ServiceConfig};
use acore_cim::coordinator::registry::ModelRegistry;
use acore_cim::coordinator::service::{
    place, CimService, CoreBoard, Job, Placement, SubmitOpts, TileRef,
};
use acore_cim::coordinator::wire::{RemoteClient, WireServer};
use acore_cim::util::proptest::forall;
use acore_cim::util::rng::Rng;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

fn ideal_cfg() -> SimConfig {
    let mut cfg = SimConfig::default().scaled(0.0);
    cfg.sigma_noise = 0.0;
    cfg
}

fn rand_tile(rng: &mut Rng) -> TileRef {
    TileRef {
        layer: rng.int_in(0, 1) as usize,
        tr: rng.int_in(0, 2) as usize,
        tc: rng.int_in(0, 2) as usize,
    }
}

/// `Placement::Model` never resolves to a core that does not hold the
/// requested (model, tile) or that is fenced — including boards where
/// every holder is fenced — and the two error cases are exactly:
/// `ModelNotResident` iff no core holds it at all, `NoHealthyCore` iff
/// holders exist but every one is fenced.
#[test]
fn placement_model_never_lands_on_a_non_holder() {
    forall("Placement::Model resolves only to healthy holders", 512, |rng| {
        let k = rng.int_in(1, 6) as usize;
        let board = CoreBoard::new(k);
        for core in 0..k {
            if rng.int_in(0, 3) > 0 {
                let model = rng.int_in(0, 2) as u32;
                let tiles: Vec<TileRef> =
                    (0..rng.int_in(0, 4)).map(|_| rand_tile(rng)).collect();
                board.set_residency(core, model, tiles);
            }
            if rng.int_in(0, 3) == 0 {
                board.fence(core);
            }
        }
        let model = rng.int_in(0, 3) as u32;
        let tile = if rng.int_in(0, 1) == 1 { Some(rand_tile(rng)) } else { None };
        let holders: Vec<usize> =
            (0..k).filter(|&core| board.holds(core, model, tile.as_ref())).collect();
        let healthy: Vec<usize> =
            holders.iter().copied().filter(|&core| !board.is_fenced(core)).collect();

        let rr = AtomicUsize::new(rng.int_in(0, 1000) as usize);
        match place(&board, &rr, Placement::Model { model, tile }) {
            Ok(core) => {
                if !healthy.contains(&core) {
                    return Err(format!(
                        "placed model {model} tile {tile:?} on core {core}, \
                         but healthy holders are {healthy:?}"
                    ));
                }
                // a named tile maps deterministically: repeat placement
                // sticks to the same core (folded-tile caches stay hot)
                if tile.is_some() {
                    let again = place(&board, &rr, Placement::Model { model, tile });
                    if again != Ok(core) {
                        return Err(format!("tiled placement moved: {core} then {again:?}"));
                    }
                }
                Ok(())
            }
            Err(ServeError::ModelNotResident { model: m }) => {
                if m != model {
                    return Err(format!("error names model {m}, requested {model}"));
                }
                if !holders.is_empty() {
                    return Err(format!(
                        "ModelNotResident but cores {holders:?} hold model {model}"
                    ));
                }
                Ok(())
            }
            Err(ServeError::NoHealthyCore) => {
                if holders.is_empty() {
                    return Err("NoHealthyCore but nothing is resident \
                                (expected ModelNotResident)"
                        .to_string());
                }
                if !healthy.is_empty() {
                    return Err(format!(
                        "NoHealthyCore but healthy holders exist: {healthy:?}"
                    ));
                }
                Ok(())
            }
            Err(other) => Err(format!("unexpected placement error: {other:?}")),
        }
    });
}

/// Serve one model-targeted batch and wait. A raced `WrongModel` (the
/// placement resolved a holder that a concurrent rollout reprogrammed
/// before the job reached the head of its queue) is the protocol's
/// retryable answer — retry once; anything else is a dropped request.
fn serve_one<S: CimService>(
    svc: &S,
    model: u32,
    retried: &AtomicUsize,
) -> Result<(), ServeError> {
    let xs = vec![vec![10; c::N_ROWS]];
    for attempt in 0..2 {
        let job = Job::MacBatch { xs: clone_xs(&xs), tile: None, model: Some(model) };
        match svc.submit(job, SubmitOpts::for_model(model, None))?.typed::<Vec<Vec<u32>>>().wait()
        {
            Ok(_) => return Ok(()),
            Err(ServeError::WrongModel { .. }) if attempt == 0 => {
                retried.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => return Err(e),
        }
    }
    Err(ServeError::NoHealthyCore)
}

fn clone_xs(xs: &[Vec<i32>]) -> Vec<Vec<i32>> {
    xs.to_vec()
}

fn requests_for(stats: &[ModelStats], model: u32) -> u64 {
    stats.iter().find(|s| s.model == model).map_or(0, |s| s.requests)
}

/// In-process hot rollout: alpha serves on cores {0,1}, beta on {2};
/// beta rolls onto core 1 through the drain barrier while both models
/// take continuous traffic. Nothing is dropped, residency flips, and
/// the per-model counters split by id.
/// alpha on cores {0,1}, beta on {2}, served with a recalibration
/// engine and a band generous enough that an ideal die always rejoins.
fn two_model_server() -> (ClusterServer, ModelRegistry, u32, u32) {
    let cfg = ideal_cfg();
    let mut cluster = CimCluster::new(&cfg, 3);
    let mut reg = ModelRegistry::new();
    let alpha = reg.register("alpha", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let beta = reg.register("beta", vec![33; c::N_ROWS * c::M_COLS]).unwrap();
    reg.deploy(&mut cluster, &[(0, alpha), (1, alpha), (2, beta)]).unwrap();
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(BiscEngine::from_config(&cfg, AdcCharacterization::ideal())),
        health_band: 1.0,
    });
    (server, reg, alpha, beta)
}

#[test]
fn hot_rollout_through_the_drain_barrier_drops_nothing() {
    let (server, reg, alpha, beta) = two_model_server();

    let stop = Arc::new(AtomicBool::new(false));
    let retried = Arc::new(AtomicUsize::new(0));
    let producers: Vec<_> = [alpha, beta]
        .into_iter()
        .map(|model| {
            let client = server.client();
            let stop = Arc::clone(&stop);
            let retried = Arc::clone(&retried);
            std::thread::spawn(move || {
                let mut served = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    serve_one(&client, model, &retried).unwrap_or_else(|e| {
                        panic!("model {model} request dropped mid-rollout: {e:?}")
                    });
                    served += 1;
                }
                served
            })
        })
        .collect();

    // let traffic build, then roll beta onto core 1 live
    std::thread::sleep(std::time::Duration::from_millis(30));
    let client = server.client();
    let health = client.rollout(1, beta, reg.weights(beta).unwrap().to_vec()).unwrap();
    assert_eq!(health.core, 1);
    assert_eq!(health.model, Some(beta));
    assert!(health.recalibrated, "rollout must recalibrate the reprogrammed die");
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);
    let mut served = 0u64;
    for p in producers {
        served += p.join().expect("producer panicked (a request was dropped)");
    }
    assert!(served > 0, "producers never served a request");

    // residency flipped on the board and core 1 rejoined the scheduler
    assert_eq!(client.board().resident_model(1), Some(beta));
    assert!(!client.board().is_fenced(1), "core 1 must rejoin after rollout");
    // alpha now resolves only to core 0; beta spreads over {1, 2}
    for _ in 0..8 {
        let t = client
            .submit(
                Job::MacBatch { xs: vec![vec![1; c::N_ROWS]], tile: None, model: Some(alpha) },
                SubmitOpts::for_model(alpha, None),
            )
            .unwrap();
        assert_eq!(t.core(), 0, "core 1 no longer holds alpha");
        t.typed::<Vec<Vec<u32>>>().wait().unwrap();
    }
    // a model nobody holds is a typed error, never a panic
    match client.submit(
        Job::MacBatch { xs: vec![vec![1; c::N_ROWS]], tile: None, model: Some(77) },
        SubmitOpts::for_model(77, None),
    ) {
        Err(ServeError::ModelNotResident { model: 77 }) => {}
        other => panic!("expected ModelNotResident, got {other:?}"),
    }

    // per-model counters split by id: both models took traffic, and the
    // rollout recorded a recalibration against beta on core 1
    let stats = server.live_model_stats();
    assert!(requests_for(&stats, alpha) > 0, "no alpha requests counted: {stats:?}");
    assert!(requests_for(&stats, beta) > 0, "no beta requests counted: {stats:?}");
    assert!(
        stats.iter().any(|s| s.model == beta && s.recals > 0),
        "rollout must count a recal against beta: {stats:?}"
    );
    server.join();
}

/// Loopback wire e2e: two models served concurrently over TCP, a live
/// rollout under remote traffic with zero drops, the client's mirror
/// residency tracking the flip, and `ModelStatsReq` splitting counters
/// by model id.
#[test]
fn wire_serves_two_models_and_rolls_out_live() {
    let (server, reg, alpha, beta) = two_model_server();
    let wire = Arc::new(
        WireServer::bind(("127.0.0.1", 0), server.client(), server.live_handles())
            .expect("bind ephemeral loopback port")
            .with_models(reg.names())
            .with_model_stats(server.model_stats_handles()),
    );
    let addr = wire.local_addr().unwrap();
    let acceptor = {
        let wire = Arc::clone(&wire);
        std::thread::spawn(move || wire.serve())
    };

    let client = Arc::new(RemoteClient::connect(addr).expect("connect loopback"));
    // the Hello carried the registry names and the residency map
    assert_eq!(client.model_id("alpha"), Some(alpha));
    assert_eq!(client.model_id("beta"), Some(beta));
    assert_eq!(client.model_id("gamma"), None);
    assert_eq!(client.board().resident_model(0), Some(alpha));
    assert_eq!(client.board().resident_model(2), Some(beta));

    // both models serve concurrently over one connection
    let retried = Arc::new(AtomicUsize::new(0));
    for _ in 0..4 {
        serve_one(client.as_ref(), alpha, &retried).unwrap();
        serve_one(client.as_ref(), beta, &retried).unwrap();
    }
    // edge placement fails typed on a model nobody holds — before any
    // bytes hit the wire
    match client.submit(
        Job::MacBatch { xs: vec![vec![1; c::N_ROWS]], tile: None, model: Some(9) },
        SubmitOpts::for_model(9, None),
    ) {
        Err(ServeError::ModelNotResident { model: 9 }) => {}
        other => panic!("expected ModelNotResident, got {other:?}"),
    }

    // live rollout under remote traffic: zero dropped requests
    let stop = Arc::new(AtomicBool::new(false));
    let producer = {
        let client = Arc::clone(&client);
        let stop = Arc::clone(&stop);
        let retried = Arc::clone(&retried);
        std::thread::spawn(move || {
            let mut served = 0u64;
            while !stop.load(Ordering::Relaxed) {
                serve_one(client.as_ref(), alpha, &retried).unwrap_or_else(|e| {
                    panic!("remote alpha request dropped mid-rollout: {e:?}")
                });
                served += 1;
            }
            served
        })
    };
    std::thread::sleep(std::time::Duration::from_millis(20));
    let health = client.rollout(1, beta, reg.weights(beta).unwrap().to_vec()).unwrap();
    assert_eq!(health.model, Some(beta));
    stop.store(true, Ordering::Relaxed);
    let served = producer.join().expect("producer panicked (a request was dropped)");
    assert!(served > 0, "remote producer never served a request");

    // the mirror board tracked the flip from the rollout's Health reply
    assert_eq!(client.board().resident_model(1), Some(beta));
    assert!(!client.board().is_fenced(1), "mirror must unfence core 1 after rollout");

    // per-model counters arrive split by id over the wire
    let stats = client.remote_model_stats().expect("ModelStats round-trip");
    assert!(requests_for(&stats, alpha) > 0, "no alpha requests counted: {stats:?}");
    assert!(requests_for(&stats, beta) > 0, "no beta requests counted: {stats:?}");

    drop(client);
    wire.request_shutdown();
    acceptor.join().unwrap();
    drop(wire);
    server.join();
}
