//! End-to-end tests of the autonomous recalibration loop: injected
//! drift genuinely degrades BISC residuals under traffic, the
//! calibrator daemon detects the trend and runs the drain →
//! recalibrate → rejoin cycle on its own (no dropped jobs), the
//! worker-side refresher keeps the DNN gather trims fresh across
//! in-service drains, and a single-core deployment still self-heals
//! through the fence path.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::{Batcher, ServeError};
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::calibrator::{Calibrator, CalibratorConfig};
use acore_cim::coordinator::cluster::{CimCluster, ServiceConfig};
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::coordinator::dnn::CimMlp;
use acore_cim::coordinator::service::CimService;
use acore_cim::data::mlp::{train, Mlp, QuantMlp, TrainConfig};
use acore_cim::data::synth;
use std::time::{Duration, Instant};

#[test]
fn drift_degrades_residuals_and_recalibration_recovers() {
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    cfg.sigma_drift = 2e-4;
    let sample = VariationSample::draw(&cfg);
    let mut model = CimAnalogModel::from_sample(&cfg, &sample);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    engine.calibrate(&mut model);
    let r0 = engine.residual_gain_error(&mut model);
    assert!(r0 < 0.05, "freshly calibrated residual out of band: {r0}");

    // 800 MAC-equivalents of aging: the residual must genuinely move
    model.advance_drift(800);
    let r1 = engine.residual_gain_error(&mut model);
    assert!(
        r1 > r0 * 2.0 && r1 > 0.05,
        "drift did not degrade the residual: {r0} -> {r1}"
    );

    // recalibration pulls the drifted die back toward the floor (a few
    // columns may saturate their trim range, so "recovered" is a strong
    // reduction, not necessarily the original floor)
    engine.calibrate(&mut model);
    let r2 = engine.residual_gain_error(&mut model);
    assert!(r2 < r1 * 0.6, "recalibration did not recover: {r1} -> {r2}");
}

#[test]
fn calibrator_autonomously_recalibrates_drifting_cores() {
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    cfg.sigma_drift = 2e-4;
    let mut cluster = CimCluster::new(&cfg, 2);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    cluster.calibrate_parallel(&engine);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    // wide health band so the passive fence never beats the daemon to
    // it: any drain that happens is the daemon's own decision
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        health_band: 0.5,
    });
    let threshold = 0.05;
    let daemon = Calibrator::spawn(
        server.client(),
        CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: 0.5,
            threshold,
            max_staleness: Duration::from_secs(3600),
            cooldown: Duration::from_millis(50),
        },
    );
    let shared = daemon.shared();
    let client = server.client();

    // age the dies under real traffic until the daemon fires. The pace
    // is throttled so the dies degrade over several sampling sweeps —
    // the daemon then drains at a residual BISC can still pull back,
    // the realistic serving regime (drift per request is tiny)
    let mut sent = 0u64;
    let deadline = Instant::now() + Duration::from_secs(60);
    while shared.total_drains() == 0 {
        assert!(
            Instant::now() < deadline,
            "daemon never drained after {sent} MACs: {:?}",
            shared.snapshot()
        );
        for _ in 0..4 {
            let qs = client
                .mac_batch(vec![vec![30; c::N_ROWS]; 16])
                .expect("traffic must keep serving through autonomous drains");
            assert_eq!(qs.len(), 16);
            sent += 16;
        }
        std::thread::sleep(Duration::from_millis(3));
    }

    // stop the traffic: the dies stop aging, so the daemon must settle
    // every trend strictly below the trigger threshold (post-recal
    // residuals below the pre-recal trend by construction)
    let settle = Instant::now() + Duration::from_secs(60);
    loop {
        let stats = shared.snapshot();
        if stats.iter().all(|s| !s.trend.is_some_and(|t| t >= threshold)) {
            break;
        }
        assert!(Instant::now() < settle, "trends never settled: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    }
    let stats = daemon.stop();
    let drains: u64 = stats.iter().map(|s| s.drains).sum();
    let triggers: u64 = stats.iter().map(|s| s.trend_triggers + s.staleness_triggers).sum();
    assert!(drains >= 1, "no autonomous drain recorded: {stats:?}");
    assert!(triggers >= drains, "every drain needs a recorded trigger: {stats:?}");
    assert_eq!(
        stats.iter().map(|s| s.drain_failures).sum::<u64>(),
        0,
        "drains must succeed: {stats:?}"
    );
    // the epochs the daemon observed reached the board
    for s in &stats {
        if s.drains > 0 {
            assert!(s.last_recal_epoch > 0, "recal epoch never advanced: {s:?}");
        }
    }

    // zero dropped in-flight jobs: every mac_batch above returned Ok,
    // and the workers confirm nothing was rejected or expired
    drop(client);
    let (cluster, wstats) = server.join();
    let served: u64 = wstats.iter().map(|s| s.requests).sum();
    assert!(served >= sent, "workers served {served} of {sent}");
    assert_eq!(
        wstats.iter().map(|s| s.rejected + s.expired).sum::<u64>(),
        0,
        "jobs were dropped during autonomous recalibration: {wstats:?}"
    );
    assert!(
        cluster.cores.iter().any(|core| core.recal_count > 0),
        "no core records an in-service recalibration"
    );
}

#[test]
fn in_service_drain_refreshes_gather_side_trims() {
    // DNN pipeline with per-core digital residual trims
    let (train_ds, test_ds) = synth::generate(600, 120, 17);
    let mut mlp = Mlp::new(4);
    train(&mut mlp, &train_ds, &TrainConfig { epochs: 6, ..Default::default() });
    let q = QuantMlp::from_float(&mlp, &train_ds, 100);
    let cim_mlp = CimMlp::new(q, &train_ds, 50);

    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 2);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    cluster.calibrate_parallel(&engine);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let sched = cim_mlp.prepare_cluster(&mut cluster, Some(&cfg));
    assert!(sched.core_corrections(0).has_any(), "schedule must carry trims");
    assert_eq!(sched.core_corrections(0).epoch, 0);

    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        ..ServiceConfig::default()
    });
    let client = server.client();
    let imgs: Vec<&[f32]> = (0..4).map(|i| test_ds.image(i)).collect();
    let mut st = Default::default();
    let before = cim_mlp
        .infer_batch_service(&client, &sched, &imgs, &mut st)
        .expect("pre-drain inference");
    assert_eq!(before.len(), imgs.len());

    // in-service drain: without the worker-side refresher this would
    // leave the schedule stale and the next inference would be REFUSED;
    // with it, the worker re-measures the trims at the new epoch
    let h = client.drain(0).unwrap();
    assert!(h.recalibrated, "drain with an engine must recalibrate");
    assert_eq!(h.recal_epoch, 1);
    let cor = sched.core_corrections(0);
    assert_eq!(cor.epoch, 1, "drain must republish corrections at the new epoch");
    assert!(cor.has_any(), "refreshed corrections must still carry trims");

    let after = cim_mlp
        .infer_batch_service(&client, &sched, &imgs, &mut st)
        .expect("post-drain inference must keep serving with refreshed trims");
    assert_eq!(after.len(), imgs.len());
    for logits in &after {
        assert!(logits.iter().all(|v| v.is_finite()), "non-finite post-drain logits");
    }
    drop(client);
    server.join();
}

#[test]
fn single_core_deployment_self_heals_through_the_fence() {
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    cfg.sigma_drift = 5e-4;
    let mut cluster = CimCluster::new(&cfg, 1);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    cluster.calibrate_parallel(&engine);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let band = 0.10;
    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        health_band: band,
    });
    // threshold BELOW the band: the daemon wants to drain early, but the
    // last-healthy-core guard must hold it back until the health probe
    // fences the degraded core — at which point draining it can only
    // help, and the deployment recovers on its own
    let daemon = Calibrator::spawn(
        server.client(),
        CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: 0.5,
            threshold: 0.05,
            max_staleness: Duration::from_secs(3600),
            cooldown: Duration::from_millis(50),
        },
    );
    let shared = daemon.shared();
    let client = server.client();
    let deadline = Instant::now() + Duration::from_secs(60);
    while shared.total_drains() == 0 {
        assert!(
            Instant::now() < deadline,
            "single core never self-healed: {:?}",
            shared.snapshot()
        );
        // during the fenced window round-robin placement has no healthy
        // core — that typed error is the correct behavior, not a drop
        match client.mac_batch(vec![vec![30; c::N_ROWS]; 16]) {
            Ok(qs) => assert_eq!(qs.len(), 16),
            Err(ServeError::NoHealthyCore) => {}
            Err(e) => panic!("unexpected serving error: {e}"),
        }
        // throttled so the die crosses the band over a few sweeps, not
        // in one leap past what BISC can trim back
        std::thread::sleep(Duration::from_millis(2));
    }
    // after the drain the core rejoins and serves again
    let rejoined = Instant::now() + Duration::from_secs(30);
    loop {
        match client.mac_batch(vec![vec![30; c::N_ROWS]; 4]) {
            Ok(_) => break,
            Err(ServeError::NoHealthyCore) => {
                assert!(Instant::now() < rejoined, "core never rejoined after drain");
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("unexpected serving error: {e}"),
        }
    }
    let stats = daemon.stop();
    assert!(stats[0].drains >= 1, "no drain recorded: {stats:?}");
    assert!(
        stats[0].trend_triggers + stats[0].staleness_triggers >= 1,
        "drain without a trigger: {stats:?}"
    );
    drop(client);
    let (cluster, _) = server.join();
    assert!(cluster.cores[0].recal_count >= 1);
}
