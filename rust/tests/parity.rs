//! Cross-layer parity: the rust golden analog model (L3) and the AOT
//! JAX/Pallas artifact executed via PJRT (L1/L2) must realize the SAME
//! transfer function for identical die parameters, weights, trims, and
//! ADC references. Tolerance is one ADC code on a small fraction of
//! entries (f32 vs f64 rounding exactly at .5 boundaries).
//!
//! Requires `make artifacts` to have run (skips with a message otherwise).

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::runtime::{CimRuntime, Executor, Manifest};
use acore_cim::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    Manifest::discover().ok()
}

fn random_weights(rng: &mut Rng) -> Vec<i32> {
    (0..c::N_ROWS * c::M_COLS).map(|_| rng.int_in(-63, 63) as i32).collect()
}

fn random_inputs(rng: &mut Rng, batch: usize) -> Vec<i32> {
    (0..batch * c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect()
}

fn compare(model_q: &[u32], runtime_q: &[u32]) -> (i64, f64) {
    let max_diff = model_q
        .iter()
        .zip(runtime_q)
        .map(|(&a, &b)| (a as i64 - b as i64).abs())
        .max()
        .unwrap();
    let frac_diff = model_q
        .iter()
        .zip(runtime_q)
        .filter(|(a, b)| a != b)
        .count() as f64
        / model_q.len() as f64;
    (max_diff, frac_diff)
}

#[test]
fn artifact_matches_golden_model_ideal_die() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let exec = Executor::new(m).unwrap();
    let sample = VariationSample::ideal();
    let mut rt = CimRuntime::new(exec, sample.clone());
    let mut cfg = SimConfig::default().scaled(0.0);
    cfg.sigma_noise = 0.0;
    let mut golden = CimAnalogModel::from_sample(&cfg, &sample);

    let mut rng = Rng::new(101);
    let w = random_weights(&mut rng);
    rt.program(&w);
    golden.program(&w);
    let batch = 32;
    let x = random_inputs(&mut rng, batch);
    let q_rt = rt.forward_batch(&x, batch).unwrap();
    let q_gold = golden.forward_batch(&x, batch);
    let (max_diff, frac) = compare(&q_gold, &q_rt);
    assert!(max_diff <= 1, "max code diff {max_diff}");
    assert!(frac < 0.02, "fraction differing {frac}");
}

#[test]
fn artifact_matches_golden_model_noisy_die_with_trims() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let mut cfg = SimConfig::default();
    cfg.seed = 0xD1E;
    cfg.sigma_noise = 0.0;
    let sample = VariationSample::draw(&cfg);
    let exec = Executor::new(m).unwrap();
    let mut rt = CimRuntime::new(exec, sample.clone());
    let mut golden = CimAnalogModel::from_sample(&cfg, &sample);

    let mut rng = Rng::new(77);
    let w = random_weights(&mut rng);
    rt.program(&w);
    golden.program(&w);

    // non-trivial trims + widened refs on BOTH sides
    for col in 0..c::M_COLS {
        let pot_p = 100 + (col as u32 * 3) % 100;
        let pot_n = 90 + (col as u32 * 5) % 120;
        let cal = (col as u32) % 64;
        golden.set_trims(col, pot_p, pot_n, cal);
        rt.trims.pot_p[col] = pot_p;
        rt.trims.pot_n[col] = pot_n;
        rt.trims.cal[col] = cal;
    }
    golden.set_adc_refs(0.184, 0.648);
    rt.adc_refs = (0.184, 0.648);

    let batch = 64;
    let x = random_inputs(&mut rng, batch);
    let q_rt = rt.forward_batch(&x, batch).unwrap();
    let q_gold = golden.forward_batch(&x, batch);
    let (max_diff, frac) = compare(&q_gold, &q_rt);
    assert!(max_diff <= 1, "max code diff {max_diff}");
    assert!(frac < 0.03, "fraction differing {frac}");
}

#[test]
fn batch_padding_is_transparent() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let exec = Executor::new(m).unwrap();
    let mut rt = CimRuntime::new(exec, VariationSample::ideal());
    let mut rng = Rng::new(5);
    let w = random_weights(&mut rng);
    rt.program(&w);
    // batch 3 pads to the b8 artifact; results must match per-sample runs
    let x = random_inputs(&mut rng, 3);
    let q3 = rt.forward_batch(&x, 3).unwrap();
    for b in 0..3 {
        let q1 = rt
            .forward_batch(&x[b * c::N_ROWS..(b + 1) * c::N_ROWS], 1)
            .unwrap();
        assert_eq!(&q3[b * c::M_COLS..(b + 1) * c::M_COLS], &q1[..]);
    }
}

#[test]
fn executor_rejects_bad_shapes() {
    let Some(m) = manifest() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let mut exec = Executor::new(m).unwrap();
    use acore_cim::runtime::TensorF32;
    let bad = vec![TensorF32::new(vec![0.0; 4], &[2, 2])];
    assert!(exec.run("cim_mac_b1", &bad).is_err());
    assert!(exec.run("no_such_artifact", &[]).is_err());
}
