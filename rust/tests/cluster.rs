//! Multi-core cluster integration: concurrent scatter-gather serving
//! (every reply delivered, no cross-core mixing) and a property test
//! holding `forward_batch` / `forward_folded` / `forward_golden` to
//! parity on every core after BISC calibration.

use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::{Batcher, ServeError};
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::cluster::CimCluster;
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::coordinator::service::{CimService, Job, SubmitOpts, Ticket};
use acore_cim::util::proptest::forall;
use acore_cim::util::rng::Rng;

fn ideal_cfg() -> SimConfig {
    let mut cfg = SimConfig::default().scaled(0.0);
    cfg.sigma_noise = 0.0;
    cfg
}

/// Reference evaluation: an ideal die with the given uniform weight code.
fn reference(weight: i32, x: &[i32]) -> Vec<u32> {
    let mut m = CimAnalogModel::ideal();
    m.program(&vec![weight; c::N_ROWS * c::M_COLS]);
    m.forward_batch(x, 1)
}

#[test]
fn concurrent_clients_no_cross_core_mixing() {
    // each core gets DIFFERENT weights; pinned requests must always be
    // answered by the right core's array
    let k = 3;
    let mut cluster = CimCluster::new(&ideal_cfg(), k);
    for core in 0..k {
        cluster.program_core(core, &vec![(core as i32 + 1) * 15; c::N_ROWS * c::M_COLS]);
    }
    let server = cluster.serve(Batcher {
        max_batch: 32,
        max_wait: std::time::Duration::from_millis(1),
    });
    let expected: Vec<Vec<Vec<u32>>> = (0..k)
        .map(|core| {
            (0..4)
                .map(|t| reference((core as i32 + 1) * 15, &vec![10 + t as i32; c::N_ROWS]))
                .collect()
        })
        .collect();
    let mut joins = Vec::new();
    for t in 0..8usize {
        let client = server.client();
        let expected = expected.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t as u64 + 99);
            for _ in 0..25 {
                let core = (rng.next_u64() % 3) as usize;
                let variant = (rng.next_u64() % 4) as usize;
                let x = vec![10 + variant as i32; c::N_ROWS];
                let q = client.mac_on(core, x).expect("request failed");
                assert_eq!(
                    q, expected[core][variant],
                    "core {core} variant {variant}: reply from the wrong array"
                );
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let (_cluster, stats) = server.join();
    let total: u64 = stats.iter().map(|s| s.requests).sum();
    assert_eq!(total, 8 * 25, "every request must be answered exactly once");
    assert_eq!(stats.iter().map(|s| s.rejected).sum::<u64>(), 0);
}

#[test]
fn round_robin_scatter_delivers_every_reply() {
    let k = 4;
    let n = 500;
    let mut cluster = CimCluster::new(&ideal_cfg(), k);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let client = server.client();
    let expect = reference(40, &vec![30; c::N_ROWS]);
    // pipelined scatter: all in flight at once, then gather
    let tickets: Vec<Ticket<Vec<u32>>> = (0..n)
        .map(|_| {
            client
                .submit(Job::Mac(vec![30; c::N_ROWS]), SubmitOpts::default())
                .expect("cluster gone")
                .typed()
        })
        .collect();
    for t in tickets {
        assert_eq!(t.wait().unwrap(), expect);
    }
    drop(client);
    let (_cluster, stats) = server.join();
    assert_eq!(stats.len(), k);
    let total: u64 = stats.iter().map(|s| s.requests).sum();
    assert_eq!(total, n as u64);
    for (core, s) in stats.iter().enumerate() {
        // shared round-robin cursor: the load lands on every core
        assert!(
            s.requests >= (n / k / 2) as u64,
            "core {core} starved: {} of {n} requests",
            s.requests
        );
    }
}

#[test]
fn cluster_rejects_bad_requests_per_request() {
    let mut cluster = CimCluster::new(&ideal_cfg(), 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let client = server.client();
    let err = client.mac(vec![1; 5]).unwrap_err();
    assert_eq!(err, ServeError::BadRequest { expected: c::N_ROWS, got: 5 });
    // both workers still alive after the rejection
    for core in 0..2 {
        assert!(client.mac_on(core, vec![30; c::N_ROWS]).is_ok());
    }
    drop(client);
    let (_cluster, stats) = server.join();
    assert_eq!(stats.iter().map(|s| s.rejected).sum::<u64>(), 1);
    assert_eq!(stats.iter().map(|s| s.requests).sum::<u64>(), 2);
}

#[test]
fn per_core_path_parity_after_calibration() {
    // K dies with distinct variation draws, all BISC-calibrated; on every
    // core the three evaluation paths must agree:
    //   forward_folded == forward_batch (same folded math, cached tile)
    //   |forward_batch - forward_golden| <= 1 code (f32 vs f64 rounding)
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0; // golden is noisy otherwise
    let mut cluster = CimCluster::new(&cfg, 3);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    cluster.calibrate_parallel(&engine);
    forall("per-core path parity", 24, |rng| {
        let core = (rng.next_u64() % 3) as usize;
        let weights: Vec<i32> =
            (0..c::N_ROWS * c::M_COLS).map(|_| rng.int_in(-63, 63) as i32).collect();
        let batch = 1 + (rng.next_u64() % 6) as usize;
        let x: Vec<i32> =
            (0..batch * c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
        let model = &mut cluster.cores[core].model;
        let folded_tile = model.fold_tile(&weights);
        let q_folded = model.forward_folded(&folded_tile, &x, batch);
        model.program(&weights);
        let q_batch = model.forward_batch(&x, batch);
        if q_folded != q_batch {
            return Err(format!("core {core}: folded != batch path"));
        }
        for b in 0..batch {
            let q_gold = model.forward_golden(&x[b * c::N_ROWS..(b + 1) * c::N_ROWS]);
            for col in 0..c::M_COLS {
                let f = q_batch[b * c::M_COLS + col] as i64;
                let g = q_gold[col] as i64;
                if (f - g).abs() > 1 {
                    return Err(format!(
                        "core {core} b={b} col={col}: batch {f} vs golden {g}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn calibration_improves_every_core() {
    let cfg = SimConfig::default();
    let mut cluster = CimCluster::new(&cfg, 3);
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    // residual gain error before vs after, per core
    let residual = |model: &mut CimAnalogModel| -> f64 {
        engine
            .characterize_only(model)
            .iter()
            .map(|(p, n)| (p.g_tot - 1.0).abs() + (n.g_tot - 1.0).abs())
            .sum::<f64>()
            / (2.0 * c::M_COLS as f64)
    };
    let before: Vec<f64> =
        cluster.cores.iter_mut().map(|core| residual(&mut core.model)).collect();
    cluster.calibrate_parallel(&engine);
    for (k, core) in cluster.cores.iter_mut().enumerate() {
        let after = residual(&mut core.model);
        assert!(
            after < before[k] * 0.5,
            "core {k}: residual gain error {} -> {after}",
            before[k]
        );
        assert!(core.report.is_some());
    }
}
