//! SoC-level integration: the RV32IM core driving the CIM device over
//! AXI4-Lite — firmware-controlled MAC, the full BISC routine, cycle
//! accounting for the Table II system-throughput ratio.

use acore_cim::analog::variation::VariationSample;
use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::cim_core::regs;
use acore_cim::soc::firmware;
use acore_cim::soc::memmap::{map, Soc};
use acore_cim::soc::riscv::asm::Asm;
use acore_cim::soc::riscv::cpu::Halt;

#[test]
fn firmware_mac_loop_throughput_accounting() {
    // firmware: feed inputs, run K MACs, read outputs — measures the
    // paper's "full system" path (input generation + weight updates +
    // output reading via the RISC-V core), Table II's 113 -> 3.05 1b-GOPS
    let mut soc = Soc::new(CimAnalogModel::ideal());
    soc.cim_mut().program_weights(&vec![21; c::N_ROWS * c::M_COLS]);
    let k_macs = 50;
    let mut a = Asm::new(map::ENTRY);
    a.li(5, map::CIM_BASE as i32);
    a.li(9, k_macs); // loop counter
    a.label("mac_loop");
    // write 36 inputs
    a.li(6, 17);
    a.li(7, 0);
    a.li(28, (map::CIM_BASE + regs::INPUT) as i32);
    a.label("in_loop");
    a.sw(28, 6, 0);
    a.addi(28, 28, 4);
    a.addi(7, 7, 1);
    a.li(31, c::N_ROWS as i32);
    a.blt(7, 31, "in_loop");
    // fire MAC
    a.li(6, 1);
    a.sw(5, 6, regs::CTRL as i32);
    // read all 32 outputs (accumulate into x29 so reads aren't dead)
    a.li(7, 0);
    a.li(28, (map::CIM_BASE + regs::OUT) as i32);
    a.label("out_loop");
    a.lw(6, 28, 0);
    a.add(29, 29, 6);
    a.addi(28, 28, 4);
    a.addi(7, 7, 1);
    a.li(31, c::M_COLS as i32);
    a.blt(7, 31, "out_loop");
    a.addi(9, 9, -1);
    a.bne(9, 0, "mac_loop");
    a.li(10, 0);
    a.exit();
    soc.load_program(&a.assemble());
    let halt = soc.run(10_000_000);
    assert_eq!(halt, Halt::Exit(0));
    assert_eq!(soc.cim_mut().mac_count(), k_macs as u32);

    // system slowdown: CPU cycles per MAC vs the 1-cycle analog MAC —
    // this ratio feeds power::system_metrics (paper: ~37x)
    let cycles_per_mac = soc.cpu.cycles as f64 / k_macs as f64;
    assert!(
        cycles_per_mac > 20.0 && cycles_per_mac < 2000.0,
        "cycles/MAC = {cycles_per_mac}"
    );
    println!("system slowdown: {cycles_per_mac:.1} CPU cycles per CIM MAC");
}

#[test]
fn bisc_firmware_end_to_end_improves_accuracy_of_device() {
    // run the BISC firmware on a noisy die, then verify the device's
    // transfer is closer to nominal than before
    let mut cfg = SimConfig::default();
    cfg.seed = 0x50C;
    cfg.sigma_noise = 0.0;
    let sample = VariationSample::draw(&cfg);

    let residual = |soc: &mut Soc| -> f64 {
        let dev = soc.cim_mut();
        dev.program_weights(&vec![c::CODE_MAX; c::N_ROWS * c::M_COLS]);
        let mut err = 0.0;
        let k = c::code_gain_nominal();
        let mid = c::q_mid_nominal();
        for x in [-40i32, -20, 0, 20, 40] {
            let q = dev.model.forward_batch(&vec![x; c::N_ROWS], 1);
            let nom = mid + k * (x as f64 * 63.0 * c::N_ROWS as f64);
            for col in 0..c::M_COLS {
                err += (q[col] as f64 - nom).abs();
            }
        }
        err / (5.0 * c::M_COLS as f64)
    };

    let mut soc = Soc::new(CimAnalogModel::from_sample(&cfg, &sample));
    let before = residual(&mut soc);
    soc.load_program(&firmware::bisc_program());
    soc.write_words(
        map::PARAM_BLOCK,
        &firmware::bisc_param_block(&cfg, AdcCharacterization::ideal()),
    );
    let halt = soc.run(1_000_000_000);
    assert_eq!(halt, Halt::Exit(0), "BISC firmware failed: {halt:?}");
    let after = residual(&mut soc);
    assert!(
        after < before * 0.5,
        "BISC firmware: residual {before:.2} -> {after:.2} codes"
    );
    println!("BISC firmware: mean |error| {before:.2} -> {after:.2} codes");
    let (instret, cycles) = (soc.cpu.instret, soc.cpu.cycles);
    println!(
        "BISC firmware: {} instructions, {} cycles, {} MAC reads",
        instret,
        cycles,
        soc.cim_mut().mac_count()
    );
}

#[test]
fn bisc_firmware_latency_budget() {
    // Alg. 1 overhead: the calibration must complete within a practical
    // budget (paper: "real-time", run between workloads). At 50 MHz the
    // firmware must finish in well under a second of SoC time.
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let sample = VariationSample::draw(&cfg);
    let mut soc = Soc::new(CimAnalogModel::from_sample(&cfg, &sample));
    soc.load_program(&firmware::bisc_program());
    soc.write_words(
        map::PARAM_BLOCK,
        &firmware::bisc_param_block(&cfg, AdcCharacterization::ideal()),
    );
    assert_eq!(soc.run(1_000_000_000), Halt::Exit(0));
    let cpu_cycles = soc.cpu.cycles;
    let analog_sh = soc.cim_mut().busy_sh_periods();
    // SoC wall time at 50 MHz CPU + 1 us per analog S&H period
    let wall_s = cpu_cycles as f64 / 50e6 + analog_sh as f64 * c::T_SH;
    println!(
        "BISC latency: {cpu_cycles} CPU cycles + {analog_sh} S&H periods = {:.1} ms @50MHz",
        wall_s * 1e3
    );
    assert!(wall_s < 1.0, "calibration too slow: {wall_s} s");

    // host engine predicts the analog read count
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
    assert_eq!(analog_sh, engine.latency_sh_periods());
}

#[test]
fn gpio_and_uart_coexist_with_cim() {
    let mut soc = Soc::new(CimAnalogModel::ideal());
    let mut a = Asm::new(map::ENTRY);
    a.li(5, map::GPIO_BASE as i32);
    a.li(6, 0x5A);
    a.sw(5, 6, 0);
    a.li(5, map::UART_BASE as i32);
    a.li(6, 'B' as i32);
    a.sw(5, 6, 0);
    a.li(10, 0);
    a.exit();
    soc.load_program(&a.assemble());
    assert_eq!(soc.run(1000), Halt::Exit(0));
    assert_eq!(soc.uart_mut().tx_string(), "B");
}
