//! Counting-allocator steady-state gate (deterministic, unlike wall-time
//! floors): after warmup, the in-process Mac and MacBatch evaluation
//! paths — the analog GEMM every serving worker drives per request —
//! run with ZERO heap allocations. This pins the §Perf "zero-allocation
//! hot path" refactor (DESIGN.md §11): `Folded` carries everything
//! derivable at fold time, and the `_into` entry points reuse
//! caller-owned scratch/output buffers.
//!
//! The whole gate lives in ONE `#[test]` so no concurrently running test
//! can touch the global allocation counter mid-measurement.

use acore_cim::analog::{consts as c, CimAnalogModel, MacScratch};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System allocator wrapped with an allocation-event counter. Frees are
/// not counted — the gate is about steady-state allocation pressure.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn alloc_events() -> u64 {
    ALLOC_EVENTS.load(Ordering::Relaxed)
}

/// Run `f` and return how many allocation events it performed.
fn allocs_during<F: FnMut()>(mut f: F) -> u64 {
    let before = alloc_events();
    f();
    alloc_events() - before
}

#[test]
fn steady_state_mac_paths_allocate_nothing() {
    let mut model = CimAnalogModel::ideal();
    let weights = vec![40i32; c::N_ROWS * c::M_COLS];
    model.program(&weights);
    let x1 = vec![30i32; c::N_ROWS];
    let x64: Vec<i32> = (0..64 * c::N_ROWS).map(|i| (i % 63) as i32 - 31).collect();
    let mut out = Vec::new();

    // warmup: the first calls fold the model and grow the scratch/output
    // buffers to the largest batch used below
    model.forward_batch_into(&x1, 1, &mut out);
    model.forward_batch_into(&x64, 64, &mut out);

    // Mac path: one request per call, many calls — zero allocations
    let macs = allocs_during(|| {
        for _ in 0..256 {
            model.forward_batch_into(&x1, 1, &mut out);
        }
    });
    assert_eq!(macs, 0, "Mac path allocated {macs} times in steady state");

    // MacBatch path: 64-wide native batches — zero allocations
    let batches = allocs_during(|| {
        for _ in 0..64 {
            model.forward_batch_into(&x64, 64, &mut out);
        }
    });
    assert_eq!(batches, 0, "MacBatch path allocated {batches} times in steady state");

    // DNN tile path: a pre-folded tile evaluated through caller-owned
    // scratch — zero allocations after the same warmup
    let tile = model.fold_tile(&weights);
    let mut scratch = MacScratch::new();
    model.forward_folded_into(&tile, &x64, 64, &mut scratch, &mut out);
    let tiles = allocs_during(|| {
        for _ in 0..64 {
            model.forward_folded_into(&tile, &x1, 1, &mut scratch, &mut out);
            model.forward_folded_into(&tile, &x64, 64, &mut scratch, &mut out);
        }
    });
    assert_eq!(tiles, 0, "tile path allocated {tiles} times in steady state");

    // the outputs are still real: same codes as the allocating wrappers
    model.forward_batch_into(&x1, 1, &mut out);
    assert_eq!(out, model.forward_batch(&x1, 1));
}
