//! Scheduler semantics of the unified job API over a real cluster:
//! least-loaded placement via the shared depth gauges, health fencing
//! (zero jobs placed on an out-of-band die), and the full
//! drain -> recalibrate -> rejoin lifecycle from the periodic-BISC story.

use acore_cim::analog::{consts as c, CimAnalogModel};
use acore_cim::config::SimConfig;
use acore_cim::coordinator::batcher::Batcher;
use acore_cim::coordinator::bisc::{AdcCharacterization, BiscEngine};
use acore_cim::coordinator::cluster::{core_seed, CimCluster, ServiceConfig};
use acore_cim::coordinator::registry::deploy_uniform;
use acore_cim::coordinator::service::{gather, CimService, Job, SubmitOpts, Ticket};

fn ideal_cfg() -> SimConfig {
    let mut cfg = SimConfig::default().scaled(0.0);
    cfg.sigma_noise = 0.0;
    cfg
}

#[test]
fn least_loaded_placement_follows_the_depth_gauges() {
    let mut cluster = CimCluster::new(&ideal_cfg(), 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let server = cluster.serve(Batcher::default());
    let client = server.client();
    // pile pinned work onto core 0 without waiting for any reply: four
    // native 256-wide batches weigh 1024 in the depth gauges and take
    // far longer to serve than the submissions below take to place
    let pinned: Vec<Ticket<Vec<Vec<u32>>>> = (0..4)
        .map(|_| {
            let xs: Vec<Vec<i32>> = (0..256).map(|_| vec![10; c::N_ROWS]).collect();
            client
                .submit(Job::MacBatch { xs, tile: None, model: None }, SubmitOpts::pinned(0))
                .unwrap()
                .typed()
        })
        .collect();
    // placement is decided at submit time from the gauges: least-loaded
    // must prefer core 1 while core 0 is deep
    let mut placed = [0usize; 2];
    let ll: Vec<Ticket<Vec<u32>>> = (0..20)
        .map(|_| {
            let t = client
                .submit(Job::Mac(vec![10; c::N_ROWS]), SubmitOpts::least_loaded())
                .unwrap();
            placed[t.core()] += 1;
            t.typed()
        })
        .collect();
    assert!(
        placed[1] >= placed[0],
        "least-loaded favored the busy core: {placed:?}"
    );
    assert!(placed[1] >= 10, "least-loaded barely used the idle core: {placed:?}");
    gather(pinned).unwrap();
    gather(ll).unwrap();
    // every depth reservation must be released once replies are gathered
    assert_eq!(client.board().in_flight(0), 0);
    assert_eq!(client.board().in_flight(1), 0);
    drop(client);
    let (_cluster, stats) = server.join();
    assert_eq!(stats.iter().map(|s| s.requests).sum::<u64>(), 4 * 256 + 20);
    assert!(stats[0].requests >= 4 * 256, "pinned batches must stay on core 0");
}

#[test]
fn out_of_band_core_is_fenced_then_rejoins_after_drain() {
    // noise-free default-sigma dies: deterministic residuals, with the
    // uncalibrated die far outside any band a calibrated die satisfies
    let mut cfg = SimConfig::default();
    cfg.sigma_noise = 0.0;
    let mut cluster = CimCluster::new(&cfg, 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());

    // pre-measure the residuals on a twin of core 1 (same seed, same
    // sample, noise-free => identical die) so the band provably
    // separates the uncalibrated and calibrated states
    let mut cfg1 = cfg.clone();
    cfg1.seed = core_seed(cfg.seed, 1);
    let mut twin = CimAnalogModel::from_sample(&cfg1, &cluster.cores[1].sample);
    let r_uncal = engine.residual_gain_error(&mut twin);
    engine.calibrate(&mut twin);
    let r_cal = engine.residual_gain_error(&mut twin);
    assert!(r_cal < r_uncal, "BISC did not improve the twin: {r_cal} vs {r_uncal}");
    let band = 0.5 * (r_cal + r_uncal);

    let server = cluster.serve_with(ServiceConfig {
        batcher: Batcher::default(),
        engine: Some(engine),
        health_band: band,
    });
    let client = server.client();

    // the health probe finds core 1 out of band and fences it
    let h = client.health(1).unwrap();
    assert_eq!(h.core, 1);
    let measured = h.residual.expect("engine is configured");
    assert!(measured > band, "uncalibrated residual {measured} inside band {band}");
    assert!(h.fenced);
    assert!(client.is_fenced(1));

    // zero jobs placed on the out-of-band die, under both policies
    let tickets: Vec<Ticket<Vec<u32>>> = (0..40)
        .map(|i| {
            let opts = if i % 2 == 0 {
                SubmitOpts::default() // round-robin
            } else {
                SubmitOpts::least_loaded()
            };
            let t = client.submit(Job::Mac(vec![30; c::N_ROWS]), opts).unwrap();
            assert_ne!(t.core(), 1, "job placed on a fenced core");
            t.typed()
        })
        .collect();
    gather(tickets).unwrap();

    // drain -> recalibrate -> rejoin
    let h = client.drain(1).unwrap();
    assert!(h.recalibrated, "drain with an engine must recalibrate");
    let post = h.residual.expect("engine is configured");
    assert!(post <= band, "post-BISC residual {post} still outside band {band}");
    assert!(!h.fenced);
    assert!(!client.is_fenced(1));

    // the rejoined core serves again (shared round-robin cursor reaches
    // every healthy core within k submissions)
    let mut served_core1 = false;
    let tickets: Vec<Ticket<Vec<u32>>> = (0..8)
        .map(|_| {
            let t = client
                .submit(Job::Mac(vec![30; c::N_ROWS]), SubmitOpts::default())
                .unwrap();
            served_core1 |= t.core() == 1;
            t.typed()
        })
        .collect();
    gather(tickets).unwrap();
    assert!(served_core1, "rejoined core never placed");

    drop(client);
    let (cluster, stats) = server.join();
    // the fenced core answered only post-rejoin traffic
    assert!(stats[1].requests <= 8, "fenced core served placed jobs: {:?}", stats[1]);
    // the in-service recalibration left a report on the core
    assert!(cluster.cores[1].report.is_some());
}

#[test]
fn drain_without_engine_reports_without_recalibrating() {
    let mut cluster = CimCluster::new(&ideal_cfg(), 2);
    deploy_uniform(&mut cluster, "demo", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
    // default serve(): no engine, lifecycle jobs degrade to state reports
    let server = cluster.serve(Batcher::default());
    let client = server.client();
    let h = client.health(0).unwrap();
    assert_eq!(h.residual, None);
    assert!(!h.recalibrated);
    assert!(!h.fenced);
    // drain fences at submit time and, with no engine, cannot rejoin
    let h = client.drain(1).unwrap();
    assert!(!h.recalibrated);
    assert!(h.fenced, "without an engine a drained core stays fenced");
    assert!(client.is_fenced(1));
    // manual unfence is the operator's escape hatch
    client.unfence(1);
    assert!(!client.is_fenced(1));
    drop(client);
    server.join();
}
