//! Model registry: named weight sets and their deployment onto cluster
//! cores. This is the multi-model serving layer — a registry owns the
//! weights for every model a cluster serves, hands out stable `u32` ids
//! (the currency of [`crate::coordinator::service::Placement::Model`],
//! wire frames, and per-model statistics), and programs cores through
//! [`crate::coordinator::cluster::CimCluster::program_core`] while
//! recording core→model residency so the scheduler can resolve
//! "any healthy core holding model M" (DESIGN.md §14).
//!
//! Panic-free by policy, like the rest of the serving scope: a registry
//! is driven by operator input (CLI model lists, wire rollouts) and must
//! answer bad input with typed errors.

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::analog::consts as c;
use crate::coordinator::batcher::ServeError;
use crate::coordinator::cluster::CimCluster;
use crate::coordinator::service::NO_MODEL;

/// The id the first registered model gets — single-model deployments
/// (every pre-registry call site) serve this model.
pub const DEFAULT_MODEL: u32 = 0;

/// One named weight set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    /// Row-major `N_ROWS × M_COLS` conductance codes.
    pub weights: Vec<i32>,
}

/// Registry of named models. Ids are the insertion index, stable for the
/// registry's lifetime; names are unique.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ModelRegistry {
    models: Vec<ModelSpec>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self { models: Vec::new() }
    }

    /// Register a named weight set and return its id. Rejects a weight
    /// matrix that does not match the array geometry, a duplicate name,
    /// and (theoretical) id exhaustion — typed errors, never a panic.
    pub fn register(&mut self, name: &str, weights: Vec<i32>) -> Result<u32, ServeError> {
        let want = c::N_ROWS * c::M_COLS;
        if weights.len() != want {
            return Err(ServeError::BadRequest { expected: want, got: weights.len() });
        }
        if self.models.iter().any(|m| m.name == name) {
            return Err(ServeError::Backend(format!("model '{name}' is already registered")));
        }
        let id = self.models.len();
        if id as u64 >= NO_MODEL as u64 {
            return Err(ServeError::Backend("model id space exhausted".to_string()));
        }
        self.models.push(ModelSpec { name: name.to_string(), weights });
        Ok(id as u32)
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Registered names, in id order (index == id) — the shape the wire
    /// `Hello` frame ships so remote clients can resolve names.
    pub fn names(&self) -> Vec<String> {
        self.models.iter().map(|m| m.name.clone()).collect()
    }

    pub fn name_of(&self, id: u32) -> Option<&str> {
        self.models.get(id as usize).map(|m| m.name.as_str())
    }

    pub fn id_of(&self, name: &str) -> Option<u32> {
        self.models.iter().position(|m| m.name == name).map(|i| i as u32)
    }

    pub fn weights(&self, id: u32) -> Option<&[i32]> {
        self.models.get(id as usize).map(|m| m.weights.as_slice())
    }

    /// Program each `(core, model)` assignment onto the cluster and
    /// record the core's residency (picked up by `serve_with` when the
    /// cluster starts serving). An unknown model or out-of-range core is
    /// a typed error; earlier assignments in the slice stay applied.
    pub fn deploy(
        &self,
        cluster: &mut CimCluster,
        assignments: &[(usize, u32)],
    ) -> Result<(), ServeError> {
        for &(core, model) in assignments {
            let weights = self
                .weights(model)
                .ok_or(ServeError::ModelNotResident { model })?
                .to_vec();
            cluster.program_core(core, &weights)?;
            cluster.set_resident(core, model);
        }
        Ok(())
    }

    /// Spread the registry over the cluster: core `k` gets model
    /// `k mod len` (every model lands on at least one core when the
    /// cluster has at least as many cores as models).
    pub fn deploy_round_robin(&self, cluster: &mut CimCluster) -> Result<(), ServeError> {
        let n = self.models.len();
        if n == 0 {
            return Err(ServeError::Backend("cannot deploy an empty registry".to_string()));
        }
        let assignments: Vec<(usize, u32)> =
            (0..cluster.len()).map(|k| (k, (k % n) as u32)).collect();
        self.deploy(cluster, &assignments)
    }

    /// Program one model onto every core (the single-model case; with
    /// more than one model registered, deploys [`DEFAULT_MODEL`]).
    pub fn deploy_all(&self, cluster: &mut CimCluster) -> Result<(), ServeError> {
        if self.models.is_empty() {
            return Err(ServeError::Backend("cannot deploy an empty registry".to_string()));
        }
        let assignments: Vec<(usize, u32)> =
            (0..cluster.len()).map(|k| (k, DEFAULT_MODEL)).collect();
        self.deploy(cluster, &assignments)
    }
}

/// One-call single-model deployment: register `name` = `weights` and
/// program it onto every core with residency recorded, so model-aware
/// placement and the rollout guards work from the first job.
pub fn deploy_uniform(
    cluster: &mut CimCluster,
    name: &str,
    weights: Vec<i32>,
) -> Result<ModelRegistry, ServeError> {
    let mut reg = ModelRegistry::new();
    reg.register(name, weights)?;
    reg.deploy_all(cluster)?;
    Ok(reg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_validates_geometry_names_and_ids() {
        let mut reg = ModelRegistry::new();
        assert_eq!(
            reg.register("short", vec![1; 3]).unwrap_err(),
            ServeError::BadRequest { expected: c::N_ROWS * c::M_COLS, got: 3 }
        );
        let a = reg.register("alpha", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
        let b = reg.register("beta", vec![33; c::N_ROWS * c::M_COLS]).unwrap();
        assert_eq!((a, b), (0, 1));
        assert!(matches!(
            reg.register("alpha", vec![1; c::N_ROWS * c::M_COLS]),
            Err(ServeError::Backend(_))
        ));
        assert_eq!(reg.id_of("beta"), Some(1));
        assert_eq!(reg.name_of(0), Some("alpha"));
        assert_eq!(reg.name_of(9), None);
        assert_eq!(reg.names(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(reg.weights(1).map(|w| w[0]), Some(33));
    }

    #[test]
    fn deploy_rejects_unknown_models_and_bad_cores() {
        let mut cluster = CimCluster::new(&crate::config::SimConfig::default(), 2);
        let mut reg = ModelRegistry::new();
        reg.register("alpha", vec![40; c::N_ROWS * c::M_COLS]).unwrap();
        assert_eq!(
            reg.deploy(&mut cluster, &[(0, 7)]).unwrap_err(),
            ServeError::ModelNotResident { model: 7 }
        );
        assert!(reg.deploy(&mut cluster, &[(5, 0)]).is_err());
        reg.deploy(&mut cluster, &[(0, 0), (1, 0)]).unwrap();
    }
}
