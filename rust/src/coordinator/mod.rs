//! L3 coordinator: the CIM device register file, the BISC calibration
//! engine, compute-SNR evaluation, the DNN tile scheduler, the batching
//! request loop, the multi-core sharded serving cluster, and the TCP
//! wire front-end over it (paper Sections III, VI, VII + the multi-array
//! scaling direction).

pub mod bisc;
pub mod cim_core;
pub mod snr;
pub mod dnn;
pub mod batcher;
pub mod service;
pub mod cluster;
pub mod wire;
