//! L3 coordinator: the CIM device register file, the BISC calibration
//! engine, compute-SNR evaluation, the DNN tile scheduler, and the batching
//! request loop (paper Sections III, VI, VII).

pub mod bisc;
pub mod cim_core;
pub mod snr;
pub mod dnn;
pub mod batcher;
