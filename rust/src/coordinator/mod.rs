//! L3 coordinator: the CIM device register file, the BISC calibration
//! engine, compute-SNR evaluation, the DNN tile scheduler, the batching
//! request loop, the multi-core sharded serving cluster, the TCP wire
//! front-end over it, and the autonomous recalibration daemon that
//! closes the paper's self-calibration loop under drift (paper Sections
//! III, VI, VII + the multi-array scaling direction).

pub mod bisc;
pub mod cim_core;
pub mod snr;
pub mod dnn;
pub mod batcher;
pub mod service;
pub mod cluster;
pub mod registry;
pub mod wire;
pub mod calibrator;
