//! Unified serving API: one typed job envelope and one `submit` entry
//! point for everything the CIM serving layer does — single MACs, native
//! batches, core drain/recalibration, and health probes. This replaces
//! the old `mac`/`mac_on`/`submit`/`submit_on`/`mac_pipelined` method zoo
//! (see DESIGN.md §8 for the migration table).
//!
//! Layers:
//! * [`Job`] + [`SubmitOpts`] — what to run and how (priority, deadline,
//!   placement policy);
//! * [`Ticket`] — the typed handle for one submitted job; `wait` blocks
//!   for the reply, [`gather`] drains a whole fan-out deterministically
//!   (every in-flight reply is consumed even when one errors);
//! * [`CoreBoard`] — shared scheduler state: per-core in-flight depth
//!   gauges (for [`Placement::LeastLoaded`]) and per-core health fencing
//!   (a fenced core receives no placed jobs until it rejoins via
//!   [`Job::Drain`]);
//! * [`CimService`] — the service trait both the single-core
//!   [`crate::coordinator::batcher::Client`] and the multi-core
//!   [`crate::coordinator::cluster::ClusterClient`] implement; all the
//!   convenience entry points (`mac`, `mac_batch`, `drain`, `health`,
//!   `mac_pipelined`) are provided methods over `submit`.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::coordinator::batcher::{BatcherStats, ModelStats, ServeError};
use crate::coordinator::bisc::BiscEngine;
use crate::util::sync::lock_unpoisoned;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Lowest urgency: yields to everything else queued on the core.
pub const PRI_LOW: u8 = 0;
/// Default urgency.
pub const PRI_NORMAL: u8 = 100;
/// Jumps ahead of normal traffic on the worker's priority queue.
pub const PRI_HIGH: u8 = 200;

/// Selects one pre-folded tile from a core's installed
/// [`crate::coordinator::cluster::TileBank`] (DNN serving path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileRef {
    /// bank layer index (0-based)
    pub layer: usize,
    /// row-tile index
    pub tr: usize,
    /// column-tile index
    pub tc: usize,
}

/// One typed request to the serving layer.
#[derive(Debug, Clone, PartialEq)]
pub enum Job {
    /// One MAC over the core's currently programmed weights. The worker
    /// may coalesce adjacent `Mac` jobs of equal standing into one
    /// backend batch.
    Mac(Vec<i32>),
    /// A client-built batch executed natively: one channel round-trip and
    /// one backend call for the whole batch instead of N. With `tile`
    /// set, the batch runs against that pre-folded tile of the core's
    /// tile bank instead of the programmed weights.
    MacBatch {
        xs: Vec<Vec<i32>>,
        tile: Option<TileRef>,
        /// With `Some(model)`, the worker rejects the batch unless that
        /// model is resident on the serving core at admission time
        /// ([`ServeError::WrongModel`]) — the guard that catches a
        /// placement decision raced by a concurrent rollout.
        model: Option<u32>,
    },
    /// Drain-and-recalibrate lifecycle step: queued work ahead of it
    /// completes, then the worker recalibrates its die (when the service
    /// was configured with a [`BiscEngine`]) and the core rejoins the
    /// scheduler if its residual is back in band.
    Drain,
    /// Hot model rollout: a [`Job::Drain`]-style barrier (queued work
    /// ahead of it completes first — zero dropped jobs), then the worker
    /// reprograms its die with `weights`, records `model` as the core's
    /// residency on the board, recalibrates (when an engine is
    /// configured), and rejoins if the residual lands back in band.
    Rollout { model: u32, weights: Vec<i32> },
    /// Measure the core's BISC residual; a residual out of band fences
    /// the core (the scheduler stops placing jobs on it).
    Health,
    /// Hard-fault injection (chaos testing / degraded-mode drills): a
    /// [`Job::Drain`]-style barrier — queued work ahead of it completes
    /// untouched — then the worker strikes its die with the compact
    /// fault-plan spec (see `analog::faults::FaultPlan::parse`). Events
    /// scheduled at a MAC count arm against the core's served-MAC
    /// counter; immediate events weld before the next job runs.
    Faults(String),
}

impl Job {
    /// Scheduler weight of this job in the in-flight depth gauges
    /// (batches weigh their member count so `LeastLoaded` sees them).
    pub fn weight(&self) -> usize {
        match self {
            Job::Mac(_) => 1,
            Job::MacBatch { xs, .. } => xs.len().max(1),
            Job::Drain | Job::Rollout { .. } | Job::Health | Job::Faults(_) => 1,
        }
    }
}

/// Which core a job may be placed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Next healthy core off the shared rotating cursor.
    #[default]
    RoundRobin,
    /// Healthy core with the smallest in-flight depth gauge.
    LeastLoaded,
    /// Exactly this core — the only placement that ignores fencing
    /// (required so `Drain`/`Health` can reach a fenced core).
    Pinned(usize),
    /// Any healthy core holding `model` (and, with `tile` set, holding
    /// that pre-folded tile of it) per the board's residency records.
    /// With a tile the pick is deterministic over the healthy holders
    /// (same residency + fence state → same core); without one it
    /// round-robins across the holders. No healthy holder resolves to
    /// [`ServeError::ModelNotResident`] (model nowhere on the cluster)
    /// or [`ServeError::NoHealthyCore`] (resident but all holders
    /// fenced) — typed errors, never a panic.
    Model { model: u32, tile: Option<TileRef> },
}

/// Per-submit options: urgency, latency budget, and placement policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubmitOpts {
    /// Higher runs sooner on the worker's priority queue ([`PRI_NORMAL`]
    /// by default); ties keep submission order.
    pub priority: u8,
    /// Relative latency budget. A job still queued when it expires is
    /// answered with [`ServeError::DeadlineExceeded`] instead of running.
    pub deadline: Option<Duration>,
    pub placement: Placement,
}

impl Default for SubmitOpts {
    fn default() -> Self {
        Self { priority: PRI_NORMAL, deadline: None, placement: Placement::RoundRobin }
    }
}

impl SubmitOpts {
    /// Pin to one core (ignores fencing — see [`Placement::Pinned`]).
    pub fn pinned(core: usize) -> Self {
        Self { placement: Placement::Pinned(core), ..Self::default() }
    }

    /// Place on the least-loaded healthy core.
    pub fn least_loaded() -> Self {
        Self { placement: Placement::LeastLoaded, ..Self::default() }
    }

    /// Place on any healthy core holding `model` (and `tile` of it, when
    /// given) — see [`Placement::Model`].
    pub fn for_model(model: u32, tile: Option<TileRef>) -> Self {
        Self { placement: Placement::Model { model, tile }, ..Self::default() }
    }

    /// Set the urgency ([`PRI_NORMAL`] by default); higher runs sooner
    /// on the worker's priority queue, ties keep submission order.
    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// Set the relative latency budget; a job still queued when it
    /// expires is answered with [`ServeError::DeadlineExceeded`].
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Set the placement policy ([`Placement::RoundRobin`] by default).
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }
}

/// Health snapshot of one core, as reported by `Drain`/`Health` jobs.
#[derive(Debug, Clone, PartialEq)]
pub struct CoreHealth {
    pub core: usize,
    /// Mean per-line |g_tot - 1| from a fresh characterization; `None`
    /// when the service has no [`BiscEngine`] or the backend cannot
    /// characterize itself.
    pub residual: Option<f64>,
    /// Whether the core is fenced after this probe.
    pub fenced: bool,
    /// Whether a recalibration actually ran (`Drain` with an engine).
    pub recalibrated: bool,
    /// The server-observed recalibration epoch ([`CoreBoard::recal_epoch`])
    /// AFTER this probe. Carrying it in every lifecycle reply lets a
    /// remote mirror catch up on drains it never requested — e.g. the
    /// calibrator daemon recalibrating a core behind a client's back.
    pub recal_epoch: u64,
    /// Model resident on the core AFTER this probe (`None` when nothing
    /// is programmed). Lets a remote mirror track rollouts it never
    /// requested, the same way `recal_epoch` tracks foreign drains.
    pub model: Option<u32>,
    /// Whether the core is retired: the drain barrier's fault classifier
    /// found permanent (un-calibratable) hard faults, so the core is
    /// fenced for good and can never rejoin ([`CoreBoard::retire`]).
    pub retired: bool,
    /// Per-column permanent-fault bitmask measured by the classifier
    /// (bit `col`); 0 on a healthy core.
    pub fault_mask: u32,
}

/// The typed reply to one [`Job`].
#[derive(Debug, Clone, PartialEq)]
pub enum JobReply {
    Mac(Vec<u32>),
    MacBatch(Vec<Vec<u32>>),
    Health(CoreHealth),
}

/// Conversion from the untyped reply to the payload a [`Ticket`] carries.
pub trait FromReply: Sized {
    fn from_reply(reply: JobReply) -> Result<Self, ServeError>;
}

impl FromReply for JobReply {
    fn from_reply(reply: JobReply) -> Result<Self, ServeError> {
        Ok(reply)
    }
}

impl FromReply for Vec<u32> {
    fn from_reply(reply: JobReply) -> Result<Self, ServeError> {
        match reply {
            JobReply::Mac(q) => Ok(q),
            other => Err(reply_type_mismatch("Mac", &other)),
        }
    }
}

impl FromReply for Vec<Vec<u32>> {
    fn from_reply(reply: JobReply) -> Result<Self, ServeError> {
        match reply {
            JobReply::MacBatch(q) => Ok(q),
            other => Err(reply_type_mismatch("MacBatch", &other)),
        }
    }
}

impl FromReply for CoreHealth {
    fn from_reply(reply: JobReply) -> Result<Self, ServeError> {
        match reply {
            JobReply::Health(h) => Ok(h),
            other => Err(reply_type_mismatch("Health", &other)),
        }
    }
}

fn reply_type_mismatch(want: &str, got: &JobReply) -> ServeError {
    let got = match got {
        JobReply::Mac(_) => "Mac",
        JobReply::MacBatch(_) => "MacBatch",
        JobReply::Health(_) => "Health",
    };
    ServeError::Backend(format!("reply type mismatch: expected {want}, got {got}"))
}

/// A reply tagged with its request id and serving core, routed onto a
/// shared fan-in channel (one per wire connection) instead of a per-job
/// channel — the delivery form behind [`ReplySink::Routed`].
pub struct RoutedReply {
    pub id: u64,
    pub core: usize,
    pub result: Result<JobReply, ServeError>,
}

/// The routed-reply sender a wire connection hands to workers: the
/// fan-in channel plus an optional poller wakeup. Workers finishing a
/// job send the reply and then nudge the event loop (which sleeps in
/// `poll(2)` and cannot watch an mpsc channel) so the reply flushes to
/// the socket promptly instead of on the next poll timeout.
#[derive(Clone)]
pub struct RoutedTx {
    tx: Sender<RoutedReply>,
    waker: Option<crate::util::wake::WakeHandle>,
}

impl RoutedTx {
    /// A sender with no waker — for callers that drain the receiver from
    /// a dedicated thread (blocking `recv`) rather than an event loop.
    pub fn new(tx: Sender<RoutedReply>) -> Self {
        Self { tx, waker: None }
    }

    /// A sender that nudges `waker` after every delivery.
    pub fn with_waker(tx: Sender<RoutedReply>, waker: crate::util::wake::WakeHandle) -> Self {
        Self { tx, waker: Some(waker) }
    }

    /// Deliver one routed reply. A receiver that has gone away is not an
    /// error for the worker — the job was already executed either way.
    pub fn send(&self, reply: RoutedReply) {
        let _ = self.tx.send(reply);
        if let Some(w) = &self.waker {
            w.wake();
        }
    }
}

/// Where a worker delivers one job's reply. `Channel` is the in-process
/// form ([`Ticket`] holds the other end); `Routed` fans many jobs into
/// one shared channel with request-id correlation, so a wire connection
/// can stream out-of-order completions without a waiter thread per job.
pub enum ReplySink {
    Channel(Sender<Result<JobReply, ServeError>>),
    Routed {
        id: u64,
        core: usize,
        tx: RoutedTx,
    },
}

impl ReplySink {
    /// Deliver the reply. A receiver that has gone away is not an error
    /// for the worker — the job was already executed either way.
    pub fn send(self, result: Result<JobReply, ServeError>) {
        match self {
            ReplySink::Channel(tx) => {
                let _ = tx.send(result);
            }
            ReplySink::Routed { id, core, tx } => {
                tx.send(RoutedReply { id, core, result });
            }
        }
    }
}

/// The envelope a worker receives: the job plus its scheduling metadata
/// and the per-job reply sink.
pub struct JobEnvelope {
    pub job: Job,
    pub priority: u8,
    /// absolute expiry instant (converted from the relative budget at
    /// submit time)
    pub deadline: Option<Instant>,
    /// depth-gauge weight reserved at submit time ([`Job::weight`])
    pub weight: usize,
    pub reply: ReplySink,
}

/// Handle for one submitted job. `T` is the typed payload
/// ([`JobReply`] for the untyped form straight out of `submit`).
pub struct Ticket<T> {
    rx: Receiver<Result<JobReply, ServeError>>,
    core: usize,
    _t: PhantomData<fn() -> T>,
}

impl<T: FromReply> Ticket<T> {
    pub fn new(rx: Receiver<Result<JobReply, ServeError>>, core: usize) -> Self {
        Self { rx, core, _t: PhantomData }
    }

    /// The core this job was placed on (fixed at submit time — the DNN
    /// gather path uses it to pick that core's digital trims).
    pub fn core(&self) -> usize {
        self.core
    }

    /// Re-type the handle (e.g. `Ticket<JobReply>` -> `Ticket<Vec<u32>>`
    /// after submitting a `Job::Mac`).
    pub fn typed<U: FromReply>(self) -> Ticket<U> {
        Ticket { rx: self.rx, core: self.core, _t: PhantomData }
    }

    /// Block for the reply. A worker that shut down mid-flight surfaces
    /// as [`ServeError::Disconnected`], never a panic.
    pub fn wait(self) -> Result<T, ServeError> {
        let reply = self.rx.recv().map_err(|_| ServeError::Disconnected)?;
        T::from_reply(reply?)
    }
}

/// Gather a whole fan-out: every ticket is drained even when one errors
/// (so worker stats and reply channels settle deterministically), and the
/// first error — if any — is returned after the drain. On success the
/// payloads come back in ticket order, each tagged with its serving core.
pub fn gather<T: FromReply>(tickets: Vec<Ticket<T>>) -> Result<Vec<(usize, T)>, ServeError> {
    let mut out = Vec::with_capacity(tickets.len());
    let mut first_err: Option<ServeError> = None;
    for t in tickets {
        let core = t.core();
        match t.wait() {
            Ok(v) => out.push((core, v)),
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

/// Sentinel in the board's lock-free model column for "nothing resident"
/// (never a valid [`crate::coordinator::registry::ModelRegistry`] id —
/// the registry caps ids far below it).
pub const NO_MODEL: u32 = u32::MAX;

/// One core's model residency: which model's weights are programmed on
/// the die and which pre-folded tiles of that model the core holds.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Residency {
    pub model: u32,
    pub tiles: Vec<TileRef>,
}

/// Shared scheduler state between clients and workers: per-core in-flight
/// depth gauges, health fences, recalibration epochs, and model
/// residency.
pub struct CoreBoard {
    depth: Vec<AtomicUsize>,
    fenced: Vec<AtomicBool>,
    /// Permanently fenced: the drain barrier's fault classifier found
    /// hard faults calibration cannot trim out. A retired core stays
    /// fenced forever — [`CoreBoard::unfence`] refuses to clear it.
    retired: Vec<AtomicBool>,
    /// Per-column permanent-fault bitmask (bit `col`) measured by the
    /// classifier when the core was retired; 0 on a healthy core.
    fault_mask: Vec<AtomicU32>,
    recal_epoch: Vec<AtomicU64>,
    /// Resident model per core ([`NO_MODEL`] = nothing programmed).
    /// Lock-free so hot-path placement and per-request model accounting
    /// never take a lock.
    model: Vec<AtomicU32>,
    /// Tiles of the resident model each core holds; the mutex is only
    /// touched when a placement names a tile or residency changes.
    tiles: Vec<Mutex<Vec<TileRef>>>,
}

impl CoreBoard {
    pub fn new(cores: usize) -> Self {
        assert!(cores > 0, "a service needs at least one core");
        Self {
            depth: (0..cores).map(|_| AtomicUsize::new(0)).collect(),
            fenced: (0..cores).map(|_| AtomicBool::new(false)).collect(),
            retired: (0..cores).map(|_| AtomicBool::new(false)).collect(),
            fault_mask: (0..cores).map(|_| AtomicU32::new(0)).collect(),
            recal_epoch: (0..cores).map(|_| AtomicU64::new(0)).collect(),
            model: (0..cores).map(|_| AtomicU32::new(NO_MODEL)).collect(),
            tiles: (0..cores).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    pub fn cores(&self) -> usize {
        self.depth.len()
    }

    /// Jobs (weighted, see [`Job::weight`]) currently placed on `core`
    /// and not yet answered. Out-of-range cores read as idle — every
    /// accessor here degrades to a no-op/neutral answer instead of
    /// panicking, keeping the board safe against wire-supplied indices.
    pub fn in_flight(&self, core: usize) -> usize {
        self.depth.get(core).map_or(0, |d| d.load(Ordering::Relaxed))
    }

    pub fn add_in_flight(&self, core: usize, weight: usize) {
        if let Some(d) = self.depth.get(core) {
            d.fetch_add(weight, Ordering::Relaxed);
        }
    }

    pub fn sub_in_flight(&self, core: usize, weight: usize) {
        if let Some(d) = self.depth.get(core) {
            d.fetch_sub(weight, Ordering::Relaxed);
        }
    }

    /// Stop placing new jobs on `core` (pinned jobs still go through).
    pub fn fence(&self, core: usize) {
        if let Some(f) = self.fenced.get(core) {
            f.store(true, Ordering::Relaxed);
        }
    }

    /// Let `core` rejoin the scheduler. A retired core never rejoins —
    /// its fence is permanent and this call is a no-op.
    pub fn unfence(&self, core: usize) {
        if self.is_retired(core) {
            return;
        }
        if let Some(f) = self.fenced.get(core) {
            f.store(false, Ordering::Relaxed);
        }
    }

    /// Out-of-range cores read as fenced: the scheduler must never
    /// place on an index the board does not track.
    pub fn is_fenced(&self, core: usize) -> bool {
        self.fenced.get(core).is_none_or(|f| f.load(Ordering::Relaxed))
    }

    /// Permanently fence `core`: record the classifier's per-column
    /// fault mask, mark it retired, and fence it. [`CoreBoard::unfence`]
    /// refuses retired cores, so after this call no placement policy
    /// ever selects `core` again; [`place`] resolves `Placement::Model`
    /// around it via the surviving healthy holders, which is how DNN
    /// tiles remap off a dying die.
    pub fn retire(&self, core: usize, mask: u32) {
        if let Some(m) = self.fault_mask.get(core) {
            m.store(mask, Ordering::Relaxed);
        }
        if let Some(r) = self.retired.get(core) {
            r.store(true, Ordering::Relaxed);
        }
        self.fence(core);
    }

    /// Out-of-range cores read as retired, mirroring [`CoreBoard::is_fenced`].
    pub fn is_retired(&self, core: usize) -> bool {
        self.retired.get(core).is_none_or(|r| r.load(Ordering::Relaxed))
    }

    /// The per-column permanent-fault bitmask recorded at retirement
    /// (0: healthy, unclassified, or out of range).
    pub fn fault_mask(&self, core: usize) -> u32 {
        self.fault_mask.get(core).map_or(0, |m| m.load(Ordering::Relaxed))
    }

    /// Number of cores currently accepting placed jobs.
    pub fn healthy_cores(&self) -> usize {
        self.fenced.iter().filter(|f| !f.load(Ordering::Relaxed)).count()
    }

    /// Number of in-service recalibrations (`Drain`) this core has
    /// completed since serving started. Gather-side schedules carry the
    /// epoch their per-core digital corrections were measured at
    /// (`CoreCorrections::epoch` in the DNN scheduler) — corrections
    /// lagging this value are stale.
    pub fn recal_epoch(&self, core: usize) -> u64 {
        self.recal_epoch.get(core).map_or(0, |e| e.load(Ordering::Relaxed))
    }

    /// Record a completed in-service recalibration (worker side).
    pub fn bump_recal_epoch(&self, core: usize) {
        if let Some(e) = self.recal_epoch.get(core) {
            e.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Catch a mirror board up to a server-observed epoch (monotonic:
    /// an older reply arriving late can never roll the epoch back).
    pub fn set_recal_epoch(&self, core: usize, epoch: u64) {
        if let Some(e) = self.recal_epoch.get(core) {
            e.fetch_max(epoch, Ordering::Relaxed);
        }
    }

    /// Record that `core` now serves `model` holding `tiles`. Tiles are
    /// stored before the model id is published so a concurrent
    /// [`CoreBoard::holds`] never sees the new model with stale tiles.
    /// Out of range is a no-op, like every accessor here.
    pub fn set_residency(&self, core: usize, model: u32, tiles: Vec<TileRef>) {
        if let (Some(slot), Some(m)) = (self.tiles.get(core), self.model.get(core)) {
            *lock_unpoisoned(slot) = tiles;
            m.store(model, Ordering::Release);
        }
    }

    /// Forget `core`'s residency (nothing programmed / decommissioned).
    pub fn clear_residency(&self, core: usize) {
        if let (Some(slot), Some(m)) = (self.tiles.get(core), self.model.get(core)) {
            m.store(NO_MODEL, Ordering::Release);
            lock_unpoisoned(slot).clear();
        }
    }

    /// Model resident on `core` (`None`: nothing recorded, or the index
    /// is out of range).
    pub fn resident_model(&self, core: usize) -> Option<u32> {
        let m = self.model.get(core)?.load(Ordering::Acquire);
        (m != NO_MODEL).then_some(m)
    }

    /// Whether `core` holds `model` — and, when `tile` is named, that
    /// pre-folded tile of it. Out-of-range cores hold nothing.
    pub fn holds(&self, core: usize, model: u32, tile: Option<&TileRef>) -> bool {
        if self.resident_model(core) != Some(model) {
            return false;
        }
        match tile {
            None => true,
            Some(t) => self.tiles.get(core).is_some_and(|slot| lock_unpoisoned(slot).contains(t)),
        }
    }

    /// Snapshot every core's residency (the wire `Hello` frame's shape).
    pub fn residency_snapshot(&self) -> Vec<Option<Residency>> {
        (0..self.cores())
            .map(|core| {
                self.resident_model(core).map(|model| Residency {
                    model,
                    tiles: self
                        .tiles
                        .get(core)
                        .map(|slot| lock_unpoisoned(slot).clone())
                        .unwrap_or_default(),
                })
            })
            .collect()
    }
}

/// Deterministic tile→slot index for [`Placement::Model`] with a tile:
/// the same tile always maps to the same position among the healthy
/// holders, so repeat submissions of one tile land on one core (keeping
/// that core's folded-tile cache and digital trims hot) while distinct
/// tiles spread across the holders.
fn tile_slot(t: &TileRef) -> usize {
    t.layer.wrapping_mul(131_071).wrapping_add(t.tr.wrapping_mul(511)).wrapping_add(t.tc)
}

/// Resolve a placement policy against the board. Fenced cores are skipped
/// by `RoundRobin`/`LeastLoaded`; `Pinned` always resolves (panics on an
/// out-of-range core index — a programmer error, not a runtime state).
pub fn place(
    board: &CoreBoard,
    rr: &AtomicUsize,
    placement: Placement,
) -> Result<usize, ServeError> {
    let k = board.cores();
    match placement {
        Placement::Pinned(core) => {
            assert!(core < k, "pinned core {core} out of range (cluster has {k})");
            Ok(core)
        }
        Placement::RoundRobin => {
            // snapshot the cursor once, then probe k DISTINCT cores from
            // it — probing fetch_add k times can alias to the same fenced
            // core under concurrent submitters and spuriously report
            // NoHealthyCore while healthy cores sit idle
            let start = rr.fetch_add(1, Ordering::Relaxed);
            for i in 0..k {
                let core = start.wrapping_add(i) % k;
                if !board.is_fenced(core) {
                    return Ok(core);
                }
            }
            Err(ServeError::NoHealthyCore)
        }
        Placement::LeastLoaded => (0..k)
            .filter(|&c| !board.is_fenced(c))
            .min_by_key(|&c| board.in_flight(c))
            .ok_or(ServeError::NoHealthyCore),
        Placement::Model { model, tile } => {
            // two passes, no allocation: count the healthy holders, then
            // scan to the picked one. Residency/fences can move between
            // the passes — the fallthrough returns a typed error, and the
            // batcher's admission check (Job::MacBatch.model) catches any
            // placement a concurrent rollout raced.
            let mut resident_anywhere = 0usize;
            let mut healthy_holders = 0usize;
            for core in 0..k {
                if board.holds(core, model, tile.as_ref()) {
                    resident_anywhere += 1;
                    if !board.is_fenced(core) {
                        healthy_holders += 1;
                    }
                }
            }
            if healthy_holders == 0 {
                return if resident_anywhere == 0 {
                    Err(ServeError::ModelNotResident { model })
                } else {
                    Err(ServeError::NoHealthyCore)
                };
            }
            let pick = match tile.as_ref() {
                Some(t) => tile_slot(t),
                None => rr.fetch_add(1, Ordering::Relaxed),
            } % healthy_holders;
            let mut seen = 0usize;
            for core in 0..k {
                if board.holds(core, model, tile.as_ref()) && !board.is_fenced(core) {
                    if seen == pick {
                        return Ok(core);
                    }
                    seen += 1;
                }
            }
            Err(ServeError::NoHealthyCore)
        }
    }
}

/// Reserve depth + envelope + send to one core's worker: the tail every
/// submission path shares once placement has been resolved.
fn dispatch(
    txs: &[Sender<JobEnvelope>],
    board: &CoreBoard,
    core: usize,
    job: Job,
    opts: SubmitOpts,
    reply: ReplySink,
) -> Result<(), ServeError> {
    let weight = job.weight();
    board.add_in_flight(core, weight);
    let env = JobEnvelope {
        job,
        priority: opts.priority,
        deadline: opts.deadline.map(|d| Instant::now() + d),
        weight,
        reply,
    };
    // a missing channel (core index out of range) reads as a worker that
    // already hung up — same Disconnected answer, no panic
    let sent = txs.get(core).is_some_and(|tx| tx.send(env).is_ok());
    if !sent {
        board.sub_in_flight(core, weight);
        return Err(ServeError::Disconnected);
    }
    Ok(())
}

/// Place + reserve depth + send: the one submission path shared by every
/// [`CimService`] implementation.
pub fn submit_to(
    txs: &[Sender<JobEnvelope>],
    board: &CoreBoard,
    rr: &AtomicUsize,
    job: Job,
    opts: SubmitOpts,
) -> Result<Ticket<JobReply>, ServeError> {
    let core = place(board, rr, opts.placement)?;
    let (reply_tx, reply_rx) = channel();
    dispatch(txs, board, core, job, opts, ReplySink::Channel(reply_tx))?;
    Ok(Ticket::new(reply_rx, core))
}

/// `submit_to` with a routed reply sink: the reply lands on `tx` tagged
/// with `id` and the serving core (returned). The wire front-end's fan-in
/// path — one shared channel per connection, many jobs in flight, replies
/// streamed in completion order.
pub fn submit_routed_to(
    txs: &[Sender<JobEnvelope>],
    board: &CoreBoard,
    rr: &AtomicUsize,
    job: Job,
    opts: SubmitOpts,
    id: u64,
    tx: &RoutedTx,
) -> Result<usize, ServeError> {
    let core = place(board, rr, opts.placement)?;
    dispatch(txs, board, core, job, opts, ReplySink::Routed { id, core, tx: tx.clone() })?;
    Ok(core)
}

/// Cloneable client over a set of worker channels — THE [`CimService`]
/// implementation, shared by the multi-core cluster (re-exported as
/// `ClusterClient`) and the stand-alone single-worker case (re-exported
/// as the batcher's `Client`). Clones cooperate through the shared
/// round-robin cursor and [`CoreBoard`].
#[derive(Clone)]
pub struct ServiceClient {
    txs: Vec<Sender<JobEnvelope>>,
    rr: Arc<AtomicUsize>,
    board: Arc<CoreBoard>,
}

impl ServiceClient {
    /// Client with a fresh round-robin cursor (its clones share it).
    pub fn new(txs: Vec<Sender<JobEnvelope>>, board: Arc<CoreBoard>) -> Self {
        Self::with_cursor(txs, board, Arc::new(AtomicUsize::new(0)))
    }

    /// Client sharing an existing cursor — a server handing out many
    /// clients passes the same cursor so they all cooperate.
    pub fn with_cursor(
        txs: Vec<Sender<JobEnvelope>>,
        board: Arc<CoreBoard>,
        rr: Arc<AtomicUsize>,
    ) -> Self {
        assert_eq!(txs.len(), board.cores(), "one request channel per board core");
        Self { txs, rr, board }
    }
}

impl CimService for ServiceClient {
    fn board(&self) -> &CoreBoard {
        &self.board
    }

    fn submit(&self, job: Job, opts: SubmitOpts) -> Result<Ticket<JobReply>, ServeError> {
        submit_to(&self.txs, &self.board, &self.rr, job, opts)
    }
}

impl ServiceClient {
    /// Submit with a routed reply sink instead of a per-job channel: the
    /// worker's reply lands on `tx` tagged with `id` and the serving core
    /// (see [`submit_routed_to`]). Used by the TCP front-end so one
    /// connection can stream many in-flight replies out of order.
    pub fn submit_routed(
        &self,
        job: Job,
        opts: SubmitOpts,
        id: u64,
        tx: &RoutedTx,
    ) -> Result<usize, ServeError> {
        submit_routed_to(&self.txs, &self.board, &self.rr, job, opts, id, tx)
    }
}

/// Per-worker context: which core this worker is, the shared board it
/// reports depth/health to, and the calibration engine + residual band
/// that give `Drain`/`Health` their meaning.
pub struct CoreContext {
    pub core: usize,
    pub board: Arc<CoreBoard>,
    /// Enables `Drain` recalibration and `Health` characterization; with
    /// `None` both degrade to state reports.
    pub engine: Option<BiscEngine>,
    /// Fence when the mean per-line |g_tot - 1| exceeds this.
    pub health_band: f64,
    /// Live snapshot of the worker's [`BatcherStats`], republished every
    /// dispatch round — wire `Stats` frames and operator tooling read it
    /// without joining the worker.
    pub live: Arc<Mutex<BatcherStats>>,
    /// Live per-model serving counters of this worker, keyed by the
    /// core's resident model at admission time and republished alongside
    /// `live`. Stays empty until a model is resident.
    pub live_models: Arc<Mutex<Vec<ModelStats>>>,
}

/// Default residual band: BISC leaves well under 2% mean gain error on
/// the default die population; an uncalibrated or drifted die sits far
/// above it.
pub const DEFAULT_HEALTH_BAND: f64 = 0.05;

impl CoreContext {
    /// Context for a stand-alone single-core worker (its own board, no
    /// calibration engine).
    pub fn solo() -> Self {
        Self {
            core: 0,
            board: Arc::new(CoreBoard::new(1)),
            engine: None,
            health_band: DEFAULT_HEALTH_BAND,
            live: Arc::new(Mutex::new(BatcherStats::default())),
            live_models: Arc::new(Mutex::new(Vec::new())),
        }
    }
}

/// The unified serving surface. `submit` is the single entry point; all
/// other methods are provided conveniences over it.
pub trait CimService {
    /// Shared scheduler state (depth gauges + fences).
    fn board(&self) -> &CoreBoard;

    /// Submit one job under the given options; returns the untyped
    /// ticket (call [`Ticket::typed`] for a typed payload).
    fn submit(&self, job: Job, opts: SubmitOpts) -> Result<Ticket<JobReply>, ServeError>;

    fn cores(&self) -> usize {
        self.board().cores()
    }

    /// Administratively fence a core (no new placed jobs).
    fn fence(&self, core: usize) {
        self.board().fence(core);
    }

    /// Administratively unfence a core.
    fn unfence(&self, core: usize) {
        self.board().unfence(core);
    }

    fn is_fenced(&self, core: usize) -> bool {
        self.board().is_fenced(core)
    }

    /// Submit one MAC round-robin and wait.
    fn mac(&self, x: Vec<i32>) -> Result<Vec<u32>, ServeError> {
        self.submit(Job::Mac(x), SubmitOpts::default())?.typed::<Vec<u32>>().wait()
    }

    /// Submit one MAC pinned to `core` and wait.
    fn mac_on(&self, core: usize, x: Vec<i32>) -> Result<Vec<u32>, ServeError> {
        self.submit(Job::Mac(x), SubmitOpts::pinned(core))?.typed::<Vec<u32>>().wait()
    }

    /// Submit a native batch (one channel round-trip, one backend call)
    /// and wait.
    fn mac_batch(&self, xs: Vec<Vec<i32>>) -> Result<Vec<Vec<u32>>, ServeError> {
        self.submit(Job::MacBatch { xs, tile: None, model: None }, SubmitOpts::default())?
            .typed::<Vec<Vec<u32>>>()
            .wait()
    }

    /// Probe one core's health (characterize + fence if out of band).
    fn health(&self, core: usize) -> Result<CoreHealth, ServeError> {
        self.submit(Job::Health, SubmitOpts::pinned(core))?.typed::<CoreHealth>().wait()
    }

    /// Drain → recalibrate → rejoin: the core is fenced immediately (no
    /// new placed jobs), and the worker treats the drain as a seq
    /// BARRIER — every job admitted to the core before it completes
    /// first regardless of priority, while jobs admitted after it (only
    /// pinned ones can arrive, the fence stops placement) wait until
    /// the recalibration has run. The core rejoins the scheduler if its
    /// residual lands back inside the band.
    fn drain(&self, core: usize) -> Result<CoreHealth, ServeError> {
        self.board().fence(core);
        self.submit(Job::Drain, SubmitOpts::pinned(core))?.typed::<CoreHealth>().wait()
    }

    /// Hot model rollout on one core, through the drain barrier: the
    /// core is fenced immediately (like [`CimService::drain`]), every
    /// job admitted before the rollout completes first, then the worker
    /// reprograms the die with `weights`, records `model` as the core's
    /// residency, recalibrates, and rejoins if its residual is in band —
    /// zero dropped jobs.
    fn rollout(&self, core: usize, model: u32, weights: Vec<i32>) -> Result<CoreHealth, ServeError> {
        self.board().fence(core);
        self.submit(Job::Rollout { model, weights }, SubmitOpts::pinned(core))?
            .typed::<CoreHealth>()
            .wait()
    }

    /// Inject a hard-fault plan on one core through the drain-style
    /// barrier: every job admitted before it completes on healthy
    /// silicon, then the worker strikes the die with the events of
    /// `plan` that target this core (immediately or armed at a future
    /// served-MAC count) and keeps serving — degraded — until the
    /// calibrator notices. The core is NOT fenced: chaos drills measure
    /// how the health loop reacts, so the wound must stay live.
    fn inject_faults(&self, core: usize, plan: &str) -> Result<CoreHealth, ServeError> {
        self.submit(Job::Faults(plan.to_string()), SubmitOpts::pinned(core))?
            .typed::<CoreHealth>()
            .wait()
    }

    /// Scatter `n` MACs with up to `window` in flight, gathering every
    /// reply. On error the remaining in-flight tickets are still drained
    /// before the first error is returned.
    fn mac_pipelined<F>(&self, n: usize, window: usize, make: F) -> Result<(), ServeError>
    where
        F: FnMut(usize) -> Vec<i32>,
    {
        self.mac_pipelined_with(n, window, SubmitOpts::default(), make)
    }

    /// `mac_pipelined` with explicit submit options (placement policy,
    /// priority, deadline).
    fn mac_pipelined_with<F>(
        &self,
        n: usize,
        window: usize,
        opts: SubmitOpts,
        mut make: F,
    ) -> Result<(), ServeError>
    where
        F: FnMut(usize) -> Vec<i32>,
    {
        pipelined_gather(n, window, |i| {
            Ok(self.submit(Job::Mac(make(i)), opts)?.typed::<Vec<u32>>())
        })
    }

    /// Pipelined native batches: `jobs` batches of `batch` MACs each,
    /// with up to `window` batch jobs in flight. Same drain-on-error
    /// semantics as [`CimService::mac_pipelined`].
    fn mac_batch_pipelined<F>(
        &self,
        jobs: usize,
        batch: usize,
        window: usize,
        opts: SubmitOpts,
        mut make: F,
    ) -> Result<(), ServeError>
    where
        F: FnMut(usize) -> Vec<i32>,
    {
        pipelined_gather(jobs, window, |j| {
            let xs: Vec<Vec<i32>> = (0..batch).map(|i| make(j * batch + i)).collect();
            Ok(self
                .submit(Job::MacBatch { xs, tile: None, model: None }, opts)?
                .typed::<Vec<Vec<u32>>>())
        })
    }
}

/// Shared windowed submit/gather loop behind the pipelined conveniences:
/// keeps up to `window` tickets in flight, and on any error stops
/// submitting but still drains every in-flight reply before returning
/// the first error (worker stats and reply channels settle
/// deterministically).
fn pipelined_gather<T: FromReply>(
    n: usize,
    window: usize,
    mut submit: impl FnMut(usize) -> Result<Ticket<T>, ServeError>,
) -> Result<(), ServeError> {
    let mut inflight: std::collections::VecDeque<Ticket<T>> = std::collections::VecDeque::new();
    let mut first_err: Option<ServeError> = None;
    for i in 0..n {
        match submit(i) {
            Ok(t) => inflight.push_back(t),
            Err(e) => {
                first_err = Some(e);
                break;
            }
        }
        if inflight.len() >= window.max(1) {
            let Some(t) = inflight.pop_front() else { break };
            if let Err(e) = t.wait() {
                first_err = Some(e);
                break;
            }
        }
    }
    // drain every remaining in-flight reply regardless of errors
    for t in inflight {
        if let Err(e) = t.wait() {
            if first_err.is_none() {
                first_err = Some(e);
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_skips_fenced_cores() {
        let board = CoreBoard::new(3);
        let rr = AtomicUsize::new(0);
        board.fence(1);
        for _ in 0..6 {
            let c = place(&board, &rr, Placement::RoundRobin).unwrap();
            assert_ne!(c, 1, "round robin placed on a fenced core");
        }
        // least-loaded: core 2 busier than core 0
        board.add_in_flight(2, 5);
        assert_eq!(place(&board, &rr, Placement::LeastLoaded).unwrap(), 0);
        // pinned ignores the fence (drain path)
        assert_eq!(place(&board, &rr, Placement::Pinned(1)).unwrap(), 1);
        // everything fenced -> NoHealthyCore
        board.fence(0);
        board.fence(2);
        assert_eq!(
            place(&board, &rr, Placement::RoundRobin).unwrap_err(),
            ServeError::NoHealthyCore
        );
        assert_eq!(
            place(&board, &rr, Placement::LeastLoaded).unwrap_err(),
            ServeError::NoHealthyCore
        );
        assert_eq!(board.healthy_cores(), 0);
    }

    #[test]
    fn least_loaded_tracks_depth_gauges() {
        let board = CoreBoard::new(2);
        let rr = AtomicUsize::new(0);
        board.add_in_flight(0, 3);
        assert_eq!(place(&board, &rr, Placement::LeastLoaded).unwrap(), 1);
        board.add_in_flight(1, 7);
        assert_eq!(place(&board, &rr, Placement::LeastLoaded).unwrap(), 0);
        board.sub_in_flight(1, 7);
        assert_eq!(board.in_flight(1), 0);
    }

    #[test]
    fn job_weight_counts_batch_members() {
        assert_eq!(Job::Mac(vec![0; 4]).weight(), 1);
        assert_eq!(
            Job::MacBatch { xs: vec![vec![0; 4]; 7], tile: None, model: None }.weight(),
            7
        );
        assert_eq!(Job::Drain.weight(), 1);
        assert_eq!(Job::Rollout { model: 0, weights: vec![0; 4] }.weight(), 1);
        assert_eq!(Job::Health.weight(), 1);
        assert_eq!(Job::Faults("core=0,col=3".into()).weight(), 1);
    }

    #[test]
    fn retirement_is_a_permanent_fence() {
        let board = CoreBoard::new(3);
        let rr = AtomicUsize::new(0);
        assert!(!board.is_retired(1));
        assert_eq!(board.fault_mask(1), 0);
        board.retire(1, 0b1000_0010);
        assert!(board.is_retired(1));
        assert!(board.is_fenced(1));
        assert_eq!(board.fault_mask(1), 0b1000_0010);
        assert_eq!(board.healthy_cores(), 2);
        // the drain barrier's rejoin path cannot resurrect a retired core
        board.unfence(1);
        assert!(board.is_fenced(1), "unfence resurrected a retired core");
        // placement never selects it again
        for _ in 0..6 {
            assert_ne!(place(&board, &rr, Placement::RoundRobin).unwrap(), 1);
        }
        assert_ne!(place(&board, &rr, Placement::LeastLoaded).unwrap(), 1);
        // a merely-fenced core still rejoins — retirement is the special case
        board.fence(0);
        board.unfence(0);
        assert!(!board.is_fenced(0));
        // out-of-range degrades like is_fenced: retired, mask 0
        assert!(board.is_retired(99));
        assert_eq!(board.fault_mask(99), 0);
        board.retire(99, 0xFF); // no-op, no panic
    }

    #[test]
    fn model_placement_remaps_tiles_off_a_retired_core() {
        let board = CoreBoard::new(2);
        let rr = AtomicUsize::new(0);
        let t = TileRef { layer: 0, tr: 0, tc: 0 };
        board.set_residency(0, 7, vec![t]);
        board.set_residency(1, 7, vec![t]);
        board.retire(0, 1 << 4);
        // both cores hold the tile; only the surviving one is ever picked
        for _ in 0..4 {
            assert_eq!(place(&board, &rr, Placement::Model { model: 7, tile: Some(t) }).unwrap(), 1);
        }
    }

    #[test]
    fn model_placement_resolves_only_to_holders() {
        let board = CoreBoard::new(3);
        let rr = AtomicUsize::new(0);
        let t = TileRef { layer: 0, tr: 1, tc: 2 };
        // nothing resident -> ModelNotResident, never a panic
        assert_eq!(
            place(&board, &rr, Placement::Model { model: 7, tile: None }).unwrap_err(),
            ServeError::ModelNotResident { model: 7 }
        );
        board.set_residency(0, 7, vec![t]);
        board.set_residency(1, 7, vec![]);
        board.set_residency(2, 3, vec![t]);
        // tile-less: rotates over the two holders of model 7
        for _ in 0..4 {
            let c = place(&board, &rr, Placement::Model { model: 7, tile: None }).unwrap();
            assert!(c == 0 || c == 1);
        }
        // tile-scoped: only core 0 holds (7, t); core 2 holds t of model 3
        for _ in 0..4 {
            let c = place(&board, &rr, Placement::Model { model: 7, tile: Some(t) }).unwrap();
            assert_eq!(c, 0);
        }
        // fencing the only tile holder: resident but unhealthy
        board.fence(0);
        assert_eq!(
            place(&board, &rr, Placement::Model { model: 7, tile: Some(t) }).unwrap_err(),
            ServeError::NoHealthyCore
        );
        // unknown tile of a resident model -> ModelNotResident
        let missing = TileRef { layer: 9, tr: 0, tc: 0 };
        assert_eq!(
            place(&board, &rr, Placement::Model { model: 7, tile: Some(missing) }).unwrap_err(),
            ServeError::ModelNotResident { model: 7 }
        );
    }

    #[test]
    fn residency_accessors_degrade_out_of_range() {
        let board = CoreBoard::new(1);
        board.set_residency(5, 1, vec![]); // no-op
        board.clear_residency(5); // no-op
        assert_eq!(board.resident_model(5), None);
        assert!(!board.holds(5, 1, None));
        board.set_residency(0, 4, vec![TileRef { layer: 0, tr: 0, tc: 0 }]);
        assert_eq!(board.resident_model(0), Some(4));
        let snap = board.residency_snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].as_ref().map(|r| (r.model, r.tiles.len())), Some((4, 1)));
        board.clear_residency(0);
        assert_eq!(board.residency_snapshot(), vec![None]);
    }
}
