//! DNN tile scheduler: executes the quantized MLP on the physical 36x32
//! CIM array (paper §VII-C). Mirrors the L2 JAX graph (`model.mlp_cim`)
//! exactly: row-tiles of N=36, column-tiles of M=32, 6-bit partial sums
//! dequantized with the NOMINAL constants and accumulated digitally (the
//! RISC-V core's role), bias + ReLU + re-quantization between layers.

use crate::analog::{consts as c, CimAnalogModel, MacScratch};
use crate::config::SimConfig;
use crate::coordinator::batcher::ServeError;
use crate::coordinator::bisc::{LineFit, FAULT_DEAD_GAIN};
use crate::coordinator::cluster::TileBank;
use crate::coordinator::registry::DEFAULT_MODEL;
use crate::coordinator::service::{
    gather, CimService, Job, Placement, Residency, SubmitOpts, Ticket, TileRef,
};
use crate::data::mlp::{argmax, QuantMlp, HIDDEN};
use crate::data::synth::{Dataset, IMG_PIXELS, NUM_CLASSES};
use std::sync::{Arc, Mutex};

/// Tile counts for mapping (rows x cols) onto the array.
pub fn tile_counts(rows: usize, cols: usize) -> (usize, usize) {
    (rows.div_ceil(c::N_ROWS), cols.div_ceil(c::M_COLS))
}

/// Pre-tiled weights for one layer: `tiles[rt][ct]` is an N*M row-major
/// signed-code block (zero padded).
#[derive(Debug, Clone)]
pub struct TiledLayer {
    pub tiles: Vec<Vec<Vec<i32>>>,
    pub rows: usize,
    pub cols: usize,
}

impl TiledLayer {
    pub fn new(weights: &[i32], rows: usize, cols: usize) -> Self {
        assert_eq!(weights.len(), rows * cols);
        let (rt, ct) = tile_counts(rows, cols);
        let mut tiles = vec![vec![vec![0i32; c::N_ROWS * c::M_COLS]; ct]; rt];
        for r in 0..rows {
            for col in 0..cols {
                let (tr, tc) = (r / c::N_ROWS, col / c::M_COLS);
                let (ir, ic) = (r % c::N_ROWS, col % c::M_COLS);
                tiles[tr][tc][ir * c::M_COLS + ic] = weights[r * cols + col];
            }
        }
        Self { tiles, rows, cols }
    }

    pub fn row_tiles(&self) -> usize {
        self.tiles.len()
    }

    pub fn col_tiles(&self) -> usize {
        self.tiles[0].len()
    }
}

/// The MLP mapped onto CIM tiles.
///
/// Dynamic-range management (DESIGN.md §6): a single 36-row tile of DNN
/// weights produces MAC sums spanning only a fraction of the full-scale
/// S_max = N*63*63, so at the default ADC references the 6-bit output
/// would bury the signal in quantization. The ADC references are
/// programmable (the BISC clipping-avoidance hardware, Section VI-D-a),
/// so the scheduler calibrates a per-layer reference window to the
/// observed tile output swing — an output-side PGA, purely digital
/// bookkeeping on the RISC-V side.
pub struct CimMlp {
    pub quant: QuantMlp,
    pub layer1: TiledLayer,
    pub layer2: TiledLayer,
    /// per-layer ADC reference windows (v_l, v_h)
    pub refs1: (f64, f64),
    pub refs2: (f64, f64),
    /// digital residual compensation (RISC-V side), measured post-BISC
    pub trim1: Option<LayerTrim>,
    pub trim2: Option<LayerTrim>,
    /// zero-point subtraction (bring-up baseline): measured q at x = 0,
    /// subtracted digitally. Cheaper than BISC (no analog trimming, no
    /// gain correction) — the minimal thing any deployment does.
    pub zp1: Option<Vec<f64>>,
    pub zp2: Option<Vec<f64>>,
}

/// Per-column digital residual correction at one layer's ADC window:
/// Q_nom_est = (Q_act - eps) / g (the digital use of Eq. 9-11 on whatever
/// the analog trims could not express — cal-DAC/pot quantization, the
/// small-signal-vs-secant gain difference).
#[derive(Debug, Clone)]
pub struct LayerTrim {
    pub g: Vec<f64>,
    pub eps: Vec<f64>,
}

/// Variance-aware column placement for one core (DESIGN.md §16):
/// `perm[l] = p` maps logical tile column `l` onto physical array column
/// `p`; `inv` is the inverse map. The core's
/// [`TileBank`] folds tiles with the permutation applied and the worker
/// un-permutes every tile reply, so the gather side always sees logical
/// column order — a plan only decides WHICH physical column serves each
/// logical one. Built per core by [`ColumnPlan::from_scores`]: the most
/// important logical columns (by aggregate weight magnitude) land on the
/// lowest-variance healthy physical columns, and — under hard faults —
/// the least-loaded logical columns (zero padding, weak hidden units)
/// soak up the dead silicon.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ColumnPlan {
    /// `perm[logical] = physical`
    pub perm: Vec<usize>,
    /// `inv[physical] = logical`
    pub inv: Vec<usize>,
}

impl ColumnPlan {
    /// The identity placement (logical column l served by physical l).
    pub fn identity() -> Self {
        Self::from_perm((0..c::M_COLS).collect())
    }

    /// Build from an explicit permutation (`perm[logical] = physical`).
    /// Panics unless `perm` is a permutation of `0..M_COLS`.
    pub fn from_perm(perm: Vec<usize>) -> Self {
        assert_eq!(perm.len(), c::M_COLS, "plan must cover every column");
        let mut inv = vec![usize::MAX; c::M_COLS];
        for (l, &p) in perm.iter().enumerate() {
            assert!(p < c::M_COLS && inv[p] == usize::MAX, "not a permutation");
            inv[p] = l;
        }
        Self { perm, inv }
    }

    /// Pair the most important logical columns (descending `importance`)
    /// with the healthiest physical columns (ascending variance `score`;
    /// a faulty column scores `f64::INFINITY`). Ties break on column
    /// index so the plan is deterministic.
    pub fn from_scores(scores: &[f64], importance: &[f64]) -> Self {
        let at = |v: &[f64], i: usize, d: f64| v.get(i).copied().unwrap_or(d);
        let mut phys: Vec<usize> = (0..c::M_COLS).collect();
        phys.sort_by(|&a, &b| {
            at(scores, a, f64::INFINITY)
                .total_cmp(&at(scores, b, f64::INFINITY))
                .then(a.cmp(&b))
        });
        let mut logical: Vec<usize> = (0..c::M_COLS).collect();
        logical.sort_by(|&a, &b| {
            at(importance, b, 0.0).total_cmp(&at(importance, a, 0.0)).then(a.cmp(&b))
        });
        let mut perm = vec![0usize; c::M_COLS];
        for (rank, &l) in logical.iter().enumerate() {
            perm[l] = phys[rank];
        }
        Self::from_perm(perm)
    }

    /// Whether this is the identity placement.
    pub fn is_identity(&self) -> bool {
        self.perm.iter().enumerate().all(|(l, &p)| l == p)
    }

    /// Apply the placement to a row-major N*M tile: logical column `l`'s
    /// weights move to physical column `perm[l]`.
    pub fn permute_tile(&self, tile: &[i32]) -> Vec<i32> {
        let rows = tile.len() / c::M_COLS;
        let mut out = vec![0i32; tile.len()];
        for r in 0..rows {
            let base = r * c::M_COLS;
            for (l, &p) in self.perm.iter().enumerate() {
                out[base + p] = tile[base + l];
            }
        }
        out
    }

    /// Reorder a physically indexed per-column vector into logical order
    /// (`out[l] = vals[perm[l]]`). Corrections are measured per PHYSICAL
    /// column but the gather side indexes them by logical column (the
    /// worker un-permutes tile outputs before replying), so every
    /// correction vector passes through here before publication.
    pub fn to_logical(&self, vals: &[f64]) -> Vec<f64> {
        self.perm.iter().map(|&p| vals.get(p).copied().unwrap_or(0.0)).collect()
    }

    fn reorder_trim(&self, trim: &LayerTrim) -> LayerTrim {
        LayerTrim { g: self.to_logical(&trim.g), eps: self.to_logical(&trim.eps) }
    }
}

/// How [`CimMlp::prepare_cluster_with`] places tile columns onto the
/// physical array columns of each core.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TilePlacement {
    /// logical column l on physical column l — placement-blind
    #[default]
    Naive,
    /// measure per-column variance on every core and permute columns so
    /// high-magnitude weights land on low-variance healthy columns
    VarianceAware,
}

/// Per-physical-column placement score from a characterization: the worst
/// line's |g_tot - 1| (the calibrated variance estimate), forced to
/// infinity for flat lines so a hard-faulted column always ranks last.
fn fault_aware_scores(fits: &[(LineFit, LineFit)]) -> Vec<f64> {
    fits.iter()
        .map(|(p, n)| {
            if p.g_tot.abs() < FAULT_DEAD_GAIN || n.g_tot.abs() < FAULT_DEAD_GAIN {
                f64::INFINITY
            } else {
                (p.g_tot - 1.0).abs().max((n.g_tot - 1.0).abs())
            }
        })
        .collect()
}

/// Aggregate |weight| landing on each tile-local column across every tile
/// of both layers — the logical-column importance
/// [`ColumnPlan::from_scores`] ranks by. A column that is zero padding in
/// every tile scores 0 and soaks up the faultiest silicon; class columns
/// (used by every layer-2 tile) rank near the top and get the healthiest.
fn tile_column_importance(layers: [&TiledLayer; 2]) -> Vec<f64> {
    let mut imp = vec![0.0f64; c::M_COLS];
    for layer in layers {
        for row in &layer.tiles {
            for tile in row {
                for (i, &w) in tile.iter().enumerate() {
                    imp[i % c::M_COLS] += w.unsigned_abs() as f64;
                }
            }
        }
    }
    imp
}

/// Execution statistics of one inference.
#[derive(Debug, Clone, Copy, Default)]
pub struct InferenceStats {
    /// array activations (MAC pulses)
    pub mac_ops: u64,
    /// weight reprogram operations (tile switches)
    pub reprograms: u64,
}

/// Reusable buffers for the single-model inference paths: the GEMM
/// scratch, per-tile ADC code staging, the per-layer accumulator, and
/// the requantized hidden codes. The accuracy drivers allocate ONE and
/// thread it through every image (steady-state inference then allocates
/// only its input quantization and the returned logits);
/// `infer`/`infer_prepared` wrap a fresh one per call.
#[derive(Default)]
pub struct InferScratch {
    mac: MacScratch,
    /// per-tile ADC codes from the array
    q: Vec<u32>,
    /// per-layer accumulator, `col_tiles * M_COLS` wide (the layer's
    /// logical columns are the leading `layer.cols` entries)
    acc: Vec<f32>,
    /// requantized hidden codes between the layers
    h: Vec<i32>,
}

impl InferScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// One raw ADC code -> code-product units under the digital correction
/// precedence shared by EVERY execution path (direct, prepared, served):
/// full residual trim, else zero-point subtraction, else nominal.
fn correct_code(
    qc: f32,
    col: usize,
    trim: &Option<LayerTrim>,
    zp: &Option<Vec<f64>>,
    mid: f32,
    gain: f32,
) -> f32 {
    if let Some(t) = trim {
        ((qc - t.eps[col] as f32) / t.g[col] as f32 - mid) / gain
    } else if let Some(z) = zp {
        (qc - z[col] as f32) / gain
    } else {
        (qc - mid) / gain
    }
}

/// Characterize one die at one layer's ADC window and return the
/// per-column digital residual correction — the measurement behind
/// [`CimMlp::measure_digital_trim`], shared with the worker-side
/// [`TrimRefresher`] so an in-service recalibration can re-measure the
/// gather-side corrections on the freshly trimmed die.
fn measure_layer_trim(
    model: &mut CimAnalogModel,
    cfg: &SimConfig,
    refs: (f64, f64),
) -> LayerTrim {
    use crate::coordinator::bisc::{AdcCharacterization, BiscEngine};
    let half = c::V_BIAS - refs.0;
    let v_per_x = c::volts_per_cp() * c::CODE_MAX as f64 * c::N_ROWS as f64;
    let sweep = ((half * 0.75) / v_per_x).floor().max(2.0) as i32;
    let mut engine = BiscEngine::from_config(cfg, AdcCharacterization::ideal());
    engine.char_refs = Some(refs);
    engine.sweep_max_code = sweep.min(c::CODE_MAX);
    engine.averages = engine.averages.max(8);
    let fits = engine.characterize_only(model);
    LayerTrim {
        g: fits.iter().map(|(p, n)| 0.5 * (p.g_tot + n.g_tot)).collect(),
        eps: fits.iter().map(|(p, n)| 0.5 * (p.eps_tot + n.eps_tot)).collect(),
    }
}

/// Per-column q at x = 0 for one layer window with `tile` programmed —
/// the zero-point measurement shared by the single-array scheduler, the
/// cluster preparation, and the worker-side [`TrimRefresher`]. Leaves
/// the ADC refs at the layer window and `tile` on the array; callers
/// restore both.
fn measure_zero_point_at(
    model: &mut CimAnalogModel,
    refs: (f64, f64),
    tile: &[i32],
) -> Vec<f64> {
    let zero = [0i32; c::N_ROWS];
    model.set_adc_refs(refs.0, refs.1);
    model.program(tile);
    model.forward_averaged(&zero, 8)
}

/// Per-tile MAC sums (digital emulation) used for window calibration.
fn tile_sums(layer: &TiledLayer, x_codes: &[i32]) -> Vec<i64> {
    let (rt, ct) = (layer.row_tiles(), layer.col_tiles());
    let mut sums = Vec::with_capacity(rt * ct * c::M_COLS);
    for tr in 0..rt {
        for tc in 0..ct {
            let tile = &layer.tiles[tr][tc];
            for col in 0..c::M_COLS {
                let mut s = 0i64;
                for r in 0..c::N_ROWS {
                    let x = x_codes.get(tr * c::N_ROWS + r).copied().unwrap_or(0) as i64;
                    s += x * tile[r * c::M_COLS + col] as i64;
                }
                sums.push(s);
            }
        }
    }
    sums
}

/// Choose an ADC window covering the tile-sum swing plus headroom for the
/// analog gain/offset error budget (so an *uncalibrated* die degrades
/// rather than hard-clips, matching §VII-C's 88.7% uncal behaviour).
fn window_for(p995_abs_cp: f64) -> (f64, f64) {
    let v_per_cp = c::volts_per_cp();
    // multiplicative headroom for gain errors + additive for offsets
    let half = p995_abs_cp * v_per_cp * 1.15 + 0.012;
    let half = half.min(c::V_BIAS - c::V_INL); // never wider than default
    (c::V_BIAS - half, c::V_BIAS + half)
}

/// 99.5th percentile of |sums| (clipping a handful of outlier tiles is
/// cheaper than wasting ADC range on them).
fn p995(mut v: Vec<f64>) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[((v.len() - 1) as f64 * 0.995) as usize]
}

impl CimMlp {
    /// Build the tiled MLP, calibrating the per-layer ADC windows on a
    /// sample of `calib` images (digital emulation, no array needed).
    pub fn new(quant: QuantMlp, calib: &Dataset, calib_n: usize) -> Self {
        let layer1 = TiledLayer::new(&quant.w1_codes, IMG_PIXELS, HIDDEN);
        let layer2 = TiledLayer::new(&quant.w2_codes, HIDDEN, NUM_CLASSES);
        let mut abs1: Vec<f64> = Vec::new();
        let mut abs2: Vec<f64> = Vec::new();
        for i in 0..calib.len().min(calib_n) {
            let x = quant.quantize_input(calib.image(i));
            for s in tile_sums(&layer1, &x) {
                abs1.push(s.unsigned_abs() as f64);
            }
            // hidden codes from the digital reference path
            let mut h = quant.b1_cp.clone();
            for (px, &xi) in x.iter().enumerate() {
                if xi == 0 {
                    continue;
                }
                let row = &quant.w1_codes[px * HIDDEN..(px + 1) * HIDDEN];
                for (hj, &w) in h.iter_mut().zip(row) {
                    *hj += (xi * w) as f32;
                }
            }
            let h_codes: Vec<i32> = h
                .iter()
                .map(|&v| (v.max(0.0) * quant.act_scale1).round().min(63.0) as i32)
                .collect();
            for s in tile_sums(&layer2, &h_codes) {
                abs2.push(s.unsigned_abs() as f64);
            }
        }
        let refs1 = window_for(p995(abs1));
        let refs2 = window_for(p995(abs2));
        Self { quant, layer1, layer2, refs1, refs2, trim1: None, trim2: None, zp1: None, zp2: None }
    }

    /// Build with the default (full-range) ADC windows — the naive mapping,
    /// kept as an ablation (bench `dnn_accuracy --ablation`).
    pub fn new_default_refs(quant: QuantMlp) -> Self {
        let layer1 = TiledLayer::new(&quant.w1_codes, IMG_PIXELS, HIDDEN);
        let layer2 = TiledLayer::new(&quant.w2_codes, HIDDEN, NUM_CLASSES);
        Self {
            quant,
            layer1,
            layer2,
            refs1: (c::V_ADC_L, c::V_ADC_H),
            refs2: (c::V_ADC_L, c::V_ADC_H),
            trim1: None,
            trim2: None,
            zp1: None,
            zp2: None,
        }
    }

    /// Measure per-column zero points (q at x = 0) at each layer's window —
    /// the minimal bring-up correction: one extra read per layer, no analog
    /// trimming, no gain correction. This is the "uncalibrated" baseline a
    /// real deployment would actually ship (raw offsets accumulate
    /// coherently over the 22 row tiles and destroy the network otherwise).
    pub fn measure_zero_point(&mut self, model: &mut CimAnalogModel) {
        self.zp1 = Some(self.zero_point_at(model, self.refs1, 1));
        self.zp2 = Some(self.zero_point_at(model, self.refs2, 2));
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
    }

    /// Per-column q at x = 0 for one layer's window on one die (shared by
    /// the single-array and cluster schedulers). Leaves the ADC refs at
    /// the layer window and tile (0,0) programmed — callers restore.
    fn zero_point_at(
        &self,
        model: &mut CimAnalogModel,
        refs: (f64, f64),
        which: usize,
    ) -> Vec<f64> {
        let tile =
            if which == 1 { &self.layer1.tiles[0][0] } else { &self.layer2.tiles[0][0] };
        measure_zero_point_at(model, refs, tile)
    }

    /// Drop all digital corrections (raw-uncalibrated ablation).
    pub fn clear_corrections(&mut self) {
        self.trim1 = None;
        self.trim2 = None;
        self.zp1 = None;
        self.zp2 = None;
    }

    /// Characterize one die at one layer window and return the per-column
    /// digital residual correction (shared by the single-array and the
    /// cluster schedulers).
    fn digital_trim_at(
        &self,
        model: &mut CimAnalogModel,
        cfg: &SimConfig,
        refs: (f64, f64),
    ) -> LayerTrim {
        measure_layer_trim(model, cfg, refs)
    }

    /// Measure the digital residual trims on a (typically BISC-calibrated)
    /// die: characterize each column at each layer's window and store the
    /// per-column (g, eps) for inverse correction during inference.
    pub fn measure_digital_trim(&mut self, model: &mut CimAnalogModel, cfg: &crate::config::SimConfig) {
        self.trim1 = Some(self.digital_trim_at(model, cfg, self.refs1));
        self.trim2 = Some(self.digital_trim_at(model, cfg, self.refs2));
    }

    /// One layer on the array: x_codes (len >= rows, zero-padded) ->
    /// accumulated MAC estimates in code-product units, written into
    /// `scratch.acc` (the layer's logical output is the leading
    /// `layer.cols` entries).
    fn layer_forward(
        &self,
        model: &mut CimAnalogModel,
        layer: &TiledLayer,
        refs: (f64, f64),
        trim: &Option<LayerTrim>,
        zp: &Option<Vec<f64>>,
        x_codes: &[i32],
        stats: &mut InferenceStats,
        scratch: &mut InferScratch,
    ) {
        model.set_adc_refs(refs.0, refs.1);
        let k = c::code_gain_at(refs.0, refs.1) as f32;
        let mid = c::q_mid_at(refs.0, refs.1) as f32;
        let (rt, ct) = (layer.row_tiles(), layer.col_tiles());
        scratch.acc.clear();
        scratch.acc.resize(ct * c::M_COLS, 0.0);
        let mut xr = [0i32; c::N_ROWS];
        for tc in 0..ct {
            for tr in 0..rt {
                model.program(&layer.tiles[tr][tc]);
                stats.reprograms += 1;
                let start = tr * c::N_ROWS;
                for (i, x) in xr.iter_mut().enumerate() {
                    *x = x_codes.get(start + i).copied().unwrap_or(0);
                }
                model.forward_batch_into(&xr, 1, &mut scratch.q);
                stats.mac_ops += 1;
                for col in 0..c::M_COLS {
                    scratch.acc[tc * c::M_COLS + col] +=
                        correct_code(scratch.q[col] as f32, col, trim, zp, mid, k);
                }
            }
        }
    }

    /// Digital bias + ReLU + requantization between the layers (the
    /// RISC-V side), shared by every execution path:
    /// `scratch.acc[..cols1]` -> `scratch.h`.
    fn requantize_hidden(quant: &QuantMlp, scratch: &mut InferScratch, cols1: usize) {
        let InferScratch { acc, h, .. } = scratch;
        h.clear();
        for (&v, &b) in acc[..cols1].iter().zip(&quant.b1_cp) {
            h.push(((v + b).max(0.0) * quant.act_scale1).round().clamp(0.0, 63.0) as i32);
        }
    }

    /// Final logits from `scratch.acc[..cols2]` + the layer-2 bias.
    fn logits_from(&self, scratch: &InferScratch) -> Vec<f32> {
        scratch.acc[..self.layer2.cols]
            .iter()
            .zip(&self.quant.b2_cp)
            .map(|(&v, &b)| v + b)
            .collect()
    }

    /// Full inference of one image through the CIM array.
    pub fn infer(
        &self,
        model: &mut CimAnalogModel,
        img: &[f32],
        stats: &mut InferenceStats,
    ) -> Vec<f32> {
        let mut scratch = InferScratch::new();
        self.infer_with(model, img, stats, &mut scratch)
    }

    /// `infer` through a caller-owned [`InferScratch`] (the accuracy
    /// driver reuses one across the whole dataset).
    pub fn infer_with(
        &self,
        model: &mut CimAnalogModel,
        img: &[f32],
        stats: &mut InferenceStats,
        scratch: &mut InferScratch,
    ) -> Vec<f32> {
        let x = self.quant.quantize_input(img);
        self.layer_forward(
            model, &self.layer1, self.refs1, &self.trim1, &self.zp1, &x, stats, scratch,
        );
        Self::requantize_hidden(&self.quant, scratch, self.layer1.cols);
        let h = std::mem::take(&mut scratch.h);
        self.layer_forward(
            model, &self.layer2, self.refs2, &self.trim2, &self.zp2, &h, stats, scratch,
        );
        scratch.h = h;
        self.logits_from(scratch)
    }

    /// Classify a whole dataset; returns (accuracy, stats).
    pub fn accuracy(
        &self,
        model: &mut CimAnalogModel,
        ds: &Dataset,
        limit: usize,
    ) -> (f64, InferenceStats) {
        let n = ds.len().min(limit);
        let mut stats = InferenceStats::default();
        let mut correct = 0;
        let mut scratch = InferScratch::new();
        for i in 0..n {
            let logits = self.infer_with(model, ds.image(i), &mut stats, &mut scratch);
            if argmax(&logits) == ds.labels[i] as usize {
                correct += 1;
            }
        }
        (correct as f64 / n as f64, stats)
    }

    /// Nominal tiled reference (ideal-array digital emulation) — the
    /// "simulation" row of §VII-C including the 6-bit ADC quantization.
    pub fn infer_nominal(&self, img: &[f32]) -> Vec<f32> {
        let mut model = CimAnalogModel::ideal();
        let mut stats = InferenceStats::default();
        self.infer(&mut model, img, &mut stats)
    }

    /// Pre-fold every tile under the die's current trims (§Perf
    /// optimization 2): inference then replays cached folded tiles instead
    /// of re-programming + re-folding the array model 68 times per image.
    /// Must be re-run after any trim/refs change (BISC, zero-point).
    pub fn prepare(&self, model: &mut CimAnalogModel) -> PreparedMlp {
        let mut fold_layer = |layer: &TiledLayer, refs: (f64, f64)| {
            model.set_adc_refs(refs.0, refs.1);
            layer
                .tiles
                .iter()
                .map(|row| row.iter().map(|t| model.fold_tile(t)).collect())
                .collect()
        };
        let tiles1 = fold_layer(&self.layer1, self.refs1);
        let tiles2 = fold_layer(&self.layer2, self.refs2);
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
        PreparedMlp { tiles1, tiles2 }
    }

    fn layer_forward_prepared(
        &self,
        model: &CimAnalogModel,
        layer: &TiledLayer,
        folded: &[Vec<crate::analog::Folded>],
        refs: (f64, f64),
        trim: &Option<LayerTrim>,
        zp: &Option<Vec<f64>>,
        x_codes: &[i32],
        stats: &mut InferenceStats,
        scratch: &mut InferScratch,
    ) {
        let k = c::code_gain_at(refs.0, refs.1) as f32;
        let mid = c::q_mid_at(refs.0, refs.1) as f32;
        let (rt, ct) = (layer.row_tiles(), layer.col_tiles());
        scratch.acc.clear();
        scratch.acc.resize(ct * c::M_COLS, 0.0);
        let mut xr = [0i32; c::N_ROWS];
        for tc in 0..ct {
            for tr in 0..rt {
                let start = tr * c::N_ROWS;
                for (i, x) in xr.iter_mut().enumerate() {
                    *x = x_codes.get(start + i).copied().unwrap_or(0);
                }
                model.forward_folded_into(
                    &folded[tr][tc],
                    &xr,
                    1,
                    &mut scratch.mac,
                    &mut scratch.q,
                );
                stats.mac_ops += 1;
                for col in 0..c::M_COLS {
                    scratch.acc[tc * c::M_COLS + col] +=
                        correct_code(scratch.q[col] as f32, col, trim, zp, mid, k);
                }
            }
        }
    }

    /// Inference over the prepared (pre-folded) schedule — the production
    /// hot path; numerically identical to `infer` (noise-free path).
    pub fn infer_prepared(
        &self,
        model: &CimAnalogModel,
        prepared: &PreparedMlp,
        img: &[f32],
        stats: &mut InferenceStats,
    ) -> Vec<f32> {
        let mut scratch = InferScratch::new();
        self.infer_prepared_with(model, prepared, img, stats, &mut scratch)
    }

    /// `infer_prepared` through a caller-owned [`InferScratch`] — the
    /// steady-state form: per image it allocates only the quantized
    /// input and the returned logits.
    pub fn infer_prepared_with(
        &self,
        model: &CimAnalogModel,
        prepared: &PreparedMlp,
        img: &[f32],
        stats: &mut InferenceStats,
        scratch: &mut InferScratch,
    ) -> Vec<f32> {
        let x = self.quant.quantize_input(img);
        self.layer_forward_prepared(
            model, &self.layer1, &prepared.tiles1, self.refs1, &self.trim1, &self.zp1, &x,
            stats, scratch,
        );
        Self::requantize_hidden(&self.quant, scratch, self.layer1.cols);
        let h = std::mem::take(&mut scratch.h);
        self.layer_forward_prepared(
            model, &self.layer2, &prepared.tiles2, self.refs2, &self.trim2, &self.zp2, &h,
            stats, scratch,
        );
        scratch.h = h;
        self.logits_from(scratch)
    }

    /// Dataset accuracy over the prepared schedule.
    pub fn accuracy_prepared(
        &self,
        model: &CimAnalogModel,
        prepared: &PreparedMlp,
        ds: &Dataset,
        limit: usize,
    ) -> (f64, InferenceStats) {
        let n = ds.len().min(limit);
        let mut stats = InferenceStats::default();
        let mut correct = 0;
        let mut scratch = InferScratch::new();
        for i in 0..n {
            let logits =
                self.infer_prepared_with(model, prepared, ds.image(i), &mut stats, &mut scratch);
            if argmax(&logits) == ds.labels[i] as usize {
                correct += 1;
            }
        }
        (correct as f64 / n as f64, stats)
    }
}

/// Pre-folded tile schedule (see `CimMlp::prepare`).
pub struct PreparedMlp {
    tiles1: Vec<Vec<crate::analog::Folded>>,
    tiles2: Vec<Vec<crate::analog::Folded>>,
}

/// One core's gather-side digital corrections plus the recalibration
/// epoch they were measured at. `epoch` pairs with
/// [`crate::coordinator::service::CoreBoard::recal_epoch`]: corrections
/// are valid while their epoch is at least the board's (the worker
/// publishes refreshed corrections BEFORE the board observes the new
/// epoch, so "ahead of the board" always means "at least as fresh").
#[derive(Debug, Clone, Default)]
pub struct CoreCorrections {
    pub trim1: Option<LayerTrim>,
    pub trim2: Option<LayerTrim>,
    pub zp1: Option<Vec<f64>>,
    pub zp2: Option<Vec<f64>>,
    /// recalibration epoch these corrections were measured at
    pub epoch: u64,
}

impl CoreCorrections {
    /// Whether this core carries any correction that could go stale.
    pub fn has_any(&self) -> bool {
        self.trim1.is_some() || self.trim2.is_some() || self.zp1.is_some() || self.zp2.is_some()
    }
}

/// Shared per-core correction slots: read by the gather side of
/// [`CimMlp::infer_batch_service`], written by [`CimMlp::prepare_cluster`]
/// and — after every in-service recalibration — by the worker-side
/// [`TrimRefresher`].
pub type SharedCorrections = Arc<Vec<Mutex<CoreCorrections>>>;

/// Per-cluster digital correction schedule: every core's per-layer
/// residual trims and zero points (each core is a distinct die, so both
/// are per-core). The pre-folded tiles themselves live ON the cores as
/// [`TileBank`]s — the serving workers evaluate them natively via
/// [`Job::MacBatch`] + [`TileRef`]; this struct holds only the
/// gather-side (RISC-V) correction state.
///
/// An in-service recalibration ([`Job::Drain`]) re-folds the core's tile
/// bank AND — through the [`TrimRefresher`] `prepare_cluster` installs
/// on every core — re-measures that core's corrections on the freshly
/// trimmed die, publishing them here at the new epoch. The DNN path
/// therefore keeps serving across autonomous drains without ever
/// applying stale trims; [`CimMlp::infer_batch_service`] still refuses
/// (typed error, never silently-wrong logits) if a core's corrections
/// lag its recal epoch or a recalibration lands mid-inference.
pub struct ClusterSchedule {
    corrections: SharedCorrections,
    /// the registry model id this schedule serves — tile jobs are placed
    /// with `Placement::Model { model, tile }` and carry the id so the
    /// worker refuses them if a rollout swapped the core's model between
    /// placement and execution
    model: u32,
    /// per-schedule serving scratch pool: gather-side accumulators and
    /// requantized hidden codes reused across `infer_batch_service`
    /// invocations (§Perf; DESIGN.md §11). Each batch TAKES the scratch
    /// and puts it back when done, so concurrent batches on one schedule
    /// still overlap (a caller finding the pool empty grows a fresh
    /// scratch; the last finisher's buffers win the parking spot).
    scratch: Mutex<ServeScratch>,
}

/// Gather-side buffers of one schedule (the `ClusterSchedule::scratch`
/// pool).
#[derive(Default)]
struct ServeScratch {
    /// flattened per-image layer accumulator, `n_imgs * layer.cols`
    acc: Vec<f32>,
    /// requantized hidden codes, one row per image (outer and inner
    /// buffers both persist across invocations)
    h_rows: Vec<Vec<i32>>,
}

impl ClusterSchedule {
    pub fn cores(&self) -> usize {
        self.corrections.len()
    }

    /// The registry model id this schedule's tile jobs are placed under.
    pub fn model(&self) -> u32 {
        self.model
    }

    /// Snapshot one core's current corrections (operator tooling/tests).
    pub fn core_corrections(&self, core: usize) -> CoreCorrections {
        self.corrections[core].lock().unwrap().clone()
    }
}

/// Worker-side refresher for one core's gather-side digital corrections,
/// installed by [`CimMlp::prepare_cluster`] on every
/// [`crate::coordinator::cluster::ClusterCore`] whose schedule carries
/// corrections. After an in-service `Drain` recalibrates the die (new
/// analog trims => the old digital residual corrections are wrong), the
/// worker calls [`TrimRefresher::refresh`] to re-measure them against
/// the new trims and publish them into the shared schedule at the new
/// epoch — the serving-side half of "refresh gather-side digital trims
/// after an in-service drain".
#[derive(Clone)]
pub struct TrimRefresher {
    /// `Some` => re-measure the per-layer residual trims with this config
    cfg: Option<SimConfig>,
    refs1: (f64, f64),
    refs2: (f64, f64),
    /// `Some` => re-measure the per-layer zero points on these tiles
    zp_tiles: Option<(Vec<i32>, Vec<i32>)>,
    /// this core's column placement: zero points are measured on the
    /// permuted tile and every correction vector is re-published in
    /// logical order, matching the un-permuted tile replies
    plan: Option<ColumnPlan>,
    corrections: SharedCorrections,
}

impl TrimRefresher {
    /// Re-measure this core's corrections on the (just recalibrated)
    /// die and publish them at `epoch`. Leaves characterization/tile
    /// weights on the array — the caller restores the workload weights,
    /// exactly like the other lifecycle steps.
    pub fn refresh(&self, core: usize, model: &mut CimAnalogModel, epoch: u64) {
        let trims = self.cfg.as_ref().map(|cfg| {
            let t1 = measure_layer_trim(model, cfg, self.refs1);
            let t2 = measure_layer_trim(model, cfg, self.refs2);
            match &self.plan {
                Some(p) => (p.reorder_trim(&t1), p.reorder_trim(&t2)),
                None => (t1, t2),
            }
        });
        let zps = self.zp_tiles.as_ref().map(|(t1, t2)| {
            let (z1, z2) = match &self.plan {
                Some(p) => (
                    measure_zero_point_at(model, self.refs1, &p.permute_tile(t1)),
                    measure_zero_point_at(model, self.refs2, &p.permute_tile(t2)),
                ),
                None => (
                    measure_zero_point_at(model, self.refs1, t1),
                    measure_zero_point_at(model, self.refs2, t2),
                ),
            };
            match &self.plan {
                Some(p) => (p.to_logical(&z1), p.to_logical(&z2)),
                None => (z1, z2),
            }
        });
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
        let mut slot = self.corrections[core].lock().unwrap();
        if let Some((t1, t2)) = trims {
            slot.trim1 = Some(t1);
            slot.trim2 = Some(t2);
        }
        if let Some((z1, z2)) = zps {
            slot.zp1 = Some(z1);
            slot.zp2 = Some(z2);
        }
        slot.epoch = epoch;
    }
}

impl CimMlp {
    /// Fold the full tile schedule on every core of the cluster IN
    /// PARALLEL, installing a [`TileBank`] (layer 0 = MLP layer 1,
    /// layer 1 = MLP layer 2) on each core and optionally measuring
    /// per-core digital residual trims first (pass the config to
    /// enable). Tile jobs are then served through the cluster's
    /// `submit` path by [`CimMlp::infer_batch_service`].
    ///
    /// When the schedule carries corrections (trims and/or zero points),
    /// every core also gets a [`TrimRefresher`] so in-service drains
    /// re-measure its corrections on the recalibrated die — the DNN
    /// path keeps serving across autonomous recalibrations. Corrections
    /// are stamped with each die's monotonic recalibration clock
    /// (`ClusterCore::recal_count`, which `serve_with` seeds the board
    /// epochs from), so schedules from different generations stay
    /// comparable: an older schedule is accepted exactly while the die's
    /// trims still match it, and refused once a later recalibration
    /// outruns it.
    pub fn prepare_cluster(
        &self,
        cluster: &mut crate::coordinator::cluster::CimCluster,
        cfg: Option<&crate::config::SimConfig>,
    ) -> ClusterSchedule {
        self.prepare_cluster_with(cluster, cfg, TilePlacement::Naive)
    }

    /// [`CimMlp::prepare_cluster`] with an explicit column placement
    /// policy. Under [`TilePlacement::VarianceAware`] every core first
    /// characterizes its die, ranks physical columns by the calibrated
    /// variance estimate (hard-faulted columns rank last at infinite
    /// score), and folds its bank through a [`ColumnPlan`] that lands the
    /// highest-|weight| logical columns on the healthiest silicon — the
    /// degraded-mode placement that keeps a wounded-but-serving die close
    /// to its pre-fault accuracy (DESIGN.md §16, EXPERIMENTS.md).
    pub fn prepare_cluster_with(
        &self,
        cluster: &mut crate::coordinator::cluster::CimCluster,
        cfg: Option<&crate::config::SimConfig>,
        placement: TilePlacement,
    ) -> ClusterSchedule {
        type CoreResult = (
            usize,
            Option<(LayerTrim, LayerTrim)>,
            Option<(Vec<f64>, Vec<f64>)>,
            Option<ColumnPlan>,
        );
        let want_zp = self.zp1.is_some() || self.zp2.is_some();
        // logical-column importance is a property of the WEIGHTS, shared
        // by every core; the per-core part is the physical column scores
        let importance = (placement == TilePlacement::VarianceAware)
            .then(|| tile_column_importance([&self.layer1, &self.layer2]));
        let importance = &importance;
        // one shared copy of each layer's immutable raw tile grid: every
        // core folds the same tiles, only the folded coefficients are
        // per-core
        let raw1 = std::sync::Arc::new(self.layer1.tiles.clone());
        let raw2 = std::sync::Arc::new(self.layer2.tiles.clone());
        let mut results: Vec<CoreResult> = std::thread::scope(|s| {
            let handles: Vec<_> = cluster
                .cores
                .iter_mut()
                .map(|core| {
                    let raw1 = std::sync::Arc::clone(&raw1);
                    let raw2 = std::sync::Arc::clone(&raw2);
                    s.spawn(move || {
                        // variance-aware: score THIS die's columns and
                        // derive its placement before anything is folded
                        // or measured against it
                        let plan = importance.as_ref().map(|imp| {
                            use crate::coordinator::bisc::{AdcCharacterization, BiscEngine};
                            let score_cfg = cfg.cloned().unwrap_or_default();
                            let engine =
                                BiscEngine::from_config(&score_cfg, AdcCharacterization::ideal());
                            let fits = engine.characterize_only(&mut core.model);
                            ColumnPlan::from_scores(&fault_aware_scores(&fits), imp)
                        });
                        let trims = cfg.map(|cc| {
                            let t1 = self.digital_trim_at(&mut core.model, cc, self.refs1);
                            let t2 = self.digital_trim_at(&mut core.model, cc, self.refs2);
                            // trims are measured per physical column; the
                            // gather side indexes them logically
                            match &plan {
                                Some(p) => (p.reorder_trim(&t1), p.reorder_trim(&t2)),
                                None => (t1, t2),
                            }
                        });
                        // the CimMlp carries a zero-point correction: this
                        // core is a different die, re-measure its own (on
                        // the PERMUTED tile when a plan is installed, so
                        // the zero points match the columns as served)
                        let zps = want_zp.then(|| match &plan {
                            Some(p) => {
                                let z1 = measure_zero_point_at(
                                    &mut core.model,
                                    self.refs1,
                                    &p.permute_tile(&self.layer1.tiles[0][0]),
                                );
                                let z2 = measure_zero_point_at(
                                    &mut core.model,
                                    self.refs2,
                                    &p.permute_tile(&self.layer2.tiles[0][0]),
                                );
                                (p.to_logical(&z1), p.to_logical(&z2))
                            }
                            None => (
                                self.zero_point_at(&mut core.model, self.refs1, 1),
                                self.zero_point_at(&mut core.model, self.refs2, 2),
                            ),
                        });
                        let bank = TileBank::build_planned(
                            &mut core.model,
                            vec![(self.refs1, raw1), (self.refs2, raw2)],
                            plan.clone(),
                        );
                        core.install_bank(bank);
                        // trim measurement + folding programmed test and
                        // tile weights over the array; put the workload
                        // weights back so plain Mac jobs stay correct
                        core.restore_weights();
                        (core.id, trims, zps, plan)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("prepare worker panicked"))
                .collect()
        });
        results.sort_by_key(|r| r.0);
        let plans: Vec<Option<ColumnPlan>> = results.iter().map(|r| r.3.clone()).collect();
        // corrections were measured NOW, against the die's current
        // trims: stamp each with the die's recalibration clock
        // (`ClusterCore::recal_count`, which the serving board's epochs
        // continue), so a schedule from an older generation can never
        // pass as fresh once the die recalibrates again
        let corrections: SharedCorrections = Arc::new(
            results
                .into_iter()
                .zip(&cluster.cores)
                .map(|((_, t, z, _), core)| {
                    let (trim1, trim2) = match t {
                        Some((t1, t2)) => (Some(t1), Some(t2)),
                        None => (None, None),
                    };
                    let (zp1, zp2) = match z {
                        Some((z1, z2)) => (Some(z1), Some(z2)),
                        None => (None, None),
                    };
                    Mutex::new(CoreCorrections {
                        trim1,
                        trim2,
                        zp1,
                        zp2,
                        epoch: core.recal_count,
                    })
                })
                .collect(),
        );
        // arm the worker-side refresher so in-service drains re-measure
        // THIS schedule's corrections instead of invalidating them; a
        // later prepare_cluster replaces the refresher, after which this
        // schedule goes stale on the next drain (refused typed, §10)
        let has_corrections =
            corrections.iter().any(|slot| slot.lock().unwrap().has_any());
        let refresher = has_corrections.then(|| TrimRefresher {
            cfg: cfg.cloned(),
            refs1: self.refs1,
            refs2: self.refs2,
            zp_tiles: want_zp.then(|| {
                (self.layer1.tiles[0][0].clone(), self.layer2.tiles[0][0].clone())
            }),
            plan: None,
            corrections: Arc::clone(&corrections),
        });
        // every core now holds the FULL folded bank for both layers:
        // record that residency (model + tile list) so `serve_with` seeds
        // the board and `Placement::Model { model, tile }` can resolve
        // "any healthy core holding this tile". The DNN path registers
        // its workload under the default model id; multi-model serving
        // layers distinct banks via the registry instead.
        let mut tiles: Vec<TileRef> = Vec::with_capacity(
            self.layer1.row_tiles() * self.layer1.col_tiles()
                + self.layer2.row_tiles() * self.layer2.col_tiles(),
        );
        for (li, layer) in [&self.layer1, &self.layer2].into_iter().enumerate() {
            for tr in 0..layer.row_tiles() {
                for tc in 0..layer.col_tiles() {
                    tiles.push(TileRef { layer: li, tr, tc });
                }
            }
        }
        for (core, plan) in cluster.cores.iter_mut().zip(plans) {
            // each core's refresher carries that core's own column plan,
            // so post-drain corrections stay in logical order
            core.refresher = refresher.as_ref().map(|r| {
                let mut r = r.clone();
                r.plan = plan;
                r
            });
            core.resident = Some(Residency { model: DEFAULT_MODEL, tiles: tiles.clone() });
        }
        ClusterSchedule {
            corrections,
            model: DEFAULT_MODEL,
            scratch: Mutex::new(ServeScratch::default()),
        }
    }

    /// One layer through the serving engine: each tile becomes one
    /// [`Job::MacBatch`] over the whole image batch (one channel
    /// round-trip per tile), placed with `Placement::Model { model,
    /// tile }` — the scheduler resolves "any healthy core holding this
    /// tile of this model" via the deterministic `tile_slot` hash over
    /// the healthy holders, so the same residency and fence state
    /// reproduce the same tile→die assignment (and therefore the same
    /// corrected logits) on every run, while a fenced out-of-band die
    /// serves no tiles. Each job also CARRIES the model id, so a core
    /// whose model was swapped by a rollout between placement and
    /// execution refuses the job typed (`WrongModel`) instead of
    /// computing against the wrong weights. The gather side applies the
    /// SERVING core's digital corrections (trim > zp > nominal, as in
    /// the single-array paths) and accumulates partial sums in
    /// deterministic tile order.
    fn layer_forward_service<S: CimService>(
        &self,
        svc: &S,
        sched: &ClusterSchedule,
        layer: &TiledLayer,
        which: usize,
        xs: &[Vec<i32>],
        stats: &mut InferenceStats,
        acc: &mut Vec<f32>,
    ) -> Result<(), ServeError> {
        let refs = if which == 1 { self.refs1 } else { self.refs2 };
        let gain = c::code_gain_at(refs.0, refs.1) as f32;
        let mid = c::q_mid_at(refs.0, refs.1) as f32;
        let (rt, ct) = (layer.row_tiles(), layer.col_tiles());
        let mut tickets: Vec<Ticket<Vec<Vec<u32>>>> = Vec::with_capacity(rt * ct);
        for tr in 0..rt {
            // the input slice depends only on the row tile: build it once
            // per tr and memcpy it into each column tile's job
            let start = tr * c::N_ROWS;
            let row_xs: Vec<Vec<i32>> = xs
                .iter()
                .map(|x_codes| {
                    (0..c::N_ROWS)
                        .map(|j| x_codes.get(start + j).copied().unwrap_or(0))
                        .collect()
                })
                .collect();
            for tc in 0..ct {
                let tile = TileRef { layer: which - 1, tr, tc };
                let opts = SubmitOpts::default().with_placement(Placement::Model {
                    model: sched.model,
                    tile: Some(tile),
                });
                let job = Job::MacBatch {
                    xs: row_xs.clone(),
                    tile: Some(tile),
                    model: Some(sched.model),
                };
                match svc.submit(job, opts) {
                    Ok(t) => tickets.push(t.typed()),
                    Err(e) => {
                        // settle what is already in flight before surfacing
                        let _ = gather(tickets);
                        return Err(e);
                    }
                }
            }
        }
        stats.mac_ops += (rt * ct * xs.len()) as u64;
        let gathered = gather(tickets)?;
        // snapshot every core's corrections ONCE per layer (each lock is
        // held only for the clone, so a worker-side refresh never blocks
        // behind the gather, and the per-tile loop below stays lock-free)
        let cors: Vec<CoreCorrections> = (0..sched.cores())
            .map(|core| sched.corrections[core].lock().unwrap().clone())
            .collect();
        acc.clear();
        acc.resize(xs.len() * layer.cols, 0.0);
        for (ti, (core, qs)) in gathered.into_iter().enumerate() {
            let tc = ti % ct;
            let cor = &cors[core];
            let (trim, zp) =
                if which == 1 { (&cor.trim1, &cor.zp1) } else { (&cor.trim2, &cor.zp2) };
            for (i, q) in qs.iter().enumerate() {
                for (col, &qraw) in q.iter().enumerate() {
                    let gcol = tc * c::M_COLS + col;
                    if gcol >= layer.cols {
                        break;
                    }
                    acc[i * layer.cols + gcol] +=
                        correct_code(qraw as f32, col, trim, zp, mid, gain);
                }
            }
        }
        Ok(())
    }

    /// Batched inference through the serving engine: both layers' tiles
    /// are submitted as native batch jobs through the one
    /// `submit(Job, SubmitOpts)` entry point; digital accumulation +
    /// bias + ReLU + requantization happen on the gather side — the
    /// served, multi-array version of `infer_prepared`.
    pub fn infer_batch_service<S: CimService>(
        &self,
        svc: &S,
        sched: &ClusterSchedule,
        imgs: &[&[f32]],
        stats: &mut InferenceStats,
    ) -> Result<Vec<Vec<f32>>, ServeError> {
        assert_eq!(sched.cores(), svc.cores(), "schedule/service core-count mismatch");
        if imgs.is_empty() {
            // an empty MacBatch is malformed at admission; an empty image
            // batch is simply empty results
            return Ok(Vec::new());
        }
        // refuse stale per-core corrections: a core recalibrated in
        // service (Drain) no longer matches trims/zero-points measured
        // against its OLD analog trims — surface a typed error instead
        // of silently applying the wrong correction. With the
        // `TrimRefresher` installed by `prepare_cluster`, the worker
        // re-measures and re-publishes corrections as part of every
        // drain, so the epochs stay aligned and serving continues
        // across autonomous recalibrations; a schedule can only go
        // stale when corrections lag the board (no refresher) or a
        // recalibration lands MID-inference — caught after the layers
        // run by comparing BOTH the board epochs and the corrections'
        // own stamps against entry (the refresher publishes before the
        // board observes the bump, so watching the board alone would
        // miss a drain landing inside that window).
        let entry_board: Vec<u64> =
            (0..sched.cores()).map(|core| svc.board().recal_epoch(core)).collect();
        let mut entry_cor: Vec<(bool, u64)> = Vec::with_capacity(sched.cores());
        for (core, &epoch) in entry_board.iter().enumerate() {
            let cor = sched.corrections[core].lock().unwrap();
            if cor.has_any() && cor.epoch < epoch {
                return Err(ServeError::Backend(format!(
                    "stale schedule: core {core} corrections were measured at recal \
                     epoch {} but the core is at epoch {epoch}; re-run prepare_cluster \
                     (or serve a refresher-armed schedule) to re-measure them",
                    cor.epoch
                )));
            }
            entry_cor.push((cor.has_any(), cor.epoch));
        }
        let xs: Vec<Vec<i32>> =
            imgs.iter().map(|im| self.quant.quantize_input(im)).collect();
        // the per-schedule scratch pool: accumulators + hidden codes
        // persist across invocations, so the gather side of a warmed
        // schedule runs allocation-free up to the job payloads and the
        // returned logits. The scratch is TAKEN out of the pool (not
        // held locked) so concurrent batches on one schedule still
        // overlap — a caller finding the pool empty just grows a fresh
        // scratch, and the last finisher parks its buffers for reuse.
        let mut s = std::mem::take(&mut *sched.scratch.lock().unwrap());
        let result =
            self.infer_layers_service(svc, sched, &xs, stats, &entry_board, &entry_cor, &mut s);
        *sched.scratch.lock().unwrap() = s;
        result
    }

    /// The two served layers + gather-side requantization over a
    /// borrowed [`ServeScratch`] — split out of `infer_batch_service`
    /// so the scratch goes back into the pool on every exit path.
    fn infer_layers_service<S: CimService>(
        &self,
        svc: &S,
        sched: &ClusterSchedule,
        xs: &[Vec<i32>],
        stats: &mut InferenceStats,
        entry_board: &[u64],
        entry_cor: &[(bool, u64)],
        s: &mut ServeScratch,
    ) -> Result<Vec<Vec<f32>>, ServeError> {
        self.layer_forward_service(svc, sched, &self.layer1, 1, xs, stats, &mut s.acc)?;
        let cols1 = self.layer1.cols;
        let n = xs.len();
        // grow to the high-water batch size but never shrink: the
        // dropped rows' inner buffers are the reuse this pool exists for
        while s.h_rows.len() < n {
            s.h_rows.push(Vec::new());
        }
        for (row, acc_row) in s.h_rows.iter_mut().zip(s.acc.chunks_exact(cols1)) {
            row.clear();
            for (&v, &b) in acc_row.iter().zip(&self.quant.b1_cp) {
                row.push(
                    ((v + b).max(0.0) * self.quant.act_scale1).round().clamp(0.0, 63.0) as i32,
                );
            }
        }
        self.layer_forward_service(
            svc,
            sched,
            &self.layer2,
            2,
            &s.h_rows[..n],
            stats,
            &mut s.acc,
        )?;
        for (core, &epoch) in entry_board.iter().enumerate() {
            let (had_corrections, cor_epoch) = entry_cor[core];
            let cor = sched.corrections[core].lock().unwrap();
            let changed =
                svc.board().recal_epoch(core) != epoch || cor.epoch != cor_epoch;
            if changed && (had_corrections || cor.has_any()) {
                return Err(ServeError::Backend(format!(
                    "core {core} was recalibrated mid-inference; its tiles mixed pre- \
                     and post-recalibration corrections — retry the batch"
                )));
            }
        }
        Ok(s
            .acc
            .chunks_exact(self.layer2.cols)
            .map(|l| l.iter().zip(&self.quant.b2_cp).map(|(&v, &b)| v + b).collect())
            .collect())
    }

    /// Dataset accuracy through the serving engine.
    pub fn accuracy_service<S: CimService>(
        &self,
        svc: &S,
        sched: &ClusterSchedule,
        ds: &Dataset,
        limit: usize,
    ) -> Result<(f64, InferenceStats), ServeError> {
        let n = ds.len().min(limit);
        let mut stats = InferenceStats::default();
        let imgs: Vec<&[f32]> = (0..n).map(|i| ds.image(i)).collect();
        let logits = self.infer_batch_service(svc, sched, &imgs, &mut stats)?;
        let correct = logits
            .iter()
            .enumerate()
            .filter(|(i, l)| argmax(l) == ds.labels[*i] as usize)
            .count();
        Ok((correct as f64 / n as f64, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::variation::VariationSample;
    use crate::config::SimConfig;
    use crate::coordinator::bisc::{AdcCharacterization, BiscEngine};
    use crate::data::mlp::{train, Mlp, TrainConfig};
    use crate::data::synth;

    fn pipeline() -> (CimMlp, synth::Dataset) {
        let (train_ds, test_ds) = synth::generate(600, 120, 17);
        let mut mlp = Mlp::new(4);
        train(&mut mlp, &train_ds, &TrainConfig { epochs: 6, ..Default::default() });
        let q = QuantMlp::from_float(&mlp, &train_ds, 100);
        (CimMlp::new(q, &train_ds, 50), test_ds)
    }

    #[test]
    fn tile_counts_match_paper_mapping() {
        assert_eq!(tile_counts(784, 72), (22, 3));
        assert_eq!(tile_counts(72, 10), (2, 1));
    }

    #[test]
    fn column_plan_ranks_faulty_columns_last() {
        // physical col 5 is dead (infinite score), col 2 is the
        // healthiest; logical col 0 matters most, col 31 not at all
        let mut scores = vec![0.05; c::M_COLS];
        scores[5] = f64::INFINITY;
        scores[2] = 0.001;
        let importance: Vec<f64> = (0..c::M_COLS).map(|l| (c::M_COLS - l) as f64).collect();
        let plan = ColumnPlan::from_scores(&scores, &importance);
        assert_eq!(plan.perm[0], 2, "most important logical -> healthiest physical");
        assert_eq!(plan.perm[31], 5, "least important logical -> dead physical");
        // perm and inv are inverse
        for l in 0..c::M_COLS {
            assert_eq!(plan.inv[plan.perm[l]], l);
        }
        assert!(ColumnPlan::identity().is_identity());
        assert!(!plan.is_identity());
    }

    #[test]
    fn column_plan_permutes_tiles_and_corrections_consistently() {
        let plan = ColumnPlan::from_perm((0..c::M_COLS).rev().collect());
        let tile: Vec<i32> = (0..(c::N_ROWS * c::M_COLS) as i32).collect();
        let permuted = plan.permute_tile(&tile);
        for r in 0..c::N_ROWS {
            for l in 0..c::M_COLS {
                // logical l lives on physical perm[l]
                assert_eq!(
                    permuted[r * c::M_COLS + plan.perm[l]],
                    tile[r * c::M_COLS + l]
                );
            }
        }
        // a physically indexed measurement comes back logical:
        // to_logical(vals)[l] == vals[perm[l]]
        let vals: Vec<f64> = (0..c::M_COLS).map(|p| p as f64).collect();
        let logical = plan.to_logical(&vals);
        for l in 0..c::M_COLS {
            assert_eq!(logical[l], plan.perm[l] as f64);
        }
    }

    #[test]
    fn importance_counts_weight_mass_per_tile_column() {
        // layer with cols < M_COLS: the padding columns weigh 0
        let w = vec![3i32; 4 * 2]; // 4 rows x 2 cols
        let layer = TiledLayer::new(&w, 4, 2);
        let imp = tile_column_importance([&layer, &layer]);
        assert_eq!(imp[0], 2.0 * 4.0 * 3.0);
        assert_eq!(imp[1], 2.0 * 4.0 * 3.0);
        for col in 2..c::M_COLS {
            assert_eq!(imp[col], 0.0, "padding column {col} must weigh nothing");
        }
    }

    #[test]
    fn variance_aware_placement_matches_naive_on_ideal_dies() {
        use crate::coordinator::batcher::Batcher;
        let (cim_mlp, test_ds) = pipeline();
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        // naive baseline
        let mut cluster = crate::coordinator::cluster::CimCluster::new(&cfg, 1);
        let sched = cim_mlp.prepare_cluster(&mut cluster, None);
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        let imgs: Vec<&[f32]> = (0..8).map(|i| test_ds.image(i)).collect();
        let mut st = InferenceStats::default();
        let naive = cim_mlp.infer_batch_service(&client, &sched, &imgs, &mut st).unwrap();
        drop(client);
        server.join();
        // variance-aware on an identical ideal die: the permutation is
        // invisible (identical columns), logits match exactly
        let mut cluster = crate::coordinator::cluster::CimCluster::new(&cfg, 1);
        let sched =
            cim_mlp.prepare_cluster_with(&mut cluster, None, TilePlacement::VarianceAware);
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        let mut st = InferenceStats::default();
        let planned = cim_mlp.infer_batch_service(&client, &sched, &imgs, &mut st).unwrap();
        for (a, b) in naive.iter().flatten().zip(planned.iter().flatten()) {
            assert!((a - b).abs() < 1e-3, "placement changed ideal-die logits: {a} vs {b}");
        }
        drop(client);
        server.join();
    }

    #[test]
    fn tiled_layer_roundtrip() {
        let rows = 40;
        let cols = 33;
        let w: Vec<i32> = (0..rows * cols).map(|i| (i as i32 % 127) - 63).collect();
        let t = TiledLayer::new(&w, rows, cols);
        assert_eq!(t.row_tiles(), 2);
        assert_eq!(t.col_tiles(), 2);
        // element (37, 32) lives in tile (1,1) at (1, 0)
        assert_eq!(t.tiles[1][1][c::M_COLS + 0], w[37 * cols + 32]);
        // padding is zero
        assert_eq!(t.tiles[1][1][35 * c::M_COLS + 31], 0);
    }

    #[test]
    fn ideal_array_tracks_digital_reference() {
        let (cim_mlp, test_ds) = pipeline();
        let mut model = CimAnalogModel::ideal();
        let (acc_cim, stats) = cim_mlp.accuracy(&mut model, &test_ds, 40);
        let acc_dig = {
            let correct = (0..40)
                .filter(|&i| {
                    argmax(&cim_mlp.quant.infer_digital(test_ds.image(i)))
                        == test_ds.labels[i] as usize
                })
                .count();
            correct as f64 / 40.0
        };
        // ADC quantization costs a little accuracy but not a collapse
        assert!(acc_cim > acc_dig - 0.15, "cim {acc_cim} vs digital {acc_dig}");
        assert_eq!(stats.mac_ops, 40 * (22 * 3 + 2));
    }

    #[test]
    fn prepared_schedule_matches_direct_path() {
        let (cim_mlp, test_ds) = pipeline();
        let cfg = SimConfig::default();
        let mut cfg2 = cfg.clone();
        cfg2.sigma_noise = 0.0; // the prepared path is the noise-free fast path
        let s = VariationSample::draw(&cfg2);
        let mut die = CimAnalogModel::from_sample(&cfg2, &s);
        let prepared = cim_mlp.prepare(&mut die);
        let mut st1 = InferenceStats::default();
        let mut st2 = InferenceStats::default();
        for i in 0..10 {
            let a = cim_mlp.infer(&mut die, test_ds.image(i), &mut st1);
            let b = cim_mlp.infer_prepared(&die, &prepared, test_ds.image(i), &mut st2);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "prepared mismatch: {x} vs {y}");
            }
        }
        assert_eq!(st1.mac_ops, st2.mac_ops);
    }

    #[test]
    fn single_core_service_matches_prepared_path() {
        use crate::coordinator::batcher::Batcher;
        let (mut cim_mlp, test_ds) = pipeline();
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0; // cluster path is the noise-free fast path
        // K=1 cluster: core 0 keeps the base seed, so the die is identical
        let mut cluster = crate::coordinator::cluster::CimCluster::new(&cfg, 1);
        let sched = cim_mlp.prepare_cluster(&mut cluster, None);
        let s = VariationSample::draw(&cfg);
        let mut die = CimAnalogModel::from_sample(&cfg, &s);
        let prepared = cim_mlp.prepare(&mut die);
        let imgs: Vec<&[f32]> = (0..8).map(|i| test_ds.image(i)).collect();
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        let mut st_c = InferenceStats::default();
        let logits_c = cim_mlp
            .infer_batch_service(&client, &sched, &imgs, &mut st_c)
            .expect("serving failed");
        let mut st_p = InferenceStats::default();
        for (i, img) in imgs.iter().enumerate() {
            let direct = cim_mlp.infer_prepared(&die, &prepared, img, &mut st_p);
            for (a, b) in logits_c[i].iter().zip(&direct) {
                assert!((a - b).abs() < 1e-3, "cluster mismatch: {a} vs {b}");
            }
        }
        assert_eq!(st_c.mac_ops, st_p.mac_ops);
        drop(client);
        let (mut cluster, _) = server.join();

        // zero-point rung: the schedule re-measures per-core zps, which on
        // the identical noise-free die must equal the single-array ones
        cim_mlp.measure_zero_point(&mut die);
        let sched_zp = cim_mlp.prepare_cluster(&mut cluster, None);
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        let mut st_z = InferenceStats::default();
        let logits_z = cim_mlp
            .infer_batch_service(&client, &sched_zp, &imgs, &mut st_z)
            .expect("serving failed");
        for (i, img) in imgs.iter().enumerate() {
            let mut st = InferenceStats::default();
            let direct = cim_mlp.infer_prepared(&die, &prepared, img, &mut st);
            for (a, b) in logits_z[i].iter().zip(&direct) {
                assert!((a - b).abs() < 1e-3, "zp cluster mismatch: {a} vs {b}");
            }
        }
        drop(client);
        server.join();
    }

    #[test]
    fn multi_core_service_spreads_tiles_and_stays_accurate() {
        use crate::coordinator::batcher::Batcher;
        let (cim_mlp, test_ds) = pipeline();
        // ideal dies: sharding across cores must be numerically identical
        // to running every tile on one ideal array
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        let mut cluster = crate::coordinator::cluster::CimCluster::new(&cfg, 3);
        let sched = cim_mlp.prepare_cluster(&mut cluster, None);
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        let n = 30;
        let (acc_cluster, st) = cim_mlp
            .accuracy_service(&client, &sched, &test_ds, n)
            .expect("serving failed");
        let mut ideal = CimAnalogModel::ideal();
        let prepared = cim_mlp.prepare(&mut ideal);
        let (acc_single, _) = cim_mlp.accuracy_prepared(&ideal, &prepared, &test_ds, n);
        // same ideal dies, tiles merely sharded: logits agree to f32
        // gather-order rounding, so accuracy stays put (tolerate one
        // image flipping on an exact tie)
        assert!(
            (acc_cluster - acc_single).abs() <= 1.0 / n as f64 + 1e-9,
            "ideal-die sharding changed accuracy: {acc_cluster} vs {acc_single}"
        );
        let imgs: Vec<&[f32]> = (0..5).map(|i| test_ds.image(i)).collect();
        let mut st2 = InferenceStats::default();
        let logits_c = cim_mlp
            .infer_batch_service(&client, &sched, &imgs, &mut st2)
            .expect("serving failed");
        for (i, img) in imgs.iter().enumerate() {
            let mut stp = InferenceStats::default();
            let direct = cim_mlp.infer_prepared(&ideal, &prepared, img, &mut stp);
            for (a, b) in logits_c[i].iter().zip(&direct) {
                assert!((a - b).abs() < 1e-2, "sharded logit drifted: {a} vs {b}");
            }
        }
        assert_eq!(st.mac_ops, n as u64 * (22 * 3 + 2));
        drop(client);
        let (_cluster, wstats) = server.join();
        // the tile jobs really went through the serving workers
        let served: u64 = wstats.iter().map(|s| s.requests).sum();
        assert!(served > 0, "no tile jobs reached the workers");
    }

    #[test]
    fn errors_degrade_then_bisc_recovers() {
        let (cim_mlp, test_ds) = pipeline();
        let n = 60;
        let mut ideal = CimAnalogModel::ideal();
        let (acc_sim, _) = cim_mlp.accuracy(&mut ideal, &test_ds, n);

        let cfg = SimConfig::default();
        let s = VariationSample::draw(&cfg);
        let mut die = CimAnalogModel::from_sample(&cfg, &s);
        let (acc_uncal, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

        let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
        engine.calibrate(&mut die);
        let (acc_cal, _) = cim_mlp.accuracy(&mut die, &test_ds, n);

        // paper §VII-C shape: sim > cal > uncal
        assert!(acc_uncal < acc_sim + 0.01, "uncal {acc_uncal} sim {acc_sim}");
        assert!(
            acc_cal >= acc_uncal,
            "BISC should not hurt: uncal {acc_uncal} cal {acc_cal}"
        );
    }
}
