//! Built-In Self-Calibration engine — paper Section VI / Algorithm 1.
//!
//! Host-side reference implementation of the BISC routine. The same
//! algorithm also ships as RV32IM firmware (`soc::firmware::bisc_program`)
//! running on the ISS against the memory-mapped CIM device; an integration
//! test asserts the firmware's trims match this engine within one LSB.
//!
//! Per column (Section VI-D: SA1 and SA2 calibrated separately):
//!   1. *Online characterization*: program W_max on the line under test,
//!      apply Z stepped inputs spanning the dynamic range, read the ADC
//!      output averaged over `averages` reads, and least-squares fit
//!      Q_act = g_tot * Q_nom + eps_tot   (Eq. 13-14).
//!   2. *Online correction*: R_SA' = alpha_D * R_SA / g_tot and
//!      V_CAL' = V_CAL - (eps_tot - beta_D) / (alpha_D * C_ADC)  (Eq. 12),
//!      quantized to the digital-potentiometer / cal-DAC trim codes.
//!
//! ADC clipping (Section VI-D-a): references are widened by `ref_margin`
//! during characterization and restored afterwards.

use crate::analog::{consts as c, samp, CimAnalogModel};
use crate::config::SimConfig;
use crate::util::stats;

/// Characterization result for one column, one line.
#[derive(Debug, Clone, Copy)]
pub struct LineFit {
    /// total gain error g_tot (Eq. 13)
    pub g_tot: f64,
    /// total offset error eps_tot [codes] (Eq. 14)
    pub eps_tot: f64,
}

/// Per-column calibration outcome.
#[derive(Debug, Clone)]
pub struct ColumnCalibration {
    pub col: usize,
    pub pos: LineFit,
    pub neg: LineFit,
    /// trim codes chosen
    pub pot_p: u32,
    pub pot_n: u32,
    pub cal: u32,
    /// trim values realized by those codes
    pub rsa_p: f64,
    pub rsa_n: f64,
    pub vcal: f64,
}

/// Full-array calibration report (feeds Fig. 8).
#[derive(Debug, Clone)]
pub struct BiscReport {
    pub columns: Vec<ColumnCalibration>,
    /// total characterization MAC reads issued
    pub reads: u64,
}

/// The ADC characterization assumed known (Eq. 11: "assuming that the ADC
/// has been characterized independently").
#[derive(Debug, Clone, Copy)]
pub struct AdcCharacterization {
    pub alpha_d: f64,
    pub beta_d: f64,
}

impl AdcCharacterization {
    pub fn ideal() -> Self {
        Self { alpha_d: 1.0, beta_d: 0.0 }
    }

    /// Read the true values off the model (a perfect external ADC test).
    pub fn from_model(m: &CimAnalogModel) -> Self {
        Self { alpha_d: m.adc.alpha_d, beta_d: m.adc.beta_d }
    }
}

#[derive(Debug, Clone)]
pub struct BiscEngine {
    /// number of test vectors Z (4-8 per Section VI-C)
    pub test_points: usize,
    /// averaging reads per test point
    pub averages: usize,
    /// ADC reference widening during characterization (Alg. 1; we use 8%
    /// because this die's gain errors are larger than the paper's +/-5%)
    pub ref_margin: f64,
    /// sweep amplitude in input codes (slightly inside full scale so the
    /// widened-reference window never clips even at g ~ 1.25)
    pub sweep_max_code: i32,
    /// custom characterization ADC window; None = Alg. 1's widened default
    /// references. Operating-point calibration (DESIGN.md §6) sets this to
    /// the DNN layer window so the corrected gain matches the small-signal
    /// gain the workload actually sees (amplifier nonlinearity makes the
    /// full-range secant differ from the small-signal slope).
    pub char_refs: Option<(f64, f64)>,
    pub adc_char: AdcCharacterization,
}

impl BiscEngine {
    pub fn from_config(cfg: &SimConfig, adc_char: AdcCharacterization) -> Self {
        Self {
            test_points: cfg.bisc_test_points,
            averages: cfg.bisc_averages,
            ref_margin: cfg.bisc_ref_margin,
            sweep_max_code: 48,
            char_refs: None,
            adc_char,
        }
    }

    /// Operating-point calibration: characterize inside a +/- `half_v`
    /// window around V_BIAS with a sweep amplitude that fills (most of) it.
    pub fn for_operating_point(cfg: &SimConfig, adc_char: AdcCharacterization, half_v: f64) -> Self {
        let win = half_v * 1.5; // headroom for residual gain + offset errors
        let v_per_x = c::volts_per_cp() * (c::CODE_MAX as f64) * c::N_ROWS as f64;
        let sweep = (half_v / v_per_x).floor().max(2.0) as i32;
        Self {
            test_points: cfg.bisc_test_points,
            averages: cfg.bisc_averages.max(4),
            ref_margin: cfg.bisc_ref_margin,
            sweep_max_code: sweep.min(c::CODE_MAX),
            char_refs: Some((c::V_BIAS - win, c::V_BIAS + win)),
            adc_char,
        }
    }

    /// ADC references used during characterization: the custom operating-
    /// point window if set, else Alg. 1's widened defaults
    /// (V_L <- (1-m) V_L, V_H <- (1+m) V_H).
    pub fn widened_refs(&self) -> (f64, f64) {
        if let Some(refs) = self.char_refs {
            return refs;
        }
        (
            c::V_ADC_L * (1.0 - self.ref_margin),
            c::V_ADC_H * (1.0 + self.ref_margin),
        )
    }

    /// The stepped input codes of the characterization sweep: Z equally
    /// spaced magnitudes across the dynamic range (the line under
    /// test sees only one polarity; Section VI-D separates SA1/SA2).
    pub fn test_codes(&self) -> Vec<i32> {
        let z = self.test_points.max(2);
        (0..z)
            .map(|i| {
                let t = i as f64 / (z - 1) as f64;
                (t * 2.0 - 1.0) // -1..1
            })
            .map(|t| (t * self.sweep_max_code as f64).round() as i32)
            .collect()
    }

    /// Nominal (expected) output codes for the sweep with W_max programmed,
    /// evaluated at the *widened* ADC references: Q_nom per Eq. (7) with
    /// S = x * 63 * N on the line under test.
    pub fn nominal_codes(&self, positive_line: bool) -> Vec<f64> {
        let (v_l, v_h) = self.widened_refs();
        let c_adc = c::adc_conv_factor(v_l, v_h);
        let lsb_in = c::V_SWING / (1u64 << c::B_D) as f64;
        let k = c_adc * c::R_SA_NOM * lsb_in / (c::R_U * (1u64 << c::B_W) as f64);
        let mid = c_adc * (c::V_CAL_NOM - v_l);
        let sign = if positive_line { 1.0 } else { -1.0 };
        self.test_codes()
            .iter()
            .map(|&x| {
                let s = x as f64 * c::CODE_MAX as f64 * c::N_ROWS as f64 * sign;
                mid + k * s
            })
            .collect()
    }

    /// Characterize one line of one column: program the weights, sweep,
    /// fit. Assumes the ADC references are already widened. Leaves the
    /// column weights programmed (caller restores).
    fn characterize_line(
        &self,
        model: &mut CimAnalogModel,
        col: usize,
        positive_line: bool,
        reads: &mut u64,
    ) -> LineFit {
        let wmax = if positive_line { c::CODE_MAX } else { -c::CODE_MAX };
        model.program_column(col, &vec![wmax; c::N_ROWS]);
        let q_nom = self.nominal_codes(positive_line);
        let mut q_act = Vec::with_capacity(q_nom.len());
        for &x in &self.test_codes() {
            let xv = vec![x; c::N_ROWS];
            let avg = model.forward_averaged(&xv, self.averages);
            *reads += self.averages as u64;
            q_act.push(avg[col]);
        }
        let (g, e) = stats::linfit(&q_nom, &q_act);
        LineFit { g_tot: g, eps_tot: e }
    }

    /// Run the full BISC routine (Alg. 1) over every column of the array.
    ///
    /// The array's weights are clobbered by characterization; callers
    /// re-program their workload weights afterwards (on silicon the same
    /// is true — calibration happens between workloads).
    pub fn calibrate(&self, model: &mut CimAnalogModel) -> BiscReport {
        // Alg. 1 initialization: widen ADC references so characterization
        // never clips even with worst-case gain/offset errors
        let (vl_w, vh_w) = self.widened_refs();
        model.set_adc_refs(vl_w, vh_w);

        let mut reads = 0u64;
        let mut columns = Vec::with_capacity(c::M_COLS);
        for col in 0..c::M_COLS {
            let pos = self.characterize_line(model, col, true, &mut reads);
            let neg = self.characterize_line(model, col, false, &mut reads);
            // Eq. (12) gain correction, per line
            let a_d = self.adc_char.alpha_d;
            let b_d = self.adc_char.beta_d;
            let rsa_p = (a_d * c::R_SA_NOM / pos.g_tot)
                .clamp(samp::R_SA_MIN, samp::R_SA_MAX);
            let rsa_n = (a_d * c::R_SA_NOM / neg.g_tot)
                .clamp(samp::R_SA_MIN, samp::R_SA_MAX);
            // Offset correction. The paper sets V_CAL = V_ADC^L during
            // characterization so the fit intercept is the pure offset
            // (Section VI-B); our cal-DAC range cannot reach the widened
            // V_L', so the intercept contains a gain-pivot term
            // Q_mid' * (alpha_D - g_tot) that must be removed first
            // (DESIGN.md §6). With the pivot removed, beta_A follows
            // Eq. (11) and the corrected V_CAL makes the end-to-end
            // transfer nominal.
            let c_adc = c::adc_conv_factor(vl_w, vh_w);
            let q_mid_w = c_adc * (c::V_CAL_NOM - vl_w);
            let eps = 0.5 * (pos.eps_tot + neg.eps_tot);
            let g_avg = 0.5 * (pos.g_tot + neg.g_tot);
            let beta_a = (eps - b_d - q_mid_w * (a_d - g_avg)) / (c_adc * a_d);
            let vcal_target =
                vl_w + ((c::V_CAL_NOM - vl_w) - b_d / c_adc) / a_d - beta_a;
            let vcal = vcal_target.clamp(samp::V_CAL_MIN, samp::V_CAL_MAX);
            // quantize to trim codes and apply
            let pot_p = samp::rsa_to_pot(rsa_p);
            let pot_n = samp::rsa_to_pot(rsa_n);
            let cal = samp::vcal_to_cal(vcal);
            model.set_trims(col, pot_p, pot_n, cal);
            columns.push(ColumnCalibration {
                col,
                pos,
                neg,
                pot_p,
                pot_n,
                cal,
                rsa_p: samp::pot_to_rsa(pot_p),
                rsa_n: samp::pot_to_rsa(pot_n),
                vcal: samp::cal_to_vcal(cal),
            });
        }
        // restore the inference references (Alg. 1 epilogue)
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
        BiscReport { columns, reads }
    }

    /// One refinement pass: re-characterize at this engine's window with
    /// the previous trims applied and update them multiplicatively.
    pub fn refine(&self, model: &mut CimAnalogModel, report: &mut BiscReport) {
        let (vl_w, vh_w) = self.widened_refs();
        model.set_adc_refs(vl_w, vh_w);
        let c_adc = c::adc_conv_factor(vl_w, vh_w);
        let mut reads = 0u64;
        let a_d = self.adc_char.alpha_d;
        let b_d = self.adc_char.beta_d;
        for col in 0..c::M_COLS {
            let pos = self.characterize_line(model, col, true, &mut reads);
            let neg = self.characterize_line(model, col, false, &mut reads);
            let prev = &report.columns[col];
            // residual gain error g' scales the already-trimmed R_SA
            let rsa_p = (a_d * prev.rsa_p / pos.g_tot)
                .clamp(samp::R_SA_MIN, samp::R_SA_MAX);
            let rsa_n = (a_d * prev.rsa_n / neg.g_tot)
                .clamp(samp::R_SA_MIN, samp::R_SA_MAX);
            let q_mid_w = c_adc * (c::V_CAL_NOM - vl_w);
            let eps = 0.5 * (pos.eps_tot + neg.eps_tot);
            let g_avg = 0.5 * (pos.g_tot + neg.g_tot);
            let beta_res = (eps - b_d - q_mid_w * (a_d - g_avg)) / (c_adc * a_d);
            let vcal = (prev.vcal - beta_res).clamp(samp::V_CAL_MIN, samp::V_CAL_MAX);
            let pot_p = samp::rsa_to_pot(rsa_p);
            let pot_n = samp::rsa_to_pot(rsa_n);
            let cal = samp::vcal_to_cal(vcal);
            model.set_trims(col, pot_p, pot_n, cal);
            report.columns[col] = ColumnCalibration {
                col,
                pos,
                neg,
                pot_p,
                pot_n,
                cal,
                rsa_p: samp::pot_to_rsa(pot_p),
                rsa_n: samp::pot_to_rsa(pot_n),
                vcal: samp::cal_to_vcal(cal),
            };
        }
        report.reads += reads;
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
    }

    /// Iterative calibration: re-run characterization with the previous
    /// trims applied and refine them. The paper runs BISC "periodically at
    /// predefined intervals"; a second pass removes the second-order bias
    /// that amplifier nonlinearity induces in the first pass's offset
    /// estimate (the sweep is asymmetric until the gains are corrected).
    pub fn calibrate_iterative(&self, model: &mut CimAnalogModel, passes: usize) -> BiscReport {
        let mut report = self.calibrate(model);
        for _ in 1..passes {
            self.refine(model, &mut report);
        }
        report
    }

    /// Cascaded calibration for a small-signal workload (the DNN mapping):
    /// a full-range pass removes the large offset/gain errors, then an
    /// operating-point pass re-trims at the workload's own amplitude so the
    /// corrected gain matches the small-signal slope (the amplifier cubic
    /// makes the full-range secant differ from it).
    pub fn calibrate_for_workload(
        cfg: &SimConfig,
        adc_char: AdcCharacterization,
        model: &mut CimAnalogModel,
        op_half_v: f64,
    ) -> BiscReport {
        let full = Self::from_config(cfg, adc_char);
        let mut report = full.calibrate(model);
        let op = Self::for_operating_point(cfg, adc_char, op_half_v);
        op.refine(model, &mut report);
        report
    }

    /// Re-characterize (no correction) — used to measure residual errors
    /// after calibration (Fig. 8(e)). Uses the widened references like the
    /// calibration pass and restores the defaults afterwards.
    pub fn characterize_only(&self, model: &mut CimAnalogModel) -> Vec<(LineFit, LineFit)> {
        let (vl_w, vh_w) = self.widened_refs();
        model.set_adc_refs(vl_w, vh_w);
        let mut reads = 0u64;
        let fits = (0..c::M_COLS)
            .map(|col| {
                let p = self.characterize_line(model, col, true, &mut reads);
                let n = self.characterize_line(model, col, false, &mut reads);
                (p, n)
            })
            .collect();
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
        fits
    }

    /// Total latency of one calibration pass in S&H periods: Z test points
    /// x averages x 2 lines x M columns (Alg. 1's loop structure).
    pub fn latency_sh_periods(&self) -> u64 {
        (self.test_points * self.averages * 2 * c::M_COLS) as u64
    }

    /// Scalar health metric for the serving layer: mean per-line
    /// |g_tot - 1| over a fresh characterization. A freshly calibrated
    /// die sits well under the serving health band; an uncalibrated or
    /// drifted die sits far above it (see
    /// [`crate::coordinator::service::CoreContext::health_band`]).
    pub fn residual_gain_error(&self, model: &mut CimAnalogModel) -> f64 {
        residual_from_fits(&self.characterize_only(model))
    }
}

/// Mean per-line |g_tot - 1| of an existing characterization — the metric
/// of [`BiscEngine::residual_gain_error`] without re-measuring. The
/// serving layer keeps the fits from its last health characterization and
/// feeds them to both this and [`permanent_fault_mask`], so fault
/// classification costs no extra reads.
pub fn residual_from_fits(fits: &[(LineFit, LineFit)]) -> f64 {
    if fits.is_empty() {
        return 0.0;
    }
    fits.iter()
        .map(|(p, n)| 0.5 * ((p.g_tot - 1.0).abs() + (n.g_tot - 1.0).abs()))
        .sum::<f64>()
        / fits.len() as f64
}

/// A line whose fitted gain magnitude sits below this is *flat* — the
/// column does not respond to its inputs at all (dead column, railed SA,
/// wedged ADC slice).
pub const FAULT_DEAD_GAIN: f64 = 0.25;
/// A post-calibration per-line gain error beyond this is outside anything
/// the potentiometer trim range can produce on healthy silicon.
pub const FAULT_GAIN_ERROR: f64 = 0.5;

/// Per-column transient-vs-permanent fault classifier (DESIGN.md §16).
///
/// Call on a characterization taken AFTER a recalibration attempt: soft
/// error (variation, drift) calibrates out, so a healthy column's line
/// gains return to ~1 and clear both thresholds. A hard-faulted column
/// cannot be pulled in — its transfer is flat or its gain error exceeds
/// the trim range — and earns a bit in the returned mask. A nonzero mask
/// means the residual floor is permanent: the drain barrier retires the
/// core instead of rejoining it.
pub fn permanent_fault_mask(fits: &[(LineFit, LineFit)]) -> u32 {
    let mut mask = 0u32;
    for (col, (p, n)) in fits.iter().enumerate().take(c::M_COLS) {
        let worst = (p.g_tot - 1.0).abs().max((n.g_tot - 1.0).abs());
        let flat = p.g_tot.abs() < FAULT_DEAD_GAIN || n.g_tot.abs() < FAULT_DEAD_GAIN;
        if flat || worst > FAULT_GAIN_ERROR {
            mask |= 1u32 << col;
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::variation::VariationSample;

    fn noisy_model(seed: u64) -> CimAnalogModel {
        let mut cfg = SimConfig::default();
        cfg.seed = seed;
        let s = VariationSample::draw(&cfg);
        CimAnalogModel::from_sample(&cfg, &s)
    }

    fn engine() -> BiscEngine {
        BiscEngine {
            test_points: 8,
            averages: 4,
            ref_margin: 0.08,
            sweep_max_code: 48,
            char_refs: None,
            adc_char: AdcCharacterization::ideal(),
        }
    }

    #[test]
    fn test_codes_span_range() {
        let e = engine();
        let codes = e.test_codes();
        assert_eq!(codes.len(), 8);
        assert_eq!(codes[0], -48);
        assert_eq!(*codes.last().unwrap(), 48);
    }

    #[test]
    fn sweep_never_clips_at_worst_case_gain() {
        // worst-case die: g = 1.3, beta = +15 mV — the widened window must
        // keep every test point in the ADC's linear region
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        let mut s = VariationSample::ideal();
        s.alpha_p = vec![1.3; c::M_COLS];
        s.alpha_n = vec![1.3; c::M_COLS];
        s.beta = vec![0.015; c::M_COLS];
        let mut m = CimAnalogModel::from_sample(&cfg, &s);
        let e = engine();
        let (vl_w, vh_w) = e.widened_refs();
        m.set_adc_refs(vl_w, vh_w);
        m.program(&vec![c::CODE_MAX; c::N_ROWS * c::M_COLS]);
        for &x in &e.test_codes() {
            let v_sa = m.sa_outputs(&vec![x; c::N_ROWS]);
            for &v in &v_sa {
                assert!(!m.adc.clips(v), "clipped at x={x}, v={v}");
            }
        }
    }

    #[test]
    fn characterization_recovers_known_gain_offset() {
        // construct a die whose only error is a known SA gain + ADC offset
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        let mut s = VariationSample::ideal();
        s.alpha_p = vec![1.15; c::M_COLS];
        s.adc_beta = 2.0;
        let mut m = CimAnalogModel::from_sample(&cfg, &s);
        let e = engine();
        let (vl_w, vh_w) = e.widened_refs();
        m.set_adc_refs(vl_w, vh_w); // characterization runs at widened refs
        let mut reads = 0;
        let fit = e.characterize_line(&mut m, 5, true, &mut reads);
        // Z = 8 integer-code reads carry a deterministic quantization bias
        // of up to ~2% on the slope (no noise to dither it here)
        assert!((fit.g_tot - 1.15).abs() < 0.03, "g={}", fit.g_tot);
        // intercept = offset + gain-pivot Q_mid'*(1-g) (see calibrate())
        let q_mid_w = c::adc_conv_factor(e.widened_refs().0, e.widened_refs().1)
            * (c::V_CAL_NOM - e.widened_refs().0);
        let expect_eps = 2.0 + q_mid_w * (1.0 - 1.15);
        assert!((fit.eps_tot - expect_eps).abs() < 0.8, "e={}", fit.eps_tot);
    }

    #[test]
    fn calibration_reduces_residual_errors() {
        let mut m = noisy_model(0xBEEF);
        let e = engine();
        // before: residual = characterization at default trims
        let before = e.characterize_only(&mut m);
        let report = e.calibrate(&mut m);
        assert_eq!(report.columns.len(), c::M_COLS);
        let after = e.characterize_only(&mut m);
        let gain_err = |fits: &Vec<(LineFit, LineFit)>| -> f64 {
            fits.iter()
                .map(|(p, n)| (p.g_tot - 1.0).abs() + (n.g_tot - 1.0).abs())
                .sum::<f64>()
                / (2.0 * fits.len() as f64)
        };
        let off_err = |fits: &Vec<(LineFit, LineFit)>| -> f64 {
            fits.iter()
                .map(|(p, n)| (p.eps_tot.abs() + n.eps_tot.abs()) / 2.0)
                .sum::<f64>()
                / fits.len() as f64
        };
        assert!(
            gain_err(&after) < gain_err(&before) * 0.35,
            "gain {} -> {}",
            gain_err(&before),
            gain_err(&after)
        );
        assert!(
            off_err(&after) < off_err(&before) * 0.75,
            "offset {} -> {}",
            off_err(&before),
            off_err(&after)
        );
    }

    #[test]
    fn every_column_improves() {
        let mut m = noisy_model(0xACE);
        let e = engine();
        let before = e.characterize_only(&mut m);
        e.calibrate(&mut m);
        let after = e.characterize_only(&mut m);
        for col in 0..c::M_COLS {
            let b = (before[col].0.g_tot - 1.0).abs() + (before[col].1.g_tot - 1.0).abs();
            let a = (after[col].0.g_tot - 1.0).abs() + (after[col].1.g_tot - 1.0).abs();
            assert!(a < b + 0.02, "col {col}: gain {b} -> {a}");
        }
    }

    #[test]
    fn known_adc_characterization_improves_correction() {
        // with a strong ADC gain error, knowing (alpha_D, beta_D) lets BISC
        // split analog vs digital (Eq. 11) — but either way the end-to-end
        // transfer must be linearized
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        let mut s = VariationSample::ideal();
        s.adc_alpha = 1.12;
        s.alpha_p = vec![0.9; c::M_COLS];
        s.alpha_n = vec![0.9; c::M_COLS];
        let mut m = CimAnalogModel::from_sample(&cfg, &s);
        let mut e = engine();
        e.adc_char = AdcCharacterization::from_model(&m);
        e.calibrate(&mut m);
        let after = e.characterize_only(&mut m);
        // Eq. (12) corrects the *analog* gain to 1/alpha_A exactly, so the
        // residual end-to-end gain equals the known digital gain alpha_D
        // (which the digital side compensates numerically, Eq. 11)
        for (p, _) in &after {
            assert!((p.g_tot - 1.12).abs() < 0.04, "g={}", p.g_tot);
        }
        // whereas assuming an ideal ADC absorbs alpha_D into the trims,
        // linearizing end-to-end:
        let mut m2 = CimAnalogModel::from_sample(&cfg, &s);
        let mut e2 = engine();
        e2.adc_char = AdcCharacterization::ideal();
        e2.calibrate(&mut m2);
        let after2 = e2.characterize_only(&mut m2);
        for (p, _) in &after2 {
            assert!((p.g_tot - 1.0).abs() < 0.04, "g={}", p.g_tot);
        }
    }

    #[test]
    fn latency_accounting() {
        let e = engine();
        assert_eq!(e.latency_sh_periods(), 8 * 4 * 2 * 32);
    }

    #[test]
    fn classifier_flags_hard_faults_and_clears_soft_error() {
        let mut m = noisy_model(31);
        let e = engine();
        // soft error calibrates out: zero permanent bits after a recal
        e.calibrate(&mut m);
        let fits = e.characterize_only(&mut m);
        assert_eq!(permanent_fault_mask(&fits), 0, "soft error must classify transient");
        assert!(residual_from_fits(&fits) < 0.05);
        // hard faults persist across the next recal attempt
        let plan =
            crate::analog::faults::FaultPlan::parse("col=5,adc=11:40,sa=19:0.52").unwrap();
        m.apply_faults(&plan.events[0].map);
        e.calibrate(&mut m);
        let fits = e.characterize_only(&mut m);
        let mask = permanent_fault_mask(&fits);
        assert_eq!(mask, (1 << 5) | (1 << 11) | (1 << 19), "mask {mask:#010x}");
        // healthy columns still classify clean under the same fits
        assert_eq!(mask & (1 << 0), 0);
    }

    #[test]
    fn report_trims_within_hardware_range() {
        let mut m = noisy_model(7);
        let e = engine();
        let r = e.calibrate(&mut m);
        for cc in &r.columns {
            assert!(cc.pot_p <= samp::POT_MAX);
            assert!(cc.pot_n <= samp::POT_MAX);
            assert!(cc.cal <= samp::CAL_MAX);
            assert!(cc.rsa_p >= samp::R_SA_MIN && cc.rsa_p <= samp::R_SA_MAX);
        }
    }
}
