//! The CIM accelerator as an AXI4-Lite device: control/status registers,
//! input-code registers, weight-SRAM write port, ADC output registers, and
//! the BISC trim registers (paper Fig. 2(a) "CIM control registers ...
//! interfaced via AXI4-Lite").
//!
//! The same register map is driven by (a) the host-side coordinator (rust
//! API) and (b) the BISC firmware running on the RV32IM ISS — the paper's
//! "RISC-V controlled" property is literal here.

use crate::analog::{consts as c, samp, CimAnalogModel};
use crate::soc::bus::{BusDevice, BusResp};

/// Register map (byte offsets). All registers are 32-bit.
pub mod regs {
    /// write 1 = single MAC; write 2 = averaged MAC (AVG_CNT reads)
    pub const CTRL: u32 = 0x000;
    /// bit0: done (always 1 — the model computes synchronously)
    pub const STATUS: u32 = 0x004;
    /// averaging count for CTRL=2 (default 4)
    pub const AVG_CNT: u32 = 0x008;
    /// number of MAC operations performed (read-only)
    pub const MAC_COUNT: u32 = 0x00C;
    /// accumulated analog busy time, in S&H periods (read-only)
    pub const BUSY_SH: u32 = 0x010;
    /// input code registers, signed i32, INPUT[0..36]
    pub const INPUT: u32 = 0x020;
    /// latched ADC output codes, OUT[0..32]
    pub const OUT: u32 = 0x100;
    /// averaged outputs in Q8.8 fixed point, OUT_AVG[0..32]
    pub const OUT_AVG_Q8: u32 = 0x180;
    /// digital potentiometer codes, positive line, POT_P[0..32]
    pub const POT_P: u32 = 0x200;
    /// negative line, POT_N[0..32]
    pub const POT_N: u32 = 0x280;
    /// calibration DAC codes, CAL[0..32]
    pub const CAL: u32 = 0x300;
    /// ADC reference voltages in microvolts
    pub const VADC_L_UV: u32 = 0x380;
    pub const VADC_H_UV: u32 = 0x384;
    /// weight write port: address (row-major cell index, auto-increment)
    pub const WADDR: u32 = 0x400;
    /// weight write port: signed code; writing programs cell at WADDR
    pub const WDATA: u32 = 0x404;
    /// size of the register window
    pub const SIZE: u32 = 0x1000;
}

pub struct CimDevice {
    pub model: CimAnalogModel,
    inputs: [i32; c::N_ROWS],
    out: [u32; c::M_COLS],
    out_avg_q8: [u32; c::M_COLS],
    avg_cnt: u32,
    waddr: u32,
    mac_count: u32,
    /// analog busy time in S&H periods (1 us each)
    busy_sh: u64,
}

impl CimDevice {
    pub fn new(model: CimAnalogModel) -> Self {
        Self {
            model,
            inputs: [0; c::N_ROWS],
            out: [0; c::M_COLS],
            out_avg_q8: [0; c::M_COLS],
            avg_cnt: 4,
            waddr: 0,
            mac_count: 0,
            busy_sh: 0,
        }
    }

    /// Host-side convenience: program full weight matrix.
    pub fn program_weights(&mut self, weights: &[i32]) {
        self.model.program(weights);
    }

    pub fn mac_count(&self) -> u32 {
        self.mac_count
    }

    pub fn busy_sh_periods(&self) -> u64 {
        self.busy_sh
    }

    fn do_mac(&mut self) {
        // the analog busy time is the drift clock: every S&H period of
        // real reads ages the die (no-op on a frozen die)
        self.model.advance_drift(1);
        let q = self.model.forward_golden(&self.inputs);
        self.out.copy_from_slice(&q);
        self.mac_count = self.mac_count.wrapping_add(1);
        self.busy_sh += 1;
    }

    fn do_mac_averaged(&mut self) {
        let reads = self.avg_cnt.max(1) as usize;
        self.model.advance_drift(reads as u64);
        let avg = self.model.forward_averaged(&self.inputs, reads);
        for (dst, &a) in self.out_avg_q8.iter_mut().zip(&avg) {
            *dst = (a * 256.0).round() as u32;
        }
        // also latch the last single read approximation (rounded average)
        for (dst, &a) in self.out.iter_mut().zip(&avg) {
            *dst = a.round().clamp(0.0, c::ADC_MAX as f64) as u32;
        }
        self.mac_count = self.mac_count.wrapping_add(reads as u32);
        self.busy_sh += reads as u64;
    }

    fn idx(offset: u32, base: u32) -> usize {
        ((offset - base) / 4) as usize
    }
}

impl BusDevice for CimDevice {
    fn read32(&mut self, offset: u32) -> Result<u32, BusResp> {
        use regs::*;
        Ok(match offset {
            STATUS => 1,
            AVG_CNT => self.avg_cnt,
            MAC_COUNT => self.mac_count,
            BUSY_SH => self.busy_sh as u32,
            o if (INPUT..INPUT + 4 * c::N_ROWS as u32).contains(&o) => {
                self.inputs[Self::idx(o, INPUT)] as u32
            }
            o if (OUT..OUT + 4 * c::M_COLS as u32).contains(&o) => {
                self.out[Self::idx(o, OUT)]
            }
            o if (OUT_AVG_Q8..OUT_AVG_Q8 + 4 * c::M_COLS as u32).contains(&o) => {
                self.out_avg_q8[Self::idx(o, OUT_AVG_Q8)]
            }
            o if (POT_P..POT_P + 4 * c::M_COLS as u32).contains(&o) => {
                self.model.amps[Self::idx(o, POT_P)].pot_p
            }
            o if (POT_N..POT_N + 4 * c::M_COLS as u32).contains(&o) => {
                self.model.amps[Self::idx(o, POT_N)].pot_n
            }
            o if (CAL..CAL + 4 * c::M_COLS as u32).contains(&o) => {
                self.model.amps[Self::idx(o, CAL)].cal
            }
            VADC_L_UV => (self.model.adc.v_l * 1e6).round() as u32,
            VADC_H_UV => (self.model.adc.v_h * 1e6).round() as u32,
            WADDR => self.waddr,
            _ => return Err(BusResp::SlvErr),
        })
    }

    fn write32(&mut self, offset: u32, value: u32) -> Result<(), BusResp> {
        use regs::*;
        match offset {
            CTRL => match value {
                1 => self.do_mac(),
                2 => self.do_mac_averaged(),
                _ => return Err(BusResp::SlvErr),
            },
            AVG_CNT => self.avg_cnt = value.max(1),
            o if (INPUT..INPUT + 4 * c::N_ROWS as u32).contains(&o) => {
                self.inputs[Self::idx(o, INPUT)] =
                    (value as i32).clamp(-c::CODE_MAX, c::CODE_MAX);
            }
            o if (POT_P..POT_P + 4 * c::M_COLS as u32).contains(&o) => {
                let col = Self::idx(o, POT_P);
                let amp = &self.model.amps[col];
                let (pn, cal) = (amp.pot_n, amp.cal);
                self.model.set_trims(col, value.min(samp::POT_MAX), pn, cal);
            }
            o if (POT_N..POT_N + 4 * c::M_COLS as u32).contains(&o) => {
                let col = Self::idx(o, POT_N);
                let amp = &self.model.amps[col];
                let (pp, cal) = (amp.pot_p, amp.cal);
                self.model.set_trims(col, pp, value.min(samp::POT_MAX), cal);
            }
            o if (CAL..CAL + 4 * c::M_COLS as u32).contains(&o) => {
                let col = Self::idx(o, CAL);
                let amp = &self.model.amps[col];
                let (pp, pn) = (amp.pot_p, amp.pot_n);
                self.model.set_trims(col, pp, pn, value.min(samp::CAL_MAX));
            }
            VADC_L_UV => {
                let v_h = self.model.adc.v_h;
                self.model.set_adc_refs(value as f64 * 1e-6, v_h);
            }
            VADC_H_UV => {
                let v_l = self.model.adc.v_l;
                self.model.set_adc_refs(v_l, value as f64 * 1e-6);
            }
            WADDR => self.waddr = value % (c::N_ROWS * c::M_COLS) as u32,
            WDATA => {
                let idx = self.waddr as usize;
                let (row, col) = (idx / c::M_COLS, idx % c::M_COLS);
                let delta = self.model.array.cell(row, col).delta;
                *self.model.array.cell_mut(row, col) =
                    crate::analog::mwc::Mwc::program(value as i32).with_delta(delta);
                self.model.invalidate_fold();
                self.waddr = (self.waddr + 1) % (c::N_ROWS * c::M_COLS) as u32;
            }
            _ => return Err(BusResp::SlvErr),
        }
        Ok(())
    }

    fn size(&self) -> u32 {
        regs::SIZE
    }

    fn name(&self) -> &str {
        "cim"
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::CimAnalogModel;

    fn ideal_device() -> CimDevice {
        CimDevice::new(CimAnalogModel::ideal())
    }

    #[test]
    fn mac_through_registers_matches_direct_model() {
        let mut dev = ideal_device();
        let weights: Vec<i32> = (0..c::N_ROWS * c::M_COLS)
            .map(|i| ((i as i32 * 11) % 127) - 63)
            .collect();
        // program through the write port
        dev.write32(regs::WADDR, 0).unwrap();
        for &w in &weights {
            dev.write32(regs::WDATA, w as u32).unwrap();
        }
        // inputs
        for r in 0..c::N_ROWS {
            dev.write32(regs::INPUT + 4 * r as u32, ((r as i32 % 40) - 20) as u32).unwrap();
        }
        dev.write32(regs::CTRL, 1).unwrap();
        let via_regs: Vec<u32> = (0..c::M_COLS)
            .map(|col| dev.read32(regs::OUT + 4 * col as u32).unwrap())
            .collect();
        // direct model path
        let mut m = CimAnalogModel::ideal();
        m.program(&weights);
        let x: Vec<i32> = (0..c::N_ROWS).map(|r| (r as i32 % 40) - 20).collect();
        let direct = m.forward_batch(&x, 1);
        assert_eq!(via_regs, direct);
        assert_eq!(dev.mac_count(), 1);
    }

    #[test]
    fn waddr_autoincrements_and_wraps() {
        let mut dev = ideal_device();
        dev.write32(regs::WADDR, (c::N_ROWS * c::M_COLS - 1) as u32).unwrap();
        dev.write32(regs::WDATA, 5).unwrap();
        assert_eq!(dev.read32(regs::WADDR).unwrap(), 0);
    }

    #[test]
    fn trim_registers_reach_the_amps() {
        let mut dev = ideal_device();
        dev.write32(regs::POT_P + 4 * 3, 200).unwrap();
        dev.write32(regs::POT_N + 4 * 3, 100).unwrap();
        dev.write32(regs::CAL + 4 * 3, 50).unwrap();
        assert_eq!(dev.model.amps[3].pot_p, 200);
        assert_eq!(dev.model.amps[3].pot_n, 100);
        assert_eq!(dev.model.amps[3].cal, 50);
        // readback
        assert_eq!(dev.read32(regs::POT_P + 12).unwrap(), 200);
    }

    #[test]
    fn trim_codes_clamped_to_width() {
        let mut dev = ideal_device();
        dev.write32(regs::POT_P, 9999).unwrap();
        dev.write32(regs::CAL, 9999).unwrap();
        assert_eq!(dev.model.amps[0].pot_p, samp::POT_MAX);
        assert_eq!(dev.model.amps[0].cal, samp::CAL_MAX);
    }

    #[test]
    fn adc_refs_in_microvolts() {
        let mut dev = ideal_device();
        dev.write32(regs::VADC_L_UV, 190_000).unwrap();
        dev.write32(regs::VADC_H_UV, 630_000).unwrap();
        assert!((dev.model.adc.v_l - 0.19).abs() < 1e-9);
        assert!((dev.model.adc.v_h - 0.63).abs() < 1e-9);
        assert_eq!(dev.read32(regs::VADC_L_UV).unwrap(), 190_000);
    }

    #[test]
    fn averaged_read_q8_fixed_point() {
        let mut dev = ideal_device();
        dev.program_weights(&vec![63; c::N_ROWS * c::M_COLS]);
        for r in 0..c::N_ROWS {
            dev.write32(regs::INPUT + 4 * r as u32, 40).unwrap();
        }
        dev.write32(regs::AVG_CNT, 8).unwrap();
        dev.write32(regs::CTRL, 2).unwrap();
        let q8 = dev.read32(regs::OUT_AVG_Q8).unwrap();
        let single = dev.read32(regs::OUT).unwrap();
        // noise-free ideal die: average == single read exactly
        assert_eq!(q8, single * 256);
        assert_eq!(dev.mac_count(), 8);
    }

    #[test]
    fn mac_reads_advance_the_drift_clock() {
        use crate::analog::variation::VariationSample;
        use crate::config::SimConfig;
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        cfg.sigma_drift = 5e-4;
        let sample = VariationSample::draw(&cfg);
        let mut dev = CimDevice::new(CimAnalogModel::from_sample(&cfg, &sample));
        dev.program_weights(&vec![40; c::N_ROWS * c::M_COLS]);
        for r in 0..c::N_ROWS {
            dev.write32(regs::INPUT + 4 * r as u32, 30).unwrap();
        }
        dev.write32(regs::CTRL, 1).unwrap();
        assert_eq!(dev.model.drift_age(), 1, "one MAC = one drift unit");
        dev.write32(regs::AVG_CNT, 8).unwrap();
        dev.write32(regs::CTRL, 2).unwrap();
        // averaged reads age the die by their full analog busy time
        assert_eq!(dev.model.drift_age(), 9);
        assert_eq!(dev.model.drift_age(), dev.busy_sh_periods());
    }

    #[test]
    fn invalid_register_is_slverr() {
        let mut dev = ideal_device();
        assert_eq!(dev.read32(0xFFC).unwrap_err(), BusResp::SlvErr);
        assert_eq!(dev.write32(regs::CTRL, 99).unwrap_err(), BusResp::SlvErr);
    }

    #[test]
    fn input_codes_clamped() {
        let mut dev = ideal_device();
        dev.write32(regs::INPUT, 1000).unwrap();
        assert_eq!(dev.read32(regs::INPUT).unwrap() as i32, 63);
        dev.write32(regs::INPUT, (-1000i32) as u32).unwrap();
        assert_eq!(dev.read32(regs::INPUT).unwrap() as i32, -63);
    }
}
