//! Request batcher: aggregates MAC requests from concurrent clients into
//! array-sized batches for the backend — the serving-layer role of the
//! coordinator (cf. vllm-style routers, scaled to this accelerator:
//! batched pulses on a physical array). The multi-array scatter-gather
//! layer on top of this lives in [`crate::coordinator::cluster`].
//!
//! Design: submitters push `MacRequest`s over an mpsc channel; the worker
//! drains up to `max_batch` requests (waiting up to `max_wait` for the
//! first), executes them as one batched forward, and answers each client
//! over its own return channel. std threads + channels (tokio is not
//! vendored; the workload is CPU-bound anyway).
//!
//! Failure handling: a malformed request (wrong input length) is rejected
//! with [`ServeError::BadRequest`] on its own reply channel — it must
//! never kill the worker and strand every other queued client. A client
//! whose worker has shut down gets [`ServeError::Disconnected`] instead
//! of a panic.

use crate::analog::consts as c;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Serving-layer errors surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request was rejected before evaluation (e.g. wrong input size).
    BadRequest { expected: usize, got: usize },
    /// The backend failed to evaluate the batch (worker stays alive; the
    /// whole batch is answered with this error).
    Backend(String),
    /// The serving worker has shut down (channel closed mid-flight).
    Disconnected,
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadRequest { expected, got } => {
                write!(f, "bad MAC request: expected {expected} input codes, got {got}")
            }
            ServeError::Backend(msg) => write!(f, "backend failed: {msg}"),
            ServeError::Disconnected => write!(f, "serving worker disconnected"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a client receives back for one MAC request.
pub type MacReply = Result<Vec<u32>, ServeError>;

pub struct MacRequest {
    pub x: Vec<i32>,
    pub reply: Sender<MacReply>,
}

/// Statistics from a batcher run.
#[derive(Debug, Default, Clone, Copy)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_seen: usize,
    /// requests answered with an error instead of a result — malformed
    /// requests and members of a failed batch (not counted in `requests`)
    pub rejected: u64,
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Fold another worker's statistics into this one (cluster gather).
    pub fn merge(&mut self, other: &BatcherStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.max_batch_seen = self.max_batch_seen.max(other.max_batch_seen);
        self.rejected += other.rejected;
    }
}

/// A backend that evaluates batches of MAC requests. A failed batch is an
/// `Err` — the batcher answers every request in it with
/// [`ServeError::Backend`] and keeps serving.
pub trait MacBackend {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String>;
}

impl MacBackend for crate::analog::CimAnalogModel {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
        Ok(crate::analog::CimAnalogModel::forward_batch(self, x, batch))
    }
}

impl MacBackend for crate::runtime::CimRuntime {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
        crate::runtime::CimRuntime::forward_batch(self, x, batch).map_err(|e| e.0)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Batcher {
    fn default() -> Self {
        Self { max_batch: 256, max_wait: Duration::from_micros(200) }
    }
}

impl Batcher {
    /// Validate a request; reject it on its own reply channel if malformed.
    /// Returns the request back when it is well-formed.
    fn admit(r: MacRequest, stats: &mut BatcherStats) -> Option<MacRequest> {
        if r.x.len() == c::N_ROWS {
            Some(r)
        } else {
            stats.rejected += 1;
            let _ = r
                .reply
                .send(Err(ServeError::BadRequest { expected: c::N_ROWS, got: r.x.len() }));
            None
        }
    }

    /// Serve until the request channel closes. Returns run statistics.
    pub fn run<B: MacBackend>(&self, rx: Receiver<MacRequest>, backend: &mut B) -> BatcherStats {
        let mut stats = BatcherStats::default();
        loop {
            // block for the first request of a batch
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return stats,
            };
            let mut pending = Vec::with_capacity(self.max_batch.min(64));
            if let Some(r) = Self::admit(first, &mut stats) {
                pending.push(r);
            }
            // opportunistically drain more, up to max_batch / max_wait
            let deadline = std::time::Instant::now() + self.max_wait;
            while pending.len() < self.max_batch {
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => {
                        if let Some(r) = Self::admit(r, &mut stats) {
                            pending.push(r);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            if pending.is_empty() {
                continue; // everything in this round was rejected
            }
            // assemble the batch
            let batch = pending.len();
            let mut x = Vec::with_capacity(batch * c::N_ROWS);
            for r in &pending {
                x.extend_from_slice(&r.x);
            }
            match backend.forward_batch(&x, batch) {
                Ok(q) => {
                    for (i, r) in pending.into_iter().enumerate() {
                        let out = q[i * c::M_COLS..(i + 1) * c::M_COLS].to_vec();
                        let _ = r.reply.send(Ok(out)); // client may have gone away
                    }
                    stats.requests += batch as u64;
                    stats.batches += 1;
                    stats.max_batch_seen = stats.max_batch_seen.max(batch);
                }
                Err(msg) => {
                    // the batch failed, the worker survives: answer every
                    // request with the backend error and keep serving
                    for r in pending {
                        let _ = r.reply.send(Err(ServeError::Backend(msg.clone())));
                    }
                    stats.rejected += batch as u64;
                }
            }
        }
    }
}

/// Convenience client handle for a single worker channel.
pub struct Client {
    tx: Sender<MacRequest>,
}

impl Client {
    pub fn new(tx: Sender<MacRequest>) -> Self {
        Self { tx }
    }

    /// Submit one MAC and wait for the reply. Never panics: a shut-down
    /// worker surfaces as `Err(ServeError::Disconnected)`.
    pub fn mac(&self, x: Vec<i32>) -> Result<Vec<u32>, ServeError> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(MacRequest { x, reply: reply_tx })
            .map_err(|_| ServeError::Disconnected)?;
        reply_rx.recv().map_err(|_| ServeError::Disconnected)?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::CimAnalogModel;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn spawn_batcher(
        batcher: Batcher,
    ) -> (Sender<MacRequest>, std::thread::JoinHandle<BatcherStats>) {
        let (tx, rx) = channel::<MacRequest>();
        let handle = std::thread::spawn(move || {
            let mut model = CimAnalogModel::ideal();
            model.program(&vec![40; c::N_ROWS * c::M_COLS]);
            batcher.run(rx, &mut model)
        });
        (tx, handle)
    }

    #[test]
    fn single_client_roundtrip() {
        let (tx, handle) = spawn_batcher(Batcher::default());
        let client = Client::new(tx.clone());
        let q = client.mac(vec![30; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        // matches a direct evaluation
        let mut model = CimAnalogModel::ideal();
        model.program(&vec![40; c::N_ROWS * c::M_COLS]);
        let direct = model.forward_batch(&vec![30; c::N_ROWS], 1);
        assert_eq!(q, direct);
        drop(client);
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn concurrent_clients_all_answered_correctly() {
        let (tx, handle) = spawn_batcher(Batcher {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
        });
        let tx = Arc::new(tx);
        let mut joins = Vec::new();
        for t in 0..8 {
            let tx = Sender::clone(&tx);
            joins.push(std::thread::spawn(move || {
                let client = Client::new(tx);
                let mut rng = Rng::new(t as u64);
                for _ in 0..20 {
                    let x: Vec<i32> =
                        (0..c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
                    let q = client.mac(x.clone()).unwrap();
                    // verify against an independent model
                    let mut model = CimAnalogModel::ideal();
                    model.program(&vec![40; c::N_ROWS * c::M_COLS]);
                    assert_eq!(q, model.forward_batch(&x, 1));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 8 * 20);
        assert!(stats.batches <= stats.requests);
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let (tx, handle) = spawn_batcher(Batcher {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
        });
        // pre-queue many requests before the worker can drain them
        let mut replies = Vec::new();
        for _ in 0..50 {
            let (rtx, rrx) = channel();
            tx.send(MacRequest { x: vec![10; c::N_ROWS], reply: rtx }).unwrap();
            replies.push(rrx);
        }
        for r in replies {
            assert_eq!(r.recv().unwrap().unwrap().len(), c::M_COLS);
        }
        drop(tx);
        let stats = handle.join().unwrap();
        assert!(
            stats.mean_batch() > 2.0,
            "expected batching, mean batch {}",
            stats.mean_batch()
        );
        assert!(stats.max_batch_seen > 4);
    }

    #[test]
    fn malformed_request_rejected_without_killing_worker() {
        let (tx, handle) = spawn_batcher(Batcher::default());
        let client = Client::new(tx.clone());
        // wrong input length: must come back as BadRequest, not a panic
        let err = client.mac(vec![1; 3]).unwrap_err();
        assert_eq!(err, ServeError::BadRequest { expected: c::N_ROWS, got: 3 });
        // the worker must still be alive and serving
        let q = client.mac(vec![30; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn bad_request_inside_a_batch_spares_the_others() {
        let (tx, handle) = spawn_batcher(Batcher {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
        });
        let mut replies = Vec::new();
        for i in 0..10 {
            let (rtx, rrx) = channel();
            let x = if i == 4 { vec![0; 7] } else { vec![10; c::N_ROWS] };
            tx.send(MacRequest { x, reply: rtx }).unwrap();
            replies.push(rrx);
        }
        for (i, r) in replies.into_iter().enumerate() {
            let reply = r.recv().unwrap();
            if i == 4 {
                assert!(matches!(reply, Err(ServeError::BadRequest { .. })));
            } else {
                assert_eq!(reply.unwrap().len(), c::M_COLS);
            }
        }
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 9);
        assert_eq!(stats.rejected, 1);
    }

    /// Backend that fails its first batch, then recovers.
    struct FlakyBackend {
        fail: bool,
    }

    impl MacBackend for FlakyBackend {
        fn forward_batch(&mut self, _x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
            if self.fail {
                self.fail = false;
                Err("transient backend failure".to_string())
            } else {
                Ok(vec![0; batch * c::M_COLS])
            }
        }
    }

    #[test]
    fn backend_failure_answers_batch_and_keeps_serving() {
        let (tx, rx) = channel::<MacRequest>();
        let handle = std::thread::spawn(move || {
            let mut backend = FlakyBackend { fail: true };
            Batcher::default().run(rx, &mut backend)
        });
        let client = Client::new(tx.clone());
        let err = client.mac(vec![0; c::N_ROWS]).unwrap_err();
        assert_eq!(err, ServeError::Backend("transient backend failure".to_string()));
        // the worker must survive a backend failure and serve the next batch
        let q = client.mac(vec![0; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn client_survives_worker_shutdown() {
        let (tx, handle) = spawn_batcher(Batcher::default());
        let client = Client::new(tx.clone());
        drop(tx);
        // answer one request, then shut the worker down by dropping the
        // last sender (the client's own); a subsequent call must error.
        let q = client.mac(vec![5; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        // a client whose channel is already closed gets Disconnected
        let (dead_tx, dead_rx) = channel::<MacRequest>();
        drop(dead_rx);
        let dead = Client::new(dead_tx);
        assert_eq!(dead.mac(vec![5; c::N_ROWS]).unwrap_err(), ServeError::Disconnected);
    }
}
