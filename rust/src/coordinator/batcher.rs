//! Request batcher: the per-core serving worker behind the unified
//! [`crate::coordinator::service`] job API (cf. vllm-style routers,
//! scaled to this accelerator: batched pulses on a physical array). The
//! multi-array placement/fencing layer on top of this lives in
//! [`crate::coordinator::cluster`].
//!
//! Design: submitters push [`JobEnvelope`]s over an mpsc channel; the
//! worker drains them into a local priority queue (priority descending,
//! submission order within a priority), expires jobs whose deadline has
//! passed, coalesces adjacent `Mac` jobs into array-sized batches,
//! executes `MacBatch` jobs natively (one backend call for the whole
//! batch), and runs `Drain`/`Health` lifecycle jobs against the shared
//! [`crate::coordinator::service::CoreBoard`]. std threads + channels
//! (tokio is not vendored; the workload is CPU-bound anyway).
//!
//! Failure handling: a malformed request (wrong input length for the
//! backend's geometry) is rejected with [`ServeError::BadRequest`] on its
//! own reply channel — it must never kill the worker and strand every
//! other queued client. A job still queued past its deadline is answered
//! with [`ServeError::DeadlineExceeded`] (never silently dropped). A
//! client whose worker has shut down gets [`ServeError::Disconnected`]
//! instead of a panic.
//!
//! This file is panic-free by policy: a panic here is a silent core
//! outage, so `acore-cim lint` (rule `panic_free`, DESIGN.md §12) and the
//! clippy deny below gate every unwrap/expect/panic/index out of non-test
//! code.

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::analog::consts as c;
use crate::coordinator::bisc::BiscEngine;
use crate::coordinator::service::{
    CoreContext, CoreHealth, Job, JobEnvelope, JobReply, TileRef,
};
use crate::util::sync::lock_unpoisoned;
use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serving-layer errors surfaced to clients.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request was rejected before evaluation (e.g. wrong input size
    /// for the backend's array geometry).
    BadRequest { expected: usize, got: usize },
    /// The backend failed to evaluate the batch (worker stays alive; the
    /// whole batch is answered with this error).
    Backend(String),
    /// The serving worker has shut down (channel closed mid-flight).
    Disconnected,
    /// The job was still queued when its deadline passed; it was never
    /// executed.
    DeadlineExceeded,
    /// Every core eligible under the placement policy is fenced.
    NoHealthyCore,
    /// No core on the cluster holds the requested model (and tile, when
    /// one was named) — `Placement::Model` against an unknown model.
    ModelNotResident { model: u32 },
    /// The job named a model that is not resident on the core it landed
    /// on — a placement decision raced by a concurrent rollout, caught
    /// at execution time instead of computing against the wrong weights.
    WrongModel { requested: u32, resident: Option<u32> },
    /// The front-end refused the job at admission: either this
    /// connection's in-flight ceiling or the cluster-wide shedding
    /// threshold was exceeded. Retry after in-flight work drains —
    /// queuing past the ceiling would only convert the overload into
    /// [`ServeError::DeadlineExceeded`] later.
    Overloaded { in_flight: usize, limit: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::BadRequest { expected, got } => {
                write!(f, "bad MAC request: expected {expected} input codes, got {got}")
            }
            ServeError::Backend(msg) => write!(f, "backend failed: {msg}"),
            ServeError::Disconnected => write!(f, "serving worker disconnected"),
            ServeError::DeadlineExceeded => {
                write!(f, "deadline exceeded before the job was executed")
            }
            ServeError::NoHealthyCore => {
                write!(f, "no healthy core available under the placement policy")
            }
            ServeError::ModelNotResident { model } => {
                write!(f, "model {model} is not resident on any core")
            }
            ServeError::WrongModel { requested, resident } => match resident {
                Some(r) => write!(
                    f,
                    "job for model {requested} landed on a core now serving model {r}"
                ),
                None => write!(
                    f,
                    "job for model {requested} landed on a core with no model resident"
                ),
            },
            ServeError::Overloaded { in_flight, limit } => write!(
                f,
                "overloaded: {in_flight} jobs in flight against a limit of {limit}; retry later"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Statistics from a batcher run.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct BatcherStats {
    /// MAC evaluations answered successfully (batch members count
    /// individually; `Drain`/`Health` control jobs are not counted)
    pub requests: u64,
    /// backend invocations
    pub batches: u64,
    pub max_batch_seen: usize,
    /// requests answered with an error instead of a result — malformed
    /// requests and members of a failed batch (not counted in `requests`)
    pub rejected: u64,
    /// requests answered with [`ServeError::DeadlineExceeded`] because
    /// they were still queued when their deadline passed
    pub expired: u64,
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Fold another worker's statistics into this one (cluster gather).
    pub fn merge(&mut self, other: &BatcherStats) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.max_batch_seen = self.max_batch_seen.max(other.max_batch_seen);
        self.rejected += other.rejected;
        self.expired += other.expired;
    }
}

/// Per-model serving counters of one worker, keyed by the core's resident
/// model when the job was answered. A cluster gather merges them across
/// cores with [`merge_model_stats`].
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ModelStats {
    pub model: u32,
    /// MAC evaluations answered successfully for this model
    pub requests: u64,
    /// requests answered with an error (malformed, failed batch,
    /// wrong-model admission)
    pub rejected: u64,
    /// requests answered with [`ServeError::DeadlineExceeded`]
    pub expired: u64,
    /// in-service recalibrations (drains and rollouts) completed while
    /// this model was resident; a rollout counts under the NEW model
    pub recals: u64,
}

/// Merge per-core model counters into a cluster-wide set, by model id.
pub fn merge_model_stats(into: &mut Vec<ModelStats>, from: &[ModelStats]) {
    for m in from {
        match into.iter_mut().find(|x| x.model == m.model) {
            Some(x) => {
                x.requests += m.requests;
                x.rejected += m.rejected;
                x.expired += m.expired;
                x.recals += m.recals;
            }
            None => into.push(*m),
        }
    }
}

/// A backend that evaluates batches of MAC requests. A failed batch is an
/// `Err` — the batcher answers every request in it with
/// [`ServeError::Backend`] and keeps serving. The geometry methods drive
/// request admission, so a backend with a non-default array shape rejects
/// wrong-sized inputs correctly; the lifecycle methods give `Drain` and
/// `Health` jobs their meaning (backends that cannot characterize
/// themselves return `None` and the jobs degrade to state reports).
pub trait MacBackend {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String>;

    /// `forward_batch` into a caller-owned output buffer (cleared and
    /// refilled) — the zero-allocation steady-state form the dispatch
    /// loop drives. The default routes through the allocating method so
    /// simple backends stay one-method; hot backends override it.
    fn forward_batch_into(
        &mut self,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        let q = self.forward_batch(x, batch)?;
        out.clear();
        out.extend_from_slice(&q);
        Ok(())
    }

    /// Input codes expected per request (admission checks against this,
    /// not a hard-coded constant).
    fn rows(&self) -> usize {
        c::N_ROWS
    }

    /// Output codes produced per request.
    fn cols(&self) -> usize {
        c::M_COLS
    }

    /// Evaluate a batch against one pre-folded tile of the backend's
    /// tile bank (DNN serving path); backends without a bank reject.
    fn forward_tile(
        &mut self,
        tile: &TileRef,
        _x: &[i32],
        _batch: usize,
    ) -> Result<Vec<u32>, String> {
        Err(format!(
            "backend has no tile bank (requested layer {} tile ({}, {}))",
            tile.layer, tile.tr, tile.tc
        ))
    }

    /// `forward_tile` into a caller-owned output buffer — same contract
    /// as [`MacBackend::forward_batch_into`].
    fn forward_tile_into(
        &mut self,
        tile: &TileRef,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        let q = self.forward_tile(tile, x, batch)?;
        out.clear();
        out.extend_from_slice(&q);
        Ok(())
    }

    /// Recalibrate the die and return the post-calibration residual
    /// (mean per-line |g_tot - 1|), or `None` if unsupported.
    fn recalibrate(&mut self, _engine: &BiscEngine) -> Option<f64> {
        None
    }

    /// Measure the BISC residual without correcting anything, or `None`
    /// if unsupported.
    fn health_residual(&mut self, _engine: &BiscEngine) -> Option<f64> {
        None
    }

    /// Reprogram the die with a new model's weights (hot rollout). The
    /// default rejects — only backends that track their workload weights
    /// (so recalibration can restore them) support reprogramming.
    fn program_model(&mut self, _model: u32, _weights: &[i32]) -> Result<(), String> {
        Err("backend does not support model reprogramming".to_string())
    }

    /// Strike the die with a hard-fault plan (chaos drills /
    /// degraded-mode testing): parse `plan` (see
    /// `crate::analog::faults::FaultPlan::parse`) and apply the events
    /// targeting this backend's core — immediately, or armed to fire at
    /// a future served-MAC count. The default rejects — only backends
    /// that model physical silicon can be wounded.
    fn inject_faults(&mut self, _plan: &str) -> Result<(), String> {
        Err("backend does not support fault injection".to_string())
    }

    /// Classify per-column permanent faults AFTER a recalibration
    /// attempt: `Some(mask)` with bit `col` set for every column whose
    /// transfer stays broken with fresh trims (dead/railed — calibration
    /// cannot help), or `None` if unsupported. `Some(0)`: classified,
    /// healthy.
    fn classify_faults(&mut self, _engine: &BiscEngine) -> Option<u32> {
        None
    }
}

// NOTE: the lifecycle methods stay at their `None` defaults here — BISC
// characterization clobbers the array's programmed weights, and a bare
// model cannot restore them. [`crate::coordinator::cluster::ClusterCore`]
// tracks its workload weights and implements the full lifecycle.
impl MacBackend for crate::analog::CimAnalogModel {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
        Ok(crate::analog::CimAnalogModel::forward_batch(self, x, batch))
    }

    fn forward_batch_into(
        &mut self,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        crate::analog::CimAnalogModel::forward_batch_into(self, x, batch, out);
        Ok(())
    }
}

impl MacBackend for crate::runtime::CimRuntime {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
        crate::runtime::CimRuntime::forward_batch(self, x, batch).map_err(|e| e.0)
    }

    fn forward_batch_into(
        &mut self,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        crate::runtime::CimRuntime::forward_batch_into(self, x, batch, out).map_err(|e| e.0)
    }
}

/// A queued job: submission order breaks priority ties (FIFO within a
/// priority class).
struct Pending {
    seq: u64,
    env: JobEnvelope,
}

impl Pending {
    fn key(&self) -> (u8, std::cmp::Reverse<u64>) {
        (self.env.priority, std::cmp::Reverse(self.seq))
    }

    fn expired(&self) -> bool {
        self.env.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

impl PartialEq for Pending {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}

impl Eq for Pending {}

impl PartialOrd for Pending {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Pending {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

/// Copyable discriminant so the dispatch loop can move the envelope.
#[derive(Clone, Copy, PartialEq, Eq)]
enum JobKind {
    Mac,
    MacBatch,
    Drain,
    Rollout,
    Health,
    Faults,
}

impl JobKind {
    /// Whether this kind is a seq barrier (drain semantics): work
    /// admitted before it completes first, work admitted after it waits.
    /// Fault injection is a barrier so every job admitted before it is
    /// answered from healthy silicon — the wound lands at a
    /// deterministic point in the job stream.
    fn is_barrier(self) -> bool {
        matches!(self, JobKind::Drain | JobKind::Rollout | JobKind::Faults)
    }
}

fn kind_of(job: &Job) -> JobKind {
    match job {
        Job::Mac(_) => JobKind::Mac,
        Job::MacBatch { .. } => JobKind::MacBatch,
        Job::Drain => JobKind::Drain,
        Job::Rollout { .. } => JobKind::Rollout,
        Job::Health => JobKind::Health,
        Job::Faults(_) => JobKind::Faults,
    }
}

/// Per-worker dispatch scratch, reused across every round so the steady
/// state runs without per-request heap allocation on the worker side:
/// the coalesce set, the gathered input codes, and the backend output
/// staging all grow to the largest batch seen and stay (DESIGN.md §11).
/// Only the reply payloads still allocate — they are owned by the
/// client once sent, so they cannot be pooled here.
#[derive(Default)]
struct DispatchScratch {
    /// `Mac` jobs coalesced into the current backend batch
    pendings: Vec<Pending>,
    /// gathered input codes for one backend call
    x: Vec<i32>,
    /// backend output staging (split into per-request replies after)
    out: Vec<u32>,
}

#[derive(Debug, Clone, Copy)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Batcher {
    fn default() -> Self {
        Self { max_batch: 256, max_wait: Duration::from_micros(200) }
    }
}

impl Batcher {
    /// Validate a job against the backend geometry; push it on the local
    /// priority queue or reject it on its own reply channel. Tracks the
    /// earliest live deadline so the dispatch loop can sweep buried
    /// expired jobs (a low-priority job must still be ANSWERED
    /// `DeadlineExceeded` while higher-priority traffic keeps the heap
    /// top occupied — never left blocking its client).
    fn admit<B: MacBackend>(
        env: JobEnvelope,
        queue: &mut BinaryHeap<Pending>,
        seq: &mut u64,
        earliest: &mut Option<Instant>,
        gate: &mut Option<u64>,
        backend: &B,
        ctx: &CoreContext,
        stats: &mut BatcherStats,
        models: &mut Vec<ModelStats>,
    ) {
        let rows = backend.rows();
        let (bad, expected) = match &env.job {
            Job::Mac(x) => {
                if x.len() == rows {
                    (None, rows)
                } else {
                    (Some(x.len()), rows)
                }
            }
            Job::MacBatch { xs, .. } => {
                if xs.is_empty() {
                    (Some(0), rows)
                } else {
                    (xs.iter().find(|x| x.len() != rows).map(|x| x.len()), rows)
                }
            }
            // a malformed rollout must not become a barrier at all
            Job::Rollout { weights, .. } => {
                let want = rows * backend.cols();
                if weights.len() == want {
                    (None, want)
                } else {
                    (Some(weights.len()), want)
                }
            }
            Job::Drain | Job::Health | Job::Faults(_) => (None, rows),
        };
        if let Some(got) = bad {
            stats.rejected += env.weight as u64;
            if let Some(s) = Self::model_slot(models, ctx.board.resident_model(ctx.core)) {
                s.rejected += env.weight as u64;
            }
            // release the depth reservation BEFORE replying so a client
            // that has gathered every reply observes settled gauges
            ctx.board.sub_in_flight(ctx.core, env.weight);
            env.reply.send(Err(ServeError::BadRequest { expected, got }));
            return;
        }
        if let Some(d) = env.deadline {
            *earliest = Some(earliest.map_or(d, |e| e.min(d)));
        }
        // a Drain/Rollout becomes a barrier the moment it is ADMITTED:
        // jobs with a later seq must not run before it, whatever their
        // priority
        if kind_of(&env.job).is_barrier() && gate.is_none() {
            *gate = Some(*seq);
        }
        queue.push(Pending { seq: *seq, env });
        *seq += 1;
    }

    /// Earliest barrier (drain/rollout) seq among the queued jobs, if any.
    fn min_drain_seq(queue: &BinaryHeap<Pending>) -> Option<u64> {
        queue
            .iter()
            .filter(|p| kind_of(&p.env.job).is_barrier())
            .map(|p| p.seq)
            .min()
    }

    /// Find-or-insert the per-model counter slot for `model` (`None` —
    /// nothing resident — counts nowhere).
    fn model_slot(models: &mut Vec<ModelStats>, model: Option<u32>) -> Option<&mut ModelStats> {
        let model = model?;
        let i = match models.iter().position(|m| m.model == model) {
            Some(i) => i,
            None => {
                models.push(ModelStats { model, ..ModelStats::default() });
                models.len() - 1
            }
        };
        models.get_mut(i)
    }

    /// Expire every waiting job whose deadline has passed — in the heap
    /// AND in the barrier-deferred set — and recompute the earliest live
    /// deadline (and the drain barrier, in case an expired job WAS the
    /// barrier). Runs only when a tracked deadline has actually passed,
    /// so the O(n) rebuild is amortized over jobs that carried
    /// deadlines. The parked drain itself is answered by the caller's
    /// stash-expiry check.
    fn sweep_expired(
        queue: &mut BinaryHeap<Pending>,
        deferred: &mut Vec<Pending>,
        earliest: &mut Option<Instant>,
        gate: &mut Option<u64>,
        stash: &Option<Pending>,
        ctx: &CoreContext,
        stats: &mut BatcherStats,
        models: &mut Vec<ModelStats>,
    ) {
        let now = Instant::now();
        if !earliest.is_some_and(|e| now >= e) {
            return;
        }
        let mut next: Option<Instant> = None;
        let mut expired_drain = false;
        let mut retain = |p: Pending, kept: &mut Vec<Pending>| {
            if p.env.deadline.is_some_and(|d| now >= d) {
                expired_drain |= kind_of(&p.env.job).is_barrier();
                Self::expire(p, ctx, stats, models);
            } else {
                if let Some(d) = p.env.deadline {
                    next = Some(next.map_or(d, |e| e.min(d)));
                }
                kept.push(p);
            }
        };
        let mut kept = Vec::with_capacity(queue.len());
        for p in std::mem::take(queue).into_vec() {
            retain(p, &mut kept);
        }
        let mut kept_deferred = Vec::with_capacity(deferred.len());
        for p in std::mem::take(deferred) {
            retain(p, &mut kept_deferred);
        }
        drop(retain);
        *queue = BinaryHeap::from(kept);
        *deferred = kept_deferred;
        // the parked drain's deadline stays tracked so the next pass
        // (and the caller's stash-expiry check) stays armed
        if let Some(s) = stash {
            if let Some(d) = s.env.deadline {
                next = Some(next.map_or(d, |e| e.min(d)));
            }
        }
        *earliest = next;
        if expired_drain {
            *gate = Self::min_drain_seq(queue);
        }
    }

    /// Shared mis-shaped-output message so the Mac and MacBatch
    /// execution paths cannot drift apart.
    fn shape_error(got: usize, want: usize) -> String {
        format!("backend returned {got} outputs, expected {want}")
    }

    /// Answer an expired job and release its depth reservation.
    fn expire(p: Pending, ctx: &CoreContext, stats: &mut BatcherStats, models: &mut Vec<ModelStats>) {
        stats.expired += p.env.weight as u64;
        if let Some(s) = Self::model_slot(models, ctx.board.resident_model(ctx.core)) {
            s.expired += p.env.weight as u64;
        }
        ctx.board.sub_in_flight(ctx.core, p.env.weight);
        p.env.reply.send(Err(ServeError::DeadlineExceeded));
    }

    /// Coalesce the popped `Mac` job with further queued `Mac` jobs (in
    /// priority order) and execute them as one backend batch through the
    /// round-shared scratch buffers. With a drain barrier active
    /// (`gate_seq`), jobs admitted after the drain are left on the queue
    /// — they run after the recalibration.
    fn exec_macs<B: MacBackend>(
        &self,
        first: Pending,
        queue: &mut BinaryHeap<Pending>,
        gate_seq: Option<u64>,
        backend: &mut B,
        ctx: &CoreContext,
        stats: &mut BatcherStats,
        models: &mut Vec<ModelStats>,
        scratch: &mut DispatchScratch,
    ) {
        let cols = backend.cols();
        scratch.pendings.clear();
        scratch.pendings.push(first);
        while scratch.pendings.len() < self.max_batch {
            let eligible = queue.peek().is_some_and(|p| {
                kind_of(&p.env.job) == JobKind::Mac && gate_seq.map_or(true, |g| p.seq < g)
            });
            if !eligible {
                break;
            }
            let Some(p) = queue.pop() else { break };
            if p.expired() {
                Self::expire(p, ctx, stats, models);
            } else {
                scratch.pendings.push(p);
            }
        }
        let batch = scratch.pendings.len();
        scratch.x.clear();
        for p in &scratch.pendings {
            if let Job::Mac(xi) = &p.env.job {
                scratch.x.extend_from_slice(xi);
            }
        }
        let res = backend.forward_batch_into(&scratch.x, batch, &mut scratch.out);
        match res {
            // a mis-shaped output is a backend failure, never a panic —
            // the worker must survive backend misbehavior
            Ok(()) if scratch.out.len() == batch * cols => {
                for (i, p) in scratch.pendings.drain(..).enumerate() {
                    // length checked above; .get keeps the worker panic-free
                    // even against a miscounted backend
                    let q = scratch.out.get(i * cols..(i + 1) * cols).unwrap_or_default().to_vec();
                    ctx.board.sub_in_flight(ctx.core, p.env.weight);
                    p.env.reply.send(Ok(JobReply::Mac(q)));
                }
                stats.requests += batch as u64;
                stats.batches += 1;
                stats.max_batch_seen = stats.max_batch_seen.max(batch);
                if let Some(s) = Self::model_slot(models, ctx.board.resident_model(ctx.core)) {
                    s.requests += batch as u64;
                }
            }
            res => {
                // the batch failed, the worker survives: answer every
                // request with the backend error and keep serving
                let msg = match res {
                    Ok(()) => Self::shape_error(scratch.out.len(), batch * cols),
                    Err(msg) => msg,
                };
                for p in scratch.pendings.drain(..) {
                    ctx.board.sub_in_flight(ctx.core, p.env.weight);
                    p.env.reply.send(Err(ServeError::Backend(msg.clone())));
                }
                stats.rejected += batch as u64;
                if let Some(s) = Self::model_slot(models, ctx.board.resident_model(ctx.core)) {
                    s.rejected += batch as u64;
                }
            }
        }
    }

    /// Execute a client-built batch natively: one backend call through
    /// the round-shared scratch, one reply.
    fn exec_batch<B: MacBackend>(
        p: Pending,
        backend: &mut B,
        ctx: &CoreContext,
        stats: &mut BatcherStats,
        models: &mut Vec<ModelStats>,
        scratch: &mut DispatchScratch,
    ) {
        let cols = backend.cols();
        let env = p.env;
        let (weight, reply) = (env.weight, env.reply);
        let Job::MacBatch { xs, tile, model } = env.job else {
            // dispatch invariant broken — answer as a backend error
            // instead of killing the worker (panic-free policy)
            ctx.board.sub_in_flight(ctx.core, weight);
            reply.send(Err(ServeError::Backend(
                "exec_batch dispatched on a non-batch job".to_string(),
            )));
            stats.rejected += weight as u64;
            return;
        };
        // checked at EXECUTION time, not admission: a rollout can land
        // between placement and this batch's turn on the queue — the job
        // must then fail typed instead of computing on the wrong weights
        let resident = ctx.board.resident_model(ctx.core);
        if let Some(requested) = model {
            if resident != Some(requested) {
                ctx.board.sub_in_flight(ctx.core, weight);
                reply.send(Err(ServeError::WrongModel { requested, resident }));
                stats.rejected += weight as u64;
                if let Some(s) = Self::model_slot(models, Some(requested)) {
                    s.rejected += weight as u64;
                }
                return;
            }
        }
        let n = xs.len();
        scratch.x.clear();
        for xi in &xs {
            scratch.x.extend_from_slice(xi);
        }
        let res = match tile {
            Some(t) => backend.forward_tile_into(&t, &scratch.x, n, &mut scratch.out),
            None => backend.forward_batch_into(&scratch.x, n, &mut scratch.out),
        };
        ctx.board.sub_in_flight(ctx.core, weight);
        match res {
            // see exec_macs: mis-shaped outputs are backend failures
            Ok(()) if scratch.out.len() == n * cols => {
                // length checked above; .get keeps the worker panic-free
                let outs: Vec<Vec<u32>> = (0..n)
                    .map(|i| scratch.out.get(i * cols..(i + 1) * cols).unwrap_or_default().to_vec())
                    .collect();
                reply.send(Ok(JobReply::MacBatch(outs)));
                stats.requests += n as u64;
                stats.batches += 1;
                stats.max_batch_seen = stats.max_batch_seen.max(n);
                if let Some(s) = Self::model_slot(models, resident) {
                    s.requests += n as u64;
                }
            }
            res => {
                let msg = match res {
                    Ok(()) => Self::shape_error(scratch.out.len(), n * cols),
                    Err(msg) => msg,
                };
                reply.send(Err(ServeError::Backend(msg)));
                stats.rejected += n as u64;
                if let Some(s) = Self::model_slot(models, resident) {
                    s.rejected += n as u64;
                }
            }
        }
    }

    /// Drain lifecycle step: recalibrate the die, classify what the
    /// trims could NOT fix, and either retire (permanent faults), rejoin
    /// (residual back inside the band), or stay fenced. Control jobs are
    /// not counted in request statistics.
    fn exec_drain<B: MacBackend>(
        p: Pending,
        backend: &mut B,
        ctx: &CoreContext,
        models: &mut Vec<ModelStats>,
    ) {
        let residual = ctx.engine.as_ref().and_then(|e| backend.recalibrate(e));
        let recalibrated = residual.is_some();
        if let Some(r) = residual {
            // the die's trims changed: gather-side schedules holding
            // corrections measured against the old trims can detect it
            ctx.board.bump_recal_epoch(ctx.core);
            // transient vs permanent: calibration just ran, so a column
            // whose transfer is STILL broken is a hard fault. Checked
            // regardless of the band — one dead column is only 2 lines
            // of 2*M in the MEAN residual and can hide inside it.
            let mask = ctx.engine.as_ref().and_then(|e| backend.classify_faults(e)).unwrap_or(0);
            if mask != 0 {
                ctx.board.retire(ctx.core, mask);
                println!(
                    "core {} retired: permanent fault columns {mask:#010x} \
                     survive recalibration (residual {r:.4}) — fenced for good",
                    ctx.core
                );
            } else if r <= ctx.health_band {
                ctx.board.unfence(ctx.core);
            } else {
                ctx.board.fence(ctx.core);
            }
            if let Some(s) = Self::model_slot(models, ctx.board.resident_model(ctx.core)) {
                s.recals += 1;
            }
        }
        let health = CoreHealth {
            core: ctx.core,
            residual,
            fenced: ctx.board.is_fenced(ctx.core),
            recalibrated,
            recal_epoch: ctx.board.recal_epoch(ctx.core),
            model: ctx.board.resident_model(ctx.core),
            retired: ctx.board.is_retired(ctx.core),
            fault_mask: ctx.board.fault_mask(ctx.core),
        };
        ctx.board.sub_in_flight(ctx.core, p.env.weight);
        p.env.reply.send(Ok(JobReply::Health(health)));
    }

    /// Hot rollout lifecycle step, running AFTER the barrier has drained
    /// every pre-rollout job: reprogram the die with the new model's
    /// weights, publish the residency, recalibrate like a drain, and
    /// rejoin if the residual is in band. A backend that rejects the
    /// reprogram leaves the core fenced with its old model intact.
    fn exec_rollout<B: MacBackend>(
        p: Pending,
        backend: &mut B,
        ctx: &CoreContext,
        models: &mut Vec<ModelStats>,
    ) {
        let env = p.env;
        let (weight, reply) = (env.weight, env.reply);
        let Job::Rollout { model, weights } = env.job else {
            // dispatch invariant broken — same degradation as exec_batch
            ctx.board.sub_in_flight(ctx.core, weight);
            reply.send(Err(ServeError::Backend(
                "exec_rollout dispatched on a non-rollout job".to_string(),
            )));
            return;
        };
        if let Err(msg) = backend.program_model(model, &weights) {
            // the old model is still programmed; the core stays fenced
            // (the rollout convenience fenced it) until an operator acts
            ctx.board.sub_in_flight(ctx.core, weight);
            reply.send(Err(ServeError::Backend(msg)));
            return;
        }
        // tiles become stale with the old weights; a registry deploy (or
        // the next prepare_cluster) republishes them for the new model
        ctx.board.set_residency(ctx.core, model, Vec::new());
        let residual = ctx.engine.as_ref().and_then(|e| backend.recalibrate(e));
        let recalibrated = residual.is_some();
        match residual {
            Some(r) => {
                ctx.board.bump_recal_epoch(ctx.core);
                if r <= ctx.health_band {
                    ctx.board.unfence(ctx.core);
                } else {
                    ctx.board.fence(ctx.core);
                }
            }
            // no calibration gate configured: the reprogram succeeded,
            // rejoin (unlike Drain, which only reports state without an
            // engine — a rollout's whole point is to resume serving)
            None => ctx.board.unfence(ctx.core),
        }
        if let Some(s) = Self::model_slot(models, Some(model)) {
            s.recals += 1;
        }
        let health = CoreHealth {
            core: ctx.core,
            residual,
            fenced: ctx.board.is_fenced(ctx.core),
            recalibrated,
            recal_epoch: ctx.board.recal_epoch(ctx.core),
            model: ctx.board.resident_model(ctx.core),
            retired: ctx.board.is_retired(ctx.core),
            fault_mask: ctx.board.fault_mask(ctx.core),
        };
        ctx.board.sub_in_flight(ctx.core, weight);
        reply.send(Ok(JobReply::Health(health)));
    }

    /// Fault-injection lifecycle step, running AFTER the barrier has
    /// drained every pre-injection job: hand the plan to the backend and
    /// keep serving on the wounded die. The core is deliberately NOT
    /// fenced — the point of a chaos drill is to watch the health loop
    /// (probe → drain → classify → retire) catch the damage on its own.
    fn exec_faults<B: MacBackend>(p: Pending, backend: &mut B, ctx: &CoreContext) {
        let env = p.env;
        let (weight, reply) = (env.weight, env.reply);
        let Job::Faults(plan) = env.job else {
            // dispatch invariant broken — same degradation as exec_batch
            ctx.board.sub_in_flight(ctx.core, weight);
            reply.send(Err(ServeError::Backend(
                "exec_faults dispatched on a non-faults job".to_string(),
            )));
            return;
        };
        if let Err(msg) = backend.inject_faults(&plan) {
            ctx.board.sub_in_flight(ctx.core, weight);
            reply.send(Err(ServeError::Backend(msg)));
            return;
        }
        let health = CoreHealth {
            core: ctx.core,
            residual: None,
            fenced: ctx.board.is_fenced(ctx.core),
            recalibrated: false,
            recal_epoch: ctx.board.recal_epoch(ctx.core),
            model: ctx.board.resident_model(ctx.core),
            retired: ctx.board.is_retired(ctx.core),
            fault_mask: ctx.board.fault_mask(ctx.core),
        };
        ctx.board.sub_in_flight(ctx.core, weight);
        reply.send(Ok(JobReply::Health(health)));
    }

    /// Execute a parked/popped barrier job by its kind (drain, rollout,
    /// or fault injection) — the three share the barrier machinery in
    /// `run`.
    fn exec_barrier<B: MacBackend>(
        p: Pending,
        backend: &mut B,
        ctx: &CoreContext,
        models: &mut Vec<ModelStats>,
    ) {
        match kind_of(&p.env.job) {
            JobKind::Rollout => Self::exec_rollout(p, backend, ctx, models),
            JobKind::Faults => Self::exec_faults(p, backend, ctx),
            _ => Self::exec_drain(p, backend, ctx, models),
        }
    }

    /// Health probe: measure the residual and fence the core if it is
    /// out of band (rejoin happens only through `Drain`).
    fn exec_health<B: MacBackend>(p: Pending, backend: &mut B, ctx: &CoreContext) {
        let residual = ctx.engine.as_ref().and_then(|e| backend.health_residual(e));
        if let Some(r) = residual {
            if r > ctx.health_band {
                ctx.board.fence(ctx.core);
            }
        }
        let health = CoreHealth {
            core: ctx.core,
            residual,
            fenced: ctx.board.is_fenced(ctx.core),
            recalibrated: false,
            recal_epoch: ctx.board.recal_epoch(ctx.core),
            model: ctx.board.resident_model(ctx.core),
            retired: ctx.board.is_retired(ctx.core),
            fault_mask: ctx.board.fault_mask(ctx.core),
        };
        ctx.board.sub_in_flight(ctx.core, p.env.weight);
        p.env.reply.send(Ok(JobReply::Health(health)));
    }

    /// Serve until the request channel closes. Returns run statistics.
    pub fn run<B: MacBackend>(
        &self,
        rx: Receiver<JobEnvelope>,
        backend: &mut B,
        ctx: &CoreContext,
    ) -> BatcherStats {
        let mut stats = BatcherStats::default();
        let mut models: Vec<ModelStats> = Vec::new();
        let mut queue: BinaryHeap<Pending> = BinaryHeap::new();
        let mut seq: u64 = 0;
        let mut earliest: Option<Instant> = None;
        // drain barrier: from the moment a Drain is ADMITTED (`gate` =
        // its seq), jobs admitted after it are set aside in `deferred`
        // whatever their priority; once every earlier-admitted job has
        // completed, the drain (parked in `stash` when popped early)
        // executes and the deferred work resumes. A seq barrier, not a
        // priority: earlier work of ANY priority finishes first, later
        // arrivals can neither starve the drain nor run on the
        // not-yet-recalibrated die.
        let mut gate: Option<u64> = None;
        let mut stash: Option<Pending> = None;
        let mut deferred: Vec<Pending> = Vec::new();
        // round-shared dispatch buffers: after warmup the worker serves
        // without per-request heap allocation (reply payloads excepted)
        let mut scratch = DispatchScratch::default();
        loop {
            // republish the live statistics snapshot each dispatch round
            // (wire Stats frames read it without joining the worker).
            // clear + extend reuses the live vec's capacity: no
            // steady-state allocation once every model has a slot
            *lock_unpoisoned(&ctx.live) = stats;
            {
                let mut live = lock_unpoisoned(&ctx.live_models);
                live.clear();
                live.extend_from_slice(&models);
            }
            // release the barrier once no pre-drain work remains
            let release = stash
                .as_ref()
                .map_or(false, |s| !queue.iter().any(|p| p.seq < s.seq));
            if release {
                if let Some(drain) = stash.take() {
                    if drain.expired() {
                        Self::expire(drain, ctx, &mut stats, &mut models);
                    } else {
                        Self::exec_barrier(drain, backend, ctx, &mut models);
                    }
                    queue.extend(deferred.drain(..));
                    gate = Self::min_drain_seq(&queue);
                }
            }
            if queue.is_empty() && stash.is_none() && deferred.is_empty() {
                // block for the first job of a round
                match rx.recv() {
                    Ok(env) => Self::admit(
                        env,
                        &mut queue,
                        &mut seq,
                        &mut earliest,
                        &mut gate,
                        backend,
                        ctx,
                        &mut stats,
                        &mut models,
                    ),
                    Err(_) => {
                        *lock_unpoisoned(&ctx.live) = stats;
                        *lock_unpoisoned(&ctx.live_models) = models;
                        return stats;
                    }
                }
                // opportunistically wait for more, up to max_batch /
                // max_wait — lets batches (and higher-priority arrivals)
                // form before execution starts
                let until = Instant::now() + self.max_wait;
                while queue.len() < self.max_batch {
                    let now = Instant::now();
                    if now >= until {
                        break;
                    }
                    match rx.recv_timeout(until - now) {
                        Ok(env) => Self::admit(
                            env,
                            &mut queue,
                            &mut seq,
                            &mut earliest,
                            &mut gate,
                            backend,
                            ctx,
                            &mut stats,
                            &mut models,
                        ),
                        Err(_) => break,
                    }
                }
            }
            // keep the priority queue current before every dispatch, and
            // answer any queued job whose deadline has passed — even one
            // buried under higher-priority traffic
            while let Ok(env) = rx.try_recv() {
                Self::admit(
                    env,
                    &mut queue,
                    &mut seq,
                    &mut earliest,
                    &mut gate,
                    backend,
                    ctx,
                    &mut stats,
                    &mut models,
                );
            }
            let gate_before = gate;
            Self::sweep_expired(
                &mut queue,
                &mut deferred,
                &mut earliest,
                &mut gate,
                &stash,
                ctx,
                &mut stats,
                &mut models,
            );
            // a parked drain whose own deadline has passed is answered
            // immediately and its barrier dissolves
            if stash.as_ref().is_some_and(|s| s.expired()) {
                if let Some(drain) = stash.take() {
                    Self::expire(drain, ctx, &mut stats, &mut models);
                }
                queue.extend(deferred.drain(..));
                gate = Self::min_drain_seq(&queue);
            } else if let Some(s) = &stash {
                // a parked drain is always the earliest barrier
                gate = Some(s.seq);
            }
            if gate != gate_before && !deferred.is_empty() {
                // the barrier moved (its drain expired mid-queue):
                // requeue deferred work — it may itself contain the next
                // drain — and recompute the barrier over the whole queue
                queue.extend(deferred.drain(..));
                gate = Self::min_drain_seq(&queue);
                if let Some(s) = &stash {
                    gate = Some(s.seq);
                }
            }
            let Some(top) = queue.pop() else { continue };
            // work admitted after an active drain barrier waits until
            // the recalibration has run
            if gate.is_some_and(|g| top.seq > g) {
                deferred.push(top);
                continue;
            }
            if top.expired() {
                let was_barrier = kind_of(&top.env.job).is_barrier();
                Self::expire(top, ctx, &mut stats, &mut models);
                if was_barrier {
                    // requeue deferred work FIRST: it may contain a later
                    // drain that must become the new barrier
                    queue.extend(deferred.drain(..));
                    gate = Self::min_drain_seq(&queue);
                }
                continue;
            }
            match kind_of(&top.env.job) {
                JobKind::Mac => self.exec_macs(
                    top,
                    &mut queue,
                    gate,
                    backend,
                    ctx,
                    &mut stats,
                    &mut models,
                    &mut scratch,
                ),
                JobKind::MacBatch => {
                    Self::exec_batch(top, backend, ctx, &mut stats, &mut models, &mut scratch)
                }
                JobKind::Drain | JobKind::Rollout | JobKind::Faults => {
                    if queue.iter().any(|p| p.seq < top.seq) {
                        // earlier-admitted work still queued: park the
                        // barrier until it has all completed
                        stash = Some(top);
                    } else {
                        Self::exec_barrier(top, backend, ctx, &mut models);
                        // requeue deferred work FIRST: it may contain a
                        // later drain that must become the new barrier
                        queue.extend(deferred.drain(..));
                        gate = Self::min_drain_seq(&queue);
                    }
                }
                JobKind::Health => Self::exec_health(top, backend, ctx),
            }
        }
    }

    /// Spawn a stand-alone single-core service worker around `backend`:
    /// returns the client handle and the worker thread (which yields the
    /// backend and its run statistics once every client clone is
    /// dropped).
    pub fn spawn_solo<B: MacBackend + Send + 'static>(
        self,
        mut backend: B,
    ) -> (Client, std::thread::JoinHandle<(B, BatcherStats)>) {
        let (tx, rx) = channel::<JobEnvelope>();
        let ctx = CoreContext::solo();
        let board = Arc::clone(&ctx.board);
        let handle = std::thread::spawn(move || {
            let stats = self.run(rx, &mut backend, &ctx);
            (backend, stats)
        });
        (Client::new(vec![tx], board), handle)
    }
}

/// Client handle for a single worker channel — the one-core case of the
/// shared [`crate::coordinator::service::ServiceClient`] (placement
/// policies degenerate to core 0).
pub use crate::coordinator::service::ServiceClient as Client;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::CimAnalogModel;
    use crate::coordinator::service::{CimService, CoreBoard, SubmitOpts, Ticket, PRI_HIGH};
    use crate::util::rng::Rng;
    use std::sync::mpsc::channel;
    use std::sync::{Condvar, Mutex};

    fn programmed_model() -> CimAnalogModel {
        let mut model = CimAnalogModel::ideal();
        model.program(&vec![40; c::N_ROWS * c::M_COLS]);
        model
    }

    #[test]
    fn single_client_roundtrip() {
        let (client, handle) = Batcher::default().spawn_solo(programmed_model());
        let q = client.mac(vec![30; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        // matches a direct evaluation
        let mut model = programmed_model();
        let direct = model.forward_batch(&vec![30; c::N_ROWS], 1);
        assert_eq!(q, direct);
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn faults_job_on_plain_backend_rejects_and_worker_survives() {
        // a bare analog model cannot be wounded (no MAC counter, no
        // restore path) — the job must answer a typed Backend error and
        // the worker must keep serving
        let (client, handle) = Batcher::default().spawn_solo(programmed_model());
        let err = client.inject_faults(0, "core=0,col=3").unwrap_err();
        assert!(matches!(err, ServeError::Backend(_)), "got {err:?}");
        assert_eq!(client.mac(vec![30; c::N_ROWS]).unwrap().len(), c::M_COLS);
        assert_eq!(client.board().in_flight(0), 0, "depth gauge leaked");
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn concurrent_clients_all_answered_correctly() {
        let (client, handle) = Batcher {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
        }
        .spawn_solo(programmed_model());
        let mut joins = Vec::new();
        for t in 0..8 {
            let client = client.clone();
            joins.push(std::thread::spawn(move || {
                let mut rng = Rng::new(t as u64);
                for _ in 0..20 {
                    let x: Vec<i32> =
                        (0..c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
                    let q = client.mac(x.clone()).unwrap();
                    // verify against an independent model
                    let mut model = programmed_model();
                    assert_eq!(q, model.forward_batch(&x, 1));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 8 * 20);
        assert!(stats.batches <= stats.requests);
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let (client, handle) = Batcher {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
        }
        .spawn_solo(programmed_model());
        // pre-queue many requests before the worker can drain them
        let tickets: Vec<Ticket<Vec<u32>>> = (0..50)
            .map(|_| {
                client
                    .submit(Job::Mac(vec![10; c::N_ROWS]), SubmitOpts::default())
                    .unwrap()
                    .typed()
            })
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap().len(), c::M_COLS);
        }
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert!(
            stats.mean_batch() > 2.0,
            "expected batching, mean batch {}",
            stats.mean_batch()
        );
        assert!(stats.max_batch_seen > 4);
    }

    #[test]
    fn malformed_request_rejected_without_killing_worker() {
        let (client, handle) = Batcher::default().spawn_solo(programmed_model());
        // wrong input length: must come back as BadRequest, not a panic
        let err = client.mac(vec![1; 3]).unwrap_err();
        assert_eq!(err, ServeError::BadRequest { expected: c::N_ROWS, got: 3 });
        // the worker must still be alive and serving
        let q = client.mac(vec![30; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn bad_request_inside_a_batch_spares_the_others() {
        let (client, handle) = Batcher {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
        }
        .spawn_solo(programmed_model());
        let mut tickets = Vec::new();
        for i in 0..10 {
            let x = if i == 4 { vec![0; 7] } else { vec![10; c::N_ROWS] };
            tickets.push(
                client
                    .submit(Job::Mac(x), SubmitOpts::default())
                    .unwrap()
                    .typed::<Vec<u32>>(),
            );
        }
        for (i, t) in tickets.into_iter().enumerate() {
            let reply = t.wait();
            if i == 4 {
                assert!(matches!(reply, Err(ServeError::BadRequest { .. })));
            } else {
                assert_eq!(reply.unwrap().len(), c::M_COLS);
            }
        }
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 9);
        assert_eq!(stats.rejected, 1);
    }

    /// Backend that fails its first batch, then recovers.
    struct FlakyBackend {
        fail: bool,
    }

    impl MacBackend for FlakyBackend {
        fn forward_batch(&mut self, _x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
            if self.fail {
                self.fail = false;
                Err("transient backend failure".to_string())
            } else {
                Ok(vec![0; batch * c::M_COLS])
            }
        }
    }

    #[test]
    fn backend_failure_answers_batch_and_keeps_serving() {
        let (client, handle) = Batcher::default().spawn_solo(FlakyBackend { fail: true });
        let err = client.mac(vec![0; c::N_ROWS]).unwrap_err();
        assert_eq!(err, ServeError::Backend("transient backend failure".to_string()));
        // the worker must survive a backend failure and serve the next batch
        let q = client.mac(vec![0; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn client_survives_worker_shutdown() {
        let (client, handle) = Batcher::default().spawn_solo(programmed_model());
        let q = client.mac(vec![5; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        // a client whose worker is already gone gets Disconnected
        let (dead_tx, dead_rx) = channel::<JobEnvelope>();
        drop(dead_rx);
        let dead = Client::new(vec![dead_tx], Arc::new(CoreBoard::new(1)));
        assert_eq!(dead.mac(vec![5; c::N_ROWS]).unwrap_err(), ServeError::Disconnected);
    }

    /// Backend with a non-default geometry: admission must follow it.
    struct SmallBackend;

    impl MacBackend for SmallBackend {
        fn forward_batch(&mut self, _x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
            Ok(vec![0; batch * 3])
        }

        fn rows(&self) -> usize {
            7
        }

        fn cols(&self) -> usize {
            3
        }
    }

    #[test]
    fn admission_follows_backend_geometry_not_constants() {
        let (client, handle) = Batcher::default().spawn_solo(SmallBackend);
        // the default array size is WRONG for this backend
        let err = client.mac(vec![0; c::N_ROWS]).unwrap_err();
        assert_eq!(err, ServeError::BadRequest { expected: 7, got: c::N_ROWS });
        // the backend's own geometry is right
        let q = client.mac(vec![0; 7]).unwrap();
        assert_eq!(q.len(), 3);
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn mac_batch_executes_natively_in_one_round_trip() {
        let (client, handle) = Batcher::default().spawn_solo(programmed_model());
        let xs: Vec<Vec<i32>> = (0..5).map(|i| vec![5 * (i as i32 + 1); c::N_ROWS]).collect();
        let replies = client.mac_batch(xs.clone()).unwrap();
        assert_eq!(replies.len(), 5);
        let mut model = programmed_model();
        for (x, q) in xs.iter().zip(&replies) {
            assert_eq!(q, &model.forward_batch(x, 1));
        }
        // an empty batch is malformed, not a panic
        let err = client.mac_batch(Vec::new()).unwrap_err();
        assert_eq!(err, ServeError::BadRequest { expected: c::N_ROWS, got: 0 });
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.batches, 1, "a MacBatch is one backend invocation");
        assert_eq!(stats.max_batch_seen, 5);
    }

    #[test]
    fn rollout_without_backend_support_fails_typed_and_stays_fenced() {
        let (client, handle) = Batcher::default().spawn_solo(programmed_model());
        // malformed weights never become a barrier
        let err = client.rollout(0, 1, vec![1; 3]).unwrap_err();
        assert_eq!(err, ServeError::BadRequest { expected: c::N_ROWS * c::M_COLS, got: 3 });
        client.unfence(0);
        // a bare analog model cannot reprogram (it does not track its
        // workload weights): typed Backend error, core stays fenced
        let err = client.rollout(0, 1, vec![40; c::N_ROWS * c::M_COLS]).unwrap_err();
        assert!(matches!(err, ServeError::Backend(_)));
        assert!(client.is_fenced(0), "failed rollout must leave the core fenced");
        client.unfence(0);
        let q = client.mac(vec![5; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        handle.join().unwrap();
    }

    /// Backend whose first evaluations block on a gate — lets tests
    /// saturate the worker deterministically.
    struct GateBackend {
        gate: Arc<(Mutex<bool>, Condvar)>,
        seen: Arc<Mutex<Vec<i32>>>,
    }

    fn closed_gate() -> Arc<(Mutex<bool>, Condvar)> {
        Arc::new((Mutex::new(true), Condvar::new()))
    }

    fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
        let (lock, cv) = &**gate;
        *lock.lock().unwrap() = false;
        cv.notify_all();
    }

    impl MacBackend for GateBackend {
        fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
            {
                let (lock, cv) = &*self.gate;
                let mut closed = lock.lock().unwrap();
                while *closed {
                    closed = cv.wait(closed).unwrap();
                }
            }
            self.seen.lock().unwrap().push(x[0]);
            Ok(vec![0; batch * c::M_COLS])
        }
    }

    #[test]
    fn priority_orders_jobs_under_a_saturated_worker() {
        let gate = closed_gate();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let backend = GateBackend { gate: Arc::clone(&gate), seen: Arc::clone(&seen) };
        // max_batch = 1 so every Mac is its own backend call and the
        // execution order is observable
        let (client, handle) =
            Batcher { max_batch: 1, max_wait: Duration::from_millis(2) }.spawn_solo(backend);
        let blocker = client
            .submit(Job::Mac(vec![9; c::N_ROWS]), SubmitOpts::default().with_priority(PRI_HIGH))
            .unwrap()
            .typed::<Vec<u32>>();
        // wait until the worker is stuck inside the backend on the blocker
        std::thread::sleep(Duration::from_millis(50));
        let t_a = client
            .submit(Job::Mac(vec![1; c::N_ROWS]), SubmitOpts::default())
            .unwrap()
            .typed::<Vec<u32>>();
        let t_b = client
            .submit(Job::Mac(vec![2; c::N_ROWS]), SubmitOpts::default())
            .unwrap()
            .typed::<Vec<u32>>();
        let t_c = client
            .submit(Job::Mac(vec![3; c::N_ROWS]), SubmitOpts::default().with_priority(PRI_HIGH))
            .unwrap()
            .typed::<Vec<u32>>();
        std::thread::sleep(Duration::from_millis(20));
        open_gate(&gate);
        for t in [blocker, t_a, t_b, t_c] {
            t.wait().unwrap();
        }
        drop(client);
        let (backend, stats) = handle.join().unwrap();
        let order = backend.seen.lock().unwrap().clone();
        assert_eq!(
            order,
            vec![9, 3, 1, 2],
            "the high-priority job must jump the saturated queue"
        );
        assert_eq!(stats.requests, 4);
    }

    #[test]
    fn drain_is_a_seq_barrier_not_a_priority() {
        let gate = closed_gate();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let backend = GateBackend { gate: Arc::clone(&gate), seen: Arc::clone(&seen) };
        let (client, handle) =
            Batcher { max_batch: 1, max_wait: Duration::from_millis(2) }.spawn_solo(backend);
        let blocker = client
            .submit(Job::Mac(vec![9; c::N_ROWS]), SubmitOpts::default().with_priority(PRI_HIGH))
            .unwrap()
            .typed::<Vec<u32>>();
        std::thread::sleep(Duration::from_millis(50));
        // A: LOW priority but admitted BEFORE the drain — runs first
        let t_a = client
            .submit(Job::Mac(vec![1; c::N_ROWS]), SubmitOpts::default().with_priority(0))
            .unwrap()
            .typed::<Vec<u32>>();
        let t_drain = client
            .submit(Job::Drain, SubmitOpts::pinned(0))
            .unwrap()
            .typed::<CoreHealth>();
        // B: HIGH priority but admitted AFTER the drain — waits behind it
        let t_b = client
            .submit(Job::Mac(vec![2; c::N_ROWS]), SubmitOpts::default().with_priority(PRI_HIGH))
            .unwrap()
            .typed::<Vec<u32>>();
        std::thread::sleep(Duration::from_millis(20));
        open_gate(&gate);
        blocker.wait().unwrap();
        t_a.wait().unwrap();
        let h = t_drain.wait().unwrap();
        assert!(!h.recalibrated, "solo worker has no engine");
        t_b.wait().unwrap();
        drop(client);
        let (backend, stats) = handle.join().unwrap();
        let order = backend.seen.lock().unwrap().clone();
        assert_eq!(
            order,
            vec![9, 1, 2],
            "drain barrier: pre-drain LOW job first, post-drain HIGH job after"
        );
        assert_eq!(stats.requests, 3);
    }

    #[test]
    fn expired_jobs_answered_deadline_exceeded_not_dropped() {
        let gate = closed_gate();
        let seen = Arc::new(Mutex::new(Vec::new()));
        let backend = GateBackend { gate: Arc::clone(&gate), seen: Arc::clone(&seen) };
        let (client, handle) = Batcher {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        }
        .spawn_solo(backend);
        let blocker = client
            .submit(Job::Mac(vec![9; c::N_ROWS]), SubmitOpts::default().with_priority(PRI_HIGH))
            .unwrap()
            .typed::<Vec<u32>>();
        std::thread::sleep(Duration::from_millis(50));
        // queued behind the blocker with a 10 ms budget the gate outlives
        let doomed = client
            .submit(
                Job::Mac(vec![1; c::N_ROWS]),
                SubmitOpts::default().with_deadline(Duration::from_millis(10)),
            )
            .unwrap()
            .typed::<Vec<u32>>();
        std::thread::sleep(Duration::from_millis(40));
        open_gate(&gate);
        blocker.wait().unwrap();
        assert_eq!(doomed.wait().unwrap_err(), ServeError::DeadlineExceeded);
        // the worker survives and keeps serving
        let q = client.mac(vec![5; c::N_ROWS]).unwrap();
        assert_eq!(q.len(), c::M_COLS);
        drop(client);
        let (_backend, stats) = handle.join().unwrap();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.requests, 2);
    }
}
