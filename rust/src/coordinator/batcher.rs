//! Request batcher: aggregates MAC requests from concurrent clients into
//! array-sized batches for the PJRT (or golden-model) backend — the
//! serving-layer role of the coordinator (cf. vllm-style routers, scaled
//! to this accelerator: one physical array, batched pulses).
//!
//! Design: submitters push `MacRequest`s over an mpsc channel; the worker
//! drains up to `max_batch` requests (waiting up to `max_wait` for the
//! first), executes them as one batched forward, and answers each client
//! over its own return channel. std threads + channels (tokio is not
//! vendored; the workload is CPU-bound anyway).

use crate::analog::consts as c;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

pub struct MacRequest {
    pub x: Vec<i32>,
    pub reply: Sender<Vec<u32>>,
}

/// Statistics from a batcher run.
#[derive(Debug, Default, Clone, Copy)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub max_batch_seen: usize,
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// A backend that evaluates batches of MAC requests.
pub trait MacBackend {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Vec<u32>;
}

impl MacBackend for crate::analog::CimAnalogModel {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Vec<u32> {
        crate::analog::CimAnalogModel::forward_batch(self, x, batch)
    }
}

impl MacBackend for crate::runtime::CimRuntime {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Vec<u32> {
        crate::runtime::CimRuntime::forward_batch(self, x, batch)
            .expect("runtime backend failed")
    }
}

pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for Batcher {
    fn default() -> Self {
        Self { max_batch: 256, max_wait: Duration::from_micros(200) }
    }
}

impl Batcher {
    /// Serve until the request channel closes. Returns run statistics.
    pub fn run<B: MacBackend>(&self, rx: Receiver<MacRequest>, backend: &mut B) -> BatcherStats {
        let mut stats = BatcherStats::default();
        loop {
            // block for the first request of a batch
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return stats,
            };
            let mut pending = vec![first];
            // opportunistically drain more, up to max_batch / max_wait
            let deadline = std::time::Instant::now() + self.max_wait;
            while pending.len() < self.max_batch {
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            // assemble the batch
            let batch = pending.len();
            let mut x = Vec::with_capacity(batch * c::N_ROWS);
            for r in &pending {
                assert_eq!(r.x.len(), c::N_ROWS, "request must be N codes");
                x.extend_from_slice(&r.x);
            }
            let q = backend.forward_batch(&x, batch);
            for (i, r) in pending.into_iter().enumerate() {
                let out = q[i * c::M_COLS..(i + 1) * c::M_COLS].to_vec();
                let _ = r.reply.send(out); // client may have gone away
            }
            stats.requests += batch as u64;
            stats.batches += 1;
            stats.max_batch_seen = stats.max_batch_seen.max(batch);
        }
    }
}

/// Convenience client handle.
pub struct Client {
    tx: Sender<MacRequest>,
}

impl Client {
    pub fn new(tx: Sender<MacRequest>) -> Self {
        Self { tx }
    }

    pub fn mac(&self, x: Vec<i32>) -> Vec<u32> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(MacRequest { x, reply: reply_tx })
            .expect("batcher gone");
        reply_rx.recv().expect("batcher dropped reply")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::CimAnalogModel;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn spawn_batcher(
        batcher: Batcher,
    ) -> (Sender<MacRequest>, std::thread::JoinHandle<BatcherStats>) {
        let (tx, rx) = channel::<MacRequest>();
        let handle = std::thread::spawn(move || {
            let mut model = CimAnalogModel::ideal();
            model.program(&vec![40; c::N_ROWS * c::M_COLS]);
            batcher.run(rx, &mut model)
        });
        (tx, handle)
    }

    #[test]
    fn single_client_roundtrip() {
        let (tx, handle) = spawn_batcher(Batcher::default());
        let client = Client::new(tx.clone());
        let q = client.mac(vec![30; c::N_ROWS]);
        assert_eq!(q.len(), c::M_COLS);
        // matches a direct evaluation
        let mut model = CimAnalogModel::ideal();
        model.program(&vec![40; c::N_ROWS * c::M_COLS]);
        let direct = model.forward_batch(&vec![30; c::N_ROWS], 1);
        assert_eq!(q, direct);
        drop(client);
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn concurrent_clients_all_answered_correctly() {
        let (tx, handle) = spawn_batcher(Batcher {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
        });
        let tx = Arc::new(tx);
        let mut joins = Vec::new();
        for t in 0..8 {
            let tx = Sender::clone(&tx);
            joins.push(std::thread::spawn(move || {
                let client = Client::new(tx);
                let mut rng = Rng::new(t as u64);
                for _ in 0..20 {
                    let x: Vec<i32> =
                        (0..c::N_ROWS).map(|_| rng.int_in(-63, 63) as i32).collect();
                    let q = client.mac(x.clone());
                    // verify against an independent model
                    let mut model = CimAnalogModel::ideal();
                    model.program(&vec![40; c::N_ROWS * c::M_COLS]);
                    assert_eq!(q, model.forward_batch(&x, 1));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        drop(tx);
        let stats = handle.join().unwrap();
        assert_eq!(stats.requests, 8 * 20);
        assert!(stats.batches <= stats.requests);
    }

    #[test]
    fn batching_actually_batches_under_load() {
        let (tx, handle) = spawn_batcher(Batcher {
            max_batch: 64,
            max_wait: Duration::from_millis(20),
        });
        // pre-queue many requests before the worker can drain them
        let mut replies = Vec::new();
        for _ in 0..50 {
            let (rtx, rrx) = channel();
            tx.send(MacRequest { x: vec![10; c::N_ROWS], reply: rtx }).unwrap();
            replies.push(rrx);
        }
        for r in replies {
            assert_eq!(r.recv().unwrap().len(), c::M_COLS);
        }
        drop(tx);
        let stats = handle.join().unwrap();
        assert!(
            stats.mean_batch() > 2.0,
            "expected batching, mean batch {}",
            stats.mean_batch()
        );
        assert!(stats.max_batch_seen > 4);
    }
}
