//! Autonomous drift-triggered recalibration: a background daemon that
//! closes the paper's self-calibration loop in serving form. The paper's
//! central claim is *automated* RISC-V controlled calibration; until now
//! the serving layer only recalibrated when an operator submitted a
//! `Drain` by hand. The [`Calibrator`] watches per-core BISC residuals
//! through the ordinary [`CimService`] surface (`Health` probes), keeps
//! an EWMA trend per core, and issues the drain → recalibrate → rejoin
//! lifecycle on its own when the trend crosses a threshold or a core's
//! calibration goes stale — reliability work in the spirit of Yan et
//! al.'s CiM-reliability study: analog error under drift is a moving
//! target, so calibration must be a control loop, not an event.
//!
//! Layers:
//! * [`CalibratorPolicy`] — the pure decision state machine (no clock,
//!   no threads: `observe` residuals, `decide` drains against an
//!   explicit `now`), unit-testable for every trigger and guard;
//! * [`CalibratorBrain`] / [`HostBrain`] — the decision-maker seam: the
//!   daemon samples health and executes drains, the brain decides.
//!   [`HostBrain`] runs [`CalibratorPolicy`] in-process;
//!   [`crate::soc::ctl::FirmwareBrain`] runs the same policy as RV32IM
//!   fixed-point firmware on the simulated SoC, fed through a
//!   memory-mapped mailbox ([`Calibrator::spawn_with`] accepts either);
//! * [`Calibrator`] — the daemon: one background thread sampling
//!   `Health` per core each period and executing the brain's drains
//!   through the same `submit` path every other client uses (the drain
//!   barrier, fence, bank refold, and trim refresh all come for free);
//! * [`CalibratorShared`] / [`CoreCalStats`] — live observability: the
//!   per-core trend, last-recal epoch, and trigger counters, served
//!   over the wire as `CalStats` frames (`client --op calstats`) and
//!   printed at `serve` shutdown.
//!
//! Policy guards (tested in this file):
//! * **cool-down** — after any drain *attempt* a core is left alone for
//!   `cooldown`, so a die whose residual cannot be pulled back in band
//!   does not trigger a drain storm;
//! * **last healthy core** — a core still accepting placed work is
//!   never drained when it is the only one (availability beats
//!   freshness); a FENCED core is always drainable — it serves nothing,
//!   so recalibrating it can only help. A K=1 deployment therefore
//!   still self-heals: the residual grows past the health band, the
//!   `Health` probe fences the core, and the now-fenced core qualifies
//!   for the drain that brings it back.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::coordinator::batcher::ServeError;
use crate::coordinator::service::CimService;
use crate::util::sync::lock_unpoisoned;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Tuning knobs of the recalibration control loop.
#[derive(Debug, Clone)]
pub struct CalibratorConfig {
    /// Interval between health-sampling sweeps (one `Health` probe per
    /// core per sweep).
    pub period: Duration,
    /// Weight of the newest residual in the per-core EWMA trend
    /// (0 < alpha <= 1; 1 = track the raw residual).
    pub ewma_alpha: f64,
    /// Drain a core when its residual trend exceeds this. Typically set
    /// BELOW the serving health band: the daemon recalibrates
    /// proactively before the fence would take the core out.
    pub threshold: f64,
    /// Drain a core regardless of trend once its last recalibration is
    /// this old (periodic BISC as a freshness deadline).
    pub max_staleness: Duration,
    /// Minimum spacing between drain attempts on one core (storm guard).
    pub cooldown: Duration,
}

impl Default for CalibratorConfig {
    fn default() -> Self {
        Self {
            period: Duration::from_millis(500),
            ewma_alpha: 0.4,
            threshold: crate::coordinator::service::DEFAULT_HEALTH_BAND * 0.8,
            max_staleness: Duration::from_secs(3600),
            cooldown: Duration::from_secs(5),
        }
    }
}

/// Why the policy wants a core drained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrainReason {
    /// The EWMA residual trend crossed the threshold.
    Trend,
    /// The core's last recalibration aged past `max_staleness`.
    Staleness,
}

impl std::fmt::Display for DrainReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DrainReason::Trend => write!(f, "trend"),
            DrainReason::Staleness => write!(f, "staleness"),
        }
    }
}

/// Per-core policy state.
#[derive(Debug, Clone)]
struct CoreState {
    /// EWMA of the observed residuals; `None` until the first sample.
    ewma: Option<f64>,
    /// When this core was last known freshly calibrated (daemon start
    /// counts: serving setups calibrate before serving).
    last_recal: Instant,
    /// When a drain was last *attempted* on this core (cool-down clock).
    last_drain: Option<Instant>,
}

/// The pure decision state machine: residuals in, drain decisions out.
/// Holds no clock and spawns nothing — every transition takes an
/// explicit `now`, so tests can replay any schedule deterministically.
#[derive(Debug, Clone)]
pub struct CalibratorPolicy {
    cfg: CalibratorConfig,
    cores: Vec<CoreState>,
}

impl CalibratorPolicy {
    pub fn new(cfg: CalibratorConfig, cores: usize, now: Instant) -> Self {
        let state = CoreState { ewma: None, last_recal: now, last_drain: None };
        Self { cfg, cores: vec![state; cores] }
    }

    /// Fold one residual sample into the core's trend; returns the
    /// updated EWMA.
    pub fn observe(&mut self, core: usize, residual: f64) -> f64 {
        // an untracked core index degrades to the raw sample — the policy
        // never panics on daemon/board disagreement about the core count
        let Some(st) = self.cores.get_mut(core) else { return residual };
        let next = match st.ewma {
            None => residual,
            Some(e) => self.cfg.ewma_alpha * residual + (1.0 - self.cfg.ewma_alpha) * e,
        };
        st.ewma = Some(next);
        next
    }

    /// Current trend of one core (`None` before the first sample).
    pub fn trend(&self, core: usize) -> Option<f64> {
        self.cores.get(core).and_then(|st| st.ewma)
    }

    /// Should `core` be drained now? `healthy_cores` is the count of
    /// cores currently accepting placed work and `fenced` whether THIS
    /// core is one of the excluded.
    pub fn decide(
        &self,
        core: usize,
        healthy_cores: usize,
        fenced: bool,
        now: Instant,
    ) -> Option<DrainReason> {
        let st = self.cores.get(core)?;
        // cool-down: one drain attempt per window, success or not
        if let Some(t) = st.last_drain {
            if now < t + self.cfg.cooldown {
                return None;
            }
        }
        // availability guard: never drain the last core still serving
        // placed work; a fenced core serves nothing, so draining it can
        // only help
        if !fenced && healthy_cores <= 1 {
            return None;
        }
        if st.ewma.is_some_and(|e| e > self.cfg.threshold) {
            return Some(DrainReason::Trend);
        }
        // staleness only fires on cores whose residual is observable
        // (at least one Health probe returned a measurement): a service
        // without a calibration engine cannot recalibrate either, so a
        // staleness drain there would just fence the core forever and
        // retry a guaranteed-failing drain every cool-down
        if st.ewma.is_some() && now >= st.last_recal + self.cfg.max_staleness {
            return Some(DrainReason::Staleness);
        }
        None
    }

    /// Record a drain attempt on `core`. A successful recalibration
    /// resets the staleness clock and re-seeds the trend from the
    /// post-recalibration residual (when the drain reported one).
    pub fn record_drain(
        &mut self,
        core: usize,
        now: Instant,
        recalibrated: bool,
        residual: Option<f64>,
    ) {
        let Some(st) = self.cores.get_mut(core) else { return };
        st.last_drain = Some(now);
        if recalibrated {
            st.last_recal = now;
            st.ewma = residual;
        }
    }
}

/// The decision-maker seam of the daemon. The daemon owns the service
/// plumbing — health sampling, drain execution, stats, logging — and
/// delegates every calibration *decision* to a brain. Implementations:
/// [`HostBrain`] (the f64 [`CalibratorPolicy`] in-process) and
/// [`crate::soc::ctl::FirmwareBrain`] (the same policy as RV32IM
/// fixed-point firmware behind a memory-mapped mailbox). Remote clients
/// see identical `CalStats` frames either way.
pub trait CalibratorBrain {
    /// Fold one health sample into the per-core trend. `residual` is
    /// `None` when the service has no calibration engine; the returned
    /// trend must be `Some` only when this sample carried a residual
    /// (it feeds the `samples`/`trend` statistics).
    fn observe(
        &mut self,
        core: usize,
        residual: Option<f64>,
        fenced: bool,
        recal_epoch: u64,
        healthy_cores: usize,
    ) -> Option<f64>;

    /// Should `core` be drained now?
    fn decide(&mut self, core: usize, healthy_cores: usize, fenced: bool) -> Option<DrainReason>;

    /// Report the outcome of a drain the daemon executed for this brain.
    fn record_drain(&mut self, core: usize, recalibrated: bool, residual: Option<f64>);

    /// Current trend of one core (`None` before the first sample).
    fn trend(&self, core: usize) -> Option<f64>;

    /// Short label for log lines; the host brain stays unlabelled so
    /// existing log consumers (CI greps) are unaffected.
    fn tag(&self) -> &'static str {
        ""
    }
}

/// The in-process decision-maker: [`CalibratorPolicy`] driven by the
/// host monotonic clock.
pub struct HostBrain {
    policy: CalibratorPolicy,
}

impl HostBrain {
    pub fn new(cfg: CalibratorConfig, cores: usize) -> Self {
        Self { policy: CalibratorPolicy::new(cfg, cores, Instant::now()) }
    }
}

impl CalibratorBrain for HostBrain {
    fn observe(
        &mut self,
        core: usize,
        residual: Option<f64>,
        _fenced: bool,
        _recal_epoch: u64,
        _healthy_cores: usize,
    ) -> Option<f64> {
        residual.map(|r| self.policy.observe(core, r))
    }

    fn decide(&mut self, core: usize, healthy_cores: usize, fenced: bool) -> Option<DrainReason> {
        self.policy.decide(core, healthy_cores, fenced, Instant::now())
    }

    fn record_drain(&mut self, core: usize, recalibrated: bool, residual: Option<f64>) {
        self.policy.record_drain(core, Instant::now(), recalibrated, residual);
    }

    fn trend(&self, core: usize) -> Option<f64> {
        self.policy.trend(core)
    }
}

/// Live statistics of one core, as maintained by the daemon and served
/// over the wire (`CalStats` frames).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CoreCalStats {
    /// `Health` samples folded into the trend so far.
    pub samples: u64,
    /// Current EWMA residual trend (`None` before the first sample).
    pub trend: Option<f64>,
    /// Last server-observed recalibration epoch of this core.
    pub last_recal_epoch: u64,
    /// Drains triggered by the trend threshold.
    pub trend_triggers: u64,
    /// Drains triggered by the staleness deadline.
    pub staleness_triggers: u64,
    /// Drains that completed with a recalibration.
    pub drains: u64,
    /// Drain attempts that failed (serve error or no recalibration ran).
    pub drain_failures: u64,
    /// Whether the core was fenced at the last sweep.
    pub fenced: bool,
    /// Whether the core is RETIRED: the drain barrier's fault classifier
    /// found permanent hard faults, the fence is final, and the daemon
    /// no longer spends drains on it (a retired core can never rejoin).
    pub retired: bool,
    /// Registry id of the model resident on the core at the last sweep
    /// (`None` when nothing is resident — e.g. a core programmed
    /// directly without a registry deploy recording residency).
    pub model: Option<u32>,
}

/// Snapshot store shared between the daemon, the wire front-end, and
/// the CLI shutdown report.
pub struct CalibratorShared {
    stats: Mutex<Vec<CoreCalStats>>,
    /// completed sampling sweeps (liveness signal for operators)
    sweeps: AtomicU64,
}

impl CalibratorShared {
    fn new(cores: usize) -> Self {
        Self { stats: Mutex::new(vec![CoreCalStats::default(); cores]), sweeps: AtomicU64::new(0) }
    }

    /// Current per-core statistics.
    pub fn snapshot(&self) -> Vec<CoreCalStats> {
        lock_unpoisoned(&self.stats).clone()
    }

    /// Completed sampling sweeps so far.
    pub fn sweeps(&self) -> u64 {
        self.sweeps.load(Ordering::Relaxed)
    }

    /// Total completed drain→recalibrate cycles across all cores.
    pub fn total_drains(&self) -> u64 {
        lock_unpoisoned(&self.stats).iter().map(|s| s.drains).sum()
    }

    fn update<F: FnOnce(&mut CoreCalStats)>(&self, core: usize, f: F) {
        if let Some(s) = lock_unpoisoned(&self.stats).get_mut(core) {
            f(s);
        }
    }
}

/// The background recalibration daemon. Construct with
/// [`Calibrator::spawn`] over any [`CimService`] (the in-process
/// cluster client or a [`crate::coordinator::wire::RemoteClient`]) and
/// stop it with [`Calibrator::stop`]; dropping without `stop` also
/// shuts the thread down.
pub struct Calibrator {
    stop: Arc<AtomicBool>,
    shared: Arc<CalibratorShared>,
    handle: Option<JoinHandle<()>>,
}

impl Calibrator {
    /// Start the daemon over `svc`. The calibrator holds its own clone
    /// of the service — drop/stop it before joining the cluster server,
    /// like any other client.
    pub fn spawn<S: CimService + Send + 'static>(svc: S, cfg: CalibratorConfig) -> Self {
        let brain_cfg = cfg.clone();
        Self::spawn_with(svc, cfg, move |cores| HostBrain::new(brain_cfg, cores))
    }

    /// Start the daemon with a custom decision-maker. `make_brain` runs
    /// on the daemon thread (it receives the core count), so brains
    /// built on non-`Send` state — like the firmware supervisor's
    /// `Box<dyn BusDevice>` bus — work without threading gymnastics.
    pub fn spawn_with<S, B, F>(svc: S, cfg: CalibratorConfig, make_brain: F) -> Self
    where
        S: CimService + Send + 'static,
        B: CalibratorBrain,
        F: FnOnce(usize) -> B + Send + 'static,
    {
        let stop = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(CalibratorShared::new(svc.cores()));
        let handle = {
            let stop = Arc::clone(&stop);
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || {
                let brain = make_brain(svc.cores());
                run_with_brain(svc, cfg, brain, &stop, &shared);
            })
        };
        Self { stop, shared, handle: Some(handle) }
    }

    /// Handle on the live statistics (what the wire front-end serves).
    pub fn shared(&self) -> Arc<CalibratorShared> {
        Arc::clone(&self.shared)
    }

    /// Signal the daemon, join its thread, and return the final
    /// per-core statistics.
    pub fn stop(mut self) -> Vec<CoreCalStats> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.shared.snapshot()
    }
}

impl Drop for Calibrator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One sampling sweep + decision pass per period until stopped. Health
/// probes and drains go through the ordinary submit path, so they queue
/// behind in-flight work exactly like operator-issued lifecycle jobs.
fn run_with_brain<S: CimService, B: CalibratorBrain>(
    svc: S,
    cfg: CalibratorConfig,
    mut brain: B,
    stop: &AtomicBool,
    shared: &CalibratorShared,
) {
    let k = svc.cores();
    // the host brain logs as plain "calibrator" (byte-compatible with
    // pre-split consumers); other brains are labelled, e.g.
    // "calibrator[firmware]"
    let who = if brain.tag().is_empty() {
        "calibrator".to_string()
    } else {
        format!("calibrator[{}]", brain.tag())
    };
    while !stop.load(Ordering::SeqCst) {
        let sweep_start = Instant::now();
        for core in 0..k {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let health = match svc.health(core) {
                Ok(h) => h,
                // the service is gone: nothing left to calibrate
                Err(ServeError::Disconnected) => return,
                Err(_) => continue,
            };
            // a retired core is permanently fenced by the fault
            // classifier: recalibration cannot pull a hard fault back in
            // band, so spending drains (and characterization reads) on it
            // would be a storm with no exit — record it and move on
            if health.retired {
                shared.update(core, |s| {
                    s.retired = true;
                    s.fenced = health.fenced;
                    s.last_recal_epoch = health.recal_epoch;
                    s.model = health.model;
                });
                continue;
            }
            let healthy = svc.board().healthy_cores();
            let trend =
                brain.observe(core, health.residual, health.fenced, health.recal_epoch, healthy);
            shared.update(core, |s| {
                if trend.is_some() {
                    s.samples += 1;
                    s.trend = trend;
                }
                s.fenced = health.fenced;
                s.retired = false;
                s.last_recal_epoch = health.recal_epoch;
                s.model = health.model;
            });
            let Some(reason) = brain.decide(core, healthy, health.fenced) else {
                continue;
            };
            let pre_trend = brain.trend(core).unwrap_or(f64::NAN);
            println!(
                "{who}: core {core} {reason} trigger (trend {pre_trend:.4}, \
                 threshold {:.4}) — draining",
                cfg.threshold
            );
            shared.update(core, |s| match reason {
                DrainReason::Trend => s.trend_triggers += 1,
                DrainReason::Staleness => s.staleness_triggers += 1,
            });
            match svc.drain(core) {
                Ok(h) => {
                    brain.record_drain(core, h.recalibrated, h.residual);
                    shared.update(core, |s| {
                        if h.recalibrated {
                            s.drains += 1;
                        } else {
                            s.drain_failures += 1;
                        }
                        s.trend = h.residual.or(s.trend);
                        s.fenced = h.fenced;
                        s.retired = h.retired;
                        s.last_recal_epoch = h.recal_epoch;
                        s.model = h.model;
                    });
                    let post = h.residual.unwrap_or(f64::NAN);
                    if h.recalibrated && !h.fenced {
                        println!(
                            "{who}: core {core} drain -> recalibrate -> rejoin \
                             complete (residual {pre_trend:.4} -> {post:.4}, epoch {})",
                            h.recal_epoch
                        );
                    } else {
                        println!(
                            "{who}: core {core} drain finished without rejoining \
                             (residual {pre_trend:.4} -> {post:.4}, fenced {}, \
                             recalibrated {}, epoch {})",
                            h.fenced, h.recalibrated, h.recal_epoch
                        );
                    }
                }
                Err(ServeError::Disconnected) => return,
                Err(e) => {
                    brain.record_drain(core, false, None);
                    shared.update(core, |s| s.drain_failures += 1);
                    eprintln!("{who}: core {core} drain failed: {e}");
                }
            }
        }
        shared.sweeps.fetch_add(1, Ordering::Relaxed);
        // sleep out the rest of the period in short slices so stop()
        // never waits a full period
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let left = cfg.period.saturating_sub(sweep_start.elapsed());
            if left.is_zero() {
                break;
            }
            std::thread::sleep(left.min(Duration::from_millis(20)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CalibratorConfig {
        CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: 0.5,
            threshold: 0.05,
            max_staleness: Duration::from_secs(60),
            cooldown: Duration::from_secs(5),
        }
    }

    #[test]
    fn ewma_tracks_toward_the_residual() {
        let mut p = CalibratorPolicy::new(cfg(), 1, Instant::now());
        assert_eq!(p.trend(0), None);
        assert_eq!(p.observe(0, 0.10), 0.10, "first sample seeds the trend");
        let e = p.observe(0, 0.20);
        assert!((e - 0.15).abs() < 1e-12, "alpha 0.5 blend, got {e}");
        // repeated samples converge on the residual
        let mut e = e;
        for _ in 0..50 {
            e = p.observe(0, 0.20);
        }
        assert!((e - 0.20).abs() < 1e-6);
    }

    #[test]
    fn trend_threshold_triggers_a_drain() {
        let t0 = Instant::now();
        let mut p = CalibratorPolicy::new(cfg(), 2, t0);
        p.observe(0, 0.01);
        assert_eq!(p.decide(0, 2, false, t0), None, "in-band trend must not drain");
        // a single borderline spike is damped below the threshold by the
        // EWMA (0.5 * 0.08 + 0.5 * 0.01 = 0.045 < 0.05)...
        p.observe(0, 0.08);
        assert_eq!(p.decide(0, 2, false, t0), None, "EWMA must damp a lone spike");
        // ...but a sustained excursion pushes the trend across
        p.observe(0, 0.08);
        p.observe(0, 0.08);
        assert_eq!(p.decide(0, 2, false, t0), Some(DrainReason::Trend));
        // while the untouched core stays quiet
        assert_eq!(p.decide(1, 2, false, t0), None);
    }

    #[test]
    fn staleness_deadline_triggers_when_the_trend_is_quiet() {
        let t0 = Instant::now();
        let mut p = CalibratorPolicy::new(cfg(), 2, t0);
        // with NO residual ever observed the core cannot recalibrate
        // (no engine) — staleness must never fence it into a drain loop
        assert_eq!(p.decide(0, 2, false, t0 + Duration::from_secs(61)), None);
        // an in-band residual arms the deadline without arming the trend
        p.observe(0, 0.01);
        assert_eq!(p.decide(0, 2, false, t0 + Duration::from_secs(59)), None);
        assert_eq!(
            p.decide(0, 2, false, t0 + Duration::from_secs(61)),
            Some(DrainReason::Staleness)
        );
    }

    #[test]
    fn cooldown_prevents_drain_storms() {
        let t0 = Instant::now();
        let mut p = CalibratorPolicy::new(cfg(), 2, t0);
        // a die whose residual stays out of band even after recalibration
        p.observe(0, 0.5);
        assert_eq!(p.decide(0, 2, false, t0), Some(DrainReason::Trend));
        p.record_drain(0, t0, true, Some(0.5));
        // still out of band, but inside the cool-down window: no drain
        assert_eq!(p.decide(0, 2, false, t0 + Duration::from_secs(1)), None);
        assert_eq!(p.decide(0, 2, false, t0 + Duration::from_secs(4)), None);
        // after the window the trigger re-arms
        assert_eq!(
            p.decide(0, 2, false, t0 + Duration::from_secs(6)),
            Some(DrainReason::Trend)
        );
        // failed attempts arm the cool-down too
        p.record_drain(0, t0 + Duration::from_secs(6), false, None);
        assert_eq!(p.decide(0, 2, false, t0 + Duration::from_secs(7)), None);
    }

    #[test]
    fn never_drains_the_last_healthy_core() {
        let t0 = Instant::now();
        let mut p = CalibratorPolicy::new(cfg(), 1, t0);
        p.observe(0, 0.5);
        // the only core accepting work: neither trigger may drain it
        assert_eq!(p.decide(0, 1, false, t0), None);
        assert_eq!(p.decide(0, 1, false, t0 + Duration::from_secs(3600)), None);
        // once FENCED it serves nothing — draining it can only help
        assert_eq!(p.decide(0, 0, true, t0), Some(DrainReason::Trend));
        // and with a second healthy core available the guard releases
        assert_eq!(p.decide(0, 2, false, t0), Some(DrainReason::Trend));
    }

    #[test]
    fn successful_drain_resets_trend_and_staleness() {
        let t0 = Instant::now();
        let mut p = CalibratorPolicy::new(cfg(), 1, t0);
        p.observe(0, 0.5);
        p.record_drain(0, t0 + Duration::from_secs(10), true, Some(0.01));
        assert_eq!(p.trend(0), Some(0.01), "trend re-seeds from the post-recal residual");
        // staleness clock restarts from the drain, not from birth
        assert_eq!(
            p.decide(0, 2, false, t0 + Duration::from_secs(65)),
            None,
            "staleness must measure from the recalibration"
        );
        assert_eq!(
            p.decide(0, 2, false, t0 + Duration::from_secs(71)),
            Some(DrainReason::Staleness)
        );
    }

    use crate::coordinator::service::{
        CoreBoard, CoreHealth, Job, JobReply, Placement, SubmitOpts, Ticket,
    };
    use std::cell::{Cell, RefCell};
    use std::rc::Rc;

    /// A hand-cranked service: core 0 reports an out-of-band residual,
    /// core 1 is clean, core 2 is RETIRED on the board. Disconnects
    /// after a fixed submit budget so `run_with_brain` returns on its
    /// own (the daemon treats `Disconnected` as "service gone").
    struct RetiredFleet {
        board: Arc<CoreBoard>,
        drained: Rc<RefCell<Vec<usize>>>,
        submits: Cell<u32>,
    }

    impl CimService for RetiredFleet {
        fn board(&self) -> &CoreBoard {
            &self.board
        }

        fn submit(&self, job: Job, opts: SubmitOpts) -> Result<Ticket<JobReply>, ServeError> {
            let n = self.submits.get();
            self.submits.set(n + 1);
            if n >= 20 {
                return Err(ServeError::Disconnected);
            }
            let core = match opts.placement {
                Placement::Pinned(k) => k,
                _ => 0,
            };
            let health = |residual: f64, recalibrated: bool| CoreHealth {
                core,
                residual: Some(residual),
                fenced: self.board.is_fenced(core),
                recalibrated,
                recal_epoch: 0,
                model: None,
                retired: self.board.is_retired(core),
                fault_mask: self.board.fault_mask(core),
            };
            let reply = match job {
                Job::Health => health(if core == 0 { 0.5 } else { 0.01 }, false),
                Job::Drain => {
                    self.drained.borrow_mut().push(core);
                    // the mock worker recalibrates clean and rejoins
                    self.board.unfence(core);
                    health(0.01, true)
                }
                other => unreachable!("daemon submitted {other:?}"),
            };
            let (tx, rx) = std::sync::mpsc::channel();
            let _ = tx.send(Ok(JobReply::Health(reply)));
            Ok(Ticket::new(rx, core))
        }
    }

    #[test]
    fn a_retired_core_is_never_drained_or_rejoined() {
        let board = Arc::new(CoreBoard::new(3));
        board.retire(2, 0b0000_0100);
        let drained = Rc::new(RefCell::new(Vec::new()));
        let svc = RetiredFleet {
            board: Arc::clone(&board),
            drained: Rc::clone(&drained),
            submits: Cell::new(0),
        };
        let cfg = CalibratorConfig { period: Duration::from_millis(1), ..cfg() };
        let brain = HostBrain::new(cfg.clone(), 3);
        let stop = AtomicBool::new(false);
        let shared = CalibratorShared::new(3);
        run_with_brain(svc, cfg, brain, &stop, &shared);

        // the out-of-band live core drains exactly once (cool-down holds
        // afterwards); the retired core is never selected
        assert_eq!(*drained.borrow(), vec![0], "only the out-of-band live core may drain");

        let stats = shared.snapshot();
        assert!(stats[2].retired, "the daemon must report the retirement");
        assert!(stats[2].fenced, "retirement keeps the permanent fence visible");
        assert_eq!(stats[2].samples, 0, "no residual samples are spent on a retired core");
        assert_eq!(stats[2].trend, None);
        assert_eq!(stats[2].drains + stats[2].drain_failures, 0);
        assert_eq!(stats[0].drains, 1, "the live out-of-band core recalibrated");
        assert!(!stats[0].retired);

        // and nothing can rejoin it: the board refuses to unfence a
        // retired core, so placement never sees it again
        board.unfence(2);
        assert!(board.is_fenced(2), "a retired core must never rejoin placement");
        assert!(board.is_retired(2));
    }
}
