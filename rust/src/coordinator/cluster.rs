//! Multi-core sharded serving engine: a `CimCluster` owns K independent
//! CIM arrays ("cores"), each a full [`CimAnalogModel`] with its own
//! Monte-Carlo variation draw and its own BISC trims — the multi-tile CIM
//! fabric the paper projects when extending the proof-of-concept SoC to
//! high-density linear-resistor arrays (cf. NeuroSim-style multi-tile
//! modelling, where throughput AND calibration cost scale with the number
//! of physical arrays).
//!
//! Layers:
//! * construction — per-core seed derivation (`core_seed`) so every core
//!   is a distinct reproducible die;
//! * calibration — [`CimCluster::calibrate_parallel`] runs the per-column
//!   BISC characterization of all cores concurrently (scoped threads; on
//!   silicon each tile has its own RISC-V sequencer, so calibration time
//!   is per-core, not per-cluster);
//! * serving — [`CimCluster::serve`] converts the cluster into a worker
//!   pool (one [`Batcher`] loop per core, std threads + channels) and
//!   hands out [`ClusterClient`]s that scatter `MacRequest`s round-robin
//!   across the cores and gather replies per-request.
//!
//! The DNN tile scheduler side (tiles mapped across cores instead of
//! serialized on one array) lives in [`crate::coordinator::dnn`].

use crate::analog::variation::VariationSample;
use crate::analog::CimAnalogModel;
use crate::config::SimConfig;
use crate::coordinator::batcher::{
    Batcher, BatcherStats, MacReply, MacRequest, ServeError,
};
use crate::coordinator::bisc::{AdcCharacterization, BiscEngine, BiscReport};
use crate::util::rng::SplitMix64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Derive the die seed of core `core` from the cluster's base seed.
/// Core 0 keeps the base seed so a K=1 cluster reproduces the single-array
/// experiments bit-for-bit; the rest are SplitMix64-mixed.
pub fn core_seed(base: u64, core: usize) -> u64 {
    if core == 0 {
        base
    } else {
        let mut sm = SplitMix64::new(base ^ (core as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        sm.next_u64()
    }
}

/// One physical array of the cluster: its own die, its own trims.
pub struct ClusterCore {
    pub id: usize,
    pub seed: u64,
    pub sample: VariationSample,
    pub model: CimAnalogModel,
    /// BISC outcome of the most recent cluster calibration, if any
    pub report: Option<BiscReport>,
}

/// K independent CIM cores behind one coordinator.
pub struct CimCluster {
    pub cores: Vec<ClusterCore>,
}

impl CimCluster {
    /// Draw `k` distinct dies from the config (per-core seeds derived via
    /// [`core_seed`]). Panics on `k == 0`.
    pub fn new(cfg: &SimConfig, k: usize) -> Self {
        assert!(k > 0, "a cluster needs at least one core");
        let cores = (0..k)
            .map(|id| {
                let mut core_cfg = cfg.clone();
                core_cfg.seed = core_seed(cfg.seed, id);
                let sample = VariationSample::draw(&core_cfg);
                let model = CimAnalogModel::from_sample(&core_cfg, &sample);
                ClusterCore { id, seed: core_cfg.seed, sample, model, report: None }
            })
            .collect();
        Self { cores }
    }

    pub fn len(&self) -> usize {
        self.cores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    /// Program the same weight matrix on every core.
    pub fn program_all(&mut self, weights: &[i32]) {
        for core in &mut self.cores {
            core.model.program(weights);
        }
    }

    /// Program one core (per-core weights: tile sharding, A/B testing).
    pub fn program_core(&mut self, core: usize, weights: &[i32]) {
        self.cores[core].model.program(weights);
    }

    /// Run `f` once per core, all cores in parallel on scoped threads —
    /// the shared scaffold under every per-core cluster operation.
    pub fn for_each_core_parallel<F>(&mut self, f: F)
    where
        F: Fn(&mut ClusterCore) + Sync,
    {
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = self
                .cores
                .iter_mut()
                .map(|core| s.spawn(move || f(core)))
                .collect();
            for h in handles {
                h.join().expect("cluster core worker panicked");
            }
        });
    }

    /// Run the full per-column BISC routine on every core IN PARALLEL
    /// (one scoped thread per core). Each core keeps its own trims and
    /// its own report; total wall time is one core's calibration, not K.
    pub fn calibrate_parallel(&mut self, engine: &BiscEngine) {
        self.for_each_core_parallel(|core| {
            core.report = Some(engine.calibrate(&mut core.model));
        });
    }

    /// Iterative variant (`passes` >= 1), still one thread per core.
    pub fn calibrate_parallel_iterative(&mut self, engine: &BiscEngine, passes: usize) {
        self.for_each_core_parallel(|core| {
            core.report = Some(engine.calibrate_iterative(&mut core.model, passes));
        });
    }

    /// Cascaded workload calibration (full-range pass + operating-point
    /// refine, see [`BiscEngine::calibrate_for_workload`]) on every core
    /// in parallel.
    pub fn calibrate_for_workload_parallel(
        &mut self,
        cfg: &SimConfig,
        adc_char: AdcCharacterization,
        op_half_v: f64,
    ) {
        self.for_each_core_parallel(|core| {
            core.report = Some(BiscEngine::calibrate_for_workload(
                cfg,
                adc_char,
                &mut core.model,
                op_half_v,
            ));
        });
    }

    /// Total characterization reads issued by the last calibration.
    pub fn total_calibration_reads(&self) -> u64 {
        self.cores
            .iter()
            .filter_map(|c| c.report.as_ref().map(|r| r.reads))
            .sum()
    }

    /// Convert the cluster into a serving worker pool: one batcher loop
    /// per core. The cores move into their worker threads and come back
    /// through [`ClusterServer::join`].
    pub fn serve(self, batcher: Batcher) -> ClusterServer {
        let mut txs = Vec::with_capacity(self.cores.len());
        let mut handles = Vec::with_capacity(self.cores.len());
        for mut core in self.cores {
            let (tx, rx) = channel::<MacRequest>();
            handles.push(std::thread::spawn(move || {
                let stats = batcher.run(rx, &mut core.model);
                (core, stats)
            }));
            txs.push(tx);
        }
        ClusterServer { txs, handles, rr: Arc::new(AtomicUsize::new(0)) }
    }
}

/// The running worker pool: K batcher threads, one per core.
pub struct ClusterServer {
    txs: Vec<Sender<MacRequest>>,
    handles: Vec<JoinHandle<(ClusterCore, BatcherStats)>>,
    rr: Arc<AtomicUsize>,
}

impl ClusterServer {
    pub fn cores(&self) -> usize {
        self.txs.len()
    }

    /// A cloneable client that scatters requests across all cores.
    pub fn client(&self) -> ClusterClient {
        ClusterClient { txs: self.txs.clone(), rr: Arc::clone(&self.rr) }
    }

    /// Shut down: drop this server's senders and wait for the workers.
    /// Outstanding `ClusterClient`s keep their own senders — drop them
    /// first or the workers keep serving. Returns the cluster (cores with
    /// their final state) and per-core run statistics.
    pub fn join(self) -> (CimCluster, Vec<BatcherStats>) {
        drop(self.txs);
        let mut cores = Vec::with_capacity(self.handles.len());
        let mut stats = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            let (core, st) = h.join().expect("cluster worker panicked");
            cores.push(core);
            stats.push(st);
        }
        cores.sort_by_key(|c| c.id);
        (CimCluster { cores }, stats)
    }
}

/// Scatter-gather client handle over the cluster's request channels.
#[derive(Clone)]
pub struct ClusterClient {
    txs: Vec<Sender<MacRequest>>,
    /// shared round-robin cursor (all clones cooperate)
    rr: Arc<AtomicUsize>,
}

impl ClusterClient {
    pub fn cores(&self) -> usize {
        self.txs.len()
    }

    /// Submit one MAC to the next core (round-robin) and wait.
    pub fn mac(&self, x: Vec<i32>) -> Result<Vec<u32>, ServeError> {
        let core = self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.mac_on(core, x)
    }

    /// Submit one MAC to a specific core and wait.
    pub fn mac_on(&self, core: usize, x: Vec<i32>) -> Result<Vec<u32>, ServeError> {
        self.submit_on(core, x)?.recv().map_err(|_| ServeError::Disconnected)?
    }

    /// Fire-and-gather-later: submit to the next core (round-robin) and
    /// return the reply channel (pipelined scatter-gather).
    pub fn submit(&self, x: Vec<i32>) -> Result<Receiver<MacReply>, ServeError> {
        let core = self.rr.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        self.submit_on(core, x)
    }

    /// Fire-and-gather-later on a specific core.
    pub fn submit_on(&self, core: usize, x: Vec<i32>) -> Result<Receiver<MacReply>, ServeError> {
        let (reply_tx, reply_rx) = channel();
        self.txs[core]
            .send(MacRequest { x, reply: reply_tx })
            .map_err(|_| ServeError::Disconnected)?;
        Ok(reply_rx)
    }

    /// Scatter `n` requests round-robin with up to `window` in flight,
    /// gathering every reply — the throughput-oriented submission loop
    /// shared by `acore-cim serve` and the perf bench. `make(i)` builds
    /// the i-th input vector. Stops on the first error.
    pub fn mac_pipelined<F>(&self, n: usize, window: usize, mut make: F) -> Result<(), ServeError>
    where
        F: FnMut(usize) -> Vec<i32>,
    {
        let mut inflight: std::collections::VecDeque<Receiver<MacReply>> =
            std::collections::VecDeque::new();
        for i in 0..n {
            inflight.push_back(self.submit(make(i))?);
            if inflight.len() >= window.max(1) {
                let rx = inflight.pop_front().unwrap();
                rx.recv().map_err(|_| ServeError::Disconnected)??;
            }
        }
        for rx in inflight {
            rx.recv().map_err(|_| ServeError::Disconnected)??;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::consts as c;

    fn ideal_cfg() -> SimConfig {
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        cfg
    }

    #[test]
    fn core_seeds_are_distinct_and_stable() {
        let base = 0xAC0_CE11;
        assert_eq!(core_seed(base, 0), base);
        let seeds: Vec<u64> = (0..8).map(|k| core_seed(base, k)).collect();
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "cores {i}/{j} share a seed");
            }
        }
        assert_eq!(seeds, (0..8).map(|k| core_seed(base, k)).collect::<Vec<_>>());
    }

    #[test]
    fn cluster_cores_are_distinct_dies() {
        let cfg = SimConfig::default();
        let cluster = CimCluster::new(&cfg, 3);
        assert_eq!(cluster.len(), 3);
        assert_ne!(cluster.cores[0].sample.alpha_p, cluster.cores[1].sample.alpha_p);
        assert_ne!(cluster.cores[1].sample.alpha_p, cluster.cores[2].sample.alpha_p);
        // core 0 reproduces the single-array experiment
        let single = VariationSample::draw(&cfg);
        assert_eq!(cluster.cores[0].sample.alpha_p, single.alpha_p);
    }

    #[test]
    fn parallel_calibration_trims_every_core() {
        let cfg = SimConfig::default();
        let mut cluster = CimCluster::new(&cfg, 3);
        let engine = BiscEngine::from_config(&cfg, crate::coordinator::bisc::AdcCharacterization::ideal());
        cluster.calibrate_parallel(&engine);
        for core in &cluster.cores {
            let report = core.report.as_ref().expect("core not calibrated");
            assert_eq!(report.columns.len(), c::M_COLS);
        }
        assert_eq!(cluster.total_calibration_reads(), 3 * 2048);
        // different dies => different trims (overwhelmingly likely)
        let trims = |k: usize| {
            cluster.cores[k]
                .report
                .as_ref()
                .unwrap()
                .columns
                .iter()
                .map(|cc| cc.pot_p)
                .collect::<Vec<_>>()
        };
        assert_ne!(trims(0), trims(1));
    }

    #[test]
    fn serve_round_robin_answers_everything() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 4);
        cluster.program_all(&vec![40; c::N_ROWS * c::M_COLS]);
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        // ideal dies, same weights: every core returns the same answer
        let mut reference = CimAnalogModel::ideal();
        reference.program(&vec![40; c::N_ROWS * c::M_COLS]);
        let expect = reference.forward_batch(&vec![30; c::N_ROWS], 1);
        let n = 64;
        let replies: Vec<_> =
            (0..n).map(|_| client.submit(vec![30; c::N_ROWS]).unwrap()).collect();
        for r in replies {
            assert_eq!(r.recv().unwrap().unwrap(), expect);
        }
        drop(client);
        let (_cluster, stats) = server.join();
        let total: u64 = stats.iter().map(|s| s.requests).sum();
        assert_eq!(total, n as u64);
        // round robin spreads the load over every core
        for (k, s) in stats.iter().enumerate() {
            assert!(s.requests > 0, "core {k} served nothing");
        }
    }
}
