//! Multi-core sharded serving engine: a `CimCluster` owns K independent
//! CIM arrays ("cores"), each a full [`CimAnalogModel`] with its own
//! Monte-Carlo variation draw and its own BISC trims — the multi-tile CIM
//! fabric the paper projects when extending the proof-of-concept SoC to
//! high-density linear-resistor arrays (cf. NeuroSim-style multi-tile
//! modelling, where throughput AND calibration cost scale with the number
//! of physical arrays).
//!
//! Layers:
//! * construction — per-core seed derivation (`core_seed`) so every core
//!   is a distinct reproducible die;
//! * calibration — [`CimCluster::calibrate_parallel`] runs the per-column
//!   BISC characterization of all cores concurrently (scoped threads; on
//!   silicon each tile has its own RISC-V sequencer, so calibration time
//!   is per-core, not per-cluster);
//! * serving — [`CimCluster::serve_with`] converts the cluster into a
//!   worker pool (one [`Batcher`] loop per core, std threads + channels)
//!   and hands out [`ClusterClient`]s. A `ClusterClient` is a
//!   [`crate::coordinator::service::CimService`]: every request —
//!   single MACs, native batches, DNN
//!   tile batches, drain/health lifecycle jobs — goes through the one
//!   `submit(Job, SubmitOpts) -> Ticket` entry point, with priorities,
//!   deadlines, and a placement policy (round-robin, least-loaded via
//!   the shared [`CoreBoard`] depth gauges, or pinned);
//! * reliability — a core whose BISC residual is out of band is *fenced*
//!   (the scheduler stops placing jobs on it) and rejoins through the
//!   [`crate::coordinator::service::Job::Drain`] drain → recalibrate →
//!   rejoin lifecycle, the serving
//!   form of the paper's periodic BISC.
//!
//! The DNN tile scheduler side (tiles mapped across cores instead of
//! serialized on one array) lives in [`crate::coordinator::dnn`]; it
//! ships each core a pre-folded [`TileBank`] so tile MACs are served as
//! native `MacBatch` jobs.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::analog::faults::{FaultMap, FaultPlan};
use crate::analog::variation::VariationSample;
use crate::analog::{consts as c, CimAnalogModel, Folded, MacScratch};
use crate::config::SimConfig;
use crate::coordinator::batcher::{
    merge_model_stats, Batcher, BatcherStats, MacBackend, ModelStats, ServeError,
};
use crate::coordinator::bisc::{
    permanent_fault_mask, residual_from_fits, AdcCharacterization, BiscEngine, BiscReport, LineFit,
};
use crate::coordinator::dnn::ColumnPlan;
use crate::coordinator::service::{
    CoreBoard, CoreContext, JobEnvelope, Residency, TileRef, DEFAULT_HEALTH_BAND,
};
use crate::util::rng::SplitMix64;
use crate::util::sync::lock_unpoisoned;
use std::sync::atomic::AtomicUsize;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Derive the die seed of core `core` from the cluster's base seed.
/// Core 0 keeps the base seed so a K=1 cluster reproduces the single-array
/// experiments bit-for-bit; the rest are SplitMix64-mixed.
pub fn core_seed(base: u64, core: usize) -> u64 {
    if core == 0 {
        base
    } else {
        let mut sm = SplitMix64::new(base ^ (core as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        sm.next_u64()
    }
}

/// Pre-folded tile schedule installed on one core: the serving-side data
/// a [`crate::coordinator::service::Job::MacBatch`] with a [`TileRef`]
/// runs against. Keeps the raw
/// signed-code tiles plus each layer's ADC window so the bank can be
/// re-folded after a recalibration changes the die's trims.
pub struct TileBank {
    layers: Vec<BankLayer>,
    /// variance-aware column placement ([`ColumnPlan`], DESIGN.md §16):
    /// when present, every tile is folded with its columns permuted so
    /// logical column `l` is served by physical column `plan.perm[l]`,
    /// and [`MacBackend::forward_tile_into`] un-permutes the outputs —
    /// callers always see logical column order.
    plan: Option<ColumnPlan>,
}

/// One bank layer spec: the layer's ADC window plus its row-major
/// `[tr][tc]` grid of N*M signed-code tiles. The grid is `Arc`-shared:
/// every core of a cluster folds the SAME immutable raw tiles, so the
/// per-core retained state is the folded coefficients only.
pub type BankLayerSpec = ((f64, f64), Arc<Vec<Vec<Vec<i32>>>>);

struct BankLayer {
    refs: (f64, f64),
    raw: Arc<Vec<Vec<Vec<i32>>>>,
    folded: Vec<Vec<Folded>>,
}

impl TileBank {
    /// Fold `layers` (see [`BankLayerSpec`]) on `model`. Leaves the
    /// model's ADC refs at the defaults; the array holds the last folded
    /// tile's weights.
    pub fn build(model: &mut CimAnalogModel, layers: Vec<BankLayerSpec>) -> Self {
        Self::build_planned(model, layers, None)
    }

    /// [`TileBank::build`] with an optional variance-aware [`ColumnPlan`]:
    /// tiles are folded column-permuted so high-importance logical columns
    /// land on the die's healthiest physical columns.
    pub fn build_planned(
        model: &mut CimAnalogModel,
        layers: Vec<BankLayerSpec>,
        plan: Option<ColumnPlan>,
    ) -> Self {
        let mut bank = Self {
            layers: layers
                .into_iter()
                .map(|(refs, raw)| BankLayer { refs, raw, folded: Vec::new() })
                .collect(),
            plan,
        };
        bank.refold(model);
        bank
    }

    /// The installed column placement plan, if any.
    pub fn plan(&self) -> Option<&ColumnPlan> {
        self.plan.as_ref()
    }

    /// Re-fold every tile under the model's CURRENT trims (required after
    /// recalibration — folded coefficients bake the trims in). The raw
    /// tiles stay in logical column order; a [`ColumnPlan`] is applied
    /// here, at fold time, so a wounded die's refold keeps the placement.
    pub fn refold(&mut self, model: &mut CimAnalogModel) {
        let plan = &self.plan;
        for layer in &mut self.layers {
            model.set_adc_refs(layer.refs.0, layer.refs.1);
            layer.folded = layer
                .raw
                .iter()
                .map(|row| {
                    row.iter()
                        .map(|t| match plan {
                            Some(p) => model.fold_tile(&p.permute_tile(t)),
                            None => model.fold_tile(t),
                        })
                        .collect()
                })
                .collect();
        }
        model.set_adc_refs(c::V_ADC_L, c::V_ADC_H);
    }

    fn get(&self, tile: &TileRef) -> Option<&Folded> {
        self.layers.get(tile.layer)?.folded.get(tile.tr)?.get(tile.tc)
    }
}

/// One physical array of the cluster: its own die, its own trims.
pub struct ClusterCore {
    pub id: usize,
    pub seed: u64,
    pub sample: VariationSample,
    pub model: CimAnalogModel,
    /// BISC outcome of the most recent calibration (cluster-parallel or
    /// in-service `Drain`), if any
    pub report: Option<BiscReport>,
    /// workload weights last programmed through the cluster API; restored
    /// after `Drain`/`Health` jobs (BISC characterization clobbers the
    /// array)
    pub weights: Option<Vec<i32>>,
    /// pre-folded DNN tile schedule served via
    /// [`crate::coordinator::service::Job::MacBatch`] +
    /// [`TileRef`] (installed by `CimMlp::prepare_cluster`)
    pub bank: Option<TileBank>,
    /// the die's monotonic recalibration clock: incremented by every
    /// `MacBackend::recalibrate` and NEVER reset, so epochs stay
    /// comparable across serve sessions and schedule generations.
    /// `CimCluster::serve_with` seeds the board's recal epochs from it,
    /// and `CimMlp::prepare_cluster` stamps each schedule's corrections
    /// with it — corrections are valid exactly while their stamp is at
    /// least the die's clock.
    pub recal_count: u64,
    /// worker-side refresher for the gather-side digital corrections
    /// (installed by `CimMlp::prepare_cluster` when the schedule
    /// carries trims/zero points): every in-service recalibration
    /// re-measures this core's corrections on the freshly trimmed die
    pub refresher: Option<crate::coordinator::dnn::TrimRefresher>,
    /// model residency recorded by registry deploys / rollouts /
    /// `prepare_cluster`; seeded onto the [`CoreBoard`] by `serve_with`
    /// so `Placement::Model` can resolve from the first request
    pub resident: Option<Residency>,
    /// scheduled hard-fault injections `(due_at_macs, map)` — welded into
    /// the die by the forward paths once `macs_done` reaches the due
    /// count ([`ClusterCore::schedule_faults`])
    pending_faults: Vec<(u64, FaultMap)>,
    /// MACs this core has served — the deterministic clock scheduled
    /// fault injections fire against
    pub macs_done: u64,
    /// per-line fits from the most recent characterization (captured by
    /// `recalibrate`), so the drain barrier's fault classifier
    /// ([`MacBackend::classify_faults`]) costs no extra reads
    last_fits: Option<Vec<(LineFit, LineFit)>>,
    /// reusable evaluation scratch for the tile fast path — steady-state
    /// tile serving runs without per-request heap allocation
    scratch: MacScratch,
    /// reusable scratch for un-permuting planned tile outputs back to
    /// logical column order
    unperm: Vec<u32>,
}

impl ClusterCore {
    /// Program workload weights, remembering them for post-lifecycle
    /// restoration.
    pub fn program(&mut self, weights: &[i32]) {
        self.model.program(weights);
        self.weights = Some(weights.to_vec());
    }

    pub fn install_bank(&mut self, bank: TileBank) {
        self.bank = Some(bank);
    }

    /// Restore the serving state (workload weights) after an operation
    /// that clobbered the array — lifecycle jobs and schedule preparation
    /// both program characterization/tile weights over the workload.
    pub(crate) fn restore_weights(&mut self) {
        if let Some(w) = &self.weights {
            self.model.program(w);
        }
    }

    /// Weld a fault map into the die NOW and re-derive every downstream
    /// serving artifact: folded tiles bake the (now wounded) column
    /// transfers in, so the bank is re-folded, and the workload weights
    /// are restored over the refold's tile programming. The welds
    /// themselves survive any future reprogram — silicon stays broken.
    pub fn apply_fault_map(&mut self, map: &FaultMap) {
        self.model.apply_faults(map);
        if let Some(mut bank) = self.bank.take() {
            bank.refold(&mut self.model);
            self.bank = Some(bank);
        }
        self.restore_weights();
    }

    /// Schedule this core's share of a fault plan: events with `at=0`
    /// strike immediately, the rest arm against the core's served-MAC
    /// clock (`at` MACs from now) and strike inside the forward paths —
    /// deterministic mid-run injection.
    pub fn schedule_faults(&mut self, plan: &FaultPlan) {
        for ev in plan.events_for(self.id) {
            if ev.map.is_empty() {
                continue;
            }
            if ev.at_macs == 0 {
                self.apply_fault_map(&ev.map);
            } else {
                self.pending_faults.push((self.macs_done + ev.at_macs, ev.map.clone()));
            }
        }
    }

    /// Fire every scheduled fault whose due MAC count has been reached.
    /// Called at the top of the forward paths; the fast-path cost when
    /// nothing is scheduled is one `is_empty` check.
    fn strike_due_faults(&mut self) {
        if self.pending_faults.is_empty() {
            return;
        }
        let now = self.macs_done;
        let mut due: Vec<FaultMap> = Vec::new();
        self.pending_faults.retain(|(at, map)| {
            if *at <= now {
                due.push(map.clone());
                false
            } else {
                true
            }
        });
        for map in &due {
            self.apply_fault_map(map);
        }
    }
}

/// The cluster core is the serving backend: MACs run on the programmed
/// array, tile batches on the installed [`TileBank`], and the lifecycle
/// jobs calibrate/characterize the die and then restore the serving state
/// (re-fold the bank, re-program the workload weights).
impl MacBackend for ClusterCore {
    fn forward_batch(&mut self, x: &[i32], batch: usize) -> Result<Vec<u32>, String> {
        let mut out = Vec::new();
        self.forward_batch_into(x, batch, &mut out)?;
        Ok(out)
    }

    fn forward_batch_into(
        &mut self,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        // scheduled hard faults strike at a deterministic point in the
        // served-MAC stream: requests admitted before the due count are
        // answered by healthy silicon, everything after by the wound
        self.strike_due_faults();
        // served traffic is the drift clock: every MAC read ages the die
        // (no-op on a frozen die, so the hot path stays free by default)
        self.model.advance_drift(batch as u64);
        self.model.forward_batch_into(x, batch, out);
        self.macs_done += batch as u64;
        Ok(())
    }

    fn forward_tile(
        &mut self,
        tile: &TileRef,
        x: &[i32],
        batch: usize,
    ) -> Result<Vec<u32>, String> {
        let mut out = Vec::new();
        self.forward_tile_into(tile, x, batch, &mut out)?;
        Ok(out)
    }

    fn forward_tile_into(
        &mut self,
        tile: &TileRef,
        x: &[i32],
        batch: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        self.strike_due_faults();
        // tile reads age the die too; the pre-folded tile itself bakes
        // the coefficients of the trims it was folded under, so a
        // drifted die serves increasingly stale tile math until the next
        // drain re-folds the bank — exactly the staleness the
        // calibrator daemon exists to bound
        self.model.advance_drift(batch as u64);
        let bank = self
            .bank
            .as_ref()
            // lint: allow(hot_path_alloc) — cold error path: allocates only when no bank is installed
            .ok_or_else(|| format!("core {} has no tile bank installed", self.id))?;
        let folded = bank.get(tile).ok_or_else(|| {
            // lint: allow(hot_path_alloc) — cold error path: allocates only for an out-of-bank tile
            format!(
                "core {}: tile (layer {}, {}, {}) outside the installed bank",
                self.id, tile.layer, tile.tr, tile.tc
            )
        })?;
        self.model.forward_folded_into(folded, x, batch, &mut self.scratch, out);
        if let Some(plan) = bank.plan() {
            // a planned bank serves logical column `l` on physical
            // column `perm[l]` — un-permute each row's outputs so the
            // physical placement is invisible to the gather side
            self.unperm.clear();
            self.unperm.extend_from_slice(out);
            out.clear();
            for r in 0..batch {
                let base = r * c::M_COLS;
                for &p in &plan.perm {
                    out.push(self.unperm.get(base + p).copied().unwrap_or(0));
                }
            }
        }
        self.macs_done += batch as u64;
        Ok(())
    }

    fn recalibrate(&mut self, engine: &BiscEngine) -> Option<f64> {
        self.report = Some(engine.calibrate(&mut self.model));
        // one post-calibration characterization feeds both the residual
        // and (kept in `last_fits`) the hard-fault classifier the drain
        // barrier runs next — classification costs no extra reads
        let fits = engine.characterize_only(&mut self.model);
        let residual = residual_from_fits(&fits);
        self.last_fits = Some(fits);
        // the trims changed: folded tiles bake trims in, so re-fold; the
        // gather-side digital corrections bake the OLD trims too, so the
        // refresher (when a schedule is installed) re-measures and
        // re-publishes them at the new epoch; then restore the workload
        // weights all that characterization clobbered
        if let Some(mut bank) = self.bank.take() {
            bank.refold(&mut self.model);
            self.bank = Some(bank);
        }
        self.recal_count += 1;
        if let Some(refresher) = &self.refresher {
            refresher.refresh(self.id, &mut self.model, self.recal_count);
        }
        self.restore_weights();
        Some(residual)
    }

    fn health_residual(&mut self, engine: &BiscEngine) -> Option<f64> {
        let fits = engine.characterize_only(&mut self.model);
        let residual = residual_from_fits(&fits);
        self.last_fits = Some(fits);
        self.restore_weights();
        Some(residual)
    }

    fn inject_faults(&mut self, plan: &str) -> Result<(), String> {
        let plan = FaultPlan::parse(plan)?;
        self.schedule_faults(&plan);
        Ok(())
    }

    fn classify_faults(&mut self, engine: &BiscEngine) -> Option<u32> {
        // classify on the fits the preceding recalibrate/health pass
        // already measured; re-characterize only if none are on hand
        let fits = match self.last_fits.take() {
            Some(fits) => fits,
            None => {
                let fits = engine.characterize_only(&mut self.model);
                self.restore_weights();
                fits
            }
        };
        let mask = permanent_fault_mask(&fits);
        self.last_fits = Some(fits);
        Some(mask)
    }

    fn program_model(&mut self, model: u32, weights: &[i32]) -> Result<(), String> {
        let want = c::N_ROWS * c::M_COLS;
        if weights.len() != want {
            // lint: allow(hot_path_alloc) — cold error path: rollouts are rare control jobs
            return Err(format!(
                "rollout weights: expected {want} codes, got {}",
                weights.len()
            ));
        }
        // the old model's folded tiles and trim refresher were measured
        // against the old weights — they do not apply to the new model.
        // The next prepare_cluster (or registry deploy) rebuilds them.
        self.bank = None;
        self.refresher = None;
        self.program(weights);
        self.resident = Some(Residency { model, tiles: Vec::new() });
        Ok(())
    }
}

/// K independent CIM cores behind one coordinator.
pub struct CimCluster {
    pub cores: Vec<ClusterCore>,
}

impl CimCluster {
    /// Draw `k` distinct dies from the config (per-core seeds derived via
    /// [`core_seed`]). Panics on `k == 0`.
    pub fn new(cfg: &SimConfig, k: usize) -> Self {
        assert!(k > 0, "a cluster needs at least one core");
        let cores = (0..k)
            .map(|id| {
                let mut core_cfg = cfg.clone();
                core_cfg.seed = core_seed(cfg.seed, id);
                let sample = VariationSample::draw(&core_cfg);
                let model = CimAnalogModel::from_sample(&core_cfg, &sample);
                ClusterCore {
                    id,
                    seed: core_cfg.seed,
                    sample,
                    model,
                    report: None,
                    weights: None,
                    bank: None,
                    recal_count: 0,
                    refresher: None,
                    resident: None,
                    pending_faults: Vec::new(),
                    macs_done: 0,
                    last_fits: None,
                    scratch: MacScratch::new(),
                    unperm: Vec::new(),
                }
            })
            .collect();
        Self { cores }
    }

    pub fn len(&self) -> usize {
        self.cores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cores.is_empty()
    }

    /// Schedule a fault plan's events on every core (each core takes the
    /// events targeting its own id) — the `serve --faults` /
    /// `acore-cim faults` injection entry point at the cluster level.
    pub fn schedule_faults(&mut self, plan: &FaultPlan) {
        for core in &mut self.cores {
            core.schedule_faults(plan);
        }
    }

    /// Parse and schedule the config's `faults.plan` spec, if any. A
    /// malformed spec or an event targeting a core this cluster does not
    /// have is an error — callers surface it instead of silently serving
    /// a different chaos drill than the one asked for.
    pub fn schedule_config_faults(&mut self, cfg: &SimConfig) -> Result<(), String> {
        let Some(spec) = &cfg.faults else {
            return Ok(());
        };
        let plan = FaultPlan::parse(spec)?;
        if let Some(max) = plan.max_core() {
            if max >= self.cores.len() {
                return Err(format!(
                    "fault plan targets core {max} but the cluster has {} cores",
                    self.cores.len()
                ));
            }
        }
        self.schedule_faults(&plan);
        Ok(())
    }

    /// Program one core (per-core weights: model sharding, A/B testing).
    /// An out-of-range index is a typed error, not a silent no-op.
    pub fn program_core(&mut self, core: usize, weights: &[i32]) -> Result<(), ServeError> {
        let k = self.cores.len();
        match self.cores.get_mut(core) {
            Some(c) => {
                c.program(weights);
                Ok(())
            }
            None => Err(ServeError::Backend(format!(
                "core {core} out of range (cluster has {k} cores)"
            ))),
        }
    }

    /// Record `core`'s model residency (registry deploys); picked up by
    /// [`CimCluster::serve_with`] when serving starts. Out of range is a
    /// no-op — deploys validate the index through `program_core` first.
    pub fn set_resident(&mut self, core: usize, model: u32) {
        if let Some(c) = self.cores.get_mut(core) {
            c.resident = Some(Residency { model, tiles: Vec::new() });
        }
    }

    /// Run `f` once per core, all cores in parallel on scoped threads —
    /// the shared scaffold under every per-core cluster operation.
    pub fn for_each_core_parallel<F>(&mut self, f: F)
    where
        F: Fn(&mut ClusterCore) + Sync,
    {
        std::thread::scope(|s| {
            let f = &f;
            let handles: Vec<_> = self
                .cores
                .iter_mut()
                .map(|core| s.spawn(move || f(core)))
                .collect();
            for h in handles {
                // a panicked per-core worker re-raises on the caller's
                // thread instead of being swallowed (or double-panicking)
                if let Err(payload) = h.join() {
                    std::panic::resume_unwind(payload);
                }
            }
        });
    }

    /// Run the full per-column BISC routine on every core IN PARALLEL
    /// (one scoped thread per core). Each core keeps its own trims and
    /// its own report; total wall time is one core's calibration, not K.
    pub fn calibrate_parallel(&mut self, engine: &BiscEngine) {
        self.for_each_core_parallel(|core| {
            core.report = Some(engine.calibrate(&mut core.model));
        });
    }

    /// Iterative variant (`passes` >= 1), still one thread per core.
    pub fn calibrate_parallel_iterative(&mut self, engine: &BiscEngine, passes: usize) {
        self.for_each_core_parallel(|core| {
            core.report = Some(engine.calibrate_iterative(&mut core.model, passes));
        });
    }

    /// Cascaded workload calibration (full-range pass + operating-point
    /// refine, see [`BiscEngine::calibrate_for_workload`]) on every core
    /// in parallel.
    pub fn calibrate_for_workload_parallel(
        &mut self,
        cfg: &SimConfig,
        adc_char: AdcCharacterization,
        op_half_v: f64,
    ) {
        self.for_each_core_parallel(|core| {
            core.report = Some(BiscEngine::calibrate_for_workload(
                cfg,
                adc_char,
                &mut core.model,
                op_half_v,
            ));
        });
    }

    /// Total characterization reads issued by the last calibration.
    pub fn total_calibration_reads(&self) -> u64 {
        self.cores
            .iter()
            .filter_map(|c| c.report.as_ref().map(|r| r.reads))
            .sum()
    }

    /// Convert the cluster into a serving worker pool with the default
    /// service configuration (no lifecycle engine — `Drain`/`Health`
    /// degrade to state reports). See [`CimCluster::serve_with`].
    pub fn serve(self, batcher: Batcher) -> ClusterServer {
        self.serve_with(ServiceConfig { batcher, ..ServiceConfig::default() })
    }

    /// Convert the cluster into a serving worker pool: one batcher loop
    /// per core, all sharing one [`CoreBoard`] (depth gauges + fences).
    /// The cores move into their worker threads and come back through
    /// [`ClusterServer::join`].
    pub fn serve_with(self, svc: ServiceConfig) -> ClusterServer {
        let board = Arc::new(CoreBoard::new(self.cores.len()));
        let mut txs = Vec::with_capacity(self.cores.len());
        let mut handles = Vec::with_capacity(self.cores.len());
        let mut live = Vec::with_capacity(self.cores.len());
        let mut live_models = Vec::with_capacity(self.cores.len());
        for mut core in self.cores {
            let (tx, rx) = channel::<JobEnvelope>();
            // the board's epoch continues the die's own recalibration
            // clock, so correction stamps measured before this serve
            // session stay comparable (a schedule from an earlier
            // generation can neither pass as fresh after a new drain nor
            // be refused while still matching the die's trims)
            board.set_recal_epoch(core.id, core.recal_count);
            // ...and the board's residency continues the core's: a
            // registry deploy (or prepare_cluster) before serving makes
            // Placement::Model resolvable from the first request
            if let Some(res) = &core.resident {
                board.set_residency(core.id, res.model, res.tiles.clone());
            }
            let slot = Arc::new(Mutex::new(BatcherStats::default()));
            let model_slot = Arc::new(Mutex::new(Vec::new()));
            let ctx = CoreContext {
                core: core.id,
                board: Arc::clone(&board),
                engine: svc.engine.clone(),
                health_band: svc.health_band,
                live: Arc::clone(&slot),
                live_models: Arc::clone(&model_slot),
            };
            let batcher = svc.batcher;
            handles.push(std::thread::spawn(move || {
                let stats = batcher.run(rx, &mut core, &ctx);
                (core, stats)
            }));
            txs.push(tx);
            live.push(slot);
            live_models.push(model_slot);
        }
        ClusterServer {
            txs,
            handles,
            board,
            rr: Arc::new(AtomicUsize::new(0)),
            live,
            live_models,
        }
    }
}

/// How a cluster serves: the per-core batcher shape plus the lifecycle
/// configuration (`Drain`/`Health` need a calibration engine and a
/// residual band to act on).
#[derive(Clone)]
pub struct ServiceConfig {
    pub batcher: Batcher,
    /// Engine used by in-service `Drain` recalibration and `Health`
    /// characterization; `None` turns both into state reports.
    pub engine: Option<BiscEngine>,
    /// Fence a core when its mean per-line |g_tot - 1| exceeds this.
    pub health_band: f64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { batcher: Batcher::default(), engine: None, health_band: DEFAULT_HEALTH_BAND }
    }
}

/// The running worker pool: K batcher threads, one per core.
pub struct ClusterServer {
    txs: Vec<Sender<JobEnvelope>>,
    handles: Vec<JoinHandle<(ClusterCore, BatcherStats)>>,
    board: Arc<CoreBoard>,
    rr: Arc<AtomicUsize>,
    live: Vec<Arc<Mutex<BatcherStats>>>,
    live_models: Vec<Arc<Mutex<Vec<ModelStats>>>>,
}

impl ClusterServer {
    pub fn cores(&self) -> usize {
        self.txs.len()
    }

    /// Shared scheduler state (in-flight depth gauges, fences).
    pub fn board(&self) -> &Arc<CoreBoard> {
        &self.board
    }

    /// Handles on the per-core live statistics snapshots (each worker
    /// republishes its [`BatcherStats`] every dispatch round) — what the
    /// wire front-end's `Stats` frames read without joining the workers.
    pub fn live_handles(&self) -> Vec<Arc<Mutex<BatcherStats>>> {
        self.live.clone()
    }

    /// Current per-core statistics snapshot.
    pub fn live_stats(&self) -> Vec<BatcherStats> {
        self.live.iter().map(|s| *lock_unpoisoned(s)).collect()
    }

    /// Handles on the per-core live per-model counters (each worker
    /// republishes its [`ModelStats`] every dispatch round) — the wire
    /// front-end's `ModelStats` frames read them without joining.
    pub fn model_stats_handles(&self) -> Vec<Arc<Mutex<Vec<ModelStats>>>> {
        self.live_models.clone()
    }

    /// Cluster-wide per-model counters: every core's live snapshot
    /// merged by model id.
    pub fn live_model_stats(&self) -> Vec<ModelStats> {
        let mut out: Vec<ModelStats> = Vec::new();
        for slot in &self.live_models {
            let per_core = lock_unpoisoned(slot).clone();
            merge_model_stats(&mut out, &per_core);
        }
        out
    }

    /// A cloneable service handle over all cores (every client from this
    /// server shares the same round-robin cursor and board).
    pub fn client(&self) -> ClusterClient {
        ClusterClient::with_cursor(
            self.txs.clone(),
            Arc::clone(&self.board),
            Arc::clone(&self.rr),
        )
    }

    /// Shut down: drop this server's senders and wait for the workers.
    /// Outstanding `ClusterClient`s keep their own senders — drop them
    /// first or the workers keep serving. Returns the cluster (cores with
    /// their final state) and per-core run statistics.
    pub fn join(self) -> (CimCluster, Vec<BatcherStats>) {
        drop(self.txs);
        let mut cores = Vec::with_capacity(self.handles.len());
        let mut stats = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            match h.join() {
                Ok((core, st)) => {
                    cores.push(core);
                    stats.push(st);
                }
                // re-raise a worker panic on the joining thread
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        cores.sort_by_key(|c| c.id);
        (CimCluster { cores }, stats)
    }
}

/// Cloneable service handle over the cluster's request channels — the
/// shared [`crate::coordinator::service::ServiceClient`] over K worker
/// channels. All clones (and all clients from one server) cooperate
/// through the shared round-robin cursor and
/// [`crate::coordinator::service::CoreBoard`].
pub use crate::coordinator::service::ServiceClient as ClusterClient;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::{CimService, Job, SubmitOpts, Ticket};

    fn ideal_cfg() -> SimConfig {
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        cfg
    }

    #[test]
    fn core_seeds_are_distinct_and_stable() {
        let base = 0xAC0_CE11;
        assert_eq!(core_seed(base, 0), base);
        let seeds: Vec<u64> = (0..8).map(|k| core_seed(base, k)).collect();
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "cores {i}/{j} share a seed");
            }
        }
        assert_eq!(seeds, (0..8).map(|k| core_seed(base, k)).collect::<Vec<_>>());
    }

    #[test]
    fn cluster_cores_are_distinct_dies() {
        let cfg = SimConfig::default();
        let cluster = CimCluster::new(&cfg, 3);
        assert_eq!(cluster.len(), 3);
        assert_ne!(cluster.cores[0].sample.alpha_p, cluster.cores[1].sample.alpha_p);
        assert_ne!(cluster.cores[1].sample.alpha_p, cluster.cores[2].sample.alpha_p);
        // core 0 reproduces the single-array experiment
        let single = VariationSample::draw(&cfg);
        assert_eq!(cluster.cores[0].sample.alpha_p, single.alpha_p);
    }

    #[test]
    fn parallel_calibration_trims_every_core() {
        let cfg = SimConfig::default();
        let mut cluster = CimCluster::new(&cfg, 3);
        let engine = BiscEngine::from_config(&cfg, crate::coordinator::bisc::AdcCharacterization::ideal());
        cluster.calibrate_parallel(&engine);
        for core in &cluster.cores {
            let report = core.report.as_ref().expect("core not calibrated");
            assert_eq!(report.columns.len(), c::M_COLS);
        }
        assert_eq!(cluster.total_calibration_reads(), 3 * 2048);
        // different dies => different trims (overwhelmingly likely)
        let trims = |k: usize| {
            cluster.cores[k]
                .report
                .as_ref()
                .unwrap()
                .columns
                .iter()
                .map(|cc| cc.pot_p)
                .collect::<Vec<_>>()
        };
        assert_ne!(trims(0), trims(1));
    }

    #[test]
    fn serve_round_robin_answers_everything() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 4);
        crate::coordinator::registry::deploy_uniform(
            &mut cluster,
            "demo",
            vec![40; c::N_ROWS * c::M_COLS],
        )
        .unwrap();
        let server = cluster.serve(Batcher::default());
        let client = server.client();
        // ideal dies, same weights: every core returns the same answer
        let mut reference = CimAnalogModel::ideal();
        reference.program(&vec![40; c::N_ROWS * c::M_COLS]);
        let expect = reference.forward_batch(&vec![30; c::N_ROWS], 1);
        let n = 64;
        let tickets: Vec<Ticket<Vec<u32>>> = (0..n)
            .map(|_| {
                client
                    .submit(Job::Mac(vec![30; c::N_ROWS]), SubmitOpts::default())
                    .unwrap()
                    .typed()
            })
            .collect();
        for t in tickets {
            assert_eq!(t.wait().unwrap(), expect);
        }
        drop(client);
        let (_cluster, stats) = server.join();
        let total: u64 = stats.iter().map(|s| s.requests).sum();
        assert_eq!(total, n as u64);
        // round robin spreads the load over every core
        for (k, s) in stats.iter().enumerate() {
            assert!(s.requests > 0, "core {k} served nothing");
        }
    }

    #[test]
    fn program_core_is_typed_and_per_core() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 2);
        // out-of-range program_core is a typed error, not a no-op
        assert!(cluster.program_core(9, &vec![1; c::N_ROWS * c::M_COLS]).is_err());
        cluster.program_core(1, &vec![30; c::N_ROWS * c::M_COLS]).unwrap();
        assert_eq!(cluster.cores[1].weights.as_ref().map(|w| w[0]), Some(30));
        // the untouched core keeps no weights (per-core, not broadcast)
        assert!(cluster.cores[0].weights.is_none());
    }

    #[test]
    fn fault_plan_strikes_immediately_and_at_mac_count() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 2);
        let weights = vec![40; c::N_ROWS * c::M_COLS];
        cluster.program_core(0, &weights).unwrap();
        cluster.program_core(1, &weights).unwrap();

        let mut reference = CimAnalogModel::ideal();
        reference.program(&weights);
        let x = vec![30; c::N_ROWS];
        let healthy = reference.forward_batch(&x, 1);

        // one immediate dead column, one SA rail armed 4 MACs out
        let plan = FaultPlan::parse("core=0,col=3;core=0,at=4,sa=5:0.0").unwrap();
        cluster.schedule_faults(&plan);

        let q = cluster.cores[0].forward_batch(&x, 1).unwrap();
        assert_ne!(q[3], healthy[3], "dead column should strike immediately");
        assert_eq!(q[5], healthy[5], "scheduled fault must not strike early");
        for _ in 0..3 {
            cluster.cores[0].forward_batch(&x, 1).unwrap();
        }
        // macs_done reached the due count: the next forward strikes first
        let q = cluster.cores[0].forward_batch(&x, 1).unwrap();
        assert_ne!(q[5], healthy[5], "armed fault should strike at its MAC count");
        assert_ne!(q[3], healthy[3], "welds are permanent");
        // the other core's silicon is untouched
        assert_eq!(cluster.cores[1].forward_batch(&x, 1).unwrap(), healthy);
    }

    #[test]
    fn recalibration_classifies_permanent_faults() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 1);
        cluster.program_core(0, &vec![40; c::N_ROWS * c::M_COLS]).unwrap();
        let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
        let core = &mut cluster.cores[0];

        // healthy die: recal leaves no permanent mask
        let r0 = core.recalibrate(&engine).unwrap();
        assert!(r0 < 0.05, "ideal die residual {r0}");
        assert_eq!(core.classify_faults(&engine), Some(0));

        // weld a column dead via the MacBackend injection hook, then
        // recalibrate: the residual floor persists and the classifier
        // pins it on exactly the dead column
        core.inject_faults("core=0,col=7").unwrap();
        let r1 = core.recalibrate(&engine).unwrap();
        assert!(r1 > r0, "a dead column must raise the post-recal residual");
        assert_eq!(core.classify_faults(&engine), Some(1 << 7));
        // a malformed plan is a typed error
        assert!(core.inject_faults("col=99").is_err());
    }

    #[test]
    fn config_fault_plans_are_validated_against_the_cluster() {
        let mut cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 2);
        cfg.faults = Some("core=1,col=0".into());
        assert!(cluster.schedule_config_faults(&cfg).is_ok());
        cfg.faults = Some("core=5,col=0".into());
        let err = cluster.schedule_config_faults(&cfg).unwrap_err();
        assert!(err.contains("core 5"), "unexpected error: {err}");
        cfg.faults = Some("col=banana".into());
        assert!(cluster.schedule_config_faults(&cfg).is_err());
        cfg.faults = None;
        assert!(cluster.schedule_config_faults(&cfg).is_ok());
    }

    #[test]
    fn planned_bank_unpermutes_outputs_to_logical_order() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 1);
        let mut weights = vec![0i32; c::N_ROWS * c::M_COLS];
        for r in 0..c::N_ROWS {
            for col in 0..c::M_COLS {
                // distinct per-column weights so a permutation shows
                weights[r * c::M_COLS + col] = col as i32;
            }
        }
        let x = vec![12; c::N_ROWS];
        let mut reference = CimAnalogModel::ideal();
        let folded = reference.fold_tile(&weights);
        let expect = reference.forward_folded(&folded, &x, 1);

        // a column-reversing plan: logical l served by physical M-1-l
        let plan = ColumnPlan::from_perm((0..c::M_COLS).rev().collect());
        let core = &mut cluster.cores[0];
        let bank = TileBank::build_planned(
            &mut core.model,
            vec![((c::V_ADC_L, c::V_ADC_H), Arc::new(vec![vec![weights.clone()]]))],
            Some(plan),
        );
        assert!(bank.plan().is_some());
        core.install_bank(bank);
        let tile = TileRef { layer: 0, tr: 0, tc: 0 };
        // on an ideal die the physical placement is invisible: the
        // un-permuted outputs match the unplanned reference exactly
        let q = core.forward_tile(&tile, &x, 1).unwrap();
        assert_eq!(q, expect);
        // batch of 2 rows un-permutes per row
        let x2: Vec<i32> = x.iter().chain(x.iter()).copied().collect();
        let q2 = core.forward_tile(&tile, &x2, 2).unwrap();
        assert_eq!(&q2[..c::M_COLS], &expect[..]);
        assert_eq!(&q2[c::M_COLS..], &expect[..]);
    }

    #[test]
    fn tile_bank_serves_folded_tiles_and_survives_recalibration() {
        let cfg = ideal_cfg();
        let mut cluster = CimCluster::new(&cfg, 1);
        let weights = vec![17; c::N_ROWS * c::M_COLS];
        // expected: the folded-tile evaluation on an identical ideal die
        let mut reference = CimAnalogModel::ideal();
        let folded = reference.fold_tile(&weights);
        let x = vec![12; c::N_ROWS];
        let expect = reference.forward_folded(&folded, &x, 1);

        let core = &mut cluster.cores[0];
        let bank = TileBank::build(
            &mut core.model,
            vec![((c::V_ADC_L, c::V_ADC_H), Arc::new(vec![vec![weights.clone()]]))],
        );
        core.install_bank(bank);
        core.program(&vec![40; c::N_ROWS * c::M_COLS]);

        let tile = TileRef { layer: 0, tr: 0, tc: 0 };
        let q = core.forward_tile(&tile, &x, 1).unwrap();
        assert_eq!(q, expect);
        // an out-of-range tile is an error, not a panic
        assert!(core
            .forward_tile(&TileRef { layer: 0, tr: 1, tc: 0 }, &x, 1)
            .is_err());

        // recalibration re-folds the bank and restores workload weights
        let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
        let residual = core.recalibrate(&engine).expect("cluster cores recalibrate");
        assert!(residual < 0.05, "ideal die residual {residual}");
        let q2 = core.forward_tile(&tile, &x, 1).unwrap();
        assert_eq!(q2.len(), c::M_COLS);
        // workload weights restored: a plain MAC matches a fresh model
        // programmed with the same workload weights and trims
        let q_mac = core.forward_batch(&x, 1).unwrap();
        let mut check = CimAnalogModel::ideal();
        engine.calibrate(&mut check);
        check.program(&vec![40; c::N_ROWS * c::M_COLS]);
        assert_eq!(q_mac, check.forward_batch(&x, 1));
    }
}
