//! Compute-SNR evaluation — paper Section VII-B, Eq. (15).
//!
//! SNR_c = sigma^2(Q_nom) / sigma^2(e), e = Q_nom - Q_act, per column.
//! We interpret sigma_e^2 as *error power* E[e^2] (not the mean-removed
//! variance): a constant per-column offset error is precisely what Fig. 8
//! shows degrading the outputs and what BISC removes, so it must count
//! against the SNR. For calibrated columns the error is ~zero-mean and the
//! two definitions coincide.

use crate::analog::{consts as c, CimAnalogModel};
use crate::util::rng::Rng;
use crate::util::stats;

/// The MAC workload used for SNR evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnrWorkload {
    /// Stepped common-mode inputs with full-scale weights — exercises the
    /// full dynamic range (the characterization-style sweep).
    Ramp,
    /// Random dense signed weights and random per-row inputs.
    Random,
}

#[derive(Debug, Clone)]
pub struct SnrResult {
    /// per-column SNR [dB]
    pub snr_db: Vec<f64>,
    /// per-column ENOB [bits]
    pub enob: Vec<f64>,
}

impl SnrResult {
    pub fn mean_snr_db(&self) -> f64 {
        stats::mean(&self.snr_db)
    }

    pub fn mean_enob(&self) -> f64 {
        stats::mean(&self.enob)
    }

    pub fn min_snr_db(&self) -> f64 {
        stats::min(&self.snr_db)
    }

    pub fn max_snr_db(&self) -> f64 {
        stats::max(&self.snr_db)
    }
}

/// Build the (inputs, weights) sample set for a workload.
pub fn workload_samples(
    workload: SnrWorkload,
    samples: usize,
    seed: u64,
) -> (Vec<Vec<i32>>, Vec<i32>) {
    let mut rng = Rng::new(seed ^ 0x5A8_10AD);
    match workload {
        SnrWorkload::Ramp => {
            let weights = vec![c::CODE_MAX; c::N_ROWS * c::M_COLS];
            let xs = (0..samples)
                .map(|i| {
                    let t = i as f64 / (samples - 1).max(1) as f64;
                    let code = ((t * 2.0 - 1.0) * c::CODE_MAX as f64).round() as i32;
                    vec![code; c::N_ROWS]
                })
                .collect();
            (xs, weights)
        }
        SnrWorkload::Random => {
            let weights: Vec<i32> = (0..c::N_ROWS * c::M_COLS)
                .map(|_| rng.int_in(-63, 63) as i32)
                .collect();
            // common-mode component + per-row perturbation: keeps the MAC
            // amplitude representative of DNN activations while exercising
            // the full ADC range
            let xs = (0..samples)
                .map(|_| {
                    let cm = rng.int_in(-50, 50) as i32;
                    (0..c::N_ROWS)
                        .map(|_| (cm + rng.int_in(-13, 13) as i32).clamp(-63, 63))
                        .collect()
                })
                .collect();
            (xs, weights)
        }
    }
}

/// Measure per-column compute SNR on a model with its current trims.
/// Programs `weights` from the workload; the model's weights are clobbered.
pub fn measure_snr(
    model: &mut CimAnalogModel,
    workload: SnrWorkload,
    samples: usize,
    seed: u64,
) -> SnrResult {
    let (xs, weights) = workload_samples(workload, samples, seed);
    model.program(&weights);
    let mut nominal: Vec<Vec<f64>> = vec![Vec::with_capacity(samples); c::M_COLS];
    let mut actual: Vec<Vec<f64>> = vec![Vec::with_capacity(samples); c::M_COLS];
    for x in &xs {
        let q_nom = CimAnalogModel::q_nominal(x, &weights, 1);
        let q_act = model.forward_golden(x);
        for col in 0..c::M_COLS {
            nominal[col].push(q_nom[col]);
            actual[col].push(q_act[col] as f64);
        }
    }
    let snr_db: Vec<f64> = (0..c::M_COLS)
        .map(|col| {
            let e: Vec<f64> = nominal[col]
                .iter()
                .zip(&actual[col])
                .map(|(n, a)| n - a)
                .collect();
            let err_power = e.iter().map(|v| v * v).sum::<f64>() / e.len() as f64;
            if err_power == 0.0 {
                return f64::INFINITY;
            }
            stats::db10(stats::variance(&nominal[col]) / err_power)
        })
        .collect();
    let enob = snr_db.iter().map(|&s| stats::enob(s)).collect();
    SnrResult { snr_db, enob }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::variation::VariationSample;
    use crate::config::SimConfig;
    use crate::coordinator::bisc::{AdcCharacterization, BiscEngine};

    #[test]
    fn ideal_die_has_high_snr() {
        let mut m = CimAnalogModel::ideal();
        let r = measure_snr(&mut m, SnrWorkload::Ramp, 64, 1);
        // quantization-only: ~6.02*6+1.76 minus loading ~ > 30 dB for the
        // ramp workload amplitude
        assert!(r.mean_snr_db() > 28.0, "snr={}", r.mean_snr_db());
    }

    #[test]
    fn bisc_boosts_snr_into_paper_band() {
        let cfg = SimConfig::default();
        let s = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &s);
        let before = measure_snr(&mut m, SnrWorkload::Ramp, 64, 2);
        let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
        engine.calibrate(&mut m);
        let after = measure_snr(&mut m, SnrWorkload::Ramp, 64, 2);
        let boost = after.mean_snr_db() - before.mean_snr_db();
        // paper: 6-8 dB boost into 18-24 dB; wide tolerance here, the
        // bench reproduces the exact figure
        assert!(boost > 2.0, "boost={boost}");
        assert!(after.mean_snr_db() > before.mean_snr_db());
        assert!(
            after.mean_snr_db() > 14.0 && after.mean_snr_db() < 32.0,
            "after={}",
            after.mean_snr_db()
        );
    }

    #[test]
    fn enob_consistent_with_snr() {
        let mut m = CimAnalogModel::ideal();
        let r = measure_snr(&mut m, SnrWorkload::Ramp, 32, 3);
        for (s, e) in r.snr_db.iter().zip(&r.enob) {
            assert!((e - (s - 1.76) / 6.02).abs() < 1e-9);
        }
    }

    #[test]
    fn random_workload_runs() {
        let cfg = SimConfig::default();
        let s = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &s);
        let r = measure_snr(&mut m, SnrWorkload::Random, 128, 4);
        assert_eq!(r.snr_db.len(), c::M_COLS);
        assert!(r.snr_db.iter().all(|s| s.is_finite()));
    }
}
