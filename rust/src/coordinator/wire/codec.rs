//! Versioned, length-prefixed binary codec for the serving wire protocol
//! (DESIGN.md §9). Hand-rolled little-endian encode/decode — serde is not
//! vendored, and the frame set is small enough that an explicit codec is
//! both faster and easier to audit than a generic one.
//!
//! Every frame is `header (16 bytes) + body`:
//!
//! | offset | size | field                                   |
//! |--------|------|-----------------------------------------|
//! | 0      | 2    | magic `0xAC1E` (LE)                     |
//! | 2      | 1    | protocol version (`WIRE_VERSION`)       |
//! | 3      | 1    | frame tag                               |
//! | 4      | 8    | request id (LE; 0 for `Hello`)          |
//! | 12     | 4    | body length in bytes (LE, `<= MAX_BODY`)|
//!
//! Decoding is total: malformed input of any shape — truncated frames,
//! oversized length prefixes (outer or nested), unknown tags, wrong
//! versions, non-UTF-8 strings, trailing bytes — surfaces as a typed
//! [`WireError`], never a panic and never an allocation proportional to
//! an attacker-chosen length prefix.

use crate::coordinator::batcher::{BatcherStats, ModelStats, ServeError};
use crate::coordinator::calibrator::CoreCalStats;
use crate::coordinator::service::{CoreHealth, Job, JobReply, Placement, SubmitOpts, TileRef};
use std::io::{Read, Write};
use std::time::Duration;

/// First two bytes of every frame.
pub const WIRE_MAGIC: u16 = 0xAC1E;
/// Protocol version this build speaks. Decoders reject every other value
/// ([`WireError::BadVersion`]): the protocol is versioned as a whole, not
/// per frame — see DESIGN.md §9 for the compatibility rules.
/// Version history: 1 = initial frame set; 2 = `CoreHealth` carries the
/// server-observed recalibration epoch + the `CalStats` frame pair;
/// 3 = multi-model serving — `Hello` ships model names + per-core
/// residency, jobs/placements/health/calstats carry model ids, the
/// `Rollout` job kind and the `ModelStats` frame pair exist;
/// 4 = event-driven front-end — `Hello` carries the initial credit
/// window, `Credit` grants replace the write timeout (wire-level flow
/// control), `Subscribe` + the `FencePush`/`RecalEpochPush`/
/// `ResidencyPush`/`CalStatsPush` server-initiated frames push control-
/// plane deltas, and `ServeError::Overloaded` is the typed admission-
/// control answer;
/// 5 = degraded-mode serving — the `Faults` job kind injects a hard-
/// fault plan mid-run, `CoreHealth` carries the permanent-retirement
/// flag + per-column fault mask, `CoreCalStats` mirrors the retired
/// flag, and the `RetirePush` server-initiated frame announces a core
/// leaving service for good.
pub const WIRE_VERSION: u8 = 5;
/// Frame body cap: a length prefix beyond this is rejected before any
/// allocation ([`WireError::Oversized`]).
pub const MAX_BODY: u32 = 1 << 26;
/// Fixed frame header size in bytes.
pub const HEADER_LEN: usize = 16;

const TAG_HELLO: u8 = 1;
const TAG_SUBMIT: u8 = 2;
const TAG_REPLY: u8 = 3;
const TAG_STATS_REQ: u8 = 4;
const TAG_STATS_REPLY: u8 = 5;
const TAG_CALSTATS_REQ: u8 = 6;
const TAG_CALSTATS_REPLY: u8 = 7;
const TAG_MODELSTATS_REQ: u8 = 8;
const TAG_MODELSTATS_REPLY: u8 = 9;
const TAG_SUBSCRIBE: u8 = 10;
const TAG_CREDIT: u8 = 11;
const TAG_FENCE_PUSH: u8 = 12;
const TAG_RECAL_EPOCH_PUSH: u8 = 13;
const TAG_RESIDENCY_PUSH: u8 = 14;
const TAG_CALSTATS_PUSH: u8 = 15;
const TAG_RETIRE_PUSH: u8 = 16;

/// Decode-side failures. `Closed` is the one non-error: a connection that
/// ends exactly on a frame boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The peer closed the connection at a frame boundary (clean EOF).
    Closed,
    /// The stream ended inside a frame, or a nested length prefix claims
    /// more bytes than the frame body holds.
    Truncated,
    /// The first two bytes were not [`WIRE_MAGIC`].
    BadMagic(u16),
    /// The peer speaks a protocol version this build does not.
    BadVersion(u8),
    /// The frame tag is not one this protocol version defines.
    UnknownTag(u8),
    /// The body length prefix exceeds [`MAX_BODY`].
    Oversized { len: u32, max: u32 },
    /// The body bytes do not decode as the tagged frame.
    BadPayload(String),
    /// The underlying transport failed mid-frame.
    Io(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed at a frame boundary"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:#06x}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {WIRE_VERSION})")
            }
            WireError::UnknownTag(t) => write!(f, "unknown frame tag {t:#04x}"),
            WireError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte cap")
            }
            WireError::BadPayload(msg) => write!(f, "malformed frame payload: {msg}"),
            WireError::Io(msg) => write!(f, "wire I/O failed: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One decoded protocol frame. `Hello` opens every connection (server →
/// client) with the core count, the initial credit window, the
/// registry's model names (index == model id) and every core's current
/// residency, so a remote client can resolve `Placement::Model` at the
/// edge; `Submit` carries a job + options under a client-chosen request
/// id; `Reply` echoes that id with the serving core and the job's
/// result; `StatsReq`/`StatsReply` fetch the per-core live
/// [`BatcherStats`] snapshots; `CalStatsReq`/`CalStatsReply` fetch the
/// calibrator daemon's per-core [`CoreCalStats`] (empty when the server
/// runs without `--auto-calibrate`); `ModelStatsReq`/`ModelStatsReply`
/// fetch the cluster-merged per-model [`ModelStats`].
///
/// Wire v4 adds flow control and a server-initiated control plane:
/// `Credit` returns submit window slots as replies flush (the client
/// must not have more than `window` unanswered `Submit`s in flight);
/// `Subscribe` opts a connection into the push frames, and `FencePush`/
/// `RecalEpochPush`/`ResidencyPush`/`CalStatsPush` stream fence, epoch,
/// residency, and calibrator deltas to subscribers without the client
/// asking (DESIGN.md §15).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Hello {
        cores: u32,
        /// Initial credit window: the maximum number of unanswered
        /// `Submit` frames the client may have in flight. Replenished by
        /// `Credit` grants as replies flush.
        window: u32,
        /// Registered model names, in id order (empty on registry-less
        /// servers).
        models: Vec<String>,
        /// Per-core residency: `None` = nothing resident, `Some((model,
        /// tiles))` = the resident model id and its named tiles. Length
        /// always equals `cores` when emitted by this build's server.
        residency: Vec<Option<(u32, Vec<TileRef>)>>,
    },
    Submit { id: u64, job: Job, opts: SubmitOpts },
    Reply { id: u64, core: u32, result: Result<JobReply, ServeError> },
    StatsReq { id: u64 },
    StatsReply { id: u64, stats: Vec<BatcherStats> },
    CalStatsReq { id: u64 },
    CalStatsReply { id: u64, stats: Vec<CoreCalStats> },
    ModelStatsReq { id: u64 },
    ModelStatsReply { id: u64, stats: Vec<ModelStats> },
    /// Client → server: opt this connection into the push frames below.
    Subscribe { id: u64 },
    /// Server → client: return `grant` submit-window slots (one per
    /// flushed reply, coalesced).
    Credit { grant: u32 },
    /// Server → subscriber: core fence state changed.
    FencePush { core: u32, fenced: bool },
    /// Server → subscriber: core recalibration epoch advanced (monotonic
    /// — apply with `fetch_max`, a late push can never roll back).
    RecalEpochPush { core: u32, epoch: u64 },
    /// Server → subscriber: core residency changed (`None` = cleared).
    ResidencyPush { core: u32, residency: Option<(u32, Vec<TileRef>)> },
    /// Server → subscriber: fresh calibrator snapshot (sent when a recal
    /// epoch advances and a calibrator daemon is attached).
    CalStatsPush { stats: Vec<CoreCalStats> },
    /// Server → subscriber: a core was permanently retired — its fault
    /// mask names the physical columns whose damage survived
    /// recalibration. Terminal: a retired core never rejoins, so a
    /// client can drop it from placement bookkeeping on receipt.
    RetirePush { core: u32, mask: u32 },
}

// ---- encoder ------------------------------------------------------------

/// Body encoder over a borrowed buffer — frames encode straight into the
/// caller's (reused) output vector, so steady-state connections pay no
/// allocation per frame.
struct Enc<'a> {
    b: &'a mut Vec<u8>,
}

impl Enc<'_> {
    fn u8(&mut self, v: u8) {
        self.b.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn i32(&mut self, v: i32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    fn bool(&mut self, v: bool) {
        self.u8(v as u8);
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.b.extend_from_slice(s.as_bytes());
    }

    fn vec_i32(&mut self, v: &[i32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.i32(x);
        }
    }

    fn vec_u32(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
    }
}

// ---- decoder ------------------------------------------------------------

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Self {
        Self { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated)?;
        let s = self.b.get(self.pos..end).ok_or(WireError::Truncated)?;
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn i32(&mut self) -> Result<i32, WireError> {
        let s = self.take(4)?;
        Ok(i32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bool(&mut self) -> Result<bool, WireError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(WireError::BadPayload(format!("bad bool byte {v}"))),
        }
    }

    fn str(&mut self) -> Result<String, WireError> {
        let n = self.len_prefix(1)?;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| WireError::BadPayload("non-UTF-8 string".to_string()))
    }

    /// Read a u32 element-count prefix and reject it BEFORE allocating if
    /// the remaining body cannot possibly hold that many `elem_size`-byte
    /// elements — an adversarial length prefix must never drive an
    /// allocation.
    fn len_prefix(&mut self, elem_size: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_size.max(1)) > self.remaining() {
            return Err(WireError::Truncated);
        }
        Ok(n)
    }

    fn vec_i32(&mut self) -> Result<Vec<i32>, WireError> {
        let n = self.len_prefix(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.i32()?);
        }
        Ok(v)
    }

    fn vec_u32(&mut self) -> Result<Vec<u32>, WireError> {
        let n = self.len_prefix(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn finish(self) -> Result<(), WireError> {
        if self.pos == self.b.len() {
            Ok(())
        } else {
            Err(WireError::BadPayload("trailing bytes after the frame body".to_string()))
        }
    }
}

// ---- payload codecs -----------------------------------------------------

fn put_tile(e: &mut Enc<'_>, t: &TileRef) {
    e.u32(t.layer as u32);
    e.u32(t.tr as u32);
    e.u32(t.tc as u32);
}

fn take_tile(d: &mut Dec) -> Result<TileRef, WireError> {
    Ok(TileRef { layer: d.u32()? as usize, tr: d.u32()? as usize, tc: d.u32()? as usize })
}

fn put_tile_opt(e: &mut Enc<'_>, t: &Option<TileRef>) {
    match t {
        None => e.u8(0),
        Some(t) => {
            e.u8(1);
            put_tile(e, t);
        }
    }
}

fn take_tile_opt(d: &mut Dec) -> Result<Option<TileRef>, WireError> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(take_tile(d)?)),
        t => Err(WireError::BadPayload(format!("bad tile option tag {t}"))),
    }
}

fn put_model_opt(e: &mut Enc<'_>, m: Option<u32>) {
    match m {
        None => e.u8(0),
        Some(m) => {
            e.u8(1);
            e.u32(m);
        }
    }
}

fn take_model_opt(d: &mut Dec) -> Result<Option<u32>, WireError> {
    match d.u8()? {
        0 => Ok(None),
        1 => Ok(Some(d.u32()?)),
        t => Err(WireError::BadPayload(format!("bad model option tag {t}"))),
    }
}

fn put_job(e: &mut Enc<'_>, job: &Job) {
    match job {
        Job::Mac(x) => {
            e.u8(0);
            e.vec_i32(x);
        }
        Job::MacBatch { xs, tile, model } => {
            e.u8(1);
            e.u32(xs.len() as u32);
            for x in xs {
                e.vec_i32(x);
            }
            put_tile_opt(e, tile);
            put_model_opt(e, *model);
        }
        Job::Drain => e.u8(2),
        Job::Health => e.u8(3),
        Job::Rollout { model, weights } => {
            e.u8(4);
            e.u32(*model);
            e.vec_i32(weights);
        }
        Job::Faults(plan) => {
            e.u8(5);
            e.str(plan);
        }
    }
}

fn take_job(d: &mut Dec) -> Result<Job, WireError> {
    match d.u8()? {
        0 => Ok(Job::Mac(d.vec_i32()?)),
        1 => {
            // each batch row costs at least its own 4-byte length prefix
            let n = d.len_prefix(4)?;
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                xs.push(d.vec_i32()?);
            }
            let tile = take_tile_opt(d)?;
            let model = take_model_opt(d)?;
            Ok(Job::MacBatch { xs, tile, model })
        }
        2 => Ok(Job::Drain),
        3 => Ok(Job::Health),
        4 => Ok(Job::Rollout { model: d.u32()?, weights: d.vec_i32()? }),
        5 => Ok(Job::Faults(d.str()?)),
        t => Err(WireError::BadPayload(format!("unknown job kind {t}"))),
    }
}

fn put_opts(e: &mut Enc<'_>, opts: &SubmitOpts) {
    e.u8(opts.priority);
    match opts.deadline {
        None => e.u8(0),
        Some(d) => {
            e.u8(1);
            // relative budget in nanoseconds; the server converts to an
            // absolute expiry at admission, so network latency is not
            // billed against the job
            e.u64(d.as_nanos().min(u64::MAX as u128) as u64);
        }
    }
    match opts.placement {
        Placement::RoundRobin => e.u8(0),
        Placement::LeastLoaded => e.u8(1),
        Placement::Pinned(core) => {
            e.u8(2);
            e.u32(core as u32);
        }
        Placement::Model { model, tile } => {
            e.u8(3);
            e.u32(model);
            put_tile_opt(e, &tile);
        }
    }
}

fn take_opts(d: &mut Dec) -> Result<SubmitOpts, WireError> {
    let priority = d.u8()?;
    let deadline = match d.u8()? {
        0 => None,
        1 => Some(Duration::from_nanos(d.u64()?)),
        t => return Err(WireError::BadPayload(format!("bad deadline option tag {t}"))),
    };
    let placement = match d.u8()? {
        0 => Placement::RoundRobin,
        1 => Placement::LeastLoaded,
        2 => Placement::Pinned(d.u32()? as usize),
        3 => Placement::Model { model: d.u32()?, tile: take_tile_opt(d)? },
        t => return Err(WireError::BadPayload(format!("bad placement tag {t}"))),
    };
    Ok(SubmitOpts { priority, deadline, placement })
}

fn put_serve_error(e: &mut Enc<'_>, err: &ServeError) {
    match err {
        ServeError::BadRequest { expected, got } => {
            e.u8(0);
            e.u32(*expected as u32);
            e.u32(*got as u32);
        }
        ServeError::Backend(msg) => {
            e.u8(1);
            e.str(msg);
        }
        ServeError::Disconnected => e.u8(2),
        ServeError::DeadlineExceeded => e.u8(3),
        ServeError::NoHealthyCore => e.u8(4),
        ServeError::ModelNotResident { model } => {
            e.u8(5);
            e.u32(*model);
        }
        ServeError::WrongModel { requested, resident } => {
            e.u8(6);
            e.u32(*requested);
            put_model_opt(e, *resident);
        }
        ServeError::Overloaded { in_flight, limit } => {
            e.u8(7);
            e.u32(*in_flight as u32);
            e.u32(*limit as u32);
        }
    }
}

fn take_serve_error(d: &mut Dec) -> Result<ServeError, WireError> {
    match d.u8()? {
        0 => Ok(ServeError::BadRequest {
            expected: d.u32()? as usize,
            got: d.u32()? as usize,
        }),
        1 => Ok(ServeError::Backend(d.str()?)),
        2 => Ok(ServeError::Disconnected),
        3 => Ok(ServeError::DeadlineExceeded),
        4 => Ok(ServeError::NoHealthyCore),
        5 => Ok(ServeError::ModelNotResident { model: d.u32()? }),
        6 => Ok(ServeError::WrongModel { requested: d.u32()?, resident: take_model_opt(d)? }),
        7 => Ok(ServeError::Overloaded {
            in_flight: d.u32()? as usize,
            limit: d.u32()? as usize,
        }),
        t => Err(WireError::BadPayload(format!("unknown error kind {t}"))),
    }
}

fn put_health(e: &mut Enc<'_>, h: &CoreHealth) {
    e.u32(h.core as u32);
    match h.residual {
        None => e.u8(0),
        Some(r) => {
            e.u8(1);
            e.f64(r);
        }
    }
    e.bool(h.fenced);
    e.bool(h.recalibrated);
    e.u64(h.recal_epoch);
    put_model_opt(e, h.model);
    e.bool(h.retired);
    e.u32(h.fault_mask);
}

fn take_health(d: &mut Dec) -> Result<CoreHealth, WireError> {
    let core = d.u32()? as usize;
    let residual = match d.u8()? {
        0 => None,
        1 => Some(d.f64()?),
        t => return Err(WireError::BadPayload(format!("bad residual option tag {t}"))),
    };
    Ok(CoreHealth {
        core,
        residual,
        fenced: d.bool()?,
        recalibrated: d.bool()?,
        recal_epoch: d.u64()?,
        model: take_model_opt(d)?,
        retired: d.bool()?,
        fault_mask: d.u32()?,
    })
}

fn put_reply(e: &mut Enc<'_>, reply: &JobReply) {
    match reply {
        JobReply::Mac(q) => {
            e.u8(0);
            e.vec_u32(q);
        }
        JobReply::MacBatch(qs) => {
            e.u8(1);
            e.u32(qs.len() as u32);
            for q in qs {
                e.vec_u32(q);
            }
        }
        JobReply::Health(h) => {
            e.u8(2);
            put_health(e, h);
        }
    }
}

fn take_reply(d: &mut Dec) -> Result<JobReply, WireError> {
    match d.u8()? {
        0 => Ok(JobReply::Mac(d.vec_u32()?)),
        1 => {
            let n = d.len_prefix(4)?;
            let mut qs = Vec::with_capacity(n);
            for _ in 0..n {
                qs.push(d.vec_u32()?);
            }
            Ok(JobReply::MacBatch(qs))
        }
        2 => Ok(JobReply::Health(take_health(d)?)),
        t => Err(WireError::BadPayload(format!("unknown reply kind {t}"))),
    }
}

fn put_result(e: &mut Enc<'_>, result: &Result<JobReply, ServeError>) {
    match result {
        Ok(r) => {
            e.u8(0);
            put_reply(e, r);
        }
        Err(err) => {
            e.u8(1);
            put_serve_error(e, err);
        }
    }
}

fn take_result(d: &mut Dec) -> Result<Result<JobReply, ServeError>, WireError> {
    match d.u8()? {
        0 => Ok(Ok(take_reply(d)?)),
        1 => Ok(Err(take_serve_error(d)?)),
        t => Err(WireError::BadPayload(format!("bad result tag {t}"))),
    }
}

fn put_stats(e: &mut Enc<'_>, s: &BatcherStats) {
    e.u64(s.requests);
    e.u64(s.batches);
    e.u64(s.max_batch_seen as u64);
    e.u64(s.rejected);
    e.u64(s.expired);
}

fn take_stats(d: &mut Dec) -> Result<BatcherStats, WireError> {
    Ok(BatcherStats {
        requests: d.u64()?,
        batches: d.u64()?,
        max_batch_seen: d.u64()? as usize,
        rejected: d.u64()?,
        expired: d.u64()?,
    })
}

/// Minimum encoded size of one [`CoreCalStats`] (trend and model both
/// `None`): the element-size bound `CalStatsReply`'s length prefix is
/// checked against.
const CALSTATS_MIN_LEN: usize = 52;

fn put_calstats(e: &mut Enc<'_>, s: &CoreCalStats) {
    e.u64(s.samples);
    match s.trend {
        None => e.u8(0),
        Some(t) => {
            e.u8(1);
            e.f64(t);
        }
    }
    e.u64(s.last_recal_epoch);
    e.u64(s.trend_triggers);
    e.u64(s.staleness_triggers);
    e.u64(s.drains);
    e.u64(s.drain_failures);
    e.bool(s.fenced);
    put_model_opt(e, s.model);
    e.bool(s.retired);
}

fn take_calstats(d: &mut Dec) -> Result<CoreCalStats, WireError> {
    let samples = d.u64()?;
    let trend = match d.u8()? {
        0 => None,
        1 => Some(d.f64()?),
        t => return Err(WireError::BadPayload(format!("bad trend option tag {t}"))),
    };
    Ok(CoreCalStats {
        samples,
        trend,
        last_recal_epoch: d.u64()?,
        trend_triggers: d.u64()?,
        staleness_triggers: d.u64()?,
        drains: d.u64()?,
        drain_failures: d.u64()?,
        fenced: d.bool()?,
        model: take_model_opt(d)?,
        retired: d.bool()?,
    })
}

/// Fixed encoded size of one [`ModelStats`]: the element-size bound
/// `ModelStatsReply`'s length prefix is checked against.
const MODELSTATS_LEN: usize = 36;

fn put_modelstats(e: &mut Enc<'_>, s: &ModelStats) {
    e.u32(s.model);
    e.u64(s.requests);
    e.u64(s.rejected);
    e.u64(s.expired);
    e.u64(s.recals);
}

fn take_modelstats(d: &mut Dec) -> Result<ModelStats, WireError> {
    Ok(ModelStats {
        model: d.u32()?,
        requests: d.u64()?,
        rejected: d.u64()?,
        expired: d.u64()?,
        recals: d.u64()?,
    })
}

/// One core's optional residency — the element type of `Hello`'s
/// residency vector and the payload of `ResidencyPush`.
fn put_residency_opt(e: &mut Enc<'_>, r: &Option<(u32, Vec<TileRef>)>) {
    match r {
        None => e.u8(0),
        Some((model, tiles)) => {
            e.u8(1);
            e.u32(*model);
            e.u32(tiles.len() as u32);
            for t in tiles {
                put_tile(e, t);
            }
        }
    }
}

fn take_residency_opt(d: &mut Dec) -> Result<Option<(u32, Vec<TileRef>)>, WireError> {
    match d.u8()? {
        0 => Ok(None),
        1 => {
            let model = d.u32()?;
            let nt = d.len_prefix(12)?;
            let mut tiles = Vec::with_capacity(nt);
            for _ in 0..nt {
                tiles.push(take_tile(d)?);
            }
            Ok(Some((model, tiles)))
        }
        t => Err(WireError::BadPayload(format!("bad residency option tag {t}"))),
    }
}

// ---- frame assembly -----------------------------------------------------

/// Encode one frame (header + body), APPENDING to `out` — the tag, id,
/// and body-length header fields are backpatched once the body length is
/// known, so the whole frame encodes in place with no staging buffer.
/// Appending (rather than clearing) lets a connection coalesce several
/// frames into one buffer and flush them with a single `write_all`
/// (see the server's reply pump); steady-state connections reuse `out`
/// and pay no allocation per frame.
pub fn encode_frame_into(frame: &Frame, out: &mut Vec<u8>) {
    let header_at = out.len();
    out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    out.push(WIRE_VERSION);
    out.push(0); // tag, backpatched below
    out.extend_from_slice(&[0u8; 12]); // id + body length, backpatched
    let body_at = out.len();
    let (tag, id) = {
        let mut body = Enc { b: out };
        match frame {
            Frame::Hello { cores, window, models, residency } => {
                body.u32(*cores);
                body.u32(*window);
                body.u32(models.len() as u32);
                for m in models {
                    body.str(m);
                }
                body.u32(residency.len() as u32);
                for r in residency {
                    put_residency_opt(&mut body, r);
                }
                (TAG_HELLO, 0)
            }
            Frame::Submit { id, job, opts } => {
                put_opts(&mut body, opts);
                put_job(&mut body, job);
                (TAG_SUBMIT, *id)
            }
            Frame::Reply { id, core, result } => {
                body.u32(*core);
                put_result(&mut body, result);
                (TAG_REPLY, *id)
            }
            Frame::StatsReq { id } => (TAG_STATS_REQ, *id),
            Frame::StatsReply { id, stats } => {
                body.u32(stats.len() as u32);
                for s in stats {
                    put_stats(&mut body, s);
                }
                (TAG_STATS_REPLY, *id)
            }
            Frame::CalStatsReq { id } => (TAG_CALSTATS_REQ, *id),
            Frame::CalStatsReply { id, stats } => {
                body.u32(stats.len() as u32);
                for s in stats {
                    put_calstats(&mut body, s);
                }
                (TAG_CALSTATS_REPLY, *id)
            }
            Frame::ModelStatsReq { id } => (TAG_MODELSTATS_REQ, *id),
            Frame::ModelStatsReply { id, stats } => {
                body.u32(stats.len() as u32);
                for s in stats {
                    put_modelstats(&mut body, s);
                }
                (TAG_MODELSTATS_REPLY, *id)
            }
            Frame::Subscribe { id } => (TAG_SUBSCRIBE, *id),
            Frame::Credit { grant } => {
                body.u32(*grant);
                (TAG_CREDIT, 0)
            }
            Frame::FencePush { core, fenced } => {
                body.u32(*core);
                body.bool(*fenced);
                (TAG_FENCE_PUSH, 0)
            }
            Frame::RecalEpochPush { core, epoch } => {
                body.u32(*core);
                body.u64(*epoch);
                (TAG_RECAL_EPOCH_PUSH, 0)
            }
            Frame::ResidencyPush { core, residency } => {
                body.u32(*core);
                put_residency_opt(&mut body, residency);
                (TAG_RESIDENCY_PUSH, 0)
            }
            Frame::CalStatsPush { stats } => {
                body.u32(stats.len() as u32);
                for s in stats {
                    put_calstats(&mut body, s);
                }
                (TAG_CALSTATS_PUSH, 0)
            }
            Frame::RetirePush { core, mask } => {
                body.u32(*core);
                body.u32(*mask);
                (TAG_RETIRE_PUSH, 0)
            }
        }
    };
    let body_len = (out.len() - body_at) as u32;
    // lint: allow(panic_free) — backpatch into the header this function just appended; in-bounds by construction
    out[header_at + 3] = tag;
    // lint: allow(panic_free) — header backpatch, in-bounds by construction
    out[header_at + 4..header_at + 12].copy_from_slice(&id.to_le_bytes());
    // lint: allow(panic_free) — header backpatch, in-bounds by construction
    out[header_at + 12..header_at + 16].copy_from_slice(&body_len.to_le_bytes());
}

/// Encode one frame (header + body) into a fresh byte vector — thin
/// allocating wrapper over [`encode_frame_into`].
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    encode_frame_into(frame, &mut out);
    out
}

/// Decode one frame body given its already-parsed header fields. Public
/// so the event-loop server can parse frames incrementally out of a
/// connection's read buffer ([`decode_header`] + `decode_body`) instead
/// of through a blocking reader.
pub fn decode_body(tag: u8, id: u64, body: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec::new(body);
    let frame = match tag {
        TAG_HELLO => {
            let cores = d.u32()?;
            let window = d.u32()?;
            // each model name costs at least its own 4-byte length prefix
            let nm = d.len_prefix(4)?;
            let mut models = Vec::with_capacity(nm);
            for _ in 0..nm {
                models.push(d.str()?);
            }
            // each residency entry costs at least its 1-byte option tag
            let nr = d.len_prefix(1)?;
            let mut residency = Vec::with_capacity(nr);
            for _ in 0..nr {
                residency.push(take_residency_opt(&mut d)?);
            }
            Frame::Hello { cores, window, models, residency }
        }
        TAG_SUBMIT => {
            let opts = take_opts(&mut d)?;
            let job = take_job(&mut d)?;
            Frame::Submit { id, job, opts }
        }
        TAG_REPLY => {
            let core = d.u32()?;
            let result = take_result(&mut d)?;
            Frame::Reply { id, core, result }
        }
        TAG_STATS_REQ => Frame::StatsReq { id },
        TAG_STATS_REPLY => {
            let n = d.len_prefix(40)?;
            let mut stats = Vec::with_capacity(n);
            for _ in 0..n {
                stats.push(take_stats(&mut d)?);
            }
            Frame::StatsReply { id, stats }
        }
        TAG_CALSTATS_REQ => Frame::CalStatsReq { id },
        TAG_CALSTATS_REPLY => {
            let n = d.len_prefix(CALSTATS_MIN_LEN)?;
            let mut stats = Vec::with_capacity(n);
            for _ in 0..n {
                stats.push(take_calstats(&mut d)?);
            }
            Frame::CalStatsReply { id, stats }
        }
        TAG_MODELSTATS_REQ => Frame::ModelStatsReq { id },
        TAG_MODELSTATS_REPLY => {
            let n = d.len_prefix(MODELSTATS_LEN)?;
            let mut stats = Vec::with_capacity(n);
            for _ in 0..n {
                stats.push(take_modelstats(&mut d)?);
            }
            Frame::ModelStatsReply { id, stats }
        }
        TAG_SUBSCRIBE => Frame::Subscribe { id },
        TAG_CREDIT => Frame::Credit { grant: d.u32()? },
        TAG_FENCE_PUSH => Frame::FencePush { core: d.u32()?, fenced: d.bool()? },
        TAG_RECAL_EPOCH_PUSH => Frame::RecalEpochPush { core: d.u32()?, epoch: d.u64()? },
        TAG_RESIDENCY_PUSH => {
            Frame::ResidencyPush { core: d.u32()?, residency: take_residency_opt(&mut d)? }
        }
        TAG_CALSTATS_PUSH => {
            let n = d.len_prefix(CALSTATS_MIN_LEN)?;
            let mut stats = Vec::with_capacity(n);
            for _ in 0..n {
                stats.push(take_calstats(&mut d)?);
            }
            Frame::CalStatsPush { stats }
        }
        TAG_RETIRE_PUSH => Frame::RetirePush { core: d.u32()?, mask: d.u32()? },
        t => return Err(WireError::UnknownTag(t)),
    };
    d.finish()?;
    Ok(frame)
}

/// Fill `buf` from the reader, mapping EOF to [`WireError::Closed`] when
/// it lands exactly on a frame boundary (`at_boundary` and nothing read
/// yet) and to [`WireError::Truncated`] otherwise.
fn read_full<R: Read>(r: &mut R, buf: &mut [u8], at_boundary: bool) -> Result<(), WireError> {
    let mut filled = 0;
    while filled < buf.len() {
        // lint: allow(panic_free) — `filled < buf.len()` loop invariant keeps this slice in bounds
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if at_boundary && filled == 0 {
                    WireError::Closed
                } else {
                    WireError::Truncated
                });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e.to_string())),
        }
    }
    Ok(())
}

/// Read and decode one frame from a blocking byte stream.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut body = Vec::new();
    read_frame_buf(r, &mut body)
}

/// The validated header fields of one frame — what [`decode_header`]
/// returns before the body has arrived.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    pub tag: u8,
    pub id: u64,
    /// Body length in bytes (already checked against [`MAX_BODY`]).
    pub body_len: usize,
}

/// Validate one 16-byte frame header: magic, version, and the
/// [`MAX_BODY`] cap. Public (with [`decode_body`]) so a non-blocking
/// reader can parse frames incrementally out of its receive buffer.
pub fn decode_header(header: &[u8; HEADER_LEN]) -> Result<FrameHeader, WireError> {
    let magic = u16::from_le_bytes([header[0], header[1]]);
    if magic != WIRE_MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = header[2];
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let tag = header[3];
    let id = u64::from_le_bytes([
        header[4], header[5], header[6], header[7], header[8], header[9], header[10], header[11],
    ]);
    let len = u32::from_le_bytes([header[12], header[13], header[14], header[15]]);
    if len > MAX_BODY {
        return Err(WireError::Oversized { len, max: MAX_BODY });
    }
    Ok(FrameHeader { tag, id, body_len: len as usize })
}

/// `read_frame` through a caller-owned body buffer, reused across frames
/// — a long-lived connection's read loop stops allocating once the
/// buffer has grown to the largest body seen. The [`MAX_BODY`] check
/// still runs before the buffer is sized, so an adversarial length
/// prefix can never drive an allocation.
pub fn read_frame_buf<R: Read>(r: &mut R, body: &mut Vec<u8>) -> Result<Frame, WireError> {
    let mut header = [0u8; HEADER_LEN];
    read_full(r, &mut header, true)?;
    let h = decode_header(&header)?;
    body.clear();
    body.resize(h.body_len, 0);
    read_full(r, body, false)?;
    decode_body(h.tag, h.id, body)
}

/// Encode and write one frame, flushing so it hits the socket now.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&encode_frame(frame))?;
    w.flush()
}

/// `write_frame` through a caller-owned encode buffer (cleared and
/// reused) — the steady-state form for long-lived connections.
pub fn write_frame_buf<W: Write>(
    w: &mut W,
    frame: &Frame,
    buf: &mut Vec<u8>,
) -> std::io::Result<()> {
    buf.clear();
    encode_frame_into(frame, buf);
    w.write_all(buf)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) {
        let bytes = encode_frame(&frame);
        let mut slice: &[u8] = &bytes;
        let decoded = read_frame(&mut slice).expect("well-formed frame must decode");
        assert_eq!(decoded, frame);
        assert!(slice.is_empty(), "decode must consume the whole frame");
    }

    #[test]
    fn every_frame_kind_roundtrips() {
        roundtrip(Frame::Hello {
            cores: 4,
            window: 1024,
            models: Vec::new(),
            residency: Vec::new(),
        });
        roundtrip(Frame::Hello {
            cores: 2,
            window: 1,
            models: vec!["alpha".to_string(), "beta".to_string()],
            residency: vec![
                Some((0, vec![TileRef { layer: 0, tr: 1, tc: 2 }])),
                None,
            ],
        });
        roundtrip(Frame::Submit {
            id: 7,
            job: Job::Mac(vec![-3, 0, 63]),
            opts: SubmitOpts::default(),
        });
        roundtrip(Frame::Submit {
            id: 8,
            job: Job::MacBatch {
                xs: vec![vec![1, 2], vec![-1, -2]],
                tile: Some(TileRef { layer: 1, tr: 2, tc: 3 }),
                model: Some(1),
            },
            opts: SubmitOpts::pinned(3)
                .with_priority(200)
                .with_deadline(Duration::from_micros(1500)),
        });
        roundtrip(Frame::Submit {
            id: 9,
            job: Job::MacBatch { xs: vec![vec![0]], tile: None, model: None },
            opts: SubmitOpts::for_model(2, Some(TileRef { layer: 0, tr: 0, tc: 1 })),
        });
        roundtrip(Frame::Submit {
            id: 10,
            job: Job::Rollout { model: 3, weights: vec![40, -2, 7] },
            opts: SubmitOpts::for_model(3, None),
        });
        roundtrip(Frame::Submit { id: 11, job: Job::Drain, opts: SubmitOpts::least_loaded() });
        roundtrip(Frame::Submit { id: 12, job: Job::Health, opts: SubmitOpts::default() });
        roundtrip(Frame::Submit {
            id: 26,
            job: Job::Faults("core=1,col=3;core=0,at=500,sa=5:0.0".to_string()),
            opts: SubmitOpts::pinned(1),
        });
        roundtrip(Frame::Submit {
            id: 27,
            job: Job::Faults(String::new()),
            opts: SubmitOpts::default(),
        });
        roundtrip(Frame::Reply {
            id: 13,
            core: 2,
            result: Ok(JobReply::Health(CoreHealth {
                core: 2,
                residual: Some(0.0123),
                fenced: true,
                recalibrated: false,
                recal_epoch: 3,
                model: Some(1),
                retired: true,
                fault_mask: 0x0000_0088,
            })),
        });
        roundtrip(Frame::Reply {
            id: 14,
            core: 0,
            result: Err(ServeError::BadRequest { expected: 64, got: 3 }),
        });
        roundtrip(Frame::Reply {
            id: 15,
            core: u32::MAX,
            result: Err(ServeError::ModelNotResident { model: 9 }),
        });
        roundtrip(Frame::Reply {
            id: 16,
            core: 1,
            result: Err(ServeError::WrongModel { requested: 2, resident: Some(0) }),
        });
        roundtrip(Frame::Reply {
            id: 17,
            core: 1,
            result: Err(ServeError::WrongModel { requested: 2, resident: None }),
        });
        roundtrip(Frame::StatsReq { id: 18 });
        roundtrip(Frame::StatsReply {
            id: 19,
            stats: vec![BatcherStats {
                requests: 10,
                batches: 2,
                max_batch_seen: 8,
                rejected: 1,
                expired: 3,
            }],
        });
        roundtrip(Frame::CalStatsReq { id: 20 });
        roundtrip(Frame::CalStatsReply {
            id: 21,
            stats: vec![
                CoreCalStats {
                    samples: 12,
                    trend: Some(0.042),
                    last_recal_epoch: 2,
                    trend_triggers: 1,
                    staleness_triggers: 0,
                    drains: 1,
                    drain_failures: 0,
                    fenced: false,
                    model: Some(0),
                    retired: true,
                },
                CoreCalStats::default(),
            ],
        });
        roundtrip(Frame::ModelStatsReq { id: 22 });
        roundtrip(Frame::ModelStatsReply {
            id: 23,
            stats: vec![
                ModelStats { model: 0, requests: 5, rejected: 1, expired: 0, recals: 2 },
                ModelStats { model: 1, requests: 9, rejected: 0, expired: 1, recals: 0 },
            ],
        });
        roundtrip(Frame::Reply {
            id: 24,
            core: 3,
            result: Err(ServeError::Overloaded { in_flight: 4096, limit: 1024 }),
        });
        roundtrip(Frame::Subscribe { id: 25 });
        roundtrip(Frame::Credit { grant: 17 });
        roundtrip(Frame::FencePush { core: 2, fenced: true });
        roundtrip(Frame::FencePush { core: 0, fenced: false });
        roundtrip(Frame::RecalEpochPush { core: 1, epoch: u64::MAX });
        roundtrip(Frame::ResidencyPush { core: 3, residency: None });
        roundtrip(Frame::ResidencyPush {
            core: 0,
            residency: Some((7, vec![TileRef { layer: 1, tr: 0, tc: 2 }])),
        });
        roundtrip(Frame::CalStatsPush { stats: vec![CoreCalStats::default()] });
        roundtrip(Frame::CalStatsPush { stats: Vec::new() });
        roundtrip(Frame::RetirePush { core: 1, mask: 0x8000_0004 });
        roundtrip(Frame::RetirePush { core: 0, mask: 0 });
    }

    /// Incremental parsing (the event-loop read path): `decode_header`
    /// validates the fixed header, `decode_body` finishes the frame.
    #[test]
    fn header_plus_body_decode_matches_read_frame() {
        let frame = Frame::Submit {
            id: 99,
            job: Job::Mac(vec![1, -2, 3]),
            opts: SubmitOpts::least_loaded(),
        };
        let bytes = encode_frame(&frame);
        let mut header = [0u8; HEADER_LEN];
        header.copy_from_slice(&bytes[..HEADER_LEN]);
        let h = decode_header(&header).expect("valid header");
        assert_eq!(h.id, 99);
        assert_eq!(h.body_len, bytes.len() - HEADER_LEN);
        let decoded = decode_body(h.tag, h.id, &bytes[HEADER_LEN..]).expect("valid body");
        assert_eq!(decoded, frame);
    }

    /// `encode_frame_into` appends, so several frames coalesce into one
    /// buffer and decode back out one by one — the event loop's
    /// outbound-buffer write path. The read side reuses one body buffer
    /// throughout.
    #[test]
    fn coalesced_frames_roundtrip_through_shared_buffers() {
        let frames = vec![
            Frame::Reply { id: 1, core: 0, result: Ok(JobReply::Mac(vec![1, 2, 3])) },
            Frame::Reply { id: 2, core: 1, result: Err(ServeError::DeadlineExceeded) },
            Frame::Hello {
                cores: 8,
                window: 256,
                models: vec!["alpha".to_string()],
                residency: vec![None],
            },
            Frame::StatsReq { id: 3 },
        ];
        let mut buf = Vec::new();
        for f in &frames {
            encode_frame_into(f, &mut buf);
        }
        // the coalesced buffer is the exact concatenation of the
        // one-frame encodings
        let concat: Vec<u8> = frames.iter().flat_map(encode_frame).collect();
        assert_eq!(buf, concat);
        let mut slice: &[u8] = &buf;
        let mut body = Vec::new();
        for f in &frames {
            let decoded = read_frame_buf(&mut slice, &mut body).expect("coalesced frame");
            assert_eq!(&decoded, f);
        }
        assert!(slice.is_empty());
        assert_eq!(
            read_frame_buf(&mut slice, &mut body).unwrap_err(),
            WireError::Closed,
            "exhausted buffer ends on a frame boundary"
        );
    }

    #[test]
    fn empty_mac_and_empty_batch_roundtrip() {
        roundtrip(Frame::Submit {
            id: 1,
            job: Job::Mac(Vec::new()),
            opts: SubmitOpts::default(),
        });
        roundtrip(Frame::Submit {
            id: 2,
            job: Job::MacBatch { xs: Vec::new(), tile: None, model: None },
            opts: SubmitOpts::default(),
        });
        roundtrip(Frame::StatsReply { id: 3, stats: Vec::new() });
        roundtrip(Frame::CalStatsReply { id: 4, stats: Vec::new() });
        roundtrip(Frame::ModelStatsReply { id: 5, stats: Vec::new() });
    }
}
