//! [`RemoteClient`]: the [`CimService`] trait over a TCP socket, so every
//! in-process consumer of the serving API — `CimMlp::infer_batch_service`,
//! the pipelined benches, the CLI — runs unchanged against a remote core
//! cluster.
//!
//! Placement is resolved AT THE EDGE: the connection handshake ships the
//! cluster's core count, the client keeps its own [`CoreBoard`] mirror
//! (fences, depth gauges, recalibration epochs), resolves round-robin /
//! least-loaded / pinned locally, and ships the job pre-pinned. That
//! keeps the whole `CimService` contract honest over the wire — a
//! [`Ticket`]'s serving core is exact at submit time (the DNN gather path
//! picks per-core trims by it), the depth gauges see this client's own
//! in-flight load, and `drain`'s fence takes effect before the drain job
//! is on the wire. The mirror's fence state synchronizes from
//! `Health`/`Drain` replies observed by THIS client; the recalibration
//! epoch rides in every such reply as the SERVER-observed value, so even
//! drains this client never requested — another client's, or the
//! calibrator daemon's autonomous ones — catch the mirror up on the
//! next local lifecycle probe. Connections that [`RemoteClient::subscribe`]
//! get the server-pushed control plane (wire v4): fence, epoch,
//! residency, and calibrator deltas stream in without any local probe.
//!
//! Flow control (wire v4): the handshake grants a credit window — the
//! maximum number of unanswered `Submit`s — and `Credit` frames return
//! slots as replies flush. `submit` BLOCKS while the window is empty,
//! so a client can never bury a slow server (or be buried by its own
//! replies); control requests (`stats`/`calstats`/`modelstats`) ride
//! outside the window.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::coordinator::batcher::{BatcherStats, ModelStats, ServeError};
use crate::coordinator::calibrator::CoreCalStats;
use crate::coordinator::service::{
    place, CimService, CoreBoard, Job, JobReply, Placement, SubmitOpts, Ticket,
};
use crate::coordinator::wire::codec::{
    encode_frame_into, read_frame, read_frame_buf, write_frame_buf, Frame, HEADER_LEN, MAX_BODY,
};
use crate::util::sync::lock_unpoisoned;
use std::collections::HashMap;
use std::io::{self, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};

/// One in-flight job: where its reply goes and what the mirror gauges
/// reserved for it.
struct PendingJob {
    tx: Sender<Result<JobReply, ServeError>>,
    core: usize,
    weight: usize,
    /// `Drain` or `Rollout`: both fence the mirror at submit and hold
    /// that fence until their own reply settles it.
    is_barrier: bool,
}

/// State shared with the reader thread.
struct Shared {
    board: Arc<CoreBoard>,
    /// Server-registered model names (id order), from the handshake.
    models: Vec<String>,
    pending: Mutex<HashMap<u64, PendingJob>>,
    pending_stats: Mutex<HashMap<u64, Sender<Vec<BatcherStats>>>>,
    pending_cal: Mutex<HashMap<u64, Sender<Vec<CoreCalStats>>>>,
    pending_model: Mutex<HashMap<u64, Sender<Vec<ModelStats>>>>,
    /// Per-core count of this client's in-flight barrier (`Drain` /
    /// `Rollout`) jobs. While one is pending, a concurrently measured
    /// `fenced: false` Health reply is stale — honoring it would unfence
    /// the mirror out from under the fence `drain()`/`rollout()` just
    /// placed, letting placed jobs pile up behind the server-side
    /// barrier.
    drains: Vec<AtomicUsize>,
    /// Submit-window slots currently available (wire v4 flow control):
    /// seeded by the `Hello` window, spent one per `Submit`, refilled by
    /// `Credit` grants. `submit` blocks on the condvar while empty.
    credits: Mutex<u64>,
    credit_cv: Condvar,
    /// Last `CalStatsPush` snapshot (subscribed connections only).
    pushed_cal: Mutex<Vec<CoreCalStats>>,
    alive: AtomicBool,
}

impl Shared {
    /// Take one submit-window slot, blocking while the window is empty.
    /// Returns `false` if the connection died first (or was already
    /// dead) — the waiters are woken by `Credit` grants and by the
    /// reader's exit sweep.
    fn acquire_credit(&self) -> bool {
        let mut avail = lock_unpoisoned(&self.credits);
        while *avail == 0 {
            if !self.alive.load(Ordering::SeqCst) {
                return false;
            }
            avail = match self.credit_cv.wait(avail) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        *avail -= 1;
        true
    }

    /// Return one slot (a submit that failed before reaching the wire).
    fn refund_credit(&self) {
        *lock_unpoisoned(&self.credits) += 1;
        self.credit_cv.notify_one();
    }
}

/// Remove one pending entry under its map lock. A separate function so
/// the guard is provably released before the caller touches any channel
/// or socket (rule `lock_across_io`).
fn take_pending<T>(m: &Mutex<HashMap<u64, T>>, id: u64) -> Option<T> {
    lock_unpoisoned(m).remove(&id)
}

/// The write half of the connection plus its reusable encode buffer —
/// one mutex guards both, so every frame from any clone encodes into the
/// same steady-state buffer (no allocation per submit).
struct WriteHalf {
    stream: TcpStream,
    buf: Vec<u8>,
}

struct Inner {
    shared: Arc<Shared>,
    /// original stream, kept to unblock the reader on drop
    stream: TcpStream,
    /// serialized frame writes (submits from any clone)
    write: Mutex<WriteHalf>,
    rr: AtomicUsize,
    next_id: AtomicU64,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // teardown: shutdown unblocks the reader (already-closed is
        // fine), and a reader that panicked has nothing left to clean up
        let _ = self.stream.shutdown(Shutdown::Both);
        if let Some(h) = lock_unpoisoned(&self.reader).take() {
            let _ = h.join();
        }
    }
}

/// A connection to a [`crate::coordinator::wire::WireServer`]. Cloning is
/// cheap and clones share the connection, the request-id space, and the
/// board mirror — clone freely across producer threads, exactly like the
/// in-process `ServiceClient`.
pub struct RemoteClient {
    inner: Arc<Inner>,
}

impl Clone for RemoteClient {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl RemoteClient {
    /// Connect and handshake: the server opens with a `Hello` frame
    /// carrying its core count (which sizes the local board mirror), its
    /// registered model names, and every core's current residency — the
    /// mirror starts with the server's model map, so `Placement::Model`
    /// resolves at the edge from the first submit.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> io::Result<Self> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let (cores, window, models, residency) = match read_frame(&mut stream) {
            Ok(Frame::Hello { cores, window, models, residency }) if cores > 0 => {
                // a zero window would deadlock every submit forever; treat
                // a lying server as granting the minimum useful window
                (cores as usize, u64::from(window.max(1)), models, residency)
            }
            Ok(_) | Err(_) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "server did not open with a valid Hello frame",
                ));
            }
        };
        let board = Arc::new(CoreBoard::new(cores));
        // out-of-range residency entries (a lying server) degrade to
        // no-ops inside the board accessors
        for (core, r) in residency.into_iter().enumerate() {
            if let Some((model, tiles)) = r {
                board.set_residency(core, model, tiles);
            }
        }
        let shared = Arc::new(Shared {
            board,
            models,
            pending: Mutex::new(HashMap::new()),
            pending_stats: Mutex::new(HashMap::new()),
            pending_cal: Mutex::new(HashMap::new()),
            pending_model: Mutex::new(HashMap::new()),
            drains: (0..cores).map(|_| AtomicUsize::new(0)).collect(),
            credits: Mutex::new(window),
            credit_cv: Condvar::new(),
            pushed_cal: Mutex::new(Vec::new()),
            alive: AtomicBool::new(true),
        });
        let write = stream.try_clone()?;
        let reader_stream = stream.try_clone()?;
        let reader_shared = Arc::clone(&shared);
        let reader = std::thread::spawn(move || reader_loop(reader_stream, reader_shared));
        Ok(Self {
            inner: Arc::new(Inner {
                shared,
                stream,
                write: Mutex::new(WriteHalf { stream: write, buf: Vec::new() }),
                rr: AtomicUsize::new(0),
                next_id: AtomicU64::new(1),
                reader: Mutex::new(Some(reader)),
            }),
        })
    }

    /// Fetch the server's per-core live [`BatcherStats`] snapshots.
    pub fn remote_stats(&self) -> Result<Vec<BatcherStats>, ServeError> {
        let sh = &self.inner.shared;
        if !sh.alive.load(Ordering::SeqCst) {
            return Err(ServeError::Disconnected);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        lock_unpoisoned(&sh.pending_stats).insert(id, tx);
        let sent = {
            let mut guard = lock_unpoisoned(&self.inner.write);
            let w = &mut *guard;
            // lint: allow(lock_across_io) — the write mutex serializes whole-frame writes; holding it across the write is its purpose
            write_frame_buf(&mut w.stream, &Frame::StatsReq { id }, &mut w.buf).is_ok()
        };
        // re-check AFTER the insert: the reader may have disconnected and
        // cleared the map between our alive check and the insert — if our
        // entry slipped in after that sweep, remove it ourselves so the
        // recv below can never block on a sender nobody will ever use
        if !sent || !sh.alive.load(Ordering::SeqCst) {
            take_pending(&sh.pending_stats, id);
            return Err(ServeError::Disconnected);
        }
        rx.recv().map_err(|_| ServeError::Disconnected)
    }

    /// Fetch the server-side calibrator daemon's per-core statistics.
    /// An empty vec means the server runs without `--auto-calibrate`.
    pub fn calibrator_stats(&self) -> Result<Vec<CoreCalStats>, ServeError> {
        let sh = &self.inner.shared;
        if !sh.alive.load(Ordering::SeqCst) {
            return Err(ServeError::Disconnected);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        lock_unpoisoned(&sh.pending_cal).insert(id, tx);
        let sent = {
            let mut guard = lock_unpoisoned(&self.inner.write);
            let w = &mut *guard;
            // lint: allow(lock_across_io) — the write mutex serializes whole-frame writes; holding it across the write is its purpose
            write_frame_buf(&mut w.stream, &Frame::CalStatsReq { id }, &mut w.buf).is_ok()
        };
        // same post-insert re-check as remote_stats: never block on a
        // sender the disconnected reader will never use
        if !sent || !sh.alive.load(Ordering::SeqCst) {
            take_pending(&sh.pending_cal, id);
            return Err(ServeError::Disconnected);
        }
        rx.recv().map_err(|_| ServeError::Disconnected)
    }

    /// The server's registered model names, in id order (index == the id
    /// [`Placement::Model`] and `Job::Rollout` speak). Empty on
    /// registry-less servers.
    pub fn models(&self) -> &[String] {
        &self.inner.shared.models
    }

    /// Resolve a model name from the handshake to its registry id.
    pub fn model_id(&self, name: &str) -> Option<u32> {
        self.inner.shared.models.iter().position(|m| m == name).map(|i| i as u32)
    }

    /// Opt into the server-pushed control plane: after this, the server
    /// streams fence flips, recalibration epochs, residency changes, and
    /// calibrator snapshots as they happen — the board mirror stays
    /// current WITHOUT submitting anything. The subscription opens with
    /// an initial sync (current epochs, fences, calibrator state), so an
    /// idle observer starts from truth, not from silence.
    pub fn subscribe(&self) -> Result<(), ServeError> {
        let sh = &self.inner.shared;
        if !sh.alive.load(Ordering::SeqCst) {
            return Err(ServeError::Disconnected);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let sent = {
            let mut guard = lock_unpoisoned(&self.inner.write);
            let w = &mut *guard;
            // lint: allow(lock_across_io) — the write mutex serializes whole-frame writes; holding it across the write is its purpose
            write_frame_buf(&mut w.stream, &Frame::Subscribe { id }, &mut w.buf).is_ok()
        };
        if !sent {
            return Err(ServeError::Disconnected);
        }
        Ok(())
    }

    /// The latest server-pushed calibrator snapshot (empty until a
    /// [`RemoteClient::subscribe`]d connection has received one).
    pub fn pushed_calibrator_stats(&self) -> Vec<CoreCalStats> {
        lock_unpoisoned(&self.inner.shared.pushed_cal).clone()
    }

    /// Fetch the server's cluster-merged per-model [`ModelStats`]. An
    /// empty vec means the server serves no model counters (or none have
    /// been touched yet).
    pub fn remote_model_stats(&self) -> Result<Vec<ModelStats>, ServeError> {
        let sh = &self.inner.shared;
        if !sh.alive.load(Ordering::SeqCst) {
            return Err(ServeError::Disconnected);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        lock_unpoisoned(&sh.pending_model).insert(id, tx);
        let sent = {
            let mut guard = lock_unpoisoned(&self.inner.write);
            let w = &mut *guard;
            // lint: allow(lock_across_io) — the write mutex serializes whole-frame writes; holding it across the write is its purpose
            write_frame_buf(&mut w.stream, &Frame::ModelStatsReq { id }, &mut w.buf).is_ok()
        };
        // same post-insert re-check as remote_stats: never block on a
        // sender the disconnected reader will never use
        if !sent || !sh.alive.load(Ordering::SeqCst) {
            take_pending(&sh.pending_model, id);
            return Err(ServeError::Disconnected);
        }
        rx.recv().map_err(|_| ServeError::Disconnected)
    }
}

impl CimService for RemoteClient {
    fn board(&self) -> &CoreBoard {
        &self.inner.shared.board
    }

    fn submit(&self, job: Job, opts: SubmitOpts) -> Result<Ticket<JobReply>, ServeError> {
        let sh = &self.inner.shared;
        if !sh.alive.load(Ordering::SeqCst) {
            return Err(ServeError::Disconnected);
        }
        let core = place(&sh.board, &self.inner.rr, opts.placement)?;
        // one window slot per submit — blocks while the window is empty,
        // so this client can never run further ahead of the server than
        // the handshake's credit grant (the slot comes back as a `Credit`
        // frame once the reply has been queued)
        if !sh.acquire_credit() {
            return Err(ServeError::Disconnected);
        }
        let weight = job.weight();
        let is_barrier = matches!(job, Job::Drain | Job::Rollout { .. } | Job::Faults(_));
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        sh.board.add_in_flight(core, weight);
        // registered BEFORE the frame is on the wire: the reply cannot
        // outrun its pending entry
        lock_unpoisoned(&sh.pending).insert(id, PendingJob { tx, core, weight, is_barrier });
        if is_barrier {
            if let Some(d) = sh.drains.get(core) {
                d.fetch_add(1, Ordering::SeqCst);
            }
        }
        // ship the RESOLVED placement so the server's core choice always
        // matches this ticket's core and the mirror's depth accounting;
        // the frame encodes into the connection's shared steady-state
        // buffer under the write lock (no allocation per submit)
        let wire_opts = SubmitOpts { placement: Placement::Pinned(core), ..opts };
        let frame = Frame::Submit { id, job, opts: wire_opts };
        let (sent, oversized_body) = {
            let mut guard = lock_unpoisoned(&self.inner.write);
            let w = &mut *guard;
            w.buf.clear();
            encode_frame_into(&frame, &mut w.buf);
            if w.buf.len() - HEADER_LEN > MAX_BODY as usize {
                let body_len = w.buf.len() - HEADER_LEN;
                // an over-cap encode must not pin its capacity in the
                // connection's steady-state buffer for the rest of the
                // connection's life — drop it and start fresh
                w.buf = Vec::new();
                (false, Some(body_len))
            } else {
                // lint: allow(lock_across_io) — the write mutex serializes whole-frame writes; holding it across the write is its purpose
                let ok = w.stream.write_all(&w.buf).and_then(|_| w.stream.flush()).is_ok();
                // a rare huge (near-cap) submit must not pin tens of MB
                // in the connection's steady-state buffer; ordinary
                // traffic stays well under this and keeps its capacity
                if w.buf.capacity() > (1 << 21) {
                    w.buf = Vec::new();
                }
                (ok, None)
            }
        };
        if let Some(body_len) = oversized_body {
            // enforce the peer's frame cap locally: shipping it anyway
            // would kill the whole connection (the server's decoder
            // rejects oversized bodies), taking every in-flight job with
            // this one
            if let Some(p) = take_pending(&sh.pending, id) {
                sh.board.sub_in_flight(core, weight);
                if p.is_barrier {
                    if let Some(d) = sh.drains.get(core) {
                        d.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            // the frame never reached the wire, so the server will never
            // grant this slot back — return it locally
            sh.refund_credit();
            return Err(ServeError::Backend(format!(
                "job encodes to {body_len} body bytes, over the {MAX_BODY}-byte frame cap — \
                 split the batch"
            )));
        }
        // re-check AFTER the insert (see remote_stats): if the reader
        // disconnected and swept the pending map while we were inserting,
        // our entry would otherwise linger and this ticket's wait() would
        // block forever instead of reporting Disconnected
        if !sent || !sh.alive.load(Ordering::SeqCst) {
            if let Some(p) = take_pending(&sh.pending, id) {
                // still ours — the reader's sweep did not settle it
                sh.board.sub_in_flight(core, weight);
                if p.is_barrier {
                    if let Some(d) = sh.drains.get(core) {
                        d.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            sh.alive.store(false, Ordering::SeqCst);
            return Err(ServeError::Disconnected);
        }
        Ok(Ticket::new(rx, core))
    }
}

/// Receive replies and route them to their waiting tickets; on stream
/// end, wake every waiter with `Disconnected` (by dropping its sender)
/// and settle the mirror gauges.
fn reader_loop(mut stream: TcpStream, sh: Arc<Shared>) {
    // reusable frame-body buffer: the reply stream stops allocating for
    // frame transport once the buffer covers the largest reply seen
    let mut body_buf: Vec<u8> = Vec::new();
    loop {
        match read_frame_buf(&mut stream, &mut body_buf) {
            Ok(Frame::Reply { id, core: _, result }) => {
                let Some(p) = take_pending(&sh.pending, id) else { continue };
                sh.board.sub_in_flight(p.core, p.weight);
                if p.is_barrier {
                    if let Some(d) = sh.drains.get(p.core) {
                        d.fetch_sub(1, Ordering::SeqCst);
                    }
                }
                if let Ok(JobReply::Health(h)) = &result {
                    // lifecycle replies carry the authoritative fence and
                    // recalibration state — sync the mirror BEFORE waking
                    // the ticket, so a drain()'s caller observes the
                    // rejoined core immediately
                    if h.core < sh.board.cores() {
                        // the SERVER-observed epoch, not a local bump:
                        // drains this client never requested (another
                        // client's, or the calibrator daemon's) surface
                        // in every Health reply, so the mirror cannot go
                        // silently stale behind autonomous recalibrations
                        sh.board.set_recal_epoch(h.core, h.recal_epoch);
                        // residency sync: only on an actual model CHANGE
                        // (a rollout this client, another client, or the
                        // server itself ran) — an unchanged model must
                        // not wipe the tile list the handshake shipped
                        if h.model != sh.board.resident_model(h.core) {
                            match h.model {
                                // a fresh rollout carries no named tiles
                                Some(m) => sh.board.set_residency(h.core, m, Vec::new()),
                                None => sh.board.clear_residency(h.core),
                            }
                        }
                        if h.retired {
                            // permanent retirement is terminal: mirror the
                            // fault mask and fence for good (unfence
                            // refuses retired cores, so no later frame can
                            // resurrect it)
                            sh.board.retire(h.core, h.fault_mask);
                        } else if h.fenced {
                            sh.board.fence(h.core);
                        } else if sh.drains.get(h.core).is_none_or(|d| d.load(Ordering::SeqCst) == 0)
                        {
                            // a `fenced: false` measured before one of OUR
                            // barriers went out is stale — keep its fence
                            // until its own reply settles it
                            sh.board.unfence(h.core);
                        }
                    }
                }
                let _ = p.tx.send(result);
            }
            Ok(Frame::StatsReply { id, stats }) => {
                if let Some(tx) = take_pending(&sh.pending_stats, id) {
                    let _ = tx.send(stats);
                }
            }
            Ok(Frame::CalStatsReply { id, stats }) => {
                if let Some(tx) = take_pending(&sh.pending_cal, id) {
                    let _ = tx.send(stats);
                }
            }
            Ok(Frame::ModelStatsReply { id, stats }) => {
                if let Some(tx) = take_pending(&sh.pending_model, id) {
                    let _ = tx.send(stats);
                }
            }
            Ok(Frame::Credit { grant }) => {
                // flow-control slots coming back: wake blocked submitters
                let mut avail = lock_unpoisoned(&sh.credits);
                *avail = avail.saturating_add(u64::from(grant));
                drop(avail);
                sh.credit_cv.notify_all();
            }
            Ok(Frame::FencePush { core, fenced }) => {
                let core = core as usize;
                if fenced {
                    sh.board.fence(core);
                } else if sh.drains.get(core).is_none_or(|d| d.load(Ordering::SeqCst) == 0) {
                    // same staleness rule as Health replies: while one of
                    // OUR barriers is in flight, a pushed unfence is
                    // ordered before it server-side — keep our fence
                    sh.board.unfence(core);
                }
            }
            Ok(Frame::RecalEpochPush { core, epoch }) => {
                // fetch_max inside: a pushed epoch can never move the
                // mirror backwards past a fresher Health reply
                sh.board.set_recal_epoch(core as usize, epoch);
            }
            Ok(Frame::ResidencyPush { core, residency }) => match residency {
                Some((model, tiles)) => sh.board.set_residency(core as usize, model, tiles),
                None => sh.board.clear_residency(core as usize),
            },
            Ok(Frame::CalStatsPush { stats }) => {
                *lock_unpoisoned(&sh.pushed_cal) = stats;
            }
            Ok(Frame::RetirePush { core, mask }) => {
                // terminal by construction: retire fences and pins the
                // fault mask, and the board refuses to unfence a retired
                // core — placement routes around it from here on
                sh.board.retire(core as usize, mask);
            }
            // the server must not send anything else after Hello
            Ok(_) => break,
            Err(_) => break,
        }
    }
    sh.alive.store(false, Ordering::SeqCst);
    let mut pending = lock_unpoisoned(&sh.pending);
    for (_, p) in pending.drain() {
        sh.board.sub_in_flight(p.core, p.weight);
    }
    drop(pending);
    lock_unpoisoned(&sh.pending_stats).clear();
    lock_unpoisoned(&sh.pending_cal).clear();
    lock_unpoisoned(&sh.pending_model).clear();
    // submitters parked on an empty credit window must observe the death,
    // not wait for a grant that will never come
    sh.credit_cv.notify_all();
}
