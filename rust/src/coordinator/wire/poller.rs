//! Minimal readiness poller over POSIX `poll(2)` — the zero-dependency
//! substrate under the event-driven wire front-end (DESIGN.md §15).
//!
//! libc is not vendored, so the one syscall is declared directly, the
//! same way `main.rs` declares `signal(2)` for the SIGINT handler.
//! `poll` was chosen over `epoll` deliberately: the front-end tracks at
//! most a few hundred sockets, the fd set is rebuilt per iteration
//! anyway (interest flips with buffer occupancy), and `poll`'s stateless
//! contract has no registration lifecycle to get wrong.
//!
//! On non-unix targets the module degrades to a timed tick that reports
//! every fd ready; the callers' sockets are non-blocking, so spurious
//! readiness resolves as `WouldBlock` — correct, just less efficient.

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

/// Readable readiness (or a pending accept on a listener).
pub const POLLIN: i16 = 0x001;
/// Writable readiness.
pub const POLLOUT: i16 = 0x004;
/// Error condition (revents only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (revents only).
pub const POLLHUP: i16 = 0x010;

/// One slot in the poll set, layout-compatible with `struct pollfd`.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

impl PollFd {
    /// Watch `fd` for `events` (a bitwise OR of [`POLLIN`]/[`POLLOUT`]).
    pub fn new(fd: i32, events: i16) -> Self {
        Self { fd, events, revents: 0 }
    }

    /// The fd this slot watches.
    pub fn fd(&self) -> i32 {
        self.fd
    }

    /// Whether the kernel reported `flag` (a `POLL*` bit) on this slot.
    /// [`POLLERR`]/[`POLLHUP`] can be reported even when not requested.
    pub fn is(&self, flag: i16) -> bool {
        self.revents & flag != 0
    }

    /// Whether anything at all was reported — readiness or error.
    pub fn ready(&self) -> bool {
        self.revents != 0
    }
}

#[cfg(unix)]
mod imp {
    use super::PollFd;

    extern "C" {
        // int poll(struct pollfd *fds, nfds_t nfds, int timeout);
        fn poll(fds: *mut PollFd, nfds: std::ffi::c_ulong, timeout: std::ffi::c_int) -> i32;
    }

    /// Block until at least one slot is ready or `timeout_ms` elapses
    /// (negative blocks indefinitely). Returns the number of ready
    /// slots; 0 on timeout. `EINTR` reads as a zero-event wakeup — the
    /// caller's loop re-evaluates its world either way.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        for f in fds.iter_mut() {
            f.revents = 0;
        }
        // SAFETY: `fds` is a live, exclusively borrowed slice of
        // #[repr(C)] PollFd (layout-compatible with struct pollfd), and
        // the length passed is exactly the slice length, so the kernel
        // writes only inside the borrow.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as std::ffi::c_ulong, timeout_ms) };
        if rc < 0 {
            let err = std::io::Error::last_os_error();
            if err.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(err);
        }
        Ok(rc as usize)
    }
}

#[cfg(not(unix))]
mod imp {
    use super::PollFd;

    /// Portable fallback: tick after a short sleep and report every slot
    /// ready for what it asked. Non-blocking I/O turns the spurious
    /// readiness into `WouldBlock`, so callers stay correct.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
        let ms = if timeout_ms < 0 { 10 } else { timeout_ms.min(10) as u64 };
        std::thread::sleep(std::time::Duration::from_millis(ms.max(1)));
        for f in fds.iter_mut() {
            f.revents = f.events;
        }
        Ok(fds.len())
    }
}

pub use imp::poll_fds;

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn times_out_with_nothing_ready() {
        let (_a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        use std::os::unix::io::AsRawFd;
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, 20).unwrap();
        assert_eq!(n, 0, "no bytes pending: poll must time out");
        assert!(!fds[0].ready());
    }

    #[test]
    fn reports_readable_after_a_write() {
        use std::os::unix::io::AsRawFd;
        let (mut a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        a.write_all(&[7]).unwrap();
        let mut fds = [PollFd::new(b.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].is(POLLIN));
    }

    #[test]
    fn reports_writable_on_an_open_socket() {
        use std::os::unix::io::AsRawFd;
        let (a, _b) = std::os::unix::net::UnixStream::pair().unwrap();
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLOUT)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].is(POLLOUT));
    }

    #[test]
    fn reports_hup_when_the_peer_closes() {
        use std::os::unix::io::AsRawFd;
        let (a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        drop(b);
        let mut fds = [PollFd::new(a.as_raw_fd(), POLLIN)];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert!(fds[0].is(POLLHUP) || fds[0].is(POLLIN), "close must surface");
    }
}
