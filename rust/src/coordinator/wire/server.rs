//! Event-driven TCP front-end over a [`ServiceClient`]: ONE poller
//! thread owns every socket (readiness via [`super::poller`], wire v4
//! flow control via `Credit` frames), decodes [`Frame::Submit`]s
//! incrementally out of per-connection read buffers, pushes them through
//! the shared `submit_routed` path, and streams replies back in
//! COMPLETION order with request-id correlation — hundreds of
//! connections, each with hundreds of jobs in flight, without a thread
//! pair per connection (DESIGN.md §15).
//!
//! Flow control and isolation: every connection's outbound bytes live in
//! its own buffer, written only when `poll` reports the socket writable
//! — a stalled reader backpressures exactly itself. The buffer is
//! structurally bounded: a client may have at most `window` unanswered
//! `Submit`s (granted in `Hello`, replenished by `Credit` frames that
//! ride the stream BEHIND the replies they account for), so a peer that
//! stops reading also stops earning the right to generate replies.
//!
//! Admission control: a `Submit` past the connection's window, or past
//! the cluster-wide shed threshold, is answered immediately with
//! [`ServeError::Overloaded`] — a typed, retryable rejection instead of
//! queueing the job toward a deadline it will miss.
//!
//! Control plane: connections that send `Subscribe` get server-initiated
//! `FencePush`/`RecalEpochPush`/`ResidencyPush`/`CalStatsPush`/
//! `RetirePush` frames whenever the board state changes, so remote
//! mirrors no longer depend
//! on lifecycle replies happening to ride past (the staleness class the
//! epoch fetch-max in `CoreBoard::set_recal_epoch` used to paper over).
//!
//! Graceful shutdown: [`WireServer::request_shutdown`] wakes the loop;
//! it stops accepting, every admitted job still gets its reply, every
//! flushable byte is flushed, and only then do the sockets close (with a
//! grace deadline so one wedged peer cannot hold the process hostage).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::coordinator::batcher::{merge_model_stats, BatcherStats, ModelStats, ServeError};
use crate::coordinator::calibrator::CalibratorShared;
use crate::coordinator::service::{
    CimService, Job, JobReply, Placement, Residency, RoutedReply, RoutedTx, ServiceClient, TileRef,
};
use crate::coordinator::wire::codec::{
    decode_body, decode_header, encode_frame_into, Frame, HEADER_LEN,
};
use crate::coordinator::wire::poller::{poll_fds, PollFd, POLLERR, POLLHUP, POLLIN, POLLOUT};
use crate::util::sync::lock_unpoisoned;
use crate::util::wake::{wake_pair, WakeHandle, WakeReceiver};
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Sentinel `RoutedReply::core` for replies that never reached a worker
/// (placement failed); encoded as `u32::MAX` on the wire.
const NO_CORE: usize = usize::MAX;

/// Default per-connection credit window (max unanswered `Submit`s).
pub const DEFAULT_WINDOW: u32 = 1024;

/// Poll tick: bounds push-delta latency and the stop-flag poll interval.
const TICK_MS: i32 = 25;

/// Per-iteration cap on bytes read from one socket — keeps one firehose
/// connection from starving the rest of the loop; the kernel buffer
/// holds the remainder and `POLLIN` stays set.
const READ_QUANTUM: usize = 256 * 1024;

/// Stop parsing new frames from a connection whose outbound buffer has
/// backed up past this (its reader is slow); reading resumes once the
/// buffer drains. Submit-driven growth is already credit-bounded — this
/// caps control-frame spam (e.g. `StatsReq` floods) the same way.
const OUT_HIGH_WATER: usize = 4 << 20;

/// How long a draining connection (peer EOF or server shutdown) may
/// take to accept its remaining replies before it is dropped anyway.
const DRAIN_GRACE: Duration = Duration::from_secs(10);

/// The TCP front-end. Bind it over a running cluster's client, then call
/// [`WireServer::serve`] (blocks until [`WireServer::request_shutdown`]).
pub struct WireServer {
    listener: TcpListener,
    svc: ServiceClient,
    live: Vec<Arc<Mutex<BatcherStats>>>,
    /// calibrator-daemon statistics answering `CalStats` frames; `None`
    /// (serving without `--auto-calibrate`) answers with an empty vec
    cal: Option<Arc<CalibratorShared>>,
    /// registry model names shipped in every `Hello` (index == model id);
    /// empty on registry-less servers
    models: Vec<String>,
    /// per-core live model counters answering `ModelStats` frames,
    /// merged across cores per request
    model_stats: Vec<Arc<Mutex<Vec<ModelStats>>>>,
    stop: Arc<AtomicBool>,
    /// wakes the poller from worker threads and `request_shutdown`
    waker: WakeHandle,
    /// taken (once) by `serve`
    wake_rx: Mutex<Option<WakeReceiver>>,
    /// per-connection credit window advertised in `Hello`
    window: u32,
    /// cluster-wide shed threshold over the summed depth gauges; `None`
    /// disables shedding
    shed_threshold: Option<usize>,
}

impl WireServer {
    /// Bind a listener over `svc`. `live` are the per-core statistics
    /// handles ([`crate::coordinator::cluster::ClusterServer::live_handles`])
    /// answering `Stats` frames; pass an empty vec to serve without them.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        svc: ServiceClient,
        live: Vec<Arc<Mutex<BatcherStats>>>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept: the poller owns this fd like any other
        listener.set_nonblocking(true)?;
        let (waker, wake_rx) = wake_pair()?;
        Ok(Self {
            listener,
            svc,
            live,
            cal: None,
            models: Vec::new(),
            model_stats: Vec::new(),
            stop: Arc::new(AtomicBool::new(false)),
            waker,
            wake_rx: Mutex::new(Some(wake_rx)),
            window: DEFAULT_WINDOW,
            shed_threshold: None,
        })
    }

    /// Serve the calibrator daemon's live statistics as `CalStats`
    /// frames (`client --op calstats`). Without this, `CalStatsReq` is
    /// answered with an empty list.
    pub fn with_calibrator(mut self, shared: Arc<CalibratorShared>) -> Self {
        self.cal = Some(shared);
        self
    }

    /// Ship the registry's model names (id order) in every `Hello`, so
    /// remote clients can resolve names to the ids placement speaks.
    pub fn with_models(mut self, models: Vec<String>) -> Self {
        self.models = models;
        self
    }

    /// Serve cluster-merged per-model counters as `ModelStats` frames
    /// ([`crate::coordinator::cluster::ClusterServer::model_stats_handles`]).
    /// Without this, `ModelStatsReq` is answered with an empty list.
    pub fn with_model_stats(mut self, handles: Vec<Arc<Mutex<Vec<ModelStats>>>>) -> Self {
        self.model_stats = handles;
        self
    }

    /// Set the admission limits: `window` is the per-connection credit
    /// window (max unanswered `Submit`s, [`DEFAULT_WINDOW`] by default;
    /// clamped to at least 1), `shed_threshold` the cluster-wide
    /// in-flight depth beyond which new submits are answered with
    /// [`ServeError::Overloaded`] (`None` disables shedding).
    pub fn with_admission(mut self, window: u32, shed_threshold: Option<usize>) -> Self {
        self.window = window.max(1);
        self.shed_threshold = shed_threshold;
        self
    }

    /// The bound address (port 0 resolves to an ephemeral port).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Stop accepting connections and begin the drain: every admitted
    /// job is still answered and flushed before its socket closes, then
    /// [`WireServer::serve`] returns. Safe to call from any thread, any
    /// number of times.
    pub fn request_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.waker.wake();
    }

    /// Run the event loop: accept, read, submit, flush — until shutdown
    /// is requested, then drain every connection's in-flight replies and
    /// return. One thread, all sockets.
    pub fn serve(&self) {
        let Some(mut wake_rx) = lock_unpoisoned(&self.wake_rx).take() else {
            // serve() was already called once; a second call has no
            // event sources and nothing to do
            return;
        };
        let listener_fd = listener_fd(&self.listener);
        let mut conns: Vec<Conn> = Vec::new();
        let mut push_state = PushState::snapshot(&self.svc);
        let mut stop_since: Option<Instant> = None;
        let mut fds: Vec<PollFd> = Vec::new();
        loop {
            let stopping = self.stop.load(Ordering::SeqCst);
            if stopping && stop_since.is_none() {
                stop_since = Some(Instant::now());
                for c in conns.iter_mut() {
                    c.begin_drain();
                }
            }

            // ---- wait for readiness -------------------------------------
            fds.clear();
            fds.push(PollFd::new(wake_rx.raw_fd(), POLLIN));
            let listener_slot = if stopping {
                None
            } else {
                fds.push(PollFd::new(listener_fd, POLLIN));
                Some(1)
            };
            let conn_base = fds.len();
            for c in conns.iter() {
                fds.push(PollFd::new(c.fd, c.poll_events()));
            }
            if poll_fds(&mut fds, TICK_MS).is_err() {
                // poll itself failing is unrecoverable for the loop;
                // treat it as a shutdown request so we drain and exit
                self.stop.store(true, Ordering::SeqCst);
            }
            wake_rx.drain();

            // ---- accept -------------------------------------------------
            if listener_slot.and_then(|i| fds.get(i)).is_some_and(|s| s.ready()) {
                self.accept_ready(&mut conns);
            }

            // ---- read + parse -------------------------------------------
            for (i, c) in conns.iter_mut().enumerate() {
                let ready = fds
                    .get(conn_base + i)
                    .map(|s| s.is(POLLIN) || s.is(POLLHUP) || s.is(POLLERR))
                    .unwrap_or(false);
                if ready && c.wants_read() {
                    self.read_and_parse(c);
                }
            }

            // ---- worker replies -----------------------------------------
            for c in conns.iter_mut() {
                c.drain_worker_replies();
            }

            // ---- control-plane pushes -----------------------------------
            let pushes = push_state.diff(&self.svc, self.cal.as_deref());
            if !pushes.is_empty() {
                for c in conns.iter_mut().filter(|c| c.subscribed && !c.dead) {
                    for f in &pushes {
                        c.queue_frame(f);
                    }
                }
            }

            // ---- coalesced credit grants --------------------------------
            for c in conns.iter_mut() {
                c.grant_credit();
            }

            // ---- flush --------------------------------------------------
            for c in conns.iter_mut() {
                c.flush();
            }

            // ---- reap ---------------------------------------------------
            conns.retain_mut(|c| {
                if c.dead || c.drain_complete() || c.drain_expired() {
                    c.close();
                    false
                } else {
                    true
                }
            });

            if stopping && conns.is_empty() {
                break;
            }
            if stop_since.is_some_and(|t| t.elapsed() > DRAIN_GRACE) {
                // one or more peers never accepted their drain; cut them
                for c in conns.iter_mut() {
                    c.close();
                }
                break;
            }
        }
    }

    /// Accept every pending connection (the listener is non-blocking).
    fn accept_ready(&self, conns: &mut Vec<Conn>) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if let Some(mut c) = Conn::new(stream, &self.waker) {
                        // the handshake ships the registry's names, the
                        // credit window, and the board's CURRENT
                        // residency, so the client's mirror starts
                        // correct; later deltas reach subscribers as
                        // ResidencyPush frames
                        let residency: Vec<Option<(u32, Vec<TileRef>)>> = self
                            .svc
                            .board()
                            .residency_snapshot()
                            .into_iter()
                            .map(|r| r.map(|r| (r.model, r.tiles)))
                            .collect();
                        c.queue_frame(&Frame::Hello {
                            cores: self.svc.cores() as u32,
                            window: self.window,
                            models: self.models.clone(),
                            residency,
                        });
                        conns.push(c);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    /// Pull whatever the socket has (up to the read quantum), then parse
    /// and handle every complete frame in the buffer.
    fn read_and_parse(&self, c: &mut Conn) {
        let mut tmp = [0u8; 16 * 1024];
        let mut taken = 0usize;
        loop {
            match c.sock.read(&mut tmp) {
                Ok(0) => {
                    // peer EOF: no more requests, but every admitted job
                    // still gets its reply before the socket closes
                    c.begin_drain();
                    break;
                }
                Ok(n) => {
                    if let Some(chunk) = tmp.get(..n) {
                        c.rbuf.extend_from_slice(chunk);
                    }
                    taken += n;
                    if taken >= READ_QUANTUM {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    c.dead = true;
                    return;
                }
            }
        }
        self.parse_frames(c);
    }

    /// Decode every complete frame sitting in the connection's read
    /// buffer. A malformed header or body is a protocol error — the
    /// connection is dropped rather than resynchronized (there is no
    /// reliable way back into frame alignment).
    fn parse_frames(&self, c: &mut Conn) {
        let mut consumed = 0usize;
        loop {
            let Some(header) = c.rbuf.get(consumed..consumed + HEADER_LEN) else { break };
            let Ok(header) = <&[u8; HEADER_LEN]>::try_from(header) else { break };
            let Ok(h) = decode_header(header) else {
                c.dead = true;
                break;
            };
            let body_at = consumed + HEADER_LEN;
            let Some(body) = c.rbuf.get(body_at..body_at + h.body_len) else { break };
            match decode_body(h.tag, h.id, body) {
                Ok(frame) => {
                    consumed = body_at + h.body_len;
                    if !self.handle_frame(c, frame) {
                        c.dead = true;
                        break;
                    }
                }
                Err(_) => {
                    c.dead = true;
                    break;
                }
            }
        }
        if consumed > 0 {
            c.rbuf.drain(..consumed);
        }
    }

    /// Serve one inbound frame. Returns `false` on a protocol violation
    /// (a frame only the server may send).
    fn handle_frame(&self, c: &mut Conn, frame: Frame) -> bool {
        match frame {
            Frame::Submit { id, job, opts } => {
                self.handle_submit(c, id, job, opts);
                true
            }
            Frame::StatsReq { id } => {
                let stats = snapshot_stats(&self.live);
                c.queue_frame(&Frame::StatsReply { id, stats });
                true
            }
            Frame::CalStatsReq { id } => {
                let stats = self.cal.as_ref().map(|s| s.snapshot()).unwrap_or_default();
                c.queue_frame(&Frame::CalStatsReply { id, stats });
                true
            }
            Frame::ModelStatsReq { id } => {
                let stats = snapshot_model_stats(&self.model_stats);
                c.queue_frame(&Frame::ModelStatsReply { id, stats });
                true
            }
            Frame::Subscribe { .. } => {
                c.subscribed = true;
                // initial sync: the Hello carried residency but not
                // epochs or fences — push the current values so an idle
                // subscriber starts from truth, not from zero
                let board = self.svc.board();
                for core in 0..board.cores() {
                    let epoch = board.recal_epoch(core);
                    if epoch > 0 {
                        c.queue_frame(&Frame::RecalEpochPush { core: core as u32, epoch });
                    }
                    if board.is_fenced(core) {
                        c.queue_frame(&Frame::FencePush { core: core as u32, fenced: true });
                    }
                    if board.is_retired(core) {
                        c.queue_frame(&Frame::RetirePush {
                            core: core as u32,
                            mask: board.fault_mask(core),
                        });
                    }
                }
                if let Some(cal) = &self.cal {
                    c.queue_frame(&Frame::CalStatsPush { stats: cal.snapshot() });
                }
                true
            }
            // everything else is server → client only; a peer sending
            // one is broken — drop the connection rather than guess
            _ => false,
        }
    }

    /// Admission control + submit: window ceiling, cluster-wide shed,
    /// pinned-range validation, then the shared `submit_routed` path.
    fn handle_submit(&self, c: &mut Conn, id: u64, job: Job, opts: crate::coordinator::service::SubmitOpts) {
        let window = self.window as usize;
        if c.in_flight >= window {
            // the client overran its credit window (a well-behaved one
            // blocks for Credit); answer typed, keep serving
            c.queue_reply(id, NO_CORE, Err(ServeError::Overloaded {
                in_flight: c.in_flight,
                limit: window,
            }));
            return;
        }
        if let Some(shed) = self.shed_threshold {
            let board = self.svc.board();
            let total: usize = (0..board.cores()).map(|k| board.in_flight(k)).sum();
            if total >= shed {
                c.queue_reply(id, NO_CORE, Err(ServeError::Overloaded {
                    in_flight: total,
                    limit: shed,
                }));
                return;
            }
        }
        let cores = self.svc.cores();
        if let Placement::Pinned(core) = opts.placement {
            if core >= cores {
                // a remote peer must not be able to panic the loop
                // through an out-of-range pin
                c.queue_reply(id, NO_CORE, Err(ServeError::Backend(format!(
                    "pinned core {core} out of range (cluster has {cores} cores)"
                ))));
                return;
            }
            // mirror CimService::drain / rollout: the fence lands before
            // the barrier job is queued, so no placed work slips in
            // behind it. Job::Faults is deliberately NOT here — fault
            // injection mirrors CimService::inject_faults, which leaves
            // the wounded core serving so chaos drills can watch the
            // health loop catch the damage
            if matches!(job, Job::Drain | Job::Rollout { .. }) {
                self.svc.board().fence(core);
            }
        }
        match self.svc.submit_routed(job, opts, id, &c.rtx) {
            Ok(_core) => c.in_flight += 1,
            Err(e) => c.queue_reply(id, NO_CORE, Err(e)),
        }
    }
}

/// Everything the loop tracks for one connection.
struct Conn {
    sock: TcpStream,
    fd: i32,
    /// unparsed inbound bytes (grows to one read quantum at most per
    /// iteration; complete frames are consumed immediately)
    rbuf: Vec<u8>,
    /// encoded outbound bytes not yet accepted by the kernel
    out: Vec<u8>,
    /// prefix of `out` already written
    out_pos: usize,
    /// the routed sink handed to workers (wakes the poller on delivery)
    rtx: RoutedTx,
    /// worker-reply fan-in for this connection (content bounded by the
    /// credit window: at most `window` jobs can be unanswered)
    rrx: Receiver<RoutedReply>,
    /// submits handed to workers whose replies have not come back yet
    in_flight: usize,
    /// reply frames encoded since the last `Credit` grant
    credit_owed: u32,
    subscribed: bool,
    /// no more requests will be read (peer EOF or server shutdown);
    /// close once `in_flight` is 0 and `out` has flushed
    draining: Option<Instant>,
    dead: bool,
}

impl Conn {
    fn new(sock: TcpStream, waker: &WakeHandle) -> Option<Self> {
        // some platforms have accepted sockets inherit the listener's
        // non-blocking flag, others not — set it explicitly either way
        if sock.set_nonblocking(true).is_err() {
            return None;
        }
        // best-effort latency hint: a platform refusing TCP_NODELAY
        // changes timing, never correctness
        let _ = sock.set_nodelay(true);
        let fd = stream_fd(&sock);
        let (tx, rrx) = channel::<RoutedReply>();
        Some(Self {
            sock,
            fd,
            rbuf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            rtx: RoutedTx::with_waker(tx, waker.clone()),
            rrx,
            in_flight: 0,
            credit_owed: 0,
            subscribed: false,
            draining: None,
            dead: false,
        })
    }

    /// Readiness interest for the next poll round.
    fn poll_events(&self) -> i16 {
        let mut ev = 0i16;
        if self.wants_read() {
            ev |= POLLIN;
        }
        if self.out_pos < self.out.len() {
            ev |= POLLOUT;
        }
        ev
    }

    /// Whether the loop should read this socket: not draining, and the
    /// peer is keeping up with its replies (high-water backpressure).
    fn wants_read(&self) -> bool {
        !self.dead
            && self.draining.is_none()
            && self.out.len() - self.out_pos < OUT_HIGH_WATER
    }

    /// Append one frame to the outbound buffer.
    fn queue_frame(&mut self, f: &Frame) {
        encode_frame_into(f, &mut self.out);
    }

    /// Append one `Reply` frame; every reply earns the client one credit
    /// (granted coalesced, in-stream behind the replies).
    fn queue_reply(&mut self, id: u64, core: usize, result: Result<JobReply, ServeError>) {
        let core = if core == NO_CORE { u32::MAX } else { core as u32 };
        encode_frame_into(&Frame::Reply { id, core, result }, &mut self.out);
        self.credit_owed += 1;
    }

    /// Move every completed job's reply from the worker fan-in channel
    /// into the outbound buffer.
    fn drain_worker_replies(&mut self) {
        loop {
            match self.rrx.try_recv() {
                Ok(r) => {
                    self.in_flight = self.in_flight.saturating_sub(1);
                    self.queue_reply(r.id, r.core, r.result);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
    }

    /// Emit the coalesced `Credit` grant for every reply encoded since
    /// the last one.
    fn grant_credit(&mut self) {
        if self.credit_owed > 0 && !self.dead {
            let grant = self.credit_owed;
            self.credit_owed = 0;
            self.queue_frame(&Frame::Credit { grant });
        }
    }

    /// Write as much of the outbound buffer as the kernel accepts.
    fn flush(&mut self) {
        if self.dead {
            return;
        }
        while self.out_pos < self.out.len() {
            let Some(pending) = self.out.get(self.out_pos..) else { break };
            match self.sock.write(pending) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.out_pos == self.out.len() && self.out_pos > 0 {
            self.out.clear();
            self.out_pos = 0;
            // an outsized round (giant MacBatch replies) must not pin
            // its capacity for the connection's remaining lifetime
            if self.out.capacity() > 2 * OUT_HIGH_WATER {
                self.out = Vec::new();
            }
        }
    }

    /// Stop reading requests; the connection closes once every admitted
    /// job has replied and flushed.
    fn begin_drain(&mut self) {
        if self.draining.is_none() {
            self.draining = Some(Instant::now());
        }
    }

    /// Drained clean: nothing in flight, nothing left to flush.
    fn drain_complete(&self) -> bool {
        self.draining.is_some() && self.in_flight == 0 && self.out_pos >= self.out.len()
    }

    /// Draining but the peer never took its replies within the grace
    /// period — cut it loose rather than leak the connection.
    fn drain_expired(&self) -> bool {
        self.draining.is_some_and(|t| t.elapsed() > DRAIN_GRACE)
    }

    fn close(&mut self) {
        // teardown of a connection already counted dead: a failure here
        // means the peer is gone, which is the outcome we wanted
        let _ = self.sock.shutdown(Shutdown::Both);
    }
}

/// Last-pushed control-plane state; diffed against the live board every
/// loop iteration to generate push frames for subscribers.
struct PushState {
    fenced: Vec<bool>,
    epochs: Vec<u64>,
    residency: Vec<Option<Residency>>,
    retired: Vec<bool>,
}

impl PushState {
    fn snapshot(svc: &ServiceClient) -> Self {
        let board = svc.board();
        Self {
            fenced: (0..board.cores()).map(|k| board.is_fenced(k)).collect(),
            epochs: (0..board.cores()).map(|k| board.recal_epoch(k)).collect(),
            residency: board.residency_snapshot(),
            retired: (0..board.cores()).map(|k| board.is_retired(k)).collect(),
        }
    }

    /// Compare against the live board; returns the push frames for every
    /// delta (empty when nothing changed — the common case) and adopts
    /// the new state.
    fn diff(&mut self, svc: &ServiceClient, cal: Option<&CalibratorShared>) -> Vec<Frame> {
        let board = svc.board();
        let mut out = Vec::new();
        let mut epoch_moved = false;
        for core in 0..board.cores() {
            let fenced = board.is_fenced(core);
            if self.fenced.get(core).copied() != Some(fenced) {
                if let Some(slot) = self.fenced.get_mut(core) {
                    *slot = fenced;
                }
                out.push(Frame::FencePush { core: core as u32, fenced });
            }
            let epoch = board.recal_epoch(core);
            if self.epochs.get(core).copied() != Some(epoch) {
                if let Some(slot) = self.epochs.get_mut(core) {
                    *slot = epoch;
                }
                epoch_moved = true;
                out.push(Frame::RecalEpochPush { core: core as u32, epoch });
            }
            // retirement is one-way (the board never clears it), so only
            // the false → true edge can appear
            let retired = board.is_retired(core);
            if retired && self.retired.get(core).copied() == Some(false) {
                if let Some(slot) = self.retired.get_mut(core) {
                    *slot = true;
                }
                out.push(Frame::RetirePush { core: core as u32, mask: board.fault_mask(core) });
            }
        }
        let residency = board.residency_snapshot();
        for (core, r) in residency.iter().enumerate() {
            if self.residency.get(core) != Some(r) {
                out.push(Frame::ResidencyPush {
                    core: core as u32,
                    residency: r.as_ref().map(|r| (r.model, r.tiles.clone())),
                });
            }
        }
        self.residency = residency;
        if epoch_moved {
            if let Some(cal) = cal {
                out.push(Frame::CalStatsPush { stats: cal.snapshot() });
            }
        }
        out
    }
}

/// Snapshot every core's live statistics. A separate function so each
/// per-core guard is provably released before the reply is encoded
/// (rule `lock_across_io`).
fn snapshot_stats(live: &[Arc<Mutex<BatcherStats>>]) -> Vec<BatcherStats> {
    live.iter().map(|s| *lock_unpoisoned(s)).collect()
}

/// Merge every core's live model counters into one cluster-wide set. A
/// separate function so each per-core guard is provably released before
/// the reply is encoded (rule `lock_across_io`).
fn snapshot_model_stats(handles: &[Arc<Mutex<Vec<ModelStats>>>]) -> Vec<ModelStats> {
    let mut merged = Vec::new();
    for h in handles {
        merge_model_stats(&mut merged, lock_unpoisoned(h).as_slice());
    }
    merged
}

#[cfg(unix)]
fn stream_fd(s: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
fn stream_fd(_s: &TcpStream) -> i32 {
    -1
}

#[cfg(unix)]
fn listener_fd(l: &TcpListener) -> i32 {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}

#[cfg(not(unix))]
fn listener_fd(_l: &TcpListener) -> i32 {
    -1
}
