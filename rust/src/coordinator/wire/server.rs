//! Threaded TCP front-end over a [`ServiceClient`]: accepts connections,
//! decodes [`Frame::Submit`]s, pushes them through the shared
//! `submit_routed` path, and streams replies back in COMPLETION order
//! with request-id correlation — one connection can keep hundreds of
//! jobs in flight without a waiter thread per job.
//!
//! Per connection:
//! * the handler thread owns the read half: it decodes frames and
//!   submits, so admission control (geometry, placement, fencing) runs
//!   on the server's own board;
//! * every submitted job carries a [`ReplySink::Routed`] clone of one
//!   shared fan-in channel; a writer thread drains that channel onto the
//!   socket. When the handler stops reading (client EOF, protocol error,
//!   or shutdown) it drops its sender — the channel then closes exactly
//!   when the last in-flight job has replied, so the writer drains all
//!   outstanding work before the socket closes. That is the graceful-
//!   shutdown path: ctrl-c stops accepts and unblocks readers, but every
//!   admitted job still gets its reply.
//!
//! [`ReplySink::Routed`]: crate::coordinator::service::ReplySink
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::coordinator::batcher::{merge_model_stats, BatcherStats, ModelStats, ServeError};
use crate::coordinator::calibrator::CalibratorShared;
use crate::coordinator::service::{CimService, Job, Placement, RoutedReply, ServiceClient, TileRef};
use crate::coordinator::wire::codec::{
    encode_frame_into, read_frame_buf, write_frame, write_frame_buf, Frame,
};
use crate::util::sync::lock_unpoisoned;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Sentinel `RoutedReply::core` for replies that never reached a worker
/// (placement failed); encoded as `u32::MAX` on the wire.
const NO_CORE: usize = usize::MAX;

/// Live-connection registry: one cloned stream per open connection so
/// [`WireServer::request_shutdown`] can unblock every parked reader.
/// Handlers remove their own entry on exit — a long-running server must
/// not leak one descriptor per connection it has ever served.
type ConnRegistry = Arc<Mutex<Vec<(u64, TcpStream)>>>;

/// The TCP front-end. Bind it over a running cluster's client, then call
/// [`WireServer::serve`] (blocks until [`WireServer::request_shutdown`]).
pub struct WireServer {
    listener: TcpListener,
    svc: ServiceClient,
    live: Vec<Arc<Mutex<BatcherStats>>>,
    /// calibrator-daemon statistics answering `CalStats` frames; `None`
    /// (serving without `--auto-calibrate`) answers with an empty vec
    cal: Option<Arc<CalibratorShared>>,
    /// registry model names shipped in every `Hello` (index == model id);
    /// empty on registry-less servers
    models: Vec<String>,
    /// per-core live model counters answering `ModelStats` frames,
    /// merged across cores per request
    model_stats: Vec<Arc<Mutex<Vec<ModelStats>>>>,
    stop: Arc<AtomicBool>,
    conns: ConnRegistry,
    next_conn: AtomicU64,
}

impl WireServer {
    /// Bind a listener over `svc`. `live` are the per-core statistics
    /// handles ([`crate::coordinator::cluster::ClusterServer::live_handles`])
    /// answering `Stats` frames; pass an empty vec to serve without them.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        svc: ServiceClient,
        live: Vec<Arc<Mutex<BatcherStats>>>,
    ) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept so the serve loop can poll the stop flag
        listener.set_nonblocking(true)?;
        Ok(Self {
            listener,
            svc,
            live,
            cal: None,
            models: Vec::new(),
            model_stats: Vec::new(),
            stop: Arc::new(AtomicBool::new(false)),
            conns: Arc::new(Mutex::new(Vec::new())),
            next_conn: AtomicU64::new(0),
        })
    }

    /// Serve the calibrator daemon's live statistics as `CalStats`
    /// frames (`client --op calstats`). Without this, `CalStatsReq` is
    /// answered with an empty list.
    pub fn with_calibrator(mut self, shared: Arc<CalibratorShared>) -> Self {
        self.cal = Some(shared);
        self
    }

    /// Ship the registry's model names (id order) in every `Hello`, so
    /// remote clients can resolve names to the ids placement speaks.
    pub fn with_models(mut self, models: Vec<String>) -> Self {
        self.models = models;
        self
    }

    /// Serve cluster-merged per-model counters as `ModelStats` frames
    /// ([`crate::coordinator::cluster::ClusterServer::model_stats_handles`]).
    /// Without this, `ModelStatsReq` is answered with an empty list.
    pub fn with_model_stats(mut self, handles: Vec<Arc<Mutex<Vec<ModelStats>>>>) -> Self {
        self.model_stats = handles;
        self
    }

    /// The bound address (port 0 resolves to an ephemeral port).
    pub fn local_addr(&self) -> std::io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Stop accepting connections and unblock every connection reader;
    /// [`WireServer::serve`] then drains in-flight replies and returns.
    /// Safe to call from any thread, any number of times.
    pub fn request_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for (_, s) in lock_unpoisoned(&self.conns).iter() {
            let _ = s.shutdown(Shutdown::Read);
        }
    }

    /// Accept and serve connections until shutdown is requested, then
    /// drain: every connection's in-flight jobs are answered before their
    /// sockets close, and every handler thread is joined before this
    /// returns.
    pub fn serve(&self) {
        let mut handlers: Vec<JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let cid = self.next_conn.fetch_add(1, Ordering::Relaxed);
                    // registered so request_shutdown can unblock the
                    // reader; the handler deregisters itself on exit. A
                    // connection we cannot register we also cannot
                    // unblock at shutdown — refuse it outright.
                    let Ok(clone) = stream.try_clone() else { continue };
                    lock_unpoisoned(&self.conns).push((cid, clone));
                    let svc = self.svc.clone();
                    let live = self.live.clone();
                    let cal = self.cal.clone();
                    let models = self.models.clone();
                    let model_stats = self.model_stats.clone();
                    let conns = Arc::clone(&self.conns);
                    handlers.push(std::thread::spawn(move || {
                        handle_connection(stream, svc, live, cal, models, model_stats);
                        lock_unpoisoned(&conns).retain(|(id, _)| *id != cid);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
            // completed handlers need no join; keep the list short-lived
            handlers.retain(|h| !h.is_finished());
        }
        // idempotent with request_shutdown, and covers any connection
        // accepted between the flag store and the loop exit
        for (_, s) in lock_unpoisoned(&self.conns).iter() {
            let _ = s.shutdown(Shutdown::Read);
        }
        for h in handlers {
            let _ = h.join();
        }
    }
}

/// Serve one connection: read frames until EOF/shutdown, stream replies.
fn handle_connection(
    stream: TcpStream,
    svc: ServiceClient,
    live: Vec<Arc<Mutex<BatcherStats>>>,
    cal: Option<Arc<CalibratorShared>>,
    models: Vec<String>,
    model_stats: Vec<Arc<Mutex<Vec<ModelStats>>>>,
) {
    // the listener is non-blocking (its accept loop polls the stop flag)
    // and some platforms let accepted sockets inherit that — this
    // connection's frame reads must block
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    // a peer that stops READING must not park the reply pump forever —
    // that would wedge the graceful shutdown behind its socket buffer.
    // After the timeout the write errors, the pump keeps draining (its
    // writes are best-effort), and shutdown completes. A stream that hit
    // the timeout may be mid-frame and is useless afterwards, but that
    // peer was already gone for practical purposes.
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    // one write guard shared by the reply pump and control-plane frames,
    // so concurrent frame writes never interleave
    let write = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    // the handshake ships the registry's names and the board's CURRENT
    // residency, so the client's mirror starts correct; later rollouts
    // reach it through the Health replies they generate
    let residency: Vec<Option<(u32, Vec<TileRef>)>> = svc
        .board()
        .residency_snapshot()
        .into_iter()
        .map(|r| r.map(|r| (r.model, r.tiles)))
        .collect();
    let hello = Frame::Hello { cores: svc.cores() as u32, models, residency };
    // lint: allow(lock_across_io) — serialized whole-frame writes are this mutex's purpose
    if write_frame(&mut *lock_unpoisoned(&write), &hello).is_err() {
        return;
    }
    let (rtx, rrx) = channel::<RoutedReply>();
    let pump = {
        let write = Arc::clone(&write);
        std::thread::spawn(move || reply_pump(rrx, write))
    };
    let mut reader = stream;
    // per-connection reusable buffers: frame bodies in, control-plane
    // frames out (the submit path's replies reuse the pump's buffer)
    let mut body_buf: Vec<u8> = Vec::new();
    let mut ctrl_buf: Vec<u8> = Vec::new();
    loop {
        match read_frame_buf(&mut reader, &mut body_buf) {
            Ok(Frame::Submit { id, job, opts }) => {
                let cores = svc.cores();
                if let Placement::Pinned(core) = opts.placement {
                    if core >= cores {
                        // a remote peer must not be able to panic the
                        // handler through an out-of-range pin
                        let _ = rtx.send(RoutedReply {
                            id,
                            core: NO_CORE,
                            result: Err(ServeError::Backend(format!(
                                "pinned core {core} out of range (cluster has {cores} cores)"
                            ))),
                        });
                        continue;
                    }
                    // mirror CimService::drain / rollout: the fence lands
                    // before the barrier job is queued, so no placed work
                    // slips in behind it
                    if matches!(job, Job::Drain | Job::Rollout { .. }) {
                        svc.board().fence(core);
                    }
                }
                if let Err(e) = svc.submit_routed(job, opts, id, &rtx) {
                    let _ = rtx.send(RoutedReply { id, core: NO_CORE, result: Err(e) });
                }
            }
            Ok(Frame::StatsReq { id }) => {
                let stats = snapshot_stats(&live);
                // lint: allow(lock_across_io) — serialized whole-frame writes are this mutex's purpose
                if write_frame_buf(
                    &mut *lock_unpoisoned(&write),
                    &Frame::StatsReply { id, stats },
                    &mut ctrl_buf,
                )
                .is_err()
                {
                    break;
                }
            }
            Ok(Frame::CalStatsReq { id }) => {
                let stats = cal.as_ref().map(|c| c.snapshot()).unwrap_or_default();
                // lint: allow(lock_across_io) — serialized whole-frame writes are this mutex's purpose
                if write_frame_buf(
                    &mut *lock_unpoisoned(&write),
                    &Frame::CalStatsReply { id, stats },
                    &mut ctrl_buf,
                )
                .is_err()
                {
                    break;
                }
            }
            Ok(Frame::ModelStatsReq { id }) => {
                let stats = snapshot_model_stats(&model_stats);
                // lint: allow(lock_across_io) — serialized whole-frame writes are this mutex's purpose
                if write_frame_buf(
                    &mut *lock_unpoisoned(&write),
                    &Frame::ModelStatsReply { id, stats },
                    &mut ctrl_buf,
                )
                .is_err()
                {
                    break;
                }
            }
            // clients must not send server-side frames; drop the
            // connection rather than guess
            Ok(_) => break,
            Err(_) => break,
        }
    }
    // the submit path holds sink clones for every in-flight job; dropping
    // ours closes the channel exactly when the last of them has replied,
    // so the pump drains all outstanding work before the socket closes
    drop(rtx);
    let _ = pump.join();
    let _ = reader.shutdown(Shutdown::Both);
}

/// Snapshot every core's live statistics. A separate function so each
/// per-core guard is provably released before the reply hits the socket
/// (rule `lock_across_io`).
fn snapshot_stats(live: &[Arc<Mutex<BatcherStats>>]) -> Vec<BatcherStats> {
    live.iter().map(|s| *lock_unpoisoned(s)).collect()
}

/// Merge every core's live model counters into one cluster-wide set. A
/// separate function so each per-core guard is provably released before
/// the reply hits the socket (rule `lock_across_io`).
fn snapshot_model_stats(handles: &[Arc<Mutex<Vec<ModelStats>>>]) -> Vec<ModelStats> {
    let mut merged = Vec::new();
    for h in handles {
        merge_model_stats(&mut merged, lock_unpoisoned(h).as_slice());
    }
    merged
}

/// Stream routed replies onto the socket in completion order, coalescing
/// every reply already waiting at each wakeup into ONE `write_all` +
/// `flush` — under load the framing/syscall cost amortizes across the
/// whole dispatch round instead of being paid per reply. The coalesce
/// run is bounded so a slow reader caps the buffer, not the heap.
fn reply_pump(rrx: Receiver<RoutedReply>, write: Arc<Mutex<TcpStream>>) {
    /// Replies coalesced into one socket write, at most.
    const MAX_COALESCED: usize = 256;
    /// Byte budget per coalesced write: stop coalescing once the buffer
    /// passes this, so many large `MacBatch` replies cannot pile into
    /// one multi-gigabyte write (a single reply can still exceed it —
    /// one frame must be contiguous — but never several together).
    const MAX_COALESCED_BYTES: usize = 1 << 20;
    let mut buf: Vec<u8> = Vec::new();
    while let Ok(first) = rrx.recv() {
        buf.clear();
        encode_reply(first, &mut buf);
        let mut coalesced = 1;
        while coalesced < MAX_COALESCED && buf.len() < MAX_COALESCED_BYTES {
            match rrx.try_recv() {
                Ok(r) => {
                    encode_reply(r, &mut buf);
                    coalesced += 1;
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        // a client that vanished mid-reply is not an error worth keeping
        // state for — keep consuming so no worker sink ever backs up
        let mut w = lock_unpoisoned(&write);
        // lint: allow(lock_across_io) — serialized whole-frame writes are this mutex's purpose
        let _ = w.write_all(&buf).and_then(|_| w.flush());
        drop(w);
        // an outsized round (giant single reply) must not pin its
        // capacity for the connection's remaining lifetime
        if buf.capacity() > 2 * MAX_COALESCED_BYTES {
            buf = Vec::new();
        }
    }
}

/// Append one routed reply to the coalesce buffer as a `Reply` frame.
fn encode_reply(r: RoutedReply, buf: &mut Vec<u8>) {
    let core = if r.core == NO_CORE { u32::MAX } else { r.core as u32 };
    encode_frame_into(&Frame::Reply { id: r.id, core, result: r.result }, buf);
}
