//! Wire protocol front-end for the serving engine: the typed
//! [`crate::coordinator::service::Job`] envelope serialized onto TCP, so
//! a CIM core cluster is driven the way the paper drives its silicon —
//! from an external host over a standard control interface, not by
//! in-process calls.
//!
//! Three layers, each usable alone:
//! * [`codec`] — the versioned, length-prefixed binary frame codec
//!   (DESIGN.md §9 documents the layout); zero dependencies, total
//!   decoding (`WireError`, never a panic);
//! * [`server`] — [`WireServer`], the threaded TCP acceptor over a
//!   running cluster's `ServiceClient`, streaming replies in completion
//!   order with request-id correlation; optionally serves the
//!   calibrator daemon's live statistics as `CalStats` frames
//!   ([`WireServer::with_calibrator`]);
//! * [`client`] — [`RemoteClient`], the full
//!   [`crate::coordinator::service::CimService`] trait over one socket:
//!   DNN serving, pipelined benches, and lifecycle (drain/health) jobs
//!   run unchanged against a remote cluster.

pub mod client;
pub mod codec;
pub mod server;

pub use client::RemoteClient;
pub use codec::{
    encode_frame, encode_frame_into, read_frame, read_frame_buf, write_frame, write_frame_buf,
    Frame, WireError, HEADER_LEN, MAX_BODY, WIRE_MAGIC, WIRE_VERSION,
};
pub use server::WireServer;
