//! Wire protocol front-end for the serving engine: the typed
//! [`crate::coordinator::service::Job`] envelope serialized onto TCP, so
//! a CIM core cluster is driven the way the paper drives its silicon —
//! from an external host over a standard control interface, not by
//! in-process calls.
//!
//! Four layers, each usable alone:
//! * [`codec`] — the versioned, length-prefixed binary frame codec
//!   (DESIGN.md §9 documents the layout); zero dependencies, total
//!   decoding (`WireError`, never a panic);
//! * [`poller`] — a minimal `poll(2)` readiness wrapper (no libc; the
//!   one syscall is declared directly, DESIGN.md §15);
//! * [`server`] — [`WireServer`], a single-threaded event loop over a
//!   running cluster's `ServiceClient`: non-blocking reads feed the
//!   shared submit path, per-connection outbound buffers are bounded by
//!   wire-level `Credit` flow control (a slow reader backpressures only
//!   itself), admission control answers overload with the typed
//!   `ServeError::Overloaded`, and subscribed connections receive
//!   server-pushed fence/epoch/residency/calibrator deltas; optionally
//!   serves the calibrator daemon's live statistics as `CalStats`
//!   frames ([`WireServer::with_calibrator`]);
//! * [`client`] — [`RemoteClient`], the full
//!   [`crate::coordinator::service::CimService`] trait over one socket:
//!   DNN serving, pipelined benches, and lifecycle (drain/health) jobs
//!   run unchanged against a remote cluster, with submits blocking on
//!   the server's credit window.

pub mod client;
pub mod codec;
pub mod poller;
pub mod server;

pub use client::RemoteClient;
pub use codec::{
    decode_body, decode_header, encode_frame, encode_frame_into, read_frame, read_frame_buf,
    write_frame, write_frame_buf, Frame, FrameHeader, WireError, HEADER_LEN, MAX_BODY, WIRE_MAGIC,
    WIRE_VERSION,
};
pub use server::{WireServer, DEFAULT_WINDOW};
