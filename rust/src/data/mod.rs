//! Datasets and model training for the DNN demonstration (paper §VII-C):
//! MNIST IDX loading (when available), a deterministic synthetic fallback,
//! float MLP training, and quantization to the CIM code domain.

pub mod mlp;
pub mod mnist;
pub mod synth;

/// Load MNIST if present, else generate the synthetic dataset
/// (DESIGN.md §2 substitution). Returns (train, test, name).
pub fn load_or_synth(n_train: usize, n_test: usize, seed: u64) -> (synth::Dataset, synth::Dataset, &'static str) {
    if let Some((mut train, mut test)) = mnist::load() {
        train.images.truncate(n_train * synth::IMG_PIXELS);
        train.labels.truncate(n_train);
        test.images.truncate(n_test * synth::IMG_PIXELS);
        test.labels.truncate(n_test);
        (train, test, "mnist")
    } else {
        let (train, test) = synth::generate(n_train, n_test, seed);
        (train, test, "synthetic")
    }
}
