//! MNIST IDX loader. If the canonical ubyte files are present (pointed to
//! by `ACORE_MNIST_DIR` or `./data/mnist`), the DNN demo uses real MNIST
//! (paper §VII-C); otherwise callers fall back to `data::synth`.

use super::synth::{Dataset, IMG_PIXELS};
use std::io::Read;
use std::path::{Path, PathBuf};

fn read_u32be(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn read_file(path: &Path) -> Result<Vec<u8>, String> {
    let mut f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(buf)
}

/// Parse an IDX3 image file + IDX1 label file pair.
pub fn load_pair(images: &Path, labels: &Path) -> Result<Dataset, String> {
    let ib = read_file(images)?;
    let lb = read_file(labels)?;
    if read_u32be(&ib, 0) != 0x0000_0803 {
        return Err(format!("{}: not an IDX3 image file", images.display()));
    }
    if read_u32be(&lb, 0) != 0x0000_0801 {
        return Err(format!("{}: not an IDX1 label file", labels.display()));
    }
    let n = read_u32be(&ib, 4) as usize;
    let rows = read_u32be(&ib, 8) as usize;
    let cols = read_u32be(&ib, 12) as usize;
    if rows * cols != IMG_PIXELS {
        return Err(format!("unexpected image size {rows}x{cols}"));
    }
    if read_u32be(&lb, 4) as usize != n {
        return Err("image/label count mismatch".to_string());
    }
    let pixels = &ib[16..16 + n * IMG_PIXELS];
    let images = pixels.iter().map(|&p| p as f32 / 255.0).collect();
    let labels = lb[8..8 + n].to_vec();
    Ok(Dataset { images, labels })
}

/// Search for MNIST in ACORE_MNIST_DIR or ./data/mnist.
pub fn find_dir() -> Option<PathBuf> {
    let candidates = [
        std::env::var("ACORE_MNIST_DIR").ok().map(PathBuf::from),
        Some(PathBuf::from("data/mnist")),
    ];
    candidates
        .into_iter()
        .flatten()
        .find(|d| d.join("train-images-idx3-ubyte").exists())
}

/// Load (train, test) if available.
pub fn load() -> Option<(Dataset, Dataset)> {
    let dir = find_dir()?;
    let train = load_pair(
        &dir.join("train-images-idx3-ubyte"),
        &dir.join("train-labels-idx1-ubyte"),
    )
    .ok()?;
    let test = load_pair(
        &dir.join("t10k-images-idx3-ubyte"),
        &dir.join("t10k-labels-idx1-ubyte"),
    )
    .ok()?;
    Some((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_idx(dir: &Path, n: usize) -> (PathBuf, PathBuf) {
        let img_path = dir.join("imgs");
        let lbl_path = dir.join("lbls");
        let mut f = std::fs::File::create(&img_path).unwrap();
        f.write_all(&0x0803u32.to_be_bytes()).unwrap();
        f.write_all(&(n as u32).to_be_bytes()).unwrap();
        f.write_all(&28u32.to_be_bytes()).unwrap();
        f.write_all(&28u32.to_be_bytes()).unwrap();
        f.write_all(&vec![128u8; n * IMG_PIXELS]).unwrap();
        let mut f = std::fs::File::create(&lbl_path).unwrap();
        f.write_all(&0x0801u32.to_be_bytes()).unwrap();
        f.write_all(&(n as u32).to_be_bytes()).unwrap();
        f.write_all(&(0..n).map(|i| (i % 10) as u8).collect::<Vec<_>>()).unwrap();
        (img_path, lbl_path)
    }

    #[test]
    fn parses_valid_idx() {
        let dir = std::env::temp_dir().join("acore_mnist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let (ip, lp) = write_idx(&dir, 5);
        let ds = load_pair(&ip, &lp).unwrap();
        assert_eq!(ds.len(), 5);
        assert!((ds.images[0] - 128.0 / 255.0).abs() < 1e-6);
        assert_eq!(ds.labels, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("acore_mnist_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let (ip, lp) = write_idx(&dir, 2);
        // swap: labels file as images
        assert!(load_pair(&lp, &ip).is_err());
    }
}
