//! Deterministic synthetic 10-class digit-like dataset.
//!
//! Substitution (DESIGN.md §2): the paper evaluates an MLP on MNIST; this
//! environment has no network access, so when no MNIST IDX files are found
//! we generate a 28x28, 10-class dataset with the same shape and a similar
//! difficulty profile: each class is a smooth random prototype (low-
//! frequency blobs), samples add per-pixel noise, random shifts, and
//! amplitude jitter. The headline metric — accuracy degradation from CIM
//! non-idealities and its recovery by BISC — exercises identically.

use crate::util::rng::Rng;

pub const IMG_SIDE: usize = 28;
pub const IMG_PIXELS: usize = IMG_SIDE * IMG_SIDE;
pub const NUM_CLASSES: usize = 10;

#[derive(Debug, Clone)]
pub struct Dataset {
    /// row-major images, f32 in [0, 1], len = n * IMG_PIXELS
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * IMG_PIXELS..(i + 1) * IMG_PIXELS]
    }
}

/// Smooth class prototype: sum of a few random Gaussian blobs.
fn prototype(rng: &mut Rng) -> Vec<f32> {
    let mut img = vec![0f32; IMG_PIXELS];
    let blobs = 3 + (rng.next_u64() % 3) as usize;
    for _ in 0..blobs {
        let cx = rng.uniform_in(6.0, 22.0);
        let cy = rng.uniform_in(6.0, 22.0);
        let sx = rng.uniform_in(2.0, 5.0);
        let sy = rng.uniform_in(2.0, 5.0);
        let amp = rng.uniform_in(0.5, 1.0) as f32;
        for y in 0..IMG_SIDE {
            for x in 0..IMG_SIDE {
                let dx = (x as f64 - cx) / sx;
                let dy = (y as f64 - cy) / sy;
                img[y * IMG_SIDE + x] += amp * (-(dx * dx + dy * dy) / 2.0).exp() as f32;
            }
        }
    }
    let max = img.iter().cloned().fold(0f32, f32::max).max(1e-6);
    img.iter_mut().for_each(|v| *v /= max);
    img
}

/// Shift an image by (dx, dy) pixels with zero fill.
fn shifted(img: &[f32], dx: i32, dy: i32) -> Vec<f32> {
    let mut out = vec![0f32; IMG_PIXELS];
    for y in 0..IMG_SIDE as i32 {
        for x in 0..IMG_SIDE as i32 {
            let sx = x - dx;
            let sy = y - dy;
            if (0..IMG_SIDE as i32).contains(&sx) && (0..IMG_SIDE as i32).contains(&sy) {
                out[(y as usize) * IMG_SIDE + x as usize] =
                    img[(sy as usize) * IMG_SIDE + sx as usize];
            }
        }
    }
    out
}

/// Generate train/test splits. Noise and shifts make the task non-trivial
/// (float MLP lands ~mid-90s accuracy, mirroring §VII-C's 94.23%).
pub fn generate(n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    let mut rng = Rng::new(seed ^ 0x5F4_DA7A);
    let protos: Vec<Vec<f32>> = (0..NUM_CLASSES).map(|_| prototype(&mut rng)).collect();
    let mut make = |n: usize, rng: &mut Rng| {
        let mut images = Vec::with_capacity(n * IMG_PIXELS);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let class = (i % NUM_CLASSES) as u8;
            let dx = rng.int_in(-3, 3) as i32;
            let dy = rng.int_in(-3, 3) as i32;
            let base = shifted(&protos[class as usize], dx, dy);
            let amp = rng.uniform_in(0.7, 1.1) as f32;
            for &p in &base {
                let noisy = p * amp + (rng.normal() * 0.18) as f32;
                images.push(noisy.clamp(0.0, 1.0));
            }
            labels.push(class);
        }
        Dataset { images, labels }
    };
    let train = make(n_train, &mut rng);
    let test = make(n_test, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let (a, _) = generate(50, 10, 42);
        let (b, _) = generate(50, 10, 42);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn shapes_and_ranges() {
        let (tr, te) = generate(100, 20, 7);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.images.len(), 100 * IMG_PIXELS);
        assert!(tr.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn labels_balanced() {
        let (tr, _) = generate(100, 10, 3);
        for class in 0..NUM_CLASSES as u8 {
            let count = tr.labels.iter().filter(|&&l| l == class).count();
            assert_eq!(count, 10);
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // nearest-prototype classifier on clean prototypes should beat
        // chance comfortably on the noisy test set
        let (_, te) = generate(10, 200, 11);
        let mut rng = Rng::new(11 ^ 0x5F4_DA7A);
        let protos: Vec<Vec<f32>> = (0..NUM_CLASSES).map(|_| prototype(&mut rng)).collect();
        let mut correct = 0;
        for i in 0..te.len() {
            let img = te.image(i);
            let best = (0..NUM_CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = protos[a].iter().zip(img).map(|(p, q)| (p - q).powi(2)).sum();
                    let db: f32 = protos[b].iter().zip(img).map(|(p, q)| (p - q).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as u8 == te.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len() as f64;
        assert!(acc > 0.5, "nearest-prototype accuracy {acc}");
    }

    #[test]
    fn shift_preserves_mass_interior() {
        let mut rng = Rng::new(1);
        let p = prototype(&mut rng);
        let s = shifted(&p, 0, 0);
        assert_eq!(p, s);
    }
}
