//! Float MLP (784-72-10, paper §VII-C) with minibatch SGD training, plus
//! quantization to the CIM's 6+1-bit code domain.
//!
//! Training happens entirely in rust (no external framework): He init,
//! ReLU hidden layer, softmax cross-entropy, momentum SGD. Good enough to
//! reach the paper's ~94% regime on MNIST-or-synthetic in seconds.

use super::synth::{Dataset, IMG_PIXELS, NUM_CLASSES};
use crate::analog::consts as c;
use crate::util::rng::Rng;

pub const HIDDEN: usize = 72;

#[derive(Debug, Clone)]
pub struct Mlp {
    /// [IMG_PIXELS][HIDDEN] row-major
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    /// [HIDDEN][NUM_CLASSES] row-major
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl Mlp {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x31337);
        let s1 = (2.0 / IMG_PIXELS as f64).sqrt();
        let s2 = (2.0 / HIDDEN as f64).sqrt();
        Self {
            w1: (0..IMG_PIXELS * HIDDEN).map(|_| (rng.normal() * s1) as f32).collect(),
            b1: vec![0.0; HIDDEN],
            w2: (0..HIDDEN * NUM_CLASSES).map(|_| (rng.normal() * s2) as f32).collect(),
            b2: vec![0.0; NUM_CLASSES],
        }
    }

    /// Forward pass; returns (hidden post-ReLU, logits).
    pub fn forward(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let mut h = self.b1.clone();
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let row = &self.w1[i * HIDDEN..(i + 1) * HIDDEN];
            for (hj, &w) in h.iter_mut().zip(row) {
                *hj += xi * w;
            }
        }
        h.iter_mut().for_each(|v| *v = v.max(0.0));
        let mut logits = self.b2.clone();
        for (j, &hj) in h.iter().enumerate() {
            if hj == 0.0 {
                continue;
            }
            let row = &self.w2[j * NUM_CLASSES..(j + 1) * NUM_CLASSES];
            for (o, &w) in logits.iter_mut().zip(row) {
                *o += hj * w;
            }
        }
        (h, logits)
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let (_, logits) = self.forward(x);
        argmax(&logits)
    }

    pub fn accuracy(&self, ds: &Dataset) -> f64 {
        let correct = (0..ds.len())
            .filter(|&i| self.predict(ds.image(i)) == ds.labels[i] as usize)
            .count();
        correct as f64 / ds.len() as f64
    }
}

pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

fn softmax_inplace(v: &mut [f32]) {
    let m = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in v.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    v.iter_mut().for_each(|x| *x /= sum);
}

pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { epochs: 12, batch: 32, lr: 0.08, momentum: 0.9, seed: 1 }
    }
}

/// Minibatch SGD with momentum; returns per-epoch train accuracy.
pub fn train(mlp: &mut Mlp, ds: &Dataset, cfg: &TrainConfig) -> Vec<f64> {
    let mut rng = Rng::new(cfg.seed ^ 0x7EA1);
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    let mut vw1 = vec![0f32; mlp.w1.len()];
    let mut vb1 = vec![0f32; mlp.b1.len()];
    let mut vw2 = vec![0f32; mlp.w2.len()];
    let mut vb2 = vec![0f32; mlp.b2.len()];
    let mut history = Vec::new();
    for _epoch in 0..cfg.epochs {
        rng.shuffle(&mut idx);
        let mut correct = 0usize;
        for chunk in idx.chunks(cfg.batch) {
            let mut gw1 = vec![0f32; mlp.w1.len()];
            let mut gb1 = vec![0f32; mlp.b1.len()];
            let mut gw2 = vec![0f32; mlp.w2.len()];
            let mut gb2 = vec![0f32; mlp.b2.len()];
            for &i in chunk {
                let x = ds.image(i);
                let (h, mut logits) = mlp.forward(x);
                if argmax(&logits) == ds.labels[i] as usize {
                    correct += 1;
                }
                softmax_inplace(&mut logits);
                logits[ds.labels[i] as usize] -= 1.0; // dL/dlogits
                // layer 2 grads
                for (j, &hj) in h.iter().enumerate() {
                    if hj == 0.0 {
                        continue;
                    }
                    for (k, &d) in logits.iter().enumerate() {
                        gw2[j * NUM_CLASSES + k] += hj * d;
                    }
                }
                for (k, &d) in logits.iter().enumerate() {
                    gb2[k] += d;
                }
                // backprop to hidden
                let mut dh = vec![0f32; HIDDEN];
                for (j, dhj) in dh.iter_mut().enumerate() {
                    if h[j] <= 0.0 {
                        continue; // ReLU gate
                    }
                    let row = &mlp.w2[j * NUM_CLASSES..(j + 1) * NUM_CLASSES];
                    *dhj = row.iter().zip(&logits).map(|(w, d)| w * d).sum();
                }
                // layer 1 grads
                for (i_px, &xi) in x.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    let g = &mut gw1[i_px * HIDDEN..(i_px + 1) * HIDDEN];
                    for (gj, &dhj) in g.iter_mut().zip(&dh) {
                        *gj += xi * dhj;
                    }
                }
                for (gj, &dhj) in gb1.iter_mut().zip(&dh) {
                    *gj += dhj;
                }
            }
            let scale = cfg.lr / chunk.len() as f32;
            let step = |w: &mut [f32], v: &mut [f32], g: &[f32]| {
                for i in 0..w.len() {
                    v[i] = cfg.momentum * v[i] - scale * g[i];
                    w[i] += v[i];
                }
            };
            step(&mut mlp.w1, &mut vw1, &gw1);
            step(&mut mlp.b1, &mut vb1, &gb1);
            step(&mut mlp.w2, &mut vw2, &gw2);
            step(&mut mlp.b2, &mut vb2, &gb2);
        }
        history.push(correct as f64 / ds.len() as f64);
    }
    history
}

/// Quantized MLP in CIM code domain (DESIGN.md §6 conventions):
///   * weights -> signed codes in [-63, 63] with per-layer scale sw
///   * input pixels -> codes 0..63 (scale sx1 = 63)
///   * hidden acts -> codes 0..63 with calibrated scale sx2
///   * biases folded into code-product units (x_code * w_code)
#[derive(Debug, Clone)]
pub struct QuantMlp {
    pub w1_codes: Vec<i32>, // [784][72]
    pub b1_cp: Vec<f32>,    // code-product units
    pub w2_codes: Vec<i32>, // [72][10]
    pub b2_cp: Vec<f32>,
    /// hidden-activation quantization scale (codes per code-product unit)
    pub act_scale1: f32,
    /// weight scales (w_float = code / sw)
    pub sw1: f32,
    pub sw2: f32,
}

fn quantize_weights(w: &[f32]) -> (Vec<i32>, f32) {
    let max = w.iter().fold(0f32, |m, &v| m.max(v.abs())).max(1e-9);
    let sw = c::CODE_MAX as f32 / max;
    let codes = w.iter().map(|&v| (v * sw).round() as i32).collect();
    (codes, sw)
}

impl QuantMlp {
    /// Quantize a trained float MLP, calibrating the hidden activation
    /// scale on a sample of the training set.
    pub fn from_float(mlp: &Mlp, calib: &Dataset, calib_n: usize) -> Self {
        let (w1_codes, sw1) = quantize_weights(&mlp.w1);
        let (w2_codes, sw2) = quantize_weights(&mlp.w2);
        let sx1 = c::CODE_MAX as f32; // pixels in [0,1] -> 0..63
        // bias in layer-1 code-product units: b * sx1 * sw1
        let b1_cp: Vec<f32> = mlp.b1.iter().map(|&b| b * sx1 * sw1).collect();
        // hidden activation calibration: find the max hidden value in
        // code-product units on the calibration sample
        let mut hmax = 1e-6f32;
        for i in 0..calib.len().min(calib_n) {
            let (h, _) = mlp.forward(calib.image(i));
            for &v in &h {
                hmax = hmax.max(v * sx1 * sw1);
            }
        }
        // map [0, hmax] -> [0, 63]; use the 99.5th-percentile-ish headroom
        let act_scale1 = c::CODE_MAX as f32 / hmax * 0.9;
        // layer-2 bias in code-product units: b2 * sx2_eff * sw2, where a
        // hidden activation a (cp units) becomes code a*act_scale1, so the
        // effective layer-2 input scale is act_scale1 relative to cp units:
        // b2_float * sw2 / (per-cp-unit) ... derive: logits_cp =
        // sum(code2 * w2code) = sum(a*act_scale1 * w2 * sw2)
        //   = act_scale1*sw2 * sum(a_cp * w2_float)
        // and a_cp = a_float * sx1 * sw1, so
        // logits_cp = act_scale1*sw2*sx1*sw1 * logits_partial. Bias joins as
        // b2 * act_scale1 * sw2 * sx1 * sw1.
        let b2_cp: Vec<f32> = mlp
            .b2
            .iter()
            .map(|&b| b * act_scale1 * sw2 * sx1 * sw1)
            .collect();
        Self { w1_codes, b1_cp, w2_codes, b2_cp, act_scale1, sw1, sw2 }
    }

    /// Quantize an input image to codes 0..63.
    pub fn quantize_input(&self, img: &[f32]) -> Vec<i32> {
        img.iter()
            .map(|&p| (p * c::CODE_MAX as f32).round().clamp(0.0, 63.0) as i32)
            .collect()
    }

    /// Pure-digital reference inference in code domain (no CIM errors, no
    /// ADC) — the upper bound for the CIM pipeline.
    pub fn infer_digital(&self, img: &[f32]) -> Vec<f32> {
        let x = self.quantize_input(img);
        let mut h = self.b1_cp.clone();
        for (i, &xi) in x.iter().enumerate() {
            if xi == 0 {
                continue;
            }
            let row = &self.w1_codes[i * HIDDEN..(i + 1) * HIDDEN];
            for (hj, &w) in h.iter_mut().zip(row) {
                *hj += (xi * w) as f32;
            }
        }
        let h_codes: Vec<i32> = h
            .iter()
            .map(|&v| (v.max(0.0) * self.act_scale1).round().min(63.0) as i32)
            .collect();
        let mut logits = self.b2_cp.clone();
        for (j, &hc) in h_codes.iter().enumerate() {
            if hc == 0 {
                continue;
            }
            let row = &self.w2_codes[j * NUM_CLASSES..(j + 1) * NUM_CLASSES];
            for (o, &w) in logits.iter_mut().zip(row) {
                *o += (hc * w) as f32;
            }
        }
        logits
    }

    pub fn accuracy_digital(&self, ds: &Dataset) -> f64 {
        let correct = (0..ds.len())
            .filter(|&i| argmax(&self.infer_digital(ds.image(i))) == ds.labels[i] as usize)
            .count();
        correct as f64 / ds.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn small_trained() -> (Mlp, synth::Dataset, synth::Dataset) {
        let (train_ds, test_ds) = synth::generate(600, 200, 9);
        let mut mlp = Mlp::new(3);
        let cfg = TrainConfig { epochs: 6, ..Default::default() };
        train(&mut mlp, &train_ds, &cfg);
        (mlp, train_ds, test_ds)
    }

    #[test]
    fn training_improves_accuracy() {
        let (train_ds, _) = synth::generate(400, 100, 5);
        let mut mlp = Mlp::new(1);
        let before = mlp.accuracy(&train_ds);
        let hist = train(&mut mlp, &train_ds, &TrainConfig { epochs: 4, ..Default::default() });
        let after = mlp.accuracy(&train_ds);
        assert!(after > before + 0.3, "{before} -> {after}, hist {hist:?}");
        assert!(after > 0.85, "train acc {after}");
    }

    #[test]
    fn test_accuracy_in_paper_regime() {
        let (mlp, _, test_ds) = small_trained();
        let acc = mlp.accuracy(&test_ds);
        assert!(acc > 0.80, "test acc {acc}");
    }

    #[test]
    fn quantization_preserves_most_accuracy() {
        let (mlp, train_ds, test_ds) = small_trained();
        let q = QuantMlp::from_float(&mlp, &train_ds, 100);
        let fa = mlp.accuracy(&test_ds);
        let qa = q.accuracy_digital(&test_ds);
        assert!(qa > fa - 0.08, "float {fa} quant {qa}");
    }

    #[test]
    fn weight_codes_in_range() {
        let (mlp, train_ds, _) = small_trained();
        let q = QuantMlp::from_float(&mlp, &train_ds, 50);
        assert!(q.w1_codes.iter().all(|&w| (-63..=63).contains(&w)));
        assert!(q.w2_codes.iter().all(|&w| (-63..=63).contains(&w)));
        // full range used
        assert_eq!(q.w1_codes.iter().map(|w| w.abs()).max().unwrap(), 63);
    }

    #[test]
    fn input_quantization_clamps() {
        let (mlp, train_ds, _) = small_trained();
        let q = QuantMlp::from_float(&mlp, &train_ds, 10);
        let img = vec![2.0f32; IMG_PIXELS];
        assert!(q.quantize_input(&img).iter().all(|&v| v == 63));
    }
}
