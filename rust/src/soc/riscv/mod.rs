//! RISC-V RV32IM instruction-set simulator, macro-assembler, and the
//! firmware that runs on it — the stand-in for the paper's A-core
//! (RV32IMFC; the F/C extensions are unused by the control firmware,
//! DESIGN.md §2).

pub mod asm;
pub mod cpu;
pub mod decode;
pub mod selftest;
