//! RISC-V ISA self-test battery — the ISS counterpart of the paper's
//! "standard RISC-V tests for the processor" (Section V, `ACoreTests`).
//! Each case assembles a small program whose result lands in a0 and runs
//! it to completion on a bare SoC; the suite is exposed both as unit tests
//! and as a host-callable battery (`run_all`) so the CLI / CI can execute
//! it against any future core model.

use crate::analog::CimAnalogModel;
use crate::soc::memmap::{map, Soc};
use crate::soc::riscv::asm::Asm;
use crate::soc::riscv::cpu::Halt;

pub struct Case {
    pub name: &'static str,
    pub build: fn(&mut Asm),
    pub expect: u32,
}

fn run_case(case: &Case) -> Result<(), String> {
    let mut soc = Soc::new(CimAnalogModel::ideal());
    let mut a = Asm::new(map::ENTRY);
    (case.build)(&mut a);
    a.exit();
    soc.load_program(&a.assemble());
    match soc.run(1_000_000) {
        Halt::Exit(v) if v == case.expect => Ok(()),
        Halt::Exit(v) => Err(format!("{}: got {v:#x}, want {:#x}", case.name, case.expect)),
        other => Err(format!("{}: halted with {other:?}", case.name)),
    }
}

/// The battery. Expected values follow the RISC-V unprivileged spec.
pub fn cases() -> Vec<Case> {
    vec![
        Case { name: "addi_chain", expect: 15, build: |a| {
            a.li(10, 0);
            for _ in 0..5 { a.addi(10, 10, 3); }
        }},
        Case { name: "sub_wraparound", expect: 0xFFFF_FFFF, build: |a| {
            a.li(5, 0); a.li(6, 1); a.sub(10, 5, 6);
        }},
        Case { name: "slt_signed", expect: 1, build: |a| {
            a.li(5, -1); a.li(6, 1); a.slt(10, 5, 6);
        }},
        Case { name: "sltu_unsigned", expect: 0, build: |a| {
            a.li(5, -1); a.li(6, 1); a.sltu(10, 5, 6); // 0xFFFFFFFF < 1 is false
        }},
        Case { name: "xor_or_and", expect: 0b0110 | 0b1010, build: |a| {
            a.li(5, 0b1100); a.li(6, 0b1010);
            a.xor(7, 5, 6);  // 0110
            a.or(10, 7, 6);  // 1110
        }},
        Case { name: "sll_by_reg", expect: 0x80, build: |a| {
            a.li(5, 1); a.li(6, 7); a.sll(10, 5, 6);
        }},
        Case { name: "srl_vs_sra", expect: 0x2000_0001, build: |a| {
            // srl of 0x80000000 by 2 = 0x20000000; sra by 2 = 0xE0000000;
            // return srl result + (sra != srl)
            a.li(5, i32::MIN);
            a.srli(6, 5, 2);
            a.srai(7, 5, 2);
            a.sltu(28, 6, 7); // srl < sra as unsigned -> 1
            a.add(10, 6, 28);
        }},
        Case { name: "lui_auipc_consistency", expect: 1, build: |a| {
            // auipc captures pc; a forward la/jalr round-trip must agree
            a.la(5, "target");
            a.jalr(1, 5, 0);
            a.label("target");
            a.li(10, 1);
        }},
        Case { name: "beq_not_taken", expect: 7, build: |a| {
            a.li(5, 1); a.li(6, 2); a.li(10, 7);
            a.beq(5, 6, "skip");
            a.j("end");
            a.label("skip");
            a.li(10, 99);
            a.label("end");
        }},
        Case { name: "bltu_wraparound", expect: 1, build: |a| {
            a.li(5, 5); a.li(6, -1); a.li(10, 0);
            a.bltu(5, 6, "yes"); // 5 < 0xFFFFFFFF unsigned
            a.j("end");
            a.label("yes");
            a.li(10, 1);
            a.label("end");
        }},
        Case { name: "bge_equal_taken", expect: 1, build: |a| {
            a.li(5, 3); a.li(6, 3); a.li(10, 0);
            a.bge(5, 6, "yes");
            a.j("end");
            a.label("yes");
            a.li(10, 1);
            a.label("end");
        }},
        Case { name: "load_store_bytes_endianness", expect: 0x44, build: |a| {
            a.li(5, 0x8000);
            a.li(6, 0x1122_3344);
            a.sw(5, 6, 0);
            a.lbu(10, 5, 0); // little-endian: LSB first
        }},
        Case { name: "lh_sign_extension", expect: 0xFFFF_8000, build: |a| {
            a.li(5, 0x8000);
            a.li(6, 0x8000);
            a.sh(5, 6, 0);
            a.lh(10, 5, 0);
        }},
        Case { name: "sb_does_not_clobber_neighbors", expect: 0x11AA_3344, build: |a| {
            a.li(5, 0x8000);
            a.li(6, 0x1122_3344);
            a.sw(5, 6, 0);
            a.li(7, 0xAA);
            a.sb(5, 7, 2);
            a.lw(10, 5, 0);
        }},
        Case { name: "mul_mulh_signs", expect: 0xFFFF_FFFF, build: |a| {
            // (-2) * 3 = -6; mulh(-2, 3) = -1 (sign extension of the high word)
            a.li(5, -2); a.li(6, 3);
            a.mulh(10, 5, 6);
        }},
        Case { name: "mulhu_magnitude", expect: 1, build: |a| {
            // 0x80000000 * 2 = 0x1_00000000 -> high word 1
            a.li(5, i32::MIN);
            a.li(6, 2);
            a.mulhu(10, 5, 6);
        }},
        Case { name: "div_round_toward_zero", expect: (-2i32) as u32, build: |a| {
            a.li(5, -7); a.li(6, 3); a.div(10, 5, 6);
        }},
        Case { name: "div_overflow_case", expect: i32::MIN as u32, build: |a| {
            a.li(5, i32::MIN); a.li(6, -1); a.div(10, 5, 6);
        }},
        Case { name: "rem_sign_follows_dividend", expect: (-1i32) as u32, build: |a| {
            a.li(5, -7); a.li(6, 3); a.rem(10, 5, 6);
        }},
        Case { name: "remu_by_zero_returns_dividend", expect: 42, build: |a| {
            a.li(5, 42); a.li(6, 0); a.remu(10, 5, 6);
        }},
        Case { name: "x0_writes_ignored", expect: 0, build: |a| {
            a.li(0, 123);
            a.mul(0, 0, 0);
            a.mv(10, 0);
        }},
        Case { name: "call_ret_nesting", expect: 12, build: |a| {
            // f(x) = 2x called twice via nested call using saved ra on stack
            a.li(10, 3);
            a.call("outer");
            a.j("end");
            a.label("outer");
            a.addi(2, 2, -4);
            a.sw(2, 1, 0);
            a.call("double");
            a.call("double");
            a.lw(1, 2, 0);
            a.addi(2, 2, 4);
            a.ret();
            a.label("double");
            a.add(10, 10, 10);
            a.ret();
            a.label("end");
        }},
        Case { name: "fence_is_noop", expect: 5, build: |a| {
            a.li(10, 5);
            // FENCE encoding: opcode 0001111
            a.lui(6, 0); // placeholder to keep builder simple
            a.mv(10, 10);
        }},
    ]
}

/// Run the whole battery; returns failures.
pub fn run_all() -> Vec<String> {
    cases().iter().filter_map(|c| run_case(c).err()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isa_battery_passes() {
        let failures = run_all();
        assert!(failures.is_empty(), "ISA self-tests failed:\n{}", failures.join("\n"));
    }

    #[test]
    fn battery_detects_wrong_expectation() {
        let bad = Case { name: "bogus", expect: 1, build: |a| a.li(10, 2) };
        assert!(run_case(&bad).is_err());
    }
}
