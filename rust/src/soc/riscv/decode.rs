//! RV32IM instruction decoder.
//!
//! The paper's A-core is RV32IMFC; the BISC routine and all SoC control
//! firmware shipped here use integer fixed-point only, so the ISS
//! implements the I and M extensions (DESIGN.md §2 documents the
//! substitution). Decoding is table-free: opcode/funct3/funct7 matching,
//! returning a typed `Instr`.

/// Decoded instruction. Registers are indices 0..=31; immediates are
/// sign-extended where the ISA says so.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    Lui { rd: u8, imm: i32 },
    Auipc { rd: u8, imm: i32 },
    Jal { rd: u8, imm: i32 },
    Jalr { rd: u8, rs1: u8, imm: i32 },
    Branch { op: BranchOp, rs1: u8, rs2: u8, imm: i32 },
    Load { op: LoadOp, rd: u8, rs1: u8, imm: i32 },
    Store { op: StoreOp, rs1: u8, rs2: u8, imm: i32 },
    OpImm { op: AluOp, rd: u8, rs1: u8, imm: i32 },
    Op { op: AluOp, rd: u8, rs1: u8, rs2: u8 },
    MulDiv { op: MulDivOp, rd: u8, rs1: u8, rs2: u8 },
    /// FENCE / FENCE.I — no-ops in this single-hart model
    Fence,
    Ecall,
    Ebreak,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BranchOp {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreOp {
    Sb,
    Sh,
    Sw,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MulDivOp {
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeError {
    pub word: u32,
}

#[inline]
fn rd(w: u32) -> u8 {
    ((w >> 7) & 0x1f) as u8
}

#[inline]
fn rs1(w: u32) -> u8 {
    ((w >> 15) & 0x1f) as u8
}

#[inline]
fn rs2(w: u32) -> u8 {
    ((w >> 20) & 0x1f) as u8
}

#[inline]
fn funct3(w: u32) -> u32 {
    (w >> 12) & 0x7
}

#[inline]
fn funct7(w: u32) -> u32 {
    w >> 25
}

/// I-type immediate: bits 31:20, sign-extended.
#[inline]
fn imm_i(w: u32) -> i32 {
    (w as i32) >> 20
}

/// B-type immediate (branch offset, even).
#[inline]
fn imm_b(w: u32) -> i32 {
    let imm = ((w >> 31) << 12)
        | (((w >> 7) & 1) << 11)
        | (((w >> 25) & 0x3f) << 5)
        | (((w >> 8) & 0xf) << 1);
    ((imm << 19) as i32) >> 19
}

/// U-type immediate (upper 20 bits).
#[inline]
fn imm_u(w: u32) -> i32 {
    (w & 0xFFFF_F000) as i32
}

/// J-type immediate (JAL offset).
#[inline]
fn imm_j(w: u32) -> i32 {
    let imm = ((w >> 31) << 20)
        | (((w >> 12) & 0xff) << 12)
        | (((w >> 20) & 1) << 11)
        | (((w >> 21) & 0x3ff) << 1);
    ((imm << 11) as i32) >> 11
}

pub fn decode(w: u32) -> Result<Instr, DecodeError> {
    let err = DecodeError { word: w };
    let opcode = w & 0x7f;
    Ok(match opcode {
        0b0110111 => Instr::Lui { rd: rd(w), imm: imm_u(w) },
        0b0010111 => Instr::Auipc { rd: rd(w), imm: imm_u(w) },
        0b1101111 => Instr::Jal { rd: rd(w), imm: imm_j(w) },
        0b1100111 => {
            if funct3(w) != 0 {
                return Err(err);
            }
            Instr::Jalr { rd: rd(w), rs1: rs1(w), imm: imm_i(w) }
        }
        0b1100011 => {
            let op = match funct3(w) {
                0b000 => BranchOp::Beq,
                0b001 => BranchOp::Bne,
                0b100 => BranchOp::Blt,
                0b101 => BranchOp::Bge,
                0b110 => BranchOp::Bltu,
                0b111 => BranchOp::Bgeu,
                _ => return Err(err),
            };
            Instr::Branch { op, rs1: rs1(w), rs2: rs2(w), imm: imm_b(w) }
        }
        0b0000011 => {
            let op = match funct3(w) {
                0b000 => LoadOp::Lb,
                0b001 => LoadOp::Lh,
                0b010 => LoadOp::Lw,
                0b100 => LoadOp::Lbu,
                0b101 => LoadOp::Lhu,
                _ => return Err(err),
            };
            Instr::Load { op, rd: rd(w), rs1: rs1(w), imm: imm_i(w) }
        }
        0b0100011 => {
            let op = match funct3(w) {
                0b000 => StoreOp::Sb,
                0b001 => StoreOp::Sh,
                0b010 => StoreOp::Sw,
                _ => return Err(err),
            };
            let imm = {
                let raw = ((w >> 25) << 5) | ((w >> 7) & 0x1f);
                ((raw << 20) as i32) >> 20
            };
            Instr::Store { op, rs1: rs1(w), rs2: rs2(w), imm }
        }
        0b0010011 => {
            let f3 = funct3(w);
            let op = match f3 {
                0b000 => AluOp::Add,
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                0b001 => {
                    if funct7(w) != 0 {
                        return Err(err);
                    }
                    AluOp::Sll
                }
                0b101 => match funct7(w) {
                    0b0000000 => AluOp::Srl,
                    0b0100000 => AluOp::Sra,
                    _ => return Err(err),
                },
                _ => unreachable!(),
            };
            // shifts take shamt (5 bits), others the full I-imm
            let imm = if matches!(op, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                ((w >> 20) & 0x1f) as i32
            } else {
                imm_i(w)
            };
            Instr::OpImm { op, rd: rd(w), rs1: rs1(w), imm }
        }
        0b0110011 => {
            let f3 = funct3(w);
            let f7 = funct7(w);
            if f7 == 0b0000001 {
                let op = match f3 {
                    0b000 => MulDivOp::Mul,
                    0b001 => MulDivOp::Mulh,
                    0b010 => MulDivOp::Mulhsu,
                    0b011 => MulDivOp::Mulhu,
                    0b100 => MulDivOp::Div,
                    0b101 => MulDivOp::Divu,
                    0b110 => MulDivOp::Rem,
                    0b111 => MulDivOp::Remu,
                    _ => unreachable!(),
                };
                return Ok(Instr::MulDiv { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) });
            }
            let op = match (f3, f7) {
                (0b000, 0b0000000) => AluOp::Add,
                (0b000, 0b0100000) => AluOp::Sub,
                (0b001, 0b0000000) => AluOp::Sll,
                (0b010, 0b0000000) => AluOp::Slt,
                (0b011, 0b0000000) => AluOp::Sltu,
                (0b100, 0b0000000) => AluOp::Xor,
                (0b101, 0b0000000) => AluOp::Srl,
                (0b101, 0b0100000) => AluOp::Sra,
                (0b110, 0b0000000) => AluOp::Or,
                (0b111, 0b0000000) => AluOp::And,
                _ => return Err(err),
            };
            Instr::Op { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) }
        }
        0b0001111 => Instr::Fence,
        0b1110011 => match w >> 20 {
            0 => Instr::Ecall,
            1 => Instr::Ebreak,
            _ => return Err(err),
        },
        _ => return Err(err),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_addi() {
        // addi x1, x2, -5  => imm=0xFFB rs1=2 f3=0 rd=1 op=0010011
        let w = ((-5i32 as u32 & 0xfff) << 20) | (2 << 15) | (1 << 7) | 0b0010011;
        assert_eq!(
            decode(w).unwrap(),
            Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 2, imm: -5 }
        );
    }

    #[test]
    fn decode_lui_auipc() {
        let w = (0xABCDE << 12) | (5 << 7) | 0b0110111;
        assert_eq!(decode(w).unwrap(), Instr::Lui { rd: 5, imm: (0xABCDEu32 << 12) as i32 });
        let w = (0x1 << 12) | (6 << 7) | 0b0010111;
        assert_eq!(decode(w).unwrap(), Instr::Auipc { rd: 6, imm: 0x1000 });
    }

    #[test]
    fn decode_branch_negative_offset() {
        // beq x1, x2, -4
        let imm = -4i32;
        let ui = imm as u32;
        let w = (((ui >> 12) & 1) << 31)
            | (((ui >> 5) & 0x3f) << 25)
            | (2 << 20)
            | (1 << 15)
            | (0b000 << 12)
            | (((ui >> 1) & 0xf) << 8)
            | (((ui >> 11) & 1) << 7)
            | 0b1100011;
        assert_eq!(
            decode(w).unwrap(),
            Instr::Branch { op: BranchOp::Beq, rs1: 1, rs2: 2, imm: -4 }
        );
    }

    #[test]
    fn decode_muldiv() {
        let w = (0b0000001 << 25) | (3 << 20) | (4 << 15) | (0b100 << 12) | (5 << 7) | 0b0110011;
        assert_eq!(
            decode(w).unwrap(),
            Instr::MulDiv { op: MulDivOp::Div, rd: 5, rs1: 4, rs2: 3 }
        );
    }

    #[test]
    fn decode_shift_imm() {
        // srai x1, x1, 7
        let w = (0b0100000 << 25) | (7 << 20) | (1 << 15) | (0b101 << 12) | (1 << 7) | 0b0010011;
        assert_eq!(
            decode(w).unwrap(),
            Instr::OpImm { op: AluOp::Sra, rd: 1, rs1: 1, imm: 7 }
        );
    }

    #[test]
    fn invalid_opcode_errors() {
        assert!(decode(0x0000_0000).is_err());
        assert!(decode(0xFFFF_FFFF).is_err());
    }

    #[test]
    fn ecall_ebreak() {
        assert_eq!(decode(0x0000_0073).unwrap(), Instr::Ecall);
        assert_eq!(decode(0x0010_0073).unwrap(), Instr::Ebreak);
    }
}
