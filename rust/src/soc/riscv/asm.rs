//! Programmatic RV32IM macro-assembler.
//!
//! Firmware in this repository (the BISC routine, SoC self-tests, the DNN
//! driver) is written against this builder: each method emits one
//! instruction (or a short canonical sequence for pseudo-ops like `li` and
//! `call`), labels are resolved in a second pass. The encoder is the exact
//! inverse of `decode.rs`, and a round-trip property test keeps them honest.

use std::collections::HashMap;

#[derive(Debug, Clone)]
enum Emit {
    Word(u32),
    /// branch to label: (opcode template without imm, label)
    Branch(u32, String),
    /// jal rd, label
    Jal(u8, String),
    /// auipc+addi pair target (la rd, label) — resolved as pc-relative
    La(u8, String),
}

pub struct Asm {
    base: u32,
    items: Vec<Emit>,
    labels: HashMap<String, u32>,
}

fn enc_r(opcode: u32, f3: u32, f7: u32, rd: u8, rs1: u8, rs2: u8) -> u32 {
    (f7 << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((rd as u32) << 7)
        | opcode
}

fn enc_i(opcode: u32, f3: u32, rd: u8, rs1: u8, imm: i32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "I-imm out of range: {imm}");
    ((imm as u32 & 0xfff) << 20) | ((rs1 as u32) << 15) | (f3 << 12) | ((rd as u32) << 7) | opcode
}

fn enc_s(opcode: u32, f3: u32, rs1: u8, rs2: u8, imm: i32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "S-imm out of range: {imm}");
    let ui = imm as u32 & 0xfff;
    ((ui >> 5) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((ui & 0x1f) << 7)
        | opcode
}

fn enc_b(opcode: u32, f3: u32, rs1: u8, rs2: u8, imm: i32) -> u32 {
    assert!(imm % 2 == 0 && (-4096..=4094).contains(&imm), "B-imm out of range: {imm}");
    let ui = imm as u32;
    (((ui >> 12) & 1) << 31)
        | (((ui >> 5) & 0x3f) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | (((ui >> 1) & 0xf) << 8)
        | (((ui >> 11) & 1) << 7)
        | opcode
}

fn enc_u(opcode: u32, rd: u8, imm: i32) -> u32 {
    (imm as u32 & 0xFFFF_F000) | ((rd as u32) << 7) | opcode
}

fn enc_j(opcode: u32, rd: u8, imm: i32) -> u32 {
    assert!(imm % 2 == 0 && (-(1 << 20)..(1 << 20)).contains(&imm), "J-imm out of range: {imm}");
    let ui = imm as u32;
    (((ui >> 20) & 1) << 31)
        | (((ui >> 1) & 0x3ff) << 21)
        | (((ui >> 11) & 1) << 20)
        | (((ui >> 12) & 0xff) << 12)
        | ((rd as u32) << 7)
        | opcode
}

impl Asm {
    pub fn new(base: u32) -> Self {
        Self { base, items: Vec::new(), labels: HashMap::new() }
    }

    fn pc(&self) -> u32 {
        // each Emit except La is one word; La is two
        let mut pc = self.base;
        for it in &self.items {
            pc += match it {
                Emit::La(..) => 8,
                _ => 4,
            };
        }
        pc
    }

    pub fn label(&mut self, name: &str) {
        let pc = self.pc();
        assert!(
            self.labels.insert(name.to_string(), pc).is_none(),
            "duplicate label {name}"
        );
    }

    fn word(&mut self, w: u32) {
        self.items.push(Emit::Word(w));
    }

    // ---- RV32I register/imm ops ----------------------------------------
    pub fn addi(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0010011, 0b000, rd, rs1, imm));
    }
    pub fn slti(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0010011, 0b010, rd, rs1, imm));
    }
    pub fn sltiu(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0010011, 0b011, rd, rs1, imm));
    }
    pub fn xori(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0010011, 0b100, rd, rs1, imm));
    }
    pub fn ori(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0010011, 0b110, rd, rs1, imm));
    }
    pub fn andi(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0010011, 0b111, rd, rs1, imm));
    }
    pub fn slli(&mut self, rd: u8, rs1: u8, shamt: u32) {
        self.word(enc_i(0b0010011, 0b001, rd, rs1, shamt as i32));
    }
    pub fn srli(&mut self, rd: u8, rs1: u8, shamt: u32) {
        self.word(enc_i(0b0010011, 0b101, rd, rs1, shamt as i32));
    }
    pub fn srai(&mut self, rd: u8, rs1: u8, shamt: u32) {
        self.word(enc_i(0b0010011, 0b101, rd, rs1, (shamt | 0x400) as i32));
    }

    pub fn add(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b000, 0, rd, rs1, rs2));
    }
    pub fn sub(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b000, 0b0100000, rd, rs1, rs2));
    }
    pub fn sll(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b001, 0, rd, rs1, rs2));
    }
    pub fn slt(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b010, 0, rd, rs1, rs2));
    }
    pub fn sltu(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b011, 0, rd, rs1, rs2));
    }
    pub fn xor(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b100, 0, rd, rs1, rs2));
    }
    pub fn srl(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b101, 0, rd, rs1, rs2));
    }
    pub fn sra(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b101, 0b0100000, rd, rs1, rs2));
    }
    pub fn or(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b110, 0, rd, rs1, rs2));
    }
    pub fn and(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b111, 0, rd, rs1, rs2));
    }

    // ---- M extension ----------------------------------------------------
    pub fn mul(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b000, 1, rd, rs1, rs2));
    }
    pub fn mulh(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b001, 1, rd, rs1, rs2));
    }
    pub fn mulhu(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b011, 1, rd, rs1, rs2));
    }
    pub fn div(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b100, 1, rd, rs1, rs2));
    }
    pub fn divu(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b101, 1, rd, rs1, rs2));
    }
    pub fn rem(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b110, 1, rd, rs1, rs2));
    }
    pub fn remu(&mut self, rd: u8, rs1: u8, rs2: u8) {
        self.word(enc_r(0b0110011, 0b111, 1, rd, rs1, rs2));
    }

    // ---- memory ----------------------------------------------------------
    pub fn lw(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0000011, 0b010, rd, rs1, imm));
    }
    pub fn lh(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0000011, 0b001, rd, rs1, imm));
    }
    pub fn lhu(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0000011, 0b101, rd, rs1, imm));
    }
    pub fn lb(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0000011, 0b000, rd, rs1, imm));
    }
    pub fn lbu(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b0000011, 0b100, rd, rs1, imm));
    }
    pub fn sw(&mut self, rs1: u8, rs2: u8, imm: i32) {
        self.word(enc_s(0b0100011, 0b010, rs1, rs2, imm));
    }
    pub fn sh(&mut self, rs1: u8, rs2: u8, imm: i32) {
        self.word(enc_s(0b0100011, 0b001, rs1, rs2, imm));
    }
    pub fn sb(&mut self, rs1: u8, rs2: u8, imm: i32) {
        self.word(enc_s(0b0100011, 0b000, rs1, rs2, imm));
    }

    // ---- control flow -----------------------------------------------------
    pub fn lui(&mut self, rd: u8, imm: i32) {
        self.word(enc_u(0b0110111, rd, imm));
    }
    pub fn auipc(&mut self, rd: u8, imm: i32) {
        self.word(enc_u(0b0010111, rd, imm));
    }
    pub fn jalr(&mut self, rd: u8, rs1: u8, imm: i32) {
        self.word(enc_i(0b1100111, 0b000, rd, rs1, imm));
    }
    pub fn jal_label(&mut self, rd: u8, label: &str) {
        self.items.push(Emit::Jal(rd, label.to_string()));
    }

    fn branch(&mut self, f3: u32, rs1: u8, rs2: u8, label: &str) {
        let template = enc_b(0b1100011, f3, rs1, rs2, 0);
        self.items.push(Emit::Branch(template, label.to_string()));
    }
    pub fn beq(&mut self, rs1: u8, rs2: u8, label: &str) {
        self.branch(0b000, rs1, rs2, label);
    }
    pub fn bne(&mut self, rs1: u8, rs2: u8, label: &str) {
        self.branch(0b001, rs1, rs2, label);
    }
    pub fn blt(&mut self, rs1: u8, rs2: u8, label: &str) {
        self.branch(0b100, rs1, rs2, label);
    }
    pub fn bge(&mut self, rs1: u8, rs2: u8, label: &str) {
        self.branch(0b101, rs1, rs2, label);
    }
    pub fn bltu(&mut self, rs1: u8, rs2: u8, label: &str) {
        self.branch(0b110, rs1, rs2, label);
    }
    pub fn bgeu(&mut self, rs1: u8, rs2: u8, label: &str) {
        self.branch(0b111, rs1, rs2, label);
    }

    pub fn ecall(&mut self) {
        self.word(0x0000_0073);
    }
    pub fn ebreak(&mut self) {
        self.word(0x0010_0073);
    }
    pub fn nop(&mut self) {
        self.addi(0, 0, 0);
    }

    // ---- pseudo-instructions ----------------------------------------------
    /// Load 32-bit immediate (lui+addi, or single addi when it fits).
    pub fn li(&mut self, rd: u8, value: i32) {
        if (-2048..=2047).contains(&value) {
            self.addi(rd, 0, value);
            // keep a fixed 2-word footprint so pc() stays simple? No —
            // pc() recomputes per item, single word is fine.
        } else {
            let lo = (value << 20) >> 20; // low 12, sign-extended
            let hi = value.wrapping_sub(lo);
            self.lui(rd, hi);
            if lo != 0 {
                self.addi(rd, rd, lo);
            }
        }
    }

    /// mv rd, rs
    pub fn mv(&mut self, rd: u8, rs: u8) {
        self.addi(rd, rs, 0);
    }

    /// j label
    pub fn j(&mut self, label: &str) {
        self.jal_label(0, label);
    }

    /// call label (ra = x1)
    pub fn call(&mut self, label: &str) {
        self.jal_label(1, label);
    }

    /// ret
    pub fn ret(&mut self) {
        self.jalr(0, 1, 0);
    }

    /// la rd, label (auipc + addi, pc-relative)
    pub fn la(&mut self, rd: u8, label: &str) {
        self.items.push(Emit::La(rd, label.to_string()));
    }

    /// exit with code already in a0 (x10): a7 = 93; ecall
    pub fn exit(&mut self) {
        self.li(17, 93);
        self.ecall();
    }

    /// Resolve labels and produce the little-endian byte image.
    pub fn assemble(&self) -> Vec<u8> {
        // first pass: compute pc of every item
        let mut pcs = Vec::with_capacity(self.items.len());
        let mut pc = self.base;
        for it in &self.items {
            pcs.push(pc);
            pc += match it {
                Emit::La(..) => 8,
                _ => 4,
            };
        }
        let resolve = |label: &str| -> u32 {
            *self
                .labels
                .get(label)
                .unwrap_or_else(|| panic!("undefined label `{label}`"))
        };
        let mut out: Vec<u8> = Vec::with_capacity(pc as usize - self.base as usize);
        for (it, &at) in self.items.iter().zip(&pcs) {
            match it {
                Emit::Word(w) => out.extend_from_slice(&w.to_le_bytes()),
                Emit::Branch(template, label) => {
                    let off = resolve(label) as i64 - at as i64;
                    let f3 = (template >> 12) & 7;
                    let rs1 = ((template >> 15) & 0x1f) as u8;
                    let rs2 = ((template >> 20) & 0x1f) as u8;
                    let w = enc_b(0b1100011, f3, rs1, rs2, off as i32);
                    out.extend_from_slice(&w.to_le_bytes());
                }
                Emit::Jal(rd, label) => {
                    let off = resolve(label) as i64 - at as i64;
                    let w = enc_j(0b1101111, *rd, off as i32);
                    out.extend_from_slice(&w.to_le_bytes());
                }
                Emit::La(rd, label) => {
                    let target = resolve(label) as i64;
                    let off = target - at as i64;
                    let lo = ((off << 52) >> 52) as i32; // low 12 sign-extended
                    let hi = (off as i32).wrapping_sub(lo);
                    out.extend_from_slice(&enc_u(0b0010111, *rd, hi).to_le_bytes());
                    out.extend_from_slice(
                        &enc_i(0b0010011, 0b000, *rd, *rd, lo).to_le_bytes(),
                    );
                }
            }
        }
        out
    }

    /// Number of bytes the program will occupy.
    pub fn len_bytes(&self) -> u32 {
        self.pc() - self.base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::riscv::decode::{decode, Instr};
    use crate::util::proptest::forall;

    #[test]
    fn encode_decode_roundtrip_alu() {
        let mut a = Asm::new(0);
        a.add(1, 2, 3);
        a.sub(4, 5, 6);
        a.xori(7, 8, -100);
        a.srai(9, 10, 7);
        let img = a.assemble();
        let words: Vec<u32> = img
            .chunks(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert!(matches!(decode(words[0]).unwrap(), Instr::Op { .. }));
        assert!(matches!(decode(words[2]).unwrap(), Instr::OpImm { imm: -100, .. }));
    }

    #[test]
    fn li_small_and_large() {
        for val in [0i32, 5, -5, 2047, -2048, 2048, 0x1234_5678, -1, i32::MIN, i32::MAX] {
            let mut a = Asm::new(0);
            a.li(5, val);
            let img = a.assemble();
            // emulate
            let mut reg5 = 0i64;
            for c in img.chunks(4) {
                let w = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
                match decode(w).unwrap() {
                    Instr::Lui { imm, .. } => reg5 = imm as i64,
                    Instr::OpImm { imm, .. } => reg5 = (reg5 as i32).wrapping_add(imm) as i64,
                    other => panic!("unexpected {other:?}"),
                }
            }
            assert_eq!(reg5 as i32, val, "li {val}");
        }
    }

    #[test]
    fn branch_offsets_resolve_forward_and_back() {
        let mut a = Asm::new(0x100);
        a.label("top");
        a.nop();
        a.beq(0, 0, "end"); // forward
        a.bne(1, 2, "top"); // backward
        a.label("end");
        a.nop();
        let img = a.assemble();
        let w1 = u32::from_le_bytes(img[4..8].try_into().unwrap());
        let w2 = u32::from_le_bytes(img[8..12].try_into().unwrap());
        match decode(w1).unwrap() {
            Instr::Branch { imm, .. } => assert_eq!(imm, 8),
            o => panic!("{o:?}"),
        }
        match decode(w2).unwrap() {
            Instr::Branch { imm, .. } => assert_eq!(imm, -8),
            o => panic!("{o:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "undefined label")]
    fn undefined_label_panics() {
        let mut a = Asm::new(0);
        a.j("nowhere");
        a.assemble();
    }

    #[test]
    fn roundtrip_property_random_rtype() {
        forall("rtype-roundtrip", 200, |rng| {
            let rd = rng.int_in(0, 31) as u8;
            let rs1 = rng.int_in(0, 31) as u8;
            let rs2 = rng.int_in(0, 31) as u8;
            let mut a = Asm::new(0);
            a.and(rd, rs1, rs2);
            let img = a.assemble();
            let w = u32::from_le_bytes(img[0..4].try_into().unwrap());
            match decode(w).unwrap() {
                Instr::Op { rd: d, rs1: s1, rs2: s2, .. } => {
                    crate::prop_assert!(d == rd && s1 == rs1 && s2 == rs2, "field mismatch");
                    Ok(())
                }
                other => Err(format!("decoded {other:?}")),
            }
        });
    }

    #[test]
    fn roundtrip_property_random_imm() {
        forall("imm-roundtrip", 200, |rng| {
            let rd = rng.int_in(1, 31) as u8;
            let rs1 = rng.int_in(0, 31) as u8;
            let imm = rng.int_in(-2048, 2047) as i32;
            let mut a = Asm::new(0);
            a.addi(rd, rs1, imm);
            a.lw(rd, rs1, imm);
            a.sw(rs1, rd, imm);
            let img = a.assemble();
            let words: Vec<u32> = img
                .chunks(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            match decode(words[0]).unwrap() {
                Instr::OpImm { imm: i, .. } => crate::prop_assert!(i == imm, "addi {i}!={imm}"),
                o => return Err(format!("{o:?}")),
            }
            match decode(words[1]).unwrap() {
                Instr::Load { imm: i, .. } => crate::prop_assert!(i == imm, "lw {i}!={imm}"),
                o => return Err(format!("{o:?}")),
            }
            match decode(words[2]).unwrap() {
                Instr::Store { imm: i, .. } => crate::prop_assert!(i == imm, "sw {i}!={imm}"),
                o => return Err(format!("{o:?}")),
            }
            Ok(())
        });
    }
}
