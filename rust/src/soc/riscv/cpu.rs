//! RV32IM instruction-set simulator — the A-core stand-in that executes the
//! BISC firmware against the memory-mapped CIM device (paper Section III-A
//! / VI). Single hart, in-order, with cycle accounting per instruction
//! class so firmware latency (Alg. 1 overhead) can be reported.

use super::decode::{decode, AluOp, BranchOp, Instr, LoadOp, MulDivOp, StoreOp};
use crate::soc::bus::{Axi4LiteBus, BusResp};

/// Why the CPU stopped.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Halt {
    /// ECALL with a7 = 93 (exit), a0 = exit code — Linux-style convention.
    Exit(u32),
    /// EBREAK hit.
    Break,
    /// Instruction limit reached (runaway guard).
    StepLimit,
    /// Decode or bus fault.
    Fault(String),
}

/// Per-class cycle costs (simple in-order model: base 1 cycle, memory adds
/// bus latency, mul/div multi-cycle as in small embedded cores).
#[derive(Debug, Clone, Copy)]
pub struct CycleModel {
    pub base: u64,
    pub mul: u64,
    pub div: u64,
    pub branch_taken_penalty: u64,
}

impl Default for CycleModel {
    fn default() -> Self {
        Self { base: 1, mul: 3, div: 19, branch_taken_penalty: 1 }
    }
}

pub struct Cpu {
    pub regs: [u32; 32],
    pub pc: u32,
    pub cycles: u64,
    pub instret: u64,
    pub cycle_model: CycleModel,
    /// ECALL log: (a7, a0) pairs for non-exit syscalls (e.g. putchar)
    pub ecalls: Vec<(u32, u32)>,
}

impl Cpu {
    pub fn new(pc: u32) -> Self {
        Self {
            regs: [0; 32],
            pc,
            cycles: 0,
            instret: 0,
            cycle_model: CycleModel::default(),
            ecalls: Vec::new(),
        }
    }

    #[inline]
    fn rg(&self, r: u8) -> u32 {
        self.regs[r as usize]
    }

    #[inline]
    fn wg(&mut self, r: u8, v: u32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    fn load(&mut self, bus: &mut Axi4LiteBus, op: LoadOp, addr: u32) -> Result<u32, String> {
        let word_addr = addr & !3;
        let word = bus
            .read32(word_addr)
            .map_err(|e| format!("load fault at {addr:#010x}: {e:?}"))?;
        let shift = (addr & 3) * 8;
        Ok(match op {
            LoadOp::Lw => {
                if addr & 3 != 0 {
                    return Err(format!("misaligned LW at {addr:#010x}"));
                }
                word
            }
            LoadOp::Lh | LoadOp::Lhu => {
                if addr & 1 != 0 {
                    return Err(format!("misaligned LH at {addr:#010x}"));
                }
                let half = (word >> shift) & 0xffff;
                if op == LoadOp::Lh {
                    (half as u16 as i16 as i32) as u32
                } else {
                    half
                }
            }
            LoadOp::Lb | LoadOp::Lbu => {
                let byte = (word >> shift) & 0xff;
                if op == LoadOp::Lb {
                    (byte as u8 as i8 as i32) as u32
                } else {
                    byte
                }
            }
        })
    }

    fn store(
        &mut self,
        bus: &mut Axi4LiteBus,
        op: StoreOp,
        addr: u32,
        value: u32,
    ) -> Result<(), String> {
        let word_addr = addr & !3;
        let err = |e: BusResp| format!("store fault at {addr:#010x}: {e:?}");
        match op {
            StoreOp::Sw => {
                if addr & 3 != 0 {
                    return Err(format!("misaligned SW at {addr:#010x}"));
                }
                bus.write32(word_addr, value).map_err(err)
            }
            StoreOp::Sh => {
                if addr & 1 != 0 {
                    return Err(format!("misaligned SH at {addr:#010x}"));
                }
                let old = bus.read32(word_addr).map_err(err)?;
                let shift = (addr & 2) * 8;
                let mask = 0xffffu32 << shift;
                let new = (old & !mask) | ((value & 0xffff) << shift);
                bus.write32(word_addr, new).map_err(err)
            }
            StoreOp::Sb => {
                let old = bus.read32(word_addr).map_err(err)?;
                let shift = (addr & 3) * 8;
                let mask = 0xffu32 << shift;
                let new = (old & !mask) | ((value & 0xff) << shift);
                bus.write32(word_addr, new).map_err(err)
            }
        }
    }

    fn alu(op: AluOp, a: u32, b: u32) -> u32 {
        match op {
            AluOp::Add => a.wrapping_add(b),
            AluOp::Sub => a.wrapping_sub(b),
            AluOp::Sll => a.wrapping_shl(b & 0x1f),
            AluOp::Slt => ((a as i32) < (b as i32)) as u32,
            AluOp::Sltu => (a < b) as u32,
            AluOp::Xor => a ^ b,
            AluOp::Srl => a.wrapping_shr(b & 0x1f),
            AluOp::Sra => ((a as i32).wrapping_shr(b & 0x1f)) as u32,
            AluOp::Or => a | b,
            AluOp::And => a & b,
        }
    }

    fn muldiv(op: MulDivOp, a: u32, b: u32) -> u32 {
        let (sa, sb) = (a as i32, b as i32);
        match op {
            MulDivOp::Mul => a.wrapping_mul(b),
            MulDivOp::Mulh => (((sa as i64) * (sb as i64)) >> 32) as u32,
            MulDivOp::Mulhsu => (((sa as i64) * (b as u64 as i64)) >> 32) as u32,
            MulDivOp::Mulhu => (((a as u64) * (b as u64)) >> 32) as u32,
            MulDivOp::Div => {
                if b == 0 {
                    u32::MAX
                } else if sa == i32::MIN && sb == -1 {
                    sa as u32
                } else {
                    (sa / sb) as u32
                }
            }
            MulDivOp::Divu => {
                if b == 0 {
                    u32::MAX
                } else {
                    a / b
                }
            }
            MulDivOp::Rem => {
                if b == 0 {
                    a
                } else if sa == i32::MIN && sb == -1 {
                    0
                } else {
                    (sa % sb) as u32
                }
            }
            MulDivOp::Remu => {
                if b == 0 {
                    a
                } else {
                    a % b
                }
            }
        }
    }

    /// Execute one instruction; returns Some(halt) when stopped.
    pub fn step(&mut self, bus: &mut Axi4LiteBus) -> Option<Halt> {
        let word = match bus.read32(self.pc) {
            Ok(w) => w,
            Err(e) => return Some(Halt::Fault(format!("fetch fault at {:#010x}: {e:?}", self.pc))),
        };
        // instruction fetch in a real core is on a separate port/ICache —
        // don't double-count it in the AXI data-transaction stats
        bus.cycles -= bus.timing.per_transaction();
        bus.reads -= 1;

        let instr = match decode(word) {
            Ok(i) => i,
            Err(e) => {
                return Some(Halt::Fault(format!(
                    "illegal instruction {:#010x} at {:#010x}",
                    e.word, self.pc
                )))
            }
        };
        let mut next_pc = self.pc.wrapping_add(4);
        let cm = self.cycle_model;
        self.cycles += cm.base;
        self.instret += 1;

        match instr {
            Instr::Lui { rd, imm } => self.wg(rd, imm as u32),
            Instr::Auipc { rd, imm } => self.wg(rd, self.pc.wrapping_add(imm as u32)),
            Instr::Jal { rd, imm } => {
                self.wg(rd, next_pc);
                next_pc = self.pc.wrapping_add(imm as u32);
                self.cycles += cm.branch_taken_penalty;
            }
            Instr::Jalr { rd, rs1, imm } => {
                let target = self.rg(rs1).wrapping_add(imm as u32) & !1;
                self.wg(rd, next_pc);
                next_pc = target;
                self.cycles += cm.branch_taken_penalty;
            }
            Instr::Branch { op, rs1, rs2, imm } => {
                let (a, b) = (self.rg(rs1), self.rg(rs2));
                let taken = match op {
                    BranchOp::Beq => a == b,
                    BranchOp::Bne => a != b,
                    BranchOp::Blt => (a as i32) < (b as i32),
                    BranchOp::Bge => (a as i32) >= (b as i32),
                    BranchOp::Bltu => a < b,
                    BranchOp::Bgeu => a >= b,
                };
                if taken {
                    next_pc = self.pc.wrapping_add(imm as u32);
                    self.cycles += cm.branch_taken_penalty;
                }
            }
            Instr::Load { op, rd, rs1, imm } => {
                let addr = self.rg(rs1).wrapping_add(imm as u32);
                match self.load(bus, op, addr) {
                    Ok(v) => self.wg(rd, v),
                    Err(e) => return Some(Halt::Fault(e)),
                }
            }
            Instr::Store { op, rs1, rs2, imm } => {
                let addr = self.rg(rs1).wrapping_add(imm as u32);
                let v = self.rg(rs2);
                if let Err(e) = self.store(bus, op, addr, v) {
                    return Some(Halt::Fault(e));
                }
            }
            Instr::OpImm { op, rd, rs1, imm } => {
                let v = Self::alu(op, self.rg(rs1), imm as u32);
                self.wg(rd, v);
            }
            Instr::Op { op, rd, rs1, rs2 } => {
                let v = Self::alu(op, self.rg(rs1), self.rg(rs2));
                self.wg(rd, v);
            }
            Instr::MulDiv { op, rd, rs1, rs2 } => {
                self.cycles += match op {
                    MulDivOp::Mul | MulDivOp::Mulh | MulDivOp::Mulhsu | MulDivOp::Mulhu => cm.mul,
                    _ => cm.div,
                };
                let v = Self::muldiv(op, self.rg(rs1), self.rg(rs2));
                self.wg(rd, v);
            }
            Instr::Fence => {}
            Instr::Ecall => {
                let a7 = self.rg(17);
                let a0 = self.rg(10);
                if a7 == 93 {
                    return Some(Halt::Exit(a0));
                }
                self.ecalls.push((a7, a0));
            }
            Instr::Ebreak => return Some(Halt::Break),
        }
        self.pc = next_pc;
        None
    }

    /// Run until halt or `max_steps`.
    pub fn run(&mut self, bus: &mut Axi4LiteBus, max_steps: u64) -> Halt {
        for _ in 0..max_steps {
            if let Some(h) = self.step(bus) {
                return h;
            }
        }
        Halt::StepLimit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::bus::Ram;
    use crate::soc::riscv::asm::Asm;

    fn run_asm(build: impl FnOnce(&mut Asm)) -> (Cpu, Axi4LiteBus, Halt) {
        let mut a = Asm::new(0);
        build(&mut a);
        let code = a.assemble();
        let mut bus = Axi4LiteBus::new();
        let mut ram = Ram::new(0x1_0000, "ram");
        ram.load(0, &code);
        bus.map(0, Box::new(ram));
        let mut cpu = Cpu::new(0);
        let halt = cpu.run(&mut bus, 100_000);
        (cpu, bus, halt)
    }

    #[test]
    fn arithmetic_and_exit() {
        let (cpu, _, halt) = run_asm(|a| {
            a.li(10, 0); // a0
            a.li(5, 20);
            a.li(6, 22);
            a.add(10, 5, 6);
            a.exit();
        });
        assert_eq!(halt, Halt::Exit(42));
        assert_eq!(cpu.regs[10], 42);
    }

    #[test]
    fn x0_is_hardwired_zero() {
        let (cpu, _, _) = run_asm(|a| {
            a.li(0, 1234);
            a.li(10, 0);
            a.add(10, 0, 0);
            a.exit();
        });
        assert_eq!(cpu.regs[0], 0);
        assert_eq!(cpu.regs[10], 0);
    }

    #[test]
    fn loop_with_branch() {
        // sum 1..=10 into a0
        let (_, _, halt) = run_asm(|a| {
            a.li(10, 0);
            a.li(5, 1);
            a.li(6, 11);
            a.label("loop");
            a.add(10, 10, 5);
            a.addi(5, 5, 1);
            a.blt(5, 6, "loop");
            a.exit();
        });
        assert_eq!(halt, Halt::Exit(55));
    }

    #[test]
    fn memory_roundtrip_word_half_byte() {
        let (cpu, _, halt) = run_asm(|a| {
            a.li(5, 0x8000); // scratch address
            a.li(6, 0x1234_5678u32 as i32);
            a.sw(5, 6, 0);
            a.lw(7, 5, 0);
            a.lhu(8, 5, 0); // 0x5678
            a.lbu(9, 5, 1); // 0x56
            a.lb(28, 5, 3); // 0x12 sign-pos
            a.li(10, 0);
            a.add(10, 0, 8);
            a.exit();
        });
        assert_eq!(halt, Halt::Exit(0x5678));
        assert_eq!(cpu.regs[7], 0x1234_5678);
        assert_eq!(cpu.regs[9], 0x56);
        assert_eq!(cpu.regs[28], 0x12);
    }

    #[test]
    fn sb_sh_merge_into_word() {
        let (cpu, _, _) = run_asm(|a| {
            a.li(5, 0x8000);
            a.li(6, -1); // 0xFFFFFFFF
            a.sw(5, 6, 0);
            a.li(7, 0xAB);
            a.sb(5, 7, 2);
            a.lw(10, 5, 0);
            a.exit();
        });
        assert_eq!(cpu.regs[10], 0xFFAB_FFFF);
    }

    #[test]
    fn muldiv_semantics() {
        let (cpu, _, _) = run_asm(|a| {
            a.li(5, -7);
            a.li(6, 2);
            a.mul(7, 5, 6); // -14
            a.div(8, 5, 6); // -3 (trunc toward zero)
            a.rem(9, 5, 6); // -1
            a.li(28, 0);
            a.div(29, 5, 28); // div by zero -> -1 (all ones)
            a.exit();
        });
        assert_eq!(cpu.regs[7] as i32, -14);
        assert_eq!(cpu.regs[8] as i32, -3);
        assert_eq!(cpu.regs[9] as i32, -1);
        assert_eq!(cpu.regs[29], u32::MAX);
    }

    #[test]
    fn function_call_and_return() {
        let (_, _, halt) = run_asm(|a| {
            a.li(10, 5);
            a.call("double");
            a.call("double");
            a.exit(); // 20
            a.label("double");
            a.add(10, 10, 10);
            a.ret();
        });
        assert_eq!(halt, Halt::Exit(20));
    }

    #[test]
    fn shifts_signed_unsigned() {
        let (cpu, _, _) = run_asm(|a| {
            a.li(5, -16);
            a.srai(6, 5, 2); // -4
            a.srli(7, 5, 28); // 0xF
            a.slli(8, 5, 1); // -32
            a.exit();
        });
        assert_eq!(cpu.regs[6] as i32, -4);
        assert_eq!(cpu.regs[7], 0xF);
        assert_eq!(cpu.regs[8] as i32, -32);
    }

    #[test]
    fn fault_on_illegal_instruction() {
        let mut bus = Axi4LiteBus::new();
        let mut ram = Ram::new(0x100, "ram");
        ram.load(0, &[0xFF, 0xFF, 0xFF, 0xFF]);
        bus.map(0, Box::new(ram));
        let mut cpu = Cpu::new(0);
        match cpu.run(&mut bus, 10) {
            Halt::Fault(msg) => assert!(msg.contains("illegal instruction")),
            other => panic!("expected fault, got {other:?}"),
        }
    }

    #[test]
    fn step_limit_guard() {
        let (_, _, halt) = run_asm(|a| {
            a.label("spin");
            a.jal_label(0, "spin");
        });
        assert_eq!(halt, Halt::StepLimit);
    }

    #[test]
    fn cycle_counting_progresses() {
        let (cpu, _, _) = run_asm(|a| {
            a.li(5, 3);
            a.li(6, 4);
            a.mul(7, 5, 6);
            a.exit();
        });
        assert!(cpu.cycles > cpu.instret, "mul must cost extra cycles");
    }
}
