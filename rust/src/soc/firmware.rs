//! Firmware programs for the RV32IM core — most importantly the BISC
//! routine of Algorithm 1, expressed as actual RISC-V instructions driving
//! the CIM device over AXI4-Lite. This is the paper's headline property
//! ("fully controlled by the RISC-V core") made literal.
//!
//! The firmware works in integer fixed point:
//!   * ADC codes in Q4.4 ("q4" = code * 16) for the least-squares sums,
//!   * gains in Q12 ("q12" = gain * 4096),
//!   * voltages in microvolts.
//! The host prepares a parameter block (test vectors, nominal outputs,
//! ADC characterization, trim-DAC constants) at `map::PARAM_BLOCK`; the
//! firmware writes its per-column fits to a results block for inspection.
//! `coordinator::bisc::BiscEngine` is the f64 reference; the integration
//! test in `rust/tests/soc_bisc.rs` asserts trim agreement within 1 LSB.

use crate::analog::{consts as c, samp};
use crate::config::SimConfig;
use crate::coordinator::cim_core::regs;
use crate::coordinator::bisc::{AdcCharacterization, BiscEngine};
use crate::soc::memmap::map;
use crate::soc::riscv::asm::Asm;

/// Parameter-block layout (word offsets from map::PARAM_BLOCK).
pub mod pblk {
    /// number of test vectors Z (<= 16)
    pub const Z: u32 = 0x00;
    /// hardware averaging count per test point
    pub const AVG: u32 = 0x04;
    /// ADC gain alpha_D in Q12
    pub const ALPHA_Q12: u32 = 0x08;
    /// ADC offset beta_D in Q4.4 codes (signed)
    pub const BETA_D_Q4: u32 = 0x0C;
    /// microvolts per ADC code through the ADC gain, Q8.8:
    /// round(1e6 / (alpha_D * C_ADC) * 256)
    pub const UV_PER_CODE_Q8: u32 = 0x10;
    /// digital-pot ratio constants in Q12 (R_SA_MIN/R_SA_NOM, span)
    pub const POT_OFF_Q12: u32 = 0x14;
    pub const POT_SPAN_Q12: u32 = 0x18;
    /// widened ADC references for characterization [uV] (Alg. 1)
    pub const VADC_L_W_UV: u32 = 0x1C;
    pub const VADC_H_W_UV: u32 = 0x20;
    /// default (inference) ADC references [uV], restored at the end
    pub const VADC_L_UV: u32 = 0x24;
    pub const VADC_H_UV: u32 = 0x28;
    /// mid code at the widened references, Q4.4: C' * (V_CAL_NOM - V_L')
    pub const QMID_Q4: u32 = 0x2C;
    /// offset-correction base voltage [uV]:
    /// V_L' + ((V_CAL_NOM - V_L') - beta_D/C') / alpha_D
    pub const VCAL_BASE_UV: u32 = 0x30;
    /// cal-DAC range constants [uV]
    pub const VCAL_MIN_UV: u32 = 0x34;
    pub const VCAL_SPAN_UV: u32 = 0x38;
    /// test input codes, signed i32, X[0..16]
    pub const X: u32 = 0x40;
    /// nominal output codes for the positive line, Q4.4, QPOS[0..16]
    pub const QPOS_Q4: u32 = 0x80;
    /// nominal output codes for the negative line, Q4.4, QNEG[0..16]
    pub const QNEG_Q4: u32 = 0xC0;
    /// results block: per column {g_pos_q12, eps_pos_q4, g_neg_q12,
    /// eps_neg_q4}, 4 words per column
    pub const RESULTS: u32 = 0x1000;
}

/// Maximum Z the fixed-point sums support without overflow.
pub const Z_MAX: usize = 16;

/// Build the parameter block for the BISC firmware.
pub fn bisc_param_block(cfg: &SimConfig, adc_char: AdcCharacterization) -> Vec<u32> {
    let engine = BiscEngine::from_config(cfg, adc_char);
    let z = engine.test_points.min(Z_MAX);
    assert!(z >= 2, "need at least two test points");
    let (vl_w, vh_w) = engine.widened_refs();
    let c_adc_w = c::adc_conv_factor(vl_w, vh_w);
    let mut words = vec![0u32; (pblk::QNEG_Q4 / 4) as usize + Z_MAX];
    let set = |words: &mut Vec<u32>, off: u32, v: u32| words[(off / 4) as usize] = v;
    set(&mut words, pblk::Z, z as u32);
    set(&mut words, pblk::AVG, engine.averages as u32);
    set(&mut words, pblk::ALPHA_Q12, (adc_char.alpha_d * 4096.0).round() as u32);
    set(&mut words, pblk::BETA_D_Q4, (adc_char.beta_d * 16.0).round() as i32 as u32);
    set(
        &mut words,
        pblk::UV_PER_CODE_Q8,
        (1e6 / (adc_char.alpha_d * c_adc_w) * 256.0).round() as u32,
    );
    set(&mut words, pblk::VADC_L_W_UV, (vl_w * 1e6).round() as u32);
    set(&mut words, pblk::VADC_H_W_UV, (vh_w * 1e6).round() as u32);
    set(&mut words, pblk::VADC_L_UV, (c::V_ADC_L * 1e6).round() as u32);
    set(&mut words, pblk::VADC_H_UV, (c::V_ADC_H * 1e6).round() as u32);
    let q_mid_w = c_adc_w * (c::V_CAL_NOM - vl_w);
    set(&mut words, pblk::QMID_Q4, (q_mid_w * 16.0).round() as u32);
    let vcal_base =
        vl_w + ((c::V_CAL_NOM - vl_w) - adc_char.beta_d / c_adc_w) / adc_char.alpha_d;
    set(&mut words, pblk::VCAL_BASE_UV, (vcal_base * 1e6).round() as u32);
    set(&mut words, pblk::VCAL_MIN_UV, (samp::V_CAL_MIN * 1e6).round() as u32);
    set(
        &mut words,
        pblk::VCAL_SPAN_UV,
        ((samp::V_CAL_MAX - samp::V_CAL_MIN) * 1e6).round() as u32,
    );
    set(
        &mut words,
        pblk::POT_OFF_Q12,
        (samp::R_SA_MIN / c::R_SA_NOM * 4096.0).round() as u32,
    );
    set(
        &mut words,
        pblk::POT_SPAN_Q12,
        ((samp::R_SA_MAX - samp::R_SA_MIN) / c::R_SA_NOM * 4096.0).round() as u32,
    );
    let codes = engine.test_codes();
    let qpos = engine.nominal_codes(true);
    let qneg = engine.nominal_codes(false);
    for t in 0..z {
        set(&mut words, pblk::X + 4 * t as u32, codes[t] as u32);
        set(&mut words, pblk::QPOS_Q4 + 4 * t as u32, (qpos[t] * 16.0).round() as i32 as u32);
        set(&mut words, pblk::QNEG_Q4 + 4 * t as u32, (qneg[t] * 16.0).round() as i32 as u32);
    }
    words
}

/// Assemble the BISC firmware (Algorithm 1).
///
/// Register allocation:
///   x5  CIM base          x8  param base       x9  column index
///   x18 weight code (+/-63)  x19..x22 LSQ sums Sx Sy Sxy Sxx
///   x23 t loop            x24 Z                x25 addr scratch
///   x26 g_q12             x27 eps_q4           x29 eps_pos_q4 save
///   x30 line (0 pos / 1 neg)  x6, x7, x28, x31 scratch
pub fn bisc_program() -> Vec<u8> {
    let mut a = Asm::new(map::ENTRY);
    let cim = map::CIM_BASE as i32;
    let _ = cim;
    a.li(5, map::CIM_BASE as i32);
    a.li(8, map::PARAM_BLOCK as i32);
    // AVG_CNT <- param
    a.lw(6, 8, pblk::AVG as i32);
    a.sw(5, 6, regs::AVG_CNT as i32);
    // widen the ADC references for characterization (Alg. 1)
    a.lw(6, 8, pblk::VADC_L_W_UV as i32);
    a.sw(5, 6, regs::VADC_L_UV as i32);
    a.lw(6, 8, pblk::VADC_H_W_UV as i32);
    a.sw(5, 6, regs::VADC_H_UV as i32);
    a.lw(24, 8, pblk::Z as i32); // x24 = Z
    a.li(9, 0); // col = 0

    a.label("col_loop");
    a.li(30, 0); // line = 0 (positive)

    a.label("line_loop");
    // x18 = +63 or -63
    a.li(18, 63);
    a.beq(30, 0, "wsign_done");
    a.li(18, -63);
    a.label("wsign_done");

    // ---- program column: cells at row*M + col, row = 0..N ----
    a.li(7, 0); // row
    a.label("prog_loop");
    a.slli(6, 7, 5); // row * 32
    a.add(6, 6, 9); // + col
    a.sw(5, 6, regs::WADDR as i32);
    a.sw(5, 18, regs::WDATA as i32);
    a.addi(7, 7, 1);
    a.li(6, c::N_ROWS as i32);
    a.blt(7, 6, "prog_loop");

    // ---- zero LSQ sums ----
    a.li(19, 0); // Sx
    a.li(20, 0); // Sy
    a.li(21, 0); // Sxy
    a.li(22, 0); // Sxx
    a.li(23, 0); // t

    a.label("t_loop");
    // x6 = X[t]
    a.slli(25, 23, 2);
    a.add(25, 25, 8);
    a.lw(6, 25, pblk::X as i32);
    // write all N input registers
    a.li(7, 0);
    a.li(28, (map::CIM_BASE + regs::INPUT) as i32);
    a.label("in_loop");
    a.sw(28, 6, 0);
    a.addi(28, 28, 4);
    a.addi(7, 7, 1);
    a.li(31, c::N_ROWS as i32);
    a.blt(7, 31, "in_loop");
    // CTRL = 2 (averaged MAC)
    a.li(6, 2);
    a.sw(5, 6, regs::CTRL as i32);
    // y_q4 = OUT_AVG_Q8[col] >> 4
    a.slli(6, 9, 2);
    a.add(6, 6, 5);
    a.lw(7, 6, regs::OUT_AVG_Q8 as i32);
    a.srli(7, 7, 4); // Q8.8 -> Q4.4 (y >= 0)
    // a_q4 = QPOS_Q4[t] or QNEG_Q4[t] (x30 selects)
    a.slli(6, 23, 2);
    a.add(6, 6, 8);
    a.beq(30, 0, "use_pos_table");
    a.lw(28, 6, pblk::QNEG_Q4 as i32);
    a.j("table_done");
    a.label("use_pos_table");
    a.lw(28, 6, pblk::QPOS_Q4 as i32);
    a.label("table_done");
    // accumulate sums
    a.add(19, 19, 28); // Sx += a
    a.add(20, 20, 7); // Sy += y
    a.mul(6, 28, 7);
    a.add(21, 21, 6); // Sxy += a*y
    a.mul(6, 28, 28);
    a.add(22, 22, 6); // Sxx += a*a
    a.addi(23, 23, 1);
    a.blt(23, 24, "t_loop");

    // ---- least-squares fit (Eq. 13-14) ----
    // num = Z*Sxy - Sx*Sy ; den = Z*Sxx - Sx*Sx
    a.mul(6, 24, 21);
    a.mul(7, 19, 20);
    a.sub(6, 6, 7); // num
    a.mul(7, 24, 22);
    a.mul(28, 19, 19);
    a.sub(7, 7, 28); // den
    // normalize so num << 12 cannot overflow: while |num| >= 2^17 shift both
    a.label("norm_loop");
    a.bge(6, 0, "norm_abs_done");
    a.sub(31, 0, 6);
    a.j("norm_cmp");
    a.label("norm_abs_done");
    a.mv(31, 6);
    a.label("norm_cmp");
    a.li(28, 1 << 17);
    a.blt(31, 28, "norm_done");
    a.srai(6, 6, 1);
    a.srai(7, 7, 1);
    a.j("norm_loop");
    a.label("norm_done");
    // g_q12 = (num << 12) / den
    a.slli(6, 6, 12);
    a.div(26, 6, 7); // x26 = g_q12
    // eps_q4 = (Sy - (g_q12 * Sx >> 12)) / Z
    a.mul(6, 26, 19);
    a.srai(6, 6, 12);
    a.sub(6, 20, 6);
    a.div(27, 6, 24); // x27 = eps_q4

    // store results: RESULTS + col*16 + line*8 -> {g_q12, eps_q4}
    a.slli(6, 9, 4);
    a.slli(7, 30, 3);
    a.add(6, 6, 7);
    a.add(6, 6, 8);
    a.li(31, pblk::RESULTS as i32); // offset exceeds the 12-bit S-imm
    a.add(6, 6, 31);
    a.sw(6, 26, 0);
    a.sw(6, 27, 4);

    // ---- gain correction (Eq. 12): pot = ((alpha<<12)/g - off)*255/span
    a.lw(6, 8, pblk::ALPHA_Q12 as i32);
    a.slli(6, 6, 12);
    a.div(6, 6, 26); // ratio_q12
    a.lw(7, 8, pblk::POT_OFF_Q12 as i32);
    a.sub(6, 6, 7);
    a.li(7, 255);
    a.mul(6, 6, 7);
    a.lw(7, 8, pblk::POT_SPAN_Q12 as i32);
    a.div(6, 6, 7); // pot code
    // clamp 0..255
    a.bge(6, 0, "pot_not_neg");
    a.li(6, 0);
    a.label("pot_not_neg");
    a.li(7, 255);
    a.bge(7, 6, "pot_not_big");
    a.mv(6, 7);
    a.label("pot_not_big");
    // write POT_P[col] or POT_N[col]
    a.slli(7, 9, 2);
    a.add(7, 7, 5);
    a.beq(30, 0, "write_pot_p");
    a.sw(7, 6, regs::POT_N as i32);
    a.j("pot_written");
    a.label("write_pot_p");
    a.sw(7, 6, regs::POT_P as i32);
    a.label("pot_written");

    // line bookkeeping: save eps_pos + g_pos, loop to negative line
    a.beq(30, 0, "save_pos_fit");
    a.j("lines_done");
    a.label("save_pos_fit");
    a.mv(29, 27); // x29 = eps_pos_q4
    a.mv(15, 26); // x15 = g_pos_q12
    a.li(30, 1);
    a.j("line_loop");
    a.label("lines_done");

    // ---- offset correction: pivot-corrected (see bisc.rs::calibrate) ----
    // eps_avg_q4 = (eps_pos + eps_neg) >> 1  (arithmetic)
    a.add(6, 29, 27);
    a.srai(6, 6, 1);
    // g_avg_q12 = (g_pos + g_neg) >> 1
    a.add(7, 15, 26);
    a.srai(7, 7, 1);
    // pivot_q4 = (qmid_q4 * (alpha_q12 - g_avg_q12)) >> 12
    a.lw(28, 8, pblk::ALPHA_Q12 as i32);
    a.sub(7, 28, 7);
    a.lw(28, 8, pblk::QMID_Q4 as i32);
    a.mul(7, 7, 28);
    a.srai(7, 7, 12);
    a.sub(6, 6, 7); // eps - pivot
    // beta_num_q4 = eps - pivot - beta_d_q4
    a.lw(7, 8, pblk::BETA_D_Q4 as i32);
    a.sub(6, 6, 7);
    // beta_a_uv = (beta_num_q4 * uv_per_code_q8) >> 12
    a.lw(7, 8, pblk::UV_PER_CODE_Q8 as i32);
    a.mul(6, 6, 7);
    a.srai(6, 6, 12);
    // vtarget_uv = VCAL_BASE_UV - beta_a_uv
    a.lw(7, 8, pblk::VCAL_BASE_UV as i32);
    a.sub(6, 7, 6);
    // cal = (vtarget_uv - VCAL_MIN_UV) * 63 / VCAL_SPAN_UV
    a.lw(7, 8, pblk::VCAL_MIN_UV as i32);
    a.sub(6, 6, 7);
    a.li(7, 63);
    a.mul(6, 6, 7);
    a.lw(7, 8, pblk::VCAL_SPAN_UV as i32);
    a.div(6, 6, 7);
    // clamp 0..63
    a.bge(6, 0, "cal_not_neg");
    a.li(6, 0);
    a.label("cal_not_neg");
    a.li(7, 63);
    a.bge(7, 6, "cal_not_big");
    a.mv(6, 7);
    a.label("cal_not_big");
    a.slli(7, 9, 2);
    a.add(7, 7, 5);
    a.sw(7, 6, regs::CAL as i32);

    // next column
    a.addi(9, 9, 1);
    a.li(6, c::M_COLS as i32);
    a.blt(9, 6, "col_loop");

    // restore the inference ADC references (Alg. 1 epilogue)
    a.lw(6, 8, pblk::VADC_L_UV as i32);
    a.sw(5, 6, regs::VADC_L_UV as i32);
    a.lw(6, 8, pblk::VADC_H_UV as i32);
    a.sw(5, 6, regs::VADC_H_UV as i32);

    a.li(10, 0);
    a.exit();
    a.assemble()
}

/// A small self-test firmware: runs one MAC with the given input code on
/// all rows and returns OUT[0] (used by examples and SoC smoke tests).
pub fn mac_probe_program(input_code: i32) -> Vec<u8> {
    let mut a = Asm::new(map::ENTRY);
    a.li(5, map::CIM_BASE as i32);
    a.li(6, input_code);
    a.li(7, 0);
    a.li(28, (map::CIM_BASE + regs::INPUT) as i32);
    a.label("in_loop");
    a.sw(28, 6, 0);
    a.addi(28, 28, 4);
    a.addi(7, 7, 1);
    a.li(31, c::N_ROWS as i32);
    a.blt(7, 31, "in_loop");
    a.li(6, 1);
    a.sw(5, 6, regs::CTRL as i32);
    a.lw(10, 5, regs::OUT as i32);
    a.exit();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::variation::VariationSample;
    use crate::analog::CimAnalogModel;
    use crate::soc::memmap::Soc;
    use crate::soc::riscv::cpu::Halt;

    #[test]
    fn param_block_layout_sane() {
        let cfg = SimConfig::default();
        let blk = bisc_param_block(&cfg, AdcCharacterization::ideal());
        assert_eq!(blk[(pblk::Z / 4) as usize], cfg.bisc_test_points as u32);
        assert_eq!(blk[(pblk::ALPHA_Q12 / 4) as usize], 4096);
        // uv per code at alpha=1 and widened refs:
        // C' = 63/(0.6*1.08 - 0.2*0.92), uv = 1e6/C' * 256
        let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
        let (vl_w, vh_w) = engine.widened_refs();
        let c_adc_w = crate::analog::consts::adc_conv_factor(vl_w, vh_w);
        let uv = blk[(pblk::UV_PER_CODE_Q8 / 4) as usize];
        assert!((uv as f64 - 1e6 / c_adc_w * 256.0).abs() < 2.0, "uv={uv}");
    }

    #[test]
    fn firmware_assembles() {
        let img = bisc_program();
        assert!(img.len() > 400, "suspiciously small: {}", img.len());
        assert_eq!(img.len() % 4, 0);
    }

    #[test]
    fn bisc_firmware_calibrates_a_noisy_die() {
        let mut cfg = SimConfig::default();
        cfg.seed = 0xF1A5;
        cfg.sigma_noise = 0.0; // determinism for the comparison below
        let sample = VariationSample::draw(&cfg);
        let model = CimAnalogModel::from_sample(&cfg, &sample);
        let mut soc = Soc::new(model);
        soc.load_program(&bisc_program());
        soc.write_words(
            map::PARAM_BLOCK,
            &bisc_param_block(&cfg, AdcCharacterization::ideal()),
        );
        let halt = soc.run(500_000_000);
        assert_eq!(halt, Halt::Exit(0), "firmware crashed: {halt:?}");

        // compare firmware trims against the host BISC engine on an
        // identical die
        let mut host_model = CimAnalogModel::from_sample(&cfg, &sample);
        let engine = BiscEngine::from_config(&cfg, AdcCharacterization::ideal());
        let report = engine.calibrate(&mut host_model);
        let dev = soc.cim_mut();
        let mut pot_diffs = Vec::new();
        let mut cal_diffs = Vec::new();
        for cc in &report.columns {
            let fw_pot_p = dev.model.amps[cc.col].pot_p as i64;
            let fw_pot_n = dev.model.amps[cc.col].pot_n as i64;
            let fw_cal = dev.model.amps[cc.col].cal as i64;
            pot_diffs.push((fw_pot_p - cc.pot_p as i64).abs());
            pot_diffs.push((fw_pot_n - cc.pot_n as i64).abs());
            cal_diffs.push((fw_cal - cc.cal as i64).abs());
        }
        let max_pot = *pot_diffs.iter().max().unwrap();
        let max_cal = *cal_diffs.iter().max().unwrap();
        assert!(max_pot <= 2, "pot code mismatch up to {max_pot}");
        assert!(max_cal <= 1, "cal code mismatch up to {max_cal}");
    }

    #[test]
    fn mac_probe_runs() {
        let mut soc = Soc::new(CimAnalogModel::ideal());
        soc.cim_mut().program_weights(&vec![63; c::N_ROWS * c::M_COLS]);
        soc.load_program(&mac_probe_program(63));
        assert_eq!(soc.run(100_000), Halt::Exit(62));
    }
}
