//! SoC integration layer: the AXI4-Lite interconnect, the RISC-V core, the
//! memory map, peripherals, and the firmware builders (paper Section III).

pub mod bus;
pub mod ctl;
pub mod firmware;
pub mod memmap;
pub mod periph;
pub mod riscv;
