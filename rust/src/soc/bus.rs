//! AXI4-Lite interconnect model (paper Section III-A).
//!
//! AXI4-Lite as used in the SoC: 32-bit data, no bursts, independent
//! read/write address+data channels. We model it at transaction level with
//! per-transaction handshake latency so the system-level throughput
//! accounting (Table II: 113 -> 3.05 1b-GOPS) is grounded in bus cycles
//! rather than hand-waving.

/// Result of a bus transaction (AXI BRESP/RRESP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusResp {
    Okay,
    /// SLVERR: device signalled an error
    SlvErr,
    /// DECERR: no device at this address
    DecErr,
}

/// A memory-mapped device endpoint (an AXI4-Lite slave).
pub trait BusDevice {
    /// Word-aligned read; `offset` is relative to the device base.
    fn read32(&mut self, offset: u32) -> Result<u32, BusResp>;
    /// Word-aligned write.
    fn write32(&mut self, offset: u32, value: u32) -> Result<(), BusResp>;
    /// Device size in bytes (for address decode).
    fn size(&self) -> u32;
    fn name(&self) -> &str;
    /// Downcast hook so the host can reach a concrete device after mapping.
    fn as_any(&mut self) -> &mut dyn std::any::Any;
}

/// Handshake latency model: address phase + data phase + response.
#[derive(Debug, Clone, Copy)]
pub struct AxiTiming {
    /// cycles for AW/AR handshake
    pub addr_cycles: u64,
    /// cycles for W/R data handshake
    pub data_cycles: u64,
    /// cycles for B/R response
    pub resp_cycles: u64,
}

impl Default for AxiTiming {
    fn default() -> Self {
        // 1-cycle ready on each channel: 3 cycles per transaction, the
        // optimum the paper quotes ("32-bit transfers per clock cycle
        // under optimal conditions" refers to the data beat).
        Self { addr_cycles: 1, data_cycles: 1, resp_cycles: 1 }
    }
}

impl AxiTiming {
    pub fn per_transaction(&self) -> u64 {
        self.addr_cycles + self.data_cycles + self.resp_cycles
    }
}

struct Mapping {
    base: u32,
    size: u32,
    device: Box<dyn BusDevice>,
}

/// The AXI4-Lite interconnect: address decode + transaction counting.
pub struct Axi4LiteBus {
    mappings: Vec<Mapping>,
    pub timing: AxiTiming,
    /// total bus cycles consumed by transactions
    pub cycles: u64,
    pub reads: u64,
    pub writes: u64,
    pub errors: u64,
}

impl Axi4LiteBus {
    pub fn new() -> Self {
        Self {
            mappings: Vec::new(),
            timing: AxiTiming::default(),
            cycles: 0,
            reads: 0,
            writes: 0,
            errors: 0,
        }
    }

    /// Map a device at `base`; panics on overlap (a wiring bug, not a
    /// runtime condition).
    pub fn map(&mut self, base: u32, device: Box<dyn BusDevice>) {
        let size = device.size();
        assert!(base % 4 == 0, "device base must be word aligned");
        for m in &self.mappings {
            let overlap = base < m.base + m.size && m.base < base + size;
            assert!(!overlap, "address overlap: {} vs {}", device.name(), m.device.name());
        }
        self.mappings.push(Mapping { base, size, device });
    }

    fn decode(&mut self, addr: u32) -> Option<(usize, u32)> {
        self.mappings
            .iter()
            .position(|m| addr >= m.base && addr < m.base + m.size)
            .map(|i| (i, addr - self.mappings[i].base))
    }

    pub fn read32(&mut self, addr: u32) -> Result<u32, BusResp> {
        self.cycles += self.timing.per_transaction();
        self.reads += 1;
        if addr % 4 != 0 {
            self.errors += 1;
            return Err(BusResp::SlvErr);
        }
        match self.decode(addr) {
            Some((i, off)) => self.mappings[i].device.read32(off).map_err(|e| {
                self.errors += 1;
                e
            }),
            None => {
                self.errors += 1;
                Err(BusResp::DecErr)
            }
        }
    }

    pub fn write32(&mut self, addr: u32, value: u32) -> Result<(), BusResp> {
        self.cycles += self.timing.per_transaction();
        self.writes += 1;
        if addr % 4 != 0 {
            self.errors += 1;
            return Err(BusResp::SlvErr);
        }
        match self.decode(addr) {
            Some((i, off)) => self.mappings[i].device.write32(off, value).map_err(|e| {
                self.errors += 1;
                e
            }),
            None => {
                self.errors += 1;
                Err(BusResp::DecErr)
            }
        }
    }

    /// Access a mapped device downcast-style by name (test/introspection).
    pub fn device_mut(&mut self, name: &str) -> Option<&mut Box<dyn BusDevice>> {
        self.mappings
            .iter_mut()
            .find(|m| m.device.name() == name)
            .map(|m| &mut m.device)
    }
}

impl Default for Axi4LiteBus {
    fn default() -> Self {
        Self::new()
    }
}

/// Simple RAM device (word-addressed backing store).
pub struct Ram {
    data: Vec<u8>,
    name: String,
}

impl Ram {
    pub fn new(size: u32, name: &str) -> Self {
        Self { data: vec![0; size as usize], name: name.to_string() }
    }

    pub fn load(&mut self, offset: u32, bytes: &[u8]) {
        self.data[offset as usize..offset as usize + bytes.len()].copy_from_slice(bytes);
    }

    /// Byte-level accessors used by the CPU's LB/SB paths (the CPU talks to
    /// RAM through these rather than the 32-bit AXI port for simplicity;
    /// instruction fetch uses read32).
    pub fn read8(&self, offset: u32) -> u8 {
        self.data[offset as usize]
    }

    pub fn write8(&mut self, offset: u32, v: u8) {
        self.data[offset as usize] = v;
    }
}

impl BusDevice for Ram {
    fn read32(&mut self, offset: u32) -> Result<u32, BusResp> {
        let o = offset as usize;
        if o + 4 > self.data.len() {
            return Err(BusResp::DecErr);
        }
        Ok(u32::from_le_bytes([self.data[o], self.data[o + 1], self.data[o + 2], self.data[o + 3]]))
    }

    fn write32(&mut self, offset: u32, value: u32) -> Result<(), BusResp> {
        let o = offset as usize;
        if o + 4 > self.data.len() {
            return Err(BusResp::DecErr);
        }
        self.data[o..o + 4].copy_from_slice(&value.to_le_bytes());
        Ok(())
    }

    fn size(&self) -> u32 {
        self.data.len() as u32
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ram_read_write_roundtrip() {
        let mut bus = Axi4LiteBus::new();
        bus.map(0x1000, Box::new(Ram::new(0x100, "ram")));
        bus.write32(0x1010, 0xDEADBEEF).unwrap();
        assert_eq!(bus.read32(0x1010).unwrap(), 0xDEADBEEF);
    }

    #[test]
    fn decode_error_outside_any_device() {
        let mut bus = Axi4LiteBus::new();
        bus.map(0x1000, Box::new(Ram::new(0x100, "ram")));
        assert_eq!(bus.read32(0x9000).unwrap_err(), BusResp::DecErr);
        assert_eq!(bus.errors, 1);
    }

    #[test]
    fn misaligned_is_slverr() {
        let mut bus = Axi4LiteBus::new();
        bus.map(0, Box::new(Ram::new(0x100, "ram")));
        assert_eq!(bus.read32(0x2).unwrap_err(), BusResp::SlvErr);
        assert_eq!(bus.write32(0x3, 1).unwrap_err(), BusResp::SlvErr);
    }

    #[test]
    #[should_panic(expected = "address overlap")]
    fn overlap_panics() {
        let mut bus = Axi4LiteBus::new();
        bus.map(0x1000, Box::new(Ram::new(0x100, "a")));
        bus.map(0x1080, Box::new(Ram::new(0x100, "b")));
    }

    #[test]
    fn cycle_accounting() {
        let mut bus = Axi4LiteBus::new();
        bus.map(0, Box::new(Ram::new(0x100, "ram")));
        bus.write32(0, 1).unwrap();
        bus.read32(0).unwrap();
        assert_eq!(bus.cycles, 2 * bus.timing.per_transaction());
        assert_eq!((bus.reads, bus.writes), (1, 1));
    }

    #[test]
    fn ram_bounds_checked() {
        let mut ram = Ram::new(8, "r");
        assert!(ram.read32(8).is_err());
        assert!(ram.write32(6, 0).is_err());
        assert!(ram.read32(4).is_ok());
    }
}
