//! SoC memory map and top-level assembly (paper Fig. 2(a)): RISC-V core +
//! AXI4-Lite interconnect + RAM + CIM core + UART + GPIO.

use crate::analog::CimAnalogModel;
use crate::coordinator::cim_core::CimDevice;
use crate::soc::bus::{Axi4LiteBus, BusDevice, Ram};
use crate::soc::periph::{Gpio, Uart};
use crate::soc::riscv::cpu::{Cpu, Halt};

/// Address map of the prototype SoC.
pub mod map {
    pub const RAM_BASE: u32 = 0x0000_0000;
    pub const RAM_SIZE: u32 = 0x0010_0000; // 1 MiB
    pub const CIM_BASE: u32 = 0x4000_0000;
    pub const UART_BASE: u32 = 0x5000_0000;
    pub const GPIO_BASE: u32 = 0x6000_0000;
    /// calibration mailbox (`soc::ctl::CalCtl`, supervisor SoC only)
    pub const CTL_BASE: u32 = 0x7000_0000;
    /// firmware entry point
    pub const ENTRY: u32 = RAM_BASE;
    /// initial stack pointer (top of RAM, 16-byte aligned)
    pub const STACK_TOP: u32 = RAM_BASE + RAM_SIZE - 16;
    /// conventional parameter-block location for firmware inputs
    pub const PARAM_BLOCK: u32 = 0x0008_0000;
}

/// The assembled SoC: CPU + interconnect with all devices mapped.
pub struct Soc {
    pub cpu: Cpu,
    pub bus: Axi4LiteBus,
}

impl Soc {
    /// Build the SoC around a CIM analog model (one die).
    pub fn new(model: CimAnalogModel) -> Self {
        let mut bus = Axi4LiteBus::new();
        bus.map(map::RAM_BASE, Box::new(Ram::new(map::RAM_SIZE, "ram")));
        bus.map(map::CIM_BASE, Box::new(CimDevice::new(model)));
        bus.map(map::UART_BASE, Box::new(Uart::new()));
        bus.map(map::GPIO_BASE, Box::new(Gpio::new()));
        let mut cpu = Cpu::new(map::ENTRY);
        cpu.regs[2] = map::STACK_TOP; // sp
        Self { cpu, bus }
    }

    /// Load a program image at the entry point.
    pub fn load_program(&mut self, image: &[u8]) {
        let ram = self.ram_mut();
        ram.load(map::ENTRY - map::RAM_BASE, image);
    }

    /// Write a little-endian word array into RAM (parameter blocks).
    pub fn write_words(&mut self, addr: u32, words: &[u32]) {
        let ram = self.ram_mut();
        for (i, &w) in words.iter().enumerate() {
            ram.write32(addr - map::RAM_BASE + 4 * i as u32, w)
                .expect("param block within RAM");
        }
    }

    pub fn read_word(&mut self, addr: u32) -> u32 {
        self.ram_mut()
            .read32(addr - map::RAM_BASE)
            .expect("address within RAM")
    }

    pub fn ram_mut(&mut self) -> &mut Ram {
        self.bus
            .device_mut("ram")
            .expect("ram mapped")
            .as_any()
            .downcast_mut::<Ram>()
            .expect("ram type")
    }

    pub fn cim_mut(&mut self) -> &mut CimDevice {
        self.bus
            .device_mut("cim")
            .expect("cim mapped")
            .as_any()
            .downcast_mut::<CimDevice>()
            .expect("cim type")
    }

    pub fn uart_mut(&mut self) -> &mut Uart {
        self.bus
            .device_mut("uart")
            .expect("uart mapped")
            .as_any()
            .downcast_mut::<Uart>()
            .expect("uart type")
    }

    /// Run to halt; returns the halt reason.
    pub fn run(&mut self, max_steps: u64) -> Halt {
        self.cpu.run(&mut self.bus, max_steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::soc::riscv::asm::Asm;

    #[test]
    fn soc_boots_and_exits() {
        let mut soc = Soc::new(CimAnalogModel::ideal());
        let mut a = Asm::new(map::ENTRY);
        a.li(10, 7);
        a.exit();
        soc.load_program(&a.assemble());
        assert_eq!(soc.run(1000), Halt::Exit(7));
    }

    #[test]
    fn firmware_reaches_cim_registers() {
        use crate::coordinator::cim_core::regs;
        let mut soc = Soc::new(CimAnalogModel::ideal());
        // program all weights to +63 through the write port, set all
        // inputs to +63, fire a MAC, return OUT[0]
        let mut a = Asm::new(map::ENTRY);
        a.li(5, map::CIM_BASE as i32);
        // WADDR = 0
        a.sw(5, 0, regs::WADDR as i32);
        // loop 1152 cells: WDATA = 63
        a.li(6, 63);
        a.li(7, (crate::analog::consts::N_ROWS * crate::analog::consts::M_COLS) as i32);
        a.label("wloop");
        a.sw(5, 6, regs::WDATA as i32);
        a.addi(7, 7, -1);
        a.bne(7, 0, "wloop");
        // inputs: 36 regs = 63
        a.li(7, crate::analog::consts::N_ROWS as i32);
        a.li(28, (map::CIM_BASE + regs::INPUT) as i32);
        a.label("iloop");
        a.sw(28, 6, 0);
        a.addi(28, 28, 4);
        a.addi(7, 7, -1);
        a.bne(7, 0, "iloop");
        // CTRL = 1 (single MAC)
        a.li(6, 1);
        a.sw(5, 6, regs::CTRL as i32);
        // a0 = OUT[0]
        a.lw(10, 5, regs::OUT as i32);
        a.exit();
        soc.load_program(&a.assemble());
        let halt = soc.run(100_000);
        // full-scale MAC on ideal die = code 62 (see analog::consts tests)
        assert_eq!(halt, Halt::Exit(62));
        assert_eq!(soc.cim_mut().mac_count(), 1);
    }

    #[test]
    fn uart_output_from_firmware() {
        let mut soc = Soc::new(CimAnalogModel::ideal());
        let mut a = Asm::new(map::ENTRY);
        a.li(5, map::UART_BASE as i32);
        for ch in b"ok" {
            a.li(6, *ch as i32);
            a.sw(5, 6, 0);
        }
        a.li(10, 0);
        a.exit();
        soc.load_program(&a.assemble());
        soc.run(1000);
        assert_eq!(soc.uart_mut().tx_string(), "ok");
    }

    #[test]
    fn param_block_roundtrip() {
        let mut soc = Soc::new(CimAnalogModel::ideal());
        soc.write_words(map::PARAM_BLOCK, &[1, 2, 0xFFFF_FFFF]);
        assert_eq!(soc.read_word(map::PARAM_BLOCK + 8), 0xFFFF_FFFF);
        // firmware reads it back
        let mut a = Asm::new(map::ENTRY);
        a.li(5, map::PARAM_BLOCK as i32);
        a.lw(10, 5, 4);
        a.exit();
        soc.load_program(&a.assemble());
        assert_eq!(soc.run(1000), Halt::Exit(2));
    }
}
