//! Peripherals on the interconnect (paper Fig. 2(a)): UART and GPIO.
//! Behavioural endpoints — the UART captures bytes written to TX so
//! firmware can report results; GPIO latches a 32-bit output word and
//! exposes a host-settable input word.

use crate::soc::bus::{BusDevice, BusResp};

/// UART register map (word offsets): 0x0 TX (write), 0x4 STATUS (read:
/// bit0 tx-ready, always 1 in the model), 0x8 RX (read, 0 if empty).
pub struct Uart {
    pub tx_log: Vec<u8>,
    pub rx_fifo: Vec<u8>,
}

impl Uart {
    pub fn new() -> Self {
        Self { tx_log: Vec::new(), rx_fifo: Vec::new() }
    }

    pub fn tx_string(&self) -> String {
        String::from_utf8_lossy(&self.tx_log).to_string()
    }
}

impl Default for Uart {
    fn default() -> Self {
        Self::new()
    }
}

impl BusDevice for Uart {
    fn read32(&mut self, offset: u32) -> Result<u32, BusResp> {
        match offset {
            0x4 => Ok(1), // tx always ready
            0x8 => Ok(if self.rx_fifo.is_empty() {
                0
            } else {
                self.rx_fifo.remove(0) as u32 | 0x100 // bit8 = valid
            }),
            _ => Err(BusResp::SlvErr),
        }
    }

    fn write32(&mut self, offset: u32, value: u32) -> Result<(), BusResp> {
        match offset {
            0x0 => {
                self.tx_log.push(value as u8);
                Ok(())
            }
            _ => Err(BusResp::SlvErr),
        }
    }

    fn size(&self) -> u32 {
        0x10
    }

    fn name(&self) -> &str {
        "uart"
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

/// GPIO: 0x0 OUT (r/w latch), 0x4 IN (read; host sets via `input`).
pub struct Gpio {
    pub out: u32,
    pub input: u32,
}

impl Gpio {
    pub fn new() -> Self {
        Self { out: 0, input: 0 }
    }
}

impl Default for Gpio {
    fn default() -> Self {
        Self::new()
    }
}

impl BusDevice for Gpio {
    fn read32(&mut self, offset: u32) -> Result<u32, BusResp> {
        match offset {
            0x0 => Ok(self.out),
            0x4 => Ok(self.input),
            _ => Err(BusResp::SlvErr),
        }
    }

    fn write32(&mut self, offset: u32, value: u32) -> Result<(), BusResp> {
        match offset {
            0x0 => {
                self.out = value;
                Ok(())
            }
            _ => Err(BusResp::SlvErr),
        }
    }

    fn size(&self) -> u32 {
        0x8
    }

    fn name(&self) -> &str {
        "gpio"
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uart_captures_tx() {
        let mut u = Uart::new();
        for b in b"hi" {
            u.write32(0, *b as u32).unwrap();
        }
        assert_eq!(u.tx_string(), "hi");
        assert_eq!(u.read32(4).unwrap(), 1);
    }

    #[test]
    fn uart_rx_fifo_drains() {
        let mut u = Uart::new();
        u.rx_fifo.extend_from_slice(b"A");
        assert_eq!(u.read32(8).unwrap(), 'A' as u32 | 0x100);
        assert_eq!(u.read32(8).unwrap(), 0);
    }

    #[test]
    fn gpio_out_latch_and_input() {
        let mut g = Gpio::new();
        g.write32(0, 0xFACE).unwrap();
        assert_eq!(g.read32(0).unwrap(), 0xFACE);
        g.input = 0x55;
        assert_eq!(g.read32(4).unwrap(), 0x55);
        assert!(g.write32(4, 1).is_err());
    }
}
