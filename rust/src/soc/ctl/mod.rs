//! Firmware-native calibration control: the simulated RV32 core as the
//! calibration decision-maker for the live cluster (the paper's
//! *RISC-V controlled* self-calibration, in serving form).
//!
//! The split of responsibilities:
//! * [`CalCtl`] (`periph`) — the memory-mapped mailbox. The only
//!   channel between host and firmware: residual samples, the staleness
//!   clock, the healthy-core count, and per-core drain doorbells cross
//!   it as 32-bit bus words.
//! * `firmware` — `CalibratorPolicy` in RV32IM fixed point, assembled
//!   from the in-repo `Asm` builder, run to completion once per sweep.
//! * [`SupervisorCore`] — the supervisor SoC instance (CPU + RAM +
//!   mailbox) plus the host-side protocol driver: deposit a sample, run
//!   a sweep, harvest doorbells, acknowledge executed drains.
//! * [`FirmwareBrain`] — adapts [`SupervisorCore`] to the daemon's
//!   [`CalibratorBrain`] seam; [`FirmwareCalibrator`] spawns the stock
//!   [`Calibrator`] daemon with it, so `serve --auto-calibrate
//!   --firmware` reuses all the host plumbing (health probes, drain
//!   execution, `CalStats` wire frames) and remote clients cannot tell
//!   which brain is running.
//!
//! A firmware fault (bad magic, step-limit, bus error) never takes
//! serving down: the supervisor records it, the sweep yields no
//! decisions, and the cluster keeps serving uncalibrated — identical to
//! the policy deciding "no drain", and visible via [`SupervisorCore::faults`].
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod firmware;
pub mod periph;

pub use periph::{from_q16, to_q16, CalCtl, MAGIC_VALUE, TREND_NONE};

use crate::coordinator::calibrator::{
    Calibrator, CalibratorBrain, CalibratorConfig, CalibratorShared, CoreCalStats, DrainReason,
};
use crate::coordinator::service::CimService;
use crate::soc::bus::{Axi4LiteBus, BusDevice, Ram};
use crate::soc::ctl::periph::regs;
use crate::soc::memmap::map;
use crate::soc::riscv::cpu::{Cpu, Halt};
use std::sync::Arc;
use std::time::Instant;

/// The supervisor SoC (RV32 CPU + private RAM + [`CalCtl`] mailbox) and
/// its host-side protocol driver. Deterministic and clock-free: every
/// entry point takes an explicit `now_ms`, so tests and the property
/// harness can replay any schedule.
pub struct SupervisorCore {
    cpu: Cpu,
    bus: Axi4LiteBus,
    cores: usize,
    max_steps: u64,
    /// doorbells harvested from the mailbox, awaiting `take_decision`
    pending: Vec<Option<DrainReason>>,
    /// trends published by the firmware at the last sweep
    trends: Vec<Option<f64>>,
    faults: u64,
    last_fault: Option<String>,
}

impl SupervisorCore {
    pub fn new(cores: usize, cfg: &CalibratorConfig) -> Self {
        let mut ram = Ram::new(map::RAM_SIZE, "ram");
        ram.load(0, &firmware::supervisor_program());
        for (i, &w) in firmware::supervisor_param_block(cfg).iter().enumerate() {
            let _ = ram.write32(map::PARAM_BLOCK - map::RAM_BASE + 4 * i as u32, w);
        }
        let mut bus = Axi4LiteBus::new();
        bus.map(map::RAM_BASE, Box::new(ram));
        bus.map(map::CTL_BASE, Box::new(CalCtl::new(cores)));
        let mut cpu = Cpu::new(map::ENTRY);
        cpu.regs[2] = map::STACK_TOP;
        Self {
            cpu,
            bus,
            cores,
            max_steps: firmware::max_steps(cores),
            pending: vec![None; cores],
            trends: vec![None; cores],
            faults: 0,
            last_fault: None,
        }
    }

    fn ctl_mut(&mut self) -> Option<&mut CalCtl> {
        self.bus.device_mut("calctl").and_then(|d| d.as_any().downcast_mut::<CalCtl>())
    }

    /// Deposit one health sample for `core`, run a firmware sweep, and
    /// return the trend the firmware published for `core` (which folds
    /// this sample in). Doorbells the sweep rang are parked for
    /// [`SupervisorCore::take_decision`].
    pub fn observe(
        &mut self,
        core: usize,
        residual: Option<f64>,
        fenced: bool,
        recal_epoch: u64,
        healthy_cores: usize,
        now_ms: u32,
    ) -> Option<f64> {
        if let Some(ctl) = self.ctl_mut() {
            ctl.set_clock(now_ms);
            ctl.set_healthy(u32::try_from(healthy_cores).unwrap_or(u32::MAX));
            ctl.post_sample(core, residual, fenced, recal_epoch);
        }
        self.run_sweep();
        let n = self.cores;
        let (cmds, trends): (Vec<u32>, Vec<Option<f64>>) = match self.ctl_mut() {
            Some(ctl) => (0..n).map(|c| (ctl.take_cmd(c), ctl.trend(c))).unzip(),
            None => ((0..n).map(|_| regs::CMD_NONE).collect(), vec![None; n]),
        };
        self.trends = trends;
        // Overwrite only THIS core's pending slot with its own doorbell
        // (including "none": a fresh quiet sweep supersedes any stale
        // decision). Doorbells other cores rang during this sweep are
        // dropped — their state is unchanged, so they re-derive the same
        // decision when their own sample arrives.
        if let Some(slot) = self.pending.get_mut(core) {
            *slot = match cmds.get(core).copied().unwrap_or(regs::CMD_NONE) {
                regs::CMD_TREND => Some(DrainReason::Trend),
                regs::CMD_STALENESS => Some(DrainReason::Staleness),
                _ => None,
            };
        }
        self.trends.get(core).copied().flatten()
    }

    /// Take (and clear) the firmware's drain decision for `core`.
    pub fn take_decision(&mut self, core: usize) -> Option<DrainReason> {
        self.pending.get_mut(core).and_then(|p| p.take())
    }

    /// Acknowledge a drain the host executed: the firmware folds the
    /// outcome into its cool-down/staleness/trend state on the next
    /// sweep (before it consumes the next sample — same ordering as the
    /// host policy's `record_drain` followed by `observe`).
    pub fn record_drain(
        &mut self,
        core: usize,
        recalibrated: bool,
        residual: Option<f64>,
        now_ms: u32,
    ) {
        if let Some(ctl) = self.ctl_mut() {
            ctl.post_result(core, recalibrated, residual, now_ms);
        }
    }

    /// Trend the firmware last published for `core`.
    pub fn trend(&self, core: usize) -> Option<f64> {
        self.trends.get(core).copied().flatten()
    }

    /// Completed firmware sweeps (the firmware's own liveness counter).
    pub fn sweeps(&mut self) -> u32 {
        self.ctl_mut().map(|c| c.sweep()).unwrap_or(0)
    }

    /// Sweeps that did not exit cleanly (bad magic, fault, step limit).
    pub fn faults(&self) -> u64 {
        self.faults
    }

    /// Halt description of the most recent faulted sweep.
    pub fn last_fault(&self) -> Option<&str> {
        self.last_fault.as_deref()
    }

    fn run_sweep(&mut self) {
        self.cpu.pc = map::ENTRY;
        self.cpu.regs[2] = map::STACK_TOP;
        match self.cpu.run(&mut self.bus, self.max_steps) {
            Halt::Exit(code) if code == firmware::EXIT_OK => {}
            halt => {
                self.faults += 1;
                self.last_fault = Some(format!("{halt:?}"));
            }
        }
    }
}

/// [`SupervisorCore`] behind the daemon's [`CalibratorBrain`] seam: the
/// stock daemon samples health and executes drains, the RV32 firmware
/// decides. Time is milliseconds since brain construction — the same
/// origin the firmware's zeroed `last_recal` state assumes.
pub struct FirmwareBrain {
    core: SupervisorCore,
    started: Instant,
    fault_logged: bool,
}

impl FirmwareBrain {
    pub fn new(cores: usize, cfg: &CalibratorConfig) -> Self {
        Self { core: SupervisorCore::new(cores, cfg), started: Instant::now(), fault_logged: false }
    }

    fn now_ms(&self) -> u32 {
        self.started.elapsed().as_millis().min(u32::MAX as u128) as u32
    }

    /// The wrapped supervisor (fault counters, sweep counter).
    pub fn supervisor(&mut self) -> &mut SupervisorCore {
        &mut self.core
    }
}

impl CalibratorBrain for FirmwareBrain {
    fn observe(
        &mut self,
        core: usize,
        residual: Option<f64>,
        fenced: bool,
        recal_epoch: u64,
        healthy_cores: usize,
    ) -> Option<f64> {
        let now = self.now_ms();
        let trend = self.core.observe(core, residual, fenced, recal_epoch, healthy_cores, now);
        if !self.fault_logged && self.core.faults() > 0 {
            self.fault_logged = true;
            eprintln!(
                "calibrator[firmware]: supervisor firmware fault ({}); \
                 continuing without autonomous decisions",
                self.core.last_fault().unwrap_or("unknown halt")
            );
        }
        // trend is reported only for sweeps that folded a residual in,
        // mirroring HostBrain (it feeds the samples/trend statistics)
        residual.and(trend)
    }

    fn decide(&mut self, core: usize, _healthy_cores: usize, _fenced: bool) -> Option<DrainReason> {
        self.core.take_decision(core)
    }

    fn record_drain(&mut self, core: usize, recalibrated: bool, residual: Option<f64>) {
        let now = self.now_ms();
        self.core.record_drain(core, recalibrated, residual, now);
    }

    fn trend(&self, core: usize) -> Option<f64> {
        self.core.trend(core)
    }

    fn tag(&self) -> &'static str {
        "firmware"
    }
}

/// The firmware-brained calibration daemon: drop-in for [`Calibrator`]
/// (`serve --auto-calibrate --firmware`). The supervisor SoC is built
/// on the daemon thread — its bus devices are not `Send` and never need
/// to be.
pub struct FirmwareCalibrator {
    daemon: Calibrator,
}

impl FirmwareCalibrator {
    pub fn spawn<S: CimService + Send + 'static>(svc: S, cfg: CalibratorConfig) -> Self {
        let brain_cfg = cfg.clone();
        let daemon =
            Calibrator::spawn_with(svc, cfg, move |cores| FirmwareBrain::new(cores, &brain_cfg));
        Self { daemon }
    }

    pub fn shared(&self) -> Arc<CalibratorShared> {
        self.daemon.shared()
    }

    pub fn stop(self) -> Vec<CoreCalStats> {
        self.daemon.stop()
    }

    /// Unwrap to the plain daemon handle (shared stats + stop), so the
    /// CLI can hold either brain behind one type.
    pub fn into_daemon(self) -> Calibrator {
        self.daemon
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn cfg(threshold: f64, cooldown_ms: u64, staleness_ms: u64) -> CalibratorConfig {
        CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: 0.5,
            threshold,
            max_staleness: Duration::from_millis(staleness_ms),
            cooldown: Duration::from_millis(cooldown_ms),
        }
    }

    #[test]
    fn firmware_seeds_and_blends_the_trend() {
        let mut sup = SupervisorCore::new(1, &cfg(10.0, 0, 3_600_000));
        let t = sup.observe(0, Some(0.10), false, 0, 2, 0).unwrap();
        assert!((t - 0.10).abs() < 1e-4, "first sample seeds, got {t}");
        let t = sup.observe(0, Some(0.20), false, 0, 2, 10).unwrap();
        assert!((t - 0.15).abs() < 1e-4, "alpha 0.5 blend, got {t}");
        assert_eq!(sup.take_decision(0), None, "in-band trend must not drain");
        assert_eq!(sup.faults(), 0, "{:?}", sup.last_fault());
        assert_eq!(sup.sweeps(), 2, "each observe runs exactly one sweep");
    }

    #[test]
    fn trend_trigger_rings_the_doorbell() {
        let mut sup = SupervisorCore::new(2, &cfg(0.05, 0, 3_600_000));
        sup.observe(0, Some(0.5), false, 0, 2, 0);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Trend));
        assert_eq!(sup.take_decision(0), None, "take must clear");
        assert_eq!(sup.take_decision(1), None, "the quiet core stays quiet");
        assert_eq!(sup.faults(), 0, "{:?}", sup.last_fault());
    }

    #[test]
    fn observe_without_residual_never_decides() {
        let mut sup = SupervisorCore::new(1, &cfg(0.05, 0, 1_000));
        assert_eq!(sup.observe(0, None, false, 0, 2, 0), None);
        // staleness must not fire on a core whose residual was never
        // observable, even long past the deadline
        assert_eq!(sup.observe(0, None, false, 0, 2, 50_000), None);
        assert_eq!(sup.take_decision(0), None);
    }

    #[test]
    fn cooldown_spaces_drain_attempts() {
        let mut sup = SupervisorCore::new(2, &cfg(0.05, 5_000, 3_600_000));
        sup.observe(0, Some(0.5), false, 0, 2, 0);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Trend));
        sup.record_drain(0, true, Some(0.5), 100);
        // still out of band, inside the window: quiet
        sup.observe(0, Some(0.5), false, 1, 2, 1_000);
        assert_eq!(sup.take_decision(0), None);
        sup.observe(0, Some(0.5), false, 1, 2, 4_000);
        assert_eq!(sup.take_decision(0), None);
        // past the window the trigger re-arms
        sup.observe(0, Some(0.5), false, 1, 2, 5_200);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Trend));
    }

    #[test]
    fn never_drains_the_last_healthy_core() {
        let mut sup = SupervisorCore::new(1, &cfg(0.05, 0, 3_600_000));
        sup.observe(0, Some(0.5), false, 0, 1, 0);
        assert_eq!(sup.take_decision(0), None, "availability beats freshness");
        // once fenced the core serves nothing: draining it can only help
        sup.observe(0, Some(0.5), true, 0, 0, 10);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Trend));
    }

    #[test]
    fn staleness_fires_and_recal_resets_the_clock() {
        let mut sup = SupervisorCore::new(2, &cfg(10.0, 0, 1_000));
        sup.observe(0, Some(0.01), false, 0, 2, 0);
        assert_eq!(sup.take_decision(0), None, "calibration still fresh");
        sup.observe(0, Some(0.01), false, 0, 2, 1_500);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Staleness));
        sup.record_drain(0, true, Some(0.01), 1_600);
        // the deadline now measures from the recalibration, not birth
        sup.observe(0, Some(0.01), false, 1, 2, 2_400);
        assert_eq!(sup.take_decision(0), None);
        sup.observe(0, Some(0.01), false, 1, 2, 2_700);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Staleness));
    }

    #[test]
    fn recal_result_reseeds_the_trend() {
        let mut sup = SupervisorCore::new(1, &cfg(0.05, 0, 3_600_000));
        sup.observe(0, Some(0.5), true, 0, 0, 0);
        assert_eq!(sup.take_decision(0), Some(DrainReason::Trend));
        sup.record_drain(0, true, Some(0.01), 50);
        // next sweep folds the result first, then blends the new sample
        let t = sup.observe(0, Some(0.01), false, 1, 1, 100).unwrap();
        assert!((t - 0.01).abs() < 1e-3, "trend re-seeds from the post-recal residual, got {t}");
        assert_eq!(sup.take_decision(0), None, "back in band");
    }
}
