//! The calibration mailbox/CSR peripheral (`CalCtl`): the bus-visible
//! surface between the serving cluster and the RV32 supervisor firmware.
//! The host acts as the sensor DMA — it deposits per-core health samples
//! (residual in Q16 fixed point, fence flag, recalibration epoch), the
//! healthy-core count, and a millisecond staleness clock into the
//! registers below; the firmware consumes them, runs the calibration
//! policy, and raises drain commands through per-core doorbells that the
//! host executes and acknowledges with result registers. Everything
//! crosses this device as 32-bit words over AXI4-Lite — no Rust channel
//! or shared struct leaks into the firmware's world.
//!
//! Register map (see DESIGN.md §13 for the protocol walk-through):
//!
//! | offset | register | access (fw) | contents |
//! |--------|----------|-------------|----------|
//! | 0x00 | MAGIC    | RO | [`MAGIC_VALUE`] |
//! | 0x04 | NCORES   | RO | number of per-core banks |
//! | 0x08 | NOW_MS   | RO | host-maintained ms clock (staleness/cool-down) |
//! | 0x0C | HEALTHY  | RO | cores accepting placed work at last refresh |
//! | 0x10 | SWEEP    | RW | firmware sweep counter (liveness) |
//!
//! Per-core bank at `CORE0 + core * CORE_STRIDE`:
//!
//! | +off | register | access (fw) | contents |
//! |------|--------------|----|----------|
//! | 0x00 | SAMPLE_FLAGS | RW | bit0 valid, bit1 fenced, bit2 has-residual |
//! | 0x04 | RESIDUAL_Q16 | RO | latest residual sample, Q16 |
//! | 0x08 | EPOCH        | RO | recalibration epoch (low 32 bits) |
//! | 0x0C | CMD          | RW | drain doorbell: 0 none, 1 trend, 2 staleness |
//! | 0x10 | RESULT_FLAGS | RW | bit0 valid, bit1 recalibrated, bit2 has-residual |
//! | 0x14 | RESULT_Q16   | RO | post-drain residual, Q16 |
//! | 0x18 | RESULT_MS    | RO | host clock when the drain completed |
//! | 0x1C | TREND_Q16    | RW | firmware-published EWMA ([`TREND_NONE`] = none) |
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::soc::bus::{BusDevice, BusResp};

/// Register offsets and flag bits of the `CalCtl` device.
pub mod regs {
    pub const MAGIC: u32 = 0x00;
    pub const NCORES: u32 = 0x04;
    pub const NOW_MS: u32 = 0x08;
    pub const HEALTHY: u32 = 0x0C;
    pub const SWEEP: u32 = 0x10;
    /// first per-core bank
    pub const CORE0: u32 = 0x40;
    /// bytes per per-core bank
    pub const CORE_STRIDE: u32 = 0x20;

    // per-core bank offsets
    pub const SAMPLE_FLAGS: u32 = 0x00;
    pub const RESIDUAL_Q16: u32 = 0x04;
    pub const EPOCH: u32 = 0x08;
    pub const CMD: u32 = 0x0C;
    pub const RESULT_FLAGS: u32 = 0x10;
    pub const RESULT_Q16: u32 = 0x14;
    pub const RESULT_MS: u32 = 0x18;
    pub const TREND_Q16: u32 = 0x1C;

    /// SAMPLE_FLAGS / RESULT_FLAGS bit0: producer set it, consumer clears
    pub const F_VALID: u32 = 1 << 0;
    /// SAMPLE_FLAGS bit1: the core is fenced out of placement
    pub const F_FENCED: u32 = 1 << 1;
    /// SAMPLE_FLAGS / RESULT_FLAGS bit2: the Q16 residual register holds a
    /// measurement (a service without an engine reports none)
    pub const F_HAS_RESIDUAL: u32 = 1 << 2;
    /// RESULT_FLAGS bit1: the drain ran a recalibration
    pub const F_RECALIBRATED: u32 = 1 << 1;

    /// CMD doorbell codes raised by the firmware
    pub const CMD_NONE: u32 = 0;
    pub const CMD_TREND: u32 = 1;
    pub const CMD_STALENESS: u32 = 2;
}

/// `MAGIC` register value — lets firmware verify it is talking to the
/// calibration mailbox and not an unmapped hole.
pub const MAGIC_VALUE: u32 = 0xCA1C_0DE1;

/// `TREND_Q16` sentinel for "no trend yet" (residuals are non-negative,
/// so the all-ones pattern is unreachable as a real value).
pub const TREND_NONE: u32 = 0xFFFF_FFFF;

/// Residual (f64, non-negative) to Q16 fixed point, saturating at
/// `i32::MAX` so firmware arithmetic stays signed-safe. NaN maps to 0.
pub fn to_q16(v: f64) -> u32 {
    let scaled = (v.max(0.0) * 65536.0).round();
    if scaled >= i32::MAX as f64 {
        i32::MAX as u32
    } else {
        scaled as u32
    }
}

/// Q16 fixed point back to f64.
pub fn from_q16(q: u32) -> f64 {
    q as f64 / 65536.0
}

#[derive(Debug, Clone, Copy, Default)]
struct CoreBank {
    sample_flags: u32,
    residual_q16: u32,
    epoch: u32,
    cmd: u32,
    result_flags: u32,
    result_q16: u32,
    result_ms: u32,
    trend_q16: u32,
}

/// The memory-mapped calibration mailbox. Host-side code uses the typed
/// methods; firmware uses `read32`/`write32` through the bus.
pub struct CalCtl {
    now_ms: u32,
    healthy: u32,
    sweep: u32,
    banks: Vec<CoreBank>,
}

impl CalCtl {
    pub fn new(cores: usize) -> Self {
        Self {
            now_ms: 0,
            healthy: 0,
            sweep: 0,
            banks: vec![CoreBank { trend_q16: TREND_NONE, ..CoreBank::default() }; cores],
        }
    }

    pub fn cores(&self) -> usize {
        self.banks.len()
    }

    /// Advance the staleness/cool-down clock (host-maintained).
    pub fn set_clock(&mut self, now_ms: u32) {
        self.now_ms = now_ms;
    }

    /// Refresh the healthy-core count the availability guard reads.
    pub fn set_healthy(&mut self, healthy: u32) {
        self.healthy = healthy;
    }

    /// Deposit one health sample for `core` and raise its valid flag.
    pub fn post_sample(&mut self, core: usize, residual: Option<f64>, fenced: bool, epoch: u64) {
        let Some(b) = self.banks.get_mut(core) else { return };
        let mut flags = regs::F_VALID;
        if fenced {
            flags |= regs::F_FENCED;
        }
        if let Some(r) = residual {
            flags |= regs::F_HAS_RESIDUAL;
            b.residual_q16 = to_q16(r);
        } else {
            b.residual_q16 = 0;
        }
        b.sample_flags = flags;
        b.epoch = epoch as u32;
    }

    /// Read and clear the drain doorbell of `core` (`CMD_NONE` = quiet).
    pub fn take_cmd(&mut self, core: usize) -> u32 {
        match self.banks.get_mut(core) {
            Some(b) => std::mem::replace(&mut b.cmd, regs::CMD_NONE),
            None => regs::CMD_NONE,
        }
    }

    /// Acknowledge an executed drain: the firmware folds this into its
    /// policy state (cool-down clock, staleness reset, trend re-seed) on
    /// its next sweep.
    pub fn post_result(&mut self, core: usize, recalibrated: bool, residual: Option<f64>, now_ms: u32) {
        let Some(b) = self.banks.get_mut(core) else { return };
        let mut flags = regs::F_VALID;
        if recalibrated {
            flags |= regs::F_RECALIBRATED;
        }
        if let Some(r) = residual {
            flags |= regs::F_HAS_RESIDUAL;
            b.result_q16 = to_q16(r);
        } else {
            b.result_q16 = 0;
        }
        b.result_flags = flags;
        b.result_ms = now_ms;
    }

    /// The trend the firmware last published for `core`.
    pub fn trend(&self, core: usize) -> Option<f64> {
        self.banks.get(core).and_then(|b| {
            if b.trend_q16 == TREND_NONE {
                None
            } else {
                Some(from_q16(b.trend_q16))
            }
        })
    }

    /// Completed firmware sweeps (liveness counter).
    pub fn sweep(&self) -> u32 {
        self.sweep
    }
}

impl BusDevice for CalCtl {
    fn read32(&mut self, offset: u32) -> Result<u32, BusResp> {
        match offset {
            regs::MAGIC => return Ok(MAGIC_VALUE),
            regs::NCORES => return Ok(self.banks.len() as u32),
            regs::NOW_MS => return Ok(self.now_ms),
            regs::HEALTHY => return Ok(self.healthy),
            regs::SWEEP => return Ok(self.sweep),
            _ => {}
        }
        if offset < regs::CORE0 {
            return Err(BusResp::SlvErr);
        }
        let core = ((offset - regs::CORE0) / regs::CORE_STRIDE) as usize;
        let reg = (offset - regs::CORE0) % regs::CORE_STRIDE;
        let Some(b) = self.banks.get(core) else { return Err(BusResp::SlvErr) };
        match reg {
            regs::SAMPLE_FLAGS => Ok(b.sample_flags),
            regs::RESIDUAL_Q16 => Ok(b.residual_q16),
            regs::EPOCH => Ok(b.epoch),
            regs::CMD => Ok(b.cmd),
            regs::RESULT_FLAGS => Ok(b.result_flags),
            regs::RESULT_Q16 => Ok(b.result_q16),
            regs::RESULT_MS => Ok(b.result_ms),
            regs::TREND_Q16 => Ok(b.trend_q16),
            _ => Err(BusResp::SlvErr),
        }
    }

    fn write32(&mut self, offset: u32, value: u32) -> Result<(), BusResp> {
        if offset == regs::SWEEP {
            self.sweep = value;
            return Ok(());
        }
        if offset < regs::CORE0 {
            // MAGIC/NCORES/NOW_MS/HEALTHY are host-owned: read-only on the bus
            return Err(BusResp::SlvErr);
        }
        let core = ((offset - regs::CORE0) / regs::CORE_STRIDE) as usize;
        let reg = (offset - regs::CORE0) % regs::CORE_STRIDE;
        let Some(b) = self.banks.get_mut(core) else { return Err(BusResp::SlvErr) };
        match reg {
            regs::SAMPLE_FLAGS => b.sample_flags = value,
            regs::CMD => b.cmd = value,
            regs::RESULT_FLAGS => b.result_flags = value,
            regs::TREND_Q16 => b.trend_q16 = value,
            // sample/result payloads are host-deposited: read-only on the bus
            _ => return Err(BusResp::SlvErr),
        }
        Ok(())
    }

    fn size(&self) -> u32 {
        regs::CORE0 + self.banks.len() as u32 * regs::CORE_STRIDE
    }

    fn name(&self) -> &str {
        "calctl"
    }

    fn as_any(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q16_roundtrip_and_saturation() {
        assert_eq!(to_q16(0.0), 0);
        assert_eq!(to_q16(1.0), 65536);
        assert_eq!(to_q16(0.05), 3277); // round(0.05 * 65536)
        assert_eq!(to_q16(-0.5), 0, "negative residuals clamp to zero");
        assert_eq!(to_q16(f64::NAN), 0, "NaN clamps to zero");
        assert_eq!(to_q16(1e9), i32::MAX as u32, "saturates signed-safe");
        let r = 0.0371;
        assert!((from_q16(to_q16(r)) - r).abs() < 1.0 / 65536.0);
    }

    #[test]
    fn sample_post_and_firmware_consume() {
        let mut ctl = CalCtl::new(2);
        ctl.post_sample(1, Some(0.25), true, 7);
        let bank = regs::CORE0 + regs::CORE_STRIDE;
        let flags = ctl.read32(bank + regs::SAMPLE_FLAGS).unwrap();
        assert_eq!(flags, regs::F_VALID | regs::F_FENCED | regs::F_HAS_RESIDUAL);
        assert_eq!(ctl.read32(bank + regs::RESIDUAL_Q16).unwrap(), to_q16(0.25));
        assert_eq!(ctl.read32(bank + regs::EPOCH).unwrap(), 7);
        // firmware clears the valid bit, preserving the rest
        ctl.write32(bank + regs::SAMPLE_FLAGS, flags & !regs::F_VALID).unwrap();
        assert_eq!(
            ctl.read32(bank + regs::SAMPLE_FLAGS).unwrap(),
            regs::F_FENCED | regs::F_HAS_RESIDUAL
        );
        // core 0 untouched
        assert_eq!(ctl.read32(regs::CORE0 + regs::SAMPLE_FLAGS).unwrap(), 0);
    }

    #[test]
    fn doorbell_take_clears() {
        let mut ctl = CalCtl::new(1);
        assert_eq!(ctl.take_cmd(0), regs::CMD_NONE);
        ctl.write32(regs::CORE0 + regs::CMD, regs::CMD_TREND).unwrap();
        assert_eq!(ctl.take_cmd(0), regs::CMD_TREND);
        assert_eq!(ctl.take_cmd(0), regs::CMD_NONE, "take must clear");
        assert_eq!(ctl.take_cmd(9), regs::CMD_NONE, "out of range degrades quiet");
    }

    #[test]
    fn result_ack_roundtrip() {
        let mut ctl = CalCtl::new(1);
        ctl.post_result(0, true, Some(0.01), 1234);
        let flags = ctl.read32(regs::CORE0 + regs::RESULT_FLAGS).unwrap();
        assert_eq!(flags, regs::F_VALID | regs::F_RECALIBRATED | regs::F_HAS_RESIDUAL);
        assert_eq!(ctl.read32(regs::CORE0 + regs::RESULT_Q16).unwrap(), to_q16(0.01));
        assert_eq!(ctl.read32(regs::CORE0 + regs::RESULT_MS).unwrap(), 1234);
        ctl.write32(regs::CORE0 + regs::RESULT_FLAGS, 0).unwrap();
        assert_eq!(ctl.read32(regs::CORE0 + regs::RESULT_FLAGS).unwrap(), 0);
    }

    #[test]
    fn trend_sentinel_and_publish() {
        let mut ctl = CalCtl::new(1);
        assert_eq!(ctl.trend(0), None, "no trend before the firmware publishes");
        ctl.write32(regs::CORE0 + regs::TREND_Q16, to_q16(0.125)).unwrap();
        let t = ctl.trend(0).unwrap();
        assert!((t - 0.125).abs() < 1e-9);
        ctl.write32(regs::CORE0 + regs::TREND_Q16, TREND_NONE).unwrap();
        assert_eq!(ctl.trend(0), None);
        assert_eq!(ctl.trend(5), None, "out of range degrades to none");
    }

    #[test]
    fn global_registers_and_write_protection() {
        let mut ctl = CalCtl::new(3);
        ctl.set_clock(99);
        ctl.set_healthy(2);
        assert_eq!(ctl.read32(regs::MAGIC).unwrap(), MAGIC_VALUE);
        assert_eq!(ctl.read32(regs::NCORES).unwrap(), 3);
        assert_eq!(ctl.read32(regs::NOW_MS).unwrap(), 99);
        assert_eq!(ctl.read32(regs::HEALTHY).unwrap(), 2);
        assert_eq!(ctl.write32(regs::NOW_MS, 5).unwrap_err(), BusResp::SlvErr);
        assert_eq!(
            ctl.write32(regs::CORE0 + regs::RESIDUAL_Q16, 5).unwrap_err(),
            BusResp::SlvErr,
            "sample payload is host-owned"
        );
        // sweep counter is firmware-writable
        ctl.write32(regs::SWEEP, 41).unwrap();
        ctl.write32(regs::SWEEP, 42).unwrap();
        assert_eq!(ctl.sweep(), 42);
        // size covers exactly the mapped banks
        assert_eq!(ctl.size(), regs::CORE0 + 3 * regs::CORE_STRIDE);
        assert_eq!(ctl.read32(ctl.size()).unwrap_err(), BusResp::SlvErr);
    }
}
