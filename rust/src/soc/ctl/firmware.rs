//! The calibration supervisor firmware: `CalibratorPolicy` ported to
//! RV32IM fixed point, assembled with the in-repo [`Asm`] builder and
//! run to completion once per sampling sweep on the supervisor SoC.
//!
//! Fixed-point formats (see DESIGN.md §13):
//! * residuals / trends / thresholds — **Q16.16** (`to_q16`): unsigned
//!   on the wire, kept below `i32::MAX` so the EWMA delta `r - e` stays
//!   signed-safe inside the core;
//! * EWMA alpha — Q16 in `[1, 65536]` (65536 = track the raw residual);
//! * time — unsigned milliseconds on a host-fed monotonic clock that
//!   starts at supervisor birth. All comparisons are elapsed-based
//!   (`now - t < window`), so they stay correct as the clock grows.
//!
//! The EWMA update is `e += (r - e) * alpha >> 16`, algebraically equal
//! to the host's `alpha*r + (1-alpha)*e`. The 32×32 product is composed
//! from `mul`/`mulh` so the shift sees the full 64-bit signed product —
//! the result is the exact floor, not a truncated 32-bit approximation.
//!
//! Per-core policy state (EWMA, validity flags, last-recal/last-drain
//! timestamps) lives in supervisor RAM at [`state::BASE`] and persists
//! across sweeps; zeroed RAM is the correct initial state (no trend, no
//! drain yet, staleness measured from clock origin = supervisor birth,
//! matching `CalibratorPolicy::new`'s `last_recal = now`).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::coordinator::calibrator::CalibratorConfig;
use crate::soc::ctl::periph::{regs, to_q16, MAGIC_VALUE, TREND_NONE};
use crate::soc::memmap::map;
use crate::soc::riscv::asm::Asm;
use std::time::Duration;

/// Parameter-block layout (word offsets from [`map::PARAM_BLOCK`]),
/// written by the host before the first sweep.
pub mod pblk {
    /// EWMA alpha, Q16 in `[1, 65536]`
    pub const ALPHA_Q16: u32 = 0;
    /// drain threshold, Q16
    pub const THRESHOLD_Q16: u32 = 1;
    /// cool-down window, ms
    pub const COOLDOWN_MS: u32 = 2;
    /// staleness deadline, ms
    pub const STALENESS_MS: u32 = 3;
}

/// Firmware-private per-core policy state in supervisor RAM. The host
/// never writes here after boot — it is the firmware's working memory.
pub mod state {
    /// base address of the per-core state array
    pub const BASE: u32 = 0x0009_0000;
    /// bytes per core
    pub const STRIDE: u32 = 16;
    /// EWMA trend, Q16 (valid iff [`F_EWMA_VALID`])
    pub const EWMA_Q16: u32 = 0;
    /// validity flags
    pub const FLAGS: u32 = 4;
    /// clock of the last successful recalibration (0 = supervisor birth)
    pub const LAST_RECAL_MS: u32 = 8;
    /// clock of the last drain attempt (valid iff [`F_DRAIN_VALID`])
    pub const LAST_DRAIN_MS: u32 = 12;

    pub const F_EWMA_VALID: u32 = 1 << 0;
    pub const F_DRAIN_VALID: u32 = 1 << 1;
}

/// Firmware exit code: sweep completed.
pub const EXIT_OK: u32 = 0;
/// Firmware exit code: the mailbox MAGIC probe failed.
pub const EXIT_BAD_MAGIC: u32 = 1;

/// Duration → saturating u32 milliseconds (the firmware clock format).
pub fn ms_u32(d: Duration) -> u32 {
    d.as_millis().min(u32::MAX as u128) as u32
}

/// Quantize the daemon config into the firmware parameter block.
pub fn supervisor_param_block(cfg: &CalibratorConfig) -> [u32; 4] {
    // NaN casts to 0 and clamps to 1: a degenerate alpha degrades to the
    // slowest trend instead of corrupting the fixed-point blend
    let alpha_q16 = ((cfg.ewma_alpha * 65536.0).round() as i64).clamp(1, 65536) as u32;
    [
        alpha_q16,
        to_q16(cfg.threshold),
        ms_u32(cfg.cooldown),
        ms_u32(cfg.max_staleness),
    ]
}

/// Step budget for one sweep over `cores` banks (the loop body is ~60
/// instructions; the budget is a runaway backstop, not a tuning knob).
pub fn max_steps(cores: usize) -> u64 {
    10_000 + 1_000 * cores as u64
}

/// Assemble the supervisor sweep program. Run-to-completion: the host
/// resets `pc` to [`map::ENTRY`] before every sweep; RAM carries the
/// policy state across runs. One sweep = for each core bank: fold in a
/// drain result, fold in a health sample, publish the trend, and ring
/// the drain doorbell when the policy fires — the exact trigger/guard
/// ladder of `CalibratorPolicy::decide`, in the same order.
pub fn supervisor_program() -> Vec<u8> {
    // register allocation:
    //   x5  CTL base          x21 now_ms            x26 ewma (Q16)
    //   x8  alpha (Q16)       x22 healthy cores     x27 state flags
    //   x9  threshold (Q16)   x23 core index        x28-x31 scratch
    //   x18 cooldown_ms       x24 mailbox bank addr
    //   x19 staleness_ms      x25 state addr
    //   x20 ncores            x6/x7 scratch
    let mut a = Asm::new(map::ENTRY);
    a.li(5, map::CTL_BASE as i32);
    a.lw(6, 5, regs::MAGIC as i32);
    a.li(7, MAGIC_VALUE as i32);
    a.beq(6, 7, "magic_ok");
    a.li(10, EXIT_BAD_MAGIC as i32);
    a.exit();
    a.label("magic_ok");
    a.li(6, map::PARAM_BLOCK as i32);
    a.lw(8, 6, (pblk::ALPHA_Q16 * 4) as i32);
    a.lw(9, 6, (pblk::THRESHOLD_Q16 * 4) as i32);
    a.lw(18, 6, (pblk::COOLDOWN_MS * 4) as i32);
    a.lw(19, 6, (pblk::STALENESS_MS * 4) as i32);
    a.lw(20, 5, regs::NCORES as i32);
    a.lw(21, 5, regs::NOW_MS as i32);
    a.lw(22, 5, regs::HEALTHY as i32);
    a.li(23, 0);
    a.li(24, (map::CTL_BASE + regs::CORE0) as i32);
    a.li(25, state::BASE as i32);

    a.label("core");
    a.bge(23, 20, "done");
    a.lw(26, 25, state::EWMA_Q16 as i32);
    a.lw(27, 25, state::FLAGS as i32);

    // (1) fold in the result of a drain the host executed for us:
    // last_drain always, last_recal + trend re-seed when it recalibrated
    a.lw(28, 24, regs::RESULT_FLAGS as i32);
    a.andi(29, 28, regs::F_VALID as i32);
    a.beq(29, 0, "no_result");
    a.sw(24, 0, regs::RESULT_FLAGS as i32);
    a.lw(30, 24, regs::RESULT_MS as i32);
    a.sw(25, 30, state::LAST_DRAIN_MS as i32);
    a.ori(27, 27, state::F_DRAIN_VALID as i32);
    a.andi(29, 28, regs::F_RECALIBRATED as i32);
    a.beq(29, 0, "no_result");
    a.sw(25, 30, state::LAST_RECAL_MS as i32);
    a.andi(29, 28, regs::F_HAS_RESIDUAL as i32);
    a.beq(29, 0, "recal_no_residual");
    a.lw(26, 24, regs::RESULT_Q16 as i32);
    a.ori(27, 27, state::F_EWMA_VALID as i32);
    a.j("no_result");
    a.label("recal_no_residual");
    a.andi(27, 27, !(state::F_EWMA_VALID as i32));
    a.label("no_result");

    // (2) fold in a fresh health sample: ack the valid bit (keeping
    // fenced/has-residual for the decision ladder), seed or blend
    a.lw(28, 24, regs::SAMPLE_FLAGS as i32);
    a.andi(29, 28, regs::F_VALID as i32);
    a.beq(29, 0, "no_sample");
    a.andi(30, 28, !(regs::F_VALID as i32));
    a.sw(24, 30, regs::SAMPLE_FLAGS as i32);
    a.andi(29, 28, regs::F_HAS_RESIDUAL as i32);
    a.beq(29, 0, "no_sample");
    a.lw(28, 24, regs::RESIDUAL_Q16 as i32);
    a.andi(29, 27, state::F_EWMA_VALID as i32);
    a.bne(29, 0, "blend");
    a.mv(26, 28);
    a.ori(27, 27, state::F_EWMA_VALID as i32);
    a.j("no_sample");
    a.label("blend");
    // e += (r - e) * alpha >> 16; bits [16..48) of the signed product
    a.sub(29, 28, 26);
    a.mul(30, 29, 8);
    a.mulh(31, 29, 8);
    a.srli(30, 30, 16);
    a.slli(31, 31, 16);
    a.or(30, 30, 31);
    a.add(26, 26, 30);
    a.label("no_sample");

    // (3) publish the trend for host observability
    a.andi(29, 27, state::F_EWMA_VALID as i32);
    a.bne(29, 0, "trend_val");
    a.li(30, TREND_NONE as i32);
    a.sw(24, 30, regs::TREND_Q16 as i32);
    a.j("decide");
    a.label("trend_val");
    a.sw(24, 26, regs::TREND_Q16 as i32);
    a.label("decide");

    // (4) the decision ladder, same order as CalibratorPolicy::decide:
    // cool-down, availability guard, trend trigger, staleness trigger
    a.lw(28, 24, regs::CMD as i32);
    a.bne(28, 0, "next");
    a.andi(29, 27, state::F_DRAIN_VALID as i32);
    a.beq(29, 0, "no_cooldown");
    a.lw(30, 25, state::LAST_DRAIN_MS as i32);
    a.sub(30, 21, 30);
    a.bltu(30, 18, "next");
    a.label("no_cooldown");
    a.lw(28, 24, regs::SAMPLE_FLAGS as i32);
    a.andi(29, 28, regs::F_FENCED as i32);
    a.bne(29, 0, "avail_ok");
    a.li(29, 1);
    a.bgeu(29, 22, "next");
    a.label("avail_ok");
    a.andi(29, 27, state::F_EWMA_VALID as i32);
    a.beq(29, 0, "next");
    a.bltu(9, 26, "fire_trend");
    a.lw(30, 25, state::LAST_RECAL_MS as i32);
    a.sub(30, 21, 30);
    a.bltu(30, 19, "next");
    a.li(29, regs::CMD_STALENESS as i32);
    a.sw(24, 29, regs::CMD as i32);
    a.j("next");
    a.label("fire_trend");
    a.li(29, regs::CMD_TREND as i32);
    a.sw(24, 29, regs::CMD as i32);
    a.label("next");

    // (5) persist policy state and advance to the next bank
    a.sw(25, 26, state::EWMA_Q16 as i32);
    a.sw(25, 27, state::FLAGS as i32);
    a.addi(23, 23, 1);
    a.addi(24, 24, regs::CORE_STRIDE as i32);
    a.addi(25, 25, state::STRIDE as i32);
    a.j("core");

    a.label("done");
    a.lw(6, 5, regs::SWEEP as i32);
    a.addi(6, 6, 1);
    a.sw(5, 6, regs::SWEEP as i32);
    a.li(10, EXIT_OK as i32);
    a.exit();
    a.assemble()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_assembles_below_the_param_block() {
        let image = supervisor_program();
        assert!(!image.is_empty());
        assert_eq!(image.len() % 4, 0);
        assert!(
            (image.len() as u32) < map::PARAM_BLOCK,
            "program ({} bytes) must not overlap the parameter block",
            image.len()
        );
    }

    #[test]
    fn param_block_quantization() {
        let cfg = CalibratorConfig {
            period: Duration::from_millis(10),
            ewma_alpha: 0.5,
            threshold: 0.05,
            max_staleness: Duration::from_secs(60),
            cooldown: Duration::from_secs(5),
        };
        let p = supervisor_param_block(&cfg);
        assert_eq!(p[pblk::ALPHA_Q16 as usize], 32768);
        assert_eq!(p[pblk::THRESHOLD_Q16 as usize], 3277);
        assert_eq!(p[pblk::COOLDOWN_MS as usize], 5_000);
        assert_eq!(p[pblk::STALENESS_MS as usize], 60_000);
    }

    #[test]
    fn param_block_clamps_degenerate_alpha() {
        let mut cfg = CalibratorConfig::default();
        cfg.ewma_alpha = 0.0;
        assert_eq!(supervisor_param_block(&cfg)[0], 1, "alpha floors at one LSB");
        cfg.ewma_alpha = 2.0;
        assert_eq!(supervisor_param_block(&cfg)[0], 65536, "alpha caps at unity");
        cfg.ewma_alpha = f64::NAN;
        assert_eq!(supervisor_param_block(&cfg)[0], 1, "NaN degrades to the floor");
    }

    #[test]
    fn huge_durations_saturate() {
        assert_eq!(ms_u32(Duration::from_secs(u64::MAX)), u32::MAX);
        assert_eq!(ms_u32(Duration::from_millis(7)), 7);
    }
}
