//! Configuration system: a TOML-subset parser (serde/toml are not vendored)
//! plus the typed `SimConfig` consumed across the stack.
//!
//! Grammar supported: `[section]` headers, `key = value` with string,
//! float, integer, and boolean values, `#` comments. This covers every
//! config shipped in `configs/`.

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Default, Clone)]
pub struct RawConfig {
    /// "section.key" -> value string
    values: BTreeMap<String, String>,
}

impl RawConfig {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw_line) in text.lines().enumerate() {
            let line = raw_line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`: {raw_line}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let mut val = v.trim().to_string();
            if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                val = val[1..val.len() - 1].to_string();
            }
            values.insert(key, val);
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("config key {key}: not a number: {v}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.values
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("config key {key}: not an integer: {v}")))
            .unwrap_or(default)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.values
            .get(key)
            .map(|v| v == "true" || v == "1")
            .unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

/// Variation / noise magnitudes of the Monte-Carlo silicon sample plus the
/// structural parasitic knobs. Units are fractions (gains), volts
/// (offsets), or ADC codes (beta_d). Defaults are tuned so the uncalibrated
/// per-column errors land in the paper's measured ranges (Fig. 8b:
/// g ~ 0.8-1.2, eps up to ~6 LSB) — see EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub seed: u64,
    /// input DAC per-row gain sigma (fractional)
    pub sigma_dac_gain: f64,
    /// input DAC per-row offset sigma [V]
    pub sigma_dac_off: f64,
    /// MWC conductance mismatch sigma (fractional)
    pub sigma_cell: f64,
    /// 2SA per-line gain-error sigma (fractional)
    pub sigma_sa_gain: f64,
    /// 2SA input-referred offset sigma [V]
    pub sigma_sa_off: f64,
    /// 2SA cubic distortion coefficient sigma [V^-2] — the uncorrectable
    /// nonlinearity setting the post-BISC residual floor (Fig. 10's 18-24 dB)
    pub sigma_sa_nonlin: f64,
    /// ADC gain-error sigma (fractional)
    pub sigma_adc_gain: f64,
    /// ADC offset sigma [codes]
    pub sigma_adc_off: f64,
    /// row-wire input attenuation at the far column (Fig. 1 effect 4)
    pub kappa_in: f64,
    /// summation-node regulation droop at the far row (effect 5)
    pub kappa_reg: f64,
    /// SA-referred rms noise per read [V] (thermal + flicker lump)
    pub sigma_noise: f64,
    /// per-column SA gain drift-velocity sigma, per drift unit (one S&H
    /// period of analog busy time / one served MAC). 0.0 = no drift. A
    /// non-zero value makes the die AGE under traffic: analog error
    /// becomes a moving target and periodic recalibration (the
    /// calibrator daemon) becomes load-bearing.
    pub sigma_drift: f64,
    /// hard-fault injection plan (compact spec string, see
    /// `analog::faults::FaultPlan::parse`). `None` = healthy silicon.
    /// Threaded into each `ClusterCore`, which applies the events
    /// targeting its own id — immediately or at the scheduled MAC count.
    pub faults: Option<String>,
    /// BISC: number of characterization test vectors (Z, Section VI-C)
    pub bisc_test_points: usize,
    /// BISC: averaging reads per test point
    pub bisc_averages: usize,
    /// ADC reference widening margin used during BISC (Alg. 1: 5%)
    pub bisc_ref_margin: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            seed: 0xAC0_CE11, // "acore-cell" default silicon sample
            sigma_dac_gain: 0.010,
            sigma_dac_off: 0.002,
            sigma_cell: 0.020,
            sigma_sa_gain: 0.100,
            sigma_sa_off: 0.014,
            sigma_sa_nonlin: 6.5,
            sigma_adc_gain: 0.020,
            sigma_adc_off: 1.200,
            kappa_in: crate::analog::consts::KAPPA_IN_DEFAULT,
            kappa_reg: crate::analog::consts::KAPPA_REG_DEFAULT,
            sigma_noise: 0.0005,
            sigma_drift: 0.0,
            faults: None,
            bisc_test_points: 8,
            bisc_averages: 4,
            bisc_ref_margin: 0.08,
        }
    }
}

impl SimConfig {
    pub fn from_raw(raw: &RawConfig) -> Self {
        let d = Self::default();
        Self {
            seed: raw.get_u64("sim.seed", d.seed),
            sigma_dac_gain: raw.get_f64("variation.sigma_dac_gain", d.sigma_dac_gain),
            sigma_dac_off: raw.get_f64("variation.sigma_dac_off", d.sigma_dac_off),
            sigma_cell: raw.get_f64("variation.sigma_cell", d.sigma_cell),
            sigma_sa_gain: raw.get_f64("variation.sigma_sa_gain", d.sigma_sa_gain),
            sigma_sa_off: raw.get_f64("variation.sigma_sa_off", d.sigma_sa_off),
            sigma_sa_nonlin: raw.get_f64("variation.sigma_sa_nonlin", d.sigma_sa_nonlin),
            sigma_adc_gain: raw.get_f64("variation.sigma_adc_gain", d.sigma_adc_gain),
            sigma_adc_off: raw.get_f64("variation.sigma_adc_off", d.sigma_adc_off),
            kappa_in: raw.get_f64("parasitics.kappa_in", d.kappa_in),
            kappa_reg: raw.get_f64("parasitics.kappa_reg", d.kappa_reg),
            sigma_noise: raw.get_f64("noise.sigma_v", d.sigma_noise),
            sigma_drift: raw.get_f64("drift.sigma_v", d.sigma_drift),
            faults: Some(raw.get_str("faults.plan", "")).filter(|s| !s.is_empty()),
            bisc_test_points: raw.get_u64("bisc.test_points", d.bisc_test_points as u64) as usize,
            bisc_averages: raw.get_u64("bisc.averages", d.bisc_averages as u64) as usize,
            bisc_ref_margin: raw.get_f64("bisc.ref_margin", d.bisc_ref_margin),
        }
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        Ok(Self::from_raw(&RawConfig::load(path)?))
    }

    /// Scale all variation sigmas (ablation knob).
    pub fn scaled(&self, s: f64) -> Self {
        Self {
            sigma_dac_gain: self.sigma_dac_gain * s,
            sigma_dac_off: self.sigma_dac_off * s,
            sigma_cell: self.sigma_cell * s,
            sigma_sa_gain: self.sigma_sa_gain * s,
            sigma_sa_off: self.sigma_sa_off * s,
            sigma_sa_nonlin: self.sigma_sa_nonlin * s,
            sigma_adc_gain: self.sigma_adc_gain * s,
            sigma_adc_off: self.sigma_adc_off * s,
            kappa_in: self.kappa_in * s,
            kappa_reg: self.kappa_reg * s,
            ..self.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let raw = RawConfig::parse(
            "# comment\n[sim]\nseed = 99\n[variation]\nsigma_cell = 0.5 # inline\n[x]\nname = \"abc\"\nflag = true\n",
        )
        .unwrap();
        assert_eq!(raw.get_u64("sim.seed", 0), 99);
        assert_eq!(raw.get_f64("variation.sigma_cell", 0.0), 0.5);
        assert_eq!(raw.get_str("x.name", ""), "abc");
        assert!(raw.get_bool("x.flag", false));
    }

    #[test]
    fn defaults_flow_through() {
        let raw = RawConfig::parse("").unwrap();
        let cfg = SimConfig::from_raw(&raw);
        let d = SimConfig::default();
        assert_eq!(cfg.sigma_cell, d.sigma_cell);
        assert_eq!(cfg.bisc_test_points, d.bisc_test_points);
    }

    #[test]
    fn fault_plan_key_flows_through() {
        let raw = RawConfig::parse("[faults]\nplan = \"core=1,col=7\"\n").unwrap();
        let cfg = SimConfig::from_raw(&raw);
        assert_eq!(cfg.faults.as_deref(), Some("core=1,col=7"));
        assert_eq!(SimConfig::from_raw(&RawConfig::parse("").unwrap()).faults, None);
        // the plan survives the sigma-scaling ablation knob
        assert_eq!(cfg.scaled(0.5).faults.as_deref(), Some("core=1,col=7"));
    }

    #[test]
    fn bad_line_is_error() {
        assert!(RawConfig::parse("just words\n").is_err());
    }

    #[test]
    fn scaled_halves_sigmas() {
        let c = SimConfig::default().scaled(0.5);
        assert!((c.sigma_cell - SimConfig::default().sigma_cell * 0.5).abs() < 1e-12);
    }
}
