//! Micro-benchmark harness (criterion is not vendored). Used by every
//! `benches/*.rs` target (built with `harness = false`).
//!
//! Methodology: warm-up iterations, then fixed-duration sampling; reports
//! median / p10 / p90 of per-iteration wall time plus derived throughput.
//! `black_box` prevents the optimizer from deleting the measured work.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} iters  median {:>12}  p10 {:>12}  p90 {:>12}  ({:.1}/s)",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.per_sec()
        )
    }
}

impl BenchResult {
    /// One JSON object for the CI bench-artifact trajectory
    /// (`BENCH_*.json`, uploaded by the bench-smoke workflow job).
    pub fn json(&self) -> String {
        format!(
            "{{\"name\": {}, \"iters\": {}, \"median_ns\": {:.1}, \"p10_ns\": {:.1}, \
             \"p90_ns\": {:.1}, \"mean_ns\": {:.1}, \"per_sec\": {:.3}}}",
            json_str(&self.name),
            self.iters,
            self.median_ns,
            self.p10_ns,
            self.p90_ns,
            self.mean_ns,
            self.per_sec()
        )
    }
}

/// Minimal JSON string escaping (bench names are plain ASCII, but a
/// stray quote must not corrupt the artifact).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Write `BENCH_<bench>.json` into `$ACORE_BENCH_JSON_DIR` (created if
/// missing). A no-op returning `None` when the variable is unset — local
/// bench runs stay file-free; CI sets it and uploads the directory as a
/// workflow artifact, seeding the bench trajectory.
pub fn write_bench_json(bench: &str, body: &str) -> Option<std::path::PathBuf> {
    let dir = std::env::var("ACORE_BENCH_JSON_DIR").ok()?;
    if let Err(e) = std::fs::create_dir_all(&dir) {
        eprintln!("bench json: cannot create {dir}: {e}");
        return None;
    }
    let path = std::path::Path::new(&dir).join(format!("BENCH_{bench}.json"));
    match std::fs::write(&path, body) {
        Ok(()) => {
            println!("bench json: wrote {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("bench json: cannot write {}: {e}", path.display());
            None
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    /// target measurement duration per benchmark
    pub measure: Duration,
    pub warmup: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Honor the quick mode used in CI: ACORE_BENCH_FAST=1
        let fast = std::env::var("ACORE_BENCH_FAST").is_ok();
        Self {
            measure: Duration::from_millis(if fast { 200 } else { 1500 }),
            warmup: Duration::from_millis(if fast { 50 } else { 300 }),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Measure `f` repeatedly; the closure's return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            bb(f());
        }
        // sample
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure {
            let t0 = Instant::now();
            bb(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            mean_ns: mean,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Record an externally measured rate (the serving benches compute
    /// req/s over their own wall clock) so it rides along in the JSON
    /// export; stored as its per-event period. Non-positive or
    /// non-finite rates are dropped.
    pub fn note_rate(&mut self, name: &str, per_sec: f64) {
        if !per_sec.is_finite() || per_sec <= 0.0 {
            return;
        }
        let ns = 1e9 / per_sec;
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: 1,
            median_ns: ns,
            p10_ns: ns,
            p90_ns: ns,
            mean_ns: ns,
        });
    }

    /// Export every recorded result as `BENCH_<bench>.json` (see
    /// [`write_bench_json`]; no-op without `ACORE_BENCH_JSON_DIR`).
    /// Every export carries `"provenance": "measured (...)"` — these
    /// numbers always come from an actual run of this process, which is
    /// what arms `bench-diff --gate` (estimated baselines never gate).
    pub fn export_json(&self, bench: &str) {
        let rows: Vec<String> =
            self.results.iter().map(|r| format!("    {}", r.json())).collect();
        let provenance = format!("measured ({} {})", std::env::consts::OS, std::env::consts::ARCH);
        let body = format!(
            "{{\n  \"bench\": {},\n  \"provenance\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
            json_str(bench),
            json_str(&provenance),
            rows.join(",\n")
        );
        write_bench_json(bench, &body);
    }

    /// Fixed iteration count variant for expensive bodies.
    pub fn bench_n<T, F: FnMut() -> T>(&mut self, name: &str, n: u64, mut f: F) -> &BenchResult {
        let mut samples_ns = Vec::with_capacity(n as usize);
        bb(f()); // single warmup
        for _ in 0..n {
            let t0 = Instant::now();
            bb(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n,
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            mean_ns: mean,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("ACORE_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters > 10);
    }

    #[test]
    fn bench_json_is_parseable_and_escaped() {
        let r = BenchResult {
            name: "weird \"name\" \\ here".to_string(),
            iters: 3,
            median_ns: 10.0,
            p10_ns: 9.0,
            p90_ns: 11.0,
            mean_ns: 10.0,
        };
        let j = r.json();
        let parsed = crate::util::json::parse(&j).expect("bench json must parse");
        assert_eq!(parsed.get("iters").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(
            parsed.get("name").and_then(|v| v.as_str()),
            Some("weird \"name\" \\ here")
        );
        assert_eq!(parsed.get("per_sec").and_then(|v| v.as_f64()), Some(1e9 / 10.0));
    }

    #[test]
    fn note_rate_drops_degenerate_rates() {
        let mut b = Bencher::new();
        b.note_rate("ok", 1e6);
        b.note_rate("zero", 0.0);
        b.note_rate("nan", f64::NAN);
        assert_eq!(b.results.len(), 1);
        assert!((b.results[0].median_ns - 1e3).abs() < 1e-9);
    }
}
