//! Micro-benchmark harness (criterion is not vendored). Used by every
//! `benches/*.rs` target (built with `harness = false`).
//!
//! Methodology: warm-up iterations, then fixed-duration sampling; reports
//! median / p10 / p90 of per-iteration wall time plus derived throughput.
//! `black_box` prevents the optimizer from deleting the measured work.

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1e9 / self.median_ns
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} iters  median {:>12}  p10 {:>12}  p90 {:>12}  ({:.1}/s)",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.per_sec()
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    /// target measurement duration per benchmark
    pub measure: Duration,
    pub warmup: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Honor the quick mode used in CI: ACORE_BENCH_FAST=1
        let fast = std::env::var("ACORE_BENCH_FAST").is_ok();
        Self {
            measure: Duration::from_millis(if fast { 200 } else { 1500 }),
            warmup: Duration::from_millis(if fast { 50 } else { 300 }),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Measure `f` repeatedly; the closure's return value is black-boxed.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            bb(f());
        }
        // sample
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.measure {
            let t0 = Instant::now();
            bb(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            mean_ns: mean,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Fixed iteration count variant for expensive bodies.
    pub fn bench_n<T, F: FnMut() -> T>(&mut self, name: &str, n: u64, mut f: F) -> &BenchResult {
        let mut samples_ns = Vec::with_capacity(n as usize);
        bb(f()); // single warmup
        for _ in 0..n {
            let t0 = Instant::now();
            bb(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |p: f64| samples_ns[((samples_ns.len() - 1) as f64 * p) as usize];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters: n,
            median_ns: pick(0.5),
            p10_ns: pick(0.1),
            p90_ns: pick(0.9),
            mean_ns: mean,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("ACORE_BENCH_FAST", "1");
        let mut b = Bencher::new();
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters > 10);
    }
}
