//! Poison-tolerant locking for serving threads (DESIGN.md §12).
//!
//! `Mutex::lock().unwrap()` turns one panicked holder into a cascade:
//! every later lock attempt panics on the poison flag, silently killing
//! the batcher/calibrator thread that hit it. The serving modules are
//! lint-gated panic-free (`acore-cim lint`, rule `panic_free`), so a
//! poisoned mutex there means a panic in *test-injected* or future code
//! — recovering the guard keeps the serving plane alive, and the
//! protected state (stats snapshots, connection tables, write halves)
//! is valid under torn updates: plain-old-data counters and whole-value
//! swaps, never multi-step invariants.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_after_a_panicked_holder() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
