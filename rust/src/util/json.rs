//! Minimal JSON parser (serde_json is not vendored). Supports the full
//! JSON grammar minus exotic number forms; used for `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { pos: self.i, msg: msg.to_string() })
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a value"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{word}`"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number `{s}`") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or(JsonError {
                        pos: self.i,
                        msg: "bad escape".into(),
                    })?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError { pos: self.i, msg: "bad utf8".into() })?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError { pos: self.i, msg: "bad hex".into() })?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return self.err("unknown escape"),
                    }
                }
                Some(c) => {
                    // copy raw UTF-8 bytes through
                    let start = self.i;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.i += len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError { pos: start, msg: "bad utf8".into() })?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }
}

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"artifacts": [{"name": "cim_mac_b1", "num_inputs": 15,
            "input_shapes": [[1, 36], [36, 32]], "bytes": 14291}],
            "params": {"R_U": 385000.0, "flag": true, "none": null}}"#;
        let j = parse(doc).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "cim_mac_b1");
        assert_eq!(arts[0].get("num_inputs").unwrap().as_usize().unwrap(), 15);
        let shapes = arts[0].get("input_shapes").unwrap().as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[1].as_usize().unwrap(), 36);
        assert_eq!(j.get("params").unwrap().get("R_U").unwrap().as_f64(), Some(385000.0));
        assert_eq!(j.get("params").unwrap().get("flag").unwrap(), &Json::Bool(true));
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn nested_empties() {
        let j = parse(r#"{"a": [], "b": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 0);
    }
}
