//! ASCII table rendering for benchmark/report output — every regenerated
//! paper table/figure prints through this so `EXPERIMENTS.md` rows can be
//! pasted directly from program output.

#[derive(Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:<width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format helper: fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format helper: engineering style with unit suffix.
pub fn eng(v: f64, unit: &str) -> String {
    let (scaled, prefix) = if v.abs() >= 1e9 {
        (v / 1e9, "G")
    } else if v.abs() >= 1e6 {
        (v / 1e6, "M")
    } else if v.abs() >= 1e3 {
        (v / 1e3, "k")
    } else if v.abs() >= 1.0 || v == 0.0 {
        (v, "")
    } else if v.abs() >= 1e-3 {
        (v * 1e3, "m")
    } else if v.abs() >= 1e-6 {
        (v * 1e6, "u")
    } else {
        (v * 1e9, "n")
    };
    format!("{scaled:.3} {prefix}{unit}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["col", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["long-name", "2.5"]);
        let r = t.render();
        assert!(r.contains("| long-name | 2.5   |"));
        assert!(r.contains("== demo =="));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn eng_prefixes() {
        assert_eq!(eng(2_600_000.0, "Ohm"), "2.600 MOhm");
        assert_eq!(eng(0.0000026, "A"), "2.600 uA");
        assert_eq!(eng(385_000.0, "Ohm"), "385.000 kOhm");
    }
}
