//! Small statistics toolkit: moments, percentiles, histograms, linear
//! least-squares (the BISC fit of Eq. 13-14 reuses `linfit`), and dB helpers.

/// Arithmetic mean. Returns 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100), linear interpolation, sorts a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Ordinary least-squares fit y = g*x + e over paired samples.
///
/// This is exactly the BISC estimator of Eq. (13)-(14):
///   g = (Z*sum(xy) - sum(x)*sum(y)) / (Z*sum(x^2) - sum(x)^2)
///   e = (sum(y) - g*sum(x)) / Z
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "linfit needs >= 2 points");
    let z = x.len() as f64;
    let sx: f64 = x.iter().sum();
    let sy: f64 = y.iter().sum();
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| a * b).sum();
    let sxx: f64 = x.iter().map(|a| a * a).sum();
    let denom = z * sxx - sx * sx;
    assert!(denom.abs() > 1e-12, "degenerate linfit (all x equal)");
    let g = (z * sxy - sx * sy) / denom;
    let e = (sy - g * sx) / z;
    (g, e)
}

/// Power ratio to decibels.
pub fn db10(ratio: f64) -> f64 {
    10.0 * ratio.log10()
}

/// Compute-SNR of Eq. (15): var(nominal) / var(nominal - actual), in dB.
pub fn compute_snr_db(nominal: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(nominal.len(), actual.len());
    let err: Vec<f64> = nominal.iter().zip(actual).map(|(n, a)| n - a).collect();
    let ve = variance(&err);
    if ve == 0.0 {
        return f64::INFINITY;
    }
    db10(variance(nominal) / ve)
}

/// SNR (dB) -> effective number of bits, ENOB = (SNR - 1.76) / 6.02.
pub fn enob(snr_db: f64) -> f64 {
    (snr_db - 1.76) / 6.02
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            let b = ((x - lo) / w) as usize;
            h[b.min(bins - 1)] += 1;
        }
    }
    h
}

/// Root-mean-square of a slice.
pub fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
    }

    #[test]
    fn linfit_exact_line() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y: Vec<f64> = x.iter().map(|v| 2.5 * v - 1.0).collect();
        let (g, e) = linfit(&x, &y);
        assert!((g - 2.5).abs() < 1e-12);
        assert!((e + 1.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_under_noise() {
        let mut rng = crate::util::rng::Rng::new(1);
        let x: Vec<f64> = (0..200).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 0.9 * v + 5.0 + rng.normal() * 0.1).collect();
        let (g, e) = linfit(&x, &y);
        assert!((g - 0.9).abs() < 1e-3, "g={g}");
        assert!((e - 5.0).abs() < 0.1, "e={e}");
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn snr_of_identical_is_inf() {
        let a = [1.0, 2.0, 3.0];
        assert!(compute_snr_db(&a, &a).is_infinite());
    }

    #[test]
    fn snr_known_value() {
        // signal variance 1.0 (approximately), error variance 0.01 -> 20 dB
        let n: Vec<f64> = (0..1000).map(|i| ((i % 100) as f64 - 49.5) / 28.866).collect();
        let a: Vec<f64> = n
            .iter()
            .enumerate()
            .map(|(i, v)| v + if i % 2 == 0 { 0.1 } else { -0.1 })
            .collect();
        let snr = compute_snr_db(&n, &a);
        assert!((snr - db10(variance(&n) / 0.01)).abs() < 1e-9);
    }

    #[test]
    fn enob_anchor() {
        // 6-bit ideal quantizer ~ 37.9 dB
        assert!((enob(37.88) - 6.0).abs() < 0.01);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.55, 0.9, 1.5];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }
}
