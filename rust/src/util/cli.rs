//! Minimal command-line parser (clap is not vendored in this environment).
//!
//! Supports `program <subcommand> [--flag] [--key value] [positional...]`.
//! Unknown flags are an error; every flag a subcommand reads must be
//! registered by the caller via the accessors, which also drive `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    /// (name, default, help) of every option read, for --help rendering.
    seen: std::cell::RefCell<Vec<(String, String, String)>>,
    help_requested: bool,
}

impl Args {
    pub fn parse_env() -> Self {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(raw: Vec<String>) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut subcommand = None;
        let mut help = false;
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                help = true;
            } else if let Some(name) = tok.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare boolean `--key`
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else if subcommand.is_none() && positional.is_empty() {
                subcommand = Some(tok);
            } else {
                positional.push(tok);
            }
        }
        Self {
            subcommand,
            flags,
            positional,
            seen: Default::default(),
            help_requested: help,
        }
    }

    fn record(&self, name: &str, default: &str, help: &str) {
        self.seen
            .borrow_mut()
            .push((name.to_string(), default.to_string(), help.to_string()));
    }

    pub fn get_str(&self, name: &str, default: &str, help: &str) -> String {
        self.record(name, default, help);
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_u64(&self, name: &str, default: u64, help: &str) -> u64 {
        self.record(name, &default.to_string(), help);
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize, help: &str) -> usize {
        self.get_u64(name, default as u64, help) as usize
    }

    pub fn get_f64(&self, name: &str, default: f64, help: &str) -> f64 {
        self.record(name, &default.to_string(), help);
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn get_bool(&self, name: &str, help: &str) -> bool {
        self.record(name, "false", help);
        matches!(self.flags.get(name).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn help_requested(&self) -> bool {
        self.help_requested
    }

    /// Render collected options; call after all get_* calls of a subcommand.
    pub fn render_help(&self, usage: &str) -> String {
        let mut out = format!("usage: {usage}\n\noptions:\n");
        for (name, default, help) in self.seen.borrow().iter() {
            out.push_str(&format!("  --{name:<18} {help} [default: {default}]\n"));
        }
        out
    }

    /// Error on any flag that was never read by the subcommand.
    pub fn check_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        for k in self.flags.keys() {
            if !seen.iter().any(|(n, _, _)| n == k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()).collect())
    }

    #[test]
    fn subcommand_and_flags() {
        let a = args(&["run", "--batch", "32", "--fast", "--name=x"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_u64("batch", 1, ""), 32);
        assert!(a.get_bool("fast", ""));
        assert_eq!(a.get_str("name", "", ""), "x");
    }

    #[test]
    fn defaults_apply() {
        let a = args(&["run"]);
        assert_eq!(a.get_u64("batch", 7, ""), 7);
        assert!(!a.get_bool("fast", ""));
        assert_eq!(a.get_f64("sigma", 1.5, ""), 1.5);
    }

    #[test]
    fn positional_after_subcommand() {
        let a = args(&["calibrate", "path/to/file", "--z", "8"]);
        assert_eq!(a.positional(), &["path/to/file".to_string()]);
        assert_eq!(a.get_u64("z", 4, ""), 8);
    }

    #[test]
    fn unknown_flag_detected() {
        let a = args(&["run", "--bogus", "1"]);
        let _ = a.get_u64("batch", 1, "");
        assert!(a.check_unknown().is_err());
    }

    #[test]
    fn help_flag() {
        let a = args(&["run", "--help"]);
        assert!(a.help_requested());
    }
}
