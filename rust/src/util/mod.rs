//! Infrastructure utilities built in-repo (the usual crates — rand, clap,
//! criterion, proptest, serde — are not available offline; DESIGN.md §2).

pub mod bench;
pub mod cli;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod table;
pub mod json;
pub mod wake;
