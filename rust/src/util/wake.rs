//! Cross-thread wakeup for a poll(2)-blocked event loop.
//!
//! The wire front-end's poller thread sleeps in `poll(2)` on its sockets.
//! Worker threads finishing jobs need to interrupt that sleep so replies
//! flush promptly. The classic self-pipe trick: a socketpair whose read
//! end joins the poll set; `wake()` writes one byte to the write end.
//! Built on `UnixStream::pair()` so no raw `pipe(2)` syscall declaration
//! is needed — std owns the fds and their lifetime.
//!
//! The handle is cheap to clone and safe to call from any thread. Both
//! ends are non-blocking: a `wake()` against an already-full buffer is a
//! no-op (the poller is already scheduled to wake), and `drain()` reads
//! until `WouldBlock`.
//!
//! On non-unix targets the handle degrades to a no-op; the poller
//! fallback there runs on a short timeout instead of edge wakeups.

#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

#[cfg(unix)]
mod imp {
    use std::io::{Read, Write};
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;
    use std::sync::Arc;

    /// The write side: cloned into worker-facing reply senders.
    #[derive(Clone)]
    pub struct WakeHandle {
        tx: Arc<UnixStream>,
    }

    /// The read side: owned by the poller; its fd joins the poll set.
    pub struct WakeReceiver {
        rx: UnixStream,
    }

    /// Build a connected wake pair, both ends non-blocking.
    pub fn wake_pair() -> std::io::Result<(WakeHandle, WakeReceiver)> {
        let (tx, rx) = UnixStream::pair()?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        Ok((WakeHandle { tx: Arc::new(tx) }, WakeReceiver { rx }))
    }

    impl WakeHandle {
        /// Nudge the poller. Never blocks: if the socketpair buffer is
        /// full the poller already has a pending wakeup, and any other
        /// error means the receiver is gone — the loop is shutting down
        /// and the nudge is moot either way.
        pub fn wake(&self) {
            let _ = (&*self.tx).write(&[1u8]);
        }
    }

    impl WakeReceiver {
        /// The pollable fd (registered for readability in the poll set).
        pub fn raw_fd(&self) -> i32 {
            self.rx.as_raw_fd()
        }

        /// Consume all pending wake bytes; returns whether any were read.
        pub fn drain(&mut self) -> bool {
            let mut buf = [0u8; 64];
            let mut woke = false;
            while let Ok(n) = self.rx.read(&mut buf) {
                if n == 0 {
                    break;
                }
                woke = true;
            }
            woke
        }
    }
}

#[cfg(not(unix))]
mod imp {
    /// No-op stand-in: the portable poller fallback ticks on a timeout,
    /// so explicit wakeups are unnecessary (just slower).
    #[derive(Clone)]
    pub struct WakeHandle;

    pub struct WakeReceiver;

    pub fn wake_pair() -> std::io::Result<(WakeHandle, WakeReceiver)> {
        Ok((WakeHandle, WakeReceiver))
    }

    impl WakeHandle {
        pub fn wake(&self) {}
    }

    impl WakeReceiver {
        pub fn raw_fd(&self) -> i32 {
            -1
        }

        pub fn drain(&mut self) -> bool {
            false
        }
    }
}

pub use imp::{wake_pair, WakeHandle, WakeReceiver};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_is_observable_and_drain_empties() {
        let (tx, mut rx) = wake_pair().unwrap();
        #[cfg(unix)]
        assert!(!rx.drain(), "fresh pair must start empty");
        tx.wake();
        tx.wake();
        #[cfg(unix)]
        {
            assert!(rx.drain(), "wakes must be readable");
            assert!(!rx.drain(), "drain must consume every pending byte");
        }
        let _ = rx.raw_fd();
    }

    #[test]
    fn wake_never_blocks_even_when_unread() {
        let (tx, _rx) = wake_pair().unwrap();
        // far more wakes than the socketpair buffer holds: each must
        // return immediately (WouldBlock is swallowed by design)
        for _ in 0..100_000 {
            tx.wake();
        }
    }
}
