//! Miniature property-based testing helper (proptest/quickcheck are not
//! vendored). `forall` runs a closure over many seeded random cases and, on
//! failure, reports the failing seed so the case can be replayed with
//! `forall_seeded`.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 128;

/// Run `prop` for `cases` random seeds; panic with the failing seed on the
/// first counterexample. The closure receives a fresh deterministic `Rng`.
pub fn forall<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    for case in 0..cases {
        let seed = 0xACE0_0000 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at seed {seed:#x} (case {case}): {msg}");
        }
    }
}

/// Replay a single case.
pub fn forall_seeded<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property `{name}` failed at seed {seed:#x}: {msg}");
    }
}

/// Assertion helpers returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!("{:?} != {:?}", a, b));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall("count", 16, |_rng| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 16);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        forall("fails", 8, |rng| {
            let v = rng.int_in(0, 10);
            prop_assert!(v < 100, "v={v}");
            if v >= 0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }
}
