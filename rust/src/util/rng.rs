//! Deterministic PRNG for Monte-Carlo variation sampling and noise.
//!
//! The crates-io `rand` family is not vendored in this environment, so we
//! ship a small, well-known generator: SplitMix64 for seeding and stream
//! splitting, xoshiro256++ for bulk generation, plus Box-Muller normals.
//! Every simulation draw in the repository flows through this module, so a
//! fixed seed reproduces every figure bit-for-bit.

/// SplitMix64 — used to expand user seeds into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    /// Derive an independent stream (e.g. one per column / per experiment).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Fill a vector of standard normals scaled by sigma.
    pub fn normal_vec(&mut self, n: usize, sigma: f64) -> Vec<f32> {
        (0..n).map(|_| (self.normal() * sigma) as f32).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn int_in_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.int_in(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.split(1);
        let mut b = base.split(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
