//! Random noise sources (Section II-C: thermal noise, flicker noise,
//! residual random errors that calibration cannot remove).
//!
//! The SA-referred noise is modelled as white Gaussian with rms
//! `sigma_v` plus an optional 1/f (pink) component synthesized by the
//! Voss-McCartney algorithm. BISC averages repeated reads to suppress it
//! (Section VI-C); the residual floor after calibration in Figs. 7/10 comes
//! from here.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct NoiseModel {
    /// white (thermal) rms [V]
    pub sigma_white: f64,
    /// pink (flicker) rms [V]
    pub sigma_pink: f64,
    rng: Rng,
    /// Voss-McCartney rows for pink noise
    pink_rows: [f64; 16],
    pink_counter: u64,
}

impl NoiseModel {
    pub fn new(sigma_white: f64, sigma_pink: f64, seed: u64) -> Self {
        Self {
            sigma_white,
            sigma_pink,
            rng: Rng::new(seed ^ 0x4E01_5E00),
            pink_rows: [0.0; 16],
            pink_counter: 0,
        }
    }

    pub fn silent() -> Self {
        Self::new(0.0, 0.0, 0)
    }

    /// One SA-referred noise sample [V].
    pub fn sample(&mut self) -> f64 {
        let white = self.rng.normal() * self.sigma_white;
        let pink = if self.sigma_pink > 0.0 { self.pink_sample() } else { 0.0 };
        white + pink
    }

    /// Voss-McCartney: update the row selected by the trailing zeros of the
    /// counter, sum all rows; normalized by sqrt(rows) to keep rms ~ sigma.
    fn pink_sample(&mut self) -> f64 {
        self.pink_counter = self.pink_counter.wrapping_add(1);
        let row = (self.pink_counter.trailing_zeros() as usize).min(self.pink_rows.len() - 1);
        self.pink_rows[row] = self.rng.normal();
        let sum: f64 = self.pink_rows.iter().sum();
        sum * self.sigma_pink / (self.pink_rows.len() as f64).sqrt()
    }

    /// Average of `n` samples — models BISC's repeated-read averaging.
    pub fn averaged(&mut self, n: usize) -> f64 {
        assert!(n > 0);
        (0..n).map(|_| self.sample()).sum::<f64>() / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn silent_is_zero() {
        let mut nm = NoiseModel::silent();
        for _ in 0..10 {
            assert_eq!(nm.sample(), 0.0);
        }
    }

    #[test]
    fn white_rms_matches_sigma() {
        let mut nm = NoiseModel::new(1.5e-3, 0.0, 7);
        let xs: Vec<f64> = (0..40_000).map(|_| nm.sample()).collect();
        let rms = stats::rms(&xs);
        assert!((rms - 1.5e-3).abs() < 0.1e-3, "rms={rms}");
    }

    #[test]
    fn averaging_reduces_variance() {
        let mut nm = NoiseModel::new(1.0e-3, 0.0, 9);
        let raw: Vec<f64> = (0..4_000).map(|_| nm.sample()).collect();
        let avg: Vec<f64> = (0..4_000).map(|_| nm.averaged(16)).collect();
        let r = stats::variance(&avg) / stats::variance(&raw);
        // 16x averaging => ~1/16 variance
        assert!(r < 0.12, "ratio={r}");
    }

    #[test]
    fn pink_noise_has_low_frequency_energy() {
        // crude check: adjacent-sample correlation of pink > white
        let mut white = NoiseModel::new(1e-3, 0.0, 3);
        let mut pink = NoiseModel::new(0.0, 1e-3, 3);
        let corr = |nm: &mut NoiseModel| {
            let xs: Vec<f64> = (0..20_000).map(|_| nm.sample()).collect();
            let m = stats::mean(&xs);
            let num: f64 = xs.windows(2).map(|w| (w[0] - m) * (w[1] - m)).sum();
            num / stats::variance(&xs) / (xs.len() - 1) as f64
        };
        assert!(corr(&mut pink) > corr(&mut white) + 0.1);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = NoiseModel::new(1e-3, 1e-4, 42);
        let mut b = NoiseModel::new(1e-3, 1e-4, 42);
        for _ in 0..100 {
            assert_eq!(a.sample(), b.sample());
        }
    }
}
