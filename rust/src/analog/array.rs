//! The 36x32 MWC crossbar with the interconnect parasitics of Fig. 1.
//!
//! Golden (explicit per-cell) evaluation path. The structural parasitics
//! are modelled first-order, matching the JAX model exactly:
//!   * `kappa_in`  — input-voltage attenuation across columns (effect 4):
//!                   the differential seen by column c is scaled by
//!                   (1 - kappa_in * c/(M-1)).
//!   * `kappa_reg` — summation-node regulation droop across rows (effect
//!                   5): cell conductance at row r is scaled by
//!                   (1 - kappa_reg * r/(N-1)).
//! Cell-level mismatch (effect 6) lives in each `Mwc::delta`.

use super::consts as c;
use super::faults::{CellFault, StuckLevel};
use super::mwc::{Line, Mwc};

#[derive(Debug, Clone)]
pub struct CrossbarArray {
    /// row-major cells\[r * M + c\]
    cells: Vec<Mwc>,
    /// welded cells (hard faults): forced into `cells` now and re-forced
    /// after every reprogram — writing the SRAM does not fix silicon
    faults: Vec<CellFault>,
    pub kappa_in: f64,
    pub kappa_reg: f64,
}

impl CrossbarArray {
    pub fn new(kappa_in: f64, kappa_reg: f64) -> Self {
        Self {
            cells: vec![Mwc::default(); c::N_ROWS * c::M_COLS],
            faults: Vec::new(),
            kappa_in,
            kappa_reg,
        }
    }

    pub fn ideal() -> Self {
        Self::new(0.0, 0.0)
    }

    pub fn cell(&self, row: usize, col: usize) -> &Mwc {
        &self.cells[row * c::M_COLS + col]
    }

    pub fn cell_mut(&mut self, row: usize, col: usize) -> &mut Mwc {
        &mut self.cells[row * c::M_COLS + col]
    }

    /// Program the whole array from signed codes, preserving each cell's
    /// mismatch delta (weights change, silicon doesn't).
    pub fn program(&mut self, weights: &[i32]) {
        assert_eq!(weights.len(), c::N_ROWS * c::M_COLS);
        for (cell, &w) in self.cells.iter_mut().zip(weights) {
            let delta = cell.delta;
            *cell = Mwc::program(w).with_delta(delta);
        }
        self.reapply_faults();
    }

    /// Program a single column (used by the BISC characterization, which
    /// writes W_max into the column under test).
    pub fn program_column(&mut self, col: usize, weights: &[i32]) {
        assert_eq!(weights.len(), c::N_ROWS);
        for (r, &w) in weights.iter().enumerate() {
            let delta = self.cell(r, col).delta;
            *self.cell_mut(r, col) = Mwc::program(w).with_delta(delta);
        }
        self.reapply_faults();
    }

    /// Weld one cell (hard fault): forced immediately and after every
    /// subsequent program — the fault is in the ladder/switches, not the
    /// SRAM, so reprogramming cannot clear it.
    pub fn inject_cell_fault(&mut self, fault: CellFault) {
        if fault.row >= c::N_ROWS || fault.col >= c::M_COLS {
            return;
        }
        self.faults.push(fault);
        self.force(fault);
    }

    /// The welds installed so far.
    pub fn cell_faults(&self) -> &[CellFault] {
        &self.faults
    }

    fn force(&mut self, fault: CellFault) {
        let cell = self.cell_mut(fault.row, fault.col);
        let delta = cell.delta;
        *cell = match fault.level {
            StuckLevel::G0 => Mwc::program(0),
            StuckLevel::Gmax => Mwc::program(c::CODE_MAX),
        }
        .with_delta(delta);
    }

    fn reapply_faults(&mut self) {
        for i in 0..self.faults.len() {
            let f = self.faults[i];
            self.force(f);
        }
    }

    /// Install per-cell mismatch deltas (row-major N*M).
    pub fn set_deltas(&mut self, deltas: &[f64]) {
        assert_eq!(deltas.len(), c::N_ROWS * c::M_COLS);
        for (cell, &d) in self.cells.iter_mut().zip(deltas) {
            cell.delta = d;
        }
    }

    /// Read back the signed codes (SRAM read path).
    pub fn read_weights(&self) -> Vec<i32> {
        self.cells.iter().map(|m| m.signed_code()).collect()
    }

    /// Attenuation of the input differential at column `col` (effect 4).
    pub fn col_factor(&self, col: usize) -> f64 {
        1.0 - self.kappa_in * col as f64 / (c::M_COLS - 1) as f64
    }

    /// Regulation droop factor at row `row` (effect 5).
    pub fn row_factor(&self, row: usize) -> f64 {
        1.0 - self.kappa_reg * row as f64 / (c::N_ROWS - 1) as f64
    }

    /// Accumulated (I_MAC+, I_MAC-) per column for the given per-row input
    /// differentials — the explicit Eq. (3) evaluation.
    pub fn column_currents(&self, v_diff: &[f64]) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(v_diff.len(), c::N_ROWS);
        let mut i_pos = vec![0.0; c::M_COLS];
        let mut i_neg = vec![0.0; c::M_COLS];
        for r in 0..c::N_ROWS {
            let rowfac = self.row_factor(r);
            for col in 0..c::M_COLS {
                let cell = self.cell(r, col);
                if cell.line == Line::Idle {
                    continue;
                }
                let v = v_diff[r] * self.col_factor(col);
                let i = v * cell.conductance() * rowfac;
                match cell.line {
                    Line::Positive => i_pos[col] += i,
                    Line::Negative => i_neg[col] += i,
                    Line::Idle => unreachable!(),
                }
            }
        }
        (i_pos, i_neg)
    }

    /// Effective summation-node voltage drop along one column — the
    /// "Summation Node Voltage Drop" series of Fig. 1: V_REG as seen at row
    /// r is reduced by the droop factor.
    pub fn vreg_profile(&self, v_reg: f64) -> Vec<f64> {
        (0..c::N_ROWS).map(|r| v_reg * self.row_factor(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::rdac::{InputArray, InputCode, InputDac};

    fn full_input() -> Vec<f64> {
        let arr = InputArray::ideal();
        let _ = arr; // silence
        (0..c::N_ROWS)
            .map(|_| InputDac::default().differential(InputCode(63)))
            .collect()
    }

    #[test]
    fn ideal_grid_equals_matmul() {
        // With kappa = 0 and delta = 0, column currents must equal the
        // dense matmul of Eq. (3).
        let mut arr = CrossbarArray::ideal();
        let mut weights = vec![0i32; c::N_ROWS * c::M_COLS];
        for (i, w) in weights.iter_mut().enumerate() {
            *w = ((i as i32 * 7) % 127) - 63;
        }
        arr.program(&weights);
        let v: Vec<f64> = (0..c::N_ROWS)
            .map(|r| InputDac::default().differential(InputCode((r as i32 % 63) - 31)))
            .collect();
        let (ip, in_) = arr.column_currents(&v);
        for col in 0..c::M_COLS {
            let mut expect = 0.0;
            for r in 0..c::N_ROWS {
                let w = weights[r * c::M_COLS + col] as f64;
                expect += v[r] * w / 64.0 / c::R_U;
            }
            let got = ip[col] - in_[col];
            assert!((got - expect).abs() < 1e-15, "col {col}: {got} vs {expect}");
        }
    }

    #[test]
    fn parasitic_attenuation_monotone_across_columns() {
        let mut arr = CrossbarArray::new(0.05, 0.0);
        arr.program(&vec![63; c::N_ROWS * c::M_COLS]);
        let (ip, _) = arr.column_currents(&full_input());
        for col in 1..c::M_COLS {
            assert!(ip[col] < ip[col - 1], "col {col} not attenuated");
        }
    }

    #[test]
    fn regulation_droop_reduces_total_current() {
        let mut a = CrossbarArray::new(0.0, 0.0);
        let mut b = CrossbarArray::new(0.0, 0.05);
        let w = vec![63; c::N_ROWS * c::M_COLS];
        a.program(&w);
        b.program(&w);
        let (ia, _) = a.column_currents(&full_input());
        let (ib, _) = b.column_currents(&full_input());
        assert!(ib[0] < ia[0]);
        // droop profile decreases across rows
        let prof = b.vreg_profile(c::V_BIAS);
        assert!(prof.windows(2).all(|w| w[1] < w[0]));
    }

    #[test]
    fn program_preserves_deltas() {
        let mut arr = CrossbarArray::ideal();
        let deltas: Vec<f64> = (0..c::N_ROWS * c::M_COLS).map(|i| i as f64 * 1e-4).collect();
        arr.set_deltas(&deltas);
        arr.program(&vec![5; c::N_ROWS * c::M_COLS]);
        assert_eq!(arr.cell(3, 4).delta, deltas[3 * c::M_COLS + 4]);
    }

    #[test]
    fn program_column_only_touches_column() {
        let mut arr = CrossbarArray::ideal();
        arr.program(&vec![7; c::N_ROWS * c::M_COLS]);
        arr.program_column(5, &vec![-63; c::N_ROWS]);
        assert_eq!(arr.cell(0, 5).signed_code(), -63);
        assert_eq!(arr.cell(0, 4).signed_code(), 7);
        assert_eq!(arr.cell(c::N_ROWS - 1, 6).signed_code(), 7);
    }

    #[test]
    fn welded_cells_survive_reprogramming() {
        let mut arr = CrossbarArray::ideal();
        arr.inject_cell_fault(CellFault { row: 2, col: 3, level: StuckLevel::G0 });
        arr.inject_cell_fault(CellFault { row: 4, col: 5, level: StuckLevel::Gmax });
        arr.program(&vec![17; c::N_ROWS * c::M_COLS]);
        assert_eq!(arr.cell(2, 3).signed_code(), 0);
        assert_eq!(arr.cell(4, 5).signed_code(), c::CODE_MAX);
        arr.program_column(3, &vec![-9; c::N_ROWS]);
        assert_eq!(arr.cell(2, 3).signed_code(), 0, "column rewrite cannot heal a weld");
        assert_eq!(arr.cell(0, 3).signed_code(), -9, "healthy cells in the column reprogram");
        // out-of-range welds are ignored, not panics
        arr.inject_cell_fault(CellFault { row: 99, col: 0, level: StuckLevel::G0 });
        assert_eq!(arr.cell_faults().len(), 2);
    }

    #[test]
    fn read_weights_roundtrip() {
        let mut arr = CrossbarArray::ideal();
        let w: Vec<i32> = (0..c::N_ROWS * c::M_COLS)
            .map(|i| ((i as i32 * 13) % 127) - 63)
            .collect();
        arr.program(&w);
        assert_eq!(arr.read_weights(), w);
    }
}
