//! Monte-Carlo process-variation sampling — one `VariationSample` is "one
//! die": every per-row, per-column and per-cell parameter drawn from the
//! configured sigmas (DESIGN.md §2 maps each field to a Fig. 1 effect).
//!
//! The same sample is fed to BOTH the rust golden model and the AOT HLO
//! artifact, which is what makes the parity test meaningful.

use super::consts as c;
use crate::config::SimConfig;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct VariationSample {
    /// per-row input-DAC gain errors (~1.0)
    pub dac_gain: Vec<f64>,
    /// per-row input-DAC offsets [V]
    pub dac_off: Vec<f64>,
    /// per-cell conductance mismatch, row-major N*M
    pub cell_delta: Vec<f64>,
    /// per-column SA positive-line gain errors
    pub alpha_p: Vec<f64>,
    /// per-column SA negative-line gain errors
    pub alpha_n: Vec<f64>,
    /// per-column SA input-referred offsets [V]
    pub beta: Vec<f64>,
    /// per-column SA cubic distortion coefficients [V^-2]
    pub gamma3: Vec<f64>,
    /// ADC gain error
    pub adc_alpha: f64,
    /// ADC offset error [codes]
    pub adc_beta: f64,
    /// structural parasitics
    pub kappa_in: f64,
    pub kappa_reg: f64,
    /// the seed this die was drawn from
    pub seed: u64,
}

impl VariationSample {
    /// Draw one die from the config's sigmas.
    pub fn draw(cfg: &SimConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let mut row_rng = rng.split(1);
        let mut cell_rng = rng.split(2);
        let mut col_rng = rng.split(3);
        let mut adc_rng = rng.split(4);
        Self {
            dac_gain: (0..c::N_ROWS)
                .map(|_| row_rng.normal_ms(1.0, cfg.sigma_dac_gain))
                .collect(),
            dac_off: (0..c::N_ROWS)
                .map(|_| row_rng.normal_ms(0.0, cfg.sigma_dac_off))
                .collect(),
            cell_delta: (0..c::N_ROWS * c::M_COLS)
                .map(|_| cell_rng.normal_ms(0.0, cfg.sigma_cell))
                .collect(),
            alpha_p: (0..c::M_COLS)
                .map(|_| col_rng.normal_ms(1.0, cfg.sigma_sa_gain))
                .collect(),
            alpha_n: (0..c::M_COLS)
                .map(|_| col_rng.normal_ms(1.0, cfg.sigma_sa_gain))
                .collect(),
            beta: (0..c::M_COLS)
                .map(|_| col_rng.normal_ms(0.0, cfg.sigma_sa_off))
                .collect(),
            // truncated at +/-1.5 sigma: amplifiers are designed so the
            // cubic stays within spec — unbounded tails would create
            // columns no linear calibration could ever serve (the paper's
            // Fig. 10 shows every column reaching the 18-24 dB band)
            gamma3: (0..c::M_COLS)
                .map(|_| {
                    let lim = 1.5 * cfg.sigma_sa_nonlin;
                    col_rng.normal_ms(0.0, cfg.sigma_sa_nonlin).clamp(-lim, lim)
                })
                .collect(),
            adc_alpha: adc_rng.normal_ms(1.0, cfg.sigma_adc_gain),
            adc_beta: adc_rng.normal_ms(0.0, cfg.sigma_adc_off),
            kappa_in: cfg.kappa_in,
            kappa_reg: cfg.kappa_reg,
            seed: cfg.seed,
        }
    }

    /// The error-free die ("simulation" baseline of §VII-C).
    pub fn ideal() -> Self {
        Self {
            dac_gain: vec![1.0; c::N_ROWS],
            dac_off: vec![0.0; c::N_ROWS],
            cell_delta: vec![0.0; c::N_ROWS * c::M_COLS],
            alpha_p: vec![1.0; c::M_COLS],
            alpha_n: vec![1.0; c::M_COLS],
            beta: vec![0.0; c::M_COLS],
            gamma3: vec![0.0; c::M_COLS],
            adc_alpha: 1.0,
            adc_beta: 0.0,
            kappa_in: 0.0,
            kappa_reg: 0.0,
            seed: 0,
        }
    }
}

/// Deterministic aging/drift model of one die's analog front-end: each
/// summing-amplifier line gain walks away from its as-calibrated value at
/// a per-column velocity drawn once per die, and the SA offsets creep
/// alongside. One *drift unit* is one S&H period of analog busy time (one
/// MAC read), so the die ages with traffic, not wall-clock — replaying
/// the same request stream replays the same degradation bit-for-bit.
///
/// This is the moving target the paper's periodic self-calibration
/// exists for: BISC trims compensate the CURRENT gains, drift then pulls
/// them away again, and the serving-layer calibrator daemon
/// ([`crate::coordinator::calibrator`]) closes the loop.
#[derive(Debug, Clone)]
pub struct DriftState {
    /// per-column per-unit relative drift velocity, positive SA line
    pub vel_p: Vec<f64>,
    /// per-column per-unit relative drift velocity, negative SA line
    pub vel_n: Vec<f64>,
    /// per-column additive SA offset drift velocity [V per unit]
    pub vel_beta: Vec<f64>,
    /// drift units applied so far (the die's simulated age)
    pub age: u64,
}

impl DriftState {
    /// Drift velocities for one die, or `None` when the config disables
    /// drift (`sigma_drift == 0`). Velocities are drawn from their own
    /// seed stream so enabling drift does not re-deal the static
    /// variation sample of the same seed.
    pub fn draw(cfg: &SimConfig) -> Option<Self> {
        if cfg.sigma_drift <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(cfg.seed ^ 0xD21F_7A6E_5EED_C0DE);
        // offsets creep ~two orders slower than gains drift (in volts the
        // V_BIAS-relative scale keeps both effects sub-dominant per unit)
        let beta_sigma = cfg.sigma_drift * 0.01;
        Some(Self {
            vel_p: (0..c::M_COLS).map(|_| rng.normal_ms(0.0, cfg.sigma_drift)).collect(),
            vel_n: (0..c::M_COLS).map(|_| rng.normal_ms(0.0, cfg.sigma_drift)).collect(),
            vel_beta: (0..c::M_COLS).map(|_| rng.normal_ms(0.0, beta_sigma)).collect(),
            age: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn draw_is_deterministic() {
        let cfg = SimConfig::default();
        let a = VariationSample::draw(&cfg);
        let b = VariationSample::draw(&cfg);
        assert_eq!(a.dac_gain, b.dac_gain);
        assert_eq!(a.cell_delta, b.cell_delta);
        assert_eq!(a.adc_beta, b.adc_beta);
    }

    #[test]
    fn different_seed_different_die() {
        let cfg = SimConfig::default();
        let mut cfg2 = cfg.clone();
        cfg2.seed ^= 0xDEAD;
        let a = VariationSample::draw(&cfg);
        let b = VariationSample::draw(&cfg2);
        assert_ne!(a.alpha_p, b.alpha_p);
    }

    #[test]
    fn sigma_zero_is_ideal() {
        let mut cfg = SimConfig::default().scaled(0.0);
        cfg.sigma_noise = 0.0;
        let s = VariationSample::draw(&cfg);
        let i = VariationSample::ideal();
        assert_eq!(s.dac_gain, i.dac_gain);
        assert_eq!(s.cell_delta, i.cell_delta);
        assert_eq!(s.adc_alpha, 1.0);
        assert_eq!(s.kappa_in, 0.0);
    }

    #[test]
    fn sampled_sigmas_roughly_match_config() {
        let mut cfg = SimConfig::default();
        cfg.seed = 123;
        // need many draws: aggregate cell deltas (N*M = 1152 per die)
        let s = VariationSample::draw(&cfg);
        let sd = stats::std_dev(&s.cell_delta);
        assert!((sd - cfg.sigma_cell).abs() < cfg.sigma_cell * 0.2, "sd={sd}");
    }

    #[test]
    fn drift_disabled_by_default_and_deterministic_when_on() {
        let cfg = SimConfig::default();
        assert!(DriftState::draw(&cfg).is_none(), "drift must be opt-in");
        let mut cfg_d = cfg.clone();
        cfg_d.sigma_drift = 2e-4;
        let a = DriftState::draw(&cfg_d).expect("drift enabled");
        let b = DriftState::draw(&cfg_d).expect("drift enabled");
        assert_eq!(a.vel_p, b.vel_p);
        assert_eq!(a.vel_beta, b.vel_beta);
        assert_eq!(a.age, 0);
        // enabling drift must not re-deal the static variation sample
        let s0 = VariationSample::draw(&cfg);
        let s1 = VariationSample::draw(&cfg_d);
        assert_eq!(s0.alpha_p, s1.alpha_p);
    }

    #[test]
    fn gain_errors_land_in_paper_range() {
        // Fig. 8b: per-column total gains roughly within [0.75, 1.3]
        let cfg = SimConfig::default();
        let s = VariationSample::draw(&cfg);
        for (&ap, &an) in s.alpha_p.iter().zip(&s.alpha_n) {
            let g = ap * s.adc_alpha;
            assert!(g > 0.6 && g < 1.45, "g={g}");
            let g = an * s.adc_alpha;
            assert!(g > 0.6 && g < 1.45, "g={g}");
        }
    }
}
