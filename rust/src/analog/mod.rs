//! Circuit-level behavioural model of the mixed-signal CIM core — the rust
//! "golden" reference that mirrors the JAX/Pallas artifact math exactly
//! (see python/compile/kernels/ref.py).
//!
//! Two evaluation paths:
//!   * `forward_golden` — explicit per-cell walk through every component
//!     (DAC -> parasitics -> MWC -> 2SA -> ADC). Slow, maximally checkable.
//!   * `forward_batch`  — the algebraically folded form (two GEMMs + affine
//!     epilogue), identical math, used on the hot path. `tests` +
//!     `fast_matches_golden` keep the two in lock-step.

pub mod adc;
pub mod array;
pub mod consts;
pub mod mwc;
pub mod noise;
pub mod power;
pub mod rdac;
pub mod samp;
pub mod variation;

use adc::FlashAdc;
use array::CrossbarArray;
use consts as c;
use noise::NoiseModel;
use rdac::{InputCode, InputDac};
use samp::SummingAmp;
use variation::{DriftState, VariationSample};

use crate::config::SimConfig;

/// The complete mixed-signal CIM core of one die.
pub struct CimAnalogModel {
    pub dacs: Vec<InputDac>,
    pub array: CrossbarArray,
    pub amps: Vec<SummingAmp>,
    pub adc: FlashAdc,
    pub noise: NoiseModel,
    /// temporal drift of the SA gains/offsets (`None` = frozen die);
    /// advanced by [`CimAnalogModel::advance_drift`] as traffic ages it
    drift: Option<DriftState>,
    /// folded fast-path state (rebuilt lazily after programming/trimming)
    folded: Option<Folded>,
}

/// Folded coefficients:
///   q_lin = xe·G + qc,  G = Gp·diag(qa) - Gn·diag(qb)   (single GEMM —
///   the per-column epilogue scalars fold into the conductance matrix,
///   §Perf optimization 1)
///   q     = clip(round(q_lin + qd*(q_lin - qm)^3 + noise))
///
/// `Folded` is also the unit of the DNN scheduler's tile cache (§Perf
/// optimization 2): a weight tile folded once under fixed trims/refs can
/// be replayed on every inference without re-programming the array model.
#[derive(Clone)]
pub struct Folded {
    /// combined, column-scaled conductances, N*M row-major
    g_comb: Vec<f32>,
    qc: Vec<f32>, // M
    qd: Vec<f32>,
    qm: Vec<f32>,
}

impl CimAnalogModel {
    /// Build a die from a variation sample + config (noise seeded from the
    /// die seed so the whole experiment replays from one number).
    pub fn from_sample(cfg: &SimConfig, s: &VariationSample) -> Self {
        let dacs = (0..c::N_ROWS)
            .map(|r| InputDac { gain: s.dac_gain[r], offset: s.dac_off[r], r_out: 0.0 })
            .collect();
        let mut array = CrossbarArray::new(s.kappa_in, s.kappa_reg);
        array.set_deltas(&s.cell_delta);
        let amps = (0..c::M_COLS)
            .map(|col| SummingAmp {
                alpha_p: s.alpha_p[col],
                alpha_n: s.alpha_n[col],
                beta: s.beta[col],
                gamma3: s.gamma3[col],
                ..Default::default()
            })
            .collect();
        let adc = FlashAdc { alpha_d: s.adc_alpha, beta_d: s.adc_beta, ..Default::default() };
        let noise = NoiseModel::new(cfg.sigma_noise, cfg.sigma_noise * 0.3, s.seed);
        Self { dacs, array, amps, adc, noise, drift: DriftState::draw(cfg), folded: None }
    }

    /// Error-free die with silent noise.
    pub fn ideal() -> Self {
        let cfg = SimConfig { sigma_noise: 0.0, ..SimConfig::default() };
        Self::from_sample(&cfg, &VariationSample::ideal())
    }

    pub fn program(&mut self, weights: &[i32]) {
        self.array.program(weights);
        self.folded = None;
    }

    pub fn program_column(&mut self, col: usize, weights: &[i32]) {
        self.array.program_column(col, weights);
        self.folded = None;
    }

    /// Invalidate the folded fast-path state after direct array mutation
    /// (e.g. the AXI weight write port programming single cells).
    pub fn invalidate_fold(&mut self) {
        self.folded = None;
    }

    /// Apply BISC trim codes to one column.
    pub fn set_trims(&mut self, col: usize, pot_p: u32, pot_n: u32, cal: u32) {
        let amp = &mut self.amps[col];
        amp.pot_p = pot_p;
        amp.pot_n = pot_n;
        amp.cal = cal;
        self.folded = None;
    }

    /// ADC reference control (BISC clipping avoidance, Alg. 1).
    pub fn set_adc_refs(&mut self, v_l: f64, v_h: f64) {
        self.adc.v_l = v_l;
        self.adc.v_h = v_h;
        self.folded = None;
    }

    /// Whether this die carries a drift model (`sigma_drift > 0`).
    pub fn has_drift(&self) -> bool {
        self.drift.is_some()
    }

    /// Drift units applied so far (the die's simulated age).
    pub fn drift_age(&self) -> u64 {
        self.drift.as_ref().map_or(0, |d| d.age)
    }

    /// Age the die by `units` drift ticks (one unit = one S&H period of
    /// analog busy time): every SA line gain walks by its per-column
    /// velocity and the offsets creep alongside, then the folded
    /// fast-path state is invalidated so the next evaluation sees the
    /// drifted amplifiers. No-op on a frozen die (`sigma_drift == 0`),
    /// so the hot path pays nothing when drift is disabled.
    ///
    /// Characterization reads issued through the model directly (BISC,
    /// health probes) do NOT age the die — only served traffic does, via
    /// the backends in [`crate::coordinator`] — so probing for drift
    /// never masquerades as drift itself.
    pub fn advance_drift(&mut self, units: u64) {
        let Some(d) = self.drift.as_mut() else { return };
        if units == 0 {
            return;
        }
        d.age += units;
        // (1 + v)^k applied in closed form so a large batch advances in
        // O(M) instead of O(M * batch)
        let k = units.min(i32::MAX as u64) as i32;
        for col in 0..c::M_COLS {
            let amp = &mut self.amps[col];
            amp.alpha_p *= (1.0 + d.vel_p[col]).powi(k);
            amp.alpha_n *= (1.0 + d.vel_n[col]).powi(k);
            amp.beta += d.vel_beta[col] * units as f64;
        }
        self.folded = None;
    }

    /// Pre-ADC SA output voltages for one input vector (noise-free) —
    /// used by Fig. 7's error-distribution reproduction.
    pub fn sa_outputs(&self, x: &[i32]) -> Vec<f64> {
        let v: Vec<f64> = self
            .dacs
            .iter()
            .zip(x)
            .map(|(d, &code)| d.differential(InputCode::clamp(code)))
            .collect();
        let (i_pos, i_neg) = self.array.column_currents(&v);
        (0..c::M_COLS)
            .map(|col| self.amps[col].output(i_pos[col], i_neg[col]))
            .collect()
    }

    /// Golden path: one input vector -> M ADC codes, with noise.
    pub fn forward_golden(&mut self, x: &[i32]) -> Vec<u32> {
        let mut v_sa = self.sa_outputs(x);
        for v in v_sa.iter_mut() {
            *v += self.noise.sample();
        }
        v_sa.iter().map(|&v| self.adc.quantize(v)).collect()
    }

    /// Golden path with per-read averaging (BISC characterization reads).
    pub fn forward_averaged(&mut self, x: &[i32], reads: usize) -> Vec<f64> {
        assert!(reads > 0);
        let mut acc = vec![0.0; c::M_COLS];
        for _ in 0..reads {
            let q = self.forward_golden(x);
            for (a, &qi) in acc.iter_mut().zip(&q) {
                *a += qi as f64;
            }
        }
        acc.iter_mut().for_each(|a| *a /= reads as f64);
        acc
    }

    fn fold(&mut self) {
        let c_adc = self.adc.conv_factor();
        let a = self.adc.alpha_d * c_adc;
        let mut qa = vec![0f64; c::M_COLS];
        let mut qb = vec![0f64; c::M_COLS];
        let mut qc = vec![0f32; c::M_COLS];
        let mut qd = vec![0f32; c::M_COLS];
        let mut qm = vec![0f32; c::M_COLS];
        for col in 0..c::M_COLS {
            let amp = &self.amps[col];
            let colfac = self.array.col_factor(col);
            let scale = a * colfac;
            qa[col] = scale * amp.alpha_p * amp.rsa_p();
            qb[col] = scale * amp.alpha_n * amp.rsa_n();
            qc[col] = (a * (amp.vcal() + amp.beta - self.adc.v_l) + self.adc.beta_d) as f32;
            // cubic distortion in code units (see python model.fold_params)
            qd[col] = (amp.gamma3 / (a * a)) as f32;
            qm[col] = (a * (c::V_BIAS - self.adc.v_l) + self.adc.beta_d) as f32;
        }
        // single-GEMM fold: the positive/negative line split collapses
        // because qa/qb are per-column constants
        let mut g_comb = vec![0f32; c::N_ROWS * c::M_COLS];
        for r in 0..c::N_ROWS {
            let rowfac = self.array.row_factor(r);
            for col in 0..c::M_COLS {
                let cell = self.array.cell(r, col);
                let g = cell.conductance() * rowfac;
                g_comb[r * c::M_COLS + col] = match cell.line {
                    mwc::Line::Positive => (g * qa[col]) as f32,
                    mwc::Line::Negative => (-g * qb[col]) as f32,
                    mwc::Line::Idle => 0.0,
                };
            }
        }
        self.folded = Some(Folded { g_comb, qc, qd, qm });
    }

    /// Folded fast path: batch of input vectors (row-major B x N) -> ADC
    /// codes (B x M). Noise-free (deterministic hot path; callers needing
    /// noise add it explicitly like the HLO artifact's noise operand).
    pub fn forward_batch(&mut self, x: &[i32], batch: usize) -> Vec<u32> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        if self.folded.is_none() {
            self.fold();
        }
        // fold input DAC transfer: xe = gain*x*lsb + off
        let lsb = InputDac::lsb();
        let mut xe = vec![0f32; batch * c::N_ROWS];
        for b in 0..batch {
            for r in 0..c::N_ROWS {
                let d = &self.dacs[r];
                xe[b * c::N_ROWS + r] =
                    (d.gain * x[b * c::N_ROWS + r] as f64 * lsb + d.offset) as f32;
            }
        }
        let f = self.folded.as_ref().unwrap();
        let mut out = vec![0u32; batch * c::M_COLS];
        // single GEMM: out[b,c] = sum_r xe[b,r] * G[r,c]; N=36 M=32 —
        // the 32-wide column loop auto-vectorizes (§Perf optimization 1)
        for b in 0..batch {
            let xrow = &xe[b * c::N_ROWS..(b + 1) * c::N_ROWS];
            let mut acc = [0f32; c::M_COLS];
            for r in 0..c::N_ROWS {
                let xv = xrow[r];
                if xv == 0.0 {
                    continue;
                }
                let g = &f.g_comb[r * c::M_COLS..(r + 1) * c::M_COLS];
                for col in 0..c::M_COLS {
                    acc[col] += xv * g[col];
                }
            }
            for col in 0..c::M_COLS {
                let q_lin = acc[col] + f.qc[col];
                let t = q_lin - f.qm[col];
                let q = q_lin + f.qd[col] * t * t * t;
                out[b * c::M_COLS + col] =
                    q.round().clamp(0.0, c::ADC_MAX as f32) as u32;
            }
        }
        out
    }

    /// Fold a weight tile under the CURRENT trims/ADC refs and hand the
    /// result to the caller (the DNN scheduler caches these per tile).
    pub fn fold_tile(&mut self, weights: &[i32]) -> Folded {
        self.program(weights);
        self.fold();
        self.folded.as_ref().unwrap().clone()
    }

    /// Evaluate a previously folded tile — identical math to
    /// `forward_batch` but without touching the array state.
    pub fn forward_folded(&self, tile: &Folded, x: &[i32], batch: usize) -> Vec<u32> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        let lsb = InputDac::lsb();
        let mut out = vec![0u32; batch * c::M_COLS];
        let mut xe = [0f32; c::N_ROWS];
        for b in 0..batch {
            for r in 0..c::N_ROWS {
                let d = &self.dacs[r];
                xe[r] = (d.gain * x[b * c::N_ROWS + r] as f64 * lsb + d.offset) as f32;
            }
            let mut acc = [0f32; c::M_COLS];
            for r in 0..c::N_ROWS {
                let xv = xe[r];
                if xv == 0.0 {
                    continue;
                }
                let g = &tile.g_comb[r * c::M_COLS..(r + 1) * c::M_COLS];
                for col in 0..c::M_COLS {
                    acc[col] += xv * g[col];
                }
            }
            for col in 0..c::M_COLS {
                let q_lin = acc[col] + tile.qc[col];
                let t = q_lin - tile.qm[col];
                let q = q_lin + tile.qd[col] * t * t * t;
                out[b * c::M_COLS + col] = q.round().clamp(0.0, c::ADC_MAX as f32) as u32;
            }
        }
        out
    }

    /// Ideal output of Eq. (7) in continuous code units for a batch —
    /// the Q_nom used by BISC and the compute-SNR evaluation.
    pub fn q_nominal(x: &[i32], weights: &[i32], batch: usize) -> Vec<f64> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        assert_eq!(weights.len(), c::N_ROWS * c::M_COLS);
        let k = c::code_gain_nominal();
        let mid = c::q_mid_nominal();
        let mut out = vec![0.0; batch * c::M_COLS];
        for b in 0..batch {
            for col in 0..c::M_COLS {
                let mut s = 0i64;
                for r in 0..c::N_ROWS {
                    s += x[b * c::N_ROWS + r] as i64 * weights[r * c::M_COLS + col] as i64;
                }
                out[b * c::M_COLS + col] = mid + k * s as f64;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_weights(rng: &mut Rng) -> Vec<i32> {
        (0..c::N_ROWS * c::M_COLS)
            .map(|_| rng.int_in(-63, 63) as i32)
            .collect()
    }

    fn random_inputs(rng: &mut Rng, batch: usize) -> Vec<i32> {
        (0..batch * c::N_ROWS)
            .map(|_| rng.int_in(-63, 63) as i32)
            .collect()
    }

    #[test]
    fn fast_matches_golden_noise_free() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        let mut rng = Rng::new(11);
        let w = random_weights(&mut rng);
        m.program(&w);
        let batch = 16;
        let x = random_inputs(&mut rng, batch);
        let fast = m.forward_batch(&x, batch);
        let mut mismatches = 0;
        for b in 0..batch {
            let golden = m.forward_golden(&x[b * c::N_ROWS..(b + 1) * c::N_ROWS]);
            for col in 0..c::M_COLS {
                let f = fast[b * c::M_COLS + col] as i64;
                let g = golden[col] as i64;
                assert!((f - g).abs() <= 1, "b={b} col={col}: {f} vs {g}");
                if f != g {
                    mismatches += 1;
                }
            }
        }
        // f32 vs f64 rounding ties must be rare
        assert!(mismatches < batch * c::M_COLS / 50, "{mismatches} ties");
    }

    #[test]
    fn ideal_die_matches_q_nominal() {
        let mut m = CimAnalogModel::ideal();
        let mut rng = Rng::new(5);
        let w = random_weights(&mut rng);
        m.program(&w);
        let batch = 8;
        let x = random_inputs(&mut rng, batch);
        let q = m.forward_batch(&x, batch);
        let nom = CimAnalogModel::q_nominal(&x, &w, batch);
        for i in 0..batch * c::M_COLS {
            let expect = nom[i].round().clamp(0.0, 63.0);
            assert!(
                (q[i] as f64 - expect).abs() <= 1.0,
                "i={i}: {} vs {expect}",
                q[i]
            );
        }
    }

    #[test]
    fn errors_shift_outputs_away_from_nominal() {
        let cfg = SimConfig::default().scaled(1.0);
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        let mut rng = Rng::new(9);
        let w = random_weights(&mut rng);
        m.program(&w);
        let batch = 32;
        let x = random_inputs(&mut rng, batch);
        let q = m.forward_batch(&x, batch);
        let nom = CimAnalogModel::q_nominal(&x, &w, batch);
        let mean_err: f64 = q
            .iter()
            .zip(&nom)
            .map(|(&a, &n)| (a as f64 - n).abs())
            .sum::<f64>()
            / q.len() as f64;
        assert!(mean_err > 0.5, "errors too small: {mean_err}");
    }

    #[test]
    fn trims_change_transfer() {
        let mut m = CimAnalogModel::ideal();
        let w = vec![40i32; c::N_ROWS * c::M_COLS];
        m.program(&w);
        let x = vec![30i32; c::N_ROWS];
        let q0 = m.forward_batch(&x, 1);
        m.set_trims(0, samp::POT_MAX, samp::POT_MAX, samp::CAL_MAX);
        let q1 = m.forward_batch(&x, 1);
        assert_ne!(q0[0], q1[0]);
        assert_eq!(q0[1], q1[1], "other columns untouched");
    }

    #[test]
    fn adc_refs_rescale_codes() {
        let mut m = CimAnalogModel::ideal();
        m.program(&vec![63; c::N_ROWS * c::M_COLS]);
        let x = vec![63i32; c::N_ROWS];
        let q_tight = m.forward_batch(&x, 1)[0];
        m.set_adc_refs(0.19, 0.63);
        let q_wide = m.forward_batch(&x, 1)[0];
        assert!(q_wide < q_tight, "wider range => smaller code for same V");
    }

    #[test]
    fn drift_ages_the_die_and_moves_outputs() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        cfg.sigma_drift = 5e-4;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        assert!(m.has_drift());
        let w = vec![40i32; c::N_ROWS * c::M_COLS];
        m.program(&w);
        let x = vec![30i32; c::N_ROWS];
        let q0 = m.forward_batch(&x, 1);
        m.advance_drift(500);
        assert_eq!(m.drift_age(), 500);
        let q1 = m.forward_batch(&x, 1);
        assert_ne!(q0, q1, "500 drift units must move the transfer");
        // a frozen die ignores advance_drift entirely
        let mut frozen = CimAnalogModel::ideal();
        frozen.program(&w);
        let f0 = frozen.forward_batch(&x, 1);
        frozen.advance_drift(10_000);
        assert_eq!(frozen.drift_age(), 0);
        assert_eq!(frozen.forward_batch(&x, 1), f0);
    }

    #[test]
    fn noise_perturbs_golden_path() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.01; // huge: ~1.6 codes rms
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        m.program(&vec![20; c::N_ROWS * c::M_COLS]);
        let x = vec![20i32; c::N_ROWS];
        let a = m.forward_golden(&x);
        let b = m.forward_golden(&x);
        assert_ne!(a, b, "independent noise draws should differ");
    }

    #[test]
    fn averaging_converges_to_noise_free() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.005;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        m.program(&vec![30; c::N_ROWS * c::M_COLS]);
        let x = vec![25i32; c::N_ROWS];
        let avg = m.forward_averaged(&x, 64);
        cfg.sigma_noise = 0.0;
        let mut m2 = CimAnalogModel::from_sample(&cfg, &sample);
        m2.program(&vec![30; c::N_ROWS * c::M_COLS]);
        let clean = m2.forward_batch(&x, 1);
        for col in 0..c::M_COLS {
            assert!(
                (avg[col] - clean[col] as f64).abs() < 1.5,
                "col {col}: {} vs {}",
                avg[col],
                clean[col]
            );
        }
    }
}
