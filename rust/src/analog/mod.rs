//! Circuit-level behavioural model of the mixed-signal CIM core — the rust
//! "golden" reference that mirrors the JAX/Pallas artifact math exactly
//! (see python/compile/kernels/ref.py).
//!
//! Two evaluation paths:
//!   * `forward_golden` — explicit per-cell walk through every component
//!     (DAC -> parasitics -> MWC -> 2SA -> ADC). Slow, maximally checkable.
//!   * `forward_batch`  — the algebraically folded form (two GEMMs + affine
//!     epilogue), identical math, used on the hot path. `tests` +
//!     `fast_matches_golden` keep the two in lock-step.

pub mod adc;
pub mod array;
pub mod consts;
pub mod faults;
pub mod mwc;
pub mod noise;
pub mod power;
pub mod rdac;
pub mod samp;
pub mod variation;

use adc::FlashAdc;
use array::CrossbarArray;
use consts as c;
use faults::FaultMap;
use noise::NoiseModel;
use rdac::{InputCode, InputDac};
use samp::SummingAmp;
use variation::{DriftState, VariationSample};

use crate::config::SimConfig;

/// The complete mixed-signal CIM core of one die.
pub struct CimAnalogModel {
    pub dacs: Vec<InputDac>,
    pub array: CrossbarArray,
    pub amps: Vec<SummingAmp>,
    pub adc: FlashAdc,
    pub noise: NoiseModel,
    /// temporal drift of the SA gains/offsets (`None` = frozen die);
    /// advanced by [`CimAnalogModel::advance_drift`] as traffic ages it
    drift: Option<DriftState>,
    /// per-column hard-fault ADC overrides: a wedged slice always emits
    /// this code (applied after quantization on the golden path, baked
    /// into the fold on the fast path)
    stuck_adc: Vec<Option<u32>>,
    /// folded fast-path state (rebuilt lazily after programming/trimming)
    folded: Option<Folded>,
    /// reusable evaluation scratch for the `&mut self` fast-path entry
    /// points — steady-state serving re-runs the folded GEMM with zero
    /// heap allocations (DESIGN.md §11)
    scratch: MacScratch,
}

/// Folded coefficients:
///   xe    = x·diag(dac_gain_lsb) + dac_off           (per-row DAC fold)
///   q_lin = xe·G + qc,  G = Gp·diag(qa) - Gn·diag(qb)   (single GEMM —
///   the per-column epilogue scalars fold into the conductance matrix,
///   §Perf optimization 1)
///   q     = clip(round(q_lin + qd*(q_lin - qm)^3 + noise))
///
/// `Folded` holds EVERYTHING derivable from the die's trims, refs, and
/// weights — including the per-row input-DAC transfer, hoisted here at
/// fold time so the serve-time loop never re-derives `gain * lsb` in f64
/// per element (it used to, B×N times per call). `Folded` is also the
/// unit of the DNN scheduler's tile cache (§Perf optimization 2): a
/// weight tile folded once under fixed trims/refs can be replayed on
/// every inference without re-programming the array model.
#[derive(Clone)]
pub struct Folded {
    /// combined, column-scaled conductances, N*M row-major
    g_comb: Vec<f32>,
    qc: Vec<f32>, // M
    qd: Vec<f32>,
    qm: Vec<f32>,
    /// per-row DAC transfer, pre-multiplied: xe[r] = x * dac_gain_lsb[r]
    /// + dac_off[r] (N entries each)
    dac_gain_lsb: Vec<f32>,
    dac_off: Vec<f32>,
}

/// Caller-owned scratch for the folded fast path: holds the expanded
/// DAC-domain input buffer between calls so steady-state evaluation
/// allocates nothing (it grows to the largest batch seen and stays).
#[derive(Default)]
pub struct MacScratch {
    xe: Vec<f32>,
}

impl MacScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Folded {
    /// The folded kernel shared by every fast-path entry point: DAC fold
    /// into `scratch`, one GEMM, affine + cubic epilogue into `out`
    /// (cleared and refilled; steady state reuses both buffers without
    /// allocating). Batch rows are evaluated two at a time so the
    /// 32-wide column loop carries twice the independent FMA chains —
    /// the N=36 reduction is latency-bound otherwise.
    fn forward_into(&self, x: &[i32], batch: usize, scratch: &mut MacScratch, out: &mut Vec<u32>) {
        assert_eq!(x.len(), batch * c::N_ROWS);
        let xe = &mut scratch.xe;
        xe.clear();
        xe.reserve(x.len());
        for chunk in x.chunks_exact(c::N_ROWS) {
            for ((&xi, &g), &o) in chunk.iter().zip(&self.dac_gain_lsb).zip(&self.dac_off) {
                xe.push(xi as f32 * g + o);
            }
        }
        out.clear();
        out.resize(batch * c::M_COLS, 0);
        let mut b = 0;
        while b + 2 <= batch {
            let x0 = &xe[b * c::N_ROWS..(b + 1) * c::N_ROWS];
            let x1 = &xe[(b + 1) * c::N_ROWS..(b + 2) * c::N_ROWS];
            let mut acc0 = [0f32; c::M_COLS];
            let mut acc1 = [0f32; c::M_COLS];
            for (r, g) in self.g_comb.chunks_exact(c::M_COLS).enumerate() {
                let (v0, v1) = (x0[r], x1[r]);
                if v0 == 0.0 && v1 == 0.0 {
                    continue;
                }
                // a zero row contributes exactly 0.0 to its accumulator,
                // so pairing a zero with a non-zero row changes nothing
                for col in 0..c::M_COLS {
                    acc0[col] += v0 * g[col];
                    acc1[col] += v1 * g[col];
                }
            }
            self.epilogue(&acc0, &mut out[b * c::M_COLS..(b + 1) * c::M_COLS]);
            self.epilogue(&acc1, &mut out[(b + 1) * c::M_COLS..(b + 2) * c::M_COLS]);
            b += 2;
        }
        if b < batch {
            let xrow = &xe[b * c::N_ROWS..(b + 1) * c::N_ROWS];
            let mut acc = [0f32; c::M_COLS];
            for (r, g) in self.g_comb.chunks_exact(c::M_COLS).enumerate() {
                let xv = xrow[r];
                if xv == 0.0 {
                    continue;
                }
                for col in 0..c::M_COLS {
                    acc[col] += xv * g[col];
                }
            }
            self.epilogue(&acc, &mut out[b * c::M_COLS..(b + 1) * c::M_COLS]);
        }
    }

    /// Affine + cubic-distortion epilogue for one output row.
    #[inline]
    fn epilogue(&self, acc: &[f32; c::M_COLS], out: &mut [u32]) {
        for col in 0..c::M_COLS {
            let q_lin = acc[col] + self.qc[col];
            let t = q_lin - self.qm[col];
            let q = q_lin + self.qd[col] * t * t * t;
            out[col] = q.round().clamp(0.0, c::ADC_MAX as f32) as u32;
        }
    }
}

impl CimAnalogModel {
    /// Build a die from a variation sample + config (noise seeded from the
    /// die seed so the whole experiment replays from one number).
    pub fn from_sample(cfg: &SimConfig, s: &VariationSample) -> Self {
        let dacs = (0..c::N_ROWS)
            .map(|r| InputDac { gain: s.dac_gain[r], offset: s.dac_off[r], r_out: 0.0 })
            .collect();
        let mut array = CrossbarArray::new(s.kappa_in, s.kappa_reg);
        array.set_deltas(&s.cell_delta);
        let amps = (0..c::M_COLS)
            .map(|col| SummingAmp {
                alpha_p: s.alpha_p[col],
                alpha_n: s.alpha_n[col],
                beta: s.beta[col],
                gamma3: s.gamma3[col],
                ..Default::default()
            })
            .collect();
        let adc = FlashAdc { alpha_d: s.adc_alpha, beta_d: s.adc_beta, ..Default::default() };
        let noise = NoiseModel::new(cfg.sigma_noise, cfg.sigma_noise * 0.3, s.seed);
        Self {
            dacs,
            array,
            amps,
            adc,
            noise,
            drift: DriftState::draw(cfg),
            stuck_adc: vec![None; c::M_COLS],
            folded: None,
            scratch: MacScratch::new(),
        }
    }

    /// Error-free die with silent noise.
    pub fn ideal() -> Self {
        let cfg = SimConfig { sigma_noise: 0.0, ..SimConfig::default() };
        Self::from_sample(&cfg, &VariationSample::ideal())
    }

    pub fn program(&mut self, weights: &[i32]) {
        self.array.program(weights);
        self.folded = None;
    }

    pub fn program_column(&mut self, col: usize, weights: &[i32]) {
        self.array.program_column(col, weights);
        self.folded = None;
    }

    /// Invalidate the folded fast-path state after direct array mutation
    /// (e.g. the AXI weight write port programming single cells).
    pub fn invalidate_fold(&mut self) {
        self.folded = None;
    }

    /// Apply BISC trim codes to one column.
    pub fn set_trims(&mut self, col: usize, pot_p: u32, pot_n: u32, cal: u32) {
        let amp = &mut self.amps[col];
        amp.pot_p = pot_p;
        amp.pot_n = pot_n;
        amp.cal = cal;
        self.folded = None;
    }

    /// ADC reference control (BISC clipping avoidance, Alg. 1).
    pub fn set_adc_refs(&mut self, v_l: f64, v_h: f64) {
        self.adc.v_l = v_l;
        self.adc.v_h = v_h;
        self.folded = None;
    }

    /// Strike the die with hard faults (see [`faults`]): stuck cells weld
    /// into the crossbar (and re-weld on every reprogram), railed SAs and
    /// wedged ADC slices override their columns. Permanent — there is no
    /// undo, matching silicon — and visible to the golden path, the BISC
    /// characterization reads, and the folded fast path alike.
    pub fn apply_faults(&mut self, map: &FaultMap) {
        for f in map.cell_faults() {
            self.array.inject_cell_fault(f);
        }
        for &(col, v) in &map.stuck_sa {
            if let Some(amp) = self.amps.get_mut(col) {
                amp.stuck = Some(v);
            }
        }
        for &(col, code) in &map.stuck_adc {
            if let Some(slot) = self.stuck_adc.get_mut(col) {
                *slot = Some(code.min(c::ADC_MAX));
            }
        }
        self.folded = None;
    }

    /// Ground-truth bitmask of columns carrying any hard fault (bit
    /// `col`). Test oracle — the serving stack measures its own mask via
    /// the BISC fault classifier instead of peeking at this.
    pub fn fault_column_mask(&self) -> u32 {
        let mut mask = 0u32;
        for f in self.array.cell_faults() {
            mask |= 1u32 << f.col;
        }
        for (col, amp) in self.amps.iter().enumerate() {
            if amp.stuck.is_some() {
                mask |= 1u32 << col;
            }
        }
        for (col, s) in self.stuck_adc.iter().enumerate() {
            if s.is_some() {
                mask |= 1u32 << col;
            }
        }
        mask
    }

    /// Whether this die carries a drift model (`sigma_drift > 0`).
    pub fn has_drift(&self) -> bool {
        self.drift.is_some()
    }

    /// Drift units applied so far (the die's simulated age).
    pub fn drift_age(&self) -> u64 {
        self.drift.as_ref().map_or(0, |d| d.age)
    }

    /// Age the die by `units` drift ticks (one unit = one S&H period of
    /// analog busy time): every SA line gain walks by its per-column
    /// velocity and the offsets creep alongside, then the folded
    /// fast-path state is invalidated so the next evaluation sees the
    /// drifted amplifiers. No-op on a frozen die (`sigma_drift == 0`),
    /// so the hot path pays nothing when drift is disabled.
    ///
    /// Characterization reads issued through the model directly (BISC,
    /// health probes) do NOT age the die — only served traffic does, via
    /// the backends in [`crate::coordinator`] — so probing for drift
    /// never masquerades as drift itself.
    pub fn advance_drift(&mut self, units: u64) {
        let Some(d) = self.drift.as_mut() else { return };
        if units == 0 {
            return;
        }
        d.age += units;
        // (1 + v)^k applied in closed form so a large batch advances in
        // O(M) instead of O(M * batch)
        let k = units.min(i32::MAX as u64) as i32;
        for col in 0..c::M_COLS {
            let amp = &mut self.amps[col];
            amp.alpha_p *= (1.0 + d.vel_p[col]).powi(k);
            amp.alpha_n *= (1.0 + d.vel_n[col]).powi(k);
            amp.beta += d.vel_beta[col] * units as f64;
        }
        self.folded = None;
    }

    /// Pre-ADC SA output voltages for one input vector (noise-free) —
    /// used by Fig. 7's error-distribution reproduction.
    pub fn sa_outputs(&self, x: &[i32]) -> Vec<f64> {
        let v: Vec<f64> = self
            .dacs
            .iter()
            .zip(x)
            .map(|(d, &code)| d.differential(InputCode::clamp(code)))
            .collect();
        let (i_pos, i_neg) = self.array.column_currents(&v);
        (0..c::M_COLS)
            .map(|col| self.amps[col].output(i_pos[col], i_neg[col]))
            .collect()
    }

    /// Golden path: one input vector -> M ADC codes, with noise.
    pub fn forward_golden(&mut self, x: &[i32]) -> Vec<u32> {
        let mut v_sa = self.sa_outputs(x);
        for v in v_sa.iter_mut() {
            *v += self.noise.sample();
        }
        let adc = &self.adc;
        v_sa.iter()
            .zip(&self.stuck_adc)
            .map(|(&v, stuck)| stuck.unwrap_or_else(|| adc.quantize(v)))
            .collect()
    }

    /// Golden path with per-read averaging (BISC characterization reads).
    /// The pre-ADC SA outputs are deterministic per input, so they are
    /// computed once and only the noise is re-drawn per read — the same
    /// RNG sequence (M samples per read, column order) and the same
    /// codes as `reads` independent `forward_golden` calls, without
    /// re-walking every array cell or allocating inside the read loop
    /// (BISC characterization issues thousands of these).
    pub fn forward_averaged(&mut self, x: &[i32], reads: usize) -> Vec<f64> {
        assert!(reads > 0);
        let v_sa = self.sa_outputs(x);
        let mut acc = vec![0.0; c::M_COLS];
        for _ in 0..reads {
            for (a, &v) in acc.iter_mut().zip(&v_sa) {
                *a += self.adc.quantize(v + self.noise.sample()) as f64;
            }
        }
        acc.iter_mut().for_each(|a| *a /= reads as f64);
        for (a, stuck) in acc.iter_mut().zip(&self.stuck_adc) {
            if let Some(code) = stuck {
                *a = *code as f64;
            }
        }
        acc
    }

    fn fold(&mut self) {
        let c_adc = self.adc.conv_factor();
        let a = self.adc.alpha_d * c_adc;
        let mut qa = vec![0f64; c::M_COLS];
        let mut qb = vec![0f64; c::M_COLS];
        let mut qc = vec![0f32; c::M_COLS];
        let mut qd = vec![0f32; c::M_COLS];
        let mut qm = vec![0f32; c::M_COLS];
        for col in 0..c::M_COLS {
            let amp = &self.amps[col];
            let colfac = self.array.col_factor(col);
            let scale = a * colfac;
            qa[col] = scale * amp.alpha_p * amp.rsa_p();
            qb[col] = scale * amp.alpha_n * amp.rsa_n();
            qc[col] = (a * (amp.vcal() + amp.beta - self.adc.v_l) + self.adc.beta_d) as f32;
            // cubic distortion in code units (see python model.fold_params)
            qd[col] = (amp.gamma3 / (a * a)) as f32;
            qm[col] = (a * (c::V_BIAS - self.adc.v_l) + self.adc.beta_d) as f32;
            // hard faults: a wedged ADC slice or railed SA makes the
            // column a constant — zero its conductances and pin the
            // epilogue to the stuck code (ADC wins, it is downstream)
            let sa_code = amp.stuck.map(|v| self.adc.transfer(v) as f32);
            if let Some(code) = self.stuck_adc[col].map(|q| q as f32).or(sa_code) {
                qa[col] = 0.0;
                qb[col] = 0.0;
                qc[col] = code;
                qd[col] = 0.0;
            }
        }
        // single-GEMM fold: the positive/negative line split collapses
        // because qa/qb are per-column constants
        let mut g_comb = vec![0f32; c::N_ROWS * c::M_COLS];
        for r in 0..c::N_ROWS {
            let rowfac = self.array.row_factor(r);
            for col in 0..c::M_COLS {
                let cell = self.array.cell(r, col);
                let g = cell.conductance() * rowfac;
                g_comb[r * c::M_COLS + col] = match cell.line {
                    mwc::Line::Positive => (g * qa[col]) as f32,
                    mwc::Line::Negative => (-g * qb[col]) as f32,
                    mwc::Line::Idle => 0.0,
                };
            }
        }
        // per-row DAC transfer, folded once: xe = gain*x*lsb + offset
        // becomes a single f32 multiply-add per element at serve time
        let lsb = InputDac::lsb();
        let dac_gain_lsb = self.dacs.iter().map(|d| (d.gain * lsb) as f32).collect();
        let dac_off = self.dacs.iter().map(|d| d.offset as f32).collect();
        self.folded = Some(Folded { g_comb, qc, qd, qm, dac_gain_lsb, dac_off });
    }

    /// Folded fast path: batch of input vectors (row-major B x N) -> ADC
    /// codes (B x M). Noise-free (deterministic hot path; callers needing
    /// noise add it explicitly like the HLO artifact's noise operand).
    /// Thin allocating wrapper over [`CimAnalogModel::forward_batch_into`].
    pub fn forward_batch(&mut self, x: &[i32], batch: usize) -> Vec<u32> {
        let mut out = Vec::new();
        self.forward_batch_into(x, batch, &mut out);
        out
    }

    /// `forward_batch` into a caller-owned output buffer (cleared and
    /// refilled). Steady-state serving reuses `out` and the model's
    /// internal scratch, so repeated calls allocate nothing once the
    /// buffers have grown to the largest batch seen (§Perf optimization
    /// 1; the single GEMM's 32-wide column loop auto-vectorizes).
    pub fn forward_batch_into(&mut self, x: &[i32], batch: usize, out: &mut Vec<u32>) {
        assert_eq!(x.len(), batch * c::N_ROWS);
        if self.folded.is_none() {
            self.fold();
        }
        let f = self.folded.as_ref().unwrap();
        f.forward_into(x, batch, &mut self.scratch, out);
    }

    /// Fold a weight tile under the CURRENT trims/ADC refs and hand the
    /// result to the caller (the DNN scheduler caches these per tile).
    pub fn fold_tile(&mut self, weights: &[i32]) -> Folded {
        self.program(weights);
        self.fold();
        self.folded.as_ref().unwrap().clone()
    }

    /// Evaluate a previously folded tile — identical math to
    /// `forward_batch` but without touching the array state. Thin
    /// allocating wrapper over [`CimAnalogModel::forward_folded_into`].
    pub fn forward_folded(&self, tile: &Folded, x: &[i32], batch: usize) -> Vec<u32> {
        let mut scratch = MacScratch::new();
        let mut out = Vec::new();
        self.forward_folded_into(tile, x, batch, &mut scratch, &mut out);
        out
    }

    /// `forward_folded` into caller-owned scratch + output buffers: the
    /// tile carries the fold-time DAC coefficients, so the evaluation
    /// never touches the model state and allocates nothing in steady
    /// state (the DNN tile servers thread one scratch per worker).
    pub fn forward_folded_into(
        &self,
        tile: &Folded,
        x: &[i32],
        batch: usize,
        scratch: &mut MacScratch,
        out: &mut Vec<u32>,
    ) {
        tile.forward_into(x, batch, scratch, out);
    }

    /// Ideal output of Eq. (7) in continuous code units for a batch —
    /// the Q_nom used by BISC and the compute-SNR evaluation. Same
    /// row-skip + 32-wide-column shape as the folded GEMM: every
    /// product and partial sum is an integer below 2^53, so the f64
    /// accumulation is exact and the result is bit-identical to the
    /// scalar i64 triple loop it replaces.
    pub fn q_nominal(x: &[i32], weights: &[i32], batch: usize) -> Vec<f64> {
        assert_eq!(x.len(), batch * c::N_ROWS);
        assert_eq!(weights.len(), c::N_ROWS * c::M_COLS);
        let k = c::code_gain_nominal();
        let mid = c::q_mid_nominal();
        let mut out = vec![0.0; batch * c::M_COLS];
        for (xrow, orow) in x.chunks_exact(c::N_ROWS).zip(out.chunks_exact_mut(c::M_COLS)) {
            let mut acc = [0f64; c::M_COLS];
            for (r, wrow) in weights.chunks_exact(c::M_COLS).enumerate() {
                let xv = xrow[r];
                if xv == 0 {
                    continue;
                }
                let xf = xv as f64;
                for col in 0..c::M_COLS {
                    acc[col] += xf * wrow[col] as f64;
                }
            }
            for col in 0..c::M_COLS {
                orow[col] = mid + k * acc[col];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_weights(rng: &mut Rng) -> Vec<i32> {
        (0..c::N_ROWS * c::M_COLS)
            .map(|_| rng.int_in(-63, 63) as i32)
            .collect()
    }

    fn random_inputs(rng: &mut Rng, batch: usize) -> Vec<i32> {
        (0..batch * c::N_ROWS)
            .map(|_| rng.int_in(-63, 63) as i32)
            .collect()
    }

    #[test]
    fn fast_matches_golden_noise_free() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        let mut rng = Rng::new(11);
        let w = random_weights(&mut rng);
        m.program(&w);
        let batch = 16;
        let x = random_inputs(&mut rng, batch);
        let fast = m.forward_batch(&x, batch);
        let mut mismatches = 0;
        for b in 0..batch {
            let golden = m.forward_golden(&x[b * c::N_ROWS..(b + 1) * c::N_ROWS]);
            for col in 0..c::M_COLS {
                let f = fast[b * c::M_COLS + col] as i64;
                let g = golden[col] as i64;
                assert!((f - g).abs() <= 1, "b={b} col={col}: {f} vs {g}");
                if f != g {
                    mismatches += 1;
                }
            }
        }
        // f32 vs f64 rounding ties must be rare
        assert!(mismatches < batch * c::M_COLS / 50, "{mismatches} ties");
    }

    /// The `_into` entry points are the same kernel as the allocating
    /// wrappers — pin bit-identical outputs across every fold
    /// invalidation path (trims, ADC refs, drift, reprogramming), with
    /// the scratch and output buffers reused throughout.
    #[test]
    fn into_apis_match_allocating_paths_across_invalidations() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        cfg.sigma_drift = 1e-4;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        let mut rng = Rng::new(77);
        let mut scratch = MacScratch::new();
        let mut out = Vec::new();
        for round in 0..8 {
            let w = random_weights(&mut rng);
            m.program(&w);
            match round % 4 {
                0 => {
                    let col = rng.int_in(0, c::M_COLS as i64 - 1) as usize;
                    m.set_trims(col, samp::POT_MAX / 2, samp::POT_MAX / 3, samp::CAL_MAX / 2);
                }
                1 => m.advance_drift(50),
                2 => m.set_adc_refs(0.21, 0.61),
                _ => m.invalidate_fold(),
            }
            let batch = 1 + (round % 5); // odd and even batches hit both GEMM tails
            let x = random_inputs(&mut rng, batch);
            let q_alloc = m.forward_batch(&x, batch);
            m.forward_batch_into(&x, batch, &mut out);
            assert_eq!(out, q_alloc, "round {round}: forward_batch_into drifted");
            // the tile path folds the same weights under the same trims,
            // so all four entry points must agree exactly
            let tile = m.fold_tile(&w);
            let q_tile = m.forward_folded(&tile, &x, batch);
            assert_eq!(q_tile, q_alloc, "round {round}: forward_folded drifted");
            m.forward_folded_into(&tile, &x, batch, &mut scratch, &mut out);
            assert_eq!(out, q_tile, "round {round}: forward_folded_into drifted");
        }
    }

    #[test]
    fn hard_faults_hit_both_paths_and_survive_reprogramming() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        let mut rng = Rng::new(21);
        let w = random_weights(&mut rng);
        m.program(&w);
        let plan = faults::FaultPlan::parse("col=3,adc=7:11,sa=9:0.45,cell=0:1:gmax").unwrap();
        m.apply_faults(&plan.events[0].map);
        assert_eq!(m.fault_column_mask(), plan.events[0].map.column_mask());
        let batch = 8;
        let x = random_inputs(&mut rng, batch);
        let fast = m.forward_batch(&x, batch);
        for b in 0..batch {
            let golden = m.forward_golden(&x[b * c::N_ROWS..(b + 1) * c::N_ROWS]);
            // a wedged ADC slice emits its code on both paths, exactly
            assert_eq!(golden[7], 11);
            assert_eq!(fast[b * c::M_COLS + 7], 11);
            // a dead column and a railed SA are input-independent constants
            assert_eq!(fast[b * c::M_COLS + 3], fast[3]);
            assert_eq!(fast[b * c::M_COLS + 9], fast[9]);
            // the two paths stay in lock-step under faults
            for col in 0..c::M_COLS {
                let f = fast[b * c::M_COLS + col] as i64;
                assert!((f - golden[col] as i64).abs() <= 1, "b={b} col={col}");
            }
        }
        // characterization reads see the wedge too (classifier input)
        let avg = m.forward_averaged(&x[..c::N_ROWS], 4);
        assert_eq!(avg[7], 11.0);
        // reprogramming cannot heal silicon: every fault persists
        m.program(&random_weights(&mut rng));
        let fast2 = m.forward_batch(&x, batch);
        assert_eq!(fast2[3], fast[3]);
        assert_eq!(fast2[7], 11);
        assert_eq!(fast2[9], fast[9]);
    }

    #[test]
    fn ideal_die_matches_q_nominal() {
        let mut m = CimAnalogModel::ideal();
        let mut rng = Rng::new(5);
        let w = random_weights(&mut rng);
        m.program(&w);
        let batch = 8;
        let x = random_inputs(&mut rng, batch);
        let q = m.forward_batch(&x, batch);
        let nom = CimAnalogModel::q_nominal(&x, &w, batch);
        for i in 0..batch * c::M_COLS {
            let expect = nom[i].round().clamp(0.0, 63.0);
            assert!(
                (q[i] as f64 - expect).abs() <= 1.0,
                "i={i}: {} vs {expect}",
                q[i]
            );
        }
    }

    #[test]
    fn errors_shift_outputs_away_from_nominal() {
        let cfg = SimConfig::default().scaled(1.0);
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        let mut rng = Rng::new(9);
        let w = random_weights(&mut rng);
        m.program(&w);
        let batch = 32;
        let x = random_inputs(&mut rng, batch);
        let q = m.forward_batch(&x, batch);
        let nom = CimAnalogModel::q_nominal(&x, &w, batch);
        let mean_err: f64 = q
            .iter()
            .zip(&nom)
            .map(|(&a, &n)| (a as f64 - n).abs())
            .sum::<f64>()
            / q.len() as f64;
        assert!(mean_err > 0.5, "errors too small: {mean_err}");
    }

    #[test]
    fn trims_change_transfer() {
        let mut m = CimAnalogModel::ideal();
        let w = vec![40i32; c::N_ROWS * c::M_COLS];
        m.program(&w);
        let x = vec![30i32; c::N_ROWS];
        let q0 = m.forward_batch(&x, 1);
        m.set_trims(0, samp::POT_MAX, samp::POT_MAX, samp::CAL_MAX);
        let q1 = m.forward_batch(&x, 1);
        assert_ne!(q0[0], q1[0]);
        assert_eq!(q0[1], q1[1], "other columns untouched");
    }

    #[test]
    fn adc_refs_rescale_codes() {
        let mut m = CimAnalogModel::ideal();
        m.program(&vec![63; c::N_ROWS * c::M_COLS]);
        let x = vec![63i32; c::N_ROWS];
        let q_tight = m.forward_batch(&x, 1)[0];
        m.set_adc_refs(0.19, 0.63);
        let q_wide = m.forward_batch(&x, 1)[0];
        assert!(q_wide < q_tight, "wider range => smaller code for same V");
    }

    #[test]
    fn drift_ages_the_die_and_moves_outputs() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.0;
        cfg.sigma_drift = 5e-4;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        assert!(m.has_drift());
        let w = vec![40i32; c::N_ROWS * c::M_COLS];
        m.program(&w);
        let x = vec![30i32; c::N_ROWS];
        let q0 = m.forward_batch(&x, 1);
        m.advance_drift(500);
        assert_eq!(m.drift_age(), 500);
        let q1 = m.forward_batch(&x, 1);
        assert_ne!(q0, q1, "500 drift units must move the transfer");
        // a frozen die ignores advance_drift entirely
        let mut frozen = CimAnalogModel::ideal();
        frozen.program(&w);
        let f0 = frozen.forward_batch(&x, 1);
        frozen.advance_drift(10_000);
        assert_eq!(frozen.drift_age(), 0);
        assert_eq!(frozen.forward_batch(&x, 1), f0);
    }

    #[test]
    fn noise_perturbs_golden_path() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.01; // huge: ~1.6 codes rms
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        m.program(&vec![20; c::N_ROWS * c::M_COLS]);
        let x = vec![20i32; c::N_ROWS];
        let a = m.forward_golden(&x);
        let b = m.forward_golden(&x);
        assert_ne!(a, b, "independent noise draws should differ");
    }

    #[test]
    fn averaging_converges_to_noise_free() {
        let mut cfg = SimConfig::default();
        cfg.sigma_noise = 0.005;
        let sample = VariationSample::draw(&cfg);
        let mut m = CimAnalogModel::from_sample(&cfg, &sample);
        m.program(&vec![30; c::N_ROWS * c::M_COLS]);
        let x = vec![25i32; c::N_ROWS];
        let avg = m.forward_averaged(&x, 64);
        cfg.sigma_noise = 0.0;
        let mut m2 = CimAnalogModel::from_sample(&cfg, &sample);
        m2.program(&vec![30; c::N_ROWS * c::M_COLS]);
        let clean = m2.forward_batch(&x, 1);
        for col in 0..c::M_COLS {
            assert!(
                (avg[col] - clean[col] as f64).abs() < 1.5,
                "col {col}: {} vs {}",
                avg[col],
                clean[col]
            );
        }
    }
}
