//! Hard-fault model for the CIM core — ROADMAP item 5 (degraded-mode
//! serving).
//!
//! The variation/drift machinery models *soft* analog error: everything it
//! produces is correctable by a BISC recalibration pass. Real resistive
//! arrays also fail *hard* — SRAM bits weld a cell's R-2R ladder to zero or
//! full conductance, a row driver or summation line opens, a summing
//! amplifier rails, an ADC comparator wedges one output code. These faults
//! are permanent and un-calibratable; the serving stack must detect them
//! (see the classifier in `coordinator`), retire the die, and place work
//! around it.
//!
//! This module holds the *description* of hard faults:
//!   * [`FaultMap`] — the set of faults present on one die,
//!   * [`FaultPlan`] — a deterministic injection schedule (which core,
//!     after how many served MACs, which faults), parseable from the
//!     compact spec strings used by `serve --faults` and the
//!     `acore-cim faults` subcommand.
//!
//! Application happens in the physical layers: stuck cells force the
//! stored [`super::mwc::Mwc`] state in [`super::array::CrossbarArray`]
//! (and are re-forced on every reprogram — silicon stays broken no matter
//! what is written), a stuck SA rails [`super::samp::SummingAmp::output`],
//! and stuck ADC codes override the quantizer output per column. All three
//! are visible to both the golden path and the folded fast path (the fold
//! bakes them in), so serving pays nothing for fault support.

use super::consts as c;
use crate::util::rng::Rng;

/// Conductance level a faulty cell is welded to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StuckLevel {
    /// Open: the cell contributes no current regardless of stored code.
    G0,
    /// Shorted to full scale: behaves as a permanently programmed
    /// +CODE_MAX cell on the positive line.
    Gmax,
}

/// One welded MWC.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellFault {
    pub row: usize,
    pub col: usize,
    pub level: StuckLevel,
}

/// The hard faults present on one die.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultMap {
    /// individually welded cells
    pub cells: Vec<CellFault>,
    /// rows whose driver is open — every cell in the row reads G0
    pub dead_rows: Vec<usize>,
    /// columns whose summation line is open — every cell reads G0
    pub dead_cols: Vec<usize>,
    /// summing amps railed to a constant output voltage: (col, volts)
    pub stuck_sa: Vec<(usize, f64)>,
    /// ADC slices wedged to one output code: (col, code)
    pub stuck_adc: Vec<(usize, u32)>,
}

impl FaultMap {
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
            && self.dead_rows.is_empty()
            && self.dead_cols.is_empty()
            && self.stuck_sa.is_empty()
            && self.stuck_adc.is_empty()
    }

    /// Fold another map's faults into this one.
    pub fn merge(&mut self, other: &FaultMap) {
        self.cells.extend_from_slice(&other.cells);
        self.dead_rows.extend_from_slice(&other.dead_rows);
        self.dead_cols.extend_from_slice(&other.dead_cols);
        self.stuck_sa.extend_from_slice(&other.stuck_sa);
        self.stuck_adc.extend_from_slice(&other.stuck_adc);
    }

    /// Expand dead rows/columns into per-cell G0 welds and append the
    /// explicit cell faults — the flat list the crossbar consumes.
    pub fn cell_faults(&self) -> Vec<CellFault> {
        let mut out = Vec::new();
        for &row in &self.dead_rows {
            for col in 0..c::M_COLS {
                out.push(CellFault { row, col, level: StuckLevel::G0 });
            }
        }
        for &col in &self.dead_cols {
            for row in 0..c::N_ROWS {
                out.push(CellFault { row, col, level: StuckLevel::G0 });
            }
        }
        out.extend_from_slice(&self.cells);
        out
    }

    /// Ground-truth bitmask of columns touched by any fault (bit `col`).
    /// The serving stack never reads this — it measures its own mask via
    /// the BISC classifier — but tests compare the two.
    pub fn column_mask(&self) -> u32 {
        let mut mask = 0u32;
        for f in &self.cells {
            mask |= col_bit(f.col);
        }
        if !self.dead_rows.is_empty() {
            // an open row touches every column
            mask = ((1u64 << c::M_COLS) - 1) as u32;
        }
        for &col in &self.dead_cols {
            mask |= col_bit(col);
        }
        for &(col, _) in &self.stuck_sa {
            mask |= col_bit(col);
        }
        for &(col, _) in &self.stuck_adc {
            mask |= col_bit(col);
        }
        mask
    }
}

fn col_bit(col: usize) -> u32 {
    if col < c::M_COLS {
        1u32 << col
    } else {
        0
    }
}

/// One scheduled injection: after `at_macs` MACs served by core `core`,
/// apply `map`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultEvent {
    pub core: usize,
    /// MACs the target core must have served before the fault strikes
    /// (0 = immediately on arrival of the plan).
    pub at_macs: u64,
    pub map: FaultMap,
}

/// A deterministic, seeded fault-injection schedule.
///
/// Compact spec grammar (whitespace-free; see `acore-cim faults --help`):
///
/// ```text
/// plan  := event (';' event)*
/// event := spec (',' spec)*
/// spec  := 'core=' K              target core of this event (default 0)
///        | 'at=' N               inject after N served MACs (default 0)
///        | 'col=' C              dead column C
///        | 'row=' R              dead row R
///        | 'cell=' R ':' C ':' ('g0'|'gmax')   welded cell
///        | 'sa=' C ':' V         SA railed to V volts on column C
///        | 'adc=' C ':' Q        ADC wedged to code Q on column C
///        | 'rand=' N ':' SEED    N seeded random welded cells
/// ```
///
/// Example: `core=1,at=5000,col=7,cell=3:9:gmax;core=2,adc=0:17`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Parse a compact spec string. The empty string is the empty plan.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut events = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            events.push(parse_event(part)?);
        }
        Ok(Self { events })
    }

    /// Re-serialize into the compact spec grammar (wire transport and
    /// round-trip tests). `rand=` specs are serialized expanded, so the
    /// result is deterministic without carrying the seed.
    pub fn to_spec(&self) -> String {
        let mut out = String::new();
        for (i, ev) in self.events.iter().enumerate() {
            if i > 0 {
                out.push(';');
            }
            let mut specs: Vec<String> = Vec::new();
            if ev.core != 0 {
                specs.push(format!("core={}", ev.core));
            }
            if ev.at_macs != 0 {
                specs.push(format!("at={}", ev.at_macs));
            }
            for &col in &ev.map.dead_cols {
                specs.push(format!("col={col}"));
            }
            for &row in &ev.map.dead_rows {
                specs.push(format!("row={row}"));
            }
            for f in &ev.map.cells {
                let level = match f.level {
                    StuckLevel::G0 => "g0",
                    StuckLevel::Gmax => "gmax",
                };
                specs.push(format!("cell={}:{}:{level}", f.row, f.col));
            }
            for &(col, v) in &ev.map.stuck_sa {
                specs.push(format!("sa={col}:{v}"));
            }
            for &(col, q) in &ev.map.stuck_adc {
                specs.push(format!("adc={col}:{q}"));
            }
            out.push_str(&specs.join(","));
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.events.iter().all(|e| e.map.is_empty())
    }

    /// The events targeting one core.
    pub fn events_for(&self, core: usize) -> impl Iterator<Item = &FaultEvent> {
        self.events.iter().filter(move |e| e.core == core)
    }

    /// Highest core index any event targets (plan validation at serve
    /// startup).
    pub fn max_core(&self) -> Option<usize> {
        self.events.iter().map(|e| e.core).max()
    }
}

fn parse_event(part: &str) -> Result<FaultEvent, String> {
    let mut ev = FaultEvent::default();
    for spec in part.split(',') {
        let spec = spec.trim();
        if spec.is_empty() {
            continue;
        }
        let (key, val) = spec
            .split_once('=')
            .ok_or_else(|| format!("fault spec `{spec}`: expected key=value"))?;
        match key {
            "core" => ev.core = parse_num(val, "core", usize::MAX)?,
            "at" => ev.at_macs = parse_num(val, "at", u64::MAX as usize)? as u64,
            "col" => ev.map.dead_cols.push(parse_num(val, "col", c::M_COLS - 1)?),
            "row" => ev.map.dead_rows.push(parse_num(val, "row", c::N_ROWS - 1)?),
            "cell" => {
                let (row, rest) = val
                    .split_once(':')
                    .ok_or_else(|| format!("cell spec `{val}`: expected R:C:g0|gmax"))?;
                let (col, level) = rest
                    .split_once(':')
                    .ok_or_else(|| format!("cell spec `{val}`: expected R:C:g0|gmax"))?;
                let level = match level {
                    "g0" => StuckLevel::G0,
                    "gmax" => StuckLevel::Gmax,
                    other => return Err(format!("cell level `{other}`: expected g0 or gmax")),
                };
                ev.map.cells.push(CellFault {
                    row: parse_num(row, "cell row", c::N_ROWS - 1)?,
                    col: parse_num(col, "cell col", c::M_COLS - 1)?,
                    level,
                });
            }
            "sa" => {
                let (col, volts) = val
                    .split_once(':')
                    .ok_or_else(|| format!("sa spec `{val}`: expected COL:VOLTS"))?;
                let v: f64 = volts
                    .parse()
                    .map_err(|_| format!("sa voltage `{volts}`: not a number"))?;
                if !v.is_finite() {
                    return Err(format!("sa voltage `{volts}`: not finite"));
                }
                ev.map.stuck_sa.push((parse_num(col, "sa col", c::M_COLS - 1)?, v));
            }
            "adc" => {
                let (col, code) = val
                    .split_once(':')
                    .ok_or_else(|| format!("adc spec `{val}`: expected COL:CODE"))?;
                ev.map.stuck_adc.push((
                    parse_num(col, "adc col", c::M_COLS - 1)?,
                    parse_num(code, "adc code", c::ADC_MAX as usize)? as u32,
                ));
            }
            "rand" => {
                let (n, seed) = val
                    .split_once(':')
                    .ok_or_else(|| format!("rand spec `{val}`: expected N:SEED"))?;
                let n: usize = parse_num(n, "rand count", c::N_ROWS * c::M_COLS)?;
                let seed: u64 = seed
                    .parse()
                    .map_err(|_| format!("rand seed `{seed}`: not an integer"))?;
                ev.map.cells.extend(random_cells(n, seed));
            }
            other => return Err(format!("unknown fault spec key `{other}`")),
        }
    }
    Ok(ev)
}

fn parse_num(s: &str, what: &str, max: usize) -> Result<usize, String> {
    let n: usize = s.parse().map_err(|_| format!("{what} `{s}`: not an integer"))?;
    if n > max {
        return Err(format!("{what} {n} out of range (max {max})"));
    }
    Ok(n)
}

/// Deterministic seeded weld draw: `n` distinct cells, alternating
/// G0/Gmax. The same (n, seed) always yields the same faults, so a plan
/// using `rand=` replays bit-for-bit like everything else in the repo.
fn random_cells(n: usize, seed: u64) -> Vec<CellFault> {
    let mut rng = Rng::new(seed ^ 0xFA_017_5EED);
    let mut taken = vec![false; c::N_ROWS * c::M_COLS];
    let mut out = Vec::with_capacity(n);
    while out.len() < n.min(c::N_ROWS * c::M_COLS) {
        let row = rng.int_in(0, c::N_ROWS as i64 - 1) as usize;
        let col = rng.int_in(0, c::M_COLS as i64 - 1) as usize;
        if taken[row * c::M_COLS + col] {
            continue;
        }
        taken[row * c::M_COLS + col] = true;
        let level = if out.len() % 2 == 0 { StuckLevel::G0 } else { StuckLevel::Gmax };
        out.push(CellFault { row, col, level });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_whitespace_specs_parse_to_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty());
    }

    #[test]
    fn full_grammar_roundtrips() {
        let spec = "core=1,at=5000,col=7,row=2,cell=3:9:gmax,sa=4:0.45,adc=0:17;core=2,cell=0:0:g0";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.events.len(), 2);
        let ev = &plan.events[0];
        assert_eq!((ev.core, ev.at_macs), (1, 5000));
        assert_eq!(ev.map.dead_cols, vec![7]);
        assert_eq!(ev.map.dead_rows, vec![2]);
        assert_eq!(ev.map.cells, vec![CellFault { row: 3, col: 9, level: StuckLevel::Gmax }]);
        assert_eq!(ev.map.stuck_sa, vec![(4, 0.45)]);
        assert_eq!(ev.map.stuck_adc, vec![(0, 17)]);
        assert_eq!(plan.events[1].core, 2);
        // re-serialize -> re-parse is identity
        let again = FaultPlan::parse(&plan.to_spec()).unwrap();
        assert_eq!(again, plan);
        assert_eq!(plan.max_core(), Some(2));
    }

    #[test]
    fn out_of_range_and_malformed_specs_are_rejected() {
        for bad in [
            "col=32",
            "row=36",
            "cell=0:0:weird",
            "cell=0:32:g0",
            "adc=0:64",
            "adc=33:1",
            "sa=0:abc",
            "sa=0:inf",
            "frob=1",
            "col",
            "rand=3",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn seeded_random_cells_are_deterministic_and_distinct() {
        let a = FaultPlan::parse("rand=8:42").unwrap();
        let b = FaultPlan::parse("rand=8:42").unwrap();
        assert_eq!(a, b);
        let cells = &a.events[0].map.cells;
        assert_eq!(cells.len(), 8);
        for (i, x) in cells.iter().enumerate() {
            for y in &cells[i + 1..] {
                assert!((x.row, x.col) != (y.row, y.col), "duplicate weld");
            }
        }
        let c2 = FaultPlan::parse("rand=8:43").unwrap();
        assert_ne!(a, c2, "different seed, different welds");
    }

    #[test]
    fn column_mask_covers_every_fault_kind() {
        let plan = FaultPlan::parse("col=3,cell=0:5:g0,sa=7:0.4,adc=9:0").unwrap();
        let mask = plan.events[0].map.column_mask();
        assert_eq!(mask, (1 << 3) | (1 << 5) | (1 << 7) | (1 << 9));
        let dead_row = FaultPlan::parse("row=0").unwrap();
        assert_eq!(dead_row.events[0].map.column_mask(), u32::MAX);
    }

    #[test]
    fn cell_fault_expansion_covers_dead_lines() {
        let plan = FaultPlan::parse("col=1,row=2,cell=3:4:gmax").unwrap();
        let cells = plan.events[0].map.cell_faults();
        // one dead row (M cells) + one dead column (N cells) + 1 weld
        assert_eq!(cells.len(), crate::analog::consts::M_COLS + crate::analog::consts::N_ROWS + 1);
        assert!(cells
            .iter()
            .any(|f| f.row == 3 && f.col == 4 && f.level == StuckLevel::Gmax));
        assert!(cells.iter().filter(|f| f.col == 1).count() >= crate::analog::consts::N_ROWS);
    }

    #[test]
    fn events_for_filters_by_core() {
        let plan = FaultPlan::parse("core=1,col=0;core=2,col=1;core=1,row=0").unwrap();
        assert_eq!(plan.events_for(1).count(), 2);
        assert_eq!(plan.events_for(0).count(), 0);
    }
}
