//! Time-multiplexed 6-bit flash ADC (Section III-B, Eq. 2 / Eq. 8).
//!
//! The M = 32 column voltages are multiplexed into one flash ADC running at
//! M / T_S&H = 32 MHz. Behaviourally: a linear quantizer with programmable
//! references, a gain error alpha_D and an offset error beta_D (in codes),
//! hard-clipping at the rails.

use super::consts as c;

#[derive(Debug, Clone)]
pub struct FlashAdc {
    /// digital gain error, ideally 1.0
    pub alpha_d: f64,
    /// digital offset error [codes]
    pub beta_d: f64,
    /// programmable references [V] (BISC widens these, Alg. 1)
    pub v_l: f64,
    pub v_h: f64,
}

impl Default for FlashAdc {
    fn default() -> Self {
        Self { alpha_d: 1.0, beta_d: 0.0, v_l: c::V_ADC_L, v_h: c::V_ADC_H }
    }
}

impl FlashAdc {
    /// C_ADC of Eq. (7) at the current references.
    pub fn conv_factor(&self) -> f64 {
        c::adc_conv_factor(self.v_l, self.v_h)
    }

    /// Continuous (pre-round) transfer, Eq. (8) inner part.
    pub fn transfer(&self, v: f64) -> f64 {
        self.alpha_d * self.conv_factor() * (v - self.v_l) + self.beta_d
    }

    /// Quantize one voltage to a 6-bit code.
    pub fn quantize(&self, v: f64) -> u32 {
        self.transfer(v).round().clamp(0.0, c::ADC_MAX as f64) as u32
    }

    /// True if the voltage would clip (Alg. 1 widens references to avoid
    /// exactly this during characterization).
    pub fn clips(&self, v: f64) -> bool {
        let t = self.transfer(v);
        t < 0.0 || t > c::ADC_MAX as f64
    }

    /// Widen references symmetrically by `margin` (e.g. 0.05 for the
    /// paper's +/-5%): V_L *= (1-margin-ish) — per Alg. 1,
    /// V_L <- 0.95 V_L and V_H <- 1.05 V_H.
    pub fn widen_refs(&mut self, margin: f64) {
        self.v_l *= 1.0 - margin;
        self.v_h *= 1.0 + margin;
    }

    /// Restore the default (inference) references.
    pub fn default_refs(&mut self) {
        self.v_l = c::V_ADC_L;
        self.v_h = c::V_ADC_H;
    }

    /// Sample conversion time at the multiplexed rate.
    pub fn conversion_time(&self) -> f64 {
        c::T_SH / c::M_COLS as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn midscale_maps_to_mid_code() {
        let adc = FlashAdc::default();
        // V_BIAS = 0.4 V -> (0.4-0.2)*157.5 = 31.5 -> rounds to 32
        assert_eq!(adc.quantize(c::V_BIAS), 32);
        assert!((adc.transfer(c::V_BIAS) - 31.5).abs() < 1e-12);
    }

    #[test]
    fn rails_clip() {
        let adc = FlashAdc::default();
        assert_eq!(adc.quantize(0.0), 0);
        assert_eq!(adc.quantize(1.0), 63);
        assert!(adc.clips(0.19));
        assert!(adc.clips(0.61));
        assert!(!adc.clips(0.4));
    }

    #[test]
    fn code_boundaries() {
        let adc = FlashAdc::default();
        let lsb = (c::V_ADC_H - c::V_ADC_L) / 63.0;
        assert_eq!(adc.quantize(c::V_ADC_L), 0);
        assert_eq!(adc.quantize(c::V_ADC_L + lsb), 1);
        assert_eq!(adc.quantize(c::V_ADC_H), 63);
        // half-LSB rounds away from zero-code
        assert_eq!(adc.quantize(c::V_ADC_L + 0.51 * lsb), 1);
    }

    #[test]
    fn gain_offset_errors() {
        let adc = FlashAdc { alpha_d: 1.1, beta_d: 2.0, ..Default::default() };
        let ideal = FlashAdc::default();
        let v = 0.45;
        assert!(
            (adc.transfer(v) - (1.1 * ideal.transfer(v) + 2.0)).abs() < 1e-12
        );
    }

    #[test]
    fn widened_refs_prevent_clipping() {
        let mut adc = FlashAdc::default();
        let v = 0.61; // would clip at default refs
        assert!(adc.clips(v));
        adc.widen_refs(0.05);
        assert!((adc.v_l - 0.19).abs() < 1e-12);
        assert!((adc.v_h - 0.63).abs() < 1e-12);
        assert!(!adc.clips(v));
        adc.default_refs();
        assert!(adc.clips(v));
    }

    #[test]
    fn conversion_rate_is_32mhz() {
        let adc = FlashAdc::default();
        assert!((1.0 / adc.conversion_time() - 32.0e6).abs() < 1.0);
    }
}
