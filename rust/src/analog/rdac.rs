//! Input R-2R MDAC cell (paper Fig. 3): a 6-bit magnitude + sign-bit DAC
//! with dual references (V_INL for positive codes, V_INH for negative),
//! biased so the analog zero sits at V_BIAS = (V_INL + V_INH)/2.
//!
//! The behavioural transfer is
//!     V_DAC(d) = V_BIAS + gain * d * LSB + offset,   LSB = V_SWING / 2^B_D
//! where `gain`/`offset` carry the per-row non-idealities of Fig. 1 effect 1
//! (finite output impedance, load dependency, process variation). The
//! structural load-dependency model used by the Fig. 1 reproduction is in
//! `loaded_output`.

use super::consts as c;

/// Signed sign-magnitude input code: sign bit D6 plus magnitude D5:0.
/// Stored as i32 in [-63, 63] for ergonomics; `InputCode::clamp` saturates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InputCode(pub i32);

impl InputCode {
    pub fn clamp(v: i32) -> Self {
        Self(v.clamp(-c::CODE_MAX, c::CODE_MAX))
    }

    pub fn magnitude(self) -> u32 {
        self.0.unsigned_abs()
    }

    pub fn sign_bit(self) -> bool {
        self.0 < 0
    }
}

/// One input DAC channel with its sampled per-row non-idealities.
#[derive(Debug, Clone)]
pub struct InputDac {
    /// multiplicative gain error (~1.0)
    pub gain: f64,
    /// additive output offset [V]
    pub offset: f64,
    /// output resistance R_D [Ohm] (driver, Fig. 1 effect 2)
    pub r_out: f64,
}

impl Default for InputDac {
    fn default() -> Self {
        Self { gain: 1.0, offset: 0.0, r_out: 0.0 }
    }
}

impl InputDac {
    /// Ideal unloaded LSB size [V].
    pub fn lsb() -> f64 {
        c::V_SWING / (1 << c::B_D) as f64
    }

    /// Differential output (V_DAC - V_BIAS) for a signed code — this is the
    /// quantity the MWC array multiplies (Eq. 3).
    pub fn differential(&self, code: InputCode) -> f64 {
        self.gain * code.0 as f64 * Self::lsb() + self.offset
    }

    /// Absolute output voltage.
    pub fn output(&self, code: InputCode) -> f64 {
        c::V_BIAS + self.differential(code)
    }

    /// Output under a finite load resistance R_L to the bias rail —
    /// reproduces the "DAC Non-Idealities" plot of Fig. 1: the differential
    /// is attenuated by the R_out / R_L divider.
    pub fn loaded_output(&self, code: InputCode, r_load: f64) -> f64 {
        let att = r_load / (r_load + self.r_out);
        c::V_BIAS + self.differential(code) * att
    }

    /// Transfer error in LSBs versus the ideal DAC at a given load.
    pub fn error_lsb(&self, code: InputCode, r_load: f64) -> f64 {
        let ideal = code.0 as f64 * Self::lsb();
        (self.loaded_output(code, r_load) - c::V_BIAS - ideal) / Self::lsb()
    }
}

/// The input array: N DACs + S&H chain (Section III-B-1). The S&H is
/// behaviourally transparent here (it holds the DAC value for T_S&H); its
/// droop/feedthrough can be lumped into `offset`.
#[derive(Debug, Clone)]
pub struct InputArray {
    pub dacs: Vec<InputDac>,
}

impl InputArray {
    pub fn ideal() -> Self {
        Self { dacs: vec![InputDac::default(); c::N_ROWS] }
    }

    /// Differential voltages for a full input vector.
    pub fn differentials(&self, codes: &[i32]) -> Vec<f64> {
        assert_eq!(codes.len(), c::N_ROWS);
        self.dacs
            .iter()
            .zip(codes)
            .map(|(d, &x)| d.differential(InputCode::clamp(x)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_transfer_is_symmetric_and_monotone() {
        let d = InputDac::default();
        let mut prev = f64::NEG_INFINITY;
        for code in -63..=63 {
            let v = d.output(InputCode(code));
            assert!(v > prev, "not monotone at {code}");
            prev = v;
            let vm = d.output(InputCode(-code));
            assert!(
                ((v - c::V_BIAS) + (vm - c::V_BIAS)).abs() < 1e-12,
                "not symmetric at {code}"
            );
        }
    }

    #[test]
    fn full_scale_hits_references() {
        let d = InputDac::default();
        // +63 approaches V_INH - 1 LSB; -63 approaches V_INL + 1 LSB
        let top = d.output(InputCode(63));
        let bot = d.output(InputCode(-63));
        assert!((top - (c::V_INH - InputDac::lsb())).abs() < 1e-12);
        assert!((bot - (c::V_INL + InputDac::lsb())).abs() < 1e-12);
    }

    #[test]
    fn clamp_saturates() {
        assert_eq!(InputCode::clamp(100).0, 63);
        assert_eq!(InputCode::clamp(-100).0, -63);
        assert_eq!(InputCode::clamp(5).0, 5);
    }

    #[test]
    fn sign_magnitude_fields() {
        let code = InputCode(-42);
        assert!(code.sign_bit());
        assert_eq!(code.magnitude(), 42);
    }

    #[test]
    fn loading_attenuates_differential() {
        let d = InputDac { r_out: 1000.0, ..Default::default() };
        let unloaded = d.output(InputCode(40));
        let loaded = d.loaded_output(InputCode(40), 5_000.0);
        let heavier = d.loaded_output(InputCode(40), 11_000.0);
        assert!(loaded < unloaded);
        // heavier R_L (larger) means lighter loading => closer to ideal
        assert!((heavier - c::V_BIAS).abs() > (loaded - c::V_BIAS).abs());
        // error grows with code magnitude (Fig. 1 top-left plot shape)
        assert!(d.error_lsb(InputCode(63), 5_000.0).abs() > d.error_lsb(InputCode(3), 5_000.0).abs());
    }

    #[test]
    fn gain_offset_errors_apply() {
        let d = InputDac { gain: 1.05, offset: 0.001, r_out: 0.0 };
        let v = d.differential(InputCode(10));
        let ideal = 10.0 * InputDac::lsb();
        assert!((v - (1.05 * ideal + 0.001)).abs() < 1e-12);
    }

    #[test]
    fn input_array_vectorizes() {
        let arr = InputArray::ideal();
        let mut codes = vec![0i32; c::N_ROWS];
        codes[0] = 63;
        codes[1] = -63;
        let v = arr.differentials(&codes);
        assert_eq!(v.len(), c::N_ROWS);
        assert!(v[0] > 0.0 && v[1] < 0.0 && v[2] == 0.0);
    }
}
