//! Physical/architectural constants of the Acore-CIM core.
//!
//! Mirrors `python/compile/params.py` — the two MUST stay in sync; the
//! parity integration test executes the AOT artifact and this golden model
//! on identical inputs and asserts the ADC codes agree.

/// N: input rows of the MWC array.
pub const N_ROWS: usize = 36;
/// M: output columns of the MWC array.
pub const M_COLS: usize = 32;
/// Input magnitude bits (plus one sign bit), B_D.
pub const B_D: u32 = 6;
/// Weight magnitude bits (plus two sign bits W6/W7), B_W.
pub const B_W: u32 = 6;
/// ADC output bits, B_Q.
pub const B_Q: u32 = 6;
/// Maximum input/weight magnitude code (63).
pub const CODE_MAX: i32 = (1 << B_D) - 1;
/// Maximum ADC code (63).
pub const ADC_MAX: i32 = (1 << B_Q) - 1;

/// Low input reference [V].
pub const V_INL: f64 = 0.2;
/// High input reference [V].
pub const V_INH: f64 = 0.6;
/// Analog zero level [V].
pub const V_BIAS: f64 = 0.4;
/// Single-sided DAC swing [V].
pub const V_SWING: f64 = V_INH - V_BIAS;

/// Unit resistance of the R-2R ladders [Ohm] (polysilicon baseline, Table I).
pub const R_U: f64 = 385.0e3;
/// Nominal 2SA transresistance R_SA = R_U / N (Alg. 1; ~10.7 kOhm, Fig. 7).
pub const R_SA_NOM: f64 = R_U / N_ROWS as f64;
/// Nominal calibration voltage = (V_INL + V_INH)/2 = V_BIAS.
pub const V_CAL_NOM: f64 = (V_INL + V_INH) / 2.0;

/// Default ADC references (Section III-B).
pub const V_ADC_L: f64 = V_INL;
pub const V_ADC_H: f64 = V_INH;

/// S&H / inference period [s] and inference frequency [Hz].
pub const T_SH: f64 = 1.0e-6;
pub const F_INF: f64 = 1.0 / T_SH;

/// Structural parasitic defaults (Fig. 1 effects 4 and 5).
pub const KAPPA_IN_DEFAULT: f64 = 0.02;
pub const KAPPA_REG_DEFAULT: f64 = 0.015;

/// C_ADC of Eq. (7): (2^B_Q - 1) / (V_H - V_L).
pub fn adc_conv_factor(v_l: f64, v_h: f64) -> f64 {
    ADC_MAX as f64 / (v_h - v_l)
}

/// Nominal ADC codes per unit code-product sum (dQ/dS) — the digital-side
/// dequantization constant used by the RISC-V accumulation.
pub fn code_gain_nominal() -> f64 {
    let lsb_in = V_SWING / (1 << B_D) as f64;
    adc_conv_factor(V_ADC_L, V_ADC_H) * R_SA_NOM * lsb_in / (R_U * (1 << B_W) as f64)
}

/// Nominal ADC code for a zero MAC value (mid-code, 31.5).
pub fn q_mid_nominal() -> f64 {
    adc_conv_factor(V_ADC_L, V_ADC_H) * (V_CAL_NOM - V_ADC_L)
}

/// SA output volts per unit code-product sum (dV_SA/dS) — reference-
/// independent; used to choose per-layer ADC windows for the DNN mapping.
pub fn volts_per_cp() -> f64 {
    let lsb_in = V_SWING / (1 << B_D) as f64;
    R_SA_NOM * lsb_in / (R_U * (1 << B_W) as f64)
}

/// Code gain (dQ/dS) at arbitrary ADC references.
pub fn code_gain_at(v_l: f64, v_h: f64) -> f64 {
    adc_conv_factor(v_l, v_h) * volts_per_cp()
}

/// Mid code (Q at S = 0) at arbitrary ADC references.
pub fn q_mid_at(v_l: f64, v_h: f64) -> f64 {
    adc_conv_factor(v_l, v_h) * (V_CAL_NOM - v_l)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rsa_matches_paper_fig7() {
        // Fig. 7: default R_SA = 10.7 kOhm
        assert!((R_SA_NOM - 10694.4).abs() < 1.0);
    }

    #[test]
    fn full_scale_uses_adc_range() {
        // S_max = N * 63 * 63 must map near (not beyond) the top code.
        let s_max = (N_ROWS as f64) * 63.0 * 63.0;
        let q = q_mid_nominal() + code_gain_nominal() * s_max;
        assert!(q > 60.0 && q < 63.0, "q_fullscale={q}");
    }

    #[test]
    fn c_adc_default() {
        assert!((adc_conv_factor(V_ADC_L, V_ADC_H) - 157.5).abs() < 1e-9);
    }

    #[test]
    fn mid_code() {
        assert!((q_mid_nominal() - 31.5).abs() < 1e-9);
    }
}
