//! MDAC Weight Cell (MWC) — paper Fig. 5 / Section IV.
//!
//! Each cell stores a 6-bit weight magnitude W5:0 in 6T-SRAM plus two sign
//! bits (W6, W7) that route the cell current to the positive or negative
//! summation line (or leave the cell idle when both are 0 — reducing
//! off-state leakage, Section IV-A). Multiplication is performed by an
//! R-2R ladder whose effective conductance is W/2^B_W * 1/R_U.

use super::consts as c;

/// Polarity routing of a cell (one-hot sign bits W6/W7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Line {
    /// W6 = 1: current onto the positive summation line (I_MAC+).
    Positive,
    /// W7 = 1: current onto the negative summation line (I_MAC-).
    Negative,
    /// W6 = W7 = 0: idle cell (both switches off).
    Idle,
}

/// One MWC: stored weight code + sampled conductance mismatch.
#[derive(Debug, Clone, Copy)]
pub struct Mwc {
    /// magnitude code 0..=63 (W5:0)
    pub magnitude: u8,
    pub line: Line,
    /// fractional conductance mismatch (Fig. 1 effect 6)
    pub delta: f64,
}

impl Default for Mwc {
    fn default() -> Self {
        Self { magnitude: 0, line: Line::Idle, delta: 0.0 }
    }
}

impl Mwc {
    /// Program from a signed weight code in [-63, 63]; 0 idles the cell.
    pub fn program(w: i32) -> Self {
        let w = w.clamp(-c::CODE_MAX, c::CODE_MAX);
        let line = match w.signum() {
            1 => Line::Positive,
            -1 => Line::Negative,
            _ => Line::Idle,
        };
        Self { magnitude: w.unsigned_abs() as u8, line, delta: 0.0 }
    }

    pub fn with_delta(mut self, delta: f64) -> Self {
        self.delta = delta;
        self
    }

    /// Signed view of the stored code.
    pub fn signed_code(&self) -> i32 {
        match self.line {
            Line::Positive => self.magnitude as i32,
            Line::Negative => -(self.magnitude as i32),
            Line::Idle => 0,
        }
    }

    /// Effective conductance [S] including mismatch: W/2^B_W / R_U * (1+δ).
    /// Idle cells contribute nothing.
    pub fn conductance(&self) -> f64 {
        if self.line == Line::Idle {
            return 0.0;
        }
        self.magnitude as f64 / (1u64 << c::B_W) as f64 / c::R_U * (1.0 + self.delta)
    }

    /// Cell current [A] for a differential input voltage, split onto the
    /// (positive, negative) lines per the sign-bit routing (Eq. 3).
    pub fn current(&self, v_diff: f64) -> (f64, f64) {
        let i = v_diff * self.conductance();
        match self.line {
            Line::Positive => (i, 0.0),
            Line::Negative => (0.0, i),
            Line::Idle => (0.0, 0.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn program_routes_sign_bits() {
        assert_eq!(Mwc::program(17).line, Line::Positive);
        assert_eq!(Mwc::program(-17).line, Line::Negative);
        assert_eq!(Mwc::program(0).line, Line::Idle);
        assert_eq!(Mwc::program(17).signed_code(), 17);
        assert_eq!(Mwc::program(-17).signed_code(), -17);
    }

    #[test]
    fn program_clamps() {
        assert_eq!(Mwc::program(1000).magnitude, 63);
        assert_eq!(Mwc::program(-1000).signed_code(), -63);
    }

    #[test]
    fn idle_cell_draws_nothing() {
        let cell = Mwc::program(0);
        assert_eq!(cell.conductance(), 0.0);
        assert_eq!(cell.current(0.2), (0.0, 0.0));
    }

    #[test]
    fn conductance_scales_with_code() {
        let g1 = Mwc::program(1).conductance();
        let g63 = Mwc::program(63).conductance();
        assert!((g63 / g1 - 63.0).abs() < 1e-9);
        // full code: 63/64 / R_U
        assert!((g63 - 63.0 / 64.0 / c::R_U).abs() < 1e-15);
    }

    #[test]
    fn current_splits_by_line() {
        let v = 0.1;
        let (ip, in_) = Mwc::program(32).current(v);
        assert!(ip > 0.0 && in_ == 0.0);
        let (ip2, in2) = Mwc::program(-32).current(v);
        assert!(ip2 == 0.0 && in2 > 0.0);
        // same magnitude => same current on its line
        assert!((ip - in2).abs() < 1e-18);
    }

    #[test]
    fn mismatch_shifts_conductance() {
        let base = Mwc::program(40).conductance();
        let hi = Mwc::program(40).with_delta(0.05).conductance();
        assert!((hi / base - 1.05).abs() < 1e-12);
    }

    #[test]
    fn unit_current_matches_table1() {
        // Table I footnote: ~2.6 uA per MWC at 1 V across full-scale poly R_U?
        // Sanity: full-code cell at 1 V -> (63/64)/385k ~ 2.56 uA.
        let i = Mwc::program(63).conductance() * 1.0;
        assert!((i - 2.56e-6).abs() < 0.05e-6, "i={i}");
    }
}
